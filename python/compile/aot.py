"""AOT-lower the L2 calibration graph to HLO *text* artifacts.

HLO text (NOT ``lowered.compile()``/``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md and gen_hlo.py there.

Artifacts (consumed by rust/src/runtime/artifacts.rs):
  artifacts/lm_step.hlo.txt   — full Levenberg-Marquardt iteration
  artifacts/predict.hlo.txt   — batched model prediction
  artifacts/eval_cost.hlo.txt — masked SSE cost (LM accept/reject probe)
  artifacts/manifest.json     — shape/dtype contract shared with Rust

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402

# Padded shape contract.  Large enough for every measurement-kernel set in
# the paper's evaluation (the biggest, DG, uses ~60 rows x 21 features).
L = 128        # max measurement kernels per calibration
N = 256        # max prediction batch
J = 24         # max model features
P = J + 1      # feature cost params + p_edge
DTYPE = "float64"

MANIFEST_VERSION = 3


def _spec(shape):
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(shape, jnp.dtype(DTYPE))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_artifacts():
    """Lower all entry points; returns {name: hlo_text}."""
    scalar = _spec(())
    lowered_lm = jax.jit(model.lm_step).lower(
        _spec((L, J)), _spec((L,)), _spec((L,)), _spec((3, J)),
        _spec((P,)), scalar, scalar,
    )
    lowered_predict = jax.jit(model.predict).lower(
        _spec((N, J)), _spec((3, J)), _spec((P,)), scalar,
    )
    lowered_cost = jax.jit(model.eval_cost).lower(
        _spec((L, J)), _spec((L,)), _spec((L,)), _spec((3, J)),
        _spec((P,)), scalar,
    )
    return {
        "lm_step": to_hlo_text(lowered_lm),
        "predict": to_hlo_text(lowered_predict),
        "eval_cost": to_hlo_text(lowered_cost),
    }


def manifest() -> dict:
    return {
        "version": MANIFEST_VERSION,
        "dtype": DTYPE,
        "L": L,
        "N": N,
        "J": J,
        "P": P,
        "ridge": model.RIDGE,
        "entries": {
            "lm_step": {
                "file": "lm_step.hlo.txt",
                "args": ["F[L,J]", "t[L]", "mask[L]", "groups[3,J]",
                         "p[P]", "mode[]", "lam[]"],
                "returns": ["pred[L]", "resid[L]", "jac[L,P]",
                            "delta[P]", "cost[]"],
            },
            "predict": {
                "file": "predict.hlo.txt",
                "args": ["F[N,J]", "groups[3,J]", "p[P]", "mode[]"],
                "returns": ["pred[N]"],
            },
            "eval_cost": {
                "file": "eval_cost.hlo.txt",
                "args": ["F[L,J]", "t[L]", "mask[L]", "groups[3,J]",
                         "p[P]", "mode[]"],
                "returns": ["cost[]"],
            },
        },
    }


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    texts = build_artifacts()
    for name, text in texts.items():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text):>8} chars to {path}")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest(), f, indent=2)
    print(f"wrote manifest to {mpath}")


if __name__ == "__main__":
    main()
