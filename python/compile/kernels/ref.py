"""Pure-jnp correctness oracle for the ``perflex_eval`` Pallas kernel.

Implements the same three-cost-component model family (Eq. 6-8 of the
paper) with no Pallas involvement.  ``perflex_forward_ref`` is additionally
differentiable with ``jax.jacfwd``, which the test suite uses to validate
the hand-derived Jacobian returned by both the kernel and
``perflex_eval_ref``.
"""

from __future__ import annotations

import jax.numpy as jnp


def perflex_forward_ref(F, groups, p, mode):
    """Model forward only: pred [L].  Differentiable w.r.t. ``p``."""
    F = jnp.asarray(F)
    groups = jnp.asarray(groups, dtype=F.dtype)
    p = jnp.asarray(p, dtype=F.dtype)
    J = F.shape[1]
    w = p[:J]
    e = p[J]
    c = F @ (w[None, :] * groups).T          # [L, 3]
    o, a, b = c[:, 0], c[:, 1], c[:, 2]
    u = a - b
    denom = a + b + jnp.asarray(1e-30, dtype=F.dtype)
    s1 = (jnp.tanh(e * u / denom) + 1.0) * 0.5
    pred_nl = o + b + u * s1
    pred_lin = o + a + b
    return mode * pred_nl + (1.0 - mode) * pred_lin


def perflex_eval_ref(F, groups, p, mode):
    """Forward + closed-form Jacobian, pure jnp: (pred [L], jac [L, J+1])."""
    F = jnp.asarray(F)
    groups = jnp.asarray(groups, dtype=F.dtype)
    p = jnp.asarray(p, dtype=F.dtype)
    mode = jnp.asarray(mode, dtype=F.dtype)
    J = F.shape[1]
    w = p[:J]
    e = p[J]
    c = F @ (w[None, :] * groups).T
    o, a, b = c[:, 0], c[:, 1], c[:, 2]
    eps = jnp.asarray(1e-30, dtype=F.dtype)
    u = a - b
    denom = a + b + eps
    r = u / denom
    th = jnp.tanh(e * r)
    s1 = (th + 1.0) * 0.5
    sech2 = 1.0 - th * th
    dr_da = 2.0 * b / (denom * denom)
    dr_db = -2.0 * a / (denom * denom)
    half_e_sech2 = 0.5 * e * sech2

    pred = mode * (o + b + u * s1) + (1.0 - mode) * (o + a + b)

    da = mode * (s1 + u * half_e_sech2 * dr_da) + (1.0 - mode)
    db = mode * (1.0 - s1 + u * half_e_sech2 * dr_db) + (1.0 - mode)
    de = mode * (0.5 * u * r * sech2)
    coef = (
        groups[0][None, :]
        + da[:, None] * groups[1][None, :]
        + db[:, None] * groups[2][None, :]
    )
    jac = jnp.concatenate([F * coef, de[:, None]], axis=1)
    return pred, jac
