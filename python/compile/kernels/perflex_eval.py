"""L1 Pallas kernel: blocked, batched Perflex cost-model forward + Jacobian.

This is the compute hot-spot of the paper's calibration loop (Section 7.2):
for every measurement kernel k we evaluate the model

    pred_k = g(features_k, p)

for the paper's three-cost-component model family and its closed-form
Jacobian d pred_k / d p.  Two model forms are supported, mixed by a traced
``mode`` scalar so a single AOT artifact serves both:

  linear     (Eq. 7):  pred = c_overhead + c_gmem + c_onchip
  nonlinear  (Eq. 8):  pred = c_overhead + c_gmem * s(c_gmem - c_onchip)
                              + c_onchip * s(c_onchip - c_gmem)

with a *scale-invariant* variant of the differentiable step (Eq. 6; the
paper notes variations of its Eq. 6 are admissible):

    s(u) = (tanh(p_edge * u / (a + b + eps)) + 1) / 2,  u = a - b,

so the switch depends only on the cost *ratio* — making the model
consistent between calibration on output-scaled features (Sec. 7.2) and
prediction on raw feature values.  Using s(-u) = 1 - s(u):

    pred_nl = o + b + u * s(u),   a = c_gmem, b = c_onchip.

Cost components are group-masked weighted feature sums:

    c_g = F @ (w * groups[g]),   w = p[:J],  p_edge = p[J].

TPU adaptation note (DESIGN.md §Hardware-Adaptation): rather than the GPU
one-thread-per-row mapping a CUDA port would use, the feature matrix is
tiled into VMEM-resident row blocks via BlockSpec; the group reductions are
expressed as a dense [BL,J]x[J,3] contraction (MXU-eligible) and the
tanh-switch + Jacobian are fused element-wise (VPU) work on the same
resident block — one HBM->VMEM pass per block.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot run
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _eval_block_kernel(f_ref, groups_ref, p_ref, mode_ref, pred_ref, jac_ref):
    """Pallas kernel body: one [BL, J] row-block of the feature matrix."""
    F = f_ref[...]                      # [BL, J]   VMEM-resident block
    G = groups_ref[...]                 # [3, J]    group one-hot masks
    p = p_ref[...]                      # [J + 1]   weights + p_edge
    mode = mode_ref[0]                  # 0.0 = linear, 1.0 = nonlinear

    J = G.shape[1]
    w = p[:J]
    e = p[J]

    # Cost components: c[:, g] = F @ (w * G[g]).  Contraction -> MXU.
    wg = w[None, :] * G                 # [3, J]
    c = jnp.dot(F, wg.T, preferred_element_type=F.dtype)  # [BL, 3]
    o, a, b = c[:, 0], c[:, 1], c[:, 2]

    # Scale-invariant step switch and closed-form derivatives.
    eps = jnp.asarray(1e-30, dtype=F.dtype)
    u = a - b
    denom = a + b + eps
    r = u / denom
    th = jnp.tanh(e * r)
    s1 = (th + 1.0) * 0.5               # s(u); s(-u) = 1 - s1
    sech2 = 1.0 - th * th
    # dr/da = 2b/denom^2, dr/db = -2a/denom^2.
    dr_da = 2.0 * b / (denom * denom)
    dr_db = -2.0 * a / (denom * denom)
    half_e_sech2 = 0.5 * e * sech2

    pred_nl = o + b + u * s1            # Eq. 8
    pred_lin = o + a + b                # Eq. 7
    pred = mode * pred_nl + (1.0 - mode) * pred_lin

    # d pred / d c_g, mixed across the two model forms.
    da_nl = s1 + u * half_e_sech2 * dr_da
    db_nl = 1.0 - s1 + u * half_e_sech2 * dr_db
    da = mode * da_nl + (1.0 - mode)
    db = mode * db_nl + (1.0 - mode)
    de = mode * (0.5 * u * r * sech2)   # d pred / d p_edge

    # d pred / d w_j = F[:, j] * (G0_j + da * G1_j + db * G2_j).
    coef = (
        G[0][None, :]
        + da[:, None] * G[1][None, :]
        + db[:, None] * G[2][None, :]
    )                                   # [BL, J]
    jac_w = F * coef

    pred_ref[...] = pred
    jac_ref[...] = jnp.concatenate([jac_w, de[:, None]], axis=1)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def _perflex_eval_padded(F, groups, p, mode_arr, *, block_rows):
    L, J = F.shape
    P = J + 1
    grid = (L // block_rows,)
    return pl.pallas_call(
        _eval_block_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, J), lambda i: (i, 0)),
            pl.BlockSpec((3, J), lambda i: (0, 0)),
            pl.BlockSpec((P,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, P), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L,), F.dtype),
            jax.ShapeDtypeStruct((L, P), F.dtype),
        ],
        interpret=True,
    )(F, groups, p, mode_arr)


def perflex_eval(F, groups, p, mode, *, block_rows=32):
    """Batched model forward + Jacobian via the Pallas kernel.

    Args:
      F:      [L, J] feature-value matrix (row = measurement kernel).
      groups: [3, J] one-hot masks assigning feature j to cost component
              (0 = overhead, 1 = gmem, 2 = onchip).
      p:      [J + 1] parameters; p[:J] feature costs, p[J] = p_edge.
      mode:   scalar in [0, 1]; 0 = linear (Eq. 7), 1 = nonlinear (Eq. 8).

    Returns:
      (pred [L], jac [L, J + 1]).
    """
    F = jnp.asarray(F)
    groups = jnp.asarray(groups, dtype=F.dtype)
    p = jnp.asarray(p, dtype=F.dtype)
    mode_arr = jnp.asarray(mode, dtype=F.dtype).reshape((1,))

    L, J = F.shape
    bl = min(block_rows, L)
    pad = (-L) % bl
    if pad:
        # Zero rows are harmless: c = 0 -> pred = 0, jac row = 0.
        F = jnp.concatenate([F, jnp.zeros((pad, J), F.dtype)], axis=0)
    pred, jac = _perflex_eval_padded(F, groups, p, mode_arr, block_rows=bl)
    return pred[:L], jac[:L]
