"""L2: JAX compute graph for Perflex model calibration and prediction.

Calls the L1 Pallas kernel (``kernels.perflex_eval``) for the batched
forward + Jacobian, then fuses the surrounding Levenberg-Marquardt step so
that one AOT executable performs a full LM iteration (Section 7.2 of the
paper): residual, Jacobian, damped-normal-equation solve, step and cost.

The Rust coordinator owns the LM *loop* (accept/reject, damping schedule);
Python is never on that path — these functions are lowered once by
``aot.py`` to HLO text artifacts with fixed, padded shapes.

Shape/padding contract (must match rust/src/runtime/artifacts.rs):
  * rows are padded to L with ``mask`` zero on padding rows;
  * feature columns are padded to J; unused columns have all-zero F and
    group masks, and the ridge term pins their delta to exactly 0;
  * p has length J + 1, the trailing entry being p_edge (Eq. 6);
  * ``mode`` selects the model family: 0 = linear Eq. 7, 1 = nonlinear
    Eq. 8 (intermediate values give a homotopy, used by tests only).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.perflex_eval import perflex_eval  # noqa: E402

#: Ridge added to the damped normal equations.  Feature values are scaled
#: to O(1) by the Rust caller, so A entries are O(L); 1e-9 is negligible
#: for active columns but pins all-zero (padding) columns to delta = 0.
RIDGE = 1e-9


def spd_solve(M, g):
    """Solve ``M x = g`` for symmetric positive-definite ``M``.

    Statically-unrolled Gauss-Jordan elimination without pivoting: the
    damped normal equations are SPD + ridge, so pivoting is unnecessary.
    Deliberately NOT ``jnp.linalg.solve`` — that lowers to a LAPACK
    typed-FFI custom-call (API_VERSION_TYPED_FFI) which the runtime's
    xla_extension 0.5.1 rejects; this version lowers to plain HLO ops.
    """
    P = M.shape[0]
    A = jnp.concatenate([M, g[:, None]], axis=1)  # [P, P+1]
    for k in range(P):
        row = A[k] / A[k, k]
        A = A - A[:, k : k + 1] * row[None, :]
        A = A.at[k].set(row)
    return A[:, P]


def lm_step(F, t, mask, groups, p, mode, lam):
    """One Levenberg-Marquardt step for min_p || mask * (t - g(F, p)) ||.

    Args:
      F:      [L, J] feature matrix (padded).
      t:      [L]    target output feature (1.0 after output scaling).
      mask:   [L]    1.0 for real measurement-kernel rows, 0.0 padding.
      groups: [3, J] cost-component masks (overhead, gmem, onchip).
      p:      [J+1]  current parameters (p[J] = p_edge).
      mode:   scalar, 0 = linear model, 1 = nonlinear overlap model.
      lam:    scalar Marquardt damping.

    Returns:
      (pred [L], resid [L], jac [L, J+1], delta [J+1], cost scalar)
      where p + delta is the proposed next iterate and cost = sum resid^2.
    """
    pred, jac = perflex_eval(F, groups, p, mode)
    resid = (t - pred) * mask
    Jm = jac * mask[:, None]
    A = Jm.T @ Jm
    g = Jm.T @ resid
    P = A.shape[0]
    M = A + lam * jnp.diag(jnp.diag(A)) + RIDGE * jnp.eye(P, dtype=A.dtype)
    delta = spd_solve(M, g)
    cost = jnp.sum(resid * resid)
    return pred, resid, jac, delta, cost


def predict(F, groups, p, mode):
    """Batched model prediction (no Jacobian consumers): pred [N]."""
    pred, _ = perflex_eval(F, groups, p, mode)
    return pred


def eval_cost(F, t, mask, groups, p, mode):
    """Masked sum-of-squares cost at ``p`` (used for LM accept/reject)."""
    pred, _ = perflex_eval(F, groups, p, mode)
    resid = (t - pred) * mask
    return jnp.sum(resid * resid)
