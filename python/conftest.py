import os
import sys

# Allow `pytest python/tests` from the repository root.
sys.path.insert(0, os.path.dirname(__file__))
