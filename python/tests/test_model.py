"""L2 tests: the fused Levenberg-Marquardt step and prediction graph."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import perflex_forward_ref


def _synthetic(L=48, J=8, seed=0, mode=1.0, noise=0.0):
    """Feature data generated from known ground-truth parameters."""
    rng = np.random.default_rng(seed)
    F = rng.uniform(0.2, 2.0, size=(L, J))
    groups = np.zeros((3, J))
    groups[0, 0] = 1.0
    groups[1, 1 : J // 2] = 1.0
    groups[2, J // 2 :] = 1.0
    p_true = np.concatenate([rng.uniform(0.1, 1.0, size=J), [8.0]])
    t = np.asarray(perflex_forward_ref(F, groups, p_true, mode))
    if noise:
        t = t * (1.0 + noise * rng.standard_normal(L))
    mask = np.ones(L)
    return F, t, mask, groups, p_true


def _run_lm(F, t, mask, groups, p0, mode, iters=60):
    """Reference LM driver (mirrors the Rust loop in calibrate/)."""
    p = jnp.asarray(p0)
    lam = 1e-3
    _, _, _, _, cost = model.lm_step(F, t, mask, groups, p, mode, lam)
    for _ in range(iters):
        _, _, _, delta, cost = model.lm_step(F, t, mask, groups, p, mode, lam)
        p_new = p + delta
        new_cost = model.eval_cost(F, t, mask, groups, p_new, mode)
        if new_cost < cost:
            p, cost, lam = p_new, new_cost, max(lam / 3.0, 1e-12)
        else:
            lam = min(lam * 5.0, 1e8)
    return np.asarray(p), float(cost)


def test_lm_recovers_linear_parameters_exactly():
    F, t, mask, groups, p_true = _synthetic(mode=0.0, seed=1)
    p0 = np.full_like(p_true, 0.5)
    p, cost = _run_lm(F, t, mask, groups, p0, mode=0.0)
    assert cost < 1e-18
    np.testing.assert_allclose(p[:-1], p_true[:-1], rtol=1e-6)


def test_lm_fits_nonlinear_overlap_model():
    F, t, mask, groups, p_true = _synthetic(mode=1.0, seed=2)
    p0 = np.concatenate([np.full(len(p_true) - 1, 0.5), [5.0]])
    p, cost = _run_lm(F, t, mask, groups, p0, mode=1.0, iters=120)
    pred = np.asarray(perflex_forward_ref(F, groups, p, 1.0))
    rel = np.abs(pred - t) / np.abs(t)
    assert np.max(rel) < 1e-3, f"max rel err {np.max(rel)}"


def test_lm_step_decreases_cost_from_far_start():
    F, t, mask, groups, _ = _synthetic(mode=1.0, seed=3)
    p0 = np.concatenate([np.full(len(groups[0]), 3.0), [1.0]])
    _, cost0 = _run_lm(F, t, mask, groups, p0, mode=1.0, iters=1)
    _, cost40 = _run_lm(F, t, mask, groups, p0, mode=1.0, iters=40)
    assert cost40 < cost0


@settings(max_examples=15, deadline=None)
@given(
    extra=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_row_padding_does_not_change_step(extra, seed):
    """mask=0 rows (the padding contract with Rust) must be inert."""
    F, t, mask, groups, p_true = _synthetic(L=24, seed=seed)
    p0 = np.full_like(p_true, 0.4)
    rng = np.random.default_rng(seed)
    Fp = np.concatenate([F, rng.uniform(0, 5, size=(extra, F.shape[1]))])
    tp = np.concatenate([t, rng.uniform(0, 5, size=extra)])
    mp = np.concatenate([mask, np.zeros(extra)])

    out_a = model.lm_step(F, t, mask, groups, p0, 1.0, 1e-3)
    out_b = model.lm_step(Fp, tp, mp, groups, p0, 1.0, 1e-3)
    np.testing.assert_allclose(out_a[3], out_b[3], rtol=1e-9)  # delta
    np.testing.assert_allclose(out_a[4], out_b[4], rtol=1e-12)  # cost


def test_column_padding_pins_unused_params():
    """All-zero feature columns (padding contract) get delta exactly ~0."""
    F, t, mask, groups, p_true = _synthetic(L=24, J=6, seed=5)
    Jpad = 4
    Fp = np.concatenate([F, np.zeros((F.shape[0], Jpad))], axis=1)
    gp = np.concatenate([groups, np.zeros((3, Jpad))], axis=1)
    p0 = np.concatenate([np.full(6, 0.4), np.zeros(Jpad), [8.0]])
    _, _, _, delta, _ = model.lm_step(Fp, t, mask, gp, p0, 1.0, 1e-3)
    np.testing.assert_allclose(delta[6 : 6 + Jpad], 0.0, atol=1e-12)


def test_predict_matches_forward_ref():
    F, t, mask, groups, p_true = _synthetic(seed=6)
    pred = model.predict(F, groups, p_true, 1.0)
    ref = perflex_forward_ref(F, groups, p_true, 1.0)
    np.testing.assert_allclose(pred, ref, rtol=1e-12)


def test_eval_cost_consistent_with_lm_step():
    F, t, mask, groups, p_true = _synthetic(seed=7, noise=0.05)
    p0 = p_true * 1.3
    *_, cost = model.lm_step(F, t, mask, groups, p0, 1.0, 1e-3)
    cost2 = model.eval_cost(F, t, mask, groups, p0, 1.0)
    np.testing.assert_allclose(float(cost), float(cost2), rtol=1e-12)


def test_output_scaled_calibration_matches_paper_scaling():
    """scale_features_by_output(): divide F rows by t, target becomes 1."""
    F, t, mask, groups, p_true = _synthetic(mode=0.0, seed=8)
    Fs = F / t[:, None]
    ts = np.ones_like(t)
    p0 = np.full_like(p_true, 0.5)
    p, cost = _run_lm(Fs, ts, mask, groups, p0, mode=0.0)
    assert cost < 1e-18
    np.testing.assert_allclose(p[:-1], p_true[:-1], rtol=1e-6)
