"""AOT artifact generation: HLO text structure and manifest contract."""

import json

import numpy as np
import pytest

from compile import aot, model
from compile.kernels.ref import perflex_forward_ref


@pytest.fixture(scope="module")
def texts():
    return aot.build_artifacts()


def test_all_entries_lower_to_hlo_text(texts):
    for name in ("lm_step", "predict", "eval_cost"):
        text = texts[name]
        assert "ENTRY" in text, name
        assert "f64" in text, name
        assert len(text) > 500, name


def test_lm_step_signature_shapes(texts):
    text = texts["lm_step"]
    # Inputs: F[L,J], t[L], mask[L], groups[3,J], p[P], mode, lam.
    assert f"f64[{aot.L},{aot.J}]" in text
    assert f"f64[3,{aot.J}]" in text
    assert f"f64[{aot.P}]" in text
    # Jacobian output and the PxP normal-equation solve must be present.
    assert f"f64[{aot.L},{aot.P}]" in text
    assert f"f64[{aot.P},{aot.P}]" in text


def test_predict_signature_shapes(texts):
    assert f"f64[{aot.N},{aot.J}]" in texts["predict"]


def test_manifest_matches_module_constants():
    m = aot.manifest()
    assert m["L"] == aot.L and m["J"] == aot.J and m["P"] == aot.J + 1
    assert m["dtype"] == "float64"
    assert set(m["entries"]) == {"lm_step", "predict", "eval_cost"}
    json.dumps(m)  # serializable


def test_padded_full_shape_execution():
    """Run lm_step at the exact artifact shapes (what Rust will feed)."""
    rng = np.random.default_rng(0)
    L, J, P = aot.L, aot.J, aot.P
    rows, cols = 40, 10
    F = np.zeros((L, J))
    F[:rows, :cols] = rng.uniform(0.2, 2.0, size=(rows, cols))
    groups = np.zeros((3, J))
    groups[0, 0] = 1
    groups[1, 1:5] = 1
    groups[2, 5:cols] = 1
    p_true = np.zeros(P)
    p_true[:cols] = rng.uniform(0.1, 1.0, size=cols)
    p_true[-1] = 8.0
    t = np.zeros(L)
    t[:rows] = np.asarray(
        perflex_forward_ref(F[:rows], groups, p_true, 1.0)
    )
    mask = np.zeros(L)
    mask[:rows] = 1.0

    pred, resid, jac, delta, cost = model.lm_step(
        F, t, mask, groups, p_true, 1.0, 1e-3
    )
    assert pred.shape == (L,) and jac.shape == (L, P)
    np.testing.assert_allclose(np.asarray(resid)[:rows], 0.0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(delta), 0.0, atol=1e-9)
    assert float(cost) < 1e-20
