"""Kernel-vs-reference correctness: the CORE L1 signal.

Hypothesis sweeps shapes/dtypes of the Pallas ``perflex_eval`` kernel and
asserts allclose against the pure-jnp oracle (ref.py), and validates the
hand-derived Jacobian against ``jax.jacfwd`` of the reference forward.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.perflex_eval import perflex_eval
from compile.kernels.ref import perflex_eval_ref, perflex_forward_ref


def _problem(L, J, seed, dtype):
    rng = np.random.default_rng(seed)
    F = rng.uniform(0.0, 2.0, size=(L, J)).astype(dtype)
    # Random (not necessarily one-hot) group masks exercise generality.
    groups = rng.uniform(0.0, 1.0, size=(3, J)).astype(dtype)
    p = np.concatenate(
        [rng.uniform(0.01, 1.0, size=J), rng.uniform(0.5, 20.0, size=1)]
    ).astype(dtype)
    return F, groups, p


TOL = {np.float32: dict(rtol=2e-5, atol=2e-5),
       np.float64: dict(rtol=1e-12, atol=1e-12)}


@settings(max_examples=60, deadline=None)
@given(
    L=st.integers(min_value=1, max_value=70),
    J=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    mode=st.sampled_from([0.0, 1.0, 0.37]),
    dtype=st.sampled_from([np.float32, np.float64]),
    block_rows=st.sampled_from([1, 8, 32]),
)
def test_kernel_matches_ref(L, J, seed, mode, dtype, block_rows):
    F, groups, p = _problem(L, J, seed, dtype)
    pred_k, jac_k = perflex_eval(F, groups, p, mode, block_rows=block_rows)
    pred_r, jac_r = perflex_eval_ref(F, groups, p, mode)
    tol = TOL[dtype]
    np.testing.assert_allclose(pred_k, pred_r, **tol)
    np.testing.assert_allclose(jac_k, jac_r, **tol)
    assert pred_k.shape == (L,)
    assert jac_k.shape == (L, J + 1)
    assert pred_k.dtype == np.dtype(dtype)


@settings(max_examples=30, deadline=None)
@given(
    L=st.integers(min_value=1, max_value=24),
    J=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    mode=st.sampled_from([0.0, 1.0, 0.5]),
)
def test_closed_form_jacobian_matches_autodiff(L, J, seed, mode):
    F, groups, p = _problem(L, J, seed, np.float64)
    _, jac_k = perflex_eval(F, groups, p, mode)
    jac_ad = jax.jacfwd(lambda pp: perflex_forward_ref(F, groups, pp, mode))(
        jnp.asarray(p)
    )
    np.testing.assert_allclose(jac_k, jac_ad, rtol=1e-9, atol=1e-9)


def test_linear_mode_is_plain_weighted_sum():
    F, groups, p = _problem(17, 6, 0, np.float64)
    pred, jac = perflex_eval(F, groups, p, 0.0)
    w = p[:6]
    expected = F @ (w * groups.sum(axis=0))
    np.testing.assert_allclose(pred, expected, rtol=1e-12)
    # Linear model: no p_edge sensitivity.
    np.testing.assert_allclose(jac[:, -1], 0.0, atol=0.0)


def test_nonlinear_mode_approximates_max_for_large_edge():
    """Eq. 8 with sharp step ~= overhead + max(c_gmem, c_onchip) (Eq. 3)."""
    rng = np.random.default_rng(7)
    J = 6
    F = rng.uniform(0.5, 2.0, size=(40, J))
    groups = np.zeros((3, J))
    groups[0, 0] = 1.0          # overhead
    groups[1, 1:3] = 1.0        # gmem
    groups[2, 3:] = 1.0         # onchip
    p = np.concatenate([rng.uniform(0.1, 1.0, size=J), [1e4]])
    pred, _ = perflex_eval(F, groups, p, 1.0)
    w = p[:J]
    c = F @ (w[None, :] * groups).T
    expected = c[:, 0] + np.maximum(c[:, 1], c[:, 2])
    np.testing.assert_allclose(pred, expected, rtol=1e-6)


def test_step_function_figure4_shape():
    """The scale-invariant switch s(u) = (tanh(p_edge u/(a+b))+1)/2 is
    monotone in the gmem share and hits 0/0.5/1 at the extremes (the
    shape of the paper's Figure 4, in ratio coordinates)."""
    # a sweeps 0..1 while b = 1-a: r = a-b spans -1..1.
    a = np.linspace(0.0, 1.0, 41)
    F = np.stack([a, 1.0 - a], axis=1)
    groups = np.array([[0.0, 0.0], [1.0, 0.0], [0.0, 1.0]])
    p = np.array([1.0, 1.0, 10.0])
    pred, _ = perflex_eval(F, groups, p, 1.0)
    # pred = b + (a-b) * s(r); recover s where a != b.
    u = 2.0 * a - 1.0
    s = np.where(np.abs(u) > 1e-12, (np.asarray(pred) - (1.0 - a)) / u, 0.5)
    assert s[0] == pytest.approx(0.0, abs=1e-8)      # all on-chip
    assert s[-1] == pytest.approx(1.0, abs=1e-8)     # all gmem
    assert s[20] == pytest.approx(0.5, abs=1e-9)     # balanced
    assert np.all(np.diff(s) >= -1e-9)               # monotone


def test_padding_rows_are_inert():
    F, groups, p = _problem(33, 5, 3, np.float64)   # 33 pads to 64 / 44
    pred, jac = perflex_eval(F, groups, p, 1.0, block_rows=32)
    pred2, jac2 = perflex_eval(F, groups, p, 1.0, block_rows=11)
    np.testing.assert_allclose(pred, pred2, rtol=1e-12)
    np.testing.assert_allclose(jac, jac2, rtol=1e-12)
