//! Equivalence suite for the access-pattern features
//! (`f_mem_transactions[_tag:<t>]`, `f_bank_conflict_factor`) across
//! their three evaluation paths:
//!
//! 1. direct [`FeatureSpec::eval`] over the exact `QPoly`,
//! 2. the batched [`BoundFeature::eval`] path — must be *bit-for-bit*
//!    identical to (1), and
//! 3. the lowered [`CompiledFeature`] flat-plan path — must agree with
//!    (1) within `COMPILED_REL_ERR_BOUND` relative error.
//!
//! Checked across the paper's app kernels (both coalesced and strided
//! variants), a synthetic parametric-stride kernel, every device of
//! the Table 2 fleet (whose sub-group sizes differ), and several
//! problem sizes per kernel.

use std::collections::BTreeMap;

use perflex::features::{BoundFeature, CompiledFeature, FeatureSpec};
use perflex::gpusim::fleet;
use perflex::ir::{Access, AffExpr, ArrayDecl, DType, Expr, IndexTag, Kernel, LhsRef, Stmt};
use perflex::model::COMPILED_REL_ERR_BOUND;
use perflex::polyhedral::{LoopExtent, NestedDomain, QPoly};
use perflex::uipick::apps::{build_dg, build_fdiff, build_matmul, build_transpose, DgVariant};

fn env(pairs: &[(&str, i128)]) -> BTreeMap<String, i128> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

fn rel_diff(a: f64, b: f64) -> f64 {
    if a == b {
        0.0
    } else {
        (a - b).abs() / a.abs().max(b.abs())
    }
}

/// A 16x16 work-group storing into an `n x n` global array transposed
/// (lid(0) stride is the *parametric* row pitch `n` — exercises the
/// sampled-stride fallback) plus a 16-way bank-conflicted local store.
fn strided_kernel() -> Kernel {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("li0", QPoly::int(16)),
        LoopExtent::zero_to("li1", QPoly::int(16)),
    ]);
    let mut k = Kernel::new("strided", &["n"], dom);
    k.iname_tags.insert("li0".into(), IndexTag::Local(0));
    k.iname_tags.insert("li1".into(), IndexTag::Local(1));
    k.add_array(ArrayDecl::global(
        "gout",
        DType::F32,
        vec![n.clone(), n],
    ));
    k.add_array(ArrayDecl::local("tile", DType::F32, vec![QPoly::int(4096)]));
    k.add_stmt(Stmt::new(
        "gst",
        LhsRef::Array(Access::tagged(
            "gout",
            "st_out",
            vec![AffExpr::var("li0"), AffExpr::var("li1")],
        )),
        Expr::fconst(1.0),
        &[],
    ));
    k.add_stmt(Stmt::new(
        "lst",
        LhsRef::Array(Access::new(
            "tile",
            vec![AffExpr::scaled_var("li0", 16)
                .plus(&AffExpr::scaled_var("li1", 256))],
        )),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

#[test]
fn access_features_compiled_matches_exact_across_fleet() {
    let base = vec![
        "f_mem_transactions".to_string(),
        "f_bank_conflict_factor".to_string(),
    ];
    let with_tag = |t: &str| {
        let mut ids = base.clone();
        ids.push(format!("f_mem_transactions_tag:{t}"));
        ids
    };
    let n_envs = |ns: &[i128]| -> Vec<BTreeMap<String, i128>> {
        ns.iter().map(|&n| env(&[("n", n)])).collect()
    };
    let dg_envs = vec![
        env(&[("nelements", 32768), ("nmatrices", 3)]),
        env(&[("nelements", 131072), ("nmatrices", 3)]),
    ];

    let cases: Vec<(&str, Kernel, Vec<String>, Vec<BTreeMap<String, i128>>)> = vec![
        (
            "matmul/prefetch",
            build_matmul(DType::F32, true, 16).unwrap(),
            with_tag("mm_pf_a"),
            n_envs(&[1024, 2048, 3584]),
        ),
        (
            "matmul/no_prefetch",
            build_matmul(DType::F32, false, 16).unwrap(),
            base.clone(),
            n_envs(&[1024, 2048]),
        ),
        (
            "fdiff/16x16",
            build_fdiff(16).unwrap(),
            base.clone(),
            n_envs(&[2016, 4032]),
        ),
        (
            "dg/plain",
            build_dg(DgVariant::Plain, 64, 16).unwrap(),
            base.clone(),
            dg_envs.clone(),
        ),
        (
            "dg/u_prefetch",
            build_dg(DgVariant::UPrefetch, 64, 16).unwrap(),
            base.clone(),
            dg_envs,
        ),
        (
            "transpose",
            build_transpose(16).unwrap(),
            base.clone(),
            n_envs(&[1024, 4096]),
        ),
        (
            "strided",
            strided_kernel(),
            with_tag("st_out"),
            n_envs(&[64, 1000]),
        ),
    ];

    // Sanity counters: the sweep must exercise non-trivial values on
    // both families, or the equivalence assertions prove nothing.
    let mut max_txn = 0.0f64;
    let mut max_bank = 0.0f64;
    let mut combos = 0usize;

    for dev in fleet() {
        for (label, k, ids, envs) in &cases {
            let stats = perflex::stats::gather(k, dev.sub_group_size)
                .unwrap_or_else(|e| panic!("{label} on {}: {e}", dev.id));
            let specs: Vec<FeatureSpec> =
                ids.iter().map(|id| FeatureSpec::parse(id).unwrap()).collect();
            let bounds: Vec<BoundFeature> =
                specs.iter().map(|s| s.bind(&stats).unwrap()).collect();
            // One slot table shared by all features of this kernel,
            // exactly as CompiledModel shares one across its columns.
            let mut names: Vec<String> = Vec::new();
            let compiled: Vec<CompiledFeature> = {
                let mut slot = |nm: &str| -> u32 {
                    if let Some(i) = names.iter().position(|x| x == nm) {
                        i as u32
                    } else {
                        names.push(nm.to_string());
                        (names.len() - 1) as u32
                    }
                };
                bounds.iter().map(|b| b.lower(&stats, &mut slot)).collect()
            };
            for e in envs {
                let vals: Vec<f64> = names
                    .iter()
                    .map(|nm| {
                        *e.get(nm).unwrap_or_else(|| {
                            panic!("{label}: no env value for slot '{nm}'")
                        }) as f64
                    })
                    .collect();
                for (i, id) in ids.iter().enumerate() {
                    let direct = specs[i].eval(&stats, e).unwrap();
                    let batched = bounds[i].eval(&stats, e);
                    assert_eq!(
                        direct.to_bits(),
                        batched.to_bits(),
                        "{label} {id} on {}: bound path diverged \
                         ({direct} vs {batched})",
                        dev.id
                    );
                    let fast = compiled[i].eval(&vals);
                    assert!(
                        rel_diff(direct, fast) <= COMPILED_REL_ERR_BOUND,
                        "{label} {id} on {}: compiled {fast} vs exact \
                         {direct} (rel {})",
                        dev.id,
                        rel_diff(direct, fast)
                    );
                    if id.starts_with("f_mem_transactions") {
                        max_txn = max_txn.max(direct);
                    } else {
                        max_bank = max_bank.max(direct);
                    }
                    combos += 1;
                }
            }
        }
    }
    assert!(combos >= 5 * 7 * 2 * 2, "only {combos} combos checked");
    assert!(max_txn > 0.0, "transaction feature never non-zero");
    assert!(max_bank > 0.0, "bank-conflict feature never non-zero");
}
