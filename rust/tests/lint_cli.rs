//! Exit-code matrix for `perflex lint`, driven through the real
//! binary (`CARGO_BIN_EXE_perflex`):
//!
//! | code | meaning                                           |
//! |------|---------------------------------------------------|
//! | 0    | clean, or Warn-severity findings only             |
//! | 1    | Error-severity findings (defects, infeasibility)  |
//! | 2    | usage mistakes (bad flags, unknown device/tag)    |
//! | 3    | structurally malformed kernel (MALFORMED_KERNEL)  |
//!
//! Code 3 cannot be reached through the CLI's shipped generators —
//! every inventory kernel is well-formed by construction — so it is
//! covered at the library level by
//! `tests/analysis_verifier.rs::malformed_kernel_is_the_only_diagnostic_for_broken_structure`;
//! here we pin the other three codes and that warnings do *not*
//! escalate the exit code.

use std::process::{Command, Output};

fn perflex(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_perflex"))
        .args(args)
        .output()
        .expect("failed to launch perflex binary")
}

fn stdout(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

#[test]
fn exit_0_on_clean_inventory_subset() {
    let out = perflex(&["lint", "matmul_sq"]);
    assert_eq!(out.status.code(), Some(0), "{}", stdout(&out));
    let text = stdout(&out);
    assert!(
        text.contains("0 error(s), 0 warning(s)"),
        "matmul_sq should lint spotless:\n{text}"
    );
}

#[test]
fn exit_0_with_warn_severity_findings_only() {
    // The transposed store is genuinely uncoalesced: the lint reports
    // it, but warnings never fail the gate.
    let out = perflex(&["lint", "transpose_sq"]);
    assert_eq!(out.status.code(), Some(0), "{}", stdout(&out));
    let text = stdout(&out);
    assert!(text.contains("UNCOALESCED_GLOBAL"), "{text}");
    assert!(text.contains("0 error(s)"), "{text}");
    assert!(!text.contains("0 warning(s)"), "{text}");
}

#[test]
fn exit_1_on_error_severity_findings() {
    // The 18x18 stencil tile (324 work-items) exceeds AMD's 256-item
    // limit, an Error-severity WG_SIZE_EXCEEDED under --all-devices.
    let out = perflex(&["lint", "--all-devices", "fdiff_2d5pt"]);
    assert_eq!(out.status.code(), Some(1), "{}", stdout(&out));
    assert!(stdout(&out).contains("WG_SIZE_EXCEEDED"), "{}", stdout(&out));
}

#[test]
fn exit_2_on_usage_errors() {
    // Mutually exclusive device selectors.
    let out = perflex(&["lint", "--device", "titan_v", "--all-devices"]);
    assert_eq!(out.status.code(), Some(2));
    // Unknown device id.
    let out = perflex(&["lint", "--device", "no_such_device"]);
    assert_eq!(out.status.code(), Some(2));
}

#[test]
fn json_report_is_schema_v3_and_byte_stable() {
    // Two identical runs must produce byte-identical reports: the CI
    // lint gate diffs consecutive --all-devices runs.
    let a = perflex(&["lint", "--all-devices", "--json", "fdiff_2d5pt"]);
    let b = perflex(&["lint", "--all-devices", "--json", "fdiff_2d5pt"]);
    assert_eq!(a.status.code(), Some(1));
    assert_eq!(b.status.code(), Some(1));
    let (ja, jb) = (stdout(&a), stdout(&b));
    assert_eq!(ja, jb, "lint --json output is not deterministic");
    assert!(ja.contains("\"version\":3"), "{ja}");
    assert!(ja.contains("\"feasibility\""), "{ja}");
}
