//! Integration test: the AOT JAX/Pallas calibration path must agree
//! with the native Rust evaluator, and both must calibrate a real
//! measurement set from the simulated fleet.
//!
//! Requires `make artifacts` (skips gracefully if not built).

use perflex::calibrate::{
    gather_feature_values, FeatureData, LmBackend, LmOptions,
};
use perflex::gpusim::device_by_id;
use perflex::model::{CostGroup, CostModel};
use perflex::runtime::{
    artifacts_available, fit_cost_model_aot, fit_cost_model_native, AotBackend,
    Artifacts,
};
use perflex::uipick::KernelCollection;
use perflex::util::Rng;

fn synthetic_cost_model() -> CostModel {
    CostModel::new("titan_v", true)
        .term("launch", "f_sync_kernel_launch", CostGroup::Overhead)
        .term("gmem", "f_mem_access_tag:patLD", CostGroup::Gmem)
        .term("madd", "f_op_float32_madd", CostGroup::OnChip)
}

fn synthetic_data(seed: u64, rows: usize) -> FeatureData {
    let cm = synthetic_cost_model();
    let mut rng = Rng::new(seed);
    let mut data = FeatureData {
        feature_ids: cm.feature_columns(),
        ..Default::default()
    };
    for _ in 0..rows {
        let f: Vec<f64> = (0..3).map(|_| rng.uniform_in(0.3, 3.0)).collect();
        // Ground truth: overlap model (scale-invariant switch) with
        // known params.
        let (o, a, b) = (0.05 * f[0], 0.8 * f[1], 0.5 * f[2]);
        let u: f64 = a - b;
        let s1 = ((18.0 * u / (a + b + 1e-30)).tanh() + 1.0) / 2.0;
        data.rows.push(f);
        data.outputs.push(o + b + u * s1);
        data.labels.push("syn".into());
    }
    data
}

#[test]
fn aot_backend_matches_native_backend_stepwise() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let artifacts = Artifacts::load().expect("artifacts load");
    let cm = synthetic_cost_model();
    let data = synthetic_data(11, 40);

    let model = cm.to_model();
    let names = cm.param_names();
    let mut native = perflex::calibrate::NativeBackend::with_params(
        &model,
        &data,
        names.clone(),
    );
    let mut aot = AotBackend::new(&artifacts, &cm, &data).expect("aot backend");

    let p = vec![0.1, 0.5, 0.9, 10.0]; // 3 params + p_edge
    for lam in [1e-3, 1e-1, 10.0] {
        let (d_native, c_native) = native.step(&p, lam).unwrap();
        let (d_aot, c_aot) = aot.step(&p, lam).unwrap();
        assert!(
            (c_native - c_aot).abs() <= 1e-9 * c_native.abs().max(1.0),
            "cost mismatch: {c_native} vs {c_aot}"
        );
        for (dn, da) in d_native.iter().zip(&d_aot) {
            assert!(
                (dn - da).abs() <= 1e-6 * dn.abs().max(1e-9),
                "delta mismatch at lam={lam}: {d_native:?} vs {d_aot:?}"
            );
        }
    }
    // Cost evaluation parity.
    let c1 = native.cost(&p).unwrap();
    let c2 = aot.cost(&p).unwrap();
    assert!((c1 - c2).abs() <= 1e-9 * c1.max(1.0));
}

#[test]
fn aot_and_native_fits_converge_to_same_solution() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let artifacts = Artifacts::load().expect("artifacts load");
    let cm = synthetic_cost_model();
    let data = synthetic_data(23, 60);
    let opts = LmOptions::default();

    let fit_aot = fit_cost_model_aot(&artifacts, &cm, &data, &opts).unwrap();
    let fit_native = fit_cost_model_native(&cm, &data, &opts).unwrap();

    assert!(fit_aot.residual < 1e-10, "aot residual {}", fit_aot.residual);
    assert!(
        fit_native.residual < 1e-10,
        "native residual {}",
        fit_native.residual
    );
    // Ground truth recovery by both paths.
    for fit in [&fit_aot, &fit_native] {
        assert!((fit.param("p_launch").unwrap() - 0.05).abs() < 1e-3);
        assert!((fit.param("p_gmem").unwrap() - 0.8).abs() < 1e-3);
        assert!((fit.param("p_madd").unwrap() - 0.5).abs() < 1e-3);
    }
}

#[test]
fn aot_calibrates_real_measurements_from_the_fleet() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let artifacts = Artifacts::load().expect("artifacts load");
    let dev = device_by_id("gtx_titan_x").unwrap();
    let cm = CostModel::new("gtx_titan_x", false)
        .term("launch", "f_sync_kernel_launch", CostGroup::Overhead)
        .term("wg", "f_thread_groups", CostGroup::Overhead)
        .term("gmem", "f_mem_access_tag:patLD", CostGroup::Gmem)
        .term("gst", "f_mem_access_tag:outST", CostGroup::Gmem);
    let knls = KernelCollection::all()
        .generate_kernels(&[
            "gmem_pattern",
            "dtype:float32",
            "lid_stride_0:1",
            "lid_stride_1:16",
            "n_arrays:1,2",
            "nelements:1048576,4194304,8388608",
        ])
        .unwrap();
    assert_eq!(knls.len(), 6);
    let model = cm.to_model();
    let mut data = gather_feature_values(&model, &knls, &dev).unwrap();
    data.scale_features_by_output().unwrap();
    let fit = fit_cost_model_aot(&artifacts, &cm, &data, &LmOptions::default())
        .unwrap();
    // Scaled outputs are 1; a good fit has tiny residual per row.
    let mse = fit.residual / data.len() as f64;
    assert!(mse < 0.05, "poor fit: mse={mse} {fit:?}");
}
