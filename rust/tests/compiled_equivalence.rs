//! The compiled-vs-exact contract (`perflex::model::compiled` module
//! docs): for every environment on which the exact evaluator succeeds,
//! the compiled plan agrees within `COMPILED_REL_ERR_BOUND` relative
//! error.  Property-tested over the full cross product — every
//! evaluation case, every fleet device (both sub-group sizes), both
//! model forms and every calibration target — with synthetic fits
//! (deterministically seeded, log-uniform over realistic cost scales)
//! and sizes that include the degenerate and the extreme: 1, powers of
//! two straddling the tile sizes, and values large enough that the
//! exact path's i128 rational monomials approach overflow.

use std::collections::BTreeMap;

use perflex::calibrate::{eval_with_stats, FitResult, Target};
use perflex::coordinator::expsets;
use perflex::gpusim::fleet;
use perflex::model::cost_model::EDGE_PARAM;
use perflex::model::{CompiledModel, COMPILED_REL_ERR_BOUND};
use perflex::util::Rng;

/// Synthetic fitted parameters: log-uniform over the per-feature cost
/// scales real calibrations land in, with a step-sharpness `p_edge`
/// spanning soft to hard switches.  Synthetic fits decouple the
/// equivalence property from the LM optimizer: agreement must hold for
/// *any* parameter vector, not just converged ones.
fn synthetic_fit(names: Vec<String>, target: Target, seed: u64) -> FitResult {
    let mut rng = Rng::new(seed);
    let params: Vec<f64> = names
        .iter()
        .map(|n| {
            if n == EDGE_PARAM {
                rng.uniform_in(1.0, 1e4)
            } else {
                10f64.powf(rng.uniform_in(-9.0, -3.0))
            }
        })
        .collect();
    FitResult {
        param_names: names,
        params,
        residual: 0.0,
        iterations: 0,
        target,
        converged: true,
    }
}

fn rel_diff(x: f64, y: f64) -> f64 {
    (x - y).abs() / x.abs().max(y.abs()).max(f64::MIN_POSITIVE)
}

/// Degenerate and extreme sizes.  The cap at 2^30 keeps the *exact*
/// path's rational monomials (degree <= 3 with coefficient numerators)
/// within i128 while still exercising magnitudes where a compiled-path
/// rounding bug would be visible; the compiled path itself has no such
/// ceiling.
const SIZES: &[i64] = &[
    1,
    2,
    16,
    17,
    256,
    1024,
    4096,
    1 << 20,
    (1 << 30) - 1,
    1 << 30,
];

#[test]
fn compiled_agrees_with_exact_across_cases_devices_and_targets() {
    let mut combos = 0usize;
    let mut seed = 0u64;
    for case in expsets::eval_cases() {
        let points = expsets::eval_points(case.id).unwrap();
        // The case's primary size variable (swept below); the remaining
        // bindings (e.g. dg's nmatrices) stay at their representative
        // values so exact-path magnitudes remain within i128.
        let base = points.envs[0].clone();
        let primary = base.keys().next().unwrap().clone();

        // One symbolic counting pass per distinct sub-group size.
        let mut stats_by_sg: BTreeMap<u64, perflex::stats::KernelStats> =
            BTreeMap::new();
        for device in fleet() {
            let sg = device.sub_group_size;
            let stats = &*stats_by_sg
                .entry(sg)
                .or_insert_with(|| perflex::stats::gather(&points.kernel, sg).unwrap());
            for nonlinear in [false, true] {
                let cm = (case.model)(device.id, nonlinear);
                let model = cm.to_model();
                for target in Target::ALL {
                    seed += 1;
                    let fit = synthetic_fit(cm.param_names(), target, seed);
                    let compiled =
                        CompiledModel::compile(&cm, &fit, stats).unwrap();
                    assert_eq!(compiled.target(), target);

                    let mut rng = Rng::new(seed ^ 0x5eed);
                    let sizes: Vec<i64> = SIZES
                        .iter()
                        .copied()
                        .chain((0..3).map(|_| rng.int_in(1, 1 << 20)))
                        .collect();
                    for s in sizes {
                        let mut env = base.clone();
                        env.insert(primary.clone(), s);
                        let exact =
                            eval_with_stats(&model, &fit, stats, &env).unwrap();
                        let fast = compiled.eval_env(&env).unwrap();
                        assert!(
                            rel_diff(exact, fast) <= COMPILED_REL_ERR_BOUND,
                            "{} on {} (nonlinear={nonlinear}, target {}, \
                             {primary}={s}): exact {exact} vs compiled {fast} \
                             (rel diff {:.3e})",
                            case.id,
                            device.id,
                            target.name(),
                            rel_diff(exact, fast)
                        );
                    }
                    combos += 1;
                }
            }
        }
    }
    // The cross product must actually have been covered: 3 cases x
    // 5 devices x 2 forms x 3 targets.
    assert_eq!(combos, 3 * 5 * 2 * 3);
}

/// Sweeping via slot mutation (the batch hot path) is bit-identical to
/// independent name-keyed evaluations at every point.
#[test]
fn slot_sweeps_match_independent_evaluations() {
    for case in expsets::eval_cases() {
        let points = expsets::eval_points(case.id).unwrap();
        let base = points.envs[0].clone();
        let primary = base.keys().next().unwrap().clone();
        let stats = perflex::stats::gather(&points.kernel, 32).unwrap();
        let cm = (case.model)("titan_v", true);
        let fit = synthetic_fit(cm.param_names(), Target::Time, 42);
        let compiled = CompiledModel::compile(&cm, &fit, &stats).unwrap();

        let mut vals = compiled.bind_env(&base).unwrap();
        let slot = compiled.slot_of(&primary);
        for s in [1i64, 64, 1000, 4096, 1 << 16] {
            if let Some(i) = slot {
                vals[i] = s as f64;
            }
            let swept = compiled.eval_slots(&vals);
            let mut env = base.clone();
            env.insert(primary.clone(), s);
            assert_eq!(
                swept,
                compiled.eval_env(&env).unwrap(),
                "{}: {primary}={s}",
                case.id
            );
        }
    }
}
