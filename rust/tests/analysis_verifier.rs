//! Seeded-defect and clean-sweep suite for the static kernel verifier
//! (`perflex::analysis`).
//!
//! True positives: one minimal kernel per diagnostic code, asserting
//! the exact code fires and nothing else does — and a meta-test
//! asserting the registry of seeded defects covers *every* code in
//! `DiagCode::all()`, so a new code cannot ship without a kernel that
//! triggers it.  True negatives: every kernel the repo ships — every
//! UiPiCK generator variant and every transform-chain variant the
//! experiments use — must lint completely clean, so the verifier can
//! gate counting, measurement, and the autotune pruning loop without
//! false alarms.

use std::collections::BTreeSet;

use perflex::analysis::{
    self, check_equiv, check_feasibility, Analyzer, AnalysisError, DiagCode,
};
use perflex::gpusim::device_by_id;
use perflex::ir::{
    Access, AffExpr, ArrayDecl, DType, Expr, IndexTag, Kernel, LhsRef, MemScope, Stmt,
};
use perflex::polyhedral::{LoopExtent, NestedDomain, QPoly};
use perflex::uipick::apps::{build_dg, build_fdiff, build_matmul, build_transpose, DgVariant};
use perflex::uipick::KernelCollection;

fn codes(diags: &[analysis::Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.code.as_str()).collect()
}

/// A 16x16 work-group over two local axes, one global output row.
fn two_axis_grid(name: &str) -> Kernel {
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("li0", QPoly::int(16)),
        LoopExtent::zero_to("li1", QPoly::int(16)),
    ]);
    let mut k = Kernel::new(name, &[], dom);
    k.iname_tags.insert("li0".into(), IndexTag::Local(0));
    k.iname_tags.insert("li1".into(), IndexTag::Local(1));
    k
}

// ---------------------------------------------------------------------
// Seeded-defect builders: one minimal kernel per diagnostic code.  The
// per-code tests and the coverage meta-test both draw from these.
// ---------------------------------------------------------------------

/// RACE_WRITE (axis not covered): 16x16 work-items all storing
/// out[li0] — every li1 along a fixed li0 writes the same element.
fn race_axis_kernel() -> Kernel {
    let mut k = two_axis_grid("race_axis");
    k.add_array(ArrayDecl::global("out", DType::F32, vec![QPoly::int(16)]));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("out", vec![AffExpr::var("li0")])),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// RACE_WRITE (non-injective): out[li0 + li1] collides — (1, 0) and
/// (0, 1) write element 1.
fn race_collide_kernel() -> Kernel {
    let mut k = two_axis_grid("race_collide");
    k.add_array(ArrayDecl::global("out", DType::F32, vec![QPoly::int(32)]));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new(
            "out",
            vec![AffExpr::var("li0").plus(&AffExpr::var("li1"))],
        )),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// UNCOALESCED_GLOBAL: a strided-matmul-style store out[32*li0 +
/// 512*li1] — injective (no race) and in bounds, but the lid(0)
/// stride of 32 f32 elements costs one full cache line per lane where
/// a contiguous store needs a single line per sub-group access.
fn uncoalesced_kernel() -> Kernel {
    let mut k = two_axis_grid("uncoalesced");
    k.add_array(ArrayDecl::global(
        "out",
        DType::F32,
        vec![QPoly::int(16 * 512)],
    ));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new(
            "out",
            vec![AffExpr::scaled_var("li0", 32)
                .plus(&AffExpr::scaled_var("li1", 512))],
        )),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// BANK_CONFLICT: a local scratch store at lid(0) stride 16 — the 32
/// lanes of a sub-group land on gcd(16, 32) = 16 distinct banks, a
/// 16-way serialization.  Injective, in bounds, and the array is
/// accessed (so no DEAD_ARRAY rides along).
fn bank_conflict_kernel() -> Kernel {
    let mut k = two_axis_grid("bank_conflict");
    k.add_array(ArrayDecl::local("larr", DType::F32, vec![QPoly::int(4096)]));
    k.add_stmt(Stmt::new(
        "lst",
        LhsRef::Array(Access::new(
            "larr",
            vec![AffExpr::scaled_var("li0", 16)
                .plus(&AffExpr::scaled_var("li1", 256))],
        )),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// OOB_ACCESS: out[li0 + 1] reaches index 16 of a 16-element array.
fn oob_kernel() -> Kernel {
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("li0", QPoly::int(16))]);
    let mut k = Kernel::new("oob", &[], dom);
    k.iname_tags.insert("li0".into(), IndexTag::Local(0));
    k.add_array(ArrayDecl::global("out", DType::F32, vec![QPoly::int(16)]));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("out", vec![AffExpr::var("li0").plus_cst(1)])),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// The barrier_pattern shape: work-item li writes buf[li], then reads
/// buf[15-li] — data crosses work-items, so the read must be ordered
/// after the write for the scheduler to fence the exchange.  With
/// `with_dep: false` this seeds MISSING_BARRIER.
fn exchange_kernel(with_dep: bool) -> Kernel {
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("li", QPoly::int(16))]);
    let mut k = Kernel::new("exchange", &[], dom);
    k.iname_tags.insert("li".into(), IndexTag::Local(0));
    k.add_array(ArrayDecl::local("buf", DType::F32, vec![QPoly::int(16)]));
    k.add_array(ArrayDecl::global("out", DType::F32, vec![QPoly::int(16)]));
    k.add_stmt(Stmt::new(
        "w",
        LhsRef::Array(Access::new("buf", vec![AffExpr::var("li")])),
        Expr::fconst(1.0),
        &[],
    ));
    let read = Stmt::new(
        "r",
        LhsRef::Array(Access::new("out", vec![AffExpr::var("li")])),
        Expr::load(Access::new(
            "buf",
            vec![AffExpr::scaled_var("li", -1).plus_cst(15)],
        )),
        &[],
    );
    k.add_stmt(if with_dep { read.with_deps(&["w"]) } else { read });
    k
}

/// DIVERGENT_BARRIER: the exchange sits inside `t in 0..=li` — each
/// work-item runs the loop a different number of times, so the
/// barriers the scheduler inserts into the loop body are reached
/// divergently.
fn divergent_kernel() -> Kernel {
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("li", QPoly::int(16)),
        LoopExtent::new("t", QPoly::zero(), QPoly::var("li")),
    ]);
    let mut k = Kernel::new("divergent", &[], dom);
    k.iname_tags.insert("li".into(), IndexTag::Local(0));
    k.add_array(ArrayDecl::local("buf", DType::F32, vec![QPoly::int(16)]));
    k.add_array(ArrayDecl::global("out", DType::F32, vec![QPoly::int(16)]));
    k.add_stmt(Stmt::new(
        "w",
        LhsRef::Array(Access::new("buf", vec![AffExpr::var("li")])),
        Expr::fconst(1.0),
        &["t"],
    ));
    k.add_stmt(
        Stmt::new(
            "r",
            LhsRef::Array(Access::new("out", vec![AffExpr::var("li")])),
            Expr::load(Access::new(
                "buf",
                vec![AffExpr::scaled_var("li", -1).plus_cst(15)],
            )),
            &["t"],
        )
        .with_deps(&["w"]),
    );
    k
}

/// SCOPE_MISUSE: a private array subscripted by a parallel iname.
fn private_misuse_kernel() -> Kernel {
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("li", QPoly::int(16))]);
    let mut k = Kernel::new("private_misuse", &[], dom);
    k.iname_tags.insert("li".into(), IndexTag::Local(0));
    k.add_array(ArrayDecl {
        name: "acc".into(),
        dtype: DType::F32,
        scope: MemScope::Private,
        shape: vec![QPoly::int(16)],
        axis_order: vec![0],
    });
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("acc", vec![AffExpr::var("li")])),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// UNUSED_INAME: sequential loop `z` drives nothing.
fn unused_iname_kernel() -> Kernel {
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("li", QPoly::int(16)),
        LoopExtent::zero_to("z", QPoly::int(4)),
    ]);
    let mut k = Kernel::new("unused", &[], dom);
    k.iname_tags.insert("li".into(), IndexTag::Local(0));
    k.add_array(ArrayDecl::global("out", DType::F32, vec![QPoly::int(16)]));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("out", vec![AffExpr::var("li")])),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// DEAD_ARRAY: `scratch` is declared but never accessed.
fn dead_array_kernel() -> Kernel {
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("li", QPoly::int(16))]);
    let mut k = Kernel::new("dead", &[], dom);
    k.iname_tags.insert("li".into(), IndexTag::Local(0));
    k.add_array(ArrayDecl::global("out", DType::F32, vec![QPoly::int(16)]));
    k.add_array(ArrayDecl::global("scratch", DType::F32, vec![QPoly::int(16)]));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("out", vec![AffExpr::var("li")])),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// UNPROVABLE_GUARD: `0 <= i <= floor((n-1)/16)` with no divisibility
/// assumption — the bound keeps its floor atom, which counting treats
/// as exact.
fn floored_kernel() -> Kernel {
    let hi = (&QPoly::var("n") - &QPoly::one()).floor_div(16);
    let dom = NestedDomain::new(vec![LoopExtent::new("i", QPoly::zero(), hi)]);
    let mut k = Kernel::new("floored", &["n"], dom);
    k.add_array(ArrayDecl::global("a", DType::F32, vec![QPoly::var("n")]));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("a", vec![AffExpr::var("i")])),
        Expr::fconst(1.0),
        &["i"],
    ));
    k
}

/// MALFORMED_KERNEL: a store to an undeclared array — validate()
/// rejects it and the analyzer runs nothing else.
fn ghost_store_kernel() -> Kernel {
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("li", QPoly::int(16))]);
    let mut k = Kernel::new("ghost_store", &[], dom);
    k.iname_tags.insert("li".into(), IndexTag::Local(0));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("ghost", vec![AffExpr::var("li")])),
        Expr::fconst(1.0),
        &[],
    ));
    k
}

/// EXCESSIVE_LOCAL_MEM / LOW_OCCUPANCY: a 16-item work-group writing
/// one local tile of `elems` f32 entries.
fn lmem_kernel(elems: i128) -> Kernel {
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("li", QPoly::int(16))]);
    let mut k = Kernel::new("lmem_case", &[], dom);
    k.iname_tags.insert("li".into(), IndexTag::Local(0));
    k.add_array(ArrayDecl::local("tile", DType::F32, vec![QPoly::int(elems)]));
    k.add_stmt(Stmt::new(
        "w",
        LhsRef::Array(Access::new("tile", vec![AffExpr::var("li")])),
        Expr::fconst(1.0),
        &["li"],
    ));
    k
}

/// SEMANTICS_CHANGED: a baseline writing 16 elements of `res` and a
/// "candidate" writing only the first 8 — write count and footprint
/// both shrink.
fn shrunk_write_pair() -> (Kernel, Kernel) {
    let build = |extent: i128| {
        let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", QPoly::int(extent))]);
        let mut k = Kernel::new("shrunk", &[], dom);
        k.add_array(ArrayDecl::global("res", DType::F32, vec![QPoly::int(16)]));
        k.add_stmt(Stmt::new(
            "st",
            LhsRef::Array(Access::new("res", vec![AffExpr::var("i")])),
            Expr::fconst(1.0),
            &["i"],
        ));
        k
    };
    (build(16), build(8))
}

#[test]
fn race_write_fires_when_a_parallel_axis_is_not_covered() {
    let k = race_axis_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["RACE_WRITE"], "{diags:?}");
    assert!(analysis::verify(&k).is_err());
}

#[test]
fn race_write_fires_on_non_injective_subscript() {
    let k = race_collide_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["RACE_WRITE"], "{diags:?}");
    let err = analysis::verify(&k).unwrap_err();
    assert!(matches!(err, AnalysisError::Rejected { .. }));
    let msg = err.to_string();
    assert!(msg.contains("RACE_WRITE"), "{msg}");
}

#[test]
fn oob_access_fires_when_subscript_exceeds_shape() {
    let diags = Analyzer::new().check(&oob_kernel());
    assert_eq!(codes(&diags), vec!["OOB_ACCESS"], "{diags:?}");
}

#[test]
fn missing_barrier_fires_on_unordered_cross_item_read() {
    let k = exchange_kernel(false);
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["MISSING_BARRIER"], "{diags:?}");
}

#[test]
fn dependency_ordered_exchange_lints_clean() {
    let k = exchange_kernel(true);
    let diags = Analyzer::new().check(&k);
    assert!(diags.is_empty(), "{diags:?}");
}

#[test]
fn divergent_barrier_fires_under_local_dependent_trip_count() {
    let diags = Analyzer::new().check(&divergent_kernel());
    assert_eq!(codes(&diags), vec!["DIVERGENT_BARRIER"], "{diags:?}");
}

#[test]
fn scope_misuse_fires_for_private_array_with_parallel_subscript() {
    let diags = Analyzer::new().check(&private_misuse_kernel());
    assert_eq!(codes(&diags), vec!["SCOPE_MISUSE"], "{diags:?}");
}

#[test]
fn scope_misuse_fires_for_local_array_with_group_subscript() {
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("gi", QPoly::int(8))]);
    let mut k = Kernel::new("local_misuse", &[], dom);
    k.iname_tags.insert("gi".into(), IndexTag::Group(0));
    k.add_array(ArrayDecl::local("larr", DType::F32, vec![QPoly::int(8)]));
    k.add_stmt(Stmt::new(
        "st",
        LhsRef::Array(Access::new("larr", vec![AffExpr::var("gi")])),
        Expr::fconst(1.0),
        &[],
    ));
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["SCOPE_MISUSE"], "{diags:?}");
}

#[test]
fn unused_iname_warns_without_failing_the_gate() {
    let k = unused_iname_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["UNUSED_INAME"], "{diags:?}");
    assert_eq!(diags[0].object.as_deref(), Some("z"));
    // Warnings pass the gate form.
    assert_eq!(analysis::verify(&k).unwrap().len(), 1);
}

#[test]
fn dead_array_warns_without_failing_the_gate() {
    let k = dead_array_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["DEAD_ARRAY"], "{diags:?}");
    assert_eq!(diags[0].object.as_deref(), Some("scratch"));
    assert!(analysis::verify(&k).is_ok());
}

#[test]
fn unprovable_guard_warns_on_surviving_floor_bound() {
    let k = floored_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["UNPROVABLE_GUARD"], "{diags:?}");
    assert!(analysis::verify(&k).is_ok());
}

#[test]
fn uncoalesced_global_warns_on_strided_store() {
    let k = uncoalesced_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["UNCOALESCED_GLOBAL"], "{diags:?}");
    assert_eq!(diags[0].code.severity(), analysis::Severity::Warn);
    assert_eq!(diags[0].object.as_deref(), Some("out"));
    assert!(diags[0].message.contains("stride 32"), "{}", diags[0]);
    // Warnings do not fail the gate: verify() returns them in Ok.
    let ok = analysis::verify(&k).unwrap();
    assert_eq!(codes(&ok), vec!["UNCOALESCED_GLOBAL"]);
}

#[test]
fn bank_conflict_warns_on_strided_local_access() {
    let k = bank_conflict_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["BANK_CONFLICT"], "{diags:?}");
    assert_eq!(diags[0].code.severity(), analysis::Severity::Warn);
    assert_eq!(diags[0].object.as_deref(), Some("larr"));
    assert!(diags[0].message.contains("16-way"), "{}", diags[0]);
    assert!(analysis::verify(&k).is_ok());
}

#[test]
fn malformed_kernel_is_the_only_diagnostic_for_broken_structure() {
    let k = ghost_store_kernel();
    let diags = Analyzer::new().check(&k);
    assert_eq!(codes(&diags), vec!["MALFORMED_KERNEL"], "{diags:?}");
    assert_eq!(diags[0].code.severity(), analysis::Severity::Error);
    // The typed gate distinguishes malformed from well-formed-but-bad.
    match analysis::verify(&k) {
        Err(AnalysisError::Malformed { kernel, diagnostic }) => {
            assert_eq!(kernel, "ghost_store");
            assert_eq!(diagnostic.code, DiagCode::MalformedKernel);
        }
        other => panic!("expected Malformed, got {other:?}"),
    }
}

#[test]
fn every_code_has_a_stable_severity() {
    for c in DiagCode::all() {
        match c {
            DiagCode::UnusedIname
            | DiagCode::DeadArray
            | DiagCode::UnprovableGuard
            | DiagCode::LowOccupancy
            | DiagCode::UncoalescedGlobal
            | DiagCode::BankConflict => {
                assert_eq!(c.severity(), analysis::Severity::Warn, "{}", c.as_str())
            }
            _ => assert_eq!(c.severity(), analysis::Severity::Error, "{}", c.as_str()),
        }
    }
}

/// Coverage meta-test: every code in `DiagCode::all()` has a seeded
/// defect in this file that triggers exactly that code.  Adding a
/// diagnostic code without a kernel demonstrating it fails here.
#[test]
fn every_diag_code_has_a_seeded_defect() {
    let analyzer = Analyzer::new();
    let amd = device_by_id("amd_r9_fury").unwrap();
    let titan = device_by_id("titan_v").unwrap();
    let k40c = device_by_id("tesla_k40c").unwrap();
    let fdiff18 = build_fdiff(18).unwrap();
    let (equiv_base, equiv_bad) = shrunk_write_pair();

    let registry: Vec<(DiagCode, Vec<analysis::Diagnostic>)> = vec![
        (DiagCode::RaceWrite, analyzer.check(&race_axis_kernel())),
        (DiagCode::OobAccess, analyzer.check(&oob_kernel())),
        (
            DiagCode::MissingBarrier,
            analyzer.check(&exchange_kernel(false)),
        ),
        (
            DiagCode::DivergentBarrier,
            analyzer.check(&divergent_kernel()),
        ),
        (
            DiagCode::ScopeMisuse,
            analyzer.check(&private_misuse_kernel()),
        ),
        (DiagCode::UnusedIname, analyzer.check(&unused_iname_kernel())),
        (DiagCode::DeadArray, analyzer.check(&dead_array_kernel())),
        (
            DiagCode::UnprovableGuard,
            analyzer.check(&floored_kernel()),
        ),
        (
            DiagCode::MalformedKernel,
            analyzer.check(&ghost_store_kernel()),
        ),
        (
            DiagCode::WgSizeExceeded,
            check_feasibility(&fdiff18, &amd).unwrap().diags,
        ),
        (
            DiagCode::ExcessiveLocalMem,
            check_feasibility(&lmem_kernel(1 << 18), &titan).unwrap().diags,
        ),
        (
            DiagCode::LowOccupancy,
            check_feasibility(&lmem_kernel(6000), &k40c).unwrap().diags,
        ),
        (
            DiagCode::SemanticsChanged,
            check_equiv(&equiv_base, &equiv_bad),
        ),
        (
            DiagCode::UncoalescedGlobal,
            analyzer.check(&uncoalesced_kernel()),
        ),
        (
            DiagCode::BankConflict,
            analyzer.check(&bank_conflict_kernel()),
        ),
    ];

    let mut covered: BTreeSet<DiagCode> = BTreeSet::new();
    for (code, diags) in &registry {
        assert!(
            !diags.is_empty(),
            "{}: seeded defect produced no diagnostic",
            code.as_str()
        );
        assert!(
            diags.iter().all(|d| d.code == *code),
            "{}: stray codes in seeded-defect report {:?}",
            code.as_str(),
            diags
        );
        covered.insert(*code);
    }
    for code in DiagCode::all() {
        assert!(
            covered.contains(code),
            "no seeded defect for {}",
            code.as_str()
        );
    }
}

/// Regression (the paper's motivating example): the 18x18 stencil tile
/// launches 324 work-items per group — over AMD's 256 limit, fine on
/// every Nvidia device of the fleet.
#[test]
fn amd_rejects_the_18x18_stencil_work_group() {
    let k = build_fdiff(18).unwrap();
    let amd = device_by_id("amd_r9_fury").unwrap();
    let f = check_feasibility(&k, &amd).unwrap();
    assert_eq!(f.usage.wg_size, 324);
    assert!(!f.launchable());
    assert_eq!(codes(&f.diags), vec!["WG_SIZE_EXCEEDED"], "{:?}", f.diags);
    assert!(f.diags[0].message.contains("324"), "{}", f.diags[0]);
    assert!(f.diags[0].message.contains("256"), "{}", f.diags[0]);
    for id in ["titan_v", "gtx_titan_x", "tesla_k40c", "tesla_c2070"] {
        let f = check_feasibility(&k, &device_by_id(id).unwrap()).unwrap();
        assert!(f.launchable(), "{id}: {:?}", f.diags);
        assert!(f.diags.is_empty(), "{id}: {:?}", f.diags);
    }
}

/// Access-pattern warning codes one generator variant is *expected*
/// to carry under the device-independent geometry.  The inventory
/// deliberately ships strided kernels — sweeping access patterns is
/// what `gmem_pattern` and `lmem_move` are for — and exactly those
/// must warn; everything else must stay spotless so the verifier
/// gates the pipeline with zero false positives.
fn expected_access_codes(k: &perflex::uipick::GeneratedKernel) -> BTreeSet<&'static str> {
    let arg = |key: &str| k.args.get_i64(key).unwrap_or(0);
    match k.generator.as_str() {
        // Strided global loads: one warning per strided input array.
        "gmem_pattern" if arg("lid_stride_0") > 1 => ["UNCOALESCED_GLOBAL"].into(),
        // Strided local traffic: init store, move load, move store.
        "lmem_move" if arg("stride") > 1 => ["BANK_CONFLICT"].into(),
        // A-row loads are lid(0)-strided by the (parametric) row pitch.
        "matvec" => ["UNCOALESCED_GLOBAL"].into(),
        // The classic transposed store.
        "transpose_sq" => ["UNCOALESCED_GLOBAL"].into(),
        // DG: the direct `u` loads and `res` store are element-strided
        // (stride = nunit_nodes); u_prefetch trades the u loads for a
        // bank-conflicted local tile, and only the transposed-layout
        // m_prefetch_t variant is fully clean.
        "dg_diff" => match k.args.get("variant").unwrap_or("") {
            "plain" | "m_prefetch" => ["UNCOALESCED_GLOBAL"].into(),
            "u_prefetch" => ["UNCOALESCED_GLOBAL", "BANK_CONFLICT"].into(),
            _ => BTreeSet::new(),
        },
        // Sliced DG variants keep whichever strided accesses survive
        // work removal: `u` in the plain/m_prefetch slices, both `u`
        // and the `res` store in the res_store slice.
        "gmem_from_dg" => match k.args.get("pattern").unwrap_or("") {
            "plain_u" | "mpf_u" | "res_store" => ["UNCOALESCED_GLOBAL"].into(),
            _ => BTreeSet::new(),
        },
        _ => BTreeSet::new(),
    }
}

/// True-negative sweep 1: every UiPiCK generator variant (the full
/// Cartesian product of every generator's argument domains) lints
/// with zero errors, and warns exactly where the variant's access
/// pattern says it should — genuinely strided variants carry their
/// access-pattern warning, every other variant is completely clean.
#[test]
fn every_uipick_generator_variant_lints_clean() {
    let knls = KernelCollection::all().generate_kernels(&[]).unwrap();
    assert!(!knls.is_empty());
    let analyzer = Analyzer::new();
    let mut seen = BTreeSet::new();
    let mut checked = 0usize;
    let mut warned = 0usize;
    for k in &knls {
        if !seen.insert(k.kernel.fingerprint()) {
            continue;
        }
        let diags = analyzer.check(&k.kernel);
        for d in &diags {
            assert_eq!(
                d.code.severity(),
                analysis::Severity::Warn,
                "{} (generator {}) has an error-severity finding: {d}",
                k.kernel.name,
                k.generator
            );
        }
        let got: BTreeSet<&'static str> =
            diags.iter().map(|d| d.code.as_str()).collect();
        let expected = expected_access_codes(k);
        assert_eq!(
            got, expected,
            "{} (generator {}): expected warning codes {expected:?}, \
             got {:?}",
            k.kernel.name, k.generator, diags
        );
        if !expected.is_empty() {
            warned += 1;
        }
        checked += 1;
    }
    assert!(checked >= 20, "only {checked} distinct kernels checked");
    // The sweep must exercise both sides of the predicate.
    assert!(warned >= 4, "only {warned} strided variants warned");
    assert!(
        checked > warned,
        "no clean variants left to witness zero false positives"
    );
}

/// True-negative sweep 2: every transform-chain variant `experiment
/// all` prices (the paper's app kernels at their measured
/// configurations) passes the gate form with zero errors, and its
/// warnings are exactly the access-pattern findings the chain's
/// memory layout predicts — the shipped contiguous variants (matmul,
/// the stencil, transposed-layout DG) carry none at all.
#[test]
fn every_experiment_transform_chain_verifies_clean() {
    let ug: BTreeSet<&str> = ["UNCOALESCED_GLOBAL"].into();
    let mut variants: Vec<(String, Kernel, BTreeSet<&str>)> = vec![
        (
            "matmul/prefetch".into(),
            build_matmul(DType::F32, true, 16).unwrap(),
            BTreeSet::new(),
        ),
        (
            "matmul/no_prefetch".into(),
            build_matmul(DType::F32, false, 16).unwrap(),
            BTreeSet::new(),
        ),
        ("fdiff/16x16".into(), build_fdiff(16).unwrap(), BTreeSet::new()),
        ("fdiff/18x18".into(), build_fdiff(18).unwrap(), BTreeSet::new()),
        ("transpose".into(), build_transpose(16).unwrap(), ug.clone()),
    ];
    for (v, expected) in [
        (DgVariant::Plain, ug.clone()),
        (
            DgVariant::UPrefetch,
            ["UNCOALESCED_GLOBAL", "BANK_CONFLICT"].into(),
        ),
        (DgVariant::MPrefetch, ug.clone()),
        (DgVariant::MPrefetchT, BTreeSet::new()),
    ] {
        variants.push((
            format!("dg/{}", v.label()),
            build_dg(v, 64, 16).unwrap(),
            expected,
        ));
    }
    for (label, knl, expected) in &variants {
        let diags = analysis::verify(knl).unwrap_or_else(|e| panic!("{label}: {e}"));
        for d in &diags {
            assert_eq!(
                d.code.severity(),
                analysis::Severity::Warn,
                "{label} has an error-severity finding: {d}"
            );
        }
        let got: BTreeSet<&str> = diags.iter().map(|d| d.code.as_str()).collect();
        assert_eq!(got, *expected, "{label}: {diags:?}");
    }
}
