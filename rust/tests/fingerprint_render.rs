//! Acceptance check for the frozen-kernel refactor: the measurement /
//! feature-gathering / prediction hot paths must never re-render
//! kernel IR — each kernel's fingerprint is minted exactly once, at
//! freeze time.
//!
//! This is deliberately the *only* test in this binary:
//! [`perflex::ir::ir_render_count`] is process-global, and unit tests
//! running on sibling threads would perturb it.

use perflex::coordinator::expsets;
use perflex::gpusim::{device_by_id, measure_with_cache};
use perflex::ir::ir_render_count;
use perflex::stats::StatsCache;

#[test]
fn hot_paths_never_rerender_frozen_kernel_ir() {
    let dev = device_by_id("titan_v").unwrap();
    let case = expsets::eval_case("matmul").unwrap();
    // Generation freezes every kernel (renders happen here, once per
    // generated kernel)...
    let kernels =
        expsets::generate_measurement_kernels(&(case.measurement_sets)()).unwrap();
    let ids = (case.model)(dev.id, true).feature_columns();
    let app = perflex::uipick::apps::build_matmul(perflex::ir::DType::F32, true, 16)
        .unwrap()
        .freeze();
    let env: std::collections::BTreeMap<String, i64> =
        [("n".to_string(), 2048i64)].into_iter().collect();

    // ... and from here on, zero renders: every cache key comes from a
    // frozen fingerprint.
    let cache = StatsCache::new();
    let before = ir_render_count();
    let data =
        perflex::calibrate::gather_features_by_ids_cached(ids, &kernels, &dev, &cache)
            .unwrap();
    assert!(!data.is_empty());
    for _ in 0..3 {
        measure_with_cache(&dev, &app, &env, &cache).unwrap();
    }
    assert_eq!(
        ir_render_count(),
        before,
        "measurement, gathering and prediction must not re-render IR"
    );
}
