//! Warm-vs-cold integration tests for the persistent calibration
//! session (`perflex::session`).
//!
//! The acceptance bar: a warm artifact store changes *cost*, never
//! *output* — experiment reports are byte-identical between a cold run
//! and a warm re-run, and the warm run performs zero symbolic counting
//! passes.

use std::path::PathBuf;

use perflex::calibrate::FitResult;
use perflex::coordinator::run_experiment_in_session;
use perflex::coordinator::expsets;
use perflex::gpusim::{device_by_id, fleet};
use perflex::session::{
    fit_key_parts, reachable_fit_fingerprints, GcOptions, Session,
    DEFAULT_LEASE_TTL_SECS,
};

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "perflex-itest-{name}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn experiment_fig1_reports_byte_identical_cold_vs_warm() {
    let dir = tmp_dir("fig1");

    let cold = Session::with_store(&dir).expect("store must open");
    let rep_cold = run_experiment_in_session("fig1", false, &cold).unwrap();
    assert!(
        cold.cache().misses() > 0,
        "cold run must actually run the symbolic pass"
    );

    // A fresh session over the same store: statistics come from disk.
    let warm = Session::with_store(&dir).unwrap();
    let rep_warm = run_experiment_in_session("fig1", false, &warm).unwrap();
    assert_eq!(
        warm.cache().misses(),
        0,
        "warm run must serve every symbolic bundle from the store"
    );
    assert!(warm.cache().disk_hits() > 0);

    assert_eq!(rep_cold.render(), rep_warm.render());
    assert_eq!(
        rep_cold.to_json().to_string(),
        rep_warm.to_json().to_string(),
        "warm report must be byte-identical to the cold one"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_calibrate_returns_stored_fit_for_both_model_forms() {
    let dir = tmp_dir("forms");
    let case = expsets::eval_case("matmul").unwrap();
    let dev = device_by_id("gtx_titan_x").unwrap();

    let cold = Session::with_store(&dir).unwrap();
    let nl_cold = cold.calibrate_case(&case, &dev, true, None).unwrap();
    let lin_cold = cold.calibrate_case(&case, &dev, false, None).unwrap();
    assert!(!nl_cold.from_store && !lin_cold.from_store);
    assert_ne!(
        nl_cold.fit.params, lin_cold.fit.params,
        "the two model forms are distinct artifacts"
    );

    let warm = Session::with_store(&dir).unwrap();
    let nl_warm = warm.calibrate_case(&case, &dev, true, None).unwrap();
    let lin_warm = warm.calibrate_case(&case, &dev, false, None).unwrap();
    assert!(nl_warm.from_store && lin_warm.from_store);
    assert_eq!(nl_cold.fit.params, nl_warm.fit.params);
    assert_eq!(lin_cold.fit.params, lin_warm.fit.params);
    assert_eq!(
        warm.cache().misses(),
        0,
        "stored fits must not trigger measurement or counting"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Fleet-wide sharing: a second device with the same sub-group size,
/// calibrated from a fresh session ("new process") against the same
/// store, performs zero fresh counting passes — every symbolic bundle
/// comes from the first device's run.
#[test]
fn same_sub_group_device_reuses_counting_passes_from_shared_store() {
    let dir = tmp_dir("xdev");
    let case = expsets::eval_case("matmul").unwrap();

    let a = Session::with_store(&dir).unwrap();
    let dev_a = device_by_id("titan_v").unwrap();
    a.calibrate_case(&case, &dev_a, true, None).unwrap();
    assert!(a.cache().misses() > 0, "first device pays the counting");

    let b = Session::with_store(&dir).unwrap();
    let dev_b = device_by_id("gtx_titan_x").unwrap();
    assert_eq!(dev_a.sub_group_size, dev_b.sub_group_size);
    let cal = b.calibrate_case(&case, &dev_b, true, None).unwrap();
    assert!(!cal.from_store, "a different device needs its own fit");
    assert_eq!(
        b.cache().misses(),
        0,
        "same-sub-group device must reuse every counting pass"
    );
    assert!(b.cache().disk_hits() > 0);

    // A wavefront-64 device keys a separate stats family and must
    // gather its own counts.
    let c = Session::with_store(&dir).unwrap();
    let amd = device_by_id("amd_r9_fury").unwrap();
    c.calibrate_case(&case, &amd, true, None).unwrap();
    assert!(c.cache().misses() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Two sessions (threads standing in for processes) calibrating the
/// same case against one store concurrently: both finish, produce the
/// same deterministic fit, and leave the store warm and torn-free.
#[test]
fn concurrent_sessions_share_one_store_safely() {
    let dir = tmp_dir("concurrent");
    let case = expsets::eval_case("matmul").unwrap();
    let dev = device_by_id("titan_v").unwrap();
    let cals: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..2)
            .map(|_| {
                let dir = dir.clone();
                s.spawn(move || {
                    let session = Session::with_store(&dir).unwrap();
                    let case = expsets::eval_case("matmul").unwrap();
                    let dev = device_by_id("titan_v").unwrap();
                    session.calibrate_case(&case, &dev, true, None).unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(cals[0].fit.params, cals[1].fit.params);
    assert_eq!(cals[0].fit.residual, cals[1].fit.residual);

    let warm = Session::with_store(&dir).unwrap();
    let cal = warm.calibrate_case(&case, &dev, true, None).unwrap();
    assert!(cal.from_store, "the racing writers left a loadable artifact");
    assert_eq!(warm.cache().misses(), 0);

    // The cross-process acceptance bar: after the racing writers, the
    // journaled index agrees entry-for-entry with a full rebuild scan.
    let verify = warm.store().unwrap().verify_index().unwrap();
    assert!(
        verify.matches,
        "index {:?} must equal the rebuild scan {:?}",
        verify.indexed, verify.scanned
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// A live foreign maintenance lease makes destructive `store gc` (and
/// `compact`) refuse — without deleting anything — while dry runs and
/// ordinary calibration traffic proceed untouched; the session stays
/// warm throughout.
#[test]
fn maintenance_refuses_under_foreign_lease_but_sessions_stay_live() {
    let dir = tmp_dir("lease-refusal");
    let case = expsets::eval_case("matmul").unwrap();
    let dev = device_by_id("titan_v").unwrap();
    let session = Session::with_store(&dir).unwrap();
    session.calibrate_case(&case, &dev, true, None).unwrap();

    std::fs::write(
        dir.join("gc.lease"),
        "{\"pid\":424242,\"token\":\"foreign\",\"expires_at\":99999999999}",
    )
    .unwrap();
    let store = session.store().unwrap();
    let err = store
        .gc(&GcOptions {
            temp_ttl_secs: 0,
            ..GcOptions::default()
        })
        .unwrap_err();
    assert!(err.contains("refusing"), "{err}");
    assert!(
        store.compact(DEFAULT_LEASE_TTL_SECS).unwrap_err().contains("refusing")
    );
    // Dry runs need no lease.
    let dry = store
        .gc(&GcOptions {
            temp_ttl_secs: 0,
            dry_run: true,
            ..GcOptions::default()
        })
        .unwrap();
    assert!(dry.removed.is_empty(), "{:?}", dry.removed);

    // Calibration traffic is not maintenance: a fresh session loads
    // warm under the foreign lease.
    let warm = Session::with_store(&dir).unwrap();
    let cal = warm.calibrate_case(&case, &dev, true, None).unwrap();
    assert!(cal.from_store);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The experiment harnesses' per-device fleet fits are artifacts too:
/// a cold fig9 run persists all ten (5 devices x 2 forms), and a warm
/// re-run loads every one, performs zero counting passes, and renders
/// a byte-identical report.
#[test]
fn fleet_experiment_fits_warm_start_from_shared_store() {
    let dir = tmp_dir("fleet-fig9");
    let case = expsets::eval_case("fdiff").unwrap();

    let cold = Session::with_store(&dir).unwrap();
    let rep_cold = run_experiment_in_session("fig9", false, &cold).unwrap();
    for dev in fleet() {
        assert!(
            cold.has_stored_fits(&case, &dev),
            "cold run must persist both fleet fits for {}",
            dev.id
        );
    }

    // Reachability-drift guard: GC over a store a real experiment just
    // populated must treat every persisted fleet fit as live.
    let gc = cold
        .store()
        .unwrap()
        .gc(&GcOptions {
            reachable_fits: Some(&reachable_fit_fingerprints()),
            temp_ttl_secs: 0,
            dry_run: false,
            ..GcOptions::default()
        })
        .unwrap();
    assert!(
        gc.removed.is_empty(),
        "GC must not collect live experiment fits: {:?}",
        gc.removed
    );

    let warm = Session::with_store(&dir).unwrap();
    let rep_warm = run_experiment_in_session("fig9", false, &warm).unwrap();
    assert_eq!(
        rep_cold.render(),
        rep_warm.render(),
        "warm fleet run must be byte-identical"
    );
    assert_eq!(
        warm.cache().misses(),
        0,
        "warm fleet run must not run the counting pass"
    );
    assert!(warm.cache().disk_hits() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// The v3 fit-path regression at session level: two keys minted by
/// `fit_key_parts` for the *same* (case, device, form) but different
/// model shapes (here: a changed measurement set, i.e. a "re-featured"
/// model) differ only in `model_fingerprint`.  Under the v2 path
/// scheme they shared one file and each `save_fit` silently evicted
/// the other; they must persist side by side and both load warm from
/// a fresh session without a single full-artifact parse.
#[test]
fn fingerprint_only_fit_siblings_persist_side_by_side() {
    let dir = tmp_dir("fp-siblings");
    let case = expsets::eval_case("matmul").unwrap();
    let dev = device_by_id("titan_v").unwrap();
    let cm = (case.model)(dev.id, true);
    let sets_a = (case.measurement_sets)();
    let mut sets_b = sets_a.clone();
    sets_b.push(vec!["extra_filter_tag".to_string()]);
    let key_a = fit_key_parts(case.id, &dev, true, &cm, &sets_a);
    let key_b = fit_key_parts(case.id, &dev, true, &cm, &sets_b);
    assert_eq!(key_a.case, key_b.case);
    assert_eq!(key_a.device, key_b.device);
    assert_eq!(key_a.nonlinear, key_b.nonlinear);
    assert_ne!(
        key_a.model_fingerprint, key_b.model_fingerprint,
        "a changed measurement set must re-fingerprint the fit"
    );

    let fit = |p: f64| FitResult {
        param_names: vec!["p_a".into()],
        params: vec![p],
        residual: 0.0,
        iterations: 1,
    };
    let cold = Session::with_store(&dir).unwrap();
    cold.persist_fit(&key_a, &fit(1.0)).unwrap();
    cold.persist_fit(&key_b, &fit(2.0)).unwrap();
    assert_eq!(cold.stored_fit(&key_a).unwrap().params, vec![1.0]);
    assert_eq!(
        cold.stored_fit(&key_b).unwrap().params,
        vec![2.0],
        "the sibling save must not have evicted key_a's artifact"
    );

    // A fresh session ("new process"): the journal-replayed index
    // vouches for both siblings — warm loads, zero parses.
    let warm = Session::with_store(&dir).unwrap();
    assert_eq!(warm.stored_fit(&key_a).unwrap().params, vec![1.0]);
    assert_eq!(warm.stored_fit(&key_b).unwrap().params, vec![2.0]);
    let (hits, parses) = warm.store_ledger().unwrap();
    assert_eq!(parses, 0, "index must vouch for both siblings");
    assert!(hits >= 2);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Corrupt index metadata (snapshot and journal) must never cool the
/// store: the next open rebuilds the manifest from a full scan, every
/// artifact stays warm, and the rebuild's checkpoint makes the session
/// after that parse-free again.
#[test]
fn corrupt_index_metadata_never_cools_the_store() {
    let dir = tmp_dir("ixcorrupt");
    let case = expsets::eval_case("matmul").unwrap();
    let dev = device_by_id("titan_v").unwrap();
    let cold = Session::with_store(&dir).unwrap();
    cold.calibrate_case(&case, &dev, true, None).unwrap();

    std::fs::write(dir.join("index.json"), "{definitely not json").unwrap();
    std::fs::write(dir.join("index.journal"), "garbage\nmore garbage\n").unwrap();

    let rebuilt = Session::with_store(&dir).unwrap();
    assert!(
        rebuilt.store().unwrap().artifact_parses() > 0,
        "corrupt metadata must force a rebuild scan"
    );
    let cal = rebuilt.calibrate_case(&case, &dev, true, None).unwrap();
    assert!(cal.from_store, "rebuild must re-index every live artifact");
    assert_eq!(rebuilt.cache().misses(), 0);

    // The rebuild checkpointed a fresh snapshot: the next "process"
    // answers everything from the index again.
    let warm = Session::with_store(&dir).unwrap();
    let cal = warm.calibrate_case(&case, &dev, true, None).unwrap();
    assert!(cal.from_store);
    assert_eq!(warm.cache().misses(), 0);
    assert_eq!(
        warm.store().unwrap().artifact_parses(),
        0,
        "post-rebuild snapshot must restore parse-free warm starts"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `store compact` dedups the sg-invariant stats sections between the
/// wavefront-32 devices and the wavefront-64 Fury; a warm fleet rerun
/// over the compacted store must render byte-identical reports with
/// zero counting passes and zero full-artifact parses, and GC must
/// treat the compacted layout as fully live.
#[test]
fn compaction_preserves_fleet_reports_byte_for_byte() {
    let dir = tmp_dir("compact");
    let cold = Session::with_store(&dir).unwrap();
    let rep_cold = run_experiment_in_session("fig9", false, &cold).unwrap();

    let outcome = cold.store().unwrap().compact(DEFAULT_LEASE_TTL_SECS).unwrap();
    assert!(
        outcome.shared_sections > 0 && outcome.rewritten > 0,
        "fleet stores hold sg-32/sg-64 twins to dedup: {outcome:?}"
    );
    assert_eq!(outcome.skipped, 0, "{outcome:?}");

    let warm = Session::with_store(&dir).unwrap();
    let rep_warm = run_experiment_in_session("fig9", false, &warm).unwrap();
    assert_eq!(
        rep_cold.render(),
        rep_warm.render(),
        "compaction must not change a report byte"
    );
    assert_eq!(
        rep_cold.to_json().to_string(),
        rep_warm.to_json().to_string()
    );
    assert_eq!(warm.cache().misses(), 0, "compacted store must stay warm");
    assert_eq!(
        warm.store().unwrap().artifact_parses(),
        0,
        "compaction's checkpoint must keep warm runs parse-free"
    );

    let gc = warm
        .store()
        .unwrap()
        .gc(&GcOptions {
            reachable_fits: Some(&reachable_fit_fingerprints()),
            temp_ttl_secs: 0,
            dry_run: false,
            ..GcOptions::default()
        })
        .unwrap();
    assert!(
        gc.removed.is_empty(),
        "GC must keep every compacted artifact and referenced section: {:?}",
        gc.removed
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// `store gc` with the binary's reachability set must treat everything
/// a real calibration writes as live: nothing is removed, and the
/// store stays warm afterwards.
#[test]
fn gc_keeps_everything_a_real_calibration_wrote() {
    let dir = tmp_dir("gc-live");
    let case = expsets::eval_case("matmul").unwrap();
    let dev = device_by_id("titan_v").unwrap();
    let session = Session::with_store(&dir).unwrap();
    session.calibrate_case(&case, &dev, true, None).unwrap();

    let reach = reachable_fit_fingerprints();
    let outcome = session
        .store()
        .unwrap()
        .gc(&GcOptions {
            reachable_fits: Some(&reach),
            temp_ttl_secs: 0,
            dry_run: false,
            ..GcOptions::default()
        })
        .unwrap();
    assert!(outcome.removed.is_empty(), "{:?}", outcome.removed);
    assert!(outcome.scanned > 0);

    let warm = Session::with_store(&dir).unwrap();
    let cal = warm.calibrate_case(&case, &dev, true, None).unwrap();
    assert!(cal.from_store, "gc must not disturb live artifacts");
    assert_eq!(warm.cache().misses(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}
