//! Warm-vs-cold integration tests for the persistent calibration
//! session (`perflex::session`).
//!
//! The acceptance bar: a warm artifact store changes *cost*, never
//! *output* — experiment reports are byte-identical between a cold run
//! and a warm re-run, and the warm run performs zero symbolic counting
//! passes.

use std::path::PathBuf;

use perflex::coordinator::run_experiment_in_session;
use perflex::coordinator::expsets;
use perflex::gpusim::device_by_id;
use perflex::session::Session;

fn tmp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "perflex-itest-{name}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

#[test]
fn experiment_fig1_reports_byte_identical_cold_vs_warm() {
    let dir = tmp_dir("fig1");

    let cold = Session::with_store(&dir).expect("store must open");
    let rep_cold = run_experiment_in_session("fig1", false, &cold).unwrap();
    assert!(
        cold.cache().misses() > 0,
        "cold run must actually run the symbolic pass"
    );

    // A fresh session over the same store: statistics come from disk.
    let warm = Session::with_store(&dir).unwrap();
    let rep_warm = run_experiment_in_session("fig1", false, &warm).unwrap();
    assert_eq!(
        warm.cache().misses(),
        0,
        "warm run must serve every symbolic bundle from the store"
    );
    assert!(warm.cache().disk_hits() > 0);

    assert_eq!(rep_cold.render(), rep_warm.render());
    assert_eq!(
        rep_cold.to_json().to_string(),
        rep_warm.to_json().to_string(),
        "warm report must be byte-identical to the cold one"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn warm_calibrate_returns_stored_fit_for_both_model_forms() {
    let dir = tmp_dir("forms");
    let case = expsets::eval_case("matmul").unwrap();
    let dev = device_by_id("gtx_titan_x").unwrap();

    let cold = Session::with_store(&dir).unwrap();
    let nl_cold = cold.calibrate_case(&case, &dev, true, None).unwrap();
    let lin_cold = cold.calibrate_case(&case, &dev, false, None).unwrap();
    assert!(!nl_cold.from_store && !lin_cold.from_store);
    assert_ne!(
        nl_cold.fit.params, lin_cold.fit.params,
        "the two model forms are distinct artifacts"
    );

    let warm = Session::with_store(&dir).unwrap();
    let nl_warm = warm.calibrate_case(&case, &dev, true, None).unwrap();
    let lin_warm = warm.calibrate_case(&case, &dev, false, None).unwrap();
    assert!(nl_warm.from_store && lin_warm.from_store);
    assert_eq!(nl_cold.fit.params, nl_warm.fit.params);
    assert_eq!(lin_cold.fit.params, lin_warm.fit.params);
    assert_eq!(
        warm.cache().misses(),
        0,
        "stored fits must not trigger measurement or counting"
    );
    let _ = std::fs::remove_dir_all(&dir);
}
