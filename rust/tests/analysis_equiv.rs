//! Property suite for the transform-equivalence checker and the
//! complete autotune pruning predicate (`perflex::analysis`).
//!
//! Positive sweep: every transform chain the repo ships is equivalent
//! to its untransformed baseline, and `admissible` accepts every
//! (chain, device) pair the simulator can launch — zero false
//! positives, asserted in CI.  Negative sweep: a seeded breaking chain
//! per transform family (a partial-tile-dropping split, a halo-dropped
//! prefetch, a `remove_work` strip) is caught as `SEMANTICS_CHANGED`.

use std::collections::BTreeSet;

use perflex::analysis::{admissible, check_equiv, DiagCode};
use perflex::gpusim::fleet;
use perflex::ir::{
    Access, AffExpr, ArrayDecl, DType, Expr, Kernel, LhsRef, Stmt,
};
use perflex::polyhedral::{LoopExtent, NestedDomain, QPoly};
use perflex::transform::{assume, remove_work, split_iname, RemoveSpec};
use perflex::uipick::apps::{
    build_dg, build_fdiff, build_matmul, build_transpose, dg_base, fdiff_base,
    matmul_base, transpose_base, DgVariant,
};
use perflex::uipick::KernelCollection;

/// Every shipped transform chain as (label, baseline, candidate).
fn shipped_chains() -> Vec<(String, Kernel, Kernel)> {
    let mut v = Vec::new();
    for dtype in [DType::F32, DType::F64] {
        for prefetch in [false, true] {
            v.push((
                format!("matmul/{dtype:?}/prefetch={prefetch}"),
                matmul_base(dtype, prefetch),
                build_matmul(dtype, prefetch, 16).unwrap(),
            ));
        }
    }
    for variant in [
        DgVariant::Plain,
        DgVariant::UPrefetch,
        DgVariant::MPrefetch,
        DgVariant::MPrefetchT,
    ] {
        v.push((
            format!("dg/{}", variant.label()),
            dg_base(variant, 64),
            build_dg(variant, 64, 16).unwrap(),
        ));
    }
    for lsize in [16, 18] {
        v.push((
            format!("fdiff/{lsize}x{lsize}"),
            fdiff_base(lsize),
            build_fdiff(lsize).unwrap(),
        ));
    }
    v.push((
        "transpose".to_string(),
        transpose_base(),
        build_transpose(16).unwrap(),
    ));
    v
}

/// Positive sweep 1: split/tag/prefetch/prioritize/tag_data_axes — the
/// full shipped chain of every app kernel — preserves the baseline's
/// observable semantics.
#[test]
fn every_shipped_chain_is_equivalent_to_its_baseline() {
    for (label, base, cand) in &shipped_chains() {
        let diags = check_equiv(base, cand);
        assert!(diags.is_empty(), "{label}: false positive(s) {diags:?}");
    }
}

/// Positive sweep 2: every UiPiCK inventory kernel is (trivially)
/// equivalent to itself — the summarizer handles every shipped
/// structure without degrading into a spurious finding.
#[test]
fn every_inventory_kernel_is_self_equivalent() {
    let knls = KernelCollection::all().generate_kernels(&[]).unwrap();
    let mut seen = BTreeSet::new();
    let mut checked = 0usize;
    for k in &knls {
        if !seen.insert(k.kernel.fingerprint()) {
            continue;
        }
        let diags = check_equiv(&k.kernel, &k.kernel);
        assert!(
            diags.is_empty(),
            "{} (generator {}): {:?}",
            k.kernel.name,
            k.generator,
            diags
        );
        checked += 1;
    }
    assert!(checked >= 20, "only {checked} distinct kernels checked");
}

/// `res[i] = u[i] + u[i+1]` over `i in [0, n)` — the 1-D stencil the
/// seeded breaking chains start from.
fn stencil_base() -> Kernel {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
    let mut k = Kernel::new("stencil1d", &["n"], dom);
    k.add_array(ArrayDecl::global("u", DType::F32, vec![&n + &QPoly::one()]));
    k.add_array(ArrayDecl::global("res", DType::F32, vec![n]));
    k.add_stmt(Stmt::new(
        "comp",
        LhsRef::Array(Access::new("res", vec![AffExpr::var("i")])),
        Expr::add(
            Expr::load(Access::new("u", vec![AffExpr::var("i")])),
            Expr::load(Access::new("u", vec![AffExpr::var("i").plus_cst(1)])),
        ),
        &["i"],
    ));
    k
}

/// Seeded break 1 (`split_iname` family): a split of `i` by 4 that
/// forgets the last tile — `i_out` runs to `(n-8)/4` instead of
/// `(n-4)/4`, so a quarter of the writes vanish.  The real
/// `split_iname` refuses unprovable splits outright (asserted below),
/// so the defect is seeded by hand: it is exactly what a
/// guard-dropping split would produce.
#[test]
fn partial_tile_dropping_split_is_caught() {
    let base = stencil_base();
    assert!(
        split_iname(&base, "i", 3).is_err(),
        "split_iname should refuse an unprovable split"
    );

    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![
        LoopExtent::new(
            "i_out",
            QPoly::zero(),
            (&n - &QPoly::int(8)).floor_div(4),
        ),
        LoopExtent::zero_to("i_in", QPoly::int(4)),
    ]);
    let mut bad = Kernel::new("stencil1d", &["n"], dom);
    bad.add_array(ArrayDecl::global("u", DType::F32, vec![&n + &QPoly::one()]));
    bad.add_array(ArrayDecl::global("res", DType::F32, vec![n]));
    let ix = AffExpr::scaled_var("i_out", 4).plus(&AffExpr::var("i_in"));
    bad.add_stmt(Stmt::new(
        "comp",
        LhsRef::Array(Access::new("res", vec![ix.clone()])),
        Expr::add(
            Expr::load(Access::new("u", vec![ix.clone()])),
            Expr::load(Access::new("u", vec![ix.plus_cst(1)])),
        ),
        &["i_out", "i_in"],
    ));
    let bad = assume(&bad, "n >= 8 and n % 4 = 0").unwrap();

    let diags = check_equiv(&base, &bad);
    assert!(!diags.is_empty(), "dropped partial tile not caught");
    assert!(
        diags.iter().all(|d| d.code == DiagCode::SemanticsChanged),
        "{diags:?}"
    );
    assert!(
        diags.iter().any(|d| d.object.as_deref() == Some("res")),
        "expected a finding on the written array: {diags:?}"
    );
}

/// Seeded break 2 (`add_prefetch` shape): a staging transform that
/// fetches the tile without the stencil halo — the candidate reads
/// `u[i]` into a tile and computes from the tile alone, so `u[n]` (the
/// halo) never reaches the computation.
#[test]
fn halo_dropped_prefetch_is_caught() {
    let base = stencil_base();

    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
    let mut bad = Kernel::new("stencil1d", &["n"], dom);
    bad.add_array(ArrayDecl::global("u", DType::F32, vec![&n + &QPoly::one()]));
    bad.add_array(ArrayDecl::global("res", DType::F32, vec![n.clone()]));
    bad.add_array(ArrayDecl::local("tile", DType::F32, vec![n]));
    bad.add_stmt(Stmt::new(
        "fetch",
        LhsRef::Array(Access::new("tile", vec![AffExpr::var("i")])),
        Expr::load(Access::new("u", vec![AffExpr::var("i")])),
        &["i"],
    ));
    bad.add_stmt(
        Stmt::new(
            "comp",
            LhsRef::Array(Access::new("res", vec![AffExpr::var("i")])),
            Expr::add(
                Expr::load(Access::new("tile", vec![AffExpr::var("i")])),
                Expr::load(Access::new("tile", vec![AffExpr::var("i")])),
            ),
            &["i"],
        )
        .with_deps(&["fetch"]),
    );

    let diags = check_equiv(&base, &bad);
    assert!(
        diags.iter().any(|d| {
            d.code == DiagCode::SemanticsChanged
                && d.object.as_deref() == Some("u")
                && d.message.contains("not covering")
        }),
        "halo drop not caught: {diags:?}"
    );
}

/// Seeded break 3 (`remove_work`): stripping the `b` loads from the
/// tiled matmul (the calibration microbenchmark move) is *not* an
/// equivalent kernel — the read set and op volume both change.
#[test]
fn remove_work_strip_is_caught() {
    let full = build_matmul(DType::F32, false, 16).unwrap();
    let stripped = remove_work(&full, &RemoveSpec::arrays(&["b"])).unwrap();
    let diags = check_equiv(&full, &stripped);
    assert!(
        diags.iter().any(|d| {
            d.code == DiagCode::SemanticsChanged
                && d.object.as_deref() == Some("b")
        }),
        "stripped read set not caught: {diags:?}"
    );
    assert!(
        diags.iter().all(|d| d.code == DiagCode::SemanticsChanged),
        "{diags:?}"
    );
}

/// The complete pruning predicate over the shipped inventory: every
/// (chain, device) pair the simulator can launch is admissible, and
/// the one oversized launch (the 18x18 stencil tile on AMD's 256-item
/// limit) is rejected for exactly that reason.
#[test]
fn admissible_accepts_launchable_chains_and_rejects_oversized_wg() {
    let mut rejected = Vec::new();
    for (label, base, cand) in &shipped_chains() {
        for dev in fleet() {
            let verdict = admissible(base, cand, &dev);
            if cand.work_group_size() > dev.max_wg_size {
                let errs = verdict.expect_err(&format!(
                    "{label} on {}: oversized work-group not rejected",
                    dev.id
                ));
                assert!(
                    errs.iter().all(|d| d.code == DiagCode::WgSizeExceeded),
                    "{label} on {}: {errs:?}",
                    dev.id
                );
                rejected.push(format!("{label}@{}", dev.id));
            } else {
                assert!(
                    verdict.is_ok(),
                    "{label} on {}: false positive {:?}",
                    dev.id,
                    verdict.err()
                );
            }
        }
    }
    assert_eq!(
        rejected,
        vec!["fdiff/18x18@amd_r9_fury"],
        "exactly the paper's scope example should be pruned"
    );
}
