//! Loopy-style program transformations (paper Sections 1.1, 2.1, 7.1.1).
//!
//! Mathematically-equivalent program variants are produced by chaining
//! these transformations over a clean initial kernel — the mechanism
//! UiPiCK generators use to produce both the application kernels being
//! modeled and the measurement kernels that calibrate the models.
//!
//! * [`split`] — `split_iname`: tile a loop into outer/inner pairs.
//! * [`misc`] — `tag_inames`, `assume`, `fix_parameters`,
//!   `prioritize_loops`, `tag_data_axes`, `unroll`.
//! * [`prefetch`] — `add_prefetch`: stage an array tile through local
//!   memory (with bounding-box support for stencils).
//! * [`remove_work`] — Algorithm 3: strip on-chip work to isolate
//!   global-memory access patterns for microbenchmark synthesis.

pub mod misc;
pub mod prefetch;
pub mod remove_work;
pub mod split;

pub use misc::{assume, fix_parameters, prioritize_loops, tag_data_axes, tag_inames};
pub use prefetch::add_prefetch;
pub use remove_work::remove_work;
pub use split::split_iname;
