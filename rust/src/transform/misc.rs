//! `tag_inames`, `assume`, `fix_parameters`, `prioritize_loops`,
//! `tag_data_axes`, `unroll`.

use crate::ir::{AffExpr, IndexTag, Kernel, LhsRef};
use crate::polyhedral::{Assumptions, QPoly};

/// Tag inames with thread axes, e.g.
/// `tag_inames(&k, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0")`.
///
/// After tagging, the domain is canonicalized so parallel inames nest
/// outermost (group axes, then local axes, each by descending axis
/// number — lid(0) maps to adjacent SIMD lanes and therefore sits
/// innermost among the parallel dims), and every statement's `within`
/// list is re-sorted to the new domain order.
pub fn tag_inames(knl: &Kernel, spec: &str) -> Result<Kernel, String> {
    let mut out = knl.clone();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (iname, tag) = part
            .split_once(':')
            .ok_or_else(|| format!("tag_inames: expected 'iname:tag' in '{part}'"))?;
        let tag = IndexTag::parse(tag.trim())
            .ok_or_else(|| format!("tag_inames: bad tag in '{part}'"))?;
        let iname = iname.trim();
        if !out.domain.loops.iter().any(|l| l.var == iname) {
            return Err(format!("tag_inames: unknown iname '{iname}'"));
        }
        out.iname_tags.insert(iname.to_string(), tag);
    }
    canonicalize_order(&mut out)?;
    Ok(out)
}

/// Re-sort the domain: group axes desc, local axes desc, then sequential
/// loops in their current relative order; re-sort each statement's
/// `within` accordingly.
pub(crate) fn canonicalize_order(knl: &mut Kernel) -> Result<(), String> {
    let rank = |k: &Kernel, var: &str| -> (u8, u8) {
        match k.tag(var) {
            IndexTag::Group(a) => (0, u8::MAX - a),
            IndexTag::Local(a) => (1, u8::MAX - a),
            _ => (2, 0),
        }
    };
    let mut loops = knl.domain.loops.clone();
    // Stable sort keeps sequential loops in program order.
    loops.sort_by_key(|l| rank(knl, &l.var));
    // Parallel iname bounds must not depend on other inames.
    for l in &loops {
        if knl.tag(&l.var).is_parallel() {
            for other in &loops {
                if other.var != l.var
                    && (l.lo.mentions(&other.var) || l.hi.mentions(&other.var))
                {
                    return Err(format!(
                        "parallel iname '{}' has bounds depending on '{}'",
                        l.var, other.var
                    ));
                }
            }
        }
    }
    knl.domain.loops = loops;
    let order = knl.domain.var_names();
    for s in &mut knl.stmts {
        s.within
            .sort_by_key(|w| order.iter().position(|v| v == w).unwrap_or(usize::MAX));
    }
    Ok(())
}

/// Add assumptions (`assume(&k, "n >= 1 and n % 16 = 0")`) and
/// re-simplify all loop bounds under them.
pub fn assume(knl: &Kernel, text: &str) -> Result<Kernel, String> {
    let mut out = knl.clone();
    let add = Assumptions::parse(text)?;
    out.assumptions.merge(&add);
    for l in &mut out.domain.loops {
        l.lo = out.assumptions.simplify(&l.lo);
        l.hi = out.assumptions.simplify(&l.hi);
    }
    Ok(out)
}

/// Fix a parameter to a constant value everywhere (Loopy's
/// `fix_parameters`), removing it from the parameter list.
pub fn fix_parameters(knl: &Kernel, param: &str, value: i64) -> Result<Kernel, String> {
    if !knl.params.contains(&param.to_string()) {
        return Err(format!("fix_parameters: unknown parameter '{param}'"));
    }
    let mut out = knl.clone();
    let v = QPoly::int(value as i128);
    for l in &mut out.domain.loops {
        l.lo = l.lo.subst_deep(param, &v);
        l.hi = l.hi.subst_deep(param, &v);
    }
    for a in out.arrays.values_mut() {
        for s in &mut a.shape {
            *s = s.subst_deep(param, &v);
        }
    }
    let repl = AffExpr::cst(value);
    for s in &mut out.stmts {
        s.rhs = s.rhs.subst_index(param, &repl);
        if let LhsRef::Array(acc) = &mut s.lhs {
            for ix in &mut acc.indices {
                *ix = ix.subst(param, &repl);
            }
        }
    }
    out.params.retain(|p| p != param);
    out.assumptions.divisible.remove(param);
    out.assumptions.min_value.remove(param);
    Ok(out)
}

/// Set the preferred nesting of sequential loops (Loopy's
/// `prioritize_loops`): listed inames nest in the given order (outer
/// first); unlisted sequential loops keep their relative order and
/// nest after the listed ones only if they originally did.
pub fn prioritize_loops(knl: &Kernel, order: &[&str]) -> Result<Kernel, String> {
    let mut out = knl.clone();
    for o in order {
        if !out.domain.loops.iter().any(|l| l.var == *o) {
            return Err(format!("prioritize_loops: unknown iname '{o}'"));
        }
        if out.tag(o).is_parallel() {
            return Err(format!("prioritize_loops: '{o}' is parallel"));
        }
    }
    out.loop_priority = order.iter().map(|s| s.to_string()).collect();

    // Reorder the sequential suffix of the domain to respect priority.
    let mut seq: Vec<_> = out
        .domain
        .loops
        .iter()
        .filter(|l| !out.tag(&l.var).is_parallel())
        .cloned()
        .collect();
    let par: Vec<_> = out
        .domain
        .loops
        .iter()
        .filter(|l| out.tag(&l.var).is_parallel())
        .cloned()
        .collect();
    seq.sort_by_key(|l| {
        order
            .iter()
            .position(|o| *o == l.var)
            .unwrap_or(usize::MAX)
    });
    // Dependency sanity: bounds may only reference earlier loops.
    let mut seen: Vec<String> = par.iter().map(|l| l.var.clone()).collect();
    for l in &seq {
        for prior in out.domain.loops.iter().map(|x| &x.var) {
            if !seen.contains(prior)
                && *prior != l.var
                && (l.lo.mentions(prior) || l.hi.mentions(prior))
            {
                return Err(format!(
                    "prioritize_loops: '{}' bound depends on later loop '{prior}'",
                    l.var
                ));
            }
        }
        seen.push(l.var.clone());
    }
    out.domain.loops = par.into_iter().chain(seq).collect();
    let new_order = out.domain.var_names();
    for s in &mut out.stmts {
        s.within.sort_by_key(|w| {
            new_order
                .iter()
                .position(|v| v == w)
                .unwrap_or(usize::MAX)
        });
    }
    Ok(out)
}

/// Permute an array's memory layout (Loopy's `tag_data_axes`); the spec
/// lists axes slowest-varying first, e.g. `"N1,N0"` transposes a 2-D
/// array.  Used by the DG "transposed element data" variant.
pub fn tag_data_axes(knl: &Kernel, array: &str, spec: &str) -> Result<Kernel, String> {
    let mut out = knl.clone();
    let decl = out
        .arrays
        .get_mut(array)
        .ok_or_else(|| format!("tag_data_axes: unknown array '{array}'"))?;
    let mut order = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        let axis: usize = part
            .strip_prefix('N')
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| format!("tag_data_axes: bad axis '{part}'"))?;
        if axis >= decl.shape.len() || order.contains(&axis) {
            return Err(format!("tag_data_axes: invalid/duplicate axis '{part}'"));
        }
        order.push(axis);
    }
    if order.len() != decl.shape.len() {
        return Err(format!(
            "tag_data_axes: expected {} axes, got {}",
            decl.shape.len(),
            order.len()
        ));
    }
    decl.axis_order = order;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Access, ArrayDecl, DType, Expr, Stmt};
    use crate::polyhedral::{LoopExtent, NestedDomain};
    use crate::transform::split_iname;
    use crate::util::Rat;
    use std::collections::BTreeMap;

    fn mm_like() -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let mut knl = Kernel::new("mm", &["n"], dom);
        knl.add_array(ArrayDecl::global("a", DType::F32, vec![n.clone(), n.clone()]));
        knl.add_array(ArrayDecl::global("c", DType::F32, vec![n.clone(), n]));
        knl.add_temp("acc", DType::F32);
        knl.add_stmt(Stmt::new(
            "upd",
            LhsRef::Temp("acc".into()),
            Expr::add(
                Expr::temp("acc"),
                Expr::load(Access::new(
                    "a",
                    vec![AffExpr::var("i"), AffExpr::var("k")],
                )),
            ),
            &["i", "j", "k"],
        ));
        assume(&knl, "n >= 16 and n % 16 = 0").unwrap()
    }

    #[test]
    fn tag_inames_reorders_parallel_outermost() {
        let k = mm_like();
        let k = split_iname(&k, "i", 16).unwrap();
        let k = split_iname(&k, "j", 16).unwrap();
        let k = tag_inames(&k, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0").unwrap();
        assert_eq!(
            k.domain.var_names(),
            vec!["i_out", "j_out", "i_in", "j_in", "k"]
        );
        assert_eq!(k.work_group_size(), 256);
        assert_eq!(k.stmts[0].within, vec!["i_out", "j_out", "i_in", "j_in", "k"]);
        assert_eq!(k.validate(), Ok(()));
    }

    #[test]
    fn tag_inames_rejects_unknown() {
        let k = mm_like();
        assert!(tag_inames(&k, "zz:l.0").is_err());
        assert!(tag_inames(&k, "i:w.9").is_err());
    }

    #[test]
    fn fix_parameters_substitutes_everywhere() {
        let k = mm_like();
        let k2 = fix_parameters(&k, "n", 64).unwrap();
        assert!(k2.params.is_empty());
        assert_eq!(
            k2.domain.count().eval(&BTreeMap::new()),
            Rat::int(64 * 64 * 64)
        );
        let shape0 = &k2.arrays["a"].shape[0];
        assert_eq!(shape0.as_constant(), Some(Rat::int(64)));
    }

    #[test]
    fn prioritize_loops_reorders_sequential() {
        let k = mm_like();
        let k = split_iname(&k, "k", 16).unwrap();
        let k = tag_inames(&k, "i:g.0").unwrap();
        let k2 = prioritize_loops(&k, &["k_in", "k_out"]).unwrap();
        // Listed loops nest first (in order); unlisted sequential loops
        // follow in their prior relative order.
        assert_eq!(k2.domain.var_names(), vec!["i", "k_in", "k_out", "j"]);
        assert_eq!(k2.validate(), Ok(()));
    }

    #[test]
    fn prioritize_rejects_parallel_inames() {
        let k = mm_like();
        let k = tag_inames(&k, "i:g.0").unwrap();
        assert!(prioritize_loops(&k, &["i"]).is_err());
    }

    #[test]
    fn tag_data_axes_transposes() {
        let k = mm_like();
        let k2 = tag_data_axes(&k, "a", "N1,N0").unwrap();
        let env: BTreeMap<_, _> = [("n".to_string(), 100i128)].into_iter().collect();
        let strides = k2.arrays["a"].strides();
        assert_eq!(strides[0].eval(&env), Rat::int(1));
        assert_eq!(strides[1].eval(&env), Rat::int(100));
        assert!(tag_data_axes(&k, "a", "N0").is_err());
        assert!(tag_data_axes(&k, "a", "N0,N0").is_err());
    }

    #[test]
    fn assume_simplifies_existing_bounds() {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![LoopExtent::new(
            "v",
            QPoly::zero(),
            (&n - &QPoly::int(16)).floor_div(16),
        )]);
        let k = Kernel::new("t", &["n"], dom);
        let k2 = assume(&k, "n % 16 = 0 and n >= 16").unwrap();
        let expected = &n.scale(Rat::new(1, 16)) - &QPoly::one();
        assert_eq!(k2.domain.loops[0].hi, expected);
    }
}
