//! The work-removal transformation (paper §7.1.1, Algorithm 3).
//!
//! Strips arithmetic and local-memory operations from a kernel, leaving
//! a selected subset of its global memory accesses *with their loop
//! environment intact*, so microbenchmarks can exercise an access
//! pattern exactly as the application kernel performs it.  Kept loads
//! are folded into a `read_tgt` accumulator; if no global store
//! survives, a `read_tgt_dest` store (one entry per work-item, simple
//! stride-1 pattern) is appended so optimizing compilers cannot drop
//! the chain.

use std::collections::BTreeSet;

use crate::ir::{
    Access, AffExpr, ArrayDecl, Expr, IndexTag, Kernel, LhsRef, MemScope, Stmt,
};
use crate::polyhedral::QPoly;

/// Which global accesses to remove alongside all on-chip work.
/// Accesses are matched by array name or by memory-access tag.
#[derive(Clone, Debug, Default)]
pub struct RemoveSpec {
    pub remove_arrays: Vec<String>,
    pub remove_tags: Vec<String>,
}

impl RemoveSpec {
    pub fn arrays(names: &[&str]) -> RemoveSpec {
        RemoveSpec {
            remove_arrays: names.iter().map(|s| s.to_string()).collect(),
            remove_tags: Vec::new(),
        }
    }

    fn removes(&self, acc: &Access) -> bool {
        self.remove_arrays.contains(&acc.array)
            || acc
                .tag
                .as_ref()
                .is_some_and(|t| self.remove_tags.contains(t))
    }
}

/// Algorithm 3.  Returns the measurement kernel with name
/// `<name>_rmwork`.
pub fn remove_work(knl: &Kernel, spec: &RemoveSpec) -> Result<Kernel, String> {
    let mut out = knl.clone();
    out.name = format!("{}_rmwork", knl.name);

    let is_global =
        |out: &Kernel, a: &Access| out.arrays[&a.array].scope == MemScope::Global;

    // Determine the dtype of the kept loads (for read_tgt).
    let mut kept_dtype = None;
    for s in &knl.stmts {
        for l in s.rhs.loads() {
            if is_global(&out, l) && !spec.removes(l) {
                kept_dtype = Some(out.arrays[&l.array].dtype);
            }
        }
    }
    let dtype = kept_dtype.ok_or_else(|| {
        "remove_work: no global loads survive the removal spec".to_string()
    })?;

    out.add_temp("read_tgt", dtype);
    let init = Stmt::new(
        "init_read_tgt",
        LhsRef::Temp("read_tgt".into()),
        Expr::fconst(0.0),
        &[],
    );

    let mut new_stmts: Vec<Stmt> = vec![init];
    let mut kept_store = false;
    let mut counter = 0usize;
    for s in &knl.stmts {
        // Kept global loads accumulate into read_tgt, one statement per
        // load, preserving the source statement's loop environment.
        for l in s.rhs.loads() {
            if is_global(&out, l) && !spec.removes(l) {
                counter += 1;
                new_stmts.push(Stmt {
                    id: format!("acc_read_{counter}"),
                    lhs: LhsRef::Temp("read_tgt".into()),
                    rhs: Expr::add(Expr::temp("read_tgt"), Expr::Load(l.clone())),
                    within: s.within.clone(),
                    deps: vec!["init_read_tgt".to_string()],
                });
            }
        }
        // A kept global store becomes `store = read_tgt`.
        if let LhsRef::Array(st) = &s.lhs {
            if is_global(&out, st) && !spec.removes(st) {
                kept_store = true;
                new_stmts.push(Stmt {
                    id: format!("store_{}", s.id),
                    lhs: LhsRef::Array(st.clone()),
                    rhs: Expr::temp("read_tgt"),
                    within: s.within.clone(),
                    deps: vec!["init_read_tgt".to_string()],
                });
            }
        }
        // Original statement is dropped (this strips all arithmetic and
        // every local-memory transaction).
    }

    if !kept_store {
        // Create read_tgt_dest with one entry per work-item and a
        // straightforward stride-1 store: dest[wg1*ls1 + lid1][wg0*ls0
        // + lid0] (rank = number of used parallel axes).
        let mut dims: Vec<QPoly> = Vec::new();
        let mut idxs: Vec<AffExpr> = Vec::new();
        let mut within: Vec<String> = Vec::new();
        for axis in (0..3u8).rev() {
            let g = knl.iname_with_tag(IndexTag::Group(axis)).map(str::to_string);
            let l = knl.iname_with_tag(IndexTag::Local(axis)).map(str::to_string);
            if g.is_none() && l.is_none() {
                continue;
            }
            let ls = knl.lsize(axis) as i64;
            let dim = &knl.gsize(axis) * &QPoly::int(ls as i128);
            let mut idx = AffExpr::zero();
            if let Some(g) = &g {
                idx = idx.plus(&AffExpr::scaled_var(g, ls));
                within.push(g.clone());
            }
            if let Some(l) = &l {
                idx = idx.plus(&AffExpr::var(l));
                within.push(l.clone());
            }
            dims.push(dim);
            idxs.push(idx);
        }
        if dims.is_empty() {
            // Fully sequential kernel: single-element destination.
            dims.push(QPoly::one());
            idxs.push(AffExpr::cst(0));
        }
        out.add_array(ArrayDecl {
            name: "read_tgt_dest".into(),
            dtype,
            scope: MemScope::Global,
            shape: dims,
            axis_order: (0..idxs.len()).collect(),
        });
        // Keep `within` consistent with domain order.
        let order = out.domain.var_names();
        within.sort_by_key(|w| order.iter().position(|v| v == w).unwrap_or(usize::MAX));
        let deps: Vec<String> = new_stmts.iter().map(|s| s.id.clone()).collect();
        new_stmts.push(Stmt {
            id: "store_read_tgt_dest".into(),
            lhs: LhsRef::Array(Access::new("read_tgt_dest", idxs.clone())),
            rhs: Expr::temp("read_tgt"),
            within,
            deps,
        });
    }

    out.stmts = new_stmts;

    // Drop now-unused arrays — the local tiles whose transactions were
    // stripped *and* any global whose every access was removed (a
    // declared-but-dead array would otherwise ride along in every
    // derived measurement kernel) — and temps (keep read_tgt).
    let used_arrays: Vec<String> = out
        .stmts
        .iter()
        .flat_map(|s| {
            s.rhs
                .loads()
                .into_iter()
                .map(|l| l.array.clone())
                .chain(match &s.lhs {
                    LhsRef::Array(a) => Some(a.array.clone()),
                    _ => None,
                })
        })
        .collect();
    out.arrays.retain(|name, _| used_arrays.contains(name));
    let used_temps: Vec<String> = out
        .stmts
        .iter()
        .flat_map(|s| {
            s.rhs
                .temps_read()
                .into_iter()
                .map(str::to_string)
                .chain(match &s.lhs {
                    LhsRef::Temp(t) => Some(t.clone()),
                    _ => None,
                })
        })
        .collect();
    out.temps.retain(|name, _| used_temps.contains(name));

    // Prune sequential loops that no surviving statement nests in and
    // no subscript or bound references (e.g. the rank-superfluous
    // fetch iname of a removed prefetch tile).  Parallel inames are
    // kept even when unused: they define the launch grid, and dropping
    // one would change the kernel's work-group shape.
    let mut used_inames: BTreeSet<String> = BTreeSet::new();
    for s in &out.stmts {
        used_inames.extend(s.within.iter().cloned());
        let mut record = |acc: &Access| {
            for ix in &acc.indices {
                used_inames.extend(ix.vars().cloned());
            }
        };
        if let LhsRef::Array(a) = &s.lhs {
            record(a);
        }
        for l in s.rhs.loads() {
            record(l);
        }
    }
    for l in &out.domain.loops {
        for o in &out.domain.loops {
            if o.var != l.var && (o.lo.mentions(&l.var) || o.hi.mentions(&l.var))
            {
                used_inames.insert(l.var.clone());
            }
        }
    }
    let keep: Vec<String> = out
        .domain
        .loops
        .iter()
        .filter(|l| {
            out.tag(&l.var).is_parallel() || used_inames.contains(&l.var)
        })
        .map(|l| l.var.clone())
        .collect();
    if keep.len() < out.domain.loops.len() {
        out.domain.loops.retain(|l| keep.contains(&l.var));
        for iname in out.iname_tags.keys().cloned().collect::<Vec<_>>() {
            if !keep.contains(&iname) {
                out.iname_tags.remove(&iname);
            }
        }
        out.loop_priority.retain(|p| keep.contains(p));
    }

    out.validate()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;
    use crate::polyhedral::{LoopExtent, NestedDomain};
    use crate::transform::{add_prefetch, assume, split_iname, tag_inames};
    use crate::util::Rat;
    use std::collections::BTreeMap;

    fn env(n: i128) -> BTreeMap<String, i128> {
        [("n".to_string(), n)].into_iter().collect()
    }

    /// The paper's running example: tiled prefetching matmul.
    fn prefetching_matmul() -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let mut k = Kernel::new("matmul", &["n"], dom);
        for name in ["a", "b", "c"] {
            k.add_array(ArrayDecl::global(
                name,
                DType::F32,
                vec![n.clone(), n.clone()],
            ));
        }
        k.add_temp("acc", DType::F32);
        k.add_stmt(Stmt::new(
            "init",
            LhsRef::Temp("acc".into()),
            Expr::fconst(0.0),
            &["i", "j"],
        ));
        k.add_stmt(
            Stmt::new(
                "upd",
                LhsRef::Temp("acc".into()),
                Expr::add(
                    Expr::temp("acc"),
                    Expr::mul(
                        Expr::load(Access::tagged(
                            "a",
                            "aLD",
                            vec![AffExpr::var("i"), AffExpr::var("k")],
                        )),
                        Expr::load(Access::tagged(
                            "b",
                            "bLD",
                            vec![AffExpr::var("k"), AffExpr::var("j")],
                        )),
                    ),
                ),
                &["i", "j", "k"],
            )
            .with_deps(&["init"]),
        );
        k.add_stmt(
            Stmt::new(
                "store",
                LhsRef::Array(Access::new(
                    "c",
                    vec![AffExpr::var("i"), AffExpr::var("j")],
                )),
                Expr::temp("acc"),
                &["i", "j"],
            )
            .with_deps(&["upd"]),
        );
        let k = assume(&k, "n >= 16 and n % 16 = 0").unwrap();
        let k = split_iname(&k, "i", 16).unwrap();
        let k = split_iname(&k, "j", 16).unwrap();
        let k = split_iname(&k, "k", 16).unwrap();
        let k = tag_inames(&k, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0").unwrap();
        let k = add_prefetch(&k, "a", &["i_in", "k_in"], false).unwrap();
        add_prefetch(&k, "b", &["k_in", "j_in"], false).unwrap()
    }

    #[test]
    fn isolates_b_load_like_paper_section_7_1_1() {
        // remove_work(knl, remove_vars=["a", "c"]) keeps only the b
        // pattern: read_tgt += b[...] inside k_out, plus the dest store.
        let k = prefetching_matmul();
        let m = remove_work(&k, &RemoveSpec::arrays(&["a", "c"])).unwrap();

        // No local arrays, no arithmetic beyond the accumulate.
        assert!(m.arrays.values().all(|a| a.scope != MemScope::Local));
        let accs: Vec<_> = m
            .stmts
            .iter()
            .filter(|s| s.id.starts_with("acc_read"))
            .collect();
        assert_eq!(accs.len(), 1);
        let b_ld = &accs[0].rhs.loads()[0].clone();
        assert_eq!(b_ld.array, "b");

        // The access pattern to b is unchanged (paper invariant):
        // lid0 stride 1, gid0 stride 16, k_out stride 16n.
        let e = env(1024);
        assert_eq!(m.lid_stride(b_ld, 0).eval(&e), Rat::int(1));
        assert_eq!(m.gid_stride(b_ld, 0).eval(&e), Rat::int(16));
        assert_eq!(m.loop_stride(b_ld, "k_out").eval(&e), Rat::int(16 * 1024));

        // Store chain kept alive through read_tgt_dest.
        let st = m.stmt("store_read_tgt_dest").unwrap();
        let dest = st.store().unwrap().clone();
        assert_eq!(dest.array, "read_tgt_dest");
        // Simple stride-1 pattern: lid0 stride 1.
        assert_eq!(m.lid_stride(&dest, 0).eval(&e), Rat::int(1));
        assert_eq!(m.validate(), Ok(()));
    }

    #[test]
    fn keeps_existing_store_when_not_removed() {
        // Removing only `a`: the b load is kept and the original c
        // store survives as `c[...] = read_tgt`; no dest array needed.
        let k = prefetching_matmul();
        let m = remove_work(&k, &RemoveSpec::arrays(&["a"])).unwrap();
        assert!(m.stmt("store_store").is_some());
        assert!(!m.arrays.contains_key("read_tgt_dest"));
        let accs: Vec<_> = m
            .stmts
            .iter()
            .filter(|s| s.id.starts_with("acc_read"))
            .collect();
        assert_eq!(accs.len(), 1);
        assert_eq!(accs[0].rhs.loads()[0].array, "b");
        assert_eq!(m.validate(), Ok(()));
    }

    #[test]
    fn errors_when_nothing_left() {
        let k = prefetching_matmul();
        let err = remove_work(&k, &RemoveSpec::arrays(&["a", "b", "c"])).unwrap_err();
        assert!(err.contains("no global loads"), "{err}");
    }

    #[test]
    fn removal_by_tag() {
        let k = prefetching_matmul();
        let spec = RemoveSpec {
            remove_arrays: vec!["c".into()],
            remove_tags: vec!["aLD".into()],
        };
        let m = remove_work(&k, &spec).unwrap();
        let accs: Vec<_> = m
            .stmts
            .iter()
            .filter(|s| s.id.starts_with("acc_read"))
            .collect();
        assert_eq!(accs.len(), 1);
        assert_eq!(accs[0].rhs.loads()[0].array, "b");
    }
}
