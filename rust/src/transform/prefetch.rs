//! `add_prefetch`: stage an array's per-tile footprint through local
//! (scratchpad) memory before use (paper §2.1).
//!
//! The footprint of the chosen *sweep inames* is computed per array
//! axis from the affine subscripts; a local `<array>_fetch` array of
//! that (constant) box size is allocated, a fetch statement
//! parallelized over new local-tagged fetch inames is inserted, and all
//! original loads are redirected into the staged tile.  With
//! `fetch_bounding_box`, accesses differing by constant offsets (the
//! five-point stencil) share one bounding-box tile including the halo.

use crate::ir::{Access, AffExpr, ArrayDecl, Expr, IndexTag, Kernel, LhsRef, Stmt};
use crate::polyhedral::{LoopExtent, QPoly};

use super::misc::canonicalize_order;

/// Per-axis footprint description.
struct AxisFootprint {
    /// Offset (affine in non-sweep inames) of the tile origin.
    offset: AffExpr,
    /// Constant box extent along this axis.
    size: i64,
}

/// Stage loads of `array` through local memory, sweeping `sweep_inames`.
///
/// `fetch_bounding_box` allows multiple loads whose subscripts differ by
/// constants (stencils); without it, all loads must share one subscript.
pub fn add_prefetch(
    knl: &Kernel,
    array: &str,
    sweep_inames: &[&str],
    fetch_bounding_box: bool,
) -> Result<Kernel, String> {
    let mut out = knl.clone();
    let decl = out
        .arrays
        .get(array)
        .ok_or_else(|| format!("add_prefetch: unknown array '{array}'"))?
        .clone();

    // Collect the distinct subscript vectors of all loads of `array`,
    // plus the ids of the statements that perform them.
    let mut subscripts: Vec<Vec<AffExpr>> = Vec::new();
    let mut reader_ids: Vec<String> = Vec::new();
    for s in &out.stmts {
        let mut reads_array = false;
        for l in s.rhs.loads() {
            if l.array == array {
                reads_array = true;
                if !subscripts.contains(&l.indices) {
                    subscripts.push(l.indices.clone());
                }
            }
        }
        if reads_array {
            reader_ids.push(s.id.clone());
        }
    }
    if subscripts.is_empty() {
        return Err(format!("add_prefetch: no loads of '{array}'"));
    }
    if subscripts.len() > 1 && !fetch_bounding_box {
        return Err(format!(
            "add_prefetch: {} distinct access patterns to '{array}'; \
             pass fetch_bounding_box=true",
            subscripts.len()
        ));
    }

    // Constant extent of each sweep iname.
    let sweep_extent = |iname: &str| -> Result<i64, String> {
        let l = out
            .domain
            .loops
            .iter()
            .find(|l| l.var == iname)
            .ok_or_else(|| format!("add_prefetch: unknown sweep iname '{iname}'"))?;
        out.assumptions
            .simplify(&l.extent())
            .as_constant()
            .and_then(|c| c.as_integer())
            .map(|v| v as i64)
            .ok_or_else(|| {
                format!("add_prefetch: sweep iname '{iname}' has non-constant extent")
            })
    };

    // Per-axis footprint: split each subscript into sweep part
    // (constant-coefficient over sweep inames) and the remaining offset.
    let rank = decl.shape.len();
    let mut footprint: Vec<AxisFootprint> = Vec::with_capacity(rank);
    for d in 0..rank {
        let mut base_offset: Option<AffExpr> = None;
        let mut lo: Option<i64> = None;
        let mut hi: Option<i64> = None;
        for idx in &subscripts {
            let ix = &idx[d];
            // Non-sweep, non-constant part must agree across accesses.
            let mut offset = ix.clone();
            offset.constant = 0;
            let mut sweep_min = 0i64;
            let mut sweep_max = 0i64;
            for iname in sweep_inames {
                let c = ix.coeff(iname);
                if c != 0 {
                    offset = offset.subst(iname, &AffExpr::cst(0));
                    let reach = c * (sweep_extent(iname)? - 1);
                    sweep_min += reach.min(0);
                    sweep_max += reach.max(0);
                }
            }
            match &base_offset {
                None => base_offset = Some(offset),
                Some(b) if *b == offset => {}
                Some(_) => {
                    return Err(format!(
                        "add_prefetch: loads of '{array}' disagree in \
                         non-sweep subscript terms on axis {d}"
                    ))
                }
            }
            let c = ix.constant;
            lo = Some(lo.map_or(c + sweep_min, |v| v.min(c + sweep_min)));
            hi = Some(hi.map_or(c + sweep_max, |v| v.max(c + sweep_max)));
        }
        // `subscripts` is non-empty here (checked above), so every
        // axis saw at least one index expression; degrade to an error
        // anyway rather than trusting that invariant with a panic.
        let (lo, hi, mut offset) = match (lo, hi, base_offset) {
            (Some(lo), Some(hi), Some(offset)) => (lo, hi, offset),
            _ => {
                return Err(format!(
                    "add_prefetch: no usable footprint for '{array}' on \
                     axis {d}"
                ))
            }
        };
        offset.constant = lo;
        footprint.push(AxisFootprint {
            offset,
            size: hi - lo + 1,
        });
    }

    // Allocate the local tile.
    let fetch_name = format!("{array}_fetch");
    if out.arrays.contains_key(&fetch_name) {
        return Err(format!("add_prefetch: '{fetch_name}' already exists"));
    }
    out.add_array(ArrayDecl::local(
        &fetch_name,
        decl.dtype,
        footprint.iter().map(|f| QPoly::int(f.size as i128)).collect(),
    ));

    // Fetch inames: one per axis, local-tagged so the whole work-group
    // cooperates (axis rank-1 -> l.0, rank-2 -> l.1, earlier axes
    // sequential).
    let mut fetch_inames = Vec::with_capacity(rank);
    for (d, f) in footprint.iter().enumerate() {
        let iname = format!("{array}_dim_{d}");
        out.domain
            .loops
            .push(LoopExtent::zero_to(&iname, QPoly::int(f.size as i128)));
        let from_last = rank - 1 - d;
        if from_last <= 1 {
            out.iname_tags
                .insert(iname.clone(), IndexTag::Local(from_last as u8));
        }
        fetch_inames.push(iname);
    }

    // Fetch statement: <array>_fetch[f0,..] = array[offset_d + f_d].
    let fetch_id = format!("fetch_{array}");
    let src = Access {
        array: array.to_string(),
        // Keep the original tag if all loads shared one, so models can
        // still name this access pattern.
        tag: knl
            .stmts
            .iter()
            .flat_map(|s| s.rhs.loads())
            .find(|l| l.array == array)
            .and_then(|l| l.tag.clone()),
        indices: footprint
            .iter()
            .zip(&fetch_inames)
            .map(|(f, iname)| f.offset.plus(&AffExpr::var(iname)))
            .collect(),
    };
    // The fetch nests inside every iname its subscripts mention plus
    // the fetch inames themselves.
    let mut within: Vec<String> = Vec::new();
    for idx in &src.indices {
        for v in idx.vars() {
            if out.domain.loops.iter().any(|l| &l.var == v) && !within.contains(v) {
                within.push(v.clone());
            }
        }
    }
    for f in &fetch_inames {
        if !within.contains(f) {
            within.push(f.clone());
        }
    }
    let dst = Access::new(
        &fetch_name,
        fetch_inames.iter().map(|f| AffExpr::var(f)).collect(),
    );
    out.stmts.push(Stmt {
        id: fetch_id.clone(),
        lhs: LhsRef::Array(dst),
        rhs: Expr::load(src),
        within,
        deps: Vec::new(),
    });
    // Keep fetches textually (and schedule-wise) before the compute.
    let last = out.stmts.len() - 1;
    out.stmts.rotate_right(1);
    let _ = last;

    // Redirect the original loads into the tile and record deps.
    for s in &mut out.stmts {
        if s.id == fetch_id {
            continue;
        }
        s.rhs = s.rhs.map_loads(&mut |l| {
            if l.array != array {
                return Expr::Load(l.clone());
            }
            let new_idx = footprint
                .iter()
                .zip(&l.indices)
                .map(|(f, ix)| ix.minus(&f.offset))
                .collect();
            Expr::Load(Access {
                array: fetch_name.clone(),
                tag: None,
                indices: new_idx,
            })
        });
        if reader_ids.contains(&s.id) && !s.deps.contains(&fetch_id) {
            s.deps.push(fetch_id.clone());
        }
    }

    canonicalize_order(&mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{DType, MemScope};
    use crate::polyhedral::NestedDomain;
    use crate::transform::{assume, split_iname, tag_inames};
    use crate::util::Rat;
    use std::collections::BTreeMap;

    fn env(n: i128) -> BTreeMap<String, i128> {
        [("n".to_string(), n)].into_iter().collect()
    }

    /// Build the §2.1 tiled matmul up to (but not including) prefetch.
    fn tiled_matmul() -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let mut k = Kernel::new("matmul", &["n"], dom);
        for name in ["a", "b", "c"] {
            k.add_array(ArrayDecl::global(
                name,
                DType::F32,
                vec![n.clone(), n.clone()],
            ));
        }
        k.add_temp("acc", DType::F32);
        k.add_stmt(Stmt::new(
            "init",
            LhsRef::Temp("acc".into()),
            Expr::fconst(0.0),
            &["i", "j"],
        ));
        k.add_stmt(
            Stmt::new(
                "upd",
                LhsRef::Temp("acc".into()),
                Expr::add(
                    Expr::temp("acc"),
                    Expr::mul(
                        Expr::load(Access::tagged(
                            "a",
                            "aLD",
                            vec![AffExpr::var("i"), AffExpr::var("k")],
                        )),
                        Expr::load(Access::tagged(
                            "b",
                            "bLD",
                            vec![AffExpr::var("k"), AffExpr::var("j")],
                        )),
                    ),
                ),
                &["i", "j", "k"],
            )
            .with_deps(&["init"]),
        );
        k.add_stmt(
            Stmt::new(
                "store",
                LhsRef::Array(Access::new(
                    "c",
                    vec![AffExpr::var("i"), AffExpr::var("j")],
                )),
                Expr::temp("acc"),
                &["i", "j"],
            )
            .with_deps(&["upd"]),
        );
        let k = assume(&k, "n >= 16 and n % 16 = 0").unwrap();
        let k = split_iname(&k, "i", 16).unwrap();
        let k = split_iname(&k, "j", 16).unwrap();
        let k = split_iname(&k, "k", 16).unwrap();
        tag_inames(&k, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0").unwrap()
    }

    #[test]
    fn matmul_prefetch_matches_paper_codegen() {
        let k = tiled_matmul();
        let k = add_prefetch(&k, "a", &["i_in", "k_in"], false).unwrap();
        let k = add_prefetch(&k, "b", &["k_in", "j_in"], false).unwrap();
        assert_eq!(k.validate(), Ok(()));

        // 16x16 local tiles.
        for arr in ["a_fetch", "b_fetch"] {
            let d = &k.arrays[arr];
            assert_eq!(d.scope, MemScope::Local);
            assert_eq!(d.shape[0].as_constant(), Some(Rat::int(16)));
            assert_eq!(d.shape[1].as_constant(), Some(Rat::int(16)));
        }

        // Fetch of a: a[16*i_out + a_dim_0, 16*k_out + a_dim_1] with
        // a_dim_0 ~ lid(1), a_dim_1 ~ lid(0) — paper's
        // a[n*(16*gid(1) + lid(1)) + 16*k_out + lid(0)].
        let fetch_a = k.stmt("fetch_a").unwrap();
        let ld = &fetch_a.rhs.loads()[0].clone();
        assert_eq!(k.tag("a_dim_0"), IndexTag::Local(1));
        assert_eq!(k.tag("a_dim_1"), IndexTag::Local(0));
        let e = env(1024);
        assert_eq!(k.lid_stride(ld, 0).eval(&e), Rat::int(1));
        assert_eq!(k.lid_stride(ld, 1).eval(&e), Rat::int(1024));
        assert_eq!(k.gid_stride(ld, 1).eval(&e), Rat::int(16 * 1024));
        assert_eq!(k.gid_stride(ld, 0).eval(&e), Rat::int(0));
        assert_eq!(k.loop_stride(ld, "k_out").eval(&e), Rat::int(16));

        // Fetch of b: gid0 stride 16, k_out stride 16n (Table 1).
        let fetch_b = k.stmt("fetch_b").unwrap();
        let ld = &fetch_b.rhs.loads()[0].clone();
        assert_eq!(k.lid_stride(ld, 0).eval(&e), Rat::int(1));
        assert_eq!(k.gid_stride(ld, 0).eval(&e), Rat::int(16));
        assert_eq!(k.gid_stride(ld, 1).eval(&e), Rat::int(0));
        assert_eq!(k.loop_stride(ld, "k_out").eval(&e), Rat::int(16 * 1024));

        // Compute now reads the local tiles:
        // acc + a_fetch[i_in, k_in] * b_fetch[k_in, j_in].
        let upd = k.stmt("upd").unwrap();
        let loads = upd.rhs.loads();
        assert_eq!(loads[0].array, "a_fetch");
        assert_eq!(loads[1].array, "b_fetch");
        assert_eq!(loads[0].indices[0], AffExpr::var("i_in"));
        assert_eq!(loads[0].indices[1], AffExpr::var("k_in"));
        assert!(upd.deps.contains(&"fetch_a".to_string()));
        assert!(upd.deps.contains(&"fetch_b".to_string()));
    }

    #[test]
    fn stencil_bounding_box_includes_halo() {
        // 1-D three-point stencil: res[i] = u[i] + u[i+1] + u[i+2]
        // after splitting i by 14 and prefetching with bounding box,
        // the tile must be 16 wide.
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
        let mut k = Kernel::new("stencil", &["n"], dom);
        k.add_array(ArrayDecl::global(
            "u",
            DType::F32,
            vec![(&n + &QPoly::int(2))],
        ));
        k.add_array(ArrayDecl::global("res", DType::F32, vec![n]));
        let u = |c: i64| {
            Expr::load(Access::new("u", vec![AffExpr::var("i").plus_cst(c)]))
        };
        k.add_stmt(Stmt::new(
            "s",
            LhsRef::Array(Access::new("res", vec![AffExpr::var("i")])),
            Expr::add(Expr::add(u(0), u(1)), u(2)),
            &["i"],
        ));
        let k = assume(&k, "n >= 14 and n % 14 = 0").unwrap();
        let k = split_iname(&k, "i", 14).unwrap();
        let k = tag_inames(&k, "i_out:g.0, i_in:l.0").unwrap();
        let k = add_prefetch(&k, "u", &["i_in"], true).unwrap();
        assert_eq!(k.validate(), Ok(()));

        let d = &k.arrays["u_fetch"];
        assert_eq!(d.shape[0].as_constant(), Some(Rat::int(16)));
        // Work-group is widened to 16 by the fetch iname.
        assert_eq!(k.lsize(0), 16);
        // Loads redirected with halo offsets preserved.
        let s = k.stmt("s").unwrap();
        for (ld, expected_c) in s.rhs.loads().iter().zip([0i64, 1, 2]) {
            assert_eq!(ld.array, "u_fetch");
            assert_eq!(ld.indices[0].constant, expected_c);
            assert_eq!(ld.indices[0].coeff("i_in"), 1);
        }
    }

    #[test]
    fn prefetch_rejects_multiple_patterns_without_bounding_box() {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
        let mut k = Kernel::new("t", &["n"], dom);
        k.add_array(ArrayDecl::global("u", DType::F32, vec![(&n + &QPoly::one())]));
        k.add_array(ArrayDecl::global("res", DType::F32, vec![n]));
        let u = |c: i64| Expr::load(Access::new("u", vec![AffExpr::var("i").plus_cst(c)]));
        k.add_stmt(Stmt::new(
            "s",
            LhsRef::Array(Access::new("res", vec![AffExpr::var("i")])),
            Expr::add(u(0), u(1)),
            &["i"],
        ));
        let err = add_prefetch(&k, "u", &["i"], false).unwrap_err();
        assert!(err.contains("fetch_bounding_box"), "{err}");
    }

    #[test]
    fn prefetch_counts_reduce_global_traffic() {
        // After prefetching, the only global loads of `a` are the fetch
        // statement's: (n/16)^2 groups * 256 * (n/16) instances = n^3/16
        // vs n^3 without prefetch.
        let k0 = tiled_matmul();
        let k = add_prefetch(&k0, "a", &["i_in", "k_in"], false).unwrap();
        let fetch = k.stmt("fetch_a").unwrap();
        let dom = k.stmt_domain(fetch);
        let count = k.assumptions.simplify(&dom.count());
        let e = env(64);
        // within(fetch_a) covers i_out, a_dim_0, a_dim_1, k_out:
        // 4 * 16 * 16 * 4 = 4096; the j_out group axis (extent 4) is
        // uniform-covered, making 16384 total = 64^3/16.
        assert_eq!(count.eval(&e), Rat::int(4096));
    }
}
