//! `split_iname`: divide one loop into an outer/inner nested pair.

use crate::ir::{AffExpr, Kernel, LhsRef};
use crate::polyhedral::{LoopExtent, QPoly};

/// Split `iname` (which must start at 0) by `factor`:
/// `iname = factor * iname_out + iname_in`, with
/// `0 <= iname_in < factor` and `0 <= iname_out <= floor((extent-1)/factor)`.
///
/// Matches Loopy's `lp.split_iname`.  Without a divisibility assumption
/// on the extent the outer bound stays a floor quasi-polynomial and the
/// final partial tile would need a guard; all kernels in this
/// reproduction either carry `assume(extent % factor == 0)` or only use
/// sizes where the split is exact (the paper's `groups_fit:True`), so
/// `split_iname` rejects unprovable splits rather than emitting
/// conditionals.
pub fn split_iname(knl: &Kernel, iname: &str, factor: i64) -> Result<Kernel, String> {
    if factor <= 0 {
        return Err(format!(
            "split_iname: factor must be positive, got {factor}"
        ));
    }
    let mut out = knl.clone();
    let pos = out
        .domain
        .loops
        .iter()
        .position(|l| l.var == iname)
        .ok_or_else(|| format!("split_iname: unknown iname '{iname}'"))?;

    let l = out.domain.loops[pos].clone();
    if !l.lo.is_zero() {
        return Err(format!("split_iname: '{iname}' must start at 0"));
    }
    let extent = l.extent();

    // Provability: the extent must be a multiple of `factor`, either as
    // a constant or via divisibility assumptions.
    let simplified_extent = out.assumptions.simplify(&extent);
    let exact = match simplified_extent.as_constant() {
        Some(c) => c
            .as_integer()
            .map(|v| v % factor as i128 == 0)
            .unwrap_or(false),
        None => {
            // floor(extent/f) * f == extent after assumption rewriting?
            let fd = out
                .assumptions
                .simplify(&simplified_extent.floor_div(factor as i128));
            &fd.scale(crate::util::Rat::int(factor as i128)) == &simplified_extent
        }
    };
    if !exact {
        return Err(format!(
            "split_iname: cannot prove {factor} divides extent '{extent}' of \
             '{iname}'; add an assume(... % {factor} == 0) or use sizes \
             where groups fit"
        ));
    }

    let outer = format!("{iname}_out");
    let inner = format!("{iname}_in");
    let hi_out = {
        let fd = (&extent - &QPoly::one()).floor_div(factor as i128);
        out.assumptions.simplify(&fd)
    };
    out.domain.loops.splice(
        pos..=pos,
        [
            LoopExtent::new(&outer, QPoly::zero(), hi_out),
            LoopExtent::new(&inner, QPoly::zero(), QPoly::int(factor as i128 - 1)),
        ],
    );

    // Rewrite all statements: iname -> factor*outer + inner.
    let replacement = AffExpr::scaled_var(&outer, factor).plus(&AffExpr::var(&inner));
    for s in &mut out.stmts {
        s.rhs = s.rhs.subst_index(iname, &replacement);
        if let LhsRef::Array(a) = &mut s.lhs {
            for ix in &mut a.indices {
                *ix = ix.subst(iname, &replacement);
            }
        }
        if let Some(i) = s.within.iter().position(|w| w == iname) {
            s.within
                .splice(i..=i, [outer.clone(), inner.clone()]);
        }
    }

    // Loop priority: replace mention.
    if let Some(i) = out.loop_priority.iter().position(|w| w == iname) {
        out.loop_priority
            .splice(i..=i, [outer.clone(), inner.clone()]);
    }
    out.iname_tags.remove(iname);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Access, ArrayDecl, DType, Expr, IndexTag, Stmt};
    use crate::polyhedral::{Assumptions, NestedDomain};
    use crate::util::Rat;
    use std::collections::BTreeMap;

    fn env(n: i128) -> BTreeMap<String, i128> {
        [("n".to_string(), n)].into_iter().collect()
    }

    fn simple_copy_kernel() -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
        let mut k = Kernel::new("copy", &["n"], dom);
        k.assumptions = Assumptions::none().divisible_by("n", 16).at_least("n", 16);
        k.add_array(ArrayDecl::global("x", DType::F32, vec![n.clone()]));
        k.add_array(ArrayDecl::global("y", DType::F32, vec![n]));
        k.add_stmt(Stmt::new(
            "cp",
            LhsRef::Array(Access::new("y", vec![AffExpr::var("i")])),
            Expr::load(Access::new("x", vec![AffExpr::var("i")])),
            &["i"],
        ));
        k
    }

    #[test]
    fn split_rewrites_domain_and_subscripts() {
        let k = simple_copy_kernel();
        let k2 = split_iname(&k, "i", 16).unwrap();
        assert_eq!(k2.domain.loops.len(), 2);
        assert_eq!(k2.domain.loops[0].var, "i_out");
        assert_eq!(k2.domain.loops[1].var, "i_in");
        // Point count preserved.
        assert_eq!(
            k2.domain.count().eval(&env(64)),
            k.domain.count().eval(&env(64))
        );
        // Subscript rewritten to 16*i_out + i_in.
        let s = &k2.stmts[0];
        let ld = &s.rhs.loads()[0];
        assert_eq!(ld.indices[0].coeff("i_out"), 16);
        assert_eq!(ld.indices[0].coeff("i_in"), 1);
        assert_eq!(ld.indices[0].coeff("i"), 0);
        assert_eq!(s.within, vec!["i_out", "i_in"]);
        assert_eq!(k2.validate(), Ok(()));
    }

    #[test]
    fn split_outer_bound_simplifies_under_assume() {
        let k = simple_copy_kernel();
        let k2 = split_iname(&k, "i", 16).unwrap();
        // 0 <= i_out <= n/16 - 1, cleanly (no floor atom).
        let hi = &k2.domain.loops[0].hi;
        let expected = &QPoly::var("n").scale(Rat::new(1, 16)) - &QPoly::one();
        assert_eq!(hi, &expected, "got {hi}");
    }

    #[test]
    fn split_rejects_unprovable_divisibility() {
        let mut k = simple_copy_kernel();
        k.assumptions = Assumptions::none(); // drop the % 16 fact
        let err = split_iname(&k, "i", 16).unwrap_err();
        assert!(err.contains("cannot prove"), "{err}");
    }

    #[test]
    fn split_constant_extent() {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![LoopExtent::zero_to("j", QPoly::int(64))]);
        let mut k = Kernel::new("t", &["n"], dom);
        k.add_array(ArrayDecl::global("x", DType::F32, vec![n]));
        k.add_stmt(Stmt::new(
            "s",
            LhsRef::Array(Access::new("x", vec![AffExpr::var("j")])),
            Expr::fconst(1.0),
            &["j"],
        ));
        let k2 = split_iname(&k, "j", 16).unwrap();
        assert_eq!(k2.domain.loops[0].hi, QPoly::int(3));
        assert_eq!(k2.domain.count().eval(&BTreeMap::new()), Rat::int(64));
    }

    #[test]
    fn double_split_composes() {
        let k = simple_copy_kernel();
        let k2 = split_iname(&k, "i", 16).unwrap();
        let k3 = split_iname(&k2, "i_in", 4).unwrap();
        assert_eq!(
            k3.domain.var_names(),
            vec!["i_out", "i_in_out", "i_in_in"]
        );
        assert_eq!(k3.domain.count().eval(&env(64)), Rat::int(64));
        let ld = &k3.stmts[0].rhs.loads()[0];
        assert_eq!(ld.indices[0].coeff("i_out"), 16);
        assert_eq!(ld.indices[0].coeff("i_in_out"), 4);
        assert_eq!(ld.indices[0].coeff("i_in_in"), 1);
    }

    #[test]
    fn split_preserves_tags_of_other_inames() {
        let mut k = simple_copy_kernel();
        k.iname_tags.insert("i".into(), IndexTag::Sequential);
        let k2 = split_iname(&k, "i", 16).unwrap();
        // The split iname's own tag is dropped (retag explicitly).
        assert!(!k2.iname_tags.contains_key("i"));
    }
}
