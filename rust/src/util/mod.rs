//! Shared utilities: exact rational arithmetic, deterministic RNG,
//! minimal JSON reader/writer, and a seeded property-testing helper.
//!
//! These exist because the offline crate set contains only the `xla`
//! dependency closure (see Cargo.toml header note): no `serde`, no
//! `rand`, no `proptest`.

pub mod json;
pub mod prop;
pub mod rat;
pub mod rng;

pub use rat::Rat;
pub use rng::Rng;
