//! Shared utilities: exact rational arithmetic, deterministic RNG,
//! minimal JSON reader/writer, and a seeded property-testing helper.
//!
//! These exist because the offline crate set contains only the `xla`
//! dependency closure (see Cargo.toml header note): no `serde`, no
//! `rand`, no `proptest`.

pub mod fnv;
pub mod json;
pub mod prop;
pub mod rat;
pub mod rng;

pub use fnv::Fnv128;
pub use rat::Rat;
pub use rng::Rng;

/// Ensure `dir` exists and is actually writable (a probe file is
/// created and removed): the shared fail-fast check behind the CLI's
/// `--json` flag and the artifact store root.  Creating directories
/// alone is not enough — `create_dir_all` succeeds on a pre-existing
/// read-only tree; a real write cannot.
pub fn ensure_writable_dir(dir: &std::path::Path, what: &str) -> Result<(), String> {
    std::fs::create_dir_all(dir)
        .map_err(|e| format!("{what} '{}' is unusable: {e}", dir.display()))?;
    let probe = dir.join(format!(".probe-{}", std::process::id()));
    std::fs::write(&probe, b"ok")
        .map_err(|e| format!("{what} '{}' is not writable: {e}", dir.display()))?;
    let _ = std::fs::remove_file(&probe);
    Ok(())
}
