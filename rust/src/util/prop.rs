//! Seeded property-testing helper (offline substitute for `proptest`).
//!
//! `check` runs a property over `cases` deterministically-seeded RNGs and
//! reports the failing seed so a failure can be replayed as a unit test:
//!
//! ```no_run
//! use perflex::util::prop;
//! prop::check("add commutes", 64, |rng| {
//!     let (a, b) = (rng.int_in(-100, 100), rng.int_in(-100, 100));
//!     prop::ensure(a + b == b + a, format!("{a} {b}"))
//! });
//! ```

use super::rng::Rng;

/// Property outcome: `Ok(())` or a failure description.
pub type PropResult = Result<(), String>;

/// Helper for readable property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> PropResult {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

/// Assert approximate equality with relative tolerance.
pub fn ensure_close(a: f64, b: f64, rtol: f64, what: &str) -> PropResult {
    let scale = a.abs().max(b.abs()).max(1e-300);
    if (a - b).abs() <= rtol * scale {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (rtol {rtol})"))
    }
}

/// Run `body` for `cases` seeds; panic with the seed on first failure.
pub fn check(name: &str, cases: u64, mut body: impl FnMut(&mut Rng) -> PropResult) {
    for case in 0..cases {
        // Mix the property name into the seed stream so distinct
        // properties explore distinct inputs.
        let seed = name
            .bytes()
            .fold(0xcbf29ce484222325u64, |h, b| {
                (h ^ b as u64).wrapping_mul(0x100000001b3)
            })
            .wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = body(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivially true", 32, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always false'")]
    fn failing_property_panics_with_seed() {
        check("always false", 4, |_| ensure(false, "nope"));
    }

    #[test]
    fn ensure_close_tolerance() {
        assert!(ensure_close(1.0, 1.0 + 1e-12, 1e-9, "x").is_ok());
        assert!(ensure_close(1.0, 1.1, 1e-9, "x").is_err());
    }
}
