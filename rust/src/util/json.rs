//! Minimal JSON value model, writer and reader.
//!
//! Used for the artifact manifest (read, written by python/compile/aot.py)
//! and experiment reports (write).  Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser {
            b: text.as_bytes(),
            i: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

fn write_escaped(s: &str, f: &mut fmt::Formatter<'_>) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                // Integer-valued floats print as integers — except
                // negative zero, which must keep its sign so that
                // serialize -> parse -> serialize round-trips f64s
                // exactly (the artifact store depends on this).
                if x.fract() == 0.0 && x.abs() < 1e15 && (*x != 0.0 || x.is_sign_positive())
                {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(s, f),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(k, f)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.i < self.b.len() && self.b[self.i] == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
            None => Err("unexpected end of input".into()),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or("bad \\u escape")?,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            let cp =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy one UTF-8 scalar.
                    let s = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            m.insert(k, self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let j = Json::obj(vec![
            ("name", "lm_step".into()),
            ("L", 128i64.into()),
            ("ok", true.into()),
            ("xs", vec![1i64, 2, 3].into()),
            ("nested", Json::obj(vec![("pi", 3.5.into())])),
        ]);
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_python_style_manifest() {
        let text = r#"{
          "version": 3, "dtype": "float64", "L": 128,
          "entries": {"lm_step": {"file": "lm_step.hlo.txt",
                                  "args": ["F[L,J]", "t[L]"]}}
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("L").and_then(Json::as_i64), Some(128));
        assert_eq!(
            j.get("entries")
                .and_then(|e| e.get("lm_step"))
                .and_then(|e| e.get("file"))
                .and_then(Json::as_str),
            Some("lm_step.hlo.txt")
        );
    }

    #[test]
    fn escapes() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn floats_roundtrip_exactly() {
        for x in [0.1 + 0.2, 1.5e-9, -0.0, 5.0, -7.0, 3.86e-17, 1e300] {
            let text = Json::Num(x).to_string();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(
                back.to_bits(),
                x.to_bits(),
                "{x} -> '{text}' -> {back} must preserve bits"
            );
        }
    }
}
