//! Deterministic pseudo-random numbers (xoshiro256**, Box-Muller).
//!
//! Used by the GPU simulator's measurement-noise model and by the
//! property-testing helper.  Deterministic seeding keeps every
//! experiment and test reproducible.

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Box-Muller output.
    gauss_spare: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng {
            s,
            gauss_spare: None,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)`.
    pub fn below(&mut self, n: u64) -> u64 {
        // Modulo bias is irrelevant for our n << 2^64 use cases.
        self.next_u64() % n.max(1)
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller.
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        let (mut u1, u2) = (self.uniform(), self.uniform());
        if u1 <= f64::MIN_POSITIVE {
            u1 = f64::MIN_POSITIVE;
        }
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.gauss_spare = Some(r * s);
        r * c
    }

    /// Multiplicative log-normal noise factor with std-dev ~ `sigma`.
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (sigma * self.gauss()).exp()
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gauss();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn int_in_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.int_in(-5, 5);
            assert!((-5..=5).contains(&x));
        }
    }
}
