//! Incremental 128-bit FNV-1a — the one hash implementation shared by
//! structural kernel fingerprints ([`crate::ir::Kernel::fingerprint`])
//! and artifact-store fit keys ([`crate::session::fit_key`]).

const PRIME: u64 = 0x100000001b3;

/// Incremental 128-bit FNV-1a hasher (two mixed 64-bit lanes).
pub struct Fnv128 {
    lo: u64,
    hi: u64,
}

impl Fnv128 {
    pub fn new() -> Fnv128 {
        Fnv128 {
            lo: 0xcbf29ce484222325,
            hi: 0x9e3779b97f4a7c15,
        }
    }

    /// Feed raw bytes (no framing).
    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.lo = (self.lo ^ b as u64).wrapping_mul(PRIME);
            self.hi = (self.hi ^ b as u64).wrapping_mul(PRIME).rotate_left(29);
        }
    }

    /// Feed one delimited field: the bytes plus a separator mix, so
    /// ("ab", "c") and ("a", "bc") hash differently.
    pub fn update(&mut self, bytes: &[u8]) {
        self.write(bytes);
        self.lo = (self.lo ^ 0xff).wrapping_mul(PRIME);
        self.hi = (self.hi ^ 0xff).wrapping_mul(PRIME).rotate_left(29);
    }

    pub fn finish(&self) -> u128 {
        ((self.hi as u128) << 64) | self.lo as u128
    }
}

impl Default for Fnv128 {
    fn default() -> Fnv128 {
        Fnv128::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fields(parts: &[&str]) -> u128 {
        let mut h = Fnv128::new();
        for p in parts {
            h.update(p.as_bytes());
        }
        h.finish()
    }

    #[test]
    fn field_framing_distinguishes_splits() {
        assert_ne!(fields(&["ab", "c"]), fields(&["a", "bc"]));
        assert_ne!(fields(&["ab"]), fields(&["ab", ""]));
        assert_eq!(fields(&["ab", "c"]), fields(&["ab", "c"]));
    }

    #[test]
    fn write_is_raw_concatenation() {
        let mut a = Fnv128::new();
        a.write(b"ab");
        a.write(b"c");
        let mut b = Fnv128::new();
        b.write(b"abc");
        assert_eq!(a.finish(), b.finish());
    }
}
