//! Exact rational arithmetic over `i128`.
//!
//! Quasi-polynomial coefficients (polyhedral point counts, Faulhaber
//! summation) must be exact: counts like `n^3/16` arise from summing
//! over split loops and any floating-point drift would corrupt the
//! operation counts that performance models are built from.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// A rational number `num/den` in lowest terms with `den > 0`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rat {
    num: i128,
    den: i128,
}

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Rat {
    pub const ZERO: Rat = Rat { num: 0, den: 1 };
    pub const ONE: Rat = Rat { num: 1, den: 1 };

    /// Create `num/den`, normalizing sign and common factors.
    ///
    /// Panics on `den == 0`.
    pub fn new(num: i128, den: i128) -> Rat {
        assert!(den != 0, "rational with zero denominator");
        if num == 0 {
            return Rat::ZERO;
        }
        let sign = if (num < 0) ^ (den < 0) { -1 } else { 1 };
        let (num, den) = (num.abs(), den.abs());
        let g = gcd(num, den);
        Rat {
            num: sign * (num / g),
            den: den / g,
        }
    }

    pub fn int(n: i128) -> Rat {
        Rat { num: n, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }

    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// Exact integer value, if integral.
    pub fn as_integer(&self) -> Option<i128> {
        self.is_integer().then_some(self.num)
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// Floor to integer (round toward negative infinity).
    pub fn floor(&self) -> i128 {
        self.num.div_euclid(self.den)
    }

    pub fn abs(&self) -> Rat {
        Rat {
            num: self.num.abs(),
            den: self.den,
        }
    }

    pub fn pow(&self, k: u32) -> Rat {
        let mut out = Rat::ONE;
        for _ in 0..k {
            out = out * *self;
        }
        out
    }

    pub fn recip(&self) -> Rat {
        assert!(self.num != 0, "reciprocal of zero");
        Rat::new(self.den, self.num)
    }
}

impl Add for Rat {
    type Output = Rat;
    fn add(self, o: Rat) -> Rat {
        // Reduce before multiplying to delay overflow.
        let g = gcd(self.den, o.den);
        let lhs_scale = o.den / g;
        let rhs_scale = self.den / g;
        Rat::new(
            self.num * lhs_scale + o.num * rhs_scale,
            self.den * lhs_scale,
        )
    }
}

impl AddAssign for Rat {
    fn add_assign(&mut self, o: Rat) {
        *self = *self + o;
    }
}

impl Sub for Rat {
    type Output = Rat;
    fn sub(self, o: Rat) -> Rat {
        self + (-o)
    }
}

impl Neg for Rat {
    type Output = Rat;
    fn neg(self) -> Rat {
        Rat {
            num: -self.num,
            den: self.den,
        }
    }
}

impl Mul for Rat {
    type Output = Rat;
    fn mul(self, o: Rat) -> Rat {
        // Cross-reduce first.
        let g1 = gcd(self.num, o.den);
        let g2 = gcd(o.num, self.den);
        Rat::new(
            (self.num / g1) * (o.num / g2),
            (self.den / g2) * (o.den / g1),
        )
    }
}

impl Div for Rat {
    type Output = Rat;
    fn div(self, o: Rat) -> Rat {
        self * o.recip()
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, o: &Rat) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Rat {
    fn cmp(&self, o: &Rat) -> Ordering {
        (self.num * o.den).cmp(&(o.num * self.den))
    }
}

impl fmt::Debug for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self)
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl From<i64> for Rat {
    fn from(n: i64) -> Rat {
        Rat::int(n as i128)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization() {
        assert_eq!(Rat::new(2, 4), Rat::new(1, 2));
        assert_eq!(Rat::new(-2, -4), Rat::new(1, 2));
        assert_eq!(Rat::new(2, -4), Rat::new(-1, 2));
        assert_eq!(Rat::new(0, 7), Rat::ZERO);
    }

    #[test]
    fn arithmetic() {
        let a = Rat::new(1, 3);
        let b = Rat::new(1, 6);
        assert_eq!(a + b, Rat::new(1, 2));
        assert_eq!(a - b, Rat::new(1, 6));
        assert_eq!(a * b, Rat::new(1, 18));
        assert_eq!(a / b, Rat::int(2));
    }

    #[test]
    fn floor_behaves_like_euclid() {
        assert_eq!(Rat::new(7, 2).floor(), 3);
        assert_eq!(Rat::new(-7, 2).floor(), -4);
        assert_eq!(Rat::int(5).floor(), 5);
    }

    #[test]
    fn ordering() {
        assert!(Rat::new(1, 3) < Rat::new(1, 2));
        assert!(Rat::new(-1, 2) < Rat::ZERO);
    }

    #[test]
    fn pow_and_recip() {
        assert_eq!(Rat::new(2, 3).pow(3), Rat::new(8, 27));
        assert_eq!(Rat::new(2, 3).recip(), Rat::new(3, 2));
    }
}
