//! Exact rational arithmetic over `i128`.
//!
//! Quasi-polynomial coefficients (polyhedral point counts, Faulhaber
//! summation) must be exact: counts like `n^3/16` arise from summing
//! over split loops and any floating-point drift would corrupt the
//! operation counts that performance models are built from.
//!
//! For the same reason, `Add`/`Mul` (and therefore `pow`) refuse to
//! wrap: operands are reduced by gcd first to delay overflow, and a
//! product or sum that still does not fit `i128` panics with a clear
//! message instead of silently corrupting counts in release builds.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// A rational number `num/den` in lowest terms with `den > 0`.
#[derive(Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rat {
    num: i128,
    den: i128,
}

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

fn gcd_u(mut a: u128, mut b: u128) -> u128 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Rat {
    pub const ZERO: Rat = Rat { num: 0, den: 1 };
    pub const ONE: Rat = Rat { num: 1, den: 1 };

    /// Create `num/den`, normalizing sign and common factors.
    ///
    /// Panics on `den == 0`, and on a magnitude that still exceeds
    /// `i128` after reduction (only reachable via `i128::MIN`, whose
    /// absolute value has no `i128` representation — normalizing
    /// through `u128` keeps e.g. `MIN/2` exact instead of wrapping).
    pub fn new(num: i128, den: i128) -> Rat {
        assert!(den != 0, "rational with zero denominator");
        if num == 0 {
            return Rat::ZERO;
        }
        let negative = (num < 0) ^ (den < 0);
        Rat::from_sign_mag(negative, num.unsigned_abs(), den.unsigned_abs())
            .unwrap_or_else(|| {
                panic!("Rat overflow: {num}/{den} does not fit i128 after reduction")
            })
    }

    /// Build from a sign and u128 magnitudes, reducing to lowest terms;
    /// `None` if a reduced magnitude still exceeds `i128`.  The single
    /// home for the overflow-edge arithmetic shared by [`Rat::new`] and
    /// the widening branch of `Add`.
    fn from_sign_mag(negative: bool, num_u: u128, den_u: u128) -> Option<Rat> {
        let g = gcd_u(num_u, den_u);
        let (num_r, den_r) = (num_u / g, den_u / g);
        if num_r > i128::MAX as u128 || den_r > i128::MAX as u128 {
            return None;
        }
        Some(Rat {
            num: if negative {
                -(num_r as i128)
            } else {
                num_r as i128
            },
            den: den_r as i128,
        })
    }

    pub fn int(n: i128) -> Rat {
        Rat { num: n, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }

    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn is_integer(&self) -> bool {
        self.den == 1
    }

    /// Exact integer value, if integral.
    pub fn as_integer(&self) -> Option<i128> {
        self.is_integer().then_some(self.num)
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    /// Floor to integer (round toward negative infinity).
    pub fn floor(&self) -> i128 {
        self.num.div_euclid(self.den)
    }

    pub fn abs(&self) -> Rat {
        Rat {
            num: self.num.abs(),
            den: self.den,
        }
    }

    /// `k`-th power by repeated (overflow-checked) multiplication;
    /// panics like [`Mul`] if the result does not fit `i128`.
    pub fn pow(&self, k: u32) -> Rat {
        let mut out = Rat::ONE;
        for _ in 0..k {
            out = out * *self;
        }
        out
    }

    pub fn recip(&self) -> Rat {
        assert!(self.num != 0, "reciprocal of zero");
        Rat::new(self.den, self.num)
    }
}

/// Abort with a diagnostic on `i128` overflow: wrapping would silently
/// corrupt the exact operation counts models are built from.
#[cold]
fn overflow(op: &str, a: Rat, b: Rat) -> ! {
    panic!("Rat overflow: intermediate i128 overflow computing ({a}) {op} ({b})");
}

impl Add for Rat {
    type Output = Rat;
    fn add(self, o: Rat) -> Rat {
        // Reduce before multiplying to delay overflow.
        let g = gcd(self.den, o.den);
        let lhs_scale = o.den / g;
        let rhs_scale = self.den / g;
        let p1 = self.num.checked_mul(lhs_scale);
        let p2 = o.num.checked_mul(rhs_scale);
        let den = self.den.checked_mul(lhs_scale);
        match (p1, p2, den) {
            (Some(a), Some(b), Some(den)) => match a.checked_add(b) {
                Some(num) => Rat::new(num, den),
                // The addends share a sign (opposite signs cannot
                // overflow), so their magnitude sum fits u128 — and the
                // exact result may still fit i128 once reduced against
                // the denominator (e.g. MAX/2 + MAX/2 = MAX).
                None => {
                    let mag = a.unsigned_abs() + b.unsigned_abs();
                    Rat::from_sign_mag(a < 0, mag, den.unsigned_abs())
                        .unwrap_or_else(|| overflow("+", self, o))
                }
            },
            _ => overflow("+", self, o),
        }
    }
}

impl AddAssign for Rat {
    fn add_assign(&mut self, o: Rat) {
        *self = *self + o;
    }
}

impl Sub for Rat {
    type Output = Rat;
    fn sub(self, o: Rat) -> Rat {
        self + (-o)
    }
}

impl Neg for Rat {
    type Output = Rat;
    fn neg(self) -> Rat {
        Rat {
            num: -self.num,
            den: self.den,
        }
    }
}

impl Mul for Rat {
    type Output = Rat;
    fn mul(self, o: Rat) -> Rat {
        // Cross-reduce first.
        let g1 = gcd(self.num, o.den);
        let g2 = gcd(o.num, self.den);
        let num = (self.num / g1).checked_mul(o.num / g2);
        let den = (self.den / g2).checked_mul(o.den / g1);
        match (num, den) {
            (Some(num), Some(den)) => Rat::new(num, den),
            _ => overflow("*", self, o),
        }
    }
}

impl Div for Rat {
    type Output = Rat;
    fn div(self, o: Rat) -> Rat {
        self * o.recip()
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, o: &Rat) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Rat {
    fn cmp(&self, o: &Rat) -> Ordering {
        (self.num * o.den).cmp(&(o.num * self.den))
    }
}

impl fmt::Debug for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self)
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

impl From<i64> for Rat {
    fn from(n: i64) -> Rat {
        Rat::int(n as i128)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization() {
        assert_eq!(Rat::new(2, 4), Rat::new(1, 2));
        assert_eq!(Rat::new(-2, -4), Rat::new(1, 2));
        assert_eq!(Rat::new(2, -4), Rat::new(-1, 2));
        assert_eq!(Rat::new(0, 7), Rat::ZERO);
    }

    #[test]
    fn arithmetic() {
        let a = Rat::new(1, 3);
        let b = Rat::new(1, 6);
        assert_eq!(a + b, Rat::new(1, 2));
        assert_eq!(a - b, Rat::new(1, 6));
        assert_eq!(a * b, Rat::new(1, 18));
        assert_eq!(a / b, Rat::int(2));
    }

    #[test]
    fn floor_behaves_like_euclid() {
        assert_eq!(Rat::new(7, 2).floor(), 3);
        assert_eq!(Rat::new(-7, 2).floor(), -4);
        assert_eq!(Rat::int(5).floor(), 5);
    }

    #[test]
    fn ordering() {
        assert!(Rat::new(1, 3) < Rat::new(1, 2));
        assert!(Rat::new(-1, 2) < Rat::ZERO);
    }

    #[test]
    fn pow_and_recip() {
        assert_eq!(Rat::new(2, 3).pow(3), Rat::new(8, 27));
        assert_eq!(Rat::new(2, 3).recip(), Rat::new(3, 2));
    }

    #[test]
    fn near_max_coefficients_stay_exact() {
        // 2^127 - 1 is a Mersenne prime, so nothing cross-reduces: the
        // checked product must still be exact right at the edge.
        assert_eq!(
            Rat::new(i128::MAX, 2) * Rat::new(2, 3),
            Rat::new(i128::MAX, 3)
        );
        assert_eq!(Rat::int(i128::MAX - 1) + Rat::ONE, Rat::int(i128::MAX));
        // Denominator gcd reduction: the naive common denominator 2^200
        // would overflow, the reduced one must not.
        let tiny = Rat::new(1, 1i128 << 100);
        assert_eq!(tiny + tiny, Rat::new(1, 1i128 << 99));
        // A sum whose intermediate numerator overflows i128 but whose
        // exact value is representable must survive via the widening
        // path, not panic.
        assert_eq!(
            Rat::new(i128::MAX, 2) + Rat::new(i128::MAX, 2),
            Rat::int(i128::MAX)
        );
    }

    #[test]
    fn i128_min_magnitude_normalizes_exactly() {
        // |i128::MIN| has no i128 representation; normalization must go
        // through u128 instead of wrapping (or panicking) in abs().
        assert_eq!(Rat::new(i128::MIN, 2), Rat::new(-(1i128 << 126), 1));
        assert_eq!(Rat::new(i128::MIN, i128::MIN), Rat::ONE);
        // A checked sum landing exactly on i128::MIN stays exact.
        let a = Rat::new(-((1i128 << 126) + 1), 2);
        let b = Rat::new(-((1i128 << 126) - 1), 2);
        assert_eq!(a + b, Rat::new(-(1i128 << 126), 1));
    }

    #[test]
    #[should_panic(expected = "Rat overflow")]
    fn i128_min_over_one_panics_instead_of_wrapping() {
        let _ = Rat::new(i128::MIN, 1);
    }

    #[test]
    #[should_panic(expected = "Rat overflow")]
    fn add_overflow_panics_instead_of_wrapping() {
        let _ = Rat::int(i128::MAX) + Rat::int(i128::MAX);
    }

    #[test]
    #[should_panic(expected = "Rat overflow")]
    fn mul_overflow_panics_instead_of_wrapping() {
        let _ = Rat::int(i128::MAX) * Rat::int(2);
    }

    #[test]
    #[should_panic(expected = "Rat overflow")]
    fn pow_overflow_panics_instead_of_wrapping() {
        let _ = Rat::int(2).pow(127);
    }
}
