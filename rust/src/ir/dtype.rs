//! Scalar data types.

use std::fmt;

/// Element type of arrays and temporaries.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum DType {
    F32,
    F64,
    I32,
}

impl DType {
    pub fn size_bytes(&self) -> u64 {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
        }
    }

    /// Perflex feature-identifier spelling, e.g. `float32`.
    pub fn feature_name(&self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::F64 => "float64",
            DType::I32 => "int32",
        }
    }

    pub fn parse(s: &str) -> Option<DType> {
        match s {
            "float32" | "f32" => Some(DType::F32),
            "float64" | "f64" => Some(DType::F64),
            "int32" | "i32" => Some(DType::I32),
            _ => None,
        }
    }

    /// OpenCL C spelling (for the pseudo-code generator).
    pub fn ocl_name(&self) -> &'static str {
        match self {
            DType::F32 => "float",
            DType::F64 => "double",
            DType::I32 => "int",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.feature_name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_and_names() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F64.size_bytes(), 8);
        assert_eq!(DType::parse("float32"), Some(DType::F32));
        assert_eq!(DType::parse("float64"), Some(DType::F64));
        assert_eq!(DType::parse("bogus"), None);
        assert_eq!(DType::F32.to_string(), "float32");
    }
}
