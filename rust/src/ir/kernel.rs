//! Kernels: statements over a polyhedral domain with OpenCL-model tags.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};

use super::dtype::DType;
use super::expr::{Access, AffExpr, Expr};
use crate::polyhedral::{Assumptions, NestedDomain, QPoly};
use crate::util::Rat;

/// How an iname is realized (the paper's `tag_inames`): a group (grid)
/// axis, a local (work-item) axis, a sequential loop, or unrolled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexTag {
    Group(u8),
    Local(u8),
    Sequential,
    Unroll,
}

impl IndexTag {
    /// Parse the Loopy spelling: `g.0`, `l.1`, `seq`, `unr`.
    pub fn parse(s: &str) -> Option<IndexTag> {
        if let Some(ax) = s.strip_prefix("g.") {
            return ax.parse().ok().map(IndexTag::Group);
        }
        if let Some(ax) = s.strip_prefix("l.") {
            return ax.parse().ok().map(IndexTag::Local);
        }
        match s {
            "seq" => Some(IndexTag::Sequential),
            "unr" => Some(IndexTag::Unroll),
            _ => None,
        }
    }

    pub fn is_parallel(&self) -> bool {
        matches!(self, IndexTag::Group(_) | IndexTag::Local(_))
    }
}

/// Memory space of an array.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MemScope {
    Global,
    /// OpenCL local / scratchpad, shared within a work-group.
    Local,
    /// Per-work-item private storage.
    Private,
}

/// An array declaration.
#[derive(Clone, Debug, PartialEq)]
pub struct ArrayDecl {
    pub name: String,
    pub dtype: DType,
    pub scope: MemScope,
    /// Per-axis extents (parametric).
    pub shape: Vec<QPoly>,
    /// Layout permutation: axes listed slowest-varying first.  The
    /// default `0..d` is row-major; `tag_data_axes` permutes this
    /// (the paper's DG "transposed element data" variant).
    pub axis_order: Vec<usize>,
}

impl ArrayDecl {
    pub fn global(name: &str, dtype: DType, shape: Vec<QPoly>) -> ArrayDecl {
        let d = shape.len();
        ArrayDecl {
            name: name.to_string(),
            dtype,
            scope: MemScope::Global,
            shape,
            axis_order: (0..d).collect(),
        }
    }

    pub fn local(name: &str, dtype: DType, shape: Vec<QPoly>) -> ArrayDecl {
        ArrayDecl {
            scope: MemScope::Local,
            ..ArrayDecl::global(name, dtype, shape)
        }
    }

    /// Element strides per axis under the layout permutation.
    pub fn strides(&self) -> Vec<QPoly> {
        let d = self.shape.len();
        let mut strides = vec![QPoly::one(); d];
        // Walk the layout from fastest (last in axis_order) to slowest.
        let mut running = QPoly::one();
        for &axis in self.axis_order.iter().rev() {
            strides[axis] = running.clone();
            running = &running * &self.shape[axis];
        }
        strides
    }

    /// Total element count.
    pub fn size_elems(&self) -> QPoly {
        self.shape
            .iter()
            .fold(QPoly::one(), |acc, s| &acc * s)
    }
}

/// A private scalar temporary (accumulator, work-removal target, ...).
#[derive(Clone, Debug, PartialEq)]
pub struct TempDecl {
    pub name: String,
    pub dtype: DType,
}

/// Statement left-hand side.
#[derive(Clone, Debug, PartialEq)]
pub enum LhsRef {
    Temp(String),
    Array(Access),
}

impl fmt::Display for LhsRef {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            LhsRef::Temp(t) => write!(f, "{t}"),
            LhsRef::Array(a) => write!(f, "{a}"),
        }
    }
}

/// One assignment statement.
#[derive(Clone, Debug, PartialEq)]
pub struct Stmt {
    pub id: String,
    pub lhs: LhsRef,
    pub rhs: Expr,
    /// Inames this statement nests within, ordered outer → inner; must
    /// be a subsequence of the kernel domain order.
    pub within: Vec<String>,
    /// Ids of statements that must execute before this one (within an
    /// iteration of the shared surrounding loops).
    pub deps: Vec<String>,
}

impl Stmt {
    pub fn new(id: &str, lhs: LhsRef, rhs: Expr, within: &[&str]) -> Stmt {
        Stmt {
            id: id.to_string(),
            lhs,
            rhs,
            within: within.iter().map(|s| s.to_string()).collect(),
            deps: Vec::new(),
        }
    }

    pub fn with_deps(mut self, deps: &[&str]) -> Stmt {
        self.deps = deps.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Store access, if the LHS is an array.
    pub fn store(&self) -> Option<&Access> {
        match &self.lhs {
            LhsRef::Array(a) => Some(a),
            LhsRef::Temp(_) => None,
        }
    }
}

/// A flattened array subscript as a linear form with quasi-polynomial
/// coefficients: `Σ coeff(iname) · iname + constant` (element units).
#[derive(Clone, Debug, Default)]
pub struct LinForm {
    pub coeffs: BTreeMap<String, QPoly>,
    pub constant: QPoly,
}

impl LinForm {
    pub fn coeff(&self, var: &str) -> QPoly {
        self.coeffs.get(var).cloned().unwrap_or_else(QPoly::zero)
    }
}

/// A kernel: the unit the paper's counting, modeling and measurement
/// all operate on.
#[derive(Clone, Debug, PartialEq)]
pub struct Kernel {
    pub name: String,
    /// Problem-size parameters (e.g. `n`, `nelements`).
    pub params: Vec<String>,
    /// Full loop nest (includes parallel inames), outer → inner.
    pub domain: NestedDomain,
    pub iname_tags: BTreeMap<String, IndexTag>,
    pub arrays: BTreeMap<String, ArrayDecl>,
    pub temps: BTreeMap<String, TempDecl>,
    pub stmts: Vec<Stmt>,
    pub assumptions: Assumptions,
    /// Nesting preference for sequential loops (`prioritize_loops`).
    pub loop_priority: Vec<String>,
}

impl Kernel {
    pub fn new(name: &str, params: &[&str], domain: NestedDomain) -> Kernel {
        Kernel {
            name: name.to_string(),
            params: params.iter().map(|s| s.to_string()).collect(),
            domain,
            iname_tags: BTreeMap::new(),
            arrays: BTreeMap::new(),
            temps: BTreeMap::new(),
            stmts: Vec::new(),
            assumptions: Assumptions::none(),
            loop_priority: Vec::new(),
        }
    }

    pub fn add_array(&mut self, decl: ArrayDecl) -> &mut Self {
        self.arrays.insert(decl.name.clone(), decl);
        self
    }

    pub fn add_temp(&mut self, name: &str, dtype: DType) -> &mut Self {
        self.temps.insert(
            name.to_string(),
            TempDecl {
                name: name.to_string(),
                dtype,
            },
        );
        self
    }

    pub fn add_stmt(&mut self, stmt: Stmt) -> &mut Self {
        self.stmts.push(stmt);
        self
    }

    pub fn tag(&self, iname: &str) -> IndexTag {
        self.iname_tags
            .get(iname)
            .copied()
            .unwrap_or(IndexTag::Sequential)
    }

    pub fn stmt(&self, id: &str) -> Option<&Stmt> {
        self.stmts.iter().find(|s| s.id == id)
    }

    /// The first iname carrying tag `t`, if any.
    pub fn iname_with_tag(&self, t: IndexTag) -> Option<&str> {
        self.iname_tags
            .iter()
            .find(|(_, tag)| **tag == t)
            .map(|(k, _)| k.as_str())
    }

    /// All inames carrying tag `t` (several inames may share a local
    /// axis, e.g. a stencil's interior iname plus its prefetch-fetch
    /// iname; the work-group size is the max of their extents).
    pub fn inames_with_tag(&self, t: IndexTag) -> Vec<&str> {
        self.iname_tags
            .iter()
            .filter(|(_, tag)| **tag == t)
            .map(|(k, _)| k.as_str())
            .collect()
    }

    /// Constant extent of an iname (required for local axes).
    fn const_extent(&self, iname: &str) -> Option<u64> {
        let l = self.domain.loops.iter().find(|l| l.var == iname)?;
        let e = self.assumptions.simplify(&l.extent());
        e.as_constant().and_then(|r| r.as_integer()).map(|v| v as u64)
    }

    /// Work-group size along local axis `axis` (1 if untagged).
    /// With several inames on one axis this is the max extent: shorter
    /// inames execute predicated, leaving work-items idle (the paper's
    /// finite-difference halo threads).
    pub fn lsize(&self, axis: u8) -> u64 {
        self.inames_with_tag(IndexTag::Local(axis))
            .iter()
            .map(|iname| {
                self.const_extent(iname).unwrap_or_else(|| {
                    panic!("local iname '{iname}' must have constant extent")
                })
            })
            .max()
            .unwrap_or(1)
    }

    /// Total work-items per work-group.
    pub fn work_group_size(&self) -> u64 {
        (0..3).map(|ax| self.lsize(ax)).product()
    }

    /// Grid extent (number of work-groups) along group axis `axis`.
    pub fn gsize(&self, axis: u8) -> QPoly {
        self.iname_with_tag(IndexTag::Group(axis))
            .map(|iname| {
                let l = self
                    .domain
                    .loops
                    .iter()
                    .find(|l| l.var == iname)
                    .expect("tagged iname not in domain");
                self.assumptions.simplify(&l.extent())
            })
            .unwrap_or_else(QPoly::one)
    }

    /// Total work-group count (the paper's `f_thread_groups`).
    pub fn num_groups(&self) -> QPoly {
        (0..3).fold(QPoly::one(), |acc, ax| &acc * &self.gsize(ax))
    }

    /// Flatten an access subscript into element-unit linear form using
    /// the array's layout.
    pub fn flatten_access(&self, access: &Access) -> LinForm {
        let decl = self
            .arrays
            .get(&access.array)
            .unwrap_or_else(|| panic!("unknown array '{}'", access.array));
        assert_eq!(
            decl.shape.len(),
            access.indices.len(),
            "rank mismatch accessing '{}'",
            access.array
        );
        let strides = decl.strides();
        let mut out = LinForm::default();
        for (idx, stride) in access.indices.iter().zip(&strides) {
            for (v, c) in &idx.terms {
                let add = stride.scale(Rat::int(*c as i128));
                let cur = out.coeffs.entry(v.clone()).or_insert_with(QPoly::zero);
                *cur = &*cur + &add;
            }
            out.constant =
                &out.constant + &stride.scale(Rat::int(idx.constant as i128));
        }
        // Drop zero coefficients.
        out.coeffs.retain(|_, c| !c.is_zero());
        out
    }

    /// Stride (elements) of `access` w.r.t. the local axis `axis`
    /// (the `ls0, ls1, ...` of Section 6.1.1).
    pub fn lid_stride(&self, access: &Access, axis: u8) -> QPoly {
        self.thread_stride(access, IndexTag::Local(axis))
    }

    /// Stride (elements) w.r.t. the group axis `axis` (`gs0, gs1, ...`).
    pub fn gid_stride(&self, access: &Access, axis: u8) -> QPoly {
        self.thread_stride(access, IndexTag::Group(axis))
    }

    fn thread_stride(&self, access: &Access, tag: IndexTag) -> QPoly {
        let lf = self.flatten_access(access);
        // Sum over all inames carrying this tag: an access uses at most
        // one of them, so this selects the relevant coefficient.
        self.inames_with_tag(tag)
            .iter()
            .fold(QPoly::zero(), |acc, iname| &acc + &lf.coeff(iname))
    }

    /// Stride (elements) w.r.t. a sequential iname (Table 1's "loop
    /// stride").
    pub fn loop_stride(&self, access: &Access, iname: &str) -> QPoly {
        self.flatten_access(access).coeff(iname)
    }

    /// Statement's projected domain (Algorithm 1).
    pub fn stmt_domain(&self, stmt: &Stmt) -> NestedDomain {
        self.domain.project(&stmt.within)
    }

    /// Sequential inames a statement nests in (innermost trip counts).
    pub fn sequential_within<'a>(&self, stmt: &'a Stmt) -> Vec<&'a str> {
        stmt.within
            .iter()
            .filter(|i| !self.tag(i).is_parallel())
            .map(|s| s.as_str())
            .collect()
    }

    /// Basic well-formedness checks; returns an error description.
    pub fn validate(&self) -> Result<(), String> {
        let order = self.domain.var_names();
        for s in &self.stmts {
            // `within` must be a subsequence of the domain order.
            let mut pos = 0usize;
            for w in &s.within {
                match order[pos..].iter().position(|v| v == w) {
                    Some(off) => pos += off + 1,
                    None => {
                        return Err(format!(
                            "stmt '{}': iname '{w}' not in domain order {order:?}",
                            s.id
                        ))
                    }
                }
            }
            // All accessed arrays/temps must be declared, subscripts
            // must reference only in-scope inames or parameters.
            let check_access = |a: &Access| -> Result<(), String> {
                if !self.arrays.contains_key(&a.array) {
                    return Err(format!("stmt '{}': unknown array '{}'", s.id, a.array));
                }
                for ix in &a.indices {
                    for v in ix.vars() {
                        let known = s.within.contains(v)
                            || self.params.contains(v)
                            || order.contains(v);
                        if !known {
                            return Err(format!(
                                "stmt '{}': subscript var '{v}' unknown",
                                s.id
                            ));
                        }
                    }
                }
                Ok(())
            };
            for l in s.rhs.loads() {
                check_access(l)?;
            }
            if let LhsRef::Array(a) = &s.lhs {
                check_access(a)?;
            }
            if let LhsRef::Temp(t) = &s.lhs {
                if !self.temps.contains_key(t) {
                    return Err(format!("stmt '{}': unknown temp '{t}'", s.id));
                }
            }
            for t in s.rhs.temps_read() {
                if !self.temps.contains_key(t) {
                    return Err(format!("stmt '{}': unknown temp '{t}'", s.id));
                }
            }
            for d in &s.deps {
                if self.stmt(d).is_none() {
                    return Err(format!("stmt '{}': unknown dep '{d}'", s.id));
                }
            }
        }
        // Local axes need constant extents.
        for (iname, tag) in &self.iname_tags {
            if matches!(tag, IndexTag::Local(_)) && self.const_extent(iname).is_none() {
                return Err(format!("local iname '{iname}' has non-constant extent"));
            }
        }
        Ok(())
    }

    /// Cheap structural fingerprint: 128 bits of FNV-1a over the
    /// canonical `Debug` rendering (which covers the domain, tags,
    /// arrays, temps, statements, assumptions and loop priority).
    ///
    /// [`crate::stats::StatsCache`] keys memoized statistics by
    /// (fingerprint, sub-group size); two kernels with equal
    /// fingerprints are treated as identical.  The rendering pass is
    /// orders of magnitude cheaper than the polyhedral counting pass it
    /// lets us skip, and 128 bits keep accidental collisions negligible
    /// for any realistic kernel population.
    ///
    /// Every call renders the whole IR (and bumps [`ir_render_count`]).
    /// Hot paths should not call this repeatedly: [`Kernel::freeze`]
    /// mints a [`FrozenKernel`] whose key is computed exactly once.
    pub fn fingerprint(&self) -> u128 {
        IR_RENDERS.fetch_add(1, Ordering::Relaxed);
        let s = format!("{self:?}");
        let mut h = crate::util::Fnv128::new();
        h.write(s.as_bytes());
        h.write(&(s.len() as u64).to_le_bytes());
        h.finish()
    }

    /// Human-readable pseudo-OpenCL listing (inspection/debugging).
    pub fn pseudocode(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "// kernel {} (wg {}x{})\n",
            self.name,
            self.lsize(0),
            self.lsize(1)
        ));
        for l in &self.domain.loops {
            let tag = self.tag(&l.var);
            out.push_str(&format!(
                "// iname {:>10} in [{}, {}] {:?}\n",
                l.var, l.lo, l.hi, tag
            ));
        }
        for s in &self.stmts {
            out.push_str(&format!(
                "{}: {} = {}   // within {:?}\n",
                s.id, s.lhs, s.rhs, s.within
            ));
        }
        out
    }

    /// Seal this kernel with its precomputed structural fingerprint.
    ///
    /// `Kernel` fields are `pub` and freely mutable, so a fingerprint
    /// memoized *inside* `Kernel` could silently go stale.  Freezing
    /// sidesteps the problem by construction: the key is minted once
    /// here, and [`FrozenKernel`] hands out only shared references —
    /// mutating requires [`FrozenKernel::thaw`], which discards the
    /// key.  Hot loops (the stats cache, measurement, feature
    /// gathering, prediction) accept any [`KernelRef`] and use the
    /// frozen key when present instead of re-rendering the IR.
    pub fn freeze(self) -> FrozenKernel {
        let fingerprint = self.fingerprint();
        FrozenKernel {
            kernel: self,
            fingerprint,
        }
    }
}

static IR_RENDERS: AtomicU64 = AtomicU64::new(0);

/// Process-wide count of full IR renderings performed by
/// [`Kernel::fingerprint`].  Observability hook for the "render at most
/// once per kernel" invariant: a pipeline operating on frozen kernels
/// must leave this counter unchanged.
pub fn ir_render_count() -> u64 {
    IR_RENDERS.load(Ordering::Relaxed)
}

/// A [`Kernel`] paired with its fingerprint, computed exactly once at
/// [`Kernel::freeze`] time.
///
/// Immutable by construction (`Deref` but no `DerefMut`): the cached
/// key cannot go stale because the underlying kernel cannot change
/// while frozen.  Call [`FrozenKernel::thaw`] to get the kernel back
/// for mutation; re-freeze afterwards to mint a fresh key.
#[derive(Clone, Debug, PartialEq)]
pub struct FrozenKernel {
    kernel: Kernel,
    fingerprint: u128,
}

impl FrozenKernel {
    /// The fingerprint minted at freeze time (no IR rendering).
    pub fn fingerprint(&self) -> u128 {
        self.fingerprint
    }

    pub fn kernel(&self) -> &Kernel {
        &self.kernel
    }

    /// Give up the key and recover the mutable kernel.
    pub fn thaw(self) -> Kernel {
        self.kernel
    }
}

impl std::ops::Deref for FrozenKernel {
    type Target = Kernel;
    fn deref(&self) -> &Kernel {
        &self.kernel
    }
}

/// Anything that can stand in for a kernel on the cached hot paths: a
/// borrowed view of the IR plus a structural fingerprint.  For plain
/// [`Kernel`]s the fingerprint re-renders the IR on every call; for
/// [`FrozenKernel`]s it is the memoized key.
pub trait KernelRef {
    fn as_kernel(&self) -> &Kernel;
    fn fingerprint(&self) -> u128;
}

impl KernelRef for Kernel {
    fn as_kernel(&self) -> &Kernel {
        self
    }

    fn fingerprint(&self) -> u128 {
        Kernel::fingerprint(self)
    }
}

impl KernelRef for FrozenKernel {
    fn as_kernel(&self) -> &Kernel {
        &self.kernel
    }

    fn fingerprint(&self) -> u128 {
        self.fingerprint
    }
}

impl<K: KernelRef> KernelRef for &K {
    fn as_kernel(&self) -> &Kernel {
        (**self).as_kernel()
    }

    fn fingerprint(&self) -> u128 {
        (**self).fingerprint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::polyhedral::LoopExtent;

    /// Hand-built fragment of the tiled matmul (§2.1 of the paper):
    /// enough structure to exercise geometry + stride analysis.
    fn tiled_matmul_fragment() -> Kernel {
        let n = QPoly::var("n");
        let nd16 = n.floor_div(16);
        let domain = NestedDomain::new(vec![
            LoopExtent::zero_to("i_out", nd16.clone()),
            LoopExtent::zero_to("j_out", nd16.clone()),
            LoopExtent::zero_to("i_in", QPoly::int(16)),
            LoopExtent::zero_to("j_in", QPoly::int(16)),
            LoopExtent::zero_to("k_out", nd16),
            LoopExtent::zero_to("k_in", QPoly::int(16)),
        ]);
        let mut k = Kernel::new("mm", &["n"], domain);
        k.assumptions = Assumptions::none().divisible_by("n", 16).at_least("n", 16);
        k.iname_tags.insert("i_out".into(), IndexTag::Group(1));
        k.iname_tags.insert("j_out".into(), IndexTag::Group(0));
        k.iname_tags.insert("i_in".into(), IndexTag::Local(1));
        k.iname_tags.insert("j_in".into(), IndexTag::Local(0));
        k.add_array(ArrayDecl::global(
            "a",
            DType::F32,
            vec![n.clone(), n.clone()],
        ));
        k.add_array(ArrayDecl::local(
            "a_fetch",
            DType::F32,
            vec![QPoly::int(16), QPoly::int(16)],
        ));
        k.add_temp("acc", DType::F32);
        // Prefetch of `a`, as the paper's generated code does it:
        // a_fetch[lid(1), lid(0)] = a[16*gid(1) + lid(1), 16*k_out + lid(0)]
        // i.e. the fetch loop is parallelized over the work-group, with
        // j_in (lid 0) covering the k-tile column.
        let a_ld = Access::tagged(
            "a",
            "aLD",
            vec![
                AffExpr::scaled_var("i_out", 16).plus(&AffExpr::var("i_in")),
                AffExpr::scaled_var("k_out", 16).plus(&AffExpr::var("j_in")),
            ],
        );
        k.add_stmt(
            Stmt::new(
                "fetch_a",
                LhsRef::Array(Access::new(
                    "a_fetch",
                    vec![AffExpr::var("i_in"), AffExpr::var("j_in")],
                )),
                Expr::load(a_ld),
                &["i_out", "j_out", "i_in", "j_in", "k_out"],
            ),
        );
        k
    }

    #[test]
    fn launch_geometry() {
        let k = tiled_matmul_fragment();
        assert_eq!(k.lsize(0), 16);
        assert_eq!(k.lsize(1), 16);
        assert_eq!(k.work_group_size(), 256);
        // (n/16)^2 work-groups.
        let groups = k.num_groups();
        let env: std::collections::BTreeMap<_, _> =
            [("n".to_string(), 64i128)].into_iter().collect();
        assert_eq!(groups.eval(&env), Rat::int(16));
    }

    #[test]
    fn stride_analysis_matches_table1() {
        // Paper Table 1: global loads of `a` in the prefetching matmul
        // have local strides {0: 1, 1: n}, global strides {0: 0, 1: 16n},
        // loop (k_out) stride 16.
        let k = tiled_matmul_fragment();
        let s = &k.stmts[0];
        let a_access = &s.rhs.loads()[0].clone();
        let env: std::collections::BTreeMap<_, _> =
            [("n".to_string(), 1024i128)].into_iter().collect();
        assert_eq!(k.lid_stride(a_access, 0).eval(&env), Rat::int(1));
        assert_eq!(k.lid_stride(a_access, 1).eval(&env), Rat::int(1024));
        assert_eq!(k.gid_stride(a_access, 0).eval(&env), Rat::int(0));
        assert_eq!(
            k.gid_stride(a_access, 1).eval(&env),
            Rat::int(16 * 1024)
        );
        assert_eq!(k.loop_stride(a_access, "k_out").eval(&env), Rat::int(16));
    }

    #[test]
    fn local_array_strides() {
        let k = tiled_matmul_fragment();
        let store = k.stmts[0].store().unwrap().clone();
        let env: std::collections::BTreeMap<_, _> =
            [("n".to_string(), 1024i128)].into_iter().collect();
        // a_fetch[i_in, j_in]: lid1 (i_in) stride 16, lid0 (j_in) stride 1.
        assert_eq!(k.lid_stride(&store, 1).eval(&env), Rat::int(16));
        assert_eq!(k.lid_stride(&store, 0).eval(&env), Rat::int(1));
    }

    #[test]
    fn layout_permutation_transposes_strides() {
        let n = QPoly::var("n");
        let mut d = ArrayDecl::global("u", DType::F32, vec![n.clone(), QPoly::int(64)]);
        let env: std::collections::BTreeMap<_, _> =
            [("n".to_string(), 100i128)].into_iter().collect();
        // Row-major: stride of axis0 = 64, axis1 = 1.
        let s = d.strides();
        assert_eq!(s[0].eval(&env), Rat::int(64));
        assert_eq!(s[1].eval(&env), Rat::int(1));
        // Transposed layout (the DG variant 4 trick): axis1 slowest.
        d.axis_order = vec![1, 0];
        let s = d.strides();
        assert_eq!(s[0].eval(&env), Rat::int(1));
        assert_eq!(s[1].eval(&env), Rat::int(100));
    }

    #[test]
    fn validate_accepts_wellformed() {
        let k = tiled_matmul_fragment();
        assert_eq!(k.validate(), Ok(()));
    }

    #[test]
    fn validate_rejects_unknown_array() {
        let mut k = tiled_matmul_fragment();
        k.add_stmt(Stmt::new(
            "bad",
            LhsRef::Temp("acc".into()),
            Expr::load(Access::new("nope", vec![AffExpr::var("i_in")])),
            &["i_out", "i_in"],
        ));
        assert!(k.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_order_within() {
        let mut k = tiled_matmul_fragment();
        k.add_stmt(Stmt::new(
            "bad_order",
            LhsRef::Temp("acc".into()),
            Expr::fconst(0.0),
            &["i_in", "i_out"], // wrong order
        ));
        assert!(k.validate().is_err());
    }

    #[test]
    fn fingerprint_is_structural() {
        let a = tiled_matmul_fragment();
        let b = tiled_matmul_fragment();
        assert_eq!(a.fingerprint(), b.fingerprint());
        // Any structural change — name, tags, statements — must move it.
        let mut c = tiled_matmul_fragment();
        c.name = "mm_other".into();
        assert_ne!(a.fingerprint(), c.fingerprint());
        let mut d = tiled_matmul_fragment();
        d.iname_tags.insert("k_out".into(), IndexTag::Unroll);
        assert_ne!(a.fingerprint(), d.fingerprint());
        let mut e = tiled_matmul_fragment();
        e.stmts[0].id = "fetch_a2".into();
        assert_ne!(a.fingerprint(), e.fingerprint());
    }

    #[test]
    fn freeze_memoizes_fingerprint() {
        // (The zero-render property is asserted in the dedicated
        // tests/fingerprint_render.rs binary — the render counter is
        // process-global and sibling unit tests would perturb it.)
        let k = tiled_matmul_fragment();
        let slow = k.fingerprint();
        let frozen = k.freeze();
        // The frozen key equals the rendered one, via both paths.
        assert_eq!(KernelRef::fingerprint(&frozen), slow);
        assert_eq!(frozen.fingerprint(), slow);
        // Deref exposes the kernel; thaw + mutate + refreeze moves the key.
        assert_eq!(frozen.name, "mm");
        let mut thawed = frozen.thaw();
        thawed.name = "mm2".into();
        assert_ne!(thawed.freeze().fingerprint(), slow);
    }

    #[test]
    fn stmt_domain_projection_counts() {
        let k = tiled_matmul_fragment();
        let dom = k.stmt_domain(&k.stmts[0]);
        let env: std::collections::BTreeMap<_, _> =
            [("n".to_string(), 64i128)].into_iter().collect();
        // fetch_a nests in i_out, j_out, i_in, j_in, k_out:
        // for n=64: 4 * 4 * 16 * 16 * 4 = 16384.
        let c = k.assumptions.simplify(&dom.count());
        assert_eq!(c.eval(&env), Rat::int(16384));
    }
}
