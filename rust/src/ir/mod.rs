//! Loopy-like kernel intermediate representation.
//!
//! Kernels are *static-control* array programs over a polyhedral loop
//! domain, expressed against the OpenCL machine model (Section 1.2 of
//! the paper): inames are tagged as group/local thread axes or left
//! sequential, arrays live in global/local/private memory, and array
//! subscripts are affine in the inames — the property all stride/
//! footprint reasoning (Sections 5-6) relies on.
//!
//! * [`dtype`] — scalar types.
//! * [`expr`] — affine index expressions and arithmetic expression trees
//!   (with multiply-add detection).
//! * [`kernel`] — statements, arrays, iname tags, launch geometry.

pub mod dtype;
pub mod expr;
pub mod kernel;

pub use dtype::DType;
pub use expr::{Access, AffExpr, BinOp, Expr, OpCounts};
pub use kernel::{
    ir_render_count, ArrayDecl, FrozenKernel, IndexTag, Kernel, KernelRef,
    LhsRef, MemScope, Stmt, TempDecl,
};
