//! Expression trees and affine index expressions.
//!
//! Array subscripts are [`AffExpr`] — integer-affine combinations of
//! inames and parameters.  Quasi-affine subscripts are exactly what the
//! paper's polyhedrally-based stride and footprint reasoning requires
//! (Section 6.1.1 "recall that we assume these indices are affine").
//!
//! Right-hand sides are [`Expr`] trees; [`Expr::count_ops`] implements
//! the per-statement operation counting of Algorithm 1, including the
//! multiply-add sequence detection used for the `madd` feature.

use std::collections::BTreeMap;
use std::fmt;

use crate::polyhedral::QPoly;

/// Integer-affine expression `Σ coeff_i · var_i + constant` over inames
/// and parameters.
#[derive(Clone, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct AffExpr {
    pub terms: BTreeMap<String, i64>,
    pub constant: i64,
}

impl AffExpr {
    pub fn zero() -> AffExpr {
        AffExpr::default()
    }

    pub fn cst(c: i64) -> AffExpr {
        AffExpr {
            terms: BTreeMap::new(),
            constant: c,
        }
    }

    pub fn var(name: &str) -> AffExpr {
        let mut terms = BTreeMap::new();
        terms.insert(name.to_string(), 1);
        AffExpr {
            terms,
            constant: 0,
        }
    }

    /// `coeff * var`.
    pub fn scaled_var(name: &str, coeff: i64) -> AffExpr {
        AffExpr::var(name).scaled(coeff)
    }

    pub fn scaled(&self, c: i64) -> AffExpr {
        if c == 0 {
            return AffExpr::zero();
        }
        AffExpr {
            terms: self.terms.iter().map(|(k, v)| (k.clone(), v * c)).collect(),
            constant: self.constant * c,
        }
    }

    pub fn plus(&self, o: &AffExpr) -> AffExpr {
        let mut out = self.clone();
        for (k, v) in &o.terms {
            let e = out.terms.entry(k.clone()).or_insert(0);
            *e += v;
            if *e == 0 {
                out.terms.remove(k);
            }
        }
        out.constant += o.constant;
        out
    }

    pub fn plus_cst(&self, c: i64) -> AffExpr {
        let mut out = self.clone();
        out.constant += c;
        out
    }

    pub fn minus(&self, o: &AffExpr) -> AffExpr {
        self.plus(&o.scaled(-1))
    }

    /// Coefficient of `var` (0 if absent).
    pub fn coeff(&self, var: &str) -> i64 {
        self.terms.get(var).copied().unwrap_or(0)
    }

    pub fn is_constant(&self) -> bool {
        self.terms.is_empty()
    }

    /// Substitute `var := replacement` (affine).
    pub fn subst(&self, var: &str, replacement: &AffExpr) -> AffExpr {
        let c = self.coeff(var);
        if c == 0 {
            return self.clone();
        }
        let mut out = self.clone();
        out.terms.remove(var);
        out.plus(&replacement.scaled(c))
    }

    /// Rename a variable (e.g. iname retagging).
    pub fn rename(&self, from: &str, to: &str) -> AffExpr {
        self.subst(from, &AffExpr::var(to))
    }

    pub fn eval(&self, env: &BTreeMap<String, i64>) -> i64 {
        let mut acc = self.constant;
        for (v, c) in &self.terms {
            let val = env
                .get(v)
                .unwrap_or_else(|| panic!("unbound variable '{v}' in affine expr"));
            acc += c * val;
        }
        acc
    }

    pub fn to_qpoly(&self) -> QPoly {
        let mut out = QPoly::int(self.constant as i128);
        for (v, c) in &self.terms {
            out = &out + &QPoly::var(v).scale(crate::util::Rat::int(*c as i128));
        }
        out
    }

    pub fn vars(&self) -> impl Iterator<Item = &String> {
        self.terms.keys()
    }
}

impl fmt::Display for AffExpr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for (v, c) in &self.terms {
            if !first {
                write!(f, " + ")?;
            }
            first = false;
            if *c == 1 {
                write!(f, "{v}")?;
            } else {
                write!(f, "{c}*{v}")?;
            }
        }
        if self.constant != 0 || first {
            if !first {
                write!(f, " + ")?;
            }
            write!(f, "{}", self.constant)?;
        }
        Ok(())
    }
}

/// A (possibly tagged) array access.  Direction (load/store) is implied
/// by position: LHS = store, inside RHS = load.
#[derive(Clone, Debug, PartialEq)]
pub struct Access {
    pub array: String,
    /// Memory-access tag (`a$aLD[i,k]` in the paper's Loopy syntax),
    /// used to name individual accesses in models (Section 6.1.1) and to
    /// select accesses in the work-removal transformation.
    pub tag: Option<String>,
    pub indices: Vec<AffExpr>,
}

impl Access {
    pub fn new(array: &str, indices: Vec<AffExpr>) -> Access {
        Access {
            array: array.to_string(),
            tag: None,
            indices,
        }
    }

    pub fn tagged(array: &str, tag: &str, indices: Vec<AffExpr>) -> Access {
        Access {
            array: array.to_string(),
            tag: Some(tag.to_string()),
            indices,
        }
    }
}

impl fmt::Display for Access {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.array)?;
        if let Some(t) = &self.tag {
            write!(f, "${t}")?;
        }
        write!(f, "[")?;
        for (i, ix) in self.indices.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{ix}")?;
        }
        write!(f, "]")
    }
}

/// Binary arithmetic operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum BinOp {
    Add,
    Sub,
    Mul,
    Div,
}

impl BinOp {
    pub fn feature_name(&self) -> &'static str {
        match self {
            BinOp::Add => "add",
            BinOp::Sub => "sub",
            BinOp::Mul => "mul",
            BinOp::Div => "div",
        }
    }

    fn symbol(&self) -> &'static str {
        match self {
            BinOp::Add => "+",
            BinOp::Sub => "-",
            BinOp::Mul => "*",
            BinOp::Div => "/",
        }
    }
}

/// Right-hand-side expression tree.
#[derive(Clone, Debug, PartialEq)]
pub enum Expr {
    FConst(f64),
    /// Reference to a private temporary (e.g. accumulator).
    Temp(String),
    Load(Access),
    Bin(BinOp, Box<Expr>, Box<Expr>),
}

impl Expr {
    pub fn fconst(x: f64) -> Expr {
        Expr::FConst(x)
    }

    pub fn temp(name: &str) -> Expr {
        Expr::Temp(name.to_string())
    }

    pub fn load(a: Access) -> Expr {
        Expr::Load(a)
    }

    pub fn bin(op: BinOp, lhs: Expr, rhs: Expr) -> Expr {
        Expr::Bin(op, Box::new(lhs), Box::new(rhs))
    }

    pub fn add(lhs: Expr, rhs: Expr) -> Expr {
        Expr::bin(BinOp::Add, lhs, rhs)
    }

    pub fn sub(lhs: Expr, rhs: Expr) -> Expr {
        Expr::bin(BinOp::Sub, lhs, rhs)
    }

    pub fn mul(lhs: Expr, rhs: Expr) -> Expr {
        Expr::bin(BinOp::Mul, lhs, rhs)
    }

    pub fn div(lhs: Expr, rhs: Expr) -> Expr {
        Expr::bin(BinOp::Div, lhs, rhs)
    }

    /// All loads in evaluation order.
    pub fn loads(&self) -> Vec<&Access> {
        let mut out = Vec::new();
        self.visit_loads(&mut |a| out.push(a));
        out
    }

    fn visit_loads<'a>(&'a self, f: &mut impl FnMut(&'a Access)) {
        match self {
            Expr::Load(a) => f(a),
            Expr::Bin(_, l, r) => {
                l.visit_loads(f);
                r.visit_loads(f);
            }
            _ => {}
        }
    }

    /// Temporaries read by this expression.
    pub fn temps_read(&self) -> Vec<&str> {
        let mut out = Vec::new();
        self.visit_temps(&mut |t| out.push(t));
        out
    }

    fn visit_temps<'a>(&'a self, f: &mut impl FnMut(&'a str)) {
        match self {
            Expr::Temp(t) => f(t),
            Expr::Bin(_, l, r) => {
                l.visit_temps(f);
                r.visit_temps(f);
            }
            _ => {}
        }
    }

    /// Count arithmetic operations with multiply-add fusion
    /// (Algorithm 1's per-statement `n_ops,S`).
    ///
    /// An `Add`/`Sub` with a `Mul` as either operand counts as one
    /// `madd` (fused multiply-add), matching the paper's treatment of
    /// GPU FMA units; the fused `Mul` is not counted separately.
    pub fn count_ops(&self) -> OpCounts {
        let mut c = OpCounts::default();
        self.count_into(&mut c);
        c
    }

    fn count_into(&self, c: &mut OpCounts) {
        match self {
            Expr::Bin(op @ (BinOp::Add | BinOp::Sub), l, r) => {
                // madd detection: a +/- b*c (either side).
                if let Expr::Bin(BinOp::Mul, ml, mr) = &**r {
                    c.madd += 1;
                    l.count_into(c);
                    ml.count_into(c);
                    mr.count_into(c);
                } else if let Expr::Bin(BinOp::Mul, ml, mr) = &**l {
                    c.madd += 1;
                    r.count_into(c);
                    ml.count_into(c);
                    mr.count_into(c);
                } else {
                    match op {
                        BinOp::Add => c.add += 1,
                        _ => c.sub += 1,
                    }
                    l.count_into(c);
                    r.count_into(c);
                }
            }
            Expr::Bin(BinOp::Mul, l, r) => {
                c.mul += 1;
                l.count_into(c);
                r.count_into(c);
            }
            Expr::Bin(BinOp::Div, l, r) => {
                c.div += 1;
                l.count_into(c);
                r.count_into(c);
            }
            _ => {}
        }
    }

    /// Substitute iname `var := replacement` in all access subscripts.
    pub fn subst_index(&self, var: &str, replacement: &AffExpr) -> Expr {
        match self {
            Expr::Load(a) => Expr::Load(Access {
                array: a.array.clone(),
                tag: a.tag.clone(),
                indices: a
                    .indices
                    .iter()
                    .map(|ix| ix.subst(var, replacement))
                    .collect(),
            }),
            Expr::Bin(op, l, r) => Expr::Bin(
                *op,
                Box::new(l.subst_index(var, replacement)),
                Box::new(r.subst_index(var, replacement)),
            ),
            other => other.clone(),
        }
    }

    /// Map every load through `f` (used by prefetch redirection and
    /// work removal).
    pub fn map_loads(&self, f: &mut impl FnMut(&Access) -> Expr) -> Expr {
        match self {
            Expr::Load(a) => f(a),
            Expr::Bin(op, l, r) => {
                Expr::Bin(*op, Box::new(l.map_loads(f)), Box::new(r.map_loads(f)))
            }
            other => other.clone(),
        }
    }
}

impl fmt::Display for Expr {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Expr::FConst(x) => write!(f, "{x:?}"),
            Expr::Temp(t) => write!(f, "{t}"),
            Expr::Load(a) => write!(f, "{a}"),
            Expr::Bin(op, l, r) => write!(f, "({l} {} {r})", op.symbol()),
        }
    }
}

/// Per-statement arithmetic operation counts (single execution).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct OpCounts {
    pub add: u64,
    pub sub: u64,
    pub mul: u64,
    pub div: u64,
    pub madd: u64,
}

impl OpCounts {
    pub fn total(&self) -> u64 {
        self.add + self.sub + self.mul + self.div + self.madd
    }

    /// FLOP count with madd = 2 flops (the convention used when
    /// comparing against peak rates in Table 3).
    pub fn flops(&self) -> u64 {
        self.add + self.sub + self.mul + self.div + 2 * self.madd
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn affine_arithmetic() {
        // 16*i_out + i_in
        let e = AffExpr::scaled_var("i_out", 16).plus(&AffExpr::var("i_in"));
        assert_eq!(e.coeff("i_out"), 16);
        assert_eq!(e.eval(&env(&[("i_out", 2), ("i_in", 3)])), 35);
    }

    #[test]
    fn affine_subst_models_loop_split() {
        // i -> 16*i_out + i_in applied to n*i + k
        let e = AffExpr::scaled_var("i", 3).plus(&AffExpr::var("k"));
        let split = AffExpr::scaled_var("i_out", 16).plus(&AffExpr::var("i_in"));
        let s = e.subst("i", &split);
        assert_eq!(s.coeff("i_out"), 48);
        assert_eq!(s.coeff("i_in"), 3);
        assert_eq!(s.coeff("k"), 1);
        assert_eq!(s.coeff("i"), 0);
    }

    #[test]
    fn affine_cancellation_drops_terms() {
        let e = AffExpr::var("i").minus(&AffExpr::var("i"));
        assert!(e.is_constant());
        assert_eq!(e, AffExpr::zero());
    }

    #[test]
    fn madd_detection() {
        // acc + a*b  -> 1 madd, 0 add, 0 mul
        let e = Expr::add(
            Expr::temp("acc"),
            Expr::mul(
                Expr::load(Access::new("a", vec![AffExpr::var("i")])),
                Expr::load(Access::new("b", vec![AffExpr::var("i")])),
            ),
        );
        let c = e.count_ops();
        assert_eq!(c.madd, 1);
        assert_eq!(c.add + c.mul, 0);
        assert_eq!(c.flops(), 2);
    }

    #[test]
    fn madd_detection_left_operand() {
        let e = Expr::add(
            Expr::mul(Expr::temp("x"), Expr::temp("y")),
            Expr::temp("acc"),
        );
        assert_eq!(e.count_ops().madd, 1);
    }

    #[test]
    fn plain_ops_counted_separately() {
        // (a + b) / (a - b) with one extra mul below the div
        let a = || Expr::temp("a");
        let b = || Expr::temp("b");
        let e = Expr::div(Expr::add(a(), b()), Expr::sub(Expr::mul(a(), b()), b()));
        let c = e.count_ops();
        assert_eq!(c.add, 1);
        assert_eq!(c.div, 1);
        // a*b - b fuses into one madd
        assert_eq!(c.madd, 1);
        assert_eq!(c.sub, 0);
        assert_eq!(c.mul, 0);
    }

    #[test]
    fn fdiff_stencil_counts() {
        // u[j+1] + u[i+1] - 4*u[c] + u[i+1,j+2] + u[i+2,j+1]  — the
        // paper's five-point stencil: 3 adds + 1 (mul-sub -> madd).
        let u = |i: AffExpr, j: AffExpr| Expr::load(Access::new("u", vec![i, j]));
        let i = || AffExpr::var("i");
        let j = || AffExpr::var("j");
        let e = Expr::add(
            Expr::add(
                Expr::sub(
                    Expr::add(
                        u(i(), j().plus_cst(1)),
                        u(i().plus_cst(1), j()),
                    ),
                    Expr::mul(
                        Expr::fconst(4.0),
                        u(i().plus_cst(1), j().plus_cst(1)),
                    ),
                ),
                u(i().plus_cst(1), j().plus_cst(2)),
            ),
            u(i().plus_cst(2), j().plus_cst(1)),
        );
        let c = e.count_ops();
        assert_eq!(c.madd, 1);
        assert_eq!(c.add, 3);
        assert_eq!(e.loads().len(), 5);
    }

    #[test]
    fn map_loads_rewrites() {
        let e = Expr::add(
            Expr::load(Access::new("a", vec![AffExpr::var("i")])),
            Expr::load(Access::new("b", vec![AffExpr::var("i")])),
        );
        let out = e.map_loads(&mut |a| {
            if a.array == "a" {
                Expr::fconst(0.0)
            } else {
                Expr::Load(a.clone())
            }
        });
        assert_eq!(out.loads().len(), 1);
    }

    #[test]
    fn display_roundtrips_visually() {
        let e = Expr::add(
            Expr::temp("acc"),
            Expr::mul(
                Expr::load(Access::tagged("a", "aLD", vec![AffExpr::var("i")])),
                Expr::temp("x"),
            ),
        );
        assert_eq!(e.to_string(), "(acc + (a$aLD[i] * x))");
    }
}
