//! Minimal criterion-style benchmark harness (criterion is not in the
//! offline crate set; see Cargo.toml).  Prints mean / min / max over a
//! fixed iteration count after a warmup run; [`bench_recorded`] +
//! [`write_baseline`] additionally serialize results as `BENCH_*.json`
//! perf-baseline artifacts (see `benches/baseline.rs`), so perf
//! regressions show up as a diff against the checked-in baselines
//! rather than a memory of what the numbers used to be.

use std::path::{Path, PathBuf};
use std::time::Instant;

use crate::util::json::Json;

/// One recorded benchmark result (milliseconds), the unit of a
/// `BENCH_*.json` baseline file.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRecord {
    pub name: String,
    pub iters: u32,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

impl BenchRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", self.name.as_str().into()),
            ("iters", (self.iters as i64).into()),
            ("mean_ms", self.mean_ms.into()),
            ("min_ms", self.min_ms.into()),
            ("max_ms", self.max_ms.into()),
        ])
    }
}

/// Time `f` for `iters` iterations (plus one warmup) and report.
pub fn bench(name: &str, iters: u32, f: impl FnMut()) {
    bench_recorded(name, iters, f);
}

/// [`bench`], additionally returning the measurements for baseline
/// serialization.
pub fn bench_recorded(name: &str, iters: u32, mut f: impl FnMut()) -> BenchRecord {
    f(); // warmup
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "bench {name:<40} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
    BenchRecord {
        name: name.to_string(),
        iters,
        mean_ms: mean * 1e3,
        min_ms: min * 1e3,
        max_ms: max * 1e3,
    }
}

/// Write `BENCH_<bench>.json` into `dir` and return its path.  The
/// file carries a `schema` marker, a regeneration note, and one entry
/// per record; numbers are machine-relative, so baselines are
/// refreshed (not diffed numerically) when the reference machine
/// changes.
pub fn write_baseline(
    dir: &Path,
    bench: &str,
    records: &[BenchRecord],
) -> Result<PathBuf, String> {
    write_baseline_with_summary(dir, bench, records, &[])
}

/// [`write_baseline`] with extra derived metrics (e.g. a speedup ratio
/// or an evals/sec throughput) serialized under a `summary` key.
pub fn write_baseline_with_summary(
    dir: &Path,
    bench: &str,
    records: &[BenchRecord],
    summary: &[(&str, f64)],
) -> Result<PathBuf, String> {
    let mut fields = vec![
        ("schema", Json::from("perflex-bench-baseline")),
        ("bench", bench.into()),
        (
            "note",
            "regenerate with `cargo bench` (set PERFLEX_BENCH_DIR to \
             choose the output directory); null metrics mean the \
             baseline has not been measured yet"
                .into(),
        ),
        (
            "records",
            Json::Arr(records.iter().map(BenchRecord::to_json).collect()),
        ),
    ];
    if !summary.is_empty() {
        fields.push((
            "summary",
            Json::obj(summary.iter().map(|&(k, v)| (k, Json::from(v))).collect()),
        ));
    }
    let j = Json::obj(fields);
    let path = dir.join(format!("BENCH_{bench}.json"));
    std::fs::write(&path, j.to_string())
        .map_err(|e| format!("writing {}: {e}", path.display()))?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_files_round_trip_through_the_json_codec() {
        let dir = std::env::temp_dir()
            .join(format!("perflex-bench-baseline-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let rec = bench_recorded("noop", 3, || {});
        assert_eq!(rec.iters, 3);
        assert!(rec.min_ms <= rec.mean_ms && rec.mean_ms <= rec.max_ms);
        let path = write_baseline(&dir, "smoke", std::slice::from_ref(&rec)).unwrap();
        assert!(path.ends_with("BENCH_smoke.json"));
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(
            j.get("schema").and_then(Json::as_str),
            Some("perflex-bench-baseline")
        );
        let records = match j.get("records") {
            Some(Json::Arr(r)) => r,
            other => panic!("records must be an array, got {other:?}"),
        };
        assert_eq!(records.len(), 1);
        assert_eq!(
            records[0].get("name").and_then(Json::as_str),
            Some("noop")
        );
        assert!(records[0].get("mean_ms").and_then(Json::as_f64).is_some());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn summary_metrics_serialize_under_a_summary_key() {
        let dir = std::env::temp_dir()
            .join(format!("perflex-bench-summary-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let rec = bench_recorded("noop", 1, || {});
        let path = write_baseline_with_summary(
            &dir,
            "smoke",
            std::slice::from_ref(&rec),
            &[("speedup", 123.0), ("evals_per_sec", 4.0e6)],
        )
        .unwrap();
        let j = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let summary = j.get("summary").expect("summary key present");
        assert_eq!(
            summary.get("speedup").and_then(Json::as_f64),
            Some(123.0)
        );
        assert_eq!(
            summary.get("evals_per_sec").and_then(Json::as_f64),
            Some(4.0e6)
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
