//! Minimal criterion-style benchmark harness (criterion is not in the
//! offline crate set; see Cargo.toml).  Prints mean / min / max over a
//! fixed iteration count after a warmup run.

use std::time::Instant;

/// Time `f` for `iters` iterations (plus one warmup) and report.
pub fn bench(name: &str, iters: u32, mut f: impl FnMut()) {
    f(); // warmup
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "bench {name:<40} mean {:>10.3} ms   min {:>10.3} ms   max {:>10.3} ms   ({iters} iters)",
        mean * 1e3,
        min * 1e3,
        max * 1e3
    );
}
