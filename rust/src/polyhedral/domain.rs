//! Nested loop domains, point counting and divisibility assumptions.
//!
//! A [`NestedDomain`] is an ordered loop nest with inclusive affine (or
//! floor-of-affine) bounds; inner bounds may reference outer loop
//! variables.  This is exactly the static-control shape our Loopy-like
//! IR produces, and counting its integer points (Algorithm 1's
//! `|π_S(D)|`) is a nested symbolic summation.
//!
//! [`Assumptions`] carry `n mod k == 0` divisibility facts (the paper's
//! `lp.assume(knl, "n % 16 = 0")`), used to rewrite `floor` atoms into
//! plain polynomial terms so that, e.g., the tiled matmul madd count
//! comes out as the clean `n^3/32` (per sub-group) rather than a
//! floor-laden quasi-polynomial.

use std::collections::BTreeMap;
use std::fmt;

use super::qpoly::{Atom, QPoly};
use super::sum::sum_over;
use crate::util::Rat;

/// One loop with inclusive bounds `lo <= var <= hi`.
#[derive(Clone, Debug, PartialEq)]
pub struct LoopExtent {
    pub var: String,
    pub lo: QPoly,
    pub hi: QPoly,
}

impl LoopExtent {
    pub fn new(var: &str, lo: QPoly, hi: QPoly) -> LoopExtent {
        LoopExtent {
            var: var.to_string(),
            lo,
            hi,
        }
    }

    /// `0 <= var <= extent - 1`.
    pub fn zero_to(var: &str, extent: QPoly) -> LoopExtent {
        LoopExtent::new(var, QPoly::zero(), &extent - &QPoly::one())
    }

    /// Trip count `hi - lo + 1`.
    pub fn extent(&self) -> QPoly {
        &(&self.hi - &self.lo) + &QPoly::one()
    }
}

/// An ordered (outer → inner) affinely-bounded loop nest.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NestedDomain {
    pub loops: Vec<LoopExtent>,
}

impl NestedDomain {
    pub fn new(loops: Vec<LoopExtent>) -> NestedDomain {
        NestedDomain { loops }
    }

    /// Number of integer points, as a quasi-polynomial in the parameters.
    ///
    /// Sums `1` from the innermost loop outward.  Valid wherever every
    /// range is non-empty-or-trivially-empty (`hi >= lo - 1`), the same
    /// chamber condition Ehrhart counting carries.
    pub fn count(&self) -> QPoly {
        self.sum(&QPoly::one())
    }

    /// Symbolic `Σ_domain body`.
    pub fn sum(&self, body: &QPoly) -> QPoly {
        let mut acc = body.clone();
        for l in self.loops.iter().rev() {
            acc = sum_over(&acc, &l.var, &l.lo, &l.hi);
        }
        acc
    }

    /// Sub-domain containing only the loops whose names are in `keep`
    /// (Algorithm 1's projection onto the loops a statement resides in;
    /// valid because statements live at prefix-closed nest positions and
    /// kept inner bounds may only reference kept outer variables —
    /// asserted).
    pub fn project(&self, keep: &[String]) -> NestedDomain {
        let kept: Vec<LoopExtent> = self
            .loops
            .iter()
            .filter(|l| keep.contains(&l.var))
            .cloned()
            .collect();
        let dropped: Vec<&String> = self
            .loops
            .iter()
            .map(|l| &l.var)
            .filter(|v| !keep.contains(v))
            .collect();
        for l in &kept {
            for d in &dropped {
                assert!(
                    !l.lo.mentions(d) && !l.hi.mentions(d),
                    "projection would drop variable '{d}' referenced by bounds of '{}'",
                    l.var
                );
            }
        }
        NestedDomain { loops: kept }
    }

    pub fn var_names(&self) -> Vec<String> {
        self.loops.iter().map(|l| l.var.clone()).collect()
    }
}

impl fmt::Display for NestedDomain {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{ ")?;
        for (i, l) in self.loops.iter().enumerate() {
            if i > 0 {
                write!(f, ", ")?;
            }
            write!(f, "{} <= {} <= {}", l.lo, l.var, l.hi)?;
        }
        write!(f, " }}")
    }
}

/// Divisibility and range assumptions on parameters
/// (`assume(knl, "n >= 1 and n % 16 = 0")`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Assumptions {
    /// `var -> k` meaning `var ≡ 0 (mod k)`.
    pub divisible: BTreeMap<String, i128>,
    /// `var -> lo` meaning `var >= lo`.
    pub min_value: BTreeMap<String, i128>,
}

impl Assumptions {
    pub fn none() -> Assumptions {
        Assumptions::default()
    }

    pub fn divisible_by(mut self, var: &str, k: i128) -> Assumptions {
        assert!(k > 0);
        self.divisible.insert(var.to_string(), k);
        self
    }

    pub fn at_least(mut self, var: &str, lo: i128) -> Assumptions {
        self.min_value.insert(var.to_string(), lo);
        self
    }

    /// Parse the Loopy-style assumption string, e.g.
    /// `"n >= 1 and n % 16 = 0"` (also accepts `==` and `mod`).
    pub fn parse(text: &str) -> Result<Assumptions, String> {
        let mut out = Assumptions::none();
        for clause in text.split(" and ") {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some((lhs, rhs)) = clause.split_once(">=") {
                let var = lhs.trim().to_string();
                let lo: i128 = rhs
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad bound in '{clause}'"))?;
                out.min_value.insert(var, lo);
            } else if clause.contains('%') || clause.contains(" mod ") {
                let body = clause.replace(" mod ", " % ");
                let (lhs, rhs) = body
                    .split_once('=')
                    .ok_or_else(|| format!("expected '=' in '{clause}'"))?;
                let rhs_val: i128 = rhs
                    .trim_start_matches('=')
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad rhs in '{clause}'"))?;
                if rhs_val != 0 {
                    return Err(format!("only '% k = 0' supported: '{clause}'"));
                }
                let (var, k) = lhs
                    .split_once('%')
                    .ok_or_else(|| format!("expected '%' in '{clause}'"))?;
                let k: i128 = k
                    .trim()
                    .parse()
                    .map_err(|_| format!("bad modulus in '{clause}'"))?;
                if k <= 0 {
                    return Err(format!(
                        "modulus must be positive in '{clause}'"
                    ));
                }
                out.divisible.insert(var.trim().to_string(), k);
            } else {
                return Err(format!("unsupported assumption clause '{clause}'"));
            }
        }
        Ok(out)
    }

    pub fn merge(&mut self, other: &Assumptions) {
        for (k, v) in &other.divisible {
            self.divisible.insert(k.clone(), *v);
        }
        for (k, v) in &other.min_value {
            self.min_value.insert(k.clone(), *v);
        }
    }

    /// Modulus known for the value of a whole polynomial term set:
    /// returns `m` such that `poly ≡ c (mod m)` would hold for the
    /// non-constant part; used to decide floor simplification.
    fn term_divisible(&self, mono_vars: &[(Atom, u32)], coeff: Rat, den: i128) -> bool {
        // A term c * m is divisible by den (for all assignments
        // satisfying the assumptions) if some variable v in m carries a
        // divisibility modulus k with (c * k / den) integral.
        for (a, _e) in mono_vars {
            if let Atom::Var(v) = a {
                if let Some(k) = self.divisible.get(v) {
                    if (coeff * Rat::int(*k) / Rat::int(den)).is_integer() {
                        return true;
                    }
                }
            }
        }
        false
    }

    /// Rewrite floor atoms whose argument is exactly divisible under the
    /// assumptions:  `floor((Σ c_i m_i + c0)/d) = Σ (c_i/d) m_i +
    /// floor(c0/d)` when every non-constant term is divisible by `d`.
    pub fn simplify(&self, p: &QPoly) -> QPoly {
        p.map_atoms(&mut |a| match a {
            Atom::Var(_) => QPoly::atom(a.clone()),
            Atom::Floor { num, den } => {
                let num = self.simplify(num);
                let mut var_part = QPoly::zero();
                let mut const_part = Rat::ZERO;
                let mut all_divisible = true;
                for (m, c) in num.terms() {
                    if m.is_one() {
                        const_part = *c;
                    } else if self.term_divisible(&m.0, *c, *den) {
                        var_part = &var_part
                            + &QPoly::constant(*c / Rat::int(*den)).scale(Rat::ONE).mul_mono(m);
                    } else {
                        all_divisible = false;
                        break;
                    }
                }
                if all_divisible {
                    let c_floor = (const_part / Rat::int(*den)).floor();
                    &var_part + &QPoly::int(c_floor)
                } else {
                    num.floor_div(*den)
                }
            }
        })
    }
}

impl QPoly {
    /// Multiply by a bare monomial (helper for assumption rewriting).
    fn mul_mono(&self, m: &super::qpoly::Monomial) -> QPoly {
        let mut mono_poly = QPoly::one();
        for (a, e) in &m.0 {
            mono_poly = &mono_poly * &QPoly::atom(a.clone()).pow(*e);
        }
        self * &mono_poly
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn env(pairs: &[(&str, i128)]) -> BTreeMap<String, i128> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn brute_count(dom: &NestedDomain, e: &BTreeMap<String, i128>) -> i128 {
        fn rec(loops: &[LoopExtent], env: &mut BTreeMap<String, i128>) -> i128 {
            match loops.first() {
                None => 1,
                Some(l) => {
                    let lo = l.lo.eval(env).floor();
                    let hi = l.hi.eval(env).floor();
                    let mut total = 0;
                    let mut v = lo;
                    while v <= hi {
                        env.insert(l.var.clone(), v);
                        total += rec(&loops[1..], env);
                        v += 1;
                    }
                    env.remove(&l.var);
                    total
                }
            }
        }
        let mut env = e.clone();
        rec(&dom.loops, &mut env)
    }

    #[test]
    fn box_domain_counts_product() {
        // { 0 <= i < n, 0 <= j < n } has n^2 points.
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
        ]);
        let c = dom.count();
        assert_eq!(c, n.pow(2));
    }

    #[test]
    fn paper_section5_triangular_example() {
        // Paper §5 (modulo its off-by-one typo): points (i, j) with
        // p <= i <= n, p <= j <= i - 1 number (n² + p² − 2np + n − p)/2.
        let (n, p) = (QPoly::var("n"), QPoly::var("p"));
        let dom = NestedDomain::new(vec![
            LoopExtent::new("i", p.clone(), n.clone()),
            LoopExtent::new("j", p.clone(), &QPoly::var("i") - &QPoly::one()),
        ]);
        let c = dom.count();
        let expected = {
            // (n^2 + p^2 - 2np + n - p) / 2
            let t = &(&(&n.pow(2) + &p.pow(2)) - &(&n * &p).scale(Rat::int(2))) + &(&n - &p);
            t.scale(Rat::new(1, 2))
        };
        assert_eq!(c, expected);
        assert_eq!(c.eval(&env(&[("n", 10), ("p", 3)])), Rat::int(28));
    }

    #[test]
    fn split_loop_with_assume_simplifies() {
        // i split by 16 under n % 16 == 0:
        // { 0 <= i_out <= floor((n-16)/16), 0 <= i_in <= 15 } has n points.
        let n = QPoly::var("n");
        let hi_out = (&n - &QPoly::int(16)).floor_div(16);
        let dom = NestedDomain::new(vec![
            LoopExtent::new("i_out", QPoly::zero(), hi_out),
            LoopExtent::new("i_in", QPoly::zero(), QPoly::int(15)),
        ]);
        let raw = dom.count();
        let asm = Assumptions::none().divisible_by("n", 16).at_least("n", 16);
        let simplified = asm.simplify(&raw);
        assert_eq!(simplified, n, "got {simplified}");
    }

    #[test]
    fn assume_parse() {
        let a = Assumptions::parse("n >= 1 and n % 16 = 0").unwrap();
        assert_eq!(a.min_value.get("n"), Some(&1));
        assert_eq!(a.divisible.get("n"), Some(&16));
        let b = Assumptions::parse("m mod 8 = 0").unwrap();
        assert_eq!(b.divisible.get("m"), Some(&8));
        assert!(Assumptions::parse("n < 5").is_err());
    }

    #[test]
    fn simplify_keeps_unprovable_floors() {
        let n = QPoly::var("n");
        let fd = (&n - &QPoly::int(3)).floor_div(7);
        let asm = Assumptions::none().divisible_by("n", 16);
        assert_eq!(asm.simplify(&fd), fd);
    }

    #[test]
    fn projection_drops_inner_loops() {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let proj = dom.project(&["i".into(), "j".into()]);
        assert_eq!(proj.count(), n.pow(2));
    }

    #[test]
    #[should_panic(expected = "projection would drop")]
    fn projection_rejects_dangling_bounds() {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::new("j", QPoly::zero(), QPoly::var("i")),
        ]);
        let _ = dom.project(&["j".into()]);
    }

    #[test]
    fn prop_count_matches_brute_force() {
        prop::check("nested count vs brute force", 40, |rng| {
            // Random 1-3 deep nest over small constant/parametric bounds.
            let depth = rng.int_in(1, 3);
            let mut loops = Vec::new();
            let vars = ["i", "j", "k"];
            for d in 0..depth {
                let lo = QPoly::int(rng.int_in(0, 2) as i128);
                let hi = match rng.int_in(0, 2) {
                    0 => QPoly::int(rng.int_in(2, 6) as i128),
                    1 => &QPoly::var("n") - &QPoly::one(),
                    _ if d > 0 => QPoly::var(vars[(d - 1) as usize]),
                    _ => QPoly::int(rng.int_in(2, 6) as i128),
                };
                loops.push(LoopExtent::new(vars[d as usize], lo, hi));
            }
            let dom = NestedDomain::new(loops);
            let sym = dom.count();
            let e = env(&[("n", rng.int_in(3, 9) as i128)]);
            let brute = brute_count(&dom, &e);
            prop::ensure(
                sym.eval(&e) == Rat::int(brute),
                format!("{dom} -> {sym}; brute {brute}"),
            )
        });
    }

    #[test]
    fn symbolic_reevaluation_is_cheap_and_consistent() {
        // The paper amortizes counting: one symbolic count, many sizes.
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let c = dom.count();
        for nv in [64i128, 128, 1024, 4096] {
            assert_eq!(c.eval(&env(&[("n", nv)])), Rat::int(nv * nv * nv));
        }
    }
}
