//! Multivariate quasi-polynomials with exact rational coefficients.
//!
//! A [`QPoly`] is a polynomial over *atoms*; an atom is either a named
//! integer variable (problem-size parameter or loop index) or a
//! `floor(poly / d)` term — exactly the quasi-polynomial class that
//! integer point counts of parametric polytopes live in (Barvinok 1994).

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::ops::{Add, Mul, Neg, Sub};

use crate::util::Rat;

/// An indeterminate of a quasi-polynomial.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug)]
pub enum Atom {
    /// A named integer variable (parameter or loop index).
    Var(String),
    /// `floor(num / den)` with `den > 0`.
    Floor { num: Box<QPoly>, den: i128 },
}

impl Atom {
    pub fn var(name: &str) -> Atom {
        Atom::Var(name.to_string())
    }
}

/// A power product of atoms, e.g. `n^2 * floor((n-16)/16)`.
/// Invariant: sorted by atom, exponents > 0.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug, Default)]
pub struct Monomial(pub Vec<(Atom, u32)>);

impl Monomial {
    pub fn one() -> Monomial {
        Monomial(Vec::new())
    }

    pub fn atom(a: Atom) -> Monomial {
        Monomial(vec![(a, 1)])
    }

    pub fn is_one(&self) -> bool {
        self.0.is_empty()
    }

    pub fn degree(&self) -> u32 {
        self.0.iter().map(|(_, e)| *e).sum()
    }

    fn mul(&self, other: &Monomial) -> Monomial {
        let mut m: BTreeMap<Atom, u32> = BTreeMap::new();
        for (a, e) in self.0.iter().chain(other.0.iter()) {
            *m.entry(a.clone()).or_insert(0) += e;
        }
        Monomial(m.into_iter().collect())
    }

    /// Exponent of `atom` in this monomial.
    pub fn exponent_of(&self, atom: &Atom) -> u32 {
        self.0
            .iter()
            .find(|(a, _)| a == atom)
            .map(|(_, e)| *e)
            .unwrap_or(0)
    }

    /// Remove `atom` entirely, returning (exponent, remainder monomial).
    fn split_off(&self, atom: &Atom) -> (u32, Monomial) {
        let mut rest = Vec::new();
        let mut exp = 0;
        for (a, e) in &self.0 {
            if a == atom {
                exp = *e;
            } else {
                rest.push((a.clone(), *e));
            }
        }
        (exp, Monomial(rest))
    }
}

/// A quasi-polynomial: finite sum of `coeff * monomial` with exact
/// rational coefficients.  Invariant: no zero coefficients stored.
#[derive(Clone, PartialEq, Eq, PartialOrd, Ord, Hash, Debug, Default)]
pub struct QPoly {
    terms: BTreeMap<Monomial, Rat>,
}

impl QPoly {
    pub fn zero() -> QPoly {
        QPoly::default()
    }

    pub fn one() -> QPoly {
        QPoly::constant(Rat::ONE)
    }

    pub fn constant(c: Rat) -> QPoly {
        let mut terms = BTreeMap::new();
        if !c.is_zero() {
            terms.insert(Monomial::one(), c);
        }
        QPoly { terms }
    }

    pub fn int(n: i128) -> QPoly {
        QPoly::constant(Rat::int(n))
    }

    pub fn var(name: &str) -> QPoly {
        QPoly::atom(Atom::var(name))
    }

    pub fn atom(a: Atom) -> QPoly {
        let mut terms = BTreeMap::new();
        terms.insert(Monomial::atom(a), Rat::ONE);
        QPoly { terms }
    }

    /// `floor(self / den)` as a new quasi-polynomial atom (den > 0).
    /// Constant arguments fold immediately.
    pub fn floor_div(&self, den: i128) -> QPoly {
        assert!(den > 0, "floor_div by non-positive {den}");
        if den == 1 {
            return self.clone();
        }
        if let Some(c) = self.as_constant() {
            return QPoly::constant(Rat::int((c / Rat::int(den)).floor()));
        }
        QPoly::atom(Atom::Floor {
            num: Box::new(self.clone()),
            den,
        })
    }

    pub fn is_zero(&self) -> bool {
        self.terms.is_empty()
    }

    pub fn terms(&self) -> impl Iterator<Item = (&Monomial, &Rat)> {
        self.terms.iter()
    }

    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }

    /// If the polynomial is a constant, return it.
    pub fn as_constant(&self) -> Option<Rat> {
        match self.terms.len() {
            0 => Some(Rat::ZERO),
            1 => {
                let (m, c) = self.terms.iter().next().unwrap();
                m.is_one().then_some(*c)
            }
            _ => None,
        }
    }

    pub fn degree(&self) -> u32 {
        self.terms.keys().map(Monomial::degree).max().unwrap_or(0)
    }

    fn insert_term(&mut self, m: Monomial, c: Rat) {
        if c.is_zero() {
            return;
        }
        let entry = self.terms.entry(m);
        match entry {
            std::collections::btree_map::Entry::Vacant(v) => {
                v.insert(c);
            }
            std::collections::btree_map::Entry::Occupied(mut o) => {
                let s = *o.get() + c;
                if s.is_zero() {
                    o.remove();
                } else {
                    *o.get_mut() = s;
                }
            }
        }
    }

    pub fn scale(&self, c: Rat) -> QPoly {
        if c.is_zero() {
            return QPoly::zero();
        }
        QPoly {
            terms: self.terms.iter().map(|(m, k)| (m.clone(), *k * c)).collect(),
        }
    }

    pub fn pow(&self, k: u32) -> QPoly {
        let mut out = QPoly::one();
        for _ in 0..k {
            out = &out * self;
        }
        out
    }

    /// Collect by powers of `atom`: returns `cs` with
    /// `self = sum_k cs[k] * atom^k` and `atom` absent from all `cs[k]`.
    pub fn coeffs_in(&self, atom: &Atom) -> Vec<QPoly> {
        let max_e = self
            .terms
            .keys()
            .map(|m| m.exponent_of(atom))
            .max()
            .unwrap_or(0) as usize;
        let mut out = vec![QPoly::zero(); max_e + 1];
        for (m, c) in &self.terms {
            let (e, rest) = m.split_off(atom);
            out[e as usize].insert_term(rest, *c);
        }
        out
    }

    /// Substitute `atom := value` (a polynomial).
    pub fn subst(&self, atom: &Atom, value: &QPoly) -> QPoly {
        let cs = self.coeffs_in(atom);
        let mut out = QPoly::zero();
        let mut pw = QPoly::one();
        for c in cs {
            out = &out + &(&c * &pw);
            pw = &pw * value;
        }
        out
    }

    /// True if `atom` occurs anywhere (including inside floor atoms).
    pub fn mentions(&self, name: &str) -> bool {
        self.terms.keys().any(|m| {
            m.0.iter().any(|(a, _)| match a {
                Atom::Var(v) => v == name,
                Atom::Floor { num, .. } => num.mentions(name),
            })
        })
    }

    /// Every named variable mentioned anywhere in the polynomial,
    /// including inside floor atoms.  Diagnostics use this to name the
    /// parameters a symbolic resource bound depends on.
    pub fn vars(&self) -> BTreeSet<String> {
        fn collect(q: &QPoly, out: &mut BTreeSet<String>) {
            for m in q.terms.keys() {
                for (a, _) in &m.0 {
                    match a {
                        Atom::Var(v) => {
                            out.insert(v.clone());
                        }
                        Atom::Floor { num, .. } => collect(num, out),
                    }
                }
            }
        }
        let mut out = BTreeSet::new();
        collect(self, &mut out);
        out
    }

    /// Substitute `name := value`, including occurrences inside floor
    /// atoms (constant floors fold).  Used by `fix_parameters`.
    pub fn subst_deep(&self, name: &str, value: &QPoly) -> QPoly {
        self.map_atoms(&mut |a| match a {
            Atom::Var(v) if v == name => value.clone(),
            Atom::Var(_) => QPoly::atom(a.clone()),
            Atom::Floor { num, den } => num.subst_deep(name, value).floor_div(*den),
        })
    }

    /// Exact evaluation at integer parameter values.  Panics on an
    /// unbound parameter; callers that cannot rule one out (e.g. the
    /// simulator evaluating decoded access strides) should use
    /// [`QPoly::try_eval`] instead.
    pub fn eval(&self, env: &BTreeMap<String, i128>) -> Rat {
        self.try_eval(env).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Non-panicking [`QPoly::eval`]: an unbound parameter yields an
    /// error naming it instead of aborting the process.
    pub fn try_eval(&self, env: &BTreeMap<String, i128>) -> Result<Rat, String> {
        let mut acc = Rat::ZERO;
        for (m, c) in &self.terms {
            let mut v = *c;
            for (a, e) in &m.0 {
                let base = match a {
                    Atom::Var(name) => Rat::int(
                        *env.get(name)
                            .ok_or_else(|| format!("unbound parameter '{name}'"))?,
                    ),
                    Atom::Floor { num, den } => {
                        Rat::int((num.try_eval(env)? / Rat::int(*den)).floor())
                    }
                };
                v = v * base.pow(*e);
            }
            acc += v;
        }
        Ok(acc)
    }

    pub fn eval_f64(&self, env: &BTreeMap<String, i128>) -> f64 {
        self.eval(env).to_f64()
    }

    /// Non-panicking [`QPoly::eval_f64`].
    pub fn try_eval_f64(&self, env: &BTreeMap<String, i128>) -> Result<f64, String> {
        self.try_eval(env).map(|r| r.to_f64())
    }

    /// Lower to a flat f64 evaluation plan ([`PolyPlan`]).  `slot`
    /// resolves a variable name to its index in the caller's value
    /// vector (called once per distinct name, in term order), so many
    /// polynomials can be lowered against one shared variable table —
    /// the compiled-model path
    /// ([`crate::model::compiled::CompiledModel`]) lowers every feature
    /// of a model this way and evaluates them all from a single dense
    /// slice per environment.
    pub fn lower(&self, slot: &mut impl FnMut(&str) -> u32) -> PolyPlan {
        let mut terms = Vec::with_capacity(self.terms.len());
        for (m, c) in &self.terms {
            let mut powers = Vec::new();
            let mut floors = Vec::new();
            for (a, e) in &m.0 {
                match a {
                    Atom::Var(name) => powers.push((slot(name), *e)),
                    Atom::Floor { num, den } => floors.push(FloorFactor {
                        num: num.lower(slot),
                        den: *den as f64,
                        exp: *e,
                    }),
                }
            }
            terms.push(PlanTerm {
                coeff: c.to_f64(),
                powers,
                floors,
            });
        }
        PolyPlan { terms }
    }

    /// Rewrite floor atoms using divisibility assumptions; see
    /// [`crate::polyhedral::Assumptions::simplify`].
    pub(crate) fn map_atoms(&self, f: &mut impl FnMut(&Atom) -> QPoly) -> QPoly {
        let mut out = QPoly::zero();
        for (m, c) in &self.terms {
            let mut term = QPoly::constant(*c);
            for (a, e) in &m.0 {
                let sub = f(a);
                term = &term * &sub.pow(*e);
            }
            out = &out + &term;
        }
        out
    }
}

/// Relative tolerance at which [`PolyPlan`] snaps a floor argument to
/// the nearest integer before truncating.  The exact path evaluates
/// `floor(num/den)` in rational arithmetic, where an argument that *is*
/// an integer floors to itself; the f64 numerator can land a few ulp
/// below that boundary and would otherwise floor one unit low.  Snapping
/// within `1e-9` relative recovers every such case: a rational argument
/// that is genuinely below an integer sits at least `1/(den·D)` below it
/// (D = the coefficient denominators' lcm), which exceeds the snap
/// window until the argument is so large that an off-by-one in the floor
/// is itself below the documented relative-error bound.
const FLOOR_SNAP_TOL: f64 = 1e-9;

/// One multiplicative `floor((num)/den)^exp` factor of a [`PlanTerm`].
#[derive(Clone, Debug)]
struct FloorFactor {
    num: PolyPlan,
    den: f64,
    exp: u32,
}

/// One `coeff · Π slot^exp · Π floor(...)^exp` term of a [`PolyPlan`].
#[derive(Clone, Debug)]
struct PlanTerm {
    coeff: f64,
    powers: Vec<(u32, u32)>,
    floors: Vec<FloorFactor>,
}

/// A quasi-polynomial lowered to a flat f64 evaluation plan: the
/// `BTreeMap`-of-`Monomial` structure and exact [`Rat`] coefficients of
/// a [`QPoly`] become a dense term list with f64 coefficients, integer
/// exponents over *variable slots* (indices into a caller-owned value
/// slice) and pre-lowered floor factors.  [`PolyPlan::eval`] is the
/// compiled hot path: no allocation, no map lookups, no rational
/// arithmetic — just fused multiply-adds over a slice.
///
/// # Accuracy
///
/// Terms are visited in the same order as [`QPoly::eval`] visits
/// monomials, so the only divergence from the exact path is f64
/// rounding: each term contributes at most a few ulp of relative error
/// (one rounding per multiply plus the coefficient conversion), and the
/// final sum obeys the standard summation bound
/// `|plan − exact| ≤ c·n·2⁻⁵³·Σᵢ|tᵢ|` over the n term magnitudes
/// `|tᵢ|`.  Floor factors additionally snap near-integer arguments
/// (see [`FLOOR_SNAP_TOL`]) so boundary cases truncate like the exact
/// rational path.  The model-level guarantee built on top of this is
/// documented at [`crate::model::compiled::COMPILED_REL_ERR_BOUND`].
#[derive(Clone, Debug, Default)]
pub struct PolyPlan {
    terms: Vec<PlanTerm>,
}

impl PolyPlan {
    /// Number of flat terms (0 for a zero polynomial).
    pub fn num_terms(&self) -> usize {
        self.terms.len()
    }

    /// Evaluate over `vals`, indexed by the slots handed out during
    /// [`QPoly::lower`].  Slots beyond `vals.len()` panic (the caller
    /// owns the variable table and sizes `vals` to it).
    pub fn eval(&self, vals: &[f64]) -> f64 {
        let mut acc = 0.0;
        for t in &self.terms {
            let mut v = t.coeff;
            for &(slot, e) in &t.powers {
                v *= powi(vals[slot as usize], e);
            }
            for f in &t.floors {
                v *= powi(snapped_floor(f.num.eval(vals) / f.den), f.exp);
            }
            acc += v;
        }
        acc
    }
}

/// Small-exponent power by repeated multiplication (counting
/// polynomials have single-digit degrees; this keeps rounding behavior
/// deterministic and obvious).
#[inline]
fn powi(base: f64, e: u32) -> f64 {
    let mut out = 1.0;
    for _ in 0..e {
        out *= base;
    }
    out
}

/// `x.floor()`, snapping to the nearest integer first when `x` is
/// within [`FLOOR_SNAP_TOL`] (relative) of it.
#[inline]
fn snapped_floor(x: f64) -> f64 {
    let r = x.round();
    if (x - r).abs() <= FLOOR_SNAP_TOL * r.abs().max(1.0) {
        r
    } else {
        x.floor()
    }
}

impl Add for &QPoly {
    type Output = QPoly;
    fn add(self, o: &QPoly) -> QPoly {
        let mut out = self.clone();
        for (m, c) in &o.terms {
            out.insert_term(m.clone(), *c);
        }
        out
    }
}

impl Sub for &QPoly {
    type Output = QPoly;
    fn sub(self, o: &QPoly) -> QPoly {
        self + &(-o)
    }
}

impl Neg for &QPoly {
    type Output = QPoly;
    fn neg(self) -> QPoly {
        self.scale(-Rat::ONE)
    }
}

impl Mul for &QPoly {
    type Output = QPoly;
    fn mul(self, o: &QPoly) -> QPoly {
        let mut out = QPoly::zero();
        for (ma, ca) in &self.terms {
            for (mb, cb) in &o.terms {
                out.insert_term(ma.mul(mb), *ca * *cb);
            }
        }
        out
    }
}

impl fmt::Display for Atom {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Atom::Var(v) => write!(f, "{v}"),
            Atom::Floor { num, den } => write!(f, "floor(({num})/{den})"),
        }
    }
}

impl fmt::Display for QPoly {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.terms.is_empty() {
            return write!(f, "0");
        }
        for (i, (m, c)) in self.terms.iter().enumerate() {
            if i > 0 {
                write!(f, " + ")?;
            }
            if m.is_one() {
                write!(f, "{c}")?;
                continue;
            }
            if *c != Rat::ONE {
                write!(f, "{c}*")?;
            }
            for (j, (a, e)) in m.0.iter().enumerate() {
                if j > 0 {
                    write!(f, "*")?;
                }
                if *e == 1 {
                    write!(f, "{a}")?;
                } else {
                    write!(f, "{a}^{e}")?;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn env(pairs: &[(&str, i128)]) -> BTreeMap<String, i128> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn constants_fold() {
        let p = &QPoly::int(3) + &QPoly::int(4);
        assert_eq!(p.as_constant(), Some(Rat::int(7)));
        let q = &p - &p;
        assert!(q.is_zero());
    }

    #[test]
    fn vars_sees_inside_floor_atoms() {
        let p = &(&QPoly::var("n") * &QPoly::var("m"))
            + &QPoly::var("k").floor_div(16);
        let vars: Vec<String> = p.vars().into_iter().collect();
        assert_eq!(vars, vec!["k", "m", "n"]);
        assert!(QPoly::int(7).vars().is_empty());
    }

    #[test]
    fn polynomial_arithmetic_and_eval() {
        let n = QPoly::var("n");
        // (n + 1)^2 = n^2 + 2n + 1
        let p = (&n + &QPoly::one()).pow(2);
        assert_eq!(p.eval(&env(&[("n", 9)])), Rat::int(100));
        assert_eq!(p.num_terms(), 3);
        assert_eq!(p.degree(), 2);
    }

    #[test]
    fn floor_atom_eval() {
        let n = QPoly::var("n");
        // floor((n - 16) / 16)
        let fd = (&n - &QPoly::int(16)).floor_div(16);
        assert_eq!(fd.eval(&env(&[("n", 64)])), Rat::int(3));
        assert_eq!(fd.eval(&env(&[("n", 65)])), Rat::int(3));
        assert_eq!(fd.eval(&env(&[("n", 80)])), Rat::int(4));
    }

    #[test]
    fn lowered_plan_matches_exact_eval() {
        // (n + 1)^2 * floor((n - 16)/16) + m/3 — exercises variable
        // powers, a nested floor numerator and a non-integer rational
        // coefficient through one shared slot table.
        let n = QPoly::var("n");
        let p = {
            let sq = (&n + &QPoly::one()).pow(2);
            let fd = (&n - &QPoly::int(16)).floor_div(16);
            let t = &sq * &fd;
            &t + &QPoly::var("m").scale(Rat::new(1, 3))
        };
        let mut vars: Vec<String> = Vec::new();
        let plan = p.lower(&mut |name| match vars.iter().position(|v| v == name) {
            Some(i) => i as u32,
            None => {
                vars.push(name.to_string());
                (vars.len() - 1) as u32
            }
        });
        assert!(plan.num_terms() > 0);
        for (nv, mv) in [(1i128, 0i128), (16, 3), (64, 7), (65, 9), (1 << 30, 5)] {
            let exact = p.eval_f64(&env(&[("n", nv), ("m", mv)]));
            let vals: Vec<f64> = vars
                .iter()
                .map(|v| if v == "n" { nv as f64 } else { mv as f64 })
                .collect();
            let fast = plan.eval(&vals);
            let denom = exact.abs().max(fast.abs()).max(1.0);
            assert!(
                (exact - fast).abs() / denom < 1e-12,
                "n={nv} m={mv}: exact {exact} vs plan {fast}"
            );
        }
    }

    #[test]
    fn snapped_floor_recovers_near_integer_arguments() {
        assert_eq!(snapped_floor(3.0), 3.0);
        // A few ulp below an integer boundary snaps up...
        assert_eq!(snapped_floor(2.9999999999999), 3.0);
        assert_eq!(snapped_floor(-1.0000000000001), -1.0);
        // ...but genuinely fractional arguments truncate.
        assert_eq!(snapped_floor(2.9), 2.0);
        assert_eq!(snapped_floor(-1.5), -2.0);
    }

    #[test]
    fn constant_floor_folds() {
        let p = QPoly::int(37).floor_div(16);
        assert_eq!(p.as_constant(), Some(Rat::int(2)));
    }

    #[test]
    fn subst_replaces_variable() {
        let n = Atom::var("n");
        let p = &QPoly::var("n").pow(2) + &QPoly::var("m");
        let q = p.subst(&n, &(&QPoly::var("k") + &QPoly::one()));
        assert_eq!(
            q.eval(&env(&[("k", 3), ("m", 5)])),
            Rat::int(21) // (3+1)^2 + 5
        );
    }

    #[test]
    fn coeffs_in_roundtrip() {
        let v = Atom::var("v");
        let p = {
            // v^2 * n + 3v + 7
            let t1 = &QPoly::var("v").pow(2) * &QPoly::var("n");
            let t2 = QPoly::var("v").scale(Rat::int(3));
            &(&t1 + &t2) + &QPoly::int(7)
        };
        let cs = p.coeffs_in(&v);
        assert_eq!(cs.len(), 3);
        assert_eq!(cs[0].as_constant(), Some(Rat::int(7)));
        assert_eq!(cs[1].as_constant(), Some(Rat::int(3)));
        assert!(!cs[2].mentions("v"));
        // Reassemble.
        let re = {
            let vq = QPoly::var("v");
            let mut acc = QPoly::zero();
            let mut pw = QPoly::one();
            for c in &cs {
                acc = &acc + &(c * &pw);
                pw = &pw * &vq;
            }
            acc
        };
        assert_eq!(re, p);
    }

    #[test]
    fn prop_mul_distributes_over_add() {
        prop::check("qpoly distributivity", 64, |rng| {
            let rand_poly = |rng: &mut crate::util::Rng| {
                let mut p = QPoly::zero();
                for _ in 0..rng.int_in(0, 4) {
                    let c = Rat::int(rng.int_in(-5, 5) as i128);
                    let deg_n = rng.int_in(0, 2) as u32;
                    let deg_m = rng.int_in(0, 2) as u32;
                    let mono = &QPoly::var("n").pow(deg_n) * &QPoly::var("m").pow(deg_m);
                    p = &p + &mono.scale(c);
                }
                p
            };
            let (a, b, c) = (rand_poly(rng), rand_poly(rng), rand_poly(rng));
            let lhs = &a * &(&b + &c);
            let rhs = &(&a * &b) + &(&a * &c);
            prop::ensure(lhs == rhs, format!("({a}) * ({b} + {c})"))
        });
    }

    #[test]
    fn prop_eval_is_ring_homomorphism() {
        prop::check("qpoly eval hom", 64, |rng| {
            let e = env(&[("n", rng.int_in(0, 40) as i128), ("m", rng.int_in(0, 40) as i128)]);
            let mk = |rng: &mut crate::util::Rng| {
                let c = Rat::int(rng.int_in(-4, 4) as i128);
                let p = &QPoly::var("n").pow(rng.int_in(0, 3) as u32)
                    * &QPoly::var("m").pow(rng.int_in(0, 2) as u32);
                p.scale(c)
            };
            let (a, b) = (mk(rng), mk(rng));
            prop::ensure(
                (&a + &b).eval(&e) == a.eval(&e) + b.eval(&e)
                    && (&a * &b).eval(&e) == a.eval(&e) * b.eval(&e),
                format!("a={a} b={b}"),
            )
        });
    }

    #[test]
    fn display_is_stable() {
        let p = &QPoly::var("n").pow(2).scale(Rat::new(1, 2)) + &QPoly::var("n").scale(Rat::new(1, 2));
        assert_eq!(p.to_string(), "1/2*n + 1/2*n^2");
    }
}
