//! Polyhedral counting machinery (the paper's Section 5 substrate).
//!
//! Replaces barvinok/isl: operation counts are **piecewise
//! quasi-polynomials** in the problem-size parameters, obtained by exact
//! symbolic summation (Faulhaber) over nested, affinely-bounded loop
//! domains — the static-control programs produced by our Loopy-like IR.
//!
//! * [`qpoly`] — multivariate quasi-polynomials with exact rational
//!   coefficients over parameter atoms and `floor(affine/d)` atoms.
//! * [`sum`] — symbolic summation of a polynomial over an integer
//!   interval with polynomial bounds (Bernoulli/Faulhaber power sums).
//! * [`domain`] — nested loop domains, point counting, and
//!   divisibility assumptions (`assume(n mod 16 == 0)`) that simplify
//!   floor atoms into ordinary polynomial terms.

pub mod domain;
pub mod qpoly;
pub mod sum;

pub use domain::{Assumptions, LoopExtent, NestedDomain};
pub use qpoly::{Atom, PolyPlan, QPoly};
pub use sum::sum_over;
