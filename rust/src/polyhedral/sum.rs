//! Symbolic summation of polynomials over integer intervals.
//!
//! `sum_over(P, v, lo, hi)` computes `Σ_{v=lo}^{hi} P` exactly, where the
//! bounds are themselves quasi-polynomials in outer variables/parameters.
//! This is the engine behind nested-domain point counting: summing `1`
//! over a loop nest from the innermost loop outward yields the Ehrhart-
//! style quasi-polynomial count.
//!
//! Power sums `S_k(N) = Σ_{v=0}^{N} v^k` are generated on demand through
//! the recurrence `(N+1)^{k+1} = Σ_{j<=k} C(k+1, j) S_j(N)` (equivalent
//! to Faulhaber's formula) with exact rational coefficients.

use std::sync::Mutex;

use once_cell::sync::Lazy;

use super::qpoly::{Atom, QPoly};
use crate::util::Rat;

/// Binomial coefficient as a rational.
fn binom(n: u32, k: u32) -> Rat {
    let mut out = Rat::ONE;
    for i in 0..k {
        out = out * Rat::new((n - i) as i128, (i + 1) as i128);
    }
    out
}

/// Memoized Faulhaber polynomials in the formal variable `__N`.
static POWER_SUMS: Lazy<Mutex<Vec<QPoly>>> = Lazy::new(|| Mutex::new(Vec::new()));

const N_VAR: &str = "__faulhaber_N";

/// `S_k` as a polynomial in the formal variable `__faulhaber_N`.
fn power_sum(k: u32) -> QPoly {
    let mut cache = POWER_SUMS.lock().unwrap();
    while cache.len() <= k as usize {
        let j = cache.len() as u32;
        let n = QPoly::var(N_VAR);
        let np1 = &n + &QPoly::one();
        // S_j = [ (N+1)^{j+1} - Σ_{i<j} C(j+1, i) S_i ] / (j+1)
        let mut acc = np1.pow(j + 1);
        for (i, si) in cache.iter().enumerate() {
            acc = &acc - &si.scale(binom(j + 1, i as u32));
        }
        cache.push(acc.scale(Rat::new(1, (j + 1) as i128)));
    }
    cache[k as usize].clone()
}

/// `Σ_{v=0}^{N} v^k` with `N` replaced by the polynomial `n`.
fn power_sum_at(k: u32, n: &QPoly) -> QPoly {
    power_sum(k).subst(&Atom::var(N_VAR), n)
}

/// Exact symbolic `Σ_{v=lo}^{hi} p` (inclusive bounds).
///
/// Validity: like Ehrhart/Barvinok counting this produces the polynomial
/// that agrees with the true sum whenever `hi >= lo - 1` (an empty range
/// `hi = lo - 1` correctly yields 0).  Bounds must not mention `v`, and
/// `v` must not occur inside floor atoms of `p` (our loop nests never
/// produce that shape; asserted).
pub fn sum_over(p: &QPoly, v: &str, lo: &QPoly, hi: &QPoly) -> QPoly {
    assert!(
        !lo.mentions(v) && !hi.mentions(v),
        "summation bounds of '{v}' must not mention it"
    );
    let atom = Atom::var(v);
    let coeffs = p.coeffs_in(&atom);
    // Assert v does not hide inside floor atoms of the coefficients.
    for c in &coeffs {
        assert!(
            !c.mentions(v),
            "'{v}' occurs inside a floor atom; unsupported summation shape"
        );
    }
    let lo_m1 = lo - &QPoly::one();
    let mut out = QPoly::zero();
    for (k, c) in coeffs.iter().enumerate() {
        if c.is_zero() {
            continue;
        }
        // Σ_{v=lo}^{hi} v^k = S_k(hi) - S_k(lo - 1).
        let s = &power_sum_at(k as u32, hi) - &power_sum_at(k as u32, &lo_m1);
        out = &out + &(c * &s);
    }
    out
}

#[cfg(test)]
mod tests {
    use std::collections::BTreeMap;

    use super::*;
    use crate::util::prop;

    fn env(pairs: &[(&str, i128)]) -> BTreeMap<String, i128> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn faulhaber_small_cases() {
        // S_1(N) = N(N+1)/2
        let s1 = power_sum(1);
        assert_eq!(s1.eval(&env(&[(N_VAR, 10)])), Rat::int(55));
        // S_2(N) = N(N+1)(2N+1)/6
        let s2 = power_sum(2);
        assert_eq!(s2.eval(&env(&[(N_VAR, 10)])), Rat::int(385));
        // S_3(10) = 3025
        assert_eq!(power_sum(3).eval(&env(&[(N_VAR, 10)])), Rat::int(3025));
    }

    #[test]
    fn sum_of_one_is_extent() {
        // Σ_{v=0}^{n-1} 1 = n
        let n = QPoly::var("n");
        let s = sum_over(
            &QPoly::one(),
            "v",
            &QPoly::zero(),
            &(&n - &QPoly::one()),
        );
        assert_eq!(s, n);
    }

    #[test]
    fn empty_range_gives_zero() {
        // Σ_{v=5}^{4} anything = 0
        let s = sum_over(&QPoly::var("v"), "v", &QPoly::int(5), &QPoly::int(4));
        assert!(s.is_zero());
    }

    #[test]
    fn triangular_sum() {
        // Σ_{i=0}^{n-1} i = n(n-1)/2
        let n = QPoly::var("n");
        let s = sum_over(&QPoly::var("i"), "i", &QPoly::zero(), &(&n - &QPoly::one()));
        for nv in [1i128, 2, 5, 17] {
            assert_eq!(
                s.eval(&env(&[("n", nv)])),
                Rat::int(nv * (nv - 1) / 2),
                "n={nv}"
            );
        }
    }

    #[test]
    fn prop_symbolic_sum_matches_brute_force() {
        prop::check("faulhaber vs brute force", 48, |rng| {
            // Random polynomial in v and n of small degree.
            let mut p = QPoly::zero();
            for _ in 0..rng.int_in(1, 4) {
                let c = Rat::int(rng.int_in(-3, 3) as i128);
                let mono = &QPoly::var("v").pow(rng.int_in(0, 4) as u32)
                    * &QPoly::var("n").pow(rng.int_in(0, 2) as u32);
                p = &p + &mono.scale(c);
            }
            let lo = rng.int_in(-3, 3) as i128;
            let hi = lo + rng.int_in(-1, 8) as i128; // may be empty
            let nv = rng.int_in(0, 6) as i128;

            let sym = sum_over(&p, "v", &QPoly::int(lo), &QPoly::int(hi));
            let sym_val = sym.eval(&env(&[("n", nv)]));

            let mut brute = Rat::ZERO;
            let mut v = lo;
            while v <= hi {
                brute += p.eval(&env(&[("v", v), ("n", nv)]));
                v += 1;
            }
            prop::ensure(
                sym_val == brute,
                format!("p={p} lo={lo} hi={hi} n={nv}: {sym_val} vs {brute}"),
            )
        });
    }

    #[test]
    fn parametric_bounds() {
        // Σ_{v=p}^{n} (v - p) = (n-p)(n-p+1)/2
        let (n, pvar) = (QPoly::var("n"), QPoly::var("p"));
        let body = &QPoly::var("v") - &pvar;
        let s = sum_over(&body, "v", &pvar, &n);
        for (nv, pv) in [(10i128, 3i128), (5, 5), (7, 0)] {
            let d = nv - pv;
            assert_eq!(
                s.eval(&env(&[("n", nv), ("p", pv)])),
                Rat::int(d * (d + 1) / 2)
            );
        }
    }
}
