//! Model calibration (paper Section 7.2) and prediction (§7.3).
//!
//! Feature values are gathered for a measurement-kernel set, optionally
//! scaled by the output (the paper's `scale_features_by_output`), and
//! the model is fitted by Levenberg-Marquardt.  Gathering goes through
//! a [`StatsCache`] (the `_cached` variants accept a shared one), so a
//! kernel's symbolic statistics are derived once and reused by both its
//! simulated measurement and its feature row; with a disk-backed cache
//! (`StatsCache::with_backing` over an artifact store) the counting
//! pass is skipped across processes too, and the store's journaled
//! index spares every warm hit its validation parse.  A
//! measurement set whose kernels are *all* skipped as unlaunchable
//! yields an error rather than a silent zero-row "fit".  The LM *loop* lives
//! here in Rust; the residual/Jacobian/step evaluation is a pluggable
//! [`LmBackend`]:
//!
//! * [`NativeBackend`] — the general path: any model expression, using
//!   symbolic differentiation (`ModelExpr::diff`).
//! * `runtime::AotBackend` — the accelerated path for the builtin
//!   three-component family, executing the AOT-compiled JAX/Pallas
//!   `lm_step` artifact on the PJRT CPU client.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::features::{BoundFeature, FeatureSpec};
use crate::gpusim::{
    is_per_kernel_measure_error, measure_with_cache, DeviceProfile,
    MeasuredSample,
};
use crate::ir::KernelRef;
use crate::model::{Model, ModelExpr};
use crate::stats::{KernelStats, StatsCache};
use crate::uipick::GeneratedKernel;

/// A named response variable a model can be calibrated against.
///
/// The paper fits wall time; the same symbolic operation counts also
/// support fitting energy and power (Braun et al., arXiv 2001.07104),
/// so the pipeline carries the target from measurement through
/// persistence to reporting instead of hardwiring "the output is a
/// time in seconds".
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Target {
    /// Wall time in seconds (the paper's output feature).
    #[default]
    Time,
    /// Board energy in joules over the kernel's execution.
    Energy,
    /// Average board power in watts (energy / time).
    AvgPower,
}

impl Target {
    /// Every calibratable target, in canonical order.
    pub const ALL: [Target; 3] =
        [Target::Time, Target::Energy, Target::AvgPower];

    /// The stable name used on the CLI, in fit keys and in artifacts.
    pub fn name(self) -> &'static str {
        match self {
            Target::Time => "time",
            Target::Energy => "energy",
            Target::AvgPower => "avg_power",
        }
    }

    /// Unit suffix for report columns.
    pub fn unit(self) -> &'static str {
        match self {
            Target::Time => "s",
            Target::Energy => "J",
            Target::AvgPower => "W",
        }
    }

    /// The noun used in diagnostics ("non-scalable measured {noun}").
    pub fn noun(self) -> &'static str {
        match self {
            Target::Time => "time",
            Target::Energy => "energy",
            Target::AvgPower => "average power",
        }
    }

    /// Parse a CLI/wire name; unknown names report the valid set.
    pub fn parse(s: &str) -> Result<Target, String> {
        Target::ALL
            .iter()
            .copied()
            .find(|t| t.name() == s)
            .ok_or_else(|| {
                format!(
                    "unknown target '{s}'; valid targets: {}",
                    Target::ALL
                        .iter()
                        .map(|t| t.name())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })
    }

    /// Extract this target's value from a measured sample.
    pub fn of(self, s: &MeasuredSample) -> f64 {
        match self {
            Target::Time => s.time_s,
            Target::Energy => s.energy_j,
            Target::AvgPower => s.avg_power_w(),
        }
    }
}

/// Feature values for a measurement-kernel set.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FeatureData {
    /// Input-feature identifiers (column order).
    pub feature_ids: Vec<String>,
    /// One row of input-feature values per measurement kernel.
    pub rows: Vec<Vec<f64>>,
    /// Output-feature (the measured `target` value) per measurement
    /// kernel.
    pub outputs: Vec<f64>,
    /// Kernel labels for diagnostics.
    pub labels: Vec<String>,
    /// Whether `scale_features_by_output` has been applied.
    pub scaled: bool,
    /// Which response variable `outputs` holds.
    pub target: Target,
}

impl FeatureData {
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// §7.2: divide each input-feature row by its output value and set
    /// outputs to 1, making the fit minimize *relative* error.
    ///
    /// A zero or non-finite measured output would poison every scaled
    /// feature of its row with inf/NaN and thereby the whole fit (LM
    /// happily converges on garbage once a NaN enters the normal
    /// equations), so the outputs are validated *before* anything is
    /// mutated and the offending kernel is named in the error — a
    /// labeled per-kernel failure, never a silent bad fit and never a
    /// half-scaled `FeatureData`.
    pub fn scale_features_by_output(&mut self) -> Result<(), String> {
        for (i, t) in self.outputs.iter().enumerate() {
            if !t.is_finite() || *t <= 0.0 {
                let label = self
                    .labels
                    .get(i)
                    .map(String::as_str)
                    .unwrap_or("<unlabeled>");
                return Err(format!(
                    "measurement kernel '{label}' has a non-scalable measured \
                     {} ({t}); refusing to scale features by output",
                    self.target.noun()
                ));
            }
        }
        for (row, t) in self.rows.iter_mut().zip(&self.outputs) {
            for v in row.iter_mut() {
                *v /= *t;
            }
        }
        for t in self.outputs.iter_mut() {
            *t = 1.0;
        }
        self.scaled = true;
        Ok(())
    }
}

/// Evaluate the model's input features and measure its output feature
/// for every kernel in the measurement set.
pub fn gather_feature_values(
    model: &Model,
    kernels: &[GeneratedKernel],
    device: &DeviceProfile,
) -> Result<FeatureData, String> {
    gather_features_by_ids(model.input_features(), kernels, device)
}

/// Like [`gather_feature_values`] but with an explicit feature-column
/// order (the AOT backend requires the cost model's term order).
/// Uses a private one-shot [`StatsCache`], so even a standalone call
/// pays one symbolic pass per kernel instead of two.
pub fn gather_features_by_ids(
    ids: Vec<String>,
    kernels: &[GeneratedKernel],
    device: &DeviceProfile,
) -> Result<FeatureData, String> {
    gather_features_by_ids_cached(ids, kernels, device, &StatsCache::new())
}

/// [`gather_features_by_ids`] through a shared [`StatsCache`]: each
/// distinct (kernel, sub-group size) is symbolically counted at most
/// once across measurement, feature evaluation, and any other caller
/// sharing the cache (e.g. a whole multi-device experiment).
///
/// The per-kernel measurement loop runs on scoped worker threads (one
/// per available core, work-stealing over the kernel list) sharing the
/// cache; rows are merged back in measurement-kernel order, so the
/// resulting [`FeatureData`] — and everything downstream of it, fits
/// and figure reports included — is byte-identical to the sequential
/// reference ([`gather_features_by_ids_sequential`]).  Failures are
/// part of that contract: when workers fail (errors or contained
/// panics), the surfaced error is deterministically the one at the
/// lowest kernel index — exactly what the sequential pass would have
/// reported — regardless of work-stealing or completion order.
///
/// Feature evaluation is batched across problem sizes: a measurement
/// set typically reuses one structural kernel at many sizes, so the
/// feature columns are [bound](FeatureSpec::bind) once per distinct
/// kernel (access matching, count scaling, op summation hoisted out)
/// and each size pays only cheap `QPoly` evaluations.  Kernels arrive
/// pre-frozen from UiPiCK, so cache keys reuse the fingerprint minted
/// at generation time instead of re-rendering the IR per lookup.
pub fn gather_features_by_ids_cached(
    ids: Vec<String>,
    kernels: &[GeneratedKernel],
    device: &DeviceProfile,
    cache: &StatsCache,
) -> Result<FeatureData, String> {
    gather_features_by_ids_cached_for(ids, kernels, device, cache, Target::Time)
}

/// [`gather_features_by_ids_cached`] for an arbitrary response
/// variable: the `outputs` column holds `target.of(sample)` for each
/// launchable measurement kernel.  Every target of the same kernel
/// shares one measurement (and one symbolic pass) through the cache —
/// the sample carries time and energy together.
pub fn gather_features_by_ids_cached_for(
    ids: Vec<String>,
    kernels: &[GeneratedKernel],
    device: &DeviceProfile,
    cache: &StatsCache,
    target: Target,
) -> Result<FeatureData, String> {
    let workers = std::thread::available_parallelism()
        .map(usize::from)
        .unwrap_or(1)
        .min(kernels.len().max(1));
    gather_features_by_ids_inner(ids, kernels, device, cache, workers, target)
}

/// The sequential reference implementation of
/// [`gather_features_by_ids_cached`]: one thread, kernels in order.
/// The parallel path must match it byte-for-byte (see the determinism
/// tests); it also serves as the baseline in `benches/stats_cache.rs`.
pub fn gather_features_by_ids_sequential(
    ids: Vec<String>,
    kernels: &[GeneratedKernel],
    device: &DeviceProfile,
    cache: &StatsCache,
) -> Result<FeatureData, String> {
    gather_features_by_ids_inner(ids, kernels, device, cache, 1, Target::Time)
}

/// One gathered calibration row (feature values, measured output,
/// diagnostic label), produced per launchable measurement kernel.
struct GatheredRow {
    row: Vec<f64>,
    output: f64,
    label: String,
}

/// Per-distinct-kernel bound state: the stats bundle plus the feature
/// columns bound against it.  The map entry is created under the map
/// lock, but binding runs inside the slot's own [`OnceLock`] — the
/// same pattern as [`StatsCache`] — so concurrent workers bind each
/// distinct kernel exactly once.
type BindSlot =
    Arc<OnceLock<Result<(Arc<KernelStats>, Arc<Vec<BoundFeature>>), String>>>;

fn bind_features(
    slots: &Mutex<HashMap<u128, BindSlot>>,
    gk: &GeneratedKernel,
    specs: &[FeatureSpec],
    device: &DeviceProfile,
    cache: &StatsCache,
) -> Result<(Arc<KernelStats>, Arc<Vec<BoundFeature>>), String> {
    let slot: BindSlot = {
        let mut map = slots.lock().unwrap();
        map.entry(gk.kernel.fingerprint()).or_default().clone()
    };
    slot.get_or_init(|| {
        let st = cache.get_or_gather(&gk.kernel, device.sub_group_size)?;
        let feats = specs
            .iter()
            .map(|s| s.bind(&st))
            .collect::<Result<Vec<_>, String>>()?;
        Ok((st, Arc::new(feats)))
    })
    .clone()
}

/// Measure and evaluate one measurement kernel.  `Ok(None)` when the
/// device skips it — unlaunchable work-group sizes and unmeasurable
/// access maps condemn the kernel, not the sweep.
fn gather_one(
    gk: &GeneratedKernel,
    specs: &[FeatureSpec],
    device: &DeviceProfile,
    cache: &StatsCache,
    slots: &Mutex<HashMap<u128, BindSlot>>,
    target: Target,
) -> Result<Option<GatheredRow>, String> {
    // Measure first: kernels a device cannot launch (e.g. 18x18
    // work-groups on the AMD R9 Fury) are skipped, exactly as the
    // paper had to, and the launchability check precedes all
    // symbolic work — so skipped kernels pay nothing.  Their
    // exclusive features stay at the bound of 0.
    let sample = match measure_with_cache(device, &gk.kernel, &gk.env, cache) {
        Ok(s) => s,
        Err(e) if is_per_kernel_measure_error(&e) => return Ok(None),
        Err(e) => return Err(e),
    };
    let (st, feats) = bind_features(slots, gk, specs, device, cache)?;
    let env: BTreeMap<String, i128> = gk
        .env
        .iter()
        .map(|(k, v)| (k.clone(), *v as i128))
        .collect();
    let row: Vec<f64> = feats.iter().map(|b| b.eval(&st, &env)).collect();
    Ok(Some(GatheredRow {
        row,
        output: target.of(&sample),
        label: format!(
            "{}[{}]",
            gk.kernel.name,
            gk.env
                .iter()
                .map(|(k, v)| format!("{k}={v}"))
                .collect::<Vec<_>>()
                .join(",")
        ),
    }))
}

/// Best-effort human-readable form of a panic payload.
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    payload
        .downcast_ref::<String>()
        .map(String::as_str)
        .or_else(|| payload.downcast_ref::<&str>().copied())
        .unwrap_or("opaque panic payload")
}

fn gather_features_by_ids_inner(
    ids: Vec<String>,
    kernels: &[GeneratedKernel],
    device: &DeviceProfile,
    cache: &StatsCache,
    workers: usize,
    target: Target,
) -> Result<FeatureData, String> {
    let specs: Vec<FeatureSpec> = ids
        .iter()
        .map(|id| FeatureSpec::parse(id))
        .collect::<Result<_, _>>()?;
    let slots: Mutex<HashMap<u128, BindSlot>> = Mutex::new(HashMap::new());

    // Per-kernel outcomes, indexed in measurement-kernel order.  In
    // the parallel path every claimed index reports (panics are
    // contained per kernel), so `None` only marks the tail behind a
    // sequential early stop.
    let mut outcomes: Vec<Option<Result<Option<GatheredRow>, String>>> =
        kernels.iter().map(|_| None).collect();
    if workers <= 1 {
        for (i, gk) in kernels.iter().enumerate() {
            let out = gather_one(gk, &specs, device, cache, &slots, target);
            let failed = out.is_err();
            outcomes[i] = Some(out);
            if failed {
                // Match the sequential contract: stop at the first
                // error in kernel order.
                break;
            }
        }
    } else {
        let next = AtomicUsize::new(0);
        // Each worker returns its Vec<(kernel index, outcome)>.
        let joined: Vec<Vec<_>> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let (specs, slots, next) = (&specs, &slots, &next);
                    s.spawn(move || {
                        let mut local = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= kernels.len() {
                                break;
                            }
                            // Contain panics *per kernel*, so a
                            // panicking kernel cannot discard its
                            // worker's other finished outcomes —
                            // which would make the surfaced
                            // failure depend on work-stealing
                            // order.  Every claimed index reports,
                            // and the merge below picks the lowest
                            // failing index deterministically.
                            let out = std::panic::catch_unwind(
                                std::panic::AssertUnwindSafe(|| {
                                    gather_one(
                                        &kernels[i],
                                        specs,
                                        device,
                                        cache,
                                        slots,
                                        target,
                                    )
                                }),
                            )
                            .unwrap_or_else(|payload| {
                                Err(format!(
                                    "measurement sweep worker panicked at \
                                     kernel {i}: {}",
                                    panic_message(payload.as_ref())
                                ))
                            });
                            local.push((i, out));
                        }
                        local
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().expect(
                        "sweep workers contain panics per kernel and \
                         cannot themselves panic",
                    )
                })
                .collect()
        });
        for list in joined {
            for (i, out) in list {
                outcomes[i] = Some(out);
            }
        }
    }

    // Merge in kernel order: the first error in order wins — exactly
    // the sequential short-circuit, so the surfaced error (like the
    // surviving rows) is byte-identical to the sequential pass no
    // matter how many workers failed or in which temporal order.
    // Skipped kernels drop out; surviving rows keep their
    // measurement-set order.
    let mut data = FeatureData {
        feature_ids: ids,
        target,
        ..Default::default()
    };
    for outcome in outcomes {
        match outcome {
            Some(Ok(Some(g))) => {
                data.rows.push(g.row);
                data.outputs.push(g.output);
                data.labels.push(g.label);
            }
            Some(Ok(None)) => {}
            Some(Err(e)) => return Err(e),
            // Sequential early-stop tail: the error ahead of it was
            // already returned above.
            None => break,
        }
    }
    if data.is_empty() {
        // Fitting zero rows would "succeed" on garbage parameters; make
        // the failure mode explicit instead.
        return Err(format!(
            "calibration data for device '{}' is empty: all {} measurement \
             kernels were skipped (unlaunchable or unmeasurable there) or \
             none were provided; refusing to fit a model to zero rows",
            device.id,
            kernels.len()
        ));
    }
    Ok(data)
}

/// One Levenberg-Marquardt backend: given parameters and damping,
/// produce a proposed step and the current cost.
pub trait LmBackend {
    /// Sum-of-squares cost at `p`.
    fn cost(&mut self, p: &[f64]) -> Result<f64, String>;
    /// `(delta, cost_at_p)` for the damped normal equations at `p`.
    fn step(&mut self, p: &[f64], lam: f64) -> Result<(Vec<f64>, f64), String>;
}

/// Native backend: symbolic-differentiation Jacobian over the model
/// expression (handles arbitrary user models).
pub struct NativeBackend {
    expr: ModelExpr,
    param_names: Vec<String>,
    grads: Vec<ModelExpr>,
    feature_ids: Vec<String>,
    rows: Vec<Vec<f64>>,
    outputs: Vec<f64>,
}

impl NativeBackend {
    pub fn new(model: &Model, data: &FeatureData) -> NativeBackend {
        Self::with_params(model, data, model.params())
    }

    /// Use an explicit parameter ordering (must cover the model's
    /// parameters; extras are allowed and simply have zero gradient).
    pub fn with_params(
        model: &Model,
        data: &FeatureData,
        param_names: Vec<String>,
    ) -> NativeBackend {
        let grads = param_names
            .iter()
            .map(|p| model.expr.diff(p))
            .collect();
        NativeBackend {
            expr: model.expr.clone(),
            param_names,
            grads,
            feature_ids: data.feature_ids.clone(),
            rows: data.rows.clone(),
            outputs: data.outputs.clone(),
        }
    }

    pub fn param_names(&self) -> &[String] {
        &self.param_names
    }

    fn envs(&self, p: &[f64], row: &[f64]) -> (BTreeMap<String, f64>, BTreeMap<String, f64>) {
        let params: BTreeMap<String, f64> = self
            .param_names
            .iter()
            .cloned()
            .zip(p.iter().copied())
            .collect();
        let feats: BTreeMap<String, f64> = self
            .feature_ids
            .iter()
            .cloned()
            .zip(row.iter().copied())
            .collect();
        (params, feats)
    }

    /// Predictions at `p` for every row.
    pub fn predict(&self, p: &[f64]) -> Result<Vec<f64>, String> {
        self.rows
            .iter()
            .map(|row| {
                let (pe, fe) = self.envs(p, row);
                self.expr.eval(&pe, &fe)
            })
            .collect()
    }
}

const RIDGE: f64 = 1e-9;

impl LmBackend for NativeBackend {
    fn cost(&mut self, p: &[f64]) -> Result<f64, String> {
        let pred = self.predict(p)?;
        Ok(pred
            .iter()
            .zip(&self.outputs)
            .map(|(g, t)| (t - g) * (t - g))
            .sum())
    }

    fn step(&mut self, p: &[f64], lam: f64) -> Result<(Vec<f64>, f64), String> {
        let np = self.param_names.len();
        let l = self.rows.len();
        let mut jac = vec![vec![0.0; np]; l];
        let mut resid = vec![0.0; l];
        for (k, row) in self.rows.iter().enumerate() {
            let (pe, fe) = self.envs(p, row);
            let g = self.expr.eval(&pe, &fe)?;
            resid[k] = self.outputs[k] - g;
            for (i, gexpr) in self.grads.iter().enumerate() {
                jac[k][i] = gexpr.eval(&pe, &fe)?;
            }
        }
        // Damped normal equations: (JtJ + lam diag(JtJ) + ridge I) d = Jt r.
        let mut a = vec![vec![0.0; np]; np];
        let mut b = vec![0.0; np];
        for k in 0..l {
            for i in 0..np {
                b[i] += jac[k][i] * resid[k];
                for j in 0..np {
                    a[i][j] += jac[k][i] * jac[k][j];
                }
            }
        }
        for i in 0..np {
            a[i][i] += lam * a[i][i] + RIDGE;
        }
        let delta = solve_dense(&mut a, &mut b)?;
        let cost = resid.iter().map(|r| r * r).sum();
        Ok((delta, cost))
    }
}

/// Gaussian elimination with partial pivoting (P <= ~25 here).
pub fn solve_dense(a: &mut [Vec<f64>], b: &mut [f64]) -> Result<Vec<f64>, String> {
    let n = b.len();
    for col in 0..n {
        let pivot = (col..n)
            .max_by(|&i, &j| a[i][col].abs().total_cmp(&a[j][col].abs()))
            .unwrap();
        if a[pivot][col].abs() < 1e-300 {
            return Err("singular normal equations".into());
        }
        a.swap(col, pivot);
        b.swap(col, pivot);
        for row in col + 1..n {
            let f = a[row][col] / a[col][col];
            if f == 0.0 {
                continue;
            }
            for k in col..n {
                a[row][k] -= f * a[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = vec![0.0; n];
    for col in (0..n).rev() {
        let mut acc = b[col];
        for k in col + 1..n {
            acc -= a[col][k] * x[k];
        }
        x[col] = acc / a[col][col];
    }
    Ok(x)
}

/// LM driver options.
#[derive(Clone, Debug)]
pub struct LmOptions {
    pub max_iters: usize,
    pub init_lambda: f64,
    pub tol: f64,
    /// Per-parameter lower bounds (projected LM).  The builtin cost
    /// models bound cost coefficients at 0 — the paper's
    /// interpretability criterion ("carrying out additional operations
    /// should never reduce cost") — and the overlap edge at 1 so the
    /// step switch cannot flatten or invert.
    pub lower_bounds: Option<Vec<f64>>,
}

impl Default for LmOptions {
    fn default() -> Self {
        LmOptions {
            max_iters: 200,
            init_lambda: 1e-3,
            tol: 1e-14,
            lower_bounds: None,
        }
    }
}

impl LmOptions {
    /// Bounds for a cost model with `n_terms` cost coefficients plus a
    /// trailing p_edge.
    pub fn cost_model_bounds(n_terms: usize) -> LmOptions {
        let mut lb = vec![0.0; n_terms];
        lb.push(1.0);
        LmOptions {
            lower_bounds: Some(lb),
            ..LmOptions::default()
        }
    }
}

/// Calibration result.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub param_names: Vec<String>,
    pub params: Vec<f64>,
    /// Final sum-of-squares residual (the §7.2 diagnostic Perflex logs).
    pub residual: f64,
    pub iterations: usize,
    /// The response variable this fit explains.
    pub target: Target,
    /// `true` when LM exited via its convergence criterion (relative
    /// cost improvement below `tol`); `false` on lambda saturation or
    /// the iteration cap — the parameters may still be usable, but the
    /// optimizer never declared them a minimum.
    pub converged: bool,
}

impl FitResult {
    pub fn param(&self, name: &str) -> Option<f64> {
        self.param_names
            .iter()
            .position(|n| n == name)
            .map(|i| self.params[i])
    }
}

/// The Levenberg-Marquardt loop (accept/reject with damping schedule).
///
/// The returned fit discriminates *why* the loop exited: `converged`
/// is `true` only for the convergence criterion (accepted step whose
/// relative improvement fell below `tol`), not for lambda saturation
/// (`lam >= 1e10` — the damping schedule gave up) or the iteration
/// cap.  The fit's `target` is stamped [`Target::Time`]; callers
/// fitting another response variable overwrite it from their
/// [`FeatureData`] (see [`fit_model`]).
pub fn levenberg_marquardt(
    backend: &mut dyn LmBackend,
    param_names: Vec<String>,
    p0: Vec<f64>,
    opts: &LmOptions,
) -> Result<FitResult, String> {
    let mut p = p0;
    let mut lam = opts.init_lambda;
    let mut cost = backend.cost(&p)?;
    let mut iters = 0;
    let mut converged = false;
    for _ in 0..opts.max_iters {
        iters += 1;
        let (delta, _) = backend.step(&p, lam)?;
        let mut p_new: Vec<f64> =
            p.iter().zip(&delta).map(|(a, d)| a + d).collect();
        if let Some(lb) = &opts.lower_bounds {
            for (v, b) in p_new.iter_mut().zip(lb) {
                if *v < *b {
                    *v = *b;
                }
            }
        }
        let new_cost = backend.cost(&p_new)?;
        if new_cost.is_finite() && new_cost < cost {
            let improvement = (cost - new_cost) / cost.max(1e-300);
            p = p_new;
            cost = new_cost;
            lam = (lam / 3.0).max(1e-14);
            if improvement < opts.tol {
                converged = true;
                break;
            }
        } else {
            lam = (lam * 5.0).min(1e10);
            if lam >= 1e10 {
                break;
            }
        }
    }
    Ok(FitResult {
        param_names,
        params: p,
        residual: cost,
        iterations: iters,
        target: Target::Time,
        converged,
    })
}

/// Heuristic starting point: each term contributes ~equally to the
/// (scaled) output, and the overlap edge starts moderately sharp.
pub fn initial_params(data: &FeatureData, n_terms: usize, with_edge: bool) -> Vec<f64> {
    let l = data.len().max(1);
    let t_mean: f64 = data.outputs.iter().sum::<f64>() / l as f64;
    let mut p0 = Vec::with_capacity(n_terms + usize::from(with_edge));
    for j in 0..n_terms {
        let f_mean: f64 =
            data.rows.iter().map(|r| r[j]).sum::<f64>() / l as f64;
        p0.push(if f_mean.abs() > 1e-300 {
            t_mean / (n_terms as f64 * f_mean)
        } else {
            0.0
        });
    }
    if with_edge {
        // Dimensionless sharpness of the scale-invariant switch.
        p0.push(5.0);
    }
    p0
}

/// Fit a model natively (arbitrary expression path).
pub fn fit_model(
    model: &Model,
    data: &FeatureData,
    opts: &LmOptions,
) -> Result<FitResult, String> {
    let names = model.params();
    let with_edge = names.iter().any(|n| n == "p_edge");
    let n_terms = names.len() - usize::from(with_edge);
    // Order params so p_edge (if present) is last, matching initial_params.
    let mut ordered: Vec<String> = names
        .iter()
        .filter(|n| *n != "p_edge")
        .cloned()
        .collect();
    if with_edge {
        ordered.push("p_edge".into());
    }
    let p0 = initial_params(data, n_terms, with_edge);
    let mut backend = NativeBackend::with_params(model, data, ordered.clone());
    let mut fit = levenberg_marquardt(&mut backend, ordered, p0, opts)?;
    fit.target = data.target;
    Ok(fit)
}

/// Predict the output feature for a kernel using fitted parameters
/// (§7.3 `model.eval_with_kernel`).
pub fn eval_with_kernel(
    model: &Model,
    fit: &FitResult,
    kernel: &crate::ir::Kernel,
    env: &BTreeMap<String, i64>,
    sub_group_size: u64,
) -> Result<f64, String> {
    eval_with_kernel_cached(model, fit, kernel, env, sub_group_size, &StatsCache::new())
}

/// [`eval_with_kernel`] through a shared [`StatsCache`]: predicting the
/// same kernel at many sizes (or for many variants of a sweep) pays the
/// symbolic pass once and a `QPoly` evaluation per size.  Accepts any
/// [`KernelRef`]; a [`crate::ir::FrozenKernel`] skips the per-lookup
/// IR rendering of the cache key.
pub fn eval_with_kernel_cached<K: KernelRef>(
    model: &Model,
    fit: &FitResult,
    kernel: &K,
    env: &BTreeMap<String, i64>,
    sub_group_size: u64,
    cache: &StatsCache,
) -> Result<f64, String> {
    let st = cache.get_or_gather(kernel, sub_group_size)?;
    eval_with_stats(model, fit, &st, env)
}

/// The exact evaluator against already-gathered statistics: per-query
/// feature-spec parsing, `QPoly`/`Rat` rational walks and name-keyed
/// environment maps.  This is the reference semantics the compiled
/// path ([`crate::model::compiled::CompiledModel`]) is checked against;
/// factored out so equivalence tests and benches can drive both sides
/// from one `KernelStats` bundle.
pub fn eval_with_stats(
    model: &Model,
    fit: &FitResult,
    stats: &crate::stats::KernelStats,
    env: &BTreeMap<String, i64>,
) -> Result<f64, String> {
    let ienv: BTreeMap<String, i128> =
        env.iter().map(|(k, v)| (k.clone(), *v as i128)).collect();
    let mut feats = BTreeMap::new();
    for id in model.input_features() {
        let spec = FeatureSpec::parse(&id)?;
        feats.insert(id, spec.eval(stats, &ienv)?);
    }
    let params: BTreeMap<String, f64> = fit
        .param_names
        .iter()
        .cloned()
        .zip(fit.params.iter().copied())
        .collect();
    model.expr.eval(&params, &feats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{device_by_id, measure};
    use crate::model::{CostGroup, CostModel};
    use crate::uipick::KernelCollection;
    use crate::util::prop;

    #[test]
    fn solve_dense_small_system() {
        let mut a = vec![vec![2.0, 1.0], vec![1.0, 3.0]];
        let mut b = vec![5.0, 10.0];
        let x = solve_dense(&mut a, &mut b).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn prop_solver_inverts_random_spd_systems() {
        prop::check("gaussian elimination", 40, |rng| {
            let n = rng.int_in(1, 8) as usize;
            // SPD-ish: A = M^T M + I.
            let m: Vec<Vec<f64>> = (0..n)
                .map(|_| (0..n).map(|_| rng.uniform_in(-1.0, 1.0)).collect())
                .collect();
            let mut a = vec![vec![0.0; n]; n];
            for i in 0..n {
                for j in 0..n {
                    for k in 0..n {
                        a[i][j] += m[k][i] * m[k][j];
                    }
                }
                a[i][i] += 1.0;
            }
            let x_true: Vec<f64> = (0..n).map(|_| rng.uniform_in(-2.0, 2.0)).collect();
            let mut b: Vec<f64> = (0..n)
                .map(|i| (0..n).map(|j| a[i][j] * x_true[j]).sum())
                .collect();
            let mut a2 = a.clone();
            let x = solve_dense(&mut a2, &mut b).map_err(|e| e)?;
            for i in 0..n {
                prop::ensure_close(x[i], x_true[i], 1e-6, "solution")?;
            }
            Ok(())
        });
    }

    #[test]
    fn lm_recovers_linear_model_exactly() {
        // Synthetic: t = 2*f1 + 3*f2.
        let model = Model::new(
            "f_cl_wall_time_titan_v",
            "p_a * f_op_float32_madd + p_b * f_thread_groups",
        )
        .unwrap();
        let mut data = FeatureData {
            feature_ids: vec![
                "f_op_float32_madd".into(),
                "f_thread_groups".into(),
            ],
            ..Default::default()
        };
        let mut rng = crate::util::Rng::new(1);
        for _ in 0..20 {
            let f1 = rng.uniform_in(1.0, 10.0);
            let f2 = rng.uniform_in(1.0, 10.0);
            data.rows.push(vec![f1, f2]);
            data.outputs.push(2.0 * f1 + 3.0 * f2);
            data.labels.push("synthetic".into());
        }
        let fit = fit_model(&model, &data, &LmOptions::default()).unwrap();
        assert!(fit.residual < 1e-18, "{}", fit.residual);
        assert!((fit.param("p_a").unwrap() - 2.0).abs() < 1e-6);
        assert!((fit.param("p_b").unwrap() - 3.0).abs() < 1e-6);
    }

    #[test]
    fn lm_fits_nonlinear_overlap_model() {
        // Synthetic data generated by the max()-like overlap form.
        let cm = CostModel::new("titan_v", true)
            .term("g1", "f_mem_access_tag:aLD", CostGroup::Gmem)
            .term("o1", "f_op_float32_madd", CostGroup::OnChip);
        let model = cm.to_model();
        let (pg, po, edge) = (0.7, 0.4, 25.0);
        let mut data = FeatureData {
            feature_ids: vec![
                "f_mem_access_tag:aLD".into(),
                "f_op_float32_madd".into(),
            ],
            ..Default::default()
        };
        let mut rng = crate::util::Rng::new(3);
        for _ in 0..40 {
            let fg = rng.uniform_in(0.5, 4.0);
            let fo = rng.uniform_in(0.5, 4.0);
            let (a, b) = (pg * fg, po * fo);
            let u: f64 = a - b;
            let s1 = ((edge * u / (a + b + 1e-30)).tanh() + 1.0) / 2.0;
            data.rows.push(vec![fg, fo]);
            data.outputs.push(b + u * s1);
            data.labels.push("synthetic".into());
        }
        let fit = fit_model(&model, &data, &LmOptions::default()).unwrap();
        let pred_model = fit.residual / data.len() as f64;
        assert!(pred_model < 1e-4, "mse {pred_model}");
        assert!((fit.param("p_g1").unwrap() - pg).abs() < 0.05, "{fit:?}");
        assert!((fit.param("p_o1").unwrap() - po).abs() < 0.05, "{fit:?}");
    }

    #[test]
    fn end_to_end_flops_calibration_predicts_unseen_size() {
        // §2.2 in miniature: calibrate a 1-term madd model on the madd
        // microbenchmarks, then predict a held-out variant within 25%.
        let dev = device_by_id("titan_v").unwrap();
        let knls = KernelCollection::all()
            .generate_kernels(&[
                "flops_madd_pattern",
                "dtype:float32",
                "nelements:524288,1048576",
                "m:1024,1408",
            ])
            .unwrap();
        assert_eq!(knls.len(), 4);
        let model = Model::new(
            "f_cl_wall_time_titan_v",
            "p_f32madd * f_op_float32_madd + p_launch * f_sync_kernel_launch",
        )
        .unwrap();
        let mut data = gather_feature_values(&model, &knls, &dev).unwrap();
        data.scale_features_by_output().unwrap();
        let fit = fit_model(&model, &data, &LmOptions::default()).unwrap();

        // Held-out: different (nelements, m).
        let test = KernelCollection::all()
            .generate_kernels(&[
                "flops_madd_pattern",
                "dtype:float32",
                "nelements:786432",
                "m:1280",
            ])
            .unwrap();
        let predicted = eval_with_kernel(
            &model,
            &fit,
            &test[0].kernel,
            &test[0].env,
            dev.sub_group_size,
        )
        .unwrap();
        let actual = measure(&dev, &test[0].kernel, &test[0].env)
            .unwrap()
            .time_s;
        let rel = (predicted - actual).abs() / actual;
        assert!(rel < 0.25, "predicted {predicted}, actual {actual}");

        // Interpretability: implied madd throughput is within an order
        // of magnitude of peak (it is a *throughput* kernel).
        let p_madd = fit.param("p_f32madd").unwrap();
        let implied = 2.0 * 32.0 / p_madd; // flops/s at SG granularity
        assert!(
            implied > 0.2 * dev.peak_flops() && implied < 3.0 * dev.peak_flops(),
            "implied {implied:.3e} vs peak {:.3e}",
            dev.peak_flops()
        );
    }

    /// Tentpole invariant: the parallel measurement sweep produces
    /// `FeatureData` byte-identical to the sequential reference —
    /// including on a device that skips part of the measurement set
    /// (the Fury rejects the 18x18 fdiff kernels), so row merge order
    /// and skip handling are both exercised.
    #[test]
    fn parallel_sweep_matches_sequential_byte_for_byte() {
        let dev = device_by_id("amd_r9_fury").unwrap();
        let case = &crate::coordinator::expsets::eval_cases()[2];
        let kernels = crate::coordinator::expsets::generate_measurement_kernels(
            &(case.measurement_sets)(),
        )
        .unwrap();
        let ids = (case.model)(dev.id, true).feature_columns();
        let seq = gather_features_by_ids_sequential(
            ids.clone(),
            &kernels,
            &dev,
            &StatsCache::new(),
        )
        .unwrap();
        let par =
            gather_features_by_ids_cached(ids, &kernels, &dev, &StatsCache::new())
                .unwrap();
        assert_eq!(seq, par, "parallel sweep must be byte-identical");
        assert!(
            par.len() < kernels.len(),
            "the Fury must skip the 18x18 kernels mid-sweep"
        );
    }

    #[test]
    fn scale_features_by_output_normalizes() {
        let mut d = FeatureData {
            feature_ids: vec!["f_thread_groups".into()],
            rows: vec![vec![10.0], vec![40.0]],
            outputs: vec![2.0, 8.0],
            labels: vec!["a".into(), "b".into()],
            scaled: false,
            target: Target::Time,
        };
        d.scale_features_by_output().unwrap();
        assert_eq!(d.rows, vec![vec![5.0], vec![5.0]]);
        assert_eq!(d.outputs, vec![1.0, 1.0]);
        assert!(d.scaled);
    }

    /// A zero (or NaN/inf) measured time used to silently poison the
    /// whole fit with inf/NaN features; it must instead fail with an
    /// error naming the offending kernel, leaving the data untouched.
    #[test]
    fn scale_features_by_output_rejects_unscalable_outputs() {
        let fresh = || FeatureData {
            feature_ids: vec!["f_thread_groups".into()],
            rows: vec![vec![10.0], vec![40.0]],
            outputs: vec![2.0, 0.0],
            labels: vec!["good[n=1]".into(), "bad[n=2]".into()],
            scaled: false,
            target: Target::Time,
        };
        let mut d = fresh();
        let err = d.scale_features_by_output().unwrap_err();
        assert!(err.contains("bad[n=2]"), "{err}");
        assert!(!d.scaled);
        assert_eq!(
            d.rows,
            vec![vec![10.0], vec![40.0]],
            "a rejected scale must not half-apply"
        );
        assert_eq!(d.outputs, vec![2.0, 0.0]);

        for poison in [f64::NAN, f64::INFINITY, -1.0] {
            let mut d = fresh();
            d.outputs[1] = poison;
            let err = d.scale_features_by_output().unwrap_err();
            assert!(err.contains("bad[n=2]"), "{poison}: {err}");
        }

        // The diagnostic names the target's own noun, not "time".
        let mut d = fresh();
        d.target = Target::Energy;
        let err = d.scale_features_by_output().unwrap_err();
        assert!(err.contains("non-scalable measured energy"), "{err}");
        let mut d = fresh();
        let err = d.scale_features_by_output().unwrap_err();
        assert!(err.contains("non-scalable measured time"), "{err}");
    }

    #[test]
    fn target_names_round_trip_and_unknown_names_list_the_valid_set() {
        for t in Target::ALL {
            assert_eq!(Target::parse(t.name()).unwrap(), t);
        }
        let err = Target::parse("joules").unwrap_err();
        assert!(err.contains("unknown target 'joules'"), "{err}");
        for t in Target::ALL {
            assert!(err.contains(t.name()), "missing {}: {err}", t.name());
        }
    }

    #[test]
    fn lm_discriminates_convergence_from_iteration_cap() {
        let model = Model::new(
            "f_cl_wall_time_titan_v",
            "p_a * f_op_float32_madd + p_b * f_thread_groups",
        )
        .unwrap();
        let mut data = FeatureData {
            feature_ids: vec![
                "f_op_float32_madd".into(),
                "f_thread_groups".into(),
            ],
            ..Default::default()
        };
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..20 {
            let f1 = rng.uniform_in(1.0, 10.0);
            let f2 = rng.uniform_in(1.0, 10.0);
            data.rows.push(vec![f1, f2]);
            data.outputs.push(2.0 * f1 + 3.0 * f2);
            data.labels.push("synthetic".into());
        }
        let fit = fit_model(&model, &data, &LmOptions::default()).unwrap();
        assert!(fit.converged, "{fit:?}");
        // One iteration cannot hit the 1e-14 relative-improvement
        // criterion on this data: the loop exits via the cap instead
        // and must say so.
        let capped = fit_model(
            &model,
            &data,
            &LmOptions {
                max_iters: 1,
                ..LmOptions::default()
            },
        )
        .unwrap();
        assert!(!capped.converged, "{capped:?}");
        assert_eq!(capped.iterations, 1);
    }

    /// Gathering with `Target::Energy` fills `outputs` with joules —
    /// strictly above each kernel's idle-power floor — while sharing
    /// the measurement and symbolic pass with the time gather through
    /// the cache.
    #[test]
    fn energy_target_gathers_energy_outputs() {
        let dev = device_by_id("titan_v").unwrap();
        let knls = KernelCollection::all()
            .generate_kernels(&[
                "flops_madd_pattern",
                "dtype:float32",
                "nelements:524288,1048576",
                "m:1024",
            ])
            .unwrap();
        let ids = vec!["f_op_float32_madd".to_string()];
        let cache = StatsCache::new();
        let time = gather_features_by_ids_cached_for(
            ids.clone(),
            &knls,
            &dev,
            &cache,
            Target::Time,
        )
        .unwrap();
        let energy = gather_features_by_ids_cached_for(
            ids,
            &knls,
            &dev,
            &cache,
            Target::Energy,
        )
        .unwrap();
        assert_eq!(time.target, Target::Time);
        assert_eq!(energy.target, Target::Energy);
        assert_eq!(time.rows, energy.rows, "inputs are target-independent");
        for (e, t) in energy.outputs.iter().zip(&time.outputs) {
            assert!(
                *e > dev.idle_watts * *t,
                "energy {e} !> idle floor {}",
                dev.idle_watts * *t
            );
        }
    }

    /// An axpy measurement kernel at size `n` (multiples of 256).
    fn axpy_gk(n: i64) -> GeneratedKernel {
        GeneratedKernel {
            kernel: crate::uipick::derived::build_axpy(crate::ir::DType::F32)
                .unwrap()
                .freeze(),
            generator: "test".into(),
            args: Default::default(),
            env: [("n".to_string(), n)].into_iter().collect(),
        }
    }

    /// An axpy variant poisoned with a statement reading an undeclared
    /// array: `stats::gather` rejects it at validation, which surfaces
    /// as a *hard* (non-skippable) per-kernel error naming `bad_{tag}`.
    fn poisoned_gk(tag: &str, n: i64) -> GeneratedKernel {
        use crate::ir::{Access, AffExpr, Expr, LhsRef, Stmt};
        let mut knl =
            crate::uipick::derived::build_axpy(crate::ir::DType::F32).unwrap();
        knl.name = format!("poisoned_{tag}");
        // build_axpy split `i` into i_out/i_in; reuse that order so the
        // *unknown array* check is what rejects this statement.
        knl.add_stmt(Stmt::new(
            &format!("bad_{tag}"),
            LhsRef::Array(Access::new("y", vec![AffExpr::var("i_in")])),
            Expr::load(Access::new("nope", vec![AffExpr::var("i_in")])),
            &["i_out", "i_in"],
        ));
        GeneratedKernel {
            kernel: knl.freeze(),
            generator: "test".into(),
            args: Default::default(),
            env: [("n".to_string(), n)].into_iter().collect(),
        }
    }

    /// Two injected hard failures (kernel indexes 1 and 3): the
    /// parallel sweep must surface exactly the sequential error — the
    /// one at the lowest failing kernel index — on every run,
    /// regardless of which worker hits which failure first.
    #[test]
    fn parallel_sweep_surfaces_lowest_index_error_deterministically() {
        let dev = device_by_id("titan_v").unwrap();
        let kernels = vec![
            axpy_gk(256),
            poisoned_gk("k1", 512),
            axpy_gk(768),
            poisoned_gk("k3", 1024),
            axpy_gk(1280),
        ];
        let ids = vec!["f_op_float32_madd".to_string()];
        let reference = gather_features_by_ids_sequential(
            ids.clone(),
            &kernels,
            &dev,
            &StatsCache::new(),
        )
        .unwrap_err();
        assert!(
            reference.contains("bad_k1"),
            "the sequential error names the first poisoned kernel: {reference}"
        );
        for round in 0..10 {
            let err = gather_features_by_ids_inner(
                ids.clone(),
                &kernels,
                &dev,
                &StatsCache::new(),
                4,
                Target::Time,
            )
            .unwrap_err();
            assert_eq!(
                err, reference,
                "round {round}: the lowest kernel index must win"
            );
        }
    }
}
