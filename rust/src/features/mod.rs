//! Perflex kernel features (paper Section 6.1).
//!
//! A *feature* maps (kernel, problem-size parameters) to a number.
//! Features are named by structured identifiers beginning with `f_`:
//!
//! ```text
//! f_op_float32_madd
//! f_mem_access_global_float32_load
//! f_mem_access_global_float32_lstrides:{0:1,1:>16}_afr:1
//! f_mem_access_tag:aLD
//! f_mem_transactions
//! f_mem_transactions_tag:aLD
//! f_bank_conflict_factor
//! f_sync_local_barrier_per_wg
//! f_sync_kernel_launch
//! f_thread_groups
//! f_cl_wall_time_titan_v
//! ```
//!
//! All fields after the `f_mem_access` prefix are optional filters; an
//! access contributes to the feature iff it matches every given filter
//! (the paper's property-based characterization), or is named directly
//! by its memory-access tag.
//!
//! The `f_mem_transactions[_tag:<t>]` and `f_bank_conflict_factor`
//! families weigh each access by its *pattern* — the coalescing-model
//! transaction count and the bank-conflict serialization factor of
//! [`crate::analysis::access`] — rather than its raw count.

use std::collections::BTreeMap;
use std::fmt;

use crate::analysis::access::{
    bank_conflict_multiplier, contiguous_txns, txns_for_stride, Geometry,
};
use crate::gpusim::{DEFAULT_CACHELINE_BYTES, DEFAULT_LOCAL_MEM_BANKS};
use crate::ir::{DType, MemScope};
use crate::polyhedral::{PolyPlan, QPoly};
use crate::stats::{Direction, KernelStats, MemAccessStat};
use crate::util::Rat;

/// A constraint on an integer quantity (stride or AFR).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Constraint {
    Eq(i64),
    Gt(i64),
    Lt(i64),
}

impl Constraint {
    pub fn matches(&self, v: f64) -> bool {
        match self {
            Constraint::Eq(c) => (v - *c as f64).abs() < 1e-9,
            Constraint::Gt(c) => v > *c as f64 + 1e-9,
            Constraint::Lt(c) => v < *c as f64 - 1e-9,
        }
    }

    fn parse(s: &str) -> Result<Constraint, String> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix('>') {
            rest.parse()
                .map(Constraint::Gt)
                .map_err(|_| format!("bad constraint '{s}'"))
        } else if let Some(rest) = s.strip_prefix('<') {
            rest.parse()
                .map(Constraint::Lt)
                .map_err(|_| format!("bad constraint '{s}'"))
        } else {
            s.parse()
                .map(Constraint::Eq)
                .map_err(|_| format!("bad constraint '{s}'"))
        }
    }
}

impl fmt::Display for Constraint {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Constraint::Eq(c) => write!(f, "{c}"),
            Constraint::Gt(c) => write!(f, ">{c}"),
            Constraint::Lt(c) => write!(f, "<{c}"),
        }
    }
}

/// Filter describing a family of memory accesses (§6.1.1's "memory
/// access pattern" characteristics).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MemAccessFilter {
    pub tag: Option<String>,
    pub scope: Option<MemScope>,
    pub dtype: Option<DType>,
    pub direction: Option<Direction>,
    pub lstrides: BTreeMap<u8, Constraint>,
    pub gstrides: BTreeMap<u8, Constraint>,
    pub afr: Option<Constraint>,
}

impl MemAccessFilter {
    pub fn matches(&self, m: &MemAccessStat, env: &BTreeMap<String, i128>) -> bool {
        if let Some(t) = &self.tag {
            if m.tag.as_deref() != Some(t.as_str()) {
                return false;
            }
        }
        if let Some(s) = self.scope {
            if m.scope != s {
                return false;
            }
        }
        if let Some(d) = self.dtype {
            if m.dtype != d {
                return false;
            }
        }
        if let Some(dir) = self.direction {
            if m.direction != dir {
                return false;
            }
        }
        for (axis, c) in &self.lstrides {
            if !c.matches(m.lstrides[*axis as usize].eval_f64(env)) {
                return false;
            }
        }
        for (axis, c) in &self.gstrides {
            if !c.matches(m.gstrides[*axis as usize].eval_f64(env)) {
                return false;
            }
        }
        if let Some(c) = &self.afr {
            if !c.matches(m.afr(env)) {
                return false;
            }
        }
        true
    }

    /// Decide membership without problem sizes where possible.
    ///
    /// `Some(b)` means [`MemAccessFilter::matches`] returns `b` for
    /// *every* env: the tag/scope/dtype/direction fields never depend
    /// on sizes, and a stride constraint against a constant stride
    /// polynomial evaluates the same everywhere.  `None` means the
    /// decision genuinely depends on the problem size (a constrained
    /// stride is parametric, or an AFR constraint is present) and must
    /// be re-checked per env.
    pub fn matches_static(&self, m: &MemAccessStat) -> Option<bool> {
        if let Some(t) = &self.tag {
            if m.tag.as_deref() != Some(t.as_str()) {
                return Some(false);
            }
        }
        if let Some(s) = self.scope {
            if m.scope != s {
                return Some(false);
            }
        }
        if let Some(d) = self.dtype {
            if m.dtype != d {
                return Some(false);
            }
        }
        if let Some(dir) = self.direction {
            if m.direction != dir {
                return Some(false);
            }
        }
        let mut decided = true;
        for (axis, c) in &self.lstrides {
            match m.lstrides[*axis as usize].as_constant() {
                Some(v) if !c.matches(v.to_f64()) => return Some(false),
                Some(_) => {}
                None => decided = false,
            }
        }
        for (axis, c) in &self.gstrides {
            match m.gstrides[*axis as usize].as_constant() {
                Some(v) if !c.matches(v.to_f64()) => return Some(false),
                Some(_) => {}
                None => decided = false,
            }
        }
        if self.afr.is_some() {
            decided = false;
        }
        if decided {
            Some(true)
        } else {
            None
        }
    }
}

/// One memory-access contribution of a bound feature.
#[derive(Clone, Debug)]
struct MemTerm {
    /// Index into `KernelStats::mem`.
    index: usize,
    /// Precomputed `count_at_granularity` polynomial for that access.
    count: QPoly,
    /// Whether the filter must be re-checked per problem size.
    needs_check: bool,
}

#[derive(Clone, Debug)]
enum BoundKind {
    Const(f64),
    Poly(QPoly),
    /// Two factors evaluated separately and multiplied as `f64`s,
    /// reproducing [`FeatureSpec::eval`]'s rounding exactly.
    PolyProduct(QPoly, QPoly),
    Mem {
        terms: Vec<MemTerm>,
        filter: MemAccessFilter,
    },
}

/// A [`FeatureSpec`] pre-bound to one kernel's [`KernelStats`]: the
/// spec parsing, access matching and per-access `count_at_granularity`
/// scaling are done once, leaving only cheap `QPoly` evaluations per
/// problem size.  This is the batched-evaluation engine behind
/// [`crate::calibrate::gather_features_by_ids_cached`]: a measurement
/// kernel reused across many sizes binds its features once.
///
/// Evaluation is bit-identical to [`FeatureSpec::eval`] on the same
/// statistics: per-access contributions are summed in the same order
/// with the same `f64` roundings, and accesses whose filter membership
/// depends on the problem size are re-checked per env.
#[derive(Clone, Debug)]
pub struct BoundFeature {
    kind: BoundKind,
}

impl BoundFeature {
    /// True when nothing remains to re-check per problem size (every
    /// access was statically classified).
    pub fn is_fully_batched(&self) -> bool {
        match &self.kind {
            BoundKind::Mem { terms, .. } => terms.iter().all(|t| !t.needs_check),
            _ => true,
        }
    }

    /// Evaluate at concrete sizes; `stats` must be the bundle this
    /// feature was bound against.
    pub fn eval(&self, stats: &KernelStats, env: &BTreeMap<String, i128>) -> f64 {
        match &self.kind {
            BoundKind::Const(c) => *c,
            BoundKind::Poly(p) => p.eval_f64(env),
            BoundKind::PolyProduct(a, b) => a.eval_f64(env) * b.eval_f64(env),
            BoundKind::Mem { terms, filter } => {
                let mut acc = 0.0;
                for t in terms {
                    if t.needs_check && !filter.matches(&stats.mem[t.index], env) {
                        continue;
                    }
                    acc += t.count.eval_f64(env);
                }
                acc
            }
        }
    }

    /// Lower to a [`CompiledFeature`] against a shared variable table
    /// (`slot` as in [`QPoly::lower`]).  `stats` must be the bundle
    /// this feature was bound against: per-env filter residues
    /// (parametric-stride and AFR constraints) are compiled from its
    /// access polynomials.  Constant-stride constraints were already
    /// decided at bind time and compile to nothing.
    pub fn lower(
        &self,
        stats: &KernelStats,
        slot: &mut impl FnMut(&str) -> u32,
    ) -> CompiledFeature {
        let kind = match &self.kind {
            BoundKind::Const(c) => CompiledKind::Const(*c),
            BoundKind::Poly(p) => CompiledKind::Poly(p.lower(slot)),
            BoundKind::PolyProduct(a, b) => {
                CompiledKind::PolyProduct(a.lower(slot), b.lower(slot))
            }
            BoundKind::Mem { terms, filter } => {
                let mut out = Vec::with_capacity(terms.len());
                for t in terms {
                    let mut checks = Vec::new();
                    if t.needs_check {
                        let m = &stats.mem[t.index];
                        for (axis, c) in &filter.lstrides {
                            let p = &m.lstrides[*axis as usize];
                            if p.as_constant().is_none() {
                                checks.push(CompiledCheck::Stride {
                                    plan: p.lower(slot),
                                    c: *c,
                                });
                            }
                        }
                        for (axis, c) in &filter.gstrides {
                            let p = &m.gstrides[*axis as usize];
                            if p.as_constant().is_none() {
                                checks.push(CompiledCheck::Stride {
                                    plan: p.lower(slot),
                                    c: *c,
                                });
                            }
                        }
                        if let Some(c) = &filter.afr {
                            checks.push(CompiledCheck::Afr {
                                count_wi: m.count_wi.lower(slot),
                                footprint: m.footprint.lower(slot),
                                c: *c,
                            });
                        }
                    }
                    out.push(CompiledMemTerm {
                        count: t.count.lower(slot),
                        checks,
                    });
                }
                CompiledKind::Mem(out)
            }
        };
        CompiledFeature { kind }
    }
}

/// One membership re-check a compiled mem term must pass per
/// environment — the residue of a [`MemAccessFilter`] after everything
/// statically decidable (tag/scope/dtype/direction, constant-stride
/// constraints) has been folded away at bind time.
#[derive(Clone, Debug)]
enum CompiledCheck {
    /// A constrained stride whose polynomial is parametric.
    Stride { plan: PolyPlan, c: Constraint },
    /// An access-to-footprint-ratio constraint, replicating
    /// [`MemAccessStat::afr`]'s clamp-and-divide exactly.
    Afr {
        count_wi: PolyPlan,
        footprint: PolyPlan,
        c: Constraint,
    },
}

impl CompiledCheck {
    fn passes(&self, vals: &[f64]) -> bool {
        match self {
            CompiledCheck::Stride { plan, c } => c.matches(plan.eval(vals)),
            CompiledCheck::Afr {
                count_wi,
                footprint,
                c,
            } => {
                let count = count_wi.eval(vals);
                let fp = footprint.eval(vals).min(count);
                let afr = if fp == 0.0 { 0.0 } else { count / fp };
                c.matches(afr)
            }
        }
    }
}

/// One memory-access contribution of a [`CompiledFeature`]: the
/// access's `count_at_granularity` polynomial lowered to a flat plan,
/// plus whatever filter residue must still pass per environment.
#[derive(Clone, Debug)]
struct CompiledMemTerm {
    count: PolyPlan,
    checks: Vec<CompiledCheck>,
}

#[derive(Clone, Debug)]
enum CompiledKind {
    Const(f64),
    Poly(PolyPlan),
    PolyProduct(PolyPlan, PolyPlan),
    Mem(Vec<CompiledMemTerm>),
}

/// A [`BoundFeature`] lowered all the way to flat f64 plans: no
/// `KernelStats` access, no string maps, no rational arithmetic at
/// evaluation time — just [`PolyPlan::eval`] over a dense value slice.
/// This is the per-feature building block of
/// [`crate::model::compiled::CompiledModel`]; see there for the
/// end-to-end accuracy guarantee versus the exact path.
///
/// Structure is preserved from the bound feature: mem terms are summed
/// in the same `KernelStats::mem` order with the same per-term checks
/// (constraint epsilons included), so the only divergence from
/// [`BoundFeature::eval`] is f64 rounding inside the plans.
#[derive(Clone, Debug)]
pub struct CompiledFeature {
    kind: CompiledKind,
}

impl CompiledFeature {
    /// Evaluate over `vals`, indexed by the slot table shared with the
    /// other features of the same compiled model.
    pub fn eval(&self, vals: &[f64]) -> f64 {
        match &self.kind {
            CompiledKind::Const(c) => *c,
            CompiledKind::Poly(p) => p.eval(vals),
            CompiledKind::PolyProduct(a, b) => a.eval(vals) * b.eval(vals),
            CompiledKind::Mem(terms) => {
                let mut acc = 0.0;
                for t in terms {
                    if t.checks.iter().all(|c| c.passes(vals)) {
                        acc += t.count.eval(vals);
                    }
                }
                acc
            }
        }
    }
}

/// A parsed feature identifier.
#[derive(Clone, Debug, PartialEq)]
pub enum FeatureSpec {
    /// `f_op_<dtype>_<op>` — arithmetic count, sub-group granularity.
    Op { dtype: DType, op: String },
    /// `f_mem_access_...` — classified memory access count.
    MemAccess(MemAccessFilter),
    /// `f_mem_transactions[_tag:<t>]` — global-memory transactions
    /// under the coalescing model of [`crate::analysis::access`],
    /// optionally restricted to one memory-access tag.
    MemTransactions { tag: Option<String> },
    /// `f_bank_conflict_factor` — excess bank-serialized local-memory
    /// accesses (zero for conflict-free kernels).
    BankConflictFactor,
    /// `f_sync_local_barrier_per_wg` — per-work-item barriers × groups.
    SyncBarrierPerWg,
    /// `f_sync_kernel_launch` — constant 1 per launch.
    SyncKernelLaunch,
    /// `f_thread_groups` — total work-group count.
    ThreadGroups,
    /// `f_cl_wall_time_<device>` — measured output feature.
    WallTime { device: String },
}

impl FeatureSpec {
    /// Parse a feature identifier (with its `f_` prefix).
    pub fn parse(id: &str) -> Result<FeatureSpec, String> {
        let body = id
            .strip_prefix("f_")
            .ok_or_else(|| format!("feature id must start with f_: '{id}'"))?;
        if let Some(rest) = body.strip_prefix("op_") {
            let (dts, op) = rest
                .rsplit_once('_')
                .ok_or_else(|| format!("bad op feature '{id}'"))?;
            let dtype = DType::parse(dts).ok_or_else(|| format!("bad dtype in '{id}'"))?;
            if !matches!(op, "add" | "sub" | "mul" | "div" | "madd") {
                return Err(format!("bad op '{op}' in '{id}'"));
            }
            return Ok(FeatureSpec::Op {
                dtype,
                op: op.to_string(),
            });
        }
        if let Some(rest) = body.strip_prefix("mem_transactions") {
            if rest.is_empty() {
                return Ok(FeatureSpec::MemTransactions { tag: None });
            }
            if let Some(t) = rest.strip_prefix("_tag:") {
                if !t.is_empty() {
                    return Ok(FeatureSpec::MemTransactions {
                        tag: Some(t.to_string()),
                    });
                }
            }
            return Err(format!(
                "bad mem_transactions feature '{id}' (expected \
                 f_mem_transactions or f_mem_transactions_tag:<t>)"
            ));
        }
        if let Some(rest) = body.strip_prefix("mem_access") {
            return Ok(FeatureSpec::MemAccess(parse_mem_filter(rest)?));
        }
        match body {
            "bank_conflict_factor" => Ok(FeatureSpec::BankConflictFactor),
            "sync_local_barrier_per_wg" => Ok(FeatureSpec::SyncBarrierPerWg),
            "sync_kernel_launch" => Ok(FeatureSpec::SyncKernelLaunch),
            "thread_groups" => Ok(FeatureSpec::ThreadGroups),
            _ => {
                if let Some(dev) = body.strip_prefix("cl_wall_time_") {
                    Ok(FeatureSpec::WallTime {
                        device: dev.to_string(),
                    })
                } else {
                    Err(format!(
                        "unknown feature '{id}'; valid families: \
                         f_op_<dtype>_<op>, f_mem_access[_<filters>], \
                         f_mem_transactions[_tag:<t>], \
                         f_bank_conflict_factor, \
                         f_sync_local_barrier_per_wg, \
                         f_sync_kernel_launch, f_thread_groups, \
                         f_cl_wall_time_<device>"
                    ))
                }
            }
        }
    }

    /// Evaluate against gathered statistics at concrete sizes.
    /// `WallTime` cannot be computed from statistics (it is measured);
    /// evaluating it here is an error.
    pub fn eval(
        &self,
        stats: &KernelStats,
        env: &BTreeMap<String, i128>,
    ) -> Result<f64, String> {
        let sg = stats.sub_group_size;
        match self {
            FeatureSpec::Op { dtype, op } => {
                Ok(stats.op_count(*dtype, op).eval_f64(env))
            }
            FeatureSpec::MemAccess(f) => Ok(stats
                .mem
                .iter()
                .filter(|m| f.matches(m, env))
                .map(|m| m.count_at_granularity(sg).eval_f64(env))
                .sum()),
            FeatureSpec::MemTransactions { tag } => {
                Ok(mem_transactions_poly(stats, tag.as_deref()).eval_f64(env))
            }
            FeatureSpec::BankConflictFactor => {
                Ok(bank_conflict_poly(stats).eval_f64(env))
            }
            FeatureSpec::SyncBarrierPerWg => {
                Ok(stats.barriers_per_wi.eval_f64(env) * stats.num_groups.eval_f64(env))
            }
            FeatureSpec::SyncKernelLaunch => Ok(1.0),
            FeatureSpec::ThreadGroups => Ok(stats.num_groups.eval_f64(env)),
            FeatureSpec::WallTime { device } => Err(format!(
                "f_cl_wall_time_{device} is an output feature; measure it on a device"
            )),
        }
    }

    pub fn is_wall_time(&self) -> bool {
        matches!(self, FeatureSpec::WallTime { .. })
    }

    /// Bind this feature to one kernel's statistics, hoisting all the
    /// size-independent work (access matching, count scaling, op
    /// summation) out of the per-problem-size loop.  See
    /// [`BoundFeature`] for the equivalence guarantee.
    pub fn bind(&self, stats: &KernelStats) -> Result<BoundFeature, String> {
        let kind = match self {
            FeatureSpec::Op { dtype, op } => {
                BoundKind::Poly(stats.op_count(*dtype, op))
            }
            FeatureSpec::MemAccess(f) => {
                let sg = stats.sub_group_size;
                let mut terms = Vec::new();
                for (index, m) in stats.mem.iter().enumerate() {
                    match f.matches_static(m) {
                        Some(false) => {}
                        Some(true) => terms.push(MemTerm {
                            index,
                            count: m.count_at_granularity(sg),
                            needs_check: false,
                        }),
                        None => terms.push(MemTerm {
                            index,
                            count: m.count_at_granularity(sg),
                            needs_check: true,
                        }),
                    }
                }
                BoundKind::Mem {
                    terms,
                    filter: f.clone(),
                }
            }
            FeatureSpec::MemTransactions { tag } => {
                BoundKind::Poly(mem_transactions_poly(stats, tag.as_deref()))
            }
            FeatureSpec::BankConflictFactor => {
                BoundKind::Poly(bank_conflict_poly(stats))
            }
            FeatureSpec::SyncBarrierPerWg => BoundKind::PolyProduct(
                stats.barriers_per_wi.clone(),
                stats.num_groups.clone(),
            ),
            FeatureSpec::SyncKernelLaunch => BoundKind::Const(1.0),
            FeatureSpec::ThreadGroups => BoundKind::Poly(stats.num_groups.clone()),
            FeatureSpec::WallTime { device } => {
                return Err(format!(
                    "f_cl_wall_time_{device} is an output feature; measure it on a device"
                ))
            }
        };
        Ok(BoundFeature { kind })
    }
}

fn parse_mem_filter(rest: &str) -> Result<MemAccessFilter, String> {
    let mut f = MemAccessFilter::default();
    // Split on '_' but keep {...} groups intact.
    let mut parts: Vec<String> = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for ch in rest.trim_start_matches('_').chars() {
        match ch {
            '{' => {
                depth += 1;
                cur.push(ch);
            }
            '}' => {
                depth -= 1;
                cur.push(ch);
            }
            '_' if depth == 0 => {
                parts.push(std::mem::take(&mut cur));
            }
            _ => cur.push(ch),
        }
    }
    if !cur.is_empty() {
        parts.push(cur);
    }
    // Memory-access tags may contain underscores (e.g. `dg_plain_u`):
    // after `tag:`, greedily absorb parts until a recognized keyword.
    let is_keyword = |p: &str| -> bool {
        matches!(p, "global" | "local" | "load" | "store")
            || DType::parse(p).is_some()
            || p.starts_with("lstrides:")
            || p.starts_with("gstrides:")
            || p.starts_with("afr:")
    };
    let mut merged: Vec<String> = Vec::new();
    let mut in_tag = false;
    for part in parts.into_iter().filter(|p| !p.is_empty()) {
        if part.starts_with("tag:") {
            in_tag = true;
            merged.push(part);
        } else if in_tag && !is_keyword(&part) {
            let last = merged.last_mut().unwrap();
            last.push('_');
            last.push_str(&part);
        } else {
            in_tag = false;
            merged.push(part);
        }
    }
    for part in merged.iter() {
        if let Some(t) = part.strip_prefix("tag:") {
            f.tag = Some(t.to_string());
        } else if part == "global" {
            f.scope = Some(MemScope::Global);
        } else if part == "local" {
            f.scope = Some(MemScope::Local);
        } else if part == "load" {
            f.direction = Some(Direction::Load);
        } else if part == "store" {
            f.direction = Some(Direction::Store);
        } else if let Some(dt) = DType::parse(part) {
            f.dtype = Some(dt);
        } else if let Some(body) = part.strip_prefix("lstrides:") {
            f.lstrides = parse_stride_map(body)?;
        } else if let Some(body) = part.strip_prefix("gstrides:") {
            f.gstrides = parse_stride_map(body)?;
        } else if let Some(body) = part.strip_prefix("afr:") {
            f.afr = Some(Constraint::parse(body)?);
        } else {
            return Err(format!("bad mem_access field '{part}'"));
        }
    }
    Ok(f)
}

fn parse_stride_map(body: &str) -> Result<BTreeMap<u8, Constraint>, String> {
    let inner = body
        .strip_prefix('{')
        .and_then(|s| s.strip_suffix('}'))
        .ok_or_else(|| format!("expected {{...}} in '{body}'"))?;
    let mut out = BTreeMap::new();
    for pair in inner.split(',') {
        let pair = pair.trim();
        if pair.is_empty() {
            continue;
        }
        let (axis, c) = pair
            .split_once(':')
            .ok_or_else(|| format!("expected axis:constraint in '{pair}'"))?;
        let axis: u8 = axis
            .trim()
            .parse()
            .map_err(|_| format!("bad axis '{axis}'"))?;
        out.insert(axis, Constraint::parse(c)?);
    }
    Ok(out)
}

/// Device-independent access-pattern geometry at this statistics
/// bundle's sub-group size (128-byte lines, 32 banks).  Features stay
/// device-independent — they are gathered once per kernel and reused
/// across every device — so the per-device refinement lives in the
/// analysis feasibility pass, not here.
fn feature_geometry(stats: &KernelStats) -> Geometry {
    Geometry {
        sub_group: stats.sub_group_size,
        cacheline_bytes: DEFAULT_CACHELINE_BYTES,
        local_mem_banks: DEFAULT_LOCAL_MEM_BANKS,
    }
}

/// The `f_mem_transactions` polynomial: for every global access
/// (optionally restricted to one tag), `count_wi · txns / sg` — the
/// total memory transactions the kernel issues under the coalescing
/// model of [`crate::analysis::access`].  Constant lid(0) strides get
/// their exact transaction factor; parametric strides are charged the
/// one-line-per-lane worst case so the feature stays polynomial in the
/// problem sizes.  Shared by [`FeatureSpec::eval`] and
/// [`FeatureSpec::bind`] so the two paths agree bit for bit.
fn mem_transactions_poly(stats: &KernelStats, tag: Option<&str>) -> QPoly {
    let geom = feature_geometry(stats);
    let sg = geom.sub_group as i128;
    let mut acc = QPoly::zero();
    for m in &stats.mem {
        if m.scope != MemScope::Global {
            continue;
        }
        if let Some(t) = tag {
            if m.tag.as_deref() != Some(t) {
                continue;
            }
        }
        let elem = m.dtype.size_bytes();
        let txns =
            match m.lstrides[0].as_constant().and_then(|r| r.as_integer()) {
                Some(s) => txns_for_stride(s, elem, &geom),
                None => geom.sub_group.max(contiguous_txns(elem, &geom)),
            };
        acc = &acc + &m.count_wi.scale(Rat::new(txns as i128, sg));
    }
    acc
}

/// The `f_bank_conflict_factor` polynomial: for every local access
/// whose lid(0) stride serializes `m`-way across the banks, the
/// *excess* serialized accesses `count_wi · (m − 1) / sg`.
/// Conflict-free kernels contribute exactly zero.  Shared by
/// [`FeatureSpec::eval`] and [`FeatureSpec::bind`].
fn bank_conflict_poly(stats: &KernelStats) -> QPoly {
    let geom = feature_geometry(stats);
    let sg = geom.sub_group as i128;
    let mut acc = QPoly::zero();
    for m in &stats.mem {
        if m.scope != MemScope::Local {
            continue;
        }
        let mult =
            match m.lstrides[0].as_constant().and_then(|r| r.as_integer()) {
                Some(s) => bank_conflict_multiplier(s, &geom),
                None => geom.local_mem_banks,
            };
        if mult > 1 {
            acc = &acc + &m.count_wi.scale(Rat::new(mult as i128 - 1, sg));
        }
    }
    acc
}

impl fmt::Display for FeatureSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FeatureSpec::Op { dtype, op } => write!(f, "f_op_{dtype}_{op}"),
            FeatureSpec::MemAccess(m) => {
                write!(f, "f_mem_access")?;
                if let Some(t) = &m.tag {
                    write!(f, "_tag:{t}")?;
                }
                if let Some(s) = m.scope {
                    write!(
                        f,
                        "_{}",
                        match s {
                            MemScope::Global => "global",
                            MemScope::Local => "local",
                            MemScope::Private => "private",
                        }
                    )?;
                }
                if let Some(d) = m.dtype {
                    write!(f, "_{d}")?;
                }
                if let Some(d) = m.direction {
                    write!(f, "_{}", d.feature_name())?;
                }
                let write_map = |f: &mut fmt::Formatter<'_>,
                                 name: &str,
                                 m: &BTreeMap<u8, Constraint>|
                 -> fmt::Result {
                    if !m.is_empty() {
                        write!(f, "_{name}:{{")?;
                        for (i, (k, v)) in m.iter().enumerate() {
                            if i > 0 {
                                write!(f, ",")?;
                            }
                            write!(f, "{k}:{v}")?;
                        }
                        write!(f, "}}")?;
                    }
                    Ok(())
                };
                write_map(f, "lstrides", &m.lstrides)?;
                write_map(f, "gstrides", &m.gstrides)?;
                if let Some(a) = &m.afr {
                    write!(f, "_afr:{a}")?;
                }
                Ok(())
            }
            FeatureSpec::MemTransactions { tag } => {
                write!(f, "f_mem_transactions")?;
                if let Some(t) = tag {
                    write!(f, "_tag:{t}")?;
                }
                Ok(())
            }
            FeatureSpec::BankConflictFactor => {
                write!(f, "f_bank_conflict_factor")
            }
            FeatureSpec::SyncBarrierPerWg => write!(f, "f_sync_local_barrier_per_wg"),
            FeatureSpec::SyncKernelLaunch => write!(f, "f_sync_kernel_launch"),
            FeatureSpec::ThreadGroups => write!(f, "f_thread_groups"),
            FeatureSpec::WallTime { device } => write!(f, "f_cl_wall_time_{device}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_op_feature() {
        let f = FeatureSpec::parse("f_op_float32_madd").unwrap();
        assert_eq!(
            f,
            FeatureSpec::Op {
                dtype: DType::F32,
                op: "madd".into()
            }
        );
        assert_eq!(f.to_string(), "f_op_float32_madd");
        assert!(FeatureSpec::parse("f_op_float32_xor").is_err());
    }

    #[test]
    fn parse_mem_access_with_strides_and_afr() {
        let id = "f_mem_access_global_float32_load_lstrides:{0:1,1:>16}_afr:1";
        let f = FeatureSpec::parse(id).unwrap();
        match &f {
            FeatureSpec::MemAccess(m) => {
                assert_eq!(m.scope, Some(MemScope::Global));
                assert_eq!(m.dtype, Some(DType::F32));
                assert_eq!(m.direction, Some(Direction::Load));
                assert_eq!(m.lstrides.get(&0), Some(&Constraint::Eq(1)));
                assert_eq!(m.lstrides.get(&1), Some(&Constraint::Gt(16)));
                assert_eq!(m.afr, Some(Constraint::Eq(1)));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(f.to_string(), id);
    }

    #[test]
    fn parse_tagged_access() {
        let f = FeatureSpec::parse("f_mem_access_tag:aLD").unwrap();
        match &f {
            FeatureSpec::MemAccess(m) => assert_eq!(m.tag.as_deref(), Some("aLD")),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_tag_with_underscores() {
        let f = FeatureSpec::parse("f_mem_access_tag:dg_u_prefetch_u").unwrap();
        match &f {
            FeatureSpec::MemAccess(m) => {
                assert_eq!(m.tag.as_deref(), Some("dg_u_prefetch_u"))
            }
            other => panic!("{other:?}"),
        }
        // Tag followed by keyword fields still parses.
        let f =
            FeatureSpec::parse("f_mem_access_tag:mm_pf_a_global_float32_load")
                .unwrap();
        match &f {
            FeatureSpec::MemAccess(m) => {
                assert_eq!(m.tag.as_deref(), Some("mm_pf_a"));
                assert_eq!(m.scope, Some(MemScope::Global));
                assert_eq!(m.direction, Some(Direction::Load));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_access_pattern_features() {
        let f = FeatureSpec::parse("f_mem_transactions").unwrap();
        assert_eq!(f, FeatureSpec::MemTransactions { tag: None });
        assert_eq!(f.to_string(), "f_mem_transactions");
        let f = FeatureSpec::parse("f_mem_transactions_tag:mm_pf_a").unwrap();
        assert_eq!(
            f,
            FeatureSpec::MemTransactions {
                tag: Some("mm_pf_a".into())
            }
        );
        assert_eq!(f.to_string(), "f_mem_transactions_tag:mm_pf_a");
        assert_eq!(
            FeatureSpec::parse("f_bank_conflict_factor").unwrap(),
            FeatureSpec::BankConflictFactor
        );
        assert!(FeatureSpec::parse("f_mem_transactions_tag:").is_err());
        assert!(FeatureSpec::parse("f_mem_transactions_bogus").is_err());
    }

    #[test]
    fn unknown_family_error_lists_valid_families() {
        let e = FeatureSpec::parse("f_mm_transactions").unwrap_err();
        assert!(e.contains("unknown feature"), "{e}");
        for fam in [
            "f_op_<dtype>_<op>",
            "f_mem_access",
            "f_mem_transactions",
            "f_bank_conflict_factor",
            "f_sync_local_barrier_per_wg",
            "f_sync_kernel_launch",
            "f_thread_groups",
            "f_cl_wall_time_<device>",
        ] {
            assert!(e.contains(fam), "missing {fam} in: {e}");
        }
    }

    /// 16x16 work-group; one global f32 store with lid(0) stride
    /// `gstride` and one local f32 store with lid(0) stride `lstride`
    /// (both injective, so no analyzer noise).
    fn pattern_kernel(gstride: i64, lstride: i64) -> crate::ir::Kernel {
        use crate::ir::{
            Access, AffExpr, ArrayDecl, Expr, IndexTag, Kernel, LhsRef, Stmt,
        };
        use crate::polyhedral::{LoopExtent, NestedDomain};
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("li1", QPoly::int(16)),
            LoopExtent::zero_to("li0", QPoly::int(16)),
        ]);
        let mut k = Kernel::new("pattern_case", &[], dom);
        k.iname_tags.insert("li1".into(), IndexTag::Local(1));
        k.iname_tags.insert("li0".into(), IndexTag::Local(0));
        k.add_array(ArrayDecl::global(
            "out",
            DType::F32,
            vec![QPoly::int(16 * gstride.max(1) as i128 * 16)],
        ));
        k.add_array(ArrayDecl::local(
            "tile",
            DType::F32,
            vec![QPoly::int(16 * lstride.max(1) as i128 * 16)],
        ));
        k.add_stmt(Stmt::new(
            "gst",
            LhsRef::Array(Access::tagged(
                "out",
                "pat_out",
                vec![AffExpr::scaled_var("li0", gstride)
                    .plus(&AffExpr::scaled_var("li1", 16 * gstride))],
            )),
            Expr::fconst(1.0),
            &["li1", "li0"],
        ));
        k.add_stmt(Stmt::new(
            "lst",
            LhsRef::Array(Access::new(
                "tile",
                vec![AffExpr::scaled_var("li0", lstride)
                    .plus(&AffExpr::scaled_var("li1", 16 * lstride))],
            )),
            Expr::fconst(1.0),
            &["li1", "li0"],
        ));
        k
    }

    #[test]
    fn mem_transactions_weighs_strided_accesses() {
        // 256 work-items, one global store each.  Stride 1: 256/32 = 8
        // transactions.  Stride 4: 4 lines per sub-group access, 32.
        let env: BTreeMap<String, i128> = BTreeMap::new();
        let spec = FeatureSpec::parse("f_mem_transactions").unwrap();
        let stats = crate::stats::gather(&pattern_kernel(1, 1), 32).unwrap();
        assert_eq!(spec.eval(&stats, &env).unwrap(), 8.0);
        let stats = crate::stats::gather(&pattern_kernel(4, 1), 32).unwrap();
        assert_eq!(spec.eval(&stats, &env).unwrap(), 32.0);
        // Tag filtering: the only global access carries tag pat_out.
        let tagged =
            FeatureSpec::parse("f_mem_transactions_tag:pat_out").unwrap();
        assert_eq!(tagged.eval(&stats, &env).unwrap(), 32.0);
        let other =
            FeatureSpec::parse("f_mem_transactions_tag:nope").unwrap();
        assert_eq!(other.eval(&stats, &env).unwrap(), 0.0);
    }

    #[test]
    fn bank_conflict_factor_counts_excess_serialization() {
        // Stride-1 local store: conflict-free, exactly zero.  Stride
        // 16 over 32 banks: 16-way serialization, 256·15/32 = 120
        // excess accesses.
        let env: BTreeMap<String, i128> = BTreeMap::new();
        let spec = FeatureSpec::parse("f_bank_conflict_factor").unwrap();
        let stats = crate::stats::gather(&pattern_kernel(1, 1), 32).unwrap();
        assert_eq!(spec.eval(&stats, &env).unwrap(), 0.0);
        let stats = crate::stats::gather(&pattern_kernel(1, 16), 32).unwrap();
        assert_eq!(spec.eval(&stats, &env).unwrap(), 120.0);
    }

    #[test]
    fn access_pattern_features_are_zero_penalty_on_clean_apps() {
        // The shipped matmul variants are coalesced and conflict-free:
        // the bank factor must be exactly zero and the transaction
        // count must equal the per-sub-group global access count.
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let stats = crate::stats::gather(&k, 32).unwrap();
        let env: BTreeMap<String, i128> =
            [("n".to_string(), 1024i128)].into_iter().collect();
        let bank = FeatureSpec::parse("f_bank_conflict_factor").unwrap();
        assert_eq!(bank.eval(&stats, &env).unwrap(), 0.0);
        let txn = FeatureSpec::parse("f_mem_transactions").unwrap();
        let expect: f64 = stats
            .mem
            .iter()
            .filter(|m| m.scope == MemScope::Global)
            .map(|m| m.count_wi.eval_f64(&env) / 32.0)
            .sum();
        let got = txn.eval(&stats, &env).unwrap();
        assert!(got > 0.0);
        assert!((got - expect).abs() <= expect * 1e-9, "{got} vs {expect}");
    }

    #[test]
    fn parse_sync_and_misc() {
        assert_eq!(
            FeatureSpec::parse("f_sync_local_barrier_per_wg").unwrap(),
            FeatureSpec::SyncBarrierPerWg
        );
        assert_eq!(
            FeatureSpec::parse("f_thread_groups").unwrap(),
            FeatureSpec::ThreadGroups
        );
        match FeatureSpec::parse("f_cl_wall_time_titan_v").unwrap() {
            FeatureSpec::WallTime { device } => assert_eq!(device, "titan_v"),
            other => panic!("{other:?}"),
        }
        assert!(FeatureSpec::parse("g_bogus").is_err());
    }

    #[test]
    fn constraint_semantics() {
        assert!(Constraint::Eq(16).matches(16.0));
        assert!(!Constraint::Eq(16).matches(17.0));
        assert!(Constraint::Gt(16).matches(17.0));
        assert!(!Constraint::Gt(16).matches(16.0));
        assert!(Constraint::Lt(4).matches(3.0));
    }

    #[test]
    fn bound_features_match_direct_eval_bit_for_bit() {
        // Bind every style of feature against a real app kernel and
        // check the batched path reproduces FeatureSpec::eval exactly
        // across problem sizes (including parametric-stride filters
        // that need per-env re-checks).
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let stats = crate::stats::gather(&k, 32).unwrap();
        let ids = [
            "f_op_float32_madd",
            "f_mem_access_tag:mm_pf_a",
            "f_mem_access_global_float32_store",
            "f_mem_access_local_float32",
            "f_mem_access_local_float32_lstrides:{0:<2}",
            "f_mem_access_global_float32_load_lstrides:{1:>16}",
            "f_mem_transactions",
            "f_mem_transactions_tag:mm_pf_a",
            "f_bank_conflict_factor",
            "f_sync_local_barrier_per_wg",
            "f_sync_kernel_launch",
            "f_thread_groups",
        ];
        for id in ids {
            let spec = FeatureSpec::parse(id).unwrap();
            let bound = spec.bind(&stats).unwrap();
            for n in [1024i128, 2048, 3584] {
                let env: BTreeMap<String, i128> =
                    [("n".to_string(), n)].into_iter().collect();
                let direct = spec.eval(&stats, &env).unwrap();
                let batched = bound.eval(&stats, &env);
                assert_eq!(direct, batched, "{id} at n={n}");
            }
        }
        // Constant-stride filters fold completely at bind time.
        let b = FeatureSpec::parse("f_mem_access_local_float32_lstrides:{0:<2}")
            .unwrap()
            .bind(&stats)
            .unwrap();
        assert!(b.is_fully_batched());
        // Wall time cannot be bound.
        assert!(FeatureSpec::parse("f_cl_wall_time_titan_v")
            .unwrap()
            .bind(&stats)
            .is_err());
    }

    #[test]
    fn matches_static_agrees_with_matches() {
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let stats = crate::stats::gather(&k, 32).unwrap();
        let specs = [
            "f_mem_access_tag:mm_pf_b",
            "f_mem_access_global_float32_load",
            "f_mem_access_global_float32_load_lstrides:{0:1}",
            "f_mem_access_global_float32_load_gstrides:{1:>0}",
            "f_mem_access_global_float32_load_afr:>1",
        ];
        let env: BTreeMap<String, i128> =
            [("n".to_string(), 2048i128)].into_iter().collect();
        for id in specs {
            let f = match FeatureSpec::parse(id).unwrap() {
                FeatureSpec::MemAccess(f) => f,
                other => panic!("{other:?}"),
            };
            for m in &stats.mem {
                if let Some(decided) = f.matches_static(m) {
                    assert_eq!(
                        decided,
                        f.matches(m, &env),
                        "{id} static decision wrong for {:?}/{:?}",
                        m.array,
                        m.tag
                    );
                }
            }
        }
    }

    #[test]
    fn roundtrip_display_parse() {
        for id in [
            "f_op_float64_div",
            "f_mem_access_global_float32_store",
            "f_mem_access_local_float32",
            "f_mem_access_tag:bLD",
            "f_mem_access_global_float32_load_lstrides:{0:1}_gstrides:{0:>0,1:0}_afr:>1",
            "f_mem_transactions",
            "f_mem_transactions_tag:dg_u_prefetch_u",
            "f_bank_conflict_factor",
            "f_sync_kernel_launch",
            "f_cl_wall_time_amd_r9_fury",
        ] {
            let f = FeatureSpec::parse(id).unwrap();
            assert_eq!(f.to_string(), id, "roundtrip of {id}");
            assert_eq!(FeatureSpec::parse(&f.to_string()).unwrap(), f);
        }
    }
}
