//! UiPiCK — the parameterized collection of measurement-kernel
//! generators (paper Section 7.1).
//!
//! Each [`Generator`] owns a set of *generator filter tags*, a set of
//! per-argument allowable values, and a build function.  Users select
//! generators with generator filter tags under one of four
//! [`MatchCondition`]s, restrict argument domains with
//! `argument:value[,value...]` variant filter tags, and receive one
//! kernel per element of the Cartesian product of the remaining
//! allowable values — exactly the paper's §7.1 interface:
//!
//! ```no_run
//! use perflex::uipick::{KernelCollection, MatchCondition};
//! let knls = KernelCollection::all()
//!     .generate_kernels(&[
//!         "matmul_sq", "dtype:float32", "prefetch:True",
//!         "lsize_0:16", "lsize_1:16", "groups_fit:True",
//!         "n:2048,2560",
//!     ])
//!     .unwrap();
//! assert_eq!(knls.len(), 2); // one per n
//! # let _ = MatchCondition::Superset;
//! ```

pub mod apps;
pub mod derived;
pub mod micro;

use std::collections::{BTreeMap, BTreeSet};

use crate::ir::FrozenKernel;

/// Build-function argument set: `argument -> chosen value`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VariantArgs {
    pub map: BTreeMap<String, String>,
}

impl VariantArgs {
    pub fn get(&self, key: &str) -> Result<&str, String> {
        self.map
            .get(key)
            .map(|s| s.as_str())
            .ok_or_else(|| format!("missing argument '{key}'"))
    }

    pub fn get_i64(&self, key: &str) -> Result<i64, String> {
        self.get(key)?
            .parse()
            .map_err(|_| format!("argument '{key}' is not an integer"))
    }

    pub fn get_bool(&self, key: &str) -> Result<bool, String> {
        match self.get(key)? {
            "True" | "true" | "1" => Ok(true),
            "False" | "false" | "0" => Ok(false),
            other => Err(format!("argument '{key}'={other} is not a boolean")),
        }
    }
}

/// A kernel produced by a generator, with the concrete problem sizes
/// it should be measured/evaluated at.
///
/// The kernel is [frozen](crate::ir::Kernel::freeze) at generation
/// time: its structural fingerprint is minted exactly once, and every
/// downstream cache lookup (measurement, feature gathering,
/// prediction, the persistent artifact store) reuses it instead of
/// re-rendering the IR.  `FrozenKernel` derefs to
/// [`Kernel`](crate::ir::Kernel), so read access is unchanged.
#[derive(Clone, Debug)]
pub struct GeneratedKernel {
    pub kernel: FrozenKernel,
    pub generator: String,
    pub args: VariantArgs,
    /// Values for the kernel's size parameters.
    pub env: BTreeMap<String, i64>,
}

/// A kernel creation function with its tag/argument metadata.
pub struct Generator {
    pub name: &'static str,
    /// Generator filter tags (single-value).
    pub tags: &'static [&'static str],
    /// Allowable values per argument (the Cartesian-product domains).
    pub arg_domains: Vec<(&'static str, Vec<String>)>,
    /// Build one variant.
    pub build: fn(&VariantArgs) -> Result<GeneratedKernel, String>,
}

impl Generator {
    fn tag_set(&self) -> BTreeSet<&str> {
        self.tags.iter().copied().collect()
    }
}

/// The paper's four generator match conditions (§7.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MatchCondition {
    /// Generator's tag set identical to the user tags.
    Identical,
    /// Generator's tag set ⊆ user tags.
    Subset,
    /// Generator's tag set ⊇ user tags (default).
    Superset,
    /// Non-empty intersection.
    Intersect,
}

/// A collection of generators with the tag-driven filtering interface.
pub struct KernelCollection {
    pub generators: Vec<Generator>,
}

impl KernelCollection {
    /// All built-in generators (`uipick.ALL_GENERATORS`).
    pub fn all() -> KernelCollection {
        let mut generators = Vec::new();
        generators.extend(apps::generators());
        generators.extend(micro::generators());
        generators.extend(derived::generators());
        KernelCollection { generators }
    }

    pub fn generator_names(&self) -> Vec<&'static str> {
        self.generators.iter().map(|g| g.name).collect()
    }

    /// Default match condition (3): superset.
    pub fn generate_kernels(
        &self,
        filter_tags: &[&str],
    ) -> Result<Vec<GeneratedKernel>, String> {
        self.generate_kernels_cond(filter_tags, MatchCondition::Superset)
    }

    /// Split user tags into generator tags (no colon) and variant
    /// restrictions (`argument:value[,value...]`), select matching
    /// generators, and emit the Cartesian product of surviving
    /// argument values.
    pub fn generate_kernels_cond(
        &self,
        filter_tags: &[&str],
        cond: MatchCondition,
    ) -> Result<Vec<GeneratedKernel>, String> {
        let mut gen_tags: BTreeSet<&str> = BTreeSet::new();
        let mut restrictions: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
        for t in filter_tags {
            match t.split_once(':') {
                None => {
                    gen_tags.insert(*t);
                }
                Some((arg, values)) => {
                    restrictions.insert(arg, values.split(',').collect());
                }
            }
        }

        let mut out = Vec::new();
        for g in &self.generators {
            let gs = g.tag_set();
            let selected = match cond {
                MatchCondition::Identical => gs == gen_tags.clone(),
                MatchCondition::Subset => gs.is_subset(&gen_tags),
                MatchCondition::Superset => gs.is_superset(&gen_tags),
                MatchCondition::Intersect => gs.intersection(&gen_tags).next().is_some(),
            };
            if !selected {
                continue;
            }

            // Restrict argument domains.
            let mut domains: Vec<(&str, Vec<String>)> = Vec::new();
            let mut impossible = false;
            for (arg, allowed) in &g.arg_domains {
                let dom: Vec<String> = match restrictions.get(arg) {
                    Some(vals) => {
                        let keep: Vec<String> = allowed
                            .iter()
                            .filter(|a| vals.contains(&a.as_str()))
                            .cloned()
                            .collect();
                        // Values outside the allowable set are ignored
                        // (restriction to a subset, per the paper).
                        keep
                    }
                    None => allowed.clone(),
                };
                if dom.is_empty() {
                    impossible = true;
                    break;
                }
                domains.push((arg, dom));
            }
            if impossible {
                continue;
            }

            // Cartesian product.
            let mut combos: Vec<VariantArgs> = vec![VariantArgs::default()];
            for (arg, dom) in &domains {
                let mut next = Vec::with_capacity(combos.len() * dom.len());
                for c in &combos {
                    for v in dom {
                        let mut c2 = c.clone();
                        c2.map.insert(arg.to_string(), v.clone());
                        next.push(c2);
                    }
                }
                combos = next;
            }
            for args in combos {
                out.push((g.build)(&args)?);
            }
        }
        Ok(out)
    }
}

/// Helper for arg domains: integer list.
pub(crate) fn ints(vals: &[i64]) -> Vec<String> {
    vals.iter().map(|v| v.to_string()).collect()
}

/// Helper for arg domains: string list.
pub(crate) fn strs(vals: &[&str]) -> Vec<String> {
    vals.iter().map(|s| s.to_string()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collection_has_at_least_20_generators() {
        let c = KernelCollection::all();
        assert!(
            c.generators.len() >= 20,
            "only {} generators: {:?}",
            c.generators.len(),
            c.generator_names()
        );
    }

    #[test]
    fn paper_example_produces_four_kernels() {
        // §2.2: four values of n, everything else pinned -> 4 kernels.
        let knls = KernelCollection::all()
            .generate_kernels(&[
                "matmul_sq",
                "dtype:float32",
                "prefetch:True",
                "lsize_0:16",
                "lsize_1:16",
                "groups_fit:True",
                "n:2048,2560,3072,3584",
            ])
            .unwrap();
        assert_eq!(knls.len(), 4);
        for k in &knls {
            assert_eq!(k.generator, "matmul_sq");
            assert!(k.env.contains_key("n"));
            assert_eq!(k.kernel.work_group_size(), 256);
        }
    }

    #[test]
    fn omitting_prefetch_doubles_variants() {
        // §7.1: omit prefetch:True -> one PF and one non-PF per size.
        let knls = KernelCollection::all()
            .generate_kernels(&[
                "matmul_sq",
                "dtype:float32",
                "lsize_0:16",
                "lsize_1:16",
                "groups_fit:True",
                "n:2048,2560,3072,3584",
            ])
            .unwrap();
        assert_eq!(knls.len(), 8);
    }

    #[test]
    fn conflicting_generator_tags_select_nothing_by_default() {
        // §7.1: superset condition + two app tags -> no generator has
        // both.
        let knls = KernelCollection::all()
            .generate_kernels(&["matmul_sq", "finite_diff", "n:2016"])
            .unwrap();
        assert!(knls.is_empty());
    }

    #[test]
    fn intersect_condition_selects_both() {
        let knls = KernelCollection::all()
            .generate_kernels_cond(
                &[
                    "matmul_sq",
                    "finite_diff",
                    "n:2016,2048",
                    "dtype:float32",
                    "prefetch:True",
                    "lsize_0:16",
                    "lsize_1:16",
                    "groups_fit:True",
                    "lsize:16",
                ],
                MatchCondition::Intersect,
            )
            .unwrap();
        let gens: BTreeSet<&str> =
            knls.iter().map(|k| k.generator.as_str()).collect();
        assert!(gens.contains("matmul_sq"), "{gens:?}");
        assert!(gens.contains("fdiff_2d5pt"), "{gens:?}");
    }

    #[test]
    fn all_generators_build_one_default_variant() {
        // Every generator must produce a valid, schedulable kernel for
        // its first allowable value of each argument.
        let c = KernelCollection::all();
        for g in &c.generators {
            let mut args = VariantArgs::default();
            for (arg, dom) in &g.arg_domains {
                args.map.insert(arg.to_string(), dom[0].clone());
            }
            let k = (g.build)(&args)
                .unwrap_or_else(|e| panic!("{} failed to build: {e}", g.name));
            k.kernel
                .validate()
                .unwrap_or_else(|e| panic!("{} invalid: {e}", g.name));
            crate::schedule::linearize(&k.kernel)
                .unwrap_or_else(|e| panic!("{} unschedulable: {e}", g.name));
            crate::stats::gather(&k.kernel, 32)
                .unwrap_or_else(|e| panic!("{} stats failed: {e}", g.name));
        }
    }
}
