//! Application-kernel builders and their generators: the three
//! evaluation computations of Section 8 (matrix multiplication, DG
//! differentiation, 2-D five-point finite differences) plus a square
//! transpose.
//!
//! Builders are public: the experiment coordinator uses them directly
//! to construct the kernels whose execution times the models predict.
//! Each `build_*` transform chain starts from a public `*_base`
//! untransformed kernel — the baseline `analysis::equiv` certifies the
//! chain against (and the autotuner's reference when enumerating
//! alternative chains over the same computation).

use std::collections::BTreeMap;

use super::{ints, strs, GeneratedKernel, Generator, VariantArgs};
use crate::ir::{
    Access, AffExpr, ArrayDecl, DType, Expr, Kernel, LhsRef, MemScope, Stmt,
};
use crate::polyhedral::{LoopExtent, NestedDomain, QPoly};
use crate::transform::{
    add_prefetch, assume, prioritize_loops, split_iname, tag_data_axes, tag_inames,
};

/// Untransformed square matmul `c = a @ b`: the plain `i, j, k` triple
/// loop [`build_matmul`]'s transform chain starts from.  `prefetch`
/// only selects the variant's name and memory-access tags.
pub fn matmul_base(dtype: DType, prefetch: bool) -> Kernel {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("i", n.clone()),
        LoopExtent::zero_to("j", n.clone()),
        LoopExtent::zero_to("k", n.clone()),
    ]);
    let name = if prefetch { "matmul_pf" } else { "matmul_nopf" };
    // Variant-specific memory-access tags: the paper's five distinct
    // matmul gmem patterns (mm-PF-a, mm-PF-b, mm-noPF-a, mm-noPF-b and
    // the shared stride-1 store) become distinguishable model features.
    let vtag = if prefetch { "mm_pf" } else { "mm_nopf" };
    let tag_a = format!("{vtag}_a");
    let tag_b = format!("{vtag}_b");
    let mut knl = Kernel::new(name, &["n"], dom);
    for arr in ["a", "b", "c"] {
        knl.add_array(ArrayDecl::global(arr, dtype, vec![n.clone(), n.clone()]));
    }
    knl.add_temp("acc", dtype);
    knl.add_stmt(Stmt::new(
        "init",
        LhsRef::Temp("acc".into()),
        Expr::fconst(0.0),
        &["i", "j"],
    ));
    knl.add_stmt(
        Stmt::new(
            "upd",
            LhsRef::Temp("acc".into()),
            Expr::add(
                Expr::temp("acc"),
                Expr::mul(
                    Expr::load(Access::tagged(
                        "a",
                        &tag_a,
                        vec![AffExpr::var("i"), AffExpr::var("k")],
                    )),
                    Expr::load(Access::tagged(
                        "b",
                        &tag_b,
                        vec![AffExpr::var("k"), AffExpr::var("j")],
                    )),
                ),
            ),
            &["i", "j", "k"],
        )
        .with_deps(&["init"]),
    );
    knl.add_stmt(
        Stmt::new(
            "store",
            LhsRef::Array(Access::tagged(
                "c",
                &format!("{vtag}_st"),
                vec![AffExpr::var("i"), AffExpr::var("j")],
            )),
            Expr::temp("acc"),
            &["i", "j"],
        )
        .with_deps(&["upd"]),
    );
    knl
}

/// §2.1 / §8.3: square matmul `c = a @ b` with 16x16 work-groups,
/// optionally prefetching 16x16 tiles of both inputs into local memory.
pub fn build_matmul(dtype: DType, prefetch: bool, tile: i64) -> Result<Kernel, String> {
    let knl = matmul_base(dtype, prefetch);
    let knl = assume(&knl, &format!("n >= {tile} and n % {tile} = 0"))?;
    let knl = split_iname(&knl, "i", tile)?;
    let knl = split_iname(&knl, "j", tile)?;
    let mut knl = tag_inames(&knl, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0")?;
    if prefetch {
        knl = split_iname(&knl, "k", tile)?;
        knl = add_prefetch(&knl, "a", &["i_in", "k_in"], false)?;
        knl = add_prefetch(&knl, "b", &["k_in", "j_in"], false)?;
    }
    Ok(knl)
}

/// DG differentiation variants (§8.4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DgVariant {
    /// Variant 1: tile/parallelize i and k only.
    Plain,
    /// Variant 2: prefetch 16x16 tiles of the element data `u`.
    UPrefetch,
    /// Variant 3: prefetch tiles of the differentiation matrix.
    MPrefetch,
    /// Variant 4: variant 3 plus transposed element-data layout.
    MPrefetchT,
}

impl DgVariant {
    pub fn parse(s: &str) -> Result<DgVariant, String> {
        match s {
            "plain" => Ok(DgVariant::Plain),
            "u_prefetch" => Ok(DgVariant::UPrefetch),
            "m_prefetch" => Ok(DgVariant::MPrefetch),
            "m_prefetch_t" => Ok(DgVariant::MPrefetchT),
            other => Err(format!("unknown DG variant '{other}'")),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            DgVariant::Plain => "plain",
            DgVariant::UPrefetch => "u_prefetch",
            DgVariant::MPrefetch => "m_prefetch",
            DgVariant::MPrefetchT => "m_prefetch_t",
        }
    }
}

/// Untransformed DG differentiation kernel: the loop nest
/// [`build_dg`]'s transform chain starts from.  The `UPrefetch`
/// variant already differs structurally here (duplicated init/store
/// `m` loops, private per-`m` accumulator array), so the baseline is
/// per-variant.
pub fn dg_base(variant: DgVariant, nunit_nodes: i64) -> Kernel {
    let nel = QPoly::var("nelements");
    let nmat = QPoly::var("nmatrices");
    let nun = QPoly::int(nunit_nodes as i128);

    let mut loops = vec![
        LoopExtent::zero_to("m", nmat.clone()),
        LoopExtent::zero_to("i", nun.clone()),
        LoopExtent::zero_to("e", nel.clone()),
        LoopExtent::zero_to("j", nun.clone()),
    ];
    if variant == DgVariant::UPrefetch {
        // Separate init/store m-loops (Loopy's duplicate_inames) so the
        // u tile is fetched outside the m loop.
        loops.insert(0, LoopExtent::zero_to("m_init", nmat.clone()));
        loops.push(LoopExtent::zero_to("m_store", nmat.clone()));
    }
    let dom = NestedDomain::new(loops);
    let name = format!("dg_diff_{}", variant.label());
    let mut knl = Kernel::new(&name, &["nelements", "nmatrices"], dom);
    knl.add_array(ArrayDecl::global(
        "diff_mat",
        DType::F32,
        vec![nmat.clone(), nun.clone(), nun.clone()],
    ));
    knl.add_array(ArrayDecl::global(
        "u",
        DType::F32,
        vec![nel.clone(), nun.clone()],
    ));
    knl.add_array(ArrayDecl::global(
        "res",
        DType::F32,
        vec![nmat.clone(), nel.clone(), nun.clone()],
    ));

    // Pattern-identical accesses share a tag across variants so that a
    // single work-removal microbenchmark calibrates them all (Fig. 6b's
    // 11 distinct patterns, not 4 variants x 3 arrays):
    //   u:  direct load (plain, m_prefetch), cooperative fetch
    //       (u_prefetch), transposed direct load (m_prefetch_t)
    //   dm: direct uniform load (plain, u_prefetch) vs tile fetch
    //   res: untransposed vs transposed store.
    let u_tag = match variant {
        DgVariant::UPrefetch => "dg_u_fetch",
        DgVariant::MPrefetchT => "dg_u_direct_t",
        _ => "dg_u_direct",
    };
    let dm_tag = match variant {
        DgVariant::MPrefetch | DgVariant::MPrefetchT => "dg_dm_fetch",
        // u_prefetch restructures the loops (m innermost): its direct
        // dm loads walk a 16 KiB loop stride — a different pattern
        // (Table 1 counts the sequential loop stride) from plain's
        // stride-1 j-innermost walk.
        DgVariant::UPrefetch => "dg_dm_direct_mloop",
        _ => "dg_dm_direct",
    };
    let res_tag = match variant {
        DgVariant::MPrefetchT => "dg_res_t",
        _ => "dg_res",
    };
    let dm_ld = Expr::load(Access::tagged(
        "diff_mat",
        dm_tag,
        vec![AffExpr::var("m"), AffExpr::var("i"), AffExpr::var("j")],
    ));
    let u_ld = Expr::load(Access::tagged(
        "u",
        u_tag,
        vec![AffExpr::var("e"), AffExpr::var("j")],
    ));

    if variant == DgVariant::UPrefetch {
        // Private per-m accumulator array.
        knl.add_array(ArrayDecl {
            name: "acc".into(),
            dtype: DType::F32,
            scope: MemScope::Private,
            shape: vec![nmat.clone()],
            axis_order: vec![0],
        });
        knl.add_stmt(Stmt::new(
            "init",
            LhsRef::Array(Access::new("acc", vec![AffExpr::var("m_init")])),
            Expr::fconst(0.0),
            &["m_init", "i", "e"],
        ));
        knl.add_stmt(
            Stmt::new(
                "upd",
                LhsRef::Array(Access::new("acc", vec![AffExpr::var("m")])),
                Expr::add(
                    Expr::load(Access::new("acc", vec![AffExpr::var("m")])),
                    Expr::mul(dm_ld, u_ld),
                ),
                &["i", "e", "j", "m"],
            )
            .with_deps(&["init"]),
        );
        knl.add_stmt(
            Stmt::new(
                "store",
                LhsRef::Array(Access::tagged(
                    "res",
                    res_tag,
                    vec![
                        AffExpr::var("m_store"),
                        AffExpr::var("e"),
                        AffExpr::var("i"),
                    ],
                )),
                Expr::load(Access::new("acc", vec![AffExpr::var("m_store")])),
                &["i", "e", "m_store"],
            )
            .with_deps(&["upd"]),
        );
    } else {
        knl.add_temp("acc", DType::F32);
        knl.add_stmt(Stmt::new(
            "init",
            LhsRef::Temp("acc".into()),
            Expr::fconst(0.0),
            &["m", "i", "e"],
        ));
        knl.add_stmt(
            Stmt::new(
                "upd",
                LhsRef::Temp("acc".into()),
                Expr::add(Expr::temp("acc"), Expr::mul(dm_ld, u_ld)),
                &["m", "i", "e", "j"],
            )
            .with_deps(&["init"]),
        );
        knl.add_stmt(
            Stmt::new(
                "store",
                LhsRef::Array(Access::tagged(
                    "res",
                    res_tag,
                    vec![AffExpr::var("m"), AffExpr::var("e"), AffExpr::var("i")],
                )),
                Expr::temp("acc"),
                &["m", "i", "e"],
            )
            .with_deps(&["upd"]),
        );
    }
    knl
}

/// §8.4: `res[m, e, i] = Σ_j diff_mat[m, i, j] * u[e, j]` over
/// `nelements` elements with `nunit_nodes` nodes and `nmatrices`
/// differentiation matrices; element index parallelized over
/// (g.0, l.0), node index over (g.1, l.1).
pub fn build_dg(variant: DgVariant, nunit_nodes: i64, lsize: i64) -> Result<Kernel, String> {
    let knl = dg_base(variant, nunit_nodes);
    let knl = assume(
        &knl,
        &format!("nelements >= {lsize} and nelements % {lsize} = 0"),
    )?;
    let knl = split_iname(&knl, "i", lsize)?;
    let knl = split_iname(&knl, "e", lsize)?;
    let mut knl = tag_inames(&knl, "i_out:g.1, i_in:l.1, e_out:g.0, e_in:l.0")?;

    match variant {
        DgVariant::Plain => {}
        DgVariant::UPrefetch => {
            knl = split_iname(&knl, "j", lsize)?;
            knl = add_prefetch(&knl, "u", &["e_in", "j_in"], false)?;
            knl = prioritize_loops(
                &knl,
                &["m_init", "j_out", "j_in", "m", "m_store"],
            )?;
        }
        DgVariant::MPrefetch | DgVariant::MPrefetchT => {
            knl = split_iname(&knl, "j", lsize)?;
            knl = add_prefetch(&knl, "diff_mat", &["j_in", "i_in"], false)?;
            knl = prioritize_loops(&knl, &["m", "j_out", "j_in"])?;
            if variant == DgVariant::MPrefetchT {
                // Transposed element-data layout: lid(0) stride becomes
                // 1 for both u loads and res stores.
                knl = tag_data_axes(&knl, "u", "N1,N0")?;
                knl = tag_data_axes(&knl, "res", "N0,N2,N1")?;
            }
        }
    }
    Ok(knl)
}

/// Untransformed 2-D five-point stencil: the plain `i, j` nest
/// [`build_fdiff`]'s transform chain starts from.  `lsize` only
/// selects the variant's name and memory-access tags.
pub fn fdiff_base(lsize: i64) -> Kernel {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("i", n.clone()),
        LoopExtent::zero_to("j", n.clone()),
    ]);
    let vtag = format!("fd{lsize}");
    let mut knl = Kernel::new(&format!("fdiff_{lsize}x{lsize}"), &["n"], dom);
    knl.add_array(ArrayDecl::global(
        "u",
        DType::F32,
        vec![&n + &QPoly::int(2), &n + &QPoly::int(2)],
    ));
    knl.add_array(ArrayDecl::global("res", DType::F32, vec![n.clone(), n]));
    let u_tag = format!("{vtag}_u");
    let u = move |di: i64, dj: i64| {
        Expr::load(Access::tagged(
            "u",
            &u_tag,
            vec![
                AffExpr::var("i").plus_cst(di),
                AffExpr::var("j").plus_cst(dj),
            ],
        ))
    };
    // res[i,j] = u[i,j+1] + u[i+1,j] - 4*u[i+1,j+1] + u[i+1,j+2] + u[i+2,j+1]
    let rhs = Expr::add(
        Expr::add(
            Expr::sub(
                Expr::add(u(0, 1), u(1, 0)),
                Expr::mul(Expr::fconst(4.0), u(1, 1)),
            ),
            u(1, 2),
        ),
        u(2, 1),
    );
    knl.add_stmt(Stmt::new(
        "stencil",
        LhsRef::Array(Access::tagged(
            "res",
            &format!("{vtag}_res"),
            vec![AffExpr::var("i"), AffExpr::var("j")],
        )),
        rhs,
        &["i", "j"],
    ));
    knl
}

/// §8.5: 2-D five-point stencil with bounding-box prefetch.  `lsize` is
/// the work-group edge (16 or 18); tiles of `(lsize-2)^2` interior
/// points are computed per work-group.
pub fn build_fdiff(lsize: i64) -> Result<Kernel, String> {
    let interior = lsize - 2;
    let knl = fdiff_base(lsize);
    let knl = assume(
        &knl,
        &format!("n >= {interior} and n % {interior} = 0"),
    )?;
    let knl = split_iname(&knl, "i", interior)?;
    let knl = split_iname(&knl, "j", interior)?;
    let knl = tag_inames(&knl, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0")?;
    // fetch_bounding_box: the lsize x lsize tile includes the halo; the
    // fetch inames (tagged l.1/l.0) widen the work-group to lsize^2.
    add_prefetch(&knl, "u", &["i_in", "j_in"], true)
}

/// Untransformed square transpose: the plain `i, j` nest
/// [`build_transpose`]'s transform chain starts from.
pub fn transpose_base() -> Kernel {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("i", n.clone()),
        LoopExtent::zero_to("j", n.clone()),
    ]);
    let mut knl = Kernel::new("transpose_sq", &["n"], dom);
    knl.add_array(ArrayDecl::global("inp", DType::F32, vec![n.clone(), n.clone()]));
    knl.add_array(ArrayDecl::global("outp", DType::F32, vec![n.clone(), n]));
    knl.add_stmt(Stmt::new(
        "t",
        LhsRef::Array(Access::tagged(
            "outp",
            "oST",
            vec![AffExpr::var("j"), AffExpr::var("i")],
        )),
        Expr::load(Access::tagged(
            "inp",
            "iLD",
            vec![AffExpr::var("i"), AffExpr::var("j")],
        )),
        &["i", "j"],
    ));
    knl
}

/// Square transpose `out[j, i] = in[i, j]` — a classic
/// uncoalesced-store pattern for the measurement library.
pub fn build_transpose(tile: i64) -> Result<Kernel, String> {
    let knl = transpose_base();
    let knl = assume(&knl, &format!("n >= {tile} and n % {tile} = 0"))?;
    let knl = split_iname(&knl, "i", tile)?;
    let knl = split_iname(&knl, "j", tile)?;
    tag_inames(&knl, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0")
}

fn env1(k: &str, v: i64) -> BTreeMap<String, i64> {
    [(k.to_string(), v)].into_iter().collect()
}

fn gen_matmul(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let dtype = DType::parse(args.get("dtype")?).ok_or("bad dtype")?;
    let prefetch = args.get_bool("prefetch")?;
    let tile = args.get_i64("lsize_0")?;
    if args.get_i64("lsize_1")? != tile {
        return Err("matmul_sq requires square work-groups".into());
    }
    if !args.get_bool("groups_fit")? {
        return Err("matmul_sq currently requires groups_fit:True".into());
    }
    let n = args.get_i64("n")?;
    if n % tile != 0 {
        return Err(format!("n={n} not divisible by tile {tile}"));
    }
    Ok(GeneratedKernel {
        kernel: build_matmul(dtype, prefetch, tile)?.freeze(),
        generator: "matmul_sq".into(),
        args: args.clone(),
        env: env1("n", n),
    })
}

fn gen_dg(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let variant = DgVariant::parse(args.get("variant")?)?;
    let nun = args.get_i64("nunit_nodes")?;
    let nel = args.get_i64("nelements")?;
    let nmat = args.get_i64("nmatrices")?;
    let kernel = build_dg(variant, nun, 16)?;
    let mut env = env1("nelements", nel);
    env.insert("nmatrices".into(), nmat);
    Ok(GeneratedKernel {
        kernel: kernel.freeze(),
        generator: "dg_diff".into(),
        args: args.clone(),
        env,
    })
}

fn gen_fdiff(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let lsize = args.get_i64("lsize")?;
    let n = args.get_i64("n")?;
    if n % (lsize - 2) != 0 {
        return Err(format!("n={n} not divisible by interior {}", lsize - 2));
    }
    Ok(GeneratedKernel {
        kernel: build_fdiff(lsize)?.freeze(),
        generator: "fdiff_2d5pt".into(),
        args: args.clone(),
        env: env1("n", n),
    })
}

fn gen_transpose(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let n = args.get_i64("n")?;
    Ok(GeneratedKernel {
        kernel: build_transpose(16)?.freeze(),
        generator: "transpose_sq".into(),
        args: args.clone(),
        env: env1("n", n),
    })
}

/// Application-kernel generators.
pub fn generators() -> Vec<Generator> {
    vec![
        Generator {
            name: "matmul_sq",
            tags: &["matmul_sq", "matmul", "app"],
            arg_domains: vec![
                ("dtype", strs(&["float32", "float64"])),
                ("prefetch", strs(&["True", "False"])),
                ("lsize_0", ints(&[16])),
                ("lsize_1", ints(&[16])),
                ("groups_fit", strs(&["True"])),
                ("n", ints(&[1024, 1536, 2048, 2560, 3072, 3584])),
            ],
            build: gen_matmul,
        },
        Generator {
            name: "dg_diff",
            tags: &["dg_diff", "dg", "app"],
            arg_domains: vec![
                (
                    "variant",
                    strs(&["plain", "u_prefetch", "m_prefetch", "m_prefetch_t"]),
                ),
                ("nunit_nodes", ints(&[64])),
                ("nmatrices", ints(&[3])),
                (
                    "nelements",
                    ints(&[32768, 65536, 131072, 262144, 524288]),
                ),
            ],
            build: gen_dg,
        },
        Generator {
            name: "fdiff_2d5pt",
            tags: &["finite_diff", "fdiff_2d5pt", "app"],
            arg_domains: vec![
                ("lsize", ints(&[16, 18])),
                // Multiples of lcm(14, 16) = 112 work for both tiles.
                ("n", ints(&[2016, 4032, 6048, 8064])),
            ],
            build: gen_fdiff,
        },
        Generator {
            name: "transpose_sq",
            tags: &["transpose_sq", "transpose", "app"],
            arg_domains: vec![("n", ints(&[1024, 2048, 4096]))],
            build: gen_transpose,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{device_by_id, simulate_time};
    use crate::util::Rat;

    fn env(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn dg_variants_all_build_and_validate() {
        for v in [
            DgVariant::Plain,
            DgVariant::UPrefetch,
            DgVariant::MPrefetch,
            DgVariant::MPrefetchT,
        ] {
            let k = build_dg(v, 64, 16).unwrap();
            k.validate()
                .unwrap_or_else(|e| panic!("{}: {e}", v.label()));
            crate::schedule::linearize(&k)
                .unwrap_or_else(|e| panic!("{} schedule: {e}", v.label()));
            assert_eq!(k.work_group_size(), 256, "{}", v.label());
        }
    }

    #[test]
    fn dg_madd_count_matches_formula() {
        // madds (SG granularity) = nmatrices * nunit_nodes^2 * nelements / 32.
        let k = build_dg(DgVariant::MPrefetch, 64, 16).unwrap();
        let s = crate::stats::gather(&k, 32).unwrap();
        let e: BTreeMap<String, i128> =
            [("nelements".to_string(), 4096i128), ("nmatrices".to_string(), 3)]
                .into_iter()
                .collect();
        let madd = s.op_count(DType::F32, "madd");
        assert_eq!(madd.eval(&e), Rat::new(3 * 64 * 64 * 4096, 32));
    }

    #[test]
    fn dg_transposed_layout_fixes_lid0_stride() {
        let e = env(&[("nelements", 4096), ("nmatrices", 3)]);
        let ei: BTreeMap<String, i128> =
            e.iter().map(|(k, v)| (k.clone(), *v as i128)).collect();
        let k3 = build_dg(DgVariant::MPrefetch, 64, 16).unwrap();
        let k4 = build_dg(DgVariant::MPrefetchT, 64, 16).unwrap();
        let stride_of = |k: &Kernel, tag: &str| -> i128 {
            let s = crate::stats::gather(k, 32).unwrap();
            let m = s
                .mem_matching(|m| m.tag.as_deref() == Some(tag))
                .next()
                .unwrap()
                .clone();
            m.lstrides[0].eval(&ei).floor()
        };
        // u loads: stride 64 (node-major) vs 1 (transposed).
        assert_eq!(stride_of(&k3, "dg_u_direct"), 64);
        assert_eq!(stride_of(&k4, "dg_u_direct_t"), 1);
        assert_eq!(stride_of(&k3, "dg_res"), 64);
        assert_eq!(stride_of(&k4, "dg_res_t"), 1);
    }

    #[test]
    fn dg_transposed_variant_is_fastest_everywhere() {
        // Paper §8.4: "the last variant is the fastest in all our
        // measurements".
        let e = env(&[("nelements", 131072), ("nmatrices", 3)]);
        for dev in crate::gpusim::fleet() {
            let mut times = Vec::new();
            for v in [
                DgVariant::Plain,
                DgVariant::UPrefetch,
                DgVariant::MPrefetch,
                DgVariant::MPrefetchT,
            ] {
                let k = build_dg(v, 64, 16).unwrap();
                times.push((v.label(), simulate_time(&dev, &k, &e).unwrap()));
            }
            let fastest = times
                .iter()
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            assert_eq!(
                fastest.0, "m_prefetch_t",
                "{}: times {times:?}",
                dev.id
            );
        }
    }

    #[test]
    fn fdiff_tiles_and_occupancy_match_paper() {
        let k16 = build_fdiff(16).unwrap();
        // 16x16 tile: 256 threads, 14x14 interior, 60 idle in compute.
        assert_eq!(k16.work_group_size(), 256);
        let tile = &k16.arrays["u_fetch"];
        assert_eq!(tile.shape[0].as_constant(), Some(Rat::int(16)));
        let k18 = build_fdiff(18).unwrap();
        assert_eq!(k18.work_group_size(), 324);
        assert_eq!(
            k18.arrays["u_fetch"].shape[0].as_constant(),
            Some(Rat::int(18))
        );
        // Interior statement executes (lsize-2)^2 per group.
        let s = crate::stats::gather(&k16, 32).unwrap();
        let e: BTreeMap<String, i128> = [("n".to_string(), 2016i128)].into_iter().collect();
        let store = s
            .mem_matching(|m| m.tag.as_deref() == Some("fd16_res"))
            .next()
            .unwrap()
            .clone();
        assert_eq!(store.count_wi.eval(&e), Rat::int(2016 * 2016));
    }

    #[test]
    fn fdiff_16_beats_18_mostly_and_amd_rejects_18() {
        let e = env(&[("n", 4032)]);
        let k16 = build_fdiff(16).unwrap();
        let k18 = build_fdiff(18).unwrap();
        let amd = device_by_id("amd_r9_fury").unwrap();
        assert!(simulate_time(&amd, &k18, &e).is_err());
        assert!(simulate_time(&amd, &k16, &e).is_ok());
        // On the Nvidia devices both run; 16x16 is (slightly) faster on
        // most (the paper's observed ranking, one miss allowed).
        let mut wins16 = 0;
        for id in ["titan_v", "gtx_titan_x", "tesla_k40c", "tesla_c2070"] {
            let d = device_by_id(id).unwrap();
            let t16 = simulate_time(&d, &k16, &e).unwrap();
            let t18 = simulate_time(&d, &k18, &e).unwrap();
            if t16 < t18 {
                wins16 += 1;
            }
        }
        assert!(wins16 >= 3, "16x16 won only {wins16}/4");
    }

    #[test]
    fn fdiff_bandwidth_fraction_plausible() {
        // Paper: the 16x16 variant achieves 40-82% of peak bandwidth.
        let k16 = build_fdiff(16).unwrap();
        let e = env(&[("n", 8064)]);
        for dev in crate::gpusim::fleet() {
            let t = simulate_time(&dev, &k16, &e).unwrap();
            // Useful traffic: n^2 loads (footprint) + n^2 stores.
            let bytes = 2.0 * 8064f64 * 8064.0 * 4.0;
            let frac = bytes / t / dev.peak_bw();
            assert!(
                (0.15..0.95).contains(&frac),
                "{}: {:.0}% of peak bw",
                dev.id,
                frac * 100.0
            );
        }
    }
}
