//! Derived generators: work-removal microbenchmarks (§7.1.1/7.1.2's
//! "subtractive" approach — build the application kernel, then strip
//! everything except one global access pattern) and a few simple
//! additional application patterns (axpy, vecadd, matvec, 1-D
//! stencil).

use std::collections::BTreeMap;

use super::apps::{build_dg, build_fdiff, build_matmul, DgVariant};
use super::{ints, strs, GeneratedKernel, Generator, VariantArgs};
use crate::ir::{
    Access, AffExpr, ArrayDecl, DType, Expr, Kernel, LhsRef, Stmt,
};
use crate::polyhedral::{LoopExtent, NestedDomain, QPoly};
use crate::transform::remove_work::{remove_work, RemoveSpec};
use crate::transform::{assume, split_iname, tag_inames};

fn env(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Isolated matmul global-load patterns (the paper's running §7.1.1
/// example): variants `pf_a`, `pf_b`, `nopf_a`, `nopf_b`.
fn gen_gmem_from_matmul(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let variant = args.get("variant")?;
    let n = args.get_i64("n")?;
    let (prefetch, keep, remove_tag) = match variant {
        "pf_a" => (true, "mm_pf_a", "mm_pf_b"),
        "pf_b" => (true, "mm_pf_b", "mm_pf_a"),
        "nopf_a" => (false, "mm_nopf_a", "mm_nopf_b"),
        "nopf_b" => (false, "mm_nopf_b", "mm_nopf_a"),
        other => return Err(format!("unknown matmul gmem variant '{other}'")),
    };
    let _ = keep;
    let app = build_matmul(DType::F32, prefetch, 16)?;
    let spec = RemoveSpec {
        remove_arrays: vec!["c".into()],
        remove_tags: vec![remove_tag.into()],
    };
    let mut kernel = remove_work(&app, &spec)?;
    kernel.name = format!("gmem_mm_{variant}");
    Ok(GeneratedKernel {
        kernel: kernel.freeze(),
        generator: "gmem_from_matmul".into(),
        args: args.clone(),
        env: env(&[("n", n)]),
    })
}

/// Isolated DG global access patterns (the 11 patterns of Fig. 6b are
/// drawn from these plus the matmul/fdiff families).
fn gen_gmem_from_dg(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let pattern = args.get("pattern")?;
    let nel = args.get_i64("nelements")?;
    let (variant, remove): (DgVariant, Vec<&str>) = match pattern {
        "plain_u" => (DgVariant::Plain, vec!["diff_mat", "res"]),
        "plain_dm" => (DgVariant::Plain, vec!["u", "res"]),
        "upf_u" => (DgVariant::UPrefetch, vec!["diff_mat", "res"]),
        "upf_dm" => (DgVariant::UPrefetch, vec!["u", "res"]),
        "mpf_dm" => (DgVariant::MPrefetch, vec!["u", "res"]),
        "mpf_u" => (DgVariant::MPrefetch, vec!["diff_mat", "res"]),
        "t_u" => (DgVariant::MPrefetchT, vec!["diff_mat", "res"]),
        "res_store" => (DgVariant::MPrefetch, vec!["diff_mat"]),
        "t_res_store" => (DgVariant::MPrefetchT, vec!["diff_mat"]),
        other => return Err(format!("unknown DG gmem pattern '{other}'")),
    };
    let app = build_dg(variant, 64, 16)?;
    let mut kernel = remove_work(&app, &RemoveSpec::arrays(&remove))?;
    kernel.name = format!("gmem_dg_{pattern}");
    Ok(GeneratedKernel {
        kernel: kernel.freeze(),
        generator: "gmem_from_dg".into(),
        args: args.clone(),
        env: env(&[("nelements", nel), ("nmatrices", 3)]),
    })
}

/// Isolated stencil-tile load pattern for both work-group sizes.
fn gen_gmem_from_fdiff(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let lsize = args.get_i64("lsize")?;
    let n = args.get_i64("n")?;
    let app = build_fdiff(lsize)?;
    let mut kernel = remove_work(&app, &RemoveSpec::arrays(&["res"]))?;
    kernel.name = format!("gmem_fdiff_{lsize}");
    Ok(GeneratedKernel {
        kernel: kernel.freeze(),
        generator: "gmem_from_fdiff".into(),
        args: args.clone(),
        env: env(&[("n", n)]),
    })
}

/// 1-D grid helper: n work-items in 256-wide groups (l.0 only).
fn grid_1d(name: &str) -> Result<Kernel, String> {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n)]);
    let knl = Kernel::new(name, &["n"], dom);
    let knl = assume(&knl, "n >= 256 and n % 256 = 0")?;
    Ok(knl)
}

/// `y[i] = 2*x[i] + y[i]` — one madd, two loads, one store.
pub fn build_axpy(dtype: DType) -> Result<Kernel, String> {
    let mut knl = grid_1d("axpy")?;
    let n = QPoly::var("n");
    knl.add_array(ArrayDecl::global("x", dtype, vec![n.clone()]));
    knl.add_array(ArrayDecl::global("y", dtype, vec![n]));
    knl.add_stmt(Stmt::new(
        "s",
        LhsRef::Array(Access::tagged("y", "yST", vec![AffExpr::var("i")])),
        Expr::add(
            Expr::load(Access::tagged("y", "yLD", vec![AffExpr::var("i")])),
            Expr::mul(
                Expr::fconst(2.0),
                Expr::load(Access::tagged("x", "xLD", vec![AffExpr::var("i")])),
            ),
        ),
        &["i"],
    ));
    let knl = split_iname(&knl, "i", 256)?;
    tag_inames(&knl, "i_out:g.0, i_in:l.0")
}

/// `z[i] = x[i] + y[i]`.
pub fn build_vecadd(dtype: DType) -> Result<Kernel, String> {
    let mut knl = grid_1d("vecadd")?;
    let n = QPoly::var("n");
    for a in ["x", "y", "z"] {
        knl.add_array(ArrayDecl::global(a, dtype, vec![n.clone()]));
    }
    knl.add_stmt(Stmt::new(
        "s",
        LhsRef::Array(Access::new("z", vec![AffExpr::var("i")])),
        Expr::add(
            Expr::load(Access::new("x", vec![AffExpr::var("i")])),
            Expr::load(Access::new("y", vec![AffExpr::var("i")])),
        ),
        &["i"],
    ));
    let knl = split_iname(&knl, "i", 256)?;
    tag_inames(&knl, "i_out:g.0, i_in:l.0")
}

/// `y[i] = Σ_j A[i,j] * x[j]` — a row-major matvec: the A loads are
/// lid-strided by n (uncoalesced), x is uniform.
pub fn build_matvec(dtype: DType) -> Result<Kernel, String> {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("i", n.clone()),
        LoopExtent::zero_to("j", n.clone()),
    ]);
    let mut knl = Kernel::new("matvec", &["n"], dom);
    knl.add_array(ArrayDecl::global("amat", dtype, vec![n.clone(), n.clone()]));
    knl.add_array(ArrayDecl::global("x", dtype, vec![n.clone()]));
    knl.add_array(ArrayDecl::global("y", dtype, vec![n]));
    knl.add_temp("acc", dtype);
    knl.add_stmt(Stmt::new(
        "init",
        LhsRef::Temp("acc".into()),
        Expr::fconst(0.0),
        &["i"],
    ));
    knl.add_stmt(
        Stmt::new(
            "upd",
            LhsRef::Temp("acc".into()),
            Expr::add(
                Expr::temp("acc"),
                Expr::mul(
                    Expr::load(Access::tagged(
                        "amat",
                        "aLD",
                        vec![AffExpr::var("i"), AffExpr::var("j")],
                    )),
                    Expr::load(Access::tagged("x", "xLD", vec![AffExpr::var("j")])),
                ),
            ),
            &["i", "j"],
        )
        .with_deps(&["init"]),
    );
    knl.add_stmt(
        Stmt::new(
            "store",
            LhsRef::Array(Access::new("y", vec![AffExpr::var("i")])),
            Expr::temp("acc"),
            &["i"],
        )
        .with_deps(&["upd"]),
    );
    let knl = assume(&knl, "n >= 256 and n % 256 = 0")?;
    let knl = split_iname(&knl, "i", 256)?;
    tag_inames(&knl, "i_out:g.0, i_in:l.0")
}

/// 1-D three-point stencil with bounding-box prefetch.
pub fn build_stencil1d(dtype: DType) -> Result<Kernel, String> {
    let n = QPoly::var("n");
    let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
    let mut knl = Kernel::new("stencil1d_3pt", &["n"], dom);
    knl.add_array(ArrayDecl::global(
        "u",
        dtype,
        vec![&n + &QPoly::int(2)],
    ));
    knl.add_array(ArrayDecl::global("res", dtype, vec![n]));
    let u = |c: i64| {
        Expr::load(Access::tagged(
            "u",
            "uLD",
            vec![AffExpr::var("i").plus_cst(c)],
        ))
    };
    knl.add_stmt(Stmt::new(
        "s",
        LhsRef::Array(Access::new("res", vec![AffExpr::var("i")])),
        Expr::add(Expr::add(u(0), u(1)), u(2)),
        &["i"],
    ));
    let knl = assume(&knl, "n >= 254 and n % 254 = 0")?;
    let knl = split_iname(&knl, "i", 254)?;
    let knl = tag_inames(&knl, "i_out:g.0, i_in:l.0")?;
    crate::transform::add_prefetch(&knl, "u", &["i_in"], true)
}

fn gen_axpy(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_axpy(DType::parse(args.get("dtype")?).ok_or("bad dtype")?)?.freeze(),
        generator: "axpy".into(),
        args: args.clone(),
        env: env(&[("n", args.get_i64("n")?)]),
    })
}

fn gen_vecadd(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_vecadd(DType::parse(args.get("dtype")?).ok_or("bad dtype")?)?.freeze(),
        generator: "vecadd".into(),
        args: args.clone(),
        env: env(&[("n", args.get_i64("n")?)]),
    })
}

fn gen_matvec(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_matvec(DType::F32)?.freeze(),
        generator: "matvec".into(),
        args: args.clone(),
        env: env(&[("n", args.get_i64("n")?)]),
    })
}

fn gen_stencil1d(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_stencil1d(DType::F32)?.freeze(),
        generator: "stencil1d_3pt".into(),
        args: args.clone(),
        env: env(&[("n", args.get_i64("n")?)]),
    })
}

/// Derived + extra generators.
pub fn generators() -> Vec<Generator> {
    vec![
        Generator {
            name: "gmem_from_matmul",
            tags: &["gmem_from_matmul", "gmem_workrm", "matmul", "micro"],
            arg_domains: vec![
                ("variant", strs(&["pf_a", "pf_b", "nopf_a", "nopf_b"])),
                ("n", ints(&[1024, 1536, 2048, 2560, 3072, 3584])),
            ],
            build: gen_gmem_from_matmul,
        },
        Generator {
            name: "gmem_from_dg",
            tags: &["gmem_from_dg", "gmem_workrm", "dg", "micro"],
            arg_domains: vec![
                (
                    "pattern",
                    strs(&[
                        "plain_u",
                        "plain_dm",
                        "upf_u",
                        "upf_dm",
                        "mpf_dm",
                        "mpf_u",
                        "t_u",
                        "res_store",
                        "t_res_store",
                    ]),
                ),
                (
                    "nelements",
                    ints(&[32768, 65536, 131072, 262144, 524288]),
                ),
            ],
            build: gen_gmem_from_dg,
        },
        Generator {
            name: "gmem_from_fdiff",
            tags: &["gmem_from_fdiff", "gmem_workrm", "finite_diff", "micro"],
            arg_domains: vec![
                ("lsize", ints(&[16, 18])),
                ("n", ints(&[2016, 4032, 6048, 8064])),
            ],
            build: gen_gmem_from_fdiff,
        },
        Generator {
            name: "axpy",
            tags: &["axpy", "blas1", "app"],
            arg_domains: vec![
                ("dtype", strs(&["float32", "float64"])),
                ("n", ints(&[1048576, 4194304, 16777216])),
            ],
            build: gen_axpy,
        },
        Generator {
            name: "vecadd",
            tags: &["vecadd", "blas1", "app"],
            arg_domains: vec![
                ("dtype", strs(&["float32", "float64"])),
                ("n", ints(&[1048576, 4194304, 16777216])),
            ],
            build: gen_vecadd,
        },
        Generator {
            name: "matvec",
            tags: &["matvec", "blas2", "app"],
            arg_domains: vec![("n", ints(&[2048, 4096, 8192]))],
            build: gen_matvec,
        },
        Generator {
            name: "stencil1d_3pt",
            tags: &["stencil1d_3pt", "stencil", "app"],
            arg_domains: vec![("n", ints(&[1048064, 4194304 - 4194304 % 254]))],
            build: gen_stencil1d,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::DEFAULT_SUB_GROUP_SIZE as SG;
    use crate::ir::MemScope;
    use crate::stats::Direction;
    use crate::util::Rat;

    fn ienv(pairs: &[(&str, i128)]) -> BTreeMap<String, i128> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn matmul_b_pattern_microbenchmark_preserves_pattern() {
        let mut args = VariantArgs::default();
        args.map.insert("variant".into(), "pf_b".into());
        args.map.insert("n".into(), "2048".into());
        let g = gen_gmem_from_matmul(&args).unwrap();
        let s = crate::stats::gather(&g.kernel, SG).unwrap();
        let e = ienv(&[("n", 2048)]);
        // Exactly one kept global load (the b pattern), unchanged.
        let loads: Vec<_> = s
            .mem_matching(|m| {
                m.scope == MemScope::Global && m.direction == Direction::Load
            })
            .collect();
        assert_eq!(loads.len(), 1);
        let b = loads[0];
        assert_eq!(b.tag.as_deref(), Some("mm_pf_b"));
        assert_eq!(b.lstrides[0].eval(&e), Rat::int(1));
        assert_eq!(b.gstrides[0].eval(&e), Rat::int(16));
        // No on-chip work left.
        assert!(s.ops.iter().all(|o| o.op == "add"), "{:?}", s.ops);
        assert!(s
            .mem_matching(|m| m.scope == MemScope::Local)
            .next()
            .is_none());
    }

    #[test]
    fn dg_patterns_all_build() {
        for pattern in [
            "plain_u",
            "plain_dm",
            "upf_u",
            "upf_dm",
            "mpf_dm",
            "mpf_u",
            "t_u",
            "res_store",
            "t_res_store",
        ] {
            let mut args = VariantArgs::default();
            args.map.insert("pattern".into(), pattern.into());
            args.map.insert("nelements".into(), "65536".into());
            let g = gen_gmem_from_dg(&args)
                .unwrap_or_else(|e| panic!("{pattern}: {e}"));
            g.kernel
                .validate()
                .unwrap_or_else(|e| panic!("{pattern}: {e}"));
            crate::stats::gather(&g.kernel, SG)
                .unwrap_or_else(|e| panic!("{pattern} stats: {e}"));
        }
    }

    #[test]
    fn axpy_counts() {
        let k = build_axpy(DType::F32).unwrap();
        let s = crate::stats::gather(&k, SG).unwrap();
        let e = ienv(&[("n", 1048576)]);
        assert_eq!(
            s.op_count(DType::F32, "madd").eval(&e),
            Rat::new(1048576, SG as i128)
        );
        let stores: f64 = s
            .mem_matching(|m| m.direction == Direction::Store)
            .map(|m| m.count_at_granularity(SG).eval_f64(&e))
            .sum();
        assert_eq!(stores, 1048576.0);
    }

    #[test]
    fn matvec_has_uniform_x_loads() {
        let k = build_matvec(DType::F32).unwrap();
        let s = crate::stats::gather(&k, SG).unwrap();
        let x = s
            .mem_matching(|m| m.tag.as_deref() == Some("xLD"))
            .next()
            .unwrap();
        assert_eq!(x.granularity, crate::stats::Granularity::SubGroup);
        let a = s
            .mem_matching(|m| m.tag.as_deref() == Some("aLD"))
            .next()
            .unwrap();
        let e = ienv(&[("n", 2048)]);
        assert_eq!(a.lstrides[0].eval(&e), Rat::int(2048));
    }

    #[test]
    fn fdiff_microbench_keeps_halo_footprint() {
        let mut args = VariantArgs::default();
        args.map.insert("lsize".into(), "16".into());
        args.map.insert("n".into(), "2016".into());
        let g = gen_gmem_from_fdiff(&args).unwrap();
        let s = crate::stats::gather(&g.kernel, SG).unwrap();
        let loads: Vec<_> = s
            .mem_matching(|m| {
                m.scope == MemScope::Global
                    && m.direction == Direction::Load
                    && m.array == "u"
            })
            .collect();
        assert_eq!(loads.len(), 1);
        // One fetch per work-item: (n/14)^2 groups * 256 threads.
        let e = ienv(&[("n", 2016)]);
        assert_eq!(
            loads[0].count_wi.eval(&e),
            Rat::int((2016 / 14) * (2016 / 14) * 256)
        );
    }
}
