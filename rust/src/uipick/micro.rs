//! Microbenchmark generators (paper §7.1.2): kernels designed to
//! exercise a *single* feature — arithmetic throughput, a global
//! memory access pattern, local-memory traffic, barriers, kernel/WG
//! launch overhead, and the §7.4 overlap-ratio kernel.

use std::collections::BTreeMap;

use super::{ints, strs, GeneratedKernel, Generator, VariantArgs};
use crate::ir::{
    Access, AffExpr, ArrayDecl, DType, Expr, Kernel, LhsRef, Stmt,
};
use crate::polyhedral::{LoopExtent, NestedDomain, QPoly};
use crate::transform::assume;

/// Common 1-D work-item grid: `nelements` work-items in 16x16 groups.
/// Returns (kernel, flat work-item index expression).
fn wi_grid(name: &str, extra_params: &[&str]) -> (Kernel, AffExpr) {
    let ngroups = QPoly::var("nelements").scale(crate::util::Rat::new(1, 256));
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("wg", ngroups),
        LoopExtent::zero_to("li1", QPoly::int(16)),
        LoopExtent::zero_to("li0", QPoly::int(16)),
    ]);
    let mut params = vec!["nelements"];
    params.extend_from_slice(extra_params);
    let mut knl = Kernel::new(name, &params, dom);
    knl.assumptions = crate::polyhedral::Assumptions::none()
        .divisible_by("nelements", 256)
        .at_least("nelements", 256);
    knl.iname_tags
        .insert("wg".into(), crate::ir::IndexTag::Group(0));
    knl.iname_tags
        .insert("li1".into(), crate::ir::IndexTag::Local(1));
    knl.iname_tags
        .insert("li0".into(), crate::ir::IndexTag::Local(0));
    let flat = AffExpr::scaled_var("wg", 256)
        .plus(&AffExpr::scaled_var("li1", 16))
        .plus(&AffExpr::var("li0"));
    (knl, flat)
}

fn env(pairs: &[(&str, i64)]) -> BTreeMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

/// Arithmetic-throughput kernel: per work-item, `m` iterations of 32
/// `op` updates on private values, then one stride-1 store (kept so
/// compilers cannot drop the chain; §7.1.2 "Arithmetic operations").
pub fn build_flops(op: &str, dtype: DType) -> Result<Kernel, String> {
    let (mut knl, flat) = wi_grid(&format!("flops_{op}"), &["m"]);
    // Extra loops: m iterations x 32 updates.
    knl.domain
        .loops
        .push(LoopExtent::zero_to("r", QPoly::var("m")));
    knl.domain
        .loops
        .push(LoopExtent::zero_to("uvar", QPoly::int(32)));
    knl.add_array(ArrayDecl::global(
        "out",
        dtype,
        vec![QPoly::var("nelements")],
    ));
    knl.add_temp("t0", dtype);
    knl.add_temp("t1", dtype);
    knl.add_stmt(Stmt::new(
        "init0",
        LhsRef::Temp("t0".into()),
        Expr::fconst(0.5),
        &[],
    ));
    knl.add_stmt(Stmt::new(
        "init1",
        LhsRef::Temp("t1".into()),
        Expr::fconst(1.0000001),
        &[],
    ));
    let body = match op {
        "madd" => Expr::add(Expr::temp("t0"), Expr::mul(Expr::temp("t1"), Expr::temp("t1"))),
        "mul" => Expr::mul(Expr::temp("t0"), Expr::temp("t1")),
        "add" => Expr::add(Expr::temp("t0"), Expr::temp("t1")),
        "div" => Expr::div(Expr::temp("t0"), Expr::temp("t1")),
        other => return Err(format!("unknown flops op '{other}'")),
    };
    knl.add_stmt(
        Stmt::new("upd", LhsRef::Temp("t0".into()), body, &["r", "uvar"])
            .with_deps(&["init0", "init1"]),
    );
    knl.add_stmt(
        Stmt::new(
            "store",
            LhsRef::Array(Access::tagged("out", "outST", vec![flat])),
            Expr::temp("t0"),
            &[],
        )
        .with_deps(&["upd"]),
    );
    Ok(knl)
}

/// Global-memory pattern kernel: each work-item loads from `n_arrays`
/// input arrays at a configurable (lid_stride_0, lid_stride_1) pattern
/// and stores the sum stride-1 (§7.1.2 "Global memory access", simple
/// AFR-1 variety).
pub fn build_gmem_pattern(
    dtype: DType,
    s0: i64,
    s1: i64,
    n_arrays: i64,
) -> Result<Kernel, String> {
    let (mut knl, flat) = wi_grid("gmem_pattern", &[]);
    // Per-group span keeps groups disjoint: AFR exactly 1.
    let span = s0 * 15 + s1 * 15 + 1;
    let idx = AffExpr::scaled_var("wg", span)
        .plus(&AffExpr::scaled_var("li1", s1))
        .plus(&AffExpr::scaled_var("li0", s0));
    let arr_size = QPoly::var("nelements").scale(crate::util::Rat::new(span as i128, 256));
    let mut rhs: Option<Expr> = None;
    for a in 0..n_arrays {
        let name = format!("in{a}");
        knl.add_array(ArrayDecl::global(&name, dtype, vec![arr_size.clone()]));
        let ld = Expr::load(Access::tagged(&name, "patLD", vec![idx.clone()]));
        rhs = Some(match rhs {
            None => ld,
            Some(prev) => Expr::add(prev, ld),
        });
    }
    knl.add_array(ArrayDecl::global(
        "out",
        dtype,
        vec![QPoly::var("nelements")],
    ));
    knl.add_stmt(Stmt::new(
        "s",
        LhsRef::Array(Access::tagged("out", "outST", vec![flat])),
        rhs.ok_or("n_arrays must be >= 1")?,
        &[],
    ));
    Ok(knl)
}

/// Local-memory traffic kernel (§7.1.2 "Local memory access"):
/// thread-private moves within a local array, no barriers.  `stride`
/// sets the lid(0) stride of the moves: 1 is conflict-free; larger
/// strides exercise bank conflicts (used to calibrate the
/// stride-characterized local features the DG model employs).
pub fn build_lmem_move(dtype: DType, stride: i64) -> Result<Kernel, String> {
    let (mut knl, flat) = wi_grid("lmem_move", &["m"]);
    knl.name = format!("lmem_move_s{stride}");
    knl.domain
        .loops
        .push(LoopExtent::zero_to("r", QPoly::var("m")));
    knl.add_array(ArrayDecl::local(
        "larr",
        dtype,
        vec![QPoly::int((512 * stride) as i128)],
    ));
    knl.add_array(ArrayDecl::global(
        "out",
        dtype,
        vec![QPoly::var("nelements")],
    ));
    knl.add_temp("t0", dtype);
    let wi_l = AffExpr::scaled_var("li1", 16 * stride)
        .plus(&AffExpr::scaled_var("li0", stride));
    knl.add_stmt(Stmt::new(
        "linit",
        LhsRef::Array(Access::new("larr", vec![wi_l.clone()])),
        Expr::fconst(1.0),
        &[],
    ));
    knl.add_stmt(
        Stmt::new(
            "mv_load",
            LhsRef::Temp("t0".into()),
            Expr::load(Access::new("larr", vec![wi_l.clone()])),
            &["r"],
        )
        .with_deps(&["linit"]),
    );
    knl.add_stmt(
        Stmt::new(
            "mv_store",
            LhsRef::Array(Access::new(
                "larr",
                vec![wi_l.plus_cst(256 * stride)],
            )),
            Expr::temp("t0"),
            &["r"],
        )
        .with_deps(&["mv_load"]),
    );
    knl.add_stmt(
        Stmt::new(
            "store",
            LhsRef::Array(Access::tagged("out", "outST", vec![flat])),
            Expr::temp("t0"),
            &[],
        )
        .with_deps(&["mv_store"]),
    );
    Ok(knl)
}

/// Barrier kernel: cross-work-item local traffic forces one barrier
/// per iteration (plus one up front).
pub fn build_barrier_pattern(dtype: DType) -> Result<Kernel, String> {
    let (mut knl, flat) = wi_grid("barrier_pattern", &["m"]);
    knl.domain
        .loops
        .push(LoopExtent::zero_to("r", QPoly::var("m")));
    knl.add_array(ArrayDecl::local("larr", dtype, vec![QPoly::int(256)]));
    knl.add_array(ArrayDecl::global(
        "out",
        dtype,
        vec![QPoly::var("nelements")],
    ));
    knl.add_temp("t0", dtype);
    let wi_l = AffExpr::scaled_var("li1", 16).plus(&AffExpr::var("li0"));
    // Reversed index: a genuinely cross-thread exchange.
    let rev = AffExpr::cst(255)
        .plus(&AffExpr::scaled_var("li1", -16))
        .plus(&AffExpr::scaled_var("li0", -1));
    knl.add_stmt(Stmt::new(
        "linit",
        LhsRef::Array(Access::new("larr", vec![wi_l.clone()])),
        Expr::fconst(1.0),
        &[],
    ));
    knl.add_stmt(
        Stmt::new(
            "xch_load",
            LhsRef::Temp("t0".into()),
            Expr::load(Access::new("larr", vec![rev])),
            &["r"],
        )
        .with_deps(&["linit"]),
    );
    knl.add_stmt(
        Stmt::new(
            "xch_store",
            LhsRef::Array(Access::new("larr", vec![wi_l])),
            Expr::temp("t0"),
            &["r"],
        )
        .with_deps(&["xch_load"]),
    );
    knl.add_stmt(
        Stmt::new(
            "store",
            LhsRef::Array(Access::tagged("out", "outST", vec![flat])),
            Expr::temp("t0"),
            &[],
        )
        .with_deps(&["xch_store"]),
    );
    Ok(knl)
}

/// Empty kernel: launches `n_groups` 256-item work-groups that do
/// nothing — reveals kernel-launch and per-work-group overheads
/// (§6.1.4).
pub fn build_empty() -> Result<Kernel, String> {
    let dom = NestedDomain::new(vec![
        LoopExtent::zero_to("wg", QPoly::var("n_groups")),
        LoopExtent::zero_to("li0", QPoly::int(256)),
    ]);
    let mut knl = Kernel::new("empty_kernel", &["n_groups"], dom);
    knl.iname_tags
        .insert("wg".into(), crate::ir::IndexTag::Group(0));
    knl.iname_tags
        .insert("li0".into(), crate::ir::IndexTag::Local(0));
    Ok(knl)
}

/// §7.4 overlap kernel: one global load, `m` local load-store
/// sequences, one global store per work-item.
pub fn build_overlap_ratio(dtype: DType) -> Result<Kernel, String> {
    let (mut knl, flat) = wi_grid("overlap_ratio", &["m"]);
    knl.domain
        .loops
        .push(LoopExtent::zero_to("r", QPoly::var("m")));
    knl.add_array(ArrayDecl::global(
        "inp",
        dtype,
        vec![QPoly::var("nelements")],
    ));
    knl.add_array(ArrayDecl::global(
        "out",
        dtype,
        vec![QPoly::var("nelements")],
    ));
    knl.add_array(ArrayDecl::local("larr", dtype, vec![QPoly::int(512)]));
    knl.add_temp("t0", dtype);
    let wi_l = AffExpr::scaled_var("li1", 16).plus(&AffExpr::var("li0"));
    knl.add_stmt(Stmt::new(
        "gload",
        LhsRef::Array(Access::new("larr", vec![wi_l.clone()])),
        Expr::load(Access::tagged("inp", "patLD", vec![flat.clone()])),
        &[],
    ));
    knl.add_stmt(
        Stmt::new(
            "mv_load",
            LhsRef::Temp("t0".into()),
            Expr::load(Access::new("larr", vec![wi_l.clone()])),
            &["r"],
        )
        .with_deps(&["gload"]),
    );
    knl.add_stmt(
        Stmt::new(
            "mv_store",
            LhsRef::Array(Access::new("larr", vec![wi_l.plus_cst(256)])),
            Expr::temp("t0"),
            &["r"],
        )
        .with_deps(&["mv_load"]),
    );
    knl.add_stmt(
        Stmt::new(
            "gstore",
            LhsRef::Array(Access::tagged("out", "outST", vec![flat])),
            Expr::temp("t0"),
            &[],
        )
        .with_deps(&["mv_store"]),
    );
    Ok(knl)
}

fn dtype_of(args: &VariantArgs) -> Result<DType, String> {
    DType::parse(args.get("dtype")?).ok_or_else(|| "bad dtype".to_string())
}

fn gen_flops(op: &'static str) -> fn(&VariantArgs) -> Result<GeneratedKernel, String> {
    match op {
        "madd" => |args| gen_flops_impl("madd", args),
        "mul" => |args| gen_flops_impl("mul", args),
        "add" => |args| gen_flops_impl("add", args),
        _ => |args| gen_flops_impl("div", args),
    }
}

fn gen_flops_impl(op: &str, args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let kernel = assume(
        &build_flops(op, dtype_of(args)?)?,
        "m >= 1",
    )?;
    Ok(GeneratedKernel {
        kernel: kernel.freeze(),
        generator: format!("flops_{op}_pattern"),
        args: args.clone(),
        env: env(&[
            ("nelements", args.get_i64("nelements")?),
            ("m", args.get_i64("m")?),
        ]),
    })
}

fn gen_gmem_pattern(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    let kernel = build_gmem_pattern(
        dtype_of(args)?,
        args.get_i64("lid_stride_0")?,
        args.get_i64("lid_stride_1")?,
        args.get_i64("n_arrays")?,
    )?;
    Ok(GeneratedKernel {
        kernel: kernel.freeze(),
        generator: "gmem_pattern".into(),
        args: args.clone(),
        env: env(&[("nelements", args.get_i64("nelements")?)]),
    })
}

fn gen_lmem(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_lmem_move(dtype_of(args)?, args.get_i64("stride")?)?.freeze(),
        generator: "lmem_move".into(),
        args: args.clone(),
        env: env(&[
            ("nelements", args.get_i64("nelements")?),
            ("m", args.get_i64("m")?),
        ]),
    })
}

fn gen_barrier(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_barrier_pattern(dtype_of(args)?)?.freeze(),
        generator: "barrier_pattern".into(),
        args: args.clone(),
        env: env(&[
            ("nelements", args.get_i64("nelements")?),
            ("m", args.get_i64("m")?),
        ]),
    })
}

fn gen_empty(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_empty()?.freeze(),
        generator: "empty_kernel".into(),
        args: args.clone(),
        env: env(&[("n_groups", args.get_i64("n_groups")?)]),
    })
}

fn gen_overlap(args: &VariantArgs) -> Result<GeneratedKernel, String> {
    Ok(GeneratedKernel {
        kernel: build_overlap_ratio(dtype_of(args)?)?.freeze(),
        generator: "overlap_ratio".into(),
        args: args.clone(),
        env: env(&[
            ("nelements", args.get_i64("nelements")?),
            ("m", args.get_i64("m")?),
        ]),
    })
}

/// Microbenchmark generators.
pub fn generators() -> Vec<Generator> {
    let flops_domains = || {
        vec![
            ("dtype", strs(&["float32", "float64"])),
            ("lsize_0", ints(&[16])),
            ("lsize_1", ints(&[16])),
            ("nelements", ints(&[524288, 786432, 1048576, 1310720])),
            ("m", ints(&[1024, 1152, 1280, 1408])),
        ]
    };
    vec![
        Generator {
            name: "flops_madd_pattern",
            tags: &["flops_madd_pattern", "flops", "micro"],
            arg_domains: flops_domains(),
            build: gen_flops("madd"),
        },
        Generator {
            name: "flops_mul_pattern",
            tags: &["flops_mul_pattern", "flops", "micro"],
            arg_domains: flops_domains(),
            build: gen_flops("mul"),
        },
        Generator {
            name: "flops_add_pattern",
            tags: &["flops_add_pattern", "flops", "micro"],
            arg_domains: flops_domains(),
            build: gen_flops("add"),
        },
        Generator {
            name: "flops_div_pattern",
            tags: &["flops_div_pattern", "flops", "micro"],
            arg_domains: flops_domains(),
            build: gen_flops("div"),
        },
        Generator {
            name: "gmem_pattern",
            tags: &["gmem_pattern", "gmem", "micro"],
            arg_domains: vec![
                ("dtype", strs(&["float32", "float64"])),
                ("lid_stride_0", ints(&[1, 2, 4, 32])),
                ("lid_stride_1", ints(&[16, 64, 2048])),
                ("n_arrays", ints(&[1, 2])),
                (
                    "nelements",
                    ints(&[1048576, 2097152, 4194304, 8388608]),
                ),
            ],
            build: gen_gmem_pattern,
        },
        Generator {
            name: "lmem_move",
            tags: &["lmem_move", "lmem", "micro"],
            arg_domains: vec![
                ("dtype", strs(&["float32"])),
                ("stride", ints(&[1, 16])),
                ("nelements", ints(&[262144, 524288, 1048576])),
                ("m", ints(&[256, 512, 1024, 2048])),
            ],
            build: gen_lmem,
        },
        Generator {
            name: "barrier_pattern",
            tags: &["barrier_pattern", "sync", "micro"],
            arg_domains: vec![
                ("dtype", strs(&["float32"])),
                ("nelements", ints(&[262144, 524288])),
                ("m", ints(&[64, 128, 256, 512])),
            ],
            build: gen_barrier,
        },
        Generator {
            name: "empty_kernel",
            tags: &["empty_kernel", "launch", "micro"],
            arg_domains: vec![(
                "n_groups",
                ints(&[16, 64, 512, 4096, 16384, 65536]),
            )],
            build: gen_empty,
        },
        Generator {
            name: "overlap_ratio",
            tags: &["overlap_ratio", "overlap", "micro"],
            arg_domains: vec![
                ("dtype", strs(&["float32"])),
                ("nelements", ints(&[4194304, 8388608])),
                ("m", ints(&[0, 1, 2, 4, 8, 12, 16, 24, 32, 48, 64])),
            ],
            build: gen_overlap,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::gather;
    use crate::util::Rat;

    fn ienv(pairs: &[(&str, i128)]) -> BTreeMap<String, i128> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    #[test]
    fn flops_kernel_counts_32m_ops_per_workitem() {
        for op in ["madd", "mul", "add", "div"] {
            let k = build_flops(op, DType::F32).unwrap();
            let s = gather(&k, 32).unwrap();
            let c = s.op_count(DType::F32, op);
            // nelements=512*256?: take nelements=262144, m=100:
            // total WI ops = 262144 * 32 * 100; SG granularity /32.
            assert_eq!(
                c.eval(&ienv(&[("nelements", 262144), ("m", 100)])),
                Rat::int(262144 * 32 * 100 / 32),
                "{op}"
            );
        }
    }

    #[test]
    fn gmem_pattern_strides_are_configurable() {
        let k = build_gmem_pattern(DType::F32, 2, 64, 2).unwrap();
        let s = gather(&k, 32).unwrap();
        let e = ienv(&[("nelements", 1048576)]);
        let lds: Vec<_> = s
            .mem_matching(|m| m.tag.as_deref() == Some("patLD"))
            .collect();
        assert_eq!(lds.len(), 2);
        for m in lds {
            assert_eq!(m.lstrides[0].eval(&e), Rat::int(2));
            assert_eq!(m.lstrides[1].eval(&e), Rat::int(64));
            // AFR exactly 1: disjoint per-group spans.
            assert!((m.afr(&e) - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn lmem_move_is_barrier_free() {
        let k = build_lmem_move(DType::F32, 1).unwrap();
        let sched = crate::schedule::linearize(&k).unwrap();
        assert!(sched.barrier_count(&k).is_zero());
        let s = gather(&k, 32).unwrap();
        let e = ienv(&[("nelements", 262144), ("m", 100)]);
        let local: f64 = s
            .mem_matching(|m| m.scope == crate::ir::MemScope::Local)
            .map(|m| m.count_at_granularity(32).eval_f64(&e))
            .sum();
        // (1 init + m loads + m stores + 1 final... final reads t0 not
        // larr) => 2m+1 per WI -> /32 per SG.
        assert_eq!(local, (262144.0 * (2.0 * 100.0 + 1.0)) / 32.0);
    }

    #[test]
    fn barrier_pattern_scales_with_m() {
        let k = build_barrier_pattern(DType::F32).unwrap();
        let sched = crate::schedule::linearize(&k).unwrap();
        let c = sched.barrier_count(&k);
        let at = |m: i128| c.eval(&ienv(&[("nelements", 262144), ("m", m)]));
        let d1 = at(65) - at(64);
        assert_eq!(d1, Rat::int(1), "barriers/iteration: {d1}");
        assert!(at(64) >= Rat::int(64));
    }

    #[test]
    fn empty_kernel_has_only_launch_cost() {
        let k = build_empty().unwrap();
        let s = gather(&k, 32).unwrap();
        assert!(s.ops.is_empty());
        assert!(s.mem.is_empty());
        assert_eq!(
            s.num_groups.eval(&ienv(&[("n_groups", 4096)])),
            Rat::int(4096)
        );
    }

    #[test]
    fn overlap_kernel_ratio_is_controllable() {
        let k = build_overlap_ratio(DType::F32).unwrap();
        let s = gather(&k, 32).unwrap();
        let e = ienv(&[("nelements", 4194304), ("m", 8)]);
        let gl: f64 = s
            .mem_matching(|m| {
                m.scope == crate::ir::MemScope::Global
            })
            .map(|m| m.count_at_granularity(32).eval_f64(&e))
            .sum();
        let ll: f64 = s
            .mem_matching(|m| m.scope == crate::ir::MemScope::Local)
            .map(|m| m.count_at_granularity(32).eval_f64(&e))
            .sum();
        // global: 2 per WI (work-item granularity); local: 2m + 1 per
        // WI (init store + m load/store pairs; the final global store
        // reads the private temp), reported at sub-group granularity.
        let ratio = ll * 32.0 / gl;
        assert!(
            (ratio - (2.0 * 8.0 + 1.0) / 2.0).abs() < 1e-9,
            "ratio {ratio}"
        );
    }

    #[test]
    fn overlap_time_flattens_then_grows_on_overlap_devices() {
        // §7.4 / Fig. 5: on high-overlap devices small m is hidden
        // behind the global traffic; past the crossover time grows.
        let dev = crate::gpusim::device_by_id("titan_v").unwrap();
        let k = build_overlap_ratio(DType::F32).unwrap();
        let t = |m: i64| {
            crate::gpusim::simulate_time(
                &dev,
                &k,
                &env(&[("nelements", 4194304), ("m", m)]),
            )
            .unwrap()
        };
        let (t0, t4, t64) = (t(0), t(4), t(64));
        assert!(
            (t4 - t0) / t0 < 0.25,
            "m=4 should be mostly hidden: {t0} -> {t4}"
        );
        assert!(t64 > 2.0 * t0, "m=64 must dominate: {t0} -> {t64}");

        // On Fermi (no overlap) even small m adds visible cost.
        let fermi = crate::gpusim::device_by_id("tesla_c2070").unwrap();
        let tf = |m: i64| {
            crate::gpusim::simulate_time(
                &fermi,
                &k,
                &env(&[("nelements", 4194304), ("m", m)]),
            )
            .unwrap()
        };
        let (f0, f4) = (tf(0), tf(4));
        assert!(
            (f4 - f0) / f0 > 0.10,
            "Fermi should not hide m=4: {f0} -> {f4}"
        );
    }
}
