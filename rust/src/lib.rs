//! # perflex — cross-machine black-box GPU performance modeling
//!
//! A full-system reproduction of Stevens & Klöckner, *"A mechanism for
//! balancing accuracy and scope in cross-machine black-box GPU
//! performance modeling"* (IJHPCA 2020), built as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the Loopy-like polyhedral kernel IR and
//!   transformations, symbolic operation counting, the Perflex feature
//!   and model DSL, the UiPiCK measurement-kernel generator collection,
//!   the Levenberg-Marquardt calibrator, a simulated five-GPU fleet
//!   (substituting for the paper's physical testbed; warp-32 NVIDIA
//!   parts plus a wavefront-64 GCN3 part), and the experiment
//!   coordinator that regenerates every table and figure.
//!
//! The paper's Section 5 amortization — "symbolic counts are computed
//! once per kernel, cheaply re-evaluated for new problem sizes" — is
//! enforced at three scopes:
//!
//! * **per process** by [`stats::StatsCache`], a shared memoization of
//!   [`stats::gather`] keyed by (structural kernel fingerprint,
//!   sub-group size) that measurement, feature gathering, prediction
//!   and the coordinator's parallel fleet loops all share;
//! * **per kernel** by [`ir::FrozenKernel`]: UiPiCK freezes every
//!   generated kernel, minting its fingerprint exactly once, so cache
//!   lookups never re-render the IR — and feature columns are
//!   [bound](features::FeatureSpec::bind) once per kernel and batched
//!   across problem sizes;
//! * **across processes** by [`session::Session`]: the pipeline engine
//!   (measure → gather features → fit → predict) both the CLI and
//!   [`coordinator::run_experiment`] consume, with an optional
//!   disk-backed [`session::ArtifactStore`] (`perflex --store <dir>`)
//!   that persists symbolic statistics and calibration fits — repeat
//!   runs start warm and `predict` skips refitting entirely.
//!
//! * **L2/L1 (python/compile, build-time only)** — the batched model
//!   evaluation + Jacobian + LM step, with the hot block written as a
//!   Pallas kernel, AOT-lowered to HLO text and executed from Rust via
//!   the PJRT CPU client ([`runtime`]).
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod analysis;
pub mod gpusim;
pub mod ir;
pub mod bench_harness;
pub mod calibrate;
pub mod coordinator;
pub mod features;
pub mod model;
pub mod polyhedral;
pub mod runtime;
pub mod schedule;
pub mod session;
pub mod stats;
pub mod transform;
pub mod uipick;
pub mod util;
