//! Kernel statistics gathering (paper Section 5, Algorithms 1 and 2).
//!
//! Produces symbolic, problem-size-parametric counts:
//!
//! * arithmetic operations by (dtype, op) with multiply-add fusion,
//!   counted at **sub-group** granularity;
//! * memory accesses classified by scope, direction, data width, local
//!   and global thread-axis strides, with per-access footprints and
//!   access-to-footprint ratios (AFR); global accesses count per
//!   **work-item**, except `lid(0)`-stride-0 ("uniform") accesses which
//!   count per sub-group; local accesses count per sub-group;
//! * per-work-item barrier counts (via the linearized schedule);
//! * launch statistics (work-group count, work-group size).
//!
//! All counts are [`QPoly`]s: computed once per kernel, cheaply
//! re-evaluated for new problem sizes (the paper's amortization).
//!
//! The amortization is enforced, not just enabled: [`StatsCache`]
//! (see [`cache`]) memoizes [`gather`] results by (structural kernel
//! fingerprint, sub-group size) behind interior mutability.  Simulated
//! measurement, feature gathering, prediction and the experiment
//! coordinator all share one cache per run — including across the
//! scoped threads of parallel fleet calibration — so each distinct
//! kernel pays the polyhedral counting pass exactly once and every
//! further use is a cheap `QPoly` re-evaluation.
//!
//! The amortization also crosses process boundaries: a cache built
//! with [`StatsCache::with_backing`] persists entries through a
//! [`StatsBacking`] (the disk-backed
//! [`crate::session::ArtifactStore`]), so repeated CLI invocations
//! against the same `--store` directory skip the counting pass
//! entirely.  Lookups are keyed by precomputed
//! [`crate::ir::FrozenKernel`] fingerprints on the hot paths, so the
//! IR is rendered at most once per kernel (at freeze time), not once
//! per lookup.

use std::collections::BTreeMap;

pub mod cache;

pub use cache::{StatsBacking, StatsCache, StatsKey};

use crate::ir::{Access, DType, IndexTag, Kernel, LhsRef, MemScope, Stmt};
use crate::polyhedral::QPoly;
use crate::schedule::linearize;
use crate::util::Rat;

/// Load or store.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    Load,
    Store,
}

impl Direction {
    pub fn feature_name(&self) -> &'static str {
        match self {
            Direction::Load => "load",
            Direction::Store => "store",
        }
    }
}

/// Counting granularity of an operation (paper Section 5).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Granularity {
    WorkItem,
    SubGroup,
}

/// One classified memory access (one array reference in one statement).
#[derive(Clone, Debug)]
pub struct MemAccessStat {
    pub stmt_id: String,
    pub array: String,
    pub tag: Option<String>,
    pub scope: MemScope,
    pub direction: Direction,
    pub dtype: DType,
    /// Stride in elements along local axes 0..3.
    pub lstrides: [QPoly; 3],
    /// Stride in elements along group axes 0..3.
    pub gstrides: [QPoly; 3],
    /// Total executions at work-item granularity.
    pub count_wi: QPoly,
    /// Index-footprint size in elements (Algorithm 2, box image).
    pub footprint: QPoly,
    /// Footprint restricted to a single work-group (group inames
    /// pinned): the per-WG tile the simulator checks against L1.
    pub footprint_per_wg: QPoly,
    /// Modeled cost granularity per the paper's counting rules.
    pub granularity: Granularity,
    /// Stride in elements w.r.t. each enclosing *sequential* iname
    /// (Table 1's "loop stride"; drives the simulator's DRAM-locality
    /// model).  Ordered outer → inner.
    pub loop_strides: Vec<(String, QPoly)>,
}

impl MemAccessStat {
    /// Count at the access's modeled granularity for sub-group size
    /// `sg` (exact division expected for our 256-item work-groups).
    pub fn count_at_granularity(&self, sg: u64) -> QPoly {
        match self.granularity {
            Granularity::WorkItem => self.count_wi.clone(),
            Granularity::SubGroup => self.count_wi.scale(Rat::new(1, sg as i128)),
        }
    }

    /// Access-to-footprint ratio at concrete parameter values.
    ///
    /// The footprint is a per-axis bounding box (Algorithm 2); for
    /// strided patterns that skip elements the box over-approximates
    /// the image, so it is clamped by the access count (an access can
    /// never touch more elements than it performs) — keeping AFR >= 1.
    pub fn afr(&self, env: &BTreeMap<String, i128>) -> f64 {
        let count = self.count_wi.eval_f64(env);
        let fp = self.footprint.eval_f64(env).min(count);
        if fp == 0.0 {
            return 0.0;
        }
        count / fp
    }
}

/// Aggregated arithmetic count for one (dtype, op) pair.
#[derive(Clone, Debug)]
pub struct OpStat {
    pub dtype: DType,
    /// `add`, `sub`, `mul`, `div`, or `madd`.
    pub op: String,
    /// Count at sub-group granularity (already divided by `sg`).
    pub count_sg: QPoly,
}

/// Full statistics bundle for a kernel.
#[derive(Clone, Debug)]
pub struct KernelStats {
    pub kernel_name: String,
    pub ops: Vec<OpStat>,
    pub mem: Vec<MemAccessStat>,
    /// Barriers encountered by a single work-item.
    pub barriers_per_wi: QPoly,
    /// Total work-group count.
    pub num_groups: QPoly,
    pub work_group_size: u64,
    pub sub_group_size: u64,
}

impl KernelStats {
    /// Sum of op counts matching dtype/op (sub-group granularity).
    pub fn op_count(&self, dtype: DType, op: &str) -> QPoly {
        self.ops
            .iter()
            .filter(|o| o.dtype == dtype && o.op == op)
            .fold(QPoly::zero(), |acc, o| &acc + &o.count_sg)
    }

    /// Memory accesses matching a predicate.
    pub fn mem_matching<'a>(
        &'a self,
        pred: impl Fn(&MemAccessStat) -> bool + 'a,
    ) -> impl Iterator<Item = &'a MemAccessStat> {
        self.mem.iter().filter(move |m| pred(m))
    }
}

/// Work-item-granularity execution count of a statement: the projected
/// domain count times the extents of parallel axes the statement is
/// uniform over (every work-group executes every statement; work-items
/// execute uniformly along local axes absent from `within`).
pub fn stmt_exec_count_wi(knl: &Kernel, stmt: &Stmt) -> QPoly {
    let dom = knl.stmt_domain(stmt);
    let mut count = dom.count();
    for axis in 0..3u8 {
        for tag in [IndexTag::Group(axis), IndexTag::Local(axis)] {
            let covered = stmt
                .within
                .iter()
                .any(|i| knl.tag(i) == tag);
            if !covered {
                let extent = match tag {
                    IndexTag::Group(a) => knl.gsize(a),
                    IndexTag::Local(a) => QPoly::int(knl.lsize(a) as i128),
                    _ => unreachable!(),
                };
                count = &count * &extent;
            }
        }
    }
    knl.assumptions.simplify(&count)
}

/// Symbolic per-axis range `[min, max]` of an affine subscript over the
/// statement's iteration space (interval arithmetic over the domain
/// bounds; parameters contribute their own value).  With `pin_groups`,
/// group-tagged inames are treated like parameters (pinned to one
/// work-group), giving the per-WG tile range.
fn subscript_range(
    knl: &Kernel,
    idx: &crate::ir::AffExpr,
    pin_groups: bool,
) -> (QPoly, QPoly) {
    let mut min = QPoly::int(idx.constant as i128);
    let mut max = min.clone();
    for (v, c) in &idx.terms {
        let pinned =
            pin_groups && matches!(knl.tag(v), crate::ir::IndexTag::Group(_));
        let (lo, hi) = match knl.domain.loops.iter().find(|l| &l.var == v) {
            Some(l) if !pinned => (l.lo.clone(), l.hi.clone()),
            _ => (QPoly::var(v), QPoly::var(v)), // parameter / pinned
        };
        let c = Rat::int(*c as i128);
        if c > Rat::ZERO {
            min = &min + &lo.scale(c);
            max = &max + &hi.scale(c);
        } else {
            min = &min + &hi.scale(c);
            max = &max + &lo.scale(c);
        }
    }
    (min, max)
}

/// Algorithm 2: per-access footprint size in elements (box image of the
/// iteration space under the affine index map).
fn access_footprint(knl: &Kernel, access: &Access, pin_groups: bool) -> QPoly {
    let mut size = QPoly::one();
    for idx in &access.indices {
        let (min, max) = subscript_range(knl, idx, pin_groups);
        let extent = &(&max - &min) + &QPoly::one();
        size = &size * &extent;
    }
    knl.assumptions.simplify(&size)
}

/// Statement result dtype (type inference: the LHS's declared type).
fn stmt_dtype(knl: &Kernel, stmt: &Stmt) -> DType {
    match &stmt.lhs {
        LhsRef::Temp(t) => knl.temps[t].dtype,
        LhsRef::Array(a) => knl.arrays[&a.array].dtype,
    }
}

fn classify_access(
    knl: &Kernel,
    stmt: &Stmt,
    access: &Access,
    direction: Direction,
    count_wi: &QPoly,
) -> MemAccessStat {
    let decl = &knl.arrays[&access.array];
    let mk_strides = |f: &dyn Fn(u8) -> QPoly| -> [QPoly; 3] {
        [
            knl.assumptions.simplify(&f(0)),
            knl.assumptions.simplify(&f(1)),
            knl.assumptions.simplify(&f(2)),
        ]
    };
    let lstrides = mk_strides(&|ax| knl.lid_stride(access, ax));
    let gstrides = mk_strides(&|ax| knl.gid_stride(access, ax));
    // Uniform global accesses (lid(0) stride 0) count per sub-group;
    // local accesses always count per sub-group (on-chip).
    let granularity = match decl.scope {
        MemScope::Global if lstrides[0].is_zero() => Granularity::SubGroup,
        MemScope::Global => Granularity::WorkItem,
        _ => Granularity::SubGroup,
    };
    let loop_strides = stmt
        .within
        .iter()
        .filter(|i| !knl.tag(i).is_parallel())
        .map(|i| {
            (
                i.clone(),
                knl.assumptions.simplify(&knl.loop_stride(access, i)),
            )
        })
        .collect();
    MemAccessStat {
        stmt_id: stmt.id.clone(),
        array: access.array.clone(),
        tag: access.tag.clone(),
        scope: decl.scope,
        direction,
        dtype: decl.dtype,
        lstrides,
        gstrides,
        count_wi: count_wi.clone(),
        footprint: access_footprint(knl, access, false),
        footprint_per_wg: access_footprint(knl, access, true),
        granularity,
        loop_strides,
    }
}

/// Gather all statistics for a kernel (Algorithm 1 driver).
pub fn gather(knl: &Kernel, sub_group_size: u64) -> Result<KernelStats, String> {
    knl.validate()?;
    let sched = linearize(knl)?;

    let mut ops: BTreeMap<(DType, String), QPoly> = BTreeMap::new();
    let mut mem: Vec<MemAccessStat> = Vec::new();

    for stmt in &knl.stmts {
        let count_wi = stmt_exec_count_wi(knl, stmt);
        let count_sg = count_wi.scale(Rat::new(1, sub_group_size as i128));
        let dtype = stmt_dtype(knl, stmt);

        // Arithmetic (sub-group granularity).
        let oc = stmt.rhs.count_ops();
        for (name, n) in [
            ("add", oc.add),
            ("sub", oc.sub),
            ("mul", oc.mul),
            ("div", oc.div),
            ("madd", oc.madd),
        ] {
            if n > 0 {
                let add = count_sg.scale(Rat::int(n as i128));
                let e = ops
                    .entry((dtype, name.to_string()))
                    .or_insert_with(QPoly::zero);
                *e = &*e + &add;
            }
        }

        // Memory accesses.
        for l in stmt.rhs.loads() {
            if knl.arrays[&l.array].scope == MemScope::Private {
                continue;
            }
            mem.push(classify_access(knl, stmt, l, Direction::Load, &count_wi));
        }
        if let LhsRef::Array(a) = &stmt.lhs {
            if knl.arrays[&a.array].scope != MemScope::Private {
                mem.push(classify_access(knl, stmt, a, Direction::Store, &count_wi));
            }
        }
    }

    Ok(KernelStats {
        kernel_name: knl.name.clone(),
        ops: ops
            .into_iter()
            .map(|((dtype, op), count_sg)| OpStat {
                dtype,
                op,
                count_sg: knl.assumptions.simplify(&count_sg),
            })
            .collect(),
        mem,
        barriers_per_wi: sched.barrier_count(knl),
        num_groups: knl.num_groups(),
        work_group_size: knl.work_group_size(),
        sub_group_size,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{ArrayDecl, Expr};
    use crate::ir::AffExpr;
    use crate::polyhedral::{LoopExtent, NestedDomain};
    use crate::transform::{add_prefetch, assume, split_iname, tag_inames};

    fn env(n: i128) -> BTreeMap<String, i128> {
        [("n".to_string(), n)].into_iter().collect()
    }

    fn matmul(prefetch: bool) -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let mut k = Kernel::new("matmul", &["n"], dom);
        for name in ["a", "b", "c"] {
            k.add_array(ArrayDecl::global(
                name,
                DType::F32,
                vec![n.clone(), n.clone()],
            ));
        }
        k.add_temp("acc", DType::F32);
        k.add_stmt(Stmt::new(
            "init",
            LhsRef::Temp("acc".into()),
            Expr::fconst(0.0),
            &["i", "j"],
        ));
        k.add_stmt(
            Stmt::new(
                "upd",
                LhsRef::Temp("acc".into()),
                Expr::add(
                    Expr::temp("acc"),
                    Expr::mul(
                        Expr::load(Access::tagged(
                            "a",
                            "aLD",
                            vec![AffExpr::var("i"), AffExpr::var("k")],
                        )),
                        Expr::load(Access::tagged(
                            "b",
                            "bLD",
                            vec![AffExpr::var("k"), AffExpr::var("j")],
                        )),
                    ),
                ),
                &["i", "j", "k"],
            )
            .with_deps(&["init"]),
        );
        k.add_stmt(
            Stmt::new(
                "store",
                LhsRef::Array(Access::new(
                    "c",
                    vec![AffExpr::var("i"), AffExpr::var("j")],
                )),
                Expr::temp("acc"),
                &["i", "j"],
            )
            .with_deps(&["upd"]),
        );
        let k = assume(&k, "n >= 16 and n % 16 = 0").unwrap();
        let k = split_iname(&k, "i", 16).unwrap();
        let k = split_iname(&k, "j", 16).unwrap();
        let mut k = tag_inames(&k, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0").unwrap();
        if prefetch {
            k = split_iname(&k, "k", 16).unwrap();
            k = add_prefetch(&k, "a", &["i_in", "k_in"], false).unwrap();
            k = add_prefetch(&k, "b", &["k_in", "j_in"], false).unwrap();
        }
        k
    }

    #[test]
    fn madd_count_is_n_cubed_over_subgroup() {
        // f_madd at sub-group granularity = n^3 / 32.
        for pf in [false, true] {
            let k = matmul(pf);
            let s = gather(&k, 32).unwrap();
            let madd = s.op_count(DType::F32, "madd");
            assert_eq!(
                madd.eval(&env(1024)),
                Rat::new(1024i128.pow(3), 32),
                "prefetch={pf}"
            );
        }
    }

    #[test]
    fn global_load_counts_with_and_without_prefetch() {
        // Without prefetch: n^3 work-item loads each of a and b.
        let k = matmul(false);
        let s = gather(&k, 32).unwrap();
        let count = |arr: &str| -> Rat {
            s.mem_matching(|m| {
                m.array == arr && m.direction == Direction::Load
            })
            .fold(QPoly::zero(), |acc, m| &acc + &m.count_at_granularity(32))
            .eval(&env(1024))
        };
        assert_eq!(count("b"), Rat::int(1024i128.pow(3)));
        // `a[i, k]` is uniform in lid(0) (j_in): counted per sub-group.
        assert_eq!(count("a"), Rat::new(1024i128.pow(3), 32));

        // With prefetch: 16x fewer global loads, all per work-item.
        let k = matmul(true);
        let s = gather(&k, 32).unwrap();
        let count_pf = |arr: &str| -> Rat {
            s.mem_matching(|m| {
                m.array == arr
                    && m.direction == Direction::Load
                    && m.scope == MemScope::Global
            })
            .fold(QPoly::zero(), |acc, m| &acc + &m.count_at_granularity(32))
            .eval(&env(1024))
        };
        assert_eq!(count_pf("a"), Rat::new(1024i128.pow(3), 16));
        assert_eq!(count_pf("b"), Rat::new(1024i128.pow(3), 16));
    }

    #[test]
    fn local_traffic_counts_per_subgroup() {
        // Prefetch variant: 2 local loads per madd -> 2 n^3 work-item
        // local loads -> n^3/16 at sub-group granularity; local stores
        // = 2 * n^3/16 work-item = n^3/128 per sub-group... (16x fewer).
        let k = matmul(true);
        let s = gather(&k, 32).unwrap();
        let local_loads = s
            .mem_matching(|m| {
                m.scope == MemScope::Local && m.direction == Direction::Load
            })
            .fold(QPoly::zero(), |acc, m| &acc + &m.count_at_granularity(32))
            .eval(&env(1024));
        assert_eq!(local_loads, Rat::new(2 * 1024i128.pow(3), 32));
        let local_stores = s
            .mem_matching(|m| {
                m.scope == MemScope::Local && m.direction == Direction::Store
            })
            .fold(QPoly::zero(), |acc, m| &acc + &m.count_at_granularity(32))
            .eval(&env(1024));
        assert_eq!(local_stores, Rat::new(2 * 1024i128.pow(3), 16 * 32));
    }

    #[test]
    fn afr_matches_table1() {
        // Table 1: AFR of the prefetch loads of a and b is n/16.
        let k = matmul(true);
        let s = gather(&k, 32).unwrap();
        let e = env(2048);
        for tag in ["aLD", "bLD"] {
            let m = s
                .mem_matching(|m| m.tag.as_deref() == Some(tag))
                .next()
                .unwrap_or_else(|| panic!("no access tagged {tag}"));
            assert_eq!(m.footprint.eval(&e), Rat::int(2048 * 2048), "{tag}");
            let afr = m.afr(&e);
            assert!((afr - 2048.0 / 16.0).abs() < 1e-9, "{tag}: afr={afr}");
        }
    }

    #[test]
    fn store_pattern_is_stride1_wi() {
        let k = matmul(true);
        let s = gather(&k, 32).unwrap();
        let st = s
            .mem_matching(|m| m.array == "c" && m.direction == Direction::Store)
            .next()
            .unwrap();
        let e = env(1024);
        assert_eq!(st.lstrides[0].eval(&e), Rat::int(1));
        assert_eq!(st.granularity, Granularity::WorkItem);
        assert_eq!(st.count_wi.eval(&e), Rat::int(1024 * 1024));
    }

    #[test]
    fn barriers_and_launch_stats() {
        let k = matmul(true);
        let s = gather(&k, 32).unwrap();
        assert_eq!(s.barriers_per_wi.eval(&env(1024)), Rat::int(128));
        assert_eq!(s.num_groups.eval(&env(1024)), Rat::int(64 * 64));
        assert_eq!(s.work_group_size, 256);

        let k = matmul(false);
        let s = gather(&k, 32).unwrap();
        assert_eq!(s.barriers_per_wi, QPoly::zero());
    }

    #[test]
    fn uniform_access_detected_per_subgroup() {
        // A load whose lid(0) stride is 0 counts per sub-group.
        let k = matmul(false);
        let s = gather(&k, 32).unwrap();
        let a_ld = s
            .mem_matching(|m| m.tag.as_deref() == Some("aLD"))
            .next()
            .unwrap();
        // a[i, k]: i = 16 i_out + i_in (lid 1), k sequential: no lid(0).
        assert!(a_ld.lstrides[0].is_zero());
        assert_eq!(a_ld.granularity, Granularity::SubGroup);
        let b_ld = s
            .mem_matching(|m| m.tag.as_deref() == Some("bLD"))
            .next()
            .unwrap();
        assert_eq!(b_ld.granularity, Granularity::WorkItem);
    }

    #[test]
    fn counts_reevaluate_across_sizes() {
        let k = matmul(true);
        let s = gather(&k, 32).unwrap();
        let madd = s.op_count(DType::F32, "madd");
        for n in [256i128, 512, 2048, 3584] {
            assert_eq!(madd.eval(&env(n)), Rat::new(n.pow(3), 32));
        }
    }
}
