//! Memoized symbolic-statistics cache — making the Section 5
//! amortization claim real.
//!
//! [`gather`] derives a kernel's statistics bundle with a polyhedral
//! counting pass that is far more expensive than the per-problem-size
//! [`QPoly`](crate::polyhedral::QPoly) evaluations it enables.  The
//! seed code nevertheless re-ran the full pass on every call: once
//! inside every simulated `measure()` and once more per feature row,
//! paying roughly two passes per measurement kernel per calibration.
//!
//! [`StatsCache`] memoizes [`KernelStats`] behind interior mutability,
//! keyed by ([`Kernel::fingerprint`](crate::ir::Kernel::fingerprint),
//! sub-group size).  One shared cache is threaded through simulated
//! measurement, feature gathering, prediction and the experiment
//! coordinator — including across the scoped threads of parallel fleet
//! calibration — so each distinct kernel is symbolically counted
//! exactly once per run and only cheap `QPoly` evaluation remains per
//! problem size.  Devices that share a sub-group size share entries.
//!
//! Two refinements keep the hot path cheap beyond the memoization
//! itself:
//!
//! * lookups are generic over [`KernelRef`], so a
//!   [`FrozenKernel`](crate::ir::FrozenKernel) resolves its cache key
//!   from the fingerprint minted at freeze time instead of re-rendering
//!   the whole IR per lookup;
//! * an optional [`StatsBacking`] (implemented by
//!   [`crate::session::ArtifactStore`]) persists entries across
//!   *processes*: a miss first consults the backing, and a fresh gather
//!   is written back, so repeated CLI invocations against the same
//!   store start warm.  Backing hits are tallied separately
//!   ([`StatsCache::disk_hits`]); [`StatsCache::misses`] keeps meaning
//!   "ran the full symbolic pass".  The disk backing answers
//!   existence/validity through the store's journaled index (a
//!   hash-map lookup — see `perflex::session::index`): a vouched hit
//!   skips the probe/validate parse and decodes only the payload it
//!   fetches, while a miss still falls back to one cheap file-open
//!   probe (adopt-on-miss keeps the index an accelerator, never an
//!   authority); the store ledger (`ArtifactStore::ledger`) counts
//!   index hits vs full-artifact parses next to this cache's ledger.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::{gather, KernelStats};
use crate::ir::KernelRef;

/// One memoization slot.  The map entry is created under the map lock,
/// but the expensive gather runs inside the slot's own [`OnceLock`], so
/// concurrent misses on *different* kernels proceed in parallel while
/// concurrent misses on the *same* kernel still gather only once.
type Slot = Arc<OnceLock<Result<Arc<KernelStats>, String>>>;

/// Cache key: structural kernel fingerprint + counting sub-group size.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StatsKey {
    pub fingerprint: u128,
    pub sub_group_size: u64,
}

/// Persistence hook for cache entries (disk-backed stores implement
/// this).  `load` may return `None` for any reason — missing, stale
/// format version, parse failure — and the cache falls back to a fresh
/// gather.  `store` is best-effort: persistence failures must not fail
/// the lookup.
pub trait StatsBacking: Send + Sync {
    fn load(&self, key: &StatsKey) -> Option<KernelStats>;
    fn store(&self, key: &StatsKey, stats: &KernelStats);
}

/// Shared, interior-mutable memoization of [`gather`] results.
#[derive(Default)]
pub struct StatsCache {
    slots: Mutex<HashMap<StatsKey, Slot>>,
    backing: Option<Arc<dyn StatsBacking>>,
    hits: AtomicU64,
    disk_hits: AtomicU64,
    misses: AtomicU64,
}

impl StatsCache {
    pub fn new() -> StatsCache {
        StatsCache::default()
    }

    /// A cache whose misses consult (and whose fresh gathers populate)
    /// a persistent backing.
    pub fn with_backing(backing: Arc<dyn StatsBacking>) -> StatsCache {
        StatsCache {
            backing: Some(backing),
            ..StatsCache::default()
        }
    }

    /// Cached [`gather`]: runs the symbolic counting pass at most once
    /// per distinct (kernel fingerprint, sub-group size), even under
    /// concurrent lookups (losers of the insertion race block on the
    /// winner's slot instead of re-deriving).  Gather errors are cached
    /// and replayed too, keeping cached and fresh behavior identical.
    ///
    /// Accepts any [`KernelRef`]; pass a
    /// [`FrozenKernel`](crate::ir::FrozenKernel) to key the lookup by
    /// its precomputed fingerprint instead of re-rendering the IR.
    pub fn get_or_gather<K: KernelRef>(
        &self,
        knl: &K,
        sub_group_size: u64,
    ) -> Result<Arc<KernelStats>, String> {
        let key = StatsKey {
            fingerprint: knl.fingerprint(),
            sub_group_size,
        };
        let slot: Slot = {
            let mut slots = self.slots.lock().unwrap();
            slots.entry(key).or_default().clone()
        };
        // 0 = memory hit, 1 = backing hit, 2 = fresh gather.
        let mut outcome = 0u8;
        let res = slot.get_or_init(|| {
            if let Some(backing) = &self.backing {
                if let Some(stats) = backing.load(&key) {
                    outcome = 1;
                    return Ok(Arc::new(stats));
                }
            }
            outcome = 2;
            let gathered = gather(knl.as_kernel(), sub_group_size).map(Arc::new);
            if let (Some(backing), Ok(stats)) = (&self.backing, &gathered) {
                backing.store(&key, stats);
            }
            gathered
        });
        match outcome {
            0 => self.hits.fetch_add(1, Ordering::Relaxed),
            1 => self.disk_hits.fetch_add(1, Ordering::Relaxed),
            _ => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        res.clone()
    }

    /// Lookups served from memory.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups served from the persistent backing (no symbolic pass).
    pub fn disk_hits(&self) -> u64 {
        self.disk_hits.load(Ordering::Relaxed)
    }

    /// Lookups that ran the full symbolic pass.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// `(fresh counting passes, disk hits, memory hits)` — the one-line
    /// ledger store-backed CLI commands print, and what the shared-store
    /// CI job asserts on ("0 fresh counting passes" for a device whose
    /// sub-group twin already populated the store).
    pub fn ledger(&self) -> (u64, u64, u64) {
        (self.misses(), self.disk_hits(), self.hits())
    }

    /// Distinct (kernel, sub-group size) entries resident.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;
    use crate::uipick::derived::{build_axpy, build_matvec};

    #[test]
    fn hit_and_miss_accounting() {
        let cache = StatsCache::new();
        let k = build_axpy(DType::F32).unwrap();
        let a = cache.get_or_gather(&k, 32).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (1, 0));
        let b = cache.get_or_gather(&k, 32).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached bundle");
        // A different sub-group size is a distinct entry...
        cache.get_or_gather(&k, 64).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (2, 1));
        // ... and so is a structurally different kernel.
        let m = build_matvec(DType::F32).unwrap();
        cache.get_or_gather(&m, 32).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (3, 1));
        assert_eq!(cache.len(), 3);
        assert!(!cache.is_empty());
    }

    #[test]
    fn cached_stats_match_fresh_gather() {
        let cache = StatsCache::new();
        let k = build_axpy(DType::F32).unwrap();
        let cached = cache.get_or_gather(&k, 32).unwrap();
        let fresh = gather(&k, 32).unwrap();
        let env: std::collections::BTreeMap<String, i128> =
            [("n".to_string(), 1048576i128)].into_iter().collect();
        assert_eq!(
            cached.op_count(DType::F32, "madd").eval(&env),
            fresh.op_count(DType::F32, "madd").eval(&env)
        );
        assert_eq!(cached.mem.len(), fresh.mem.len());
        assert_eq!(cached.work_group_size, fresh.work_group_size);
        assert_eq!(cached.sub_group_size, fresh.sub_group_size);
    }

    #[test]
    fn concurrent_lookups_gather_once_per_key() {
        let cache = StatsCache::new();
        let k = build_axpy(DType::F32).unwrap();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| cache.get_or_gather(&k, 32).unwrap());
            }
        });
        assert_eq!(cache.misses(), 1, "the symbolic pass must run once");
        assert_eq!(cache.hits(), 7);
    }

    #[test]
    fn frozen_lookup_matches_plain_lookup() {
        let cache = StatsCache::new();
        let k = build_axpy(DType::F32).unwrap();
        let frozen = k.clone().freeze();
        let a = cache.get_or_gather(&k, 32).unwrap();
        let b = cache.get_or_gather(&frozen, 32).unwrap();
        assert!(
            Arc::ptr_eq(&a, &b),
            "frozen and plain lookups must share an entry"
        );
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
    }

    /// An in-memory backing standing in for the disk store.
    #[derive(Default)]
    struct MapBacking {
        map: Mutex<HashMap<StatsKey, KernelStats>>,
        loads: AtomicU64,
        stores: AtomicU64,
    }

    impl StatsBacking for MapBacking {
        fn load(&self, key: &StatsKey) -> Option<KernelStats> {
            self.loads.fetch_add(1, Ordering::Relaxed);
            self.map.lock().unwrap().get(key).cloned()
        }

        fn store(&self, key: &StatsKey, stats: &KernelStats) {
            self.stores.fetch_add(1, Ordering::Relaxed);
            self.map.lock().unwrap().insert(*key, stats.clone());
        }
    }

    #[test]
    fn backing_serves_warm_starts_and_absorbs_fresh_gathers() {
        let backing = Arc::new(MapBacking::default());
        let k = build_axpy(DType::F32).unwrap();

        // First process: miss -> gather -> write-through to the backing.
        let first = StatsCache::with_backing(backing.clone());
        first.get_or_gather(&k, 32).unwrap();
        assert_eq!((first.misses(), first.disk_hits()), (1, 0));
        assert_eq!(backing.stores.load(Ordering::Relaxed), 1);

        // Second process: cold memory, warm backing -> zero symbolic
        // passes, and in-memory hits thereafter.
        let second = StatsCache::with_backing(backing.clone());
        let a = second.get_or_gather(&k, 32).unwrap();
        assert_eq!((second.misses(), second.disk_hits()), (0, 1));
        let b = second.get_or_gather(&k, 32).unwrap();
        assert_eq!((second.misses(), second.disk_hits(), second.hits()), (0, 1, 1));
        assert!(Arc::ptr_eq(&a, &b));
        // The backing is only stored to on fresh gathers.
        assert_eq!(backing.stores.load(Ordering::Relaxed), 1);
    }
}
