//! Memoized symbolic-statistics cache — making the Section 5
//! amortization claim real.
//!
//! [`gather`] derives a kernel's statistics bundle with a polyhedral
//! counting pass that is far more expensive than the per-problem-size
//! [`QPoly`](crate::polyhedral::QPoly) evaluations it enables.  The
//! seed code nevertheless re-ran the full pass on every call: once
//! inside every simulated `measure()` and once more per feature row,
//! paying roughly two passes per measurement kernel per calibration.
//!
//! [`StatsCache`] memoizes [`KernelStats`] behind interior mutability,
//! keyed by ([`Kernel::fingerprint`](crate::ir::Kernel::fingerprint),
//! sub-group size).  One shared cache is threaded through simulated
//! measurement, feature gathering, prediction and the experiment
//! coordinator — including across the scoped threads of parallel fleet
//! calibration — so each distinct kernel is symbolically counted
//! exactly once per run and only cheap `QPoly` evaluation remains per
//! problem size.  Devices that share a sub-group size share entries.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use super::{gather, KernelStats};
use crate::ir::Kernel;

/// One memoization slot.  The map entry is created under the map lock,
/// but the expensive gather runs inside the slot's own [`OnceLock`], so
/// concurrent misses on *different* kernels proceed in parallel while
/// concurrent misses on the *same* kernel still gather only once.
type Slot = Arc<OnceLock<Result<Arc<KernelStats>, String>>>;

/// Cache key: structural kernel fingerprint + counting sub-group size.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct StatsKey {
    pub fingerprint: u128,
    pub sub_group_size: u64,
}

impl StatsKey {
    pub fn of(knl: &Kernel, sub_group_size: u64) -> StatsKey {
        StatsKey {
            fingerprint: knl.fingerprint(),
            sub_group_size,
        }
    }
}

/// Shared, interior-mutable memoization of [`gather`] results.
#[derive(Default)]
pub struct StatsCache {
    slots: Mutex<HashMap<StatsKey, Slot>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl StatsCache {
    pub fn new() -> StatsCache {
        StatsCache::default()
    }

    /// Cached [`gather`]: runs the symbolic counting pass at most once
    /// per distinct (kernel fingerprint, sub-group size), even under
    /// concurrent lookups (losers of the insertion race block on the
    /// winner's slot instead of re-deriving).  Gather errors are cached
    /// and replayed too, keeping cached and fresh behavior identical.
    pub fn get_or_gather(
        &self,
        knl: &Kernel,
        sub_group_size: u64,
    ) -> Result<Arc<KernelStats>, String> {
        let key = StatsKey::of(knl, sub_group_size);
        let slot: Slot = {
            let mut slots = self.slots.lock().unwrap();
            slots.entry(key).or_default().clone()
        };
        let mut fresh = false;
        let res = slot.get_or_init(|| {
            fresh = true;
            gather(knl, sub_group_size).map(Arc::new)
        });
        if fresh {
            self.misses.fetch_add(1, Ordering::Relaxed);
        } else {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        res.clone()
    }

    /// Lookups served from memory.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookups that ran the full symbolic pass.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Distinct (kernel, sub-group size) entries resident.
    pub fn len(&self) -> usize {
        self.slots.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;
    use crate::uipick::derived::{build_axpy, build_matvec};

    #[test]
    fn hit_and_miss_accounting() {
        let cache = StatsCache::new();
        let k = build_axpy(DType::F32).unwrap();
        let a = cache.get_or_gather(&k, 32).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (1, 0));
        let b = cache.get_or_gather(&k, 32).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (1, 1));
        assert!(Arc::ptr_eq(&a, &b), "hit must return the cached bundle");
        // A different sub-group size is a distinct entry...
        cache.get_or_gather(&k, 64).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (2, 1));
        // ... and so is a structurally different kernel.
        let m = build_matvec(DType::F32).unwrap();
        cache.get_or_gather(&m, 32).unwrap();
        assert_eq!((cache.misses(), cache.hits()), (3, 1));
        assert_eq!(cache.len(), 3);
        assert!(!cache.is_empty());
    }

    #[test]
    fn cached_stats_match_fresh_gather() {
        let cache = StatsCache::new();
        let k = build_axpy(DType::F32).unwrap();
        let cached = cache.get_or_gather(&k, 32).unwrap();
        let fresh = gather(&k, 32).unwrap();
        let env: std::collections::BTreeMap<String, i128> =
            [("n".to_string(), 1048576i128)].into_iter().collect();
        assert_eq!(
            cached.op_count(DType::F32, "madd").eval(&env),
            fresh.op_count(DType::F32, "madd").eval(&env)
        );
        assert_eq!(cached.mem.len(), fresh.mem.len());
        assert_eq!(cached.work_group_size, fresh.work_group_size);
        assert_eq!(cached.sub_group_size, fresh.sub_group_size);
    }

    #[test]
    fn concurrent_lookups_gather_once_per_key() {
        let cache = StatsCache::new();
        let k = build_axpy(DType::F32).unwrap();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| cache.get_or_gather(&k, 32).unwrap());
            }
        });
        assert_eq!(cache.misses(), 1, "the symbolic pass must run once");
        assert_eq!(cache.hits(), 7);
    }
}
