//! PJRT runtime: load the AOT-compiled JAX/Pallas calibration artifacts
//! and drive them from the Rust LM loop.
//!
//! `python/compile/aot.py` lowers three entry points to HLO *text*
//! (xla_extension 0.5.1 rejects jax>=0.5 serialized protos — see
//! /opt/xla-example/README.md) with fixed padded shapes recorded in
//! `artifacts/manifest.json`.  This module compiles them once on the
//! PJRT CPU client and exposes [`AotBackend`], an [`LmBackend`] for the
//! builtin three-cost-component model family.  Python never runs on
//! this path.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

// The external `xla` crate is not in the offline crate set; a local
// module of the same name shadows it with a cleanly-erroring stub (see
// xla_stub.rs for how to re-enable the real runtime).
#[path = "xla_stub.rs"]
mod xla;

use crate::calibrate::{FeatureData, LmBackend};
use crate::model::CostModel;
use crate::util::json::Json;

/// Shape contract from `manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub version: i64,
    pub l: usize,
    pub n: usize,
    pub j: usize,
    pub p: usize,
}

/// Default artifact directory (override with `PERFLEX_ARTIFACTS`).
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PERFLEX_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// True if the AOT artifacts appear to be built.
pub fn artifacts_available() -> bool {
    let d = artifacts_dir();
    d.join("manifest.json").exists() && d.join("lm_step.hlo.txt").exists()
}

/// Compiled AOT executables.
pub struct Artifacts {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    lm_step: xla::PjRtLoadedExecutable,
    predict: xla::PjRtLoadedExecutable,
    eval_cost: xla::PjRtLoadedExecutable,
}

fn load_exe(
    client: &xla::PjRtClient,
    dir: &Path,
    file: &str,
) -> Result<xla::PjRtLoadedExecutable, String> {
    let path = dir.join(file);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| format!("loading {}: {e}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| format!("compiling {}: {e}", path.display()))
}

impl Artifacts {
    /// Load and compile all artifacts from the default directory.
    pub fn load() -> Result<Artifacts, String> {
        Self::load_from(&artifacts_dir())
    }

    pub fn load_from(dir: &Path) -> Result<Artifacts, String> {
        let mtext = std::fs::read_to_string(dir.join("manifest.json"))
            .map_err(|e| format!("reading manifest: {e}"))?;
        let m = Json::parse(&mtext)?;
        let get = |k: &str| -> Result<i64, String> {
            m.get(k)
                .and_then(Json::as_i64)
                .ok_or_else(|| format!("manifest missing '{k}'"))
        };
        let manifest = Manifest {
            version: get("version")?,
            l: get("L")? as usize,
            n: get("N")? as usize,
            j: get("J")? as usize,
            p: get("P")? as usize,
        };
        let client = xla::PjRtClient::cpu().map_err(|e| format!("PJRT: {e}"))?;
        Ok(Artifacts {
            lm_step: load_exe(&client, dir, "lm_step.hlo.txt")?,
            predict: load_exe(&client, dir, "predict.hlo.txt")?,
            eval_cost: load_exe(&client, dir, "eval_cost.hlo.txt")?,
            manifest,
            client,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn matrix_literal(
        &self,
        data: &[f64],
        rows: usize,
        cols: usize,
    ) -> Result<xla::Literal, String> {
        assert_eq!(data.len(), rows * cols);
        xla::Literal::vec1(data)
            .reshape(&[rows as i64, cols as i64])
            .map_err(|e| format!("reshape: {e}"))
    }

    /// Run one fused LM step.  All arrays are padded to manifest shapes.
    /// Returns (pred[L], resid[L], delta[P], cost).
    #[allow(clippy::too_many_arguments)]
    pub fn lm_step(
        &self,
        f: &[f64],
        t: &[f64],
        mask: &[f64],
        groups: &[f64],
        p: &[f64],
        mode: f64,
        lam: f64,
    ) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, f64), String> {
        let (l, j, np) = (self.manifest.l, self.manifest.j, self.manifest.p);
        let args = [
            self.matrix_literal(f, l, j)?,
            xla::Literal::vec1(t),
            xla::Literal::vec1(mask),
            self.matrix_literal(groups, 3, j)?,
            xla::Literal::vec1(p),
            xla::Literal::scalar(mode),
            xla::Literal::scalar(lam),
        ];
        let result = self
            .lm_step
            .execute::<xla::Literal>(&args)
            .map_err(|e| format!("lm_step execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("lm_step fetch: {e}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| format!("lm_step tuple: {e}"))?;
        if parts.len() != 5 {
            return Err(format!("lm_step returned {} outputs", parts.len()));
        }
        let as_vec = |lit: &xla::Literal| -> Result<Vec<f64>, String> {
            lit.to_vec::<f64>().map_err(|e| format!("to_vec: {e}"))
        };
        let pred = as_vec(&parts[0])?;
        let resid = as_vec(&parts[1])?;
        let delta = as_vec(&parts[3])?;
        let cost = as_vec(&parts[4])?[0];
        debug_assert_eq!(delta.len(), np);
        Ok((pred, resid, delta, cost))
    }

    /// Masked SSE cost at `p`.
    pub fn eval_cost(
        &self,
        f: &[f64],
        t: &[f64],
        mask: &[f64],
        groups: &[f64],
        p: &[f64],
        mode: f64,
    ) -> Result<f64, String> {
        let (l, j) = (self.manifest.l, self.manifest.j);
        let args = [
            self.matrix_literal(f, l, j)?,
            xla::Literal::vec1(t),
            xla::Literal::vec1(mask),
            self.matrix_literal(groups, 3, j)?,
            xla::Literal::vec1(p),
            xla::Literal::scalar(mode),
        ];
        let result = self
            .eval_cost
            .execute::<xla::Literal>(&args)
            .map_err(|e| format!("eval_cost execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("eval_cost fetch: {e}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| format!("eval_cost tuple: {e}"))?;
        Ok(out.to_vec::<f64>().map_err(|e| format!("{e}"))?[0])
    }

    /// Batched prediction for up to `manifest.n` rows.
    pub fn predict(
        &self,
        f: &[f64],
        groups: &[f64],
        p: &[f64],
        mode: f64,
    ) -> Result<Vec<f64>, String> {
        let (n, j) = (self.manifest.n, self.manifest.j);
        let args = [
            self.matrix_literal(f, n, j)?,
            self.matrix_literal(groups, 3, j)?,
            xla::Literal::vec1(p),
            xla::Literal::scalar(mode),
        ];
        let result = self
            .predict
            .execute::<xla::Literal>(&args)
            .map_err(|e| format!("predict execute: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| format!("predict fetch: {e}"))?;
        let out = result
            .to_tuple1()
            .map_err(|e| format!("predict tuple: {e}"))?;
        out.to_vec::<f64>().map_err(|e| format!("{e}"))
    }
}

/// AOT-accelerated LM backend for the builtin cost-model family.
pub struct AotBackend<'a> {
    artifacts: &'a Artifacts,
    /// Padded [L x J] feature matrix (row-major).
    f: Vec<f64>,
    t: Vec<f64>,
    mask: Vec<f64>,
    /// Padded [3 x J] group masks.
    groups: Vec<f64>,
    mode: f64,
    /// Real (unpadded) parameter count (J_real + 1).
    pub n_params: usize,
    j_real: usize,
}

impl<'a> AotBackend<'a> {
    /// Pad the feature data and group masks of a cost model into the
    /// artifact's fixed shapes.
    pub fn new(
        artifacts: &'a Artifacts,
        cm: &CostModel,
        data: &FeatureData,
    ) -> Result<AotBackend<'a>, String> {
        let (l, j) = (artifacts.manifest.l, artifacts.manifest.j);
        let j_real = cm.terms.len();
        if data.len() > l {
            return Err(format!(
                "measurement set of {} rows exceeds artifact capacity {l}",
                data.len()
            ));
        }
        if j_real > j {
            return Err(format!(
                "model with {j_real} features exceeds artifact capacity {j}"
            ));
        }
        if data.feature_ids != cm.feature_columns() {
            return Err("feature data column order must match the cost model".into());
        }
        let mut f = vec![0.0; l * j];
        let mut t = vec![0.0; l];
        let mut mask = vec![0.0; l];
        for (r, row) in data.rows.iter().enumerate() {
            f[r * j..r * j + j_real].copy_from_slice(row);
            t[r] = data.outputs[r];
            mask[r] = 1.0;
        }
        let gm = cm.groups_matrix();
        let mut groups = vec![0.0; 3 * j];
        for (gi, grow) in gm.iter().enumerate() {
            groups[gi * j..gi * j + j_real].copy_from_slice(grow);
        }
        Ok(AotBackend {
            artifacts,
            f,
            t,
            mask,
            groups,
            mode: cm.mode(),
            n_params: j_real + 1,
            j_real,
        })
    }

    fn pad_params(&self, p: &[f64]) -> Vec<f64> {
        let np = self.artifacts.manifest.p;
        let mut out = vec![0.0; np];
        out[..self.j_real].copy_from_slice(&p[..self.j_real]);
        // p_edge lives in the final artifact slot.
        out[np - 1] = p[self.n_params - 1];
        out
    }
}

impl LmBackend for AotBackend<'_> {
    fn cost(&mut self, p: &[f64]) -> Result<f64, String> {
        self.artifacts.eval_cost(
            &self.f,
            &self.t,
            &self.mask,
            &self.groups,
            &self.pad_params(p),
            self.mode,
        )
    }

    fn step(&mut self, p: &[f64], lam: f64) -> Result<(Vec<f64>, f64), String> {
        let (_, _, delta_pad, cost) = self.artifacts.lm_step(
            &self.f,
            &self.t,
            &self.mask,
            &self.groups,
            &self.pad_params(p),
            self.mode,
            lam,
        )?;
        let np = self.artifacts.manifest.p;
        let mut delta = vec![0.0; self.n_params];
        delta[..self.j_real].copy_from_slice(&delta_pad[..self.j_real]);
        delta[self.n_params - 1] = delta_pad[np - 1];
        Ok((delta, cost))
    }
}

/// Environment-variable hook: `BTreeMap` of param name -> value, used
/// by the coordinator's fit entry points.
pub fn fit_cost_model_aot(
    artifacts: &Artifacts,
    cm: &CostModel,
    data: &FeatureData,
    opts: &crate::calibrate::LmOptions,
) -> Result<crate::calibrate::FitResult, String> {
    let mut backend = AotBackend::new(artifacts, cm, data)?;
    let p0 = crate::calibrate::initial_params(data, cm.terms.len(), true);
    let mut opts = opts.clone();
    if opts.lower_bounds.is_none() {
        opts.lower_bounds =
            crate::calibrate::LmOptions::cost_model_bounds(cm.terms.len()).lower_bounds;
    }
    let mut fit = crate::calibrate::levenberg_marquardt(
        &mut backend,
        cm.param_names(),
        p0,
        &opts,
    )?;
    fit.target = data.target;
    Ok(fit)
}

/// Fit the same cost model natively (ablation / fallback path).
pub fn fit_cost_model_native(
    cm: &CostModel,
    data: &FeatureData,
    opts: &crate::calibrate::LmOptions,
) -> Result<crate::calibrate::FitResult, String> {
    let model = cm.to_model();
    let names = cm.param_names();
    let p0 = crate::calibrate::initial_params(data, cm.terms.len(), true);
    let mut opts = opts.clone();
    if opts.lower_bounds.is_none() {
        opts.lower_bounds =
            crate::calibrate::LmOptions::cost_model_bounds(cm.terms.len()).lower_bounds;
    }
    let mut backend =
        crate::calibrate::NativeBackend::with_params(&model, data, names.clone());
    let mut fit =
        crate::calibrate::levenberg_marquardt(&mut backend, names, p0, &opts)?;
    fit.target = data.target;
    Ok(fit)
}

/// Helper shared by tests and the coordinator: mapping from (BTreeMap)
/// fit output.
pub fn params_map(fit: &crate::calibrate::FitResult) -> BTreeMap<String, f64> {
    fit.param_names
        .iter()
        .cloned()
        .zip(fit.params.iter().copied())
        .collect()
}
