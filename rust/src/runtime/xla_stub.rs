//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The AOT calibration path executes HLO artifacts on a PJRT CPU
//! client through the external `xla` crate, which is not part of the
//! offline crate set this repository must build against.  This module
//! mirrors exactly the API surface `runtime` consumes; every operation
//! that would need the real runtime returns a descriptive error from
//! [`PjRtClient::cpu`] / [`HloModuleProto::from_text_file`], so
//! [`super::Artifacts::load`] fails cleanly and callers fall back to
//! the native symbolic backend ([`super::fit_cost_model_native`]).
//! `artifacts_available()` is file-based and artifacts are not shipped,
//! so in practice this path is never reached in offline builds.
//!
//! To enable the real AOT path, add the `xla` dependency to Cargo.toml
//! and delete the `mod xla` declaration in `runtime/mod.rs` (the
//! extern crate then resolves the same paths).

use std::fmt;
use std::path::Path;

/// Error type mirroring the external crate's (only `Display` is used).
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "XLA/PJRT runtime not available in this build (stubbed '{what}'); \
             the AOT path requires the external `xla` crate"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// Host-side tensor handle.  Constructors succeed (they carry no data
/// in the stub); anything that would read results back errors.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar(_v: f64) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    pub fn to_tuple1(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(
        &self,
        _comp: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}
