//! Program linearization and barrier placement (paper §5, barrier
//! counting: "the program linearization is found automatically by a
//! search procedure and determines the ordering of statements and the
//! nesting of loops, which enables a subsequent procedure that
//! determines synchronization locations").
//!
//! The linearized schedule drives three consumers: barrier counting
//! (statistics), the OpenCL-like pseudo-code listing, and the GPU
//! simulator's per-work-group execution walk.

use std::collections::BTreeMap;

use crate::ir::{Kernel, LhsRef, MemScope, Stmt};
use crate::polyhedral::QPoly;

/// One node of the linearized schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum ScheduleItem {
    /// Execute statement (index into `kernel.stmts`).
    Stmt(usize),
    /// Work-group-wide local barrier.
    Barrier,
    /// A sequential loop over `iname`.
    Loop {
        iname: String,
        body: Vec<ScheduleItem>,
    },
}

/// A linearized kernel schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Schedule {
    pub items: Vec<ScheduleItem>,
}

impl Schedule {
    /// Per-work-item barrier count (a quasi-polynomial in the problem
    /// size), i.e. the number of `barrier()` calls one work-item passes
    /// through — the paper multiplies this by the work-group count in
    /// models.
    pub fn barrier_count(&self, knl: &Kernel) -> QPoly {
        fn walk(items: &[ScheduleItem], knl: &Kernel, trip: &QPoly, acc: &mut QPoly) {
            for it in items {
                match it {
                    ScheduleItem::Barrier => *acc = &*acc + trip,
                    ScheduleItem::Loop { iname, body } => {
                        let l = knl
                            .domain
                            .loops
                            .iter()
                            .find(|l| &l.var == iname)
                            .expect("scheduled loop not in domain");
                        let t = trip * &l.extent();
                        walk(body, knl, &t, acc);
                    }
                    ScheduleItem::Stmt(_) => {}
                }
            }
        }
        let mut acc = QPoly::zero();
        walk(&self.items, knl, &QPoly::one(), &mut acc);
        knl.assumptions.simplify(&acc)
    }

    /// Flat listing for debugging / the pseudo-code generator.
    pub fn listing(&self, knl: &Kernel) -> String {
        fn walk(items: &[ScheduleItem], knl: &Kernel, depth: usize, out: &mut String) {
            let pad = "  ".repeat(depth);
            for it in items {
                match it {
                    ScheduleItem::Stmt(i) => {
                        let s = &knl.stmts[*i];
                        out.push_str(&format!("{pad}{}: {} = {}\n", s.id, s.lhs, s.rhs));
                    }
                    ScheduleItem::Barrier => {
                        out.push_str(&format!("{pad}barrier(CLK_LOCAL_MEM_FENCE);\n"))
                    }
                    ScheduleItem::Loop { iname, body } => {
                        out.push_str(&format!("{pad}for {iname} {{\n"));
                        walk(body, knl, depth + 1, out);
                        out.push_str(&format!("{pad}}}\n"));
                    }
                }
            }
        }
        let mut out = String::new();
        walk(&self.items, knl, 0, &mut out);
        out
    }
}

/// Which local arrays a statement writes / reads, restricted to arrays
/// in `communicating` (arrays whose accesses actually cross work-item
/// boundaries).
fn local_io(
    knl: &Kernel,
    s: &Stmt,
    communicating: &[String],
) -> (Vec<String>, Vec<String>) {
    let mut writes = Vec::new();
    let mut reads = Vec::new();
    if let LhsRef::Array(a) = &s.lhs {
        if knl.arrays[&a.array].scope == MemScope::Local
            && communicating.contains(&a.array)
        {
            writes.push(a.array.clone());
        }
    }
    for l in s.rhs.loads() {
        if knl.arrays[&l.array].scope == MemScope::Local
            && communicating.contains(&l.array)
        {
            reads.push(l.array.clone());
        }
    }
    (writes, reads)
}

/// Local arrays that are accessed with more than one distinct
/// local-iname coefficient signature: data written by one work-item is
/// (potentially) read by another, so barriers are required.  Arrays
/// whose every access shares one lid mapping are thread-private in
/// pattern (the lmem microbenchmark's shape) and need no barrier.
pub(crate) fn communicating_local_arrays(knl: &Kernel) -> Vec<String> {
    use std::collections::BTreeMap;
    let mut sigs: BTreeMap<String, Vec<Vec<(String, QPoly)>>> = BTreeMap::new();
    let mut record = |knl: &Kernel, a: &crate::ir::Access| {
        if knl.arrays[&a.array].scope != MemScope::Local {
            return;
        }
        let lf = knl.flatten_access(a);
        let sig: Vec<(String, QPoly)> = lf
            .coeffs
            .iter()
            .filter(|(v, _)| knl.tag(v).is_parallel())
            .map(|(v, c)| (v.clone(), c.clone()))
            .collect();
        let e = sigs.entry(a.array.clone()).or_default();
        if !e.contains(&sig) {
            e.push(sig);
        }
    };
    for s in &knl.stmts {
        for l in s.rhs.loads() {
            record(knl, l);
        }
        if let LhsRef::Array(a) = &s.lhs {
            record(knl, a);
        }
    }
    sigs.into_iter()
        .filter(|(_, v)| v.len() > 1)
        .map(|(k, _)| k)
        .collect()
}

/// Linearize a kernel: nest statements into their sequential loops,
/// ordering groups topologically by dependencies, then insert local
/// barriers.
pub fn linearize(knl: &Kernel) -> Result<Schedule, String> {
    knl.validate()?;
    // Sequential loop path per statement (parallel inames are not
    // runtime loops).
    let paths: Vec<Vec<String>> = knl
        .stmts
        .iter()
        .map(|s| {
            s.within
                .iter()
                .filter(|i| !knl.tag(i).is_parallel())
                .cloned()
                .collect()
        })
        .collect();
    let idx: Vec<usize> = (0..knl.stmts.len()).collect();
    let mut items = build_level(knl, &idx, &paths, 0)?;
    let communicating = communicating_local_arrays(knl);
    insert_barriers(knl, &mut items, false, &communicating);
    Ok(Schedule { items })
}

/// Group statements at nesting `depth` and order the groups
/// topologically (groups are atomic; cyclic inter-group deps error).
fn build_level(
    knl: &Kernel,
    stmts: &[usize],
    paths: &[Vec<String>],
    depth: usize,
) -> Result<Vec<ScheduleItem>, String> {
    // Group key: next sequential iname at this depth, or None (leaf).
    let mut groups: Vec<(Option<String>, Vec<usize>)> = Vec::new();
    for &si in stmts {
        let key = paths[si].get(depth).cloned();
        match groups.iter_mut().find(|(k, _)| *k == key && k.is_some()) {
            Some((_, members)) => members.push(si),
            None => groups.push((key, vec![si])),
        }
    }

    // Topological order over groups induced by statement deps.
    let gidx_of = |si: usize| -> usize {
        groups
            .iter()
            .position(|(_, members)| members.contains(&si))
            .unwrap()
    };
    let n = groups.len();
    let mut edges: Vec<(usize, usize)> = Vec::new();
    for &si in stmts {
        for dep in &knl.stmts[si].deps {
            if let Some(di) = knl.stmts.iter().position(|s| &s.id == dep) {
                if stmts.contains(&di) {
                    let (gd, gs) = (gidx_of(di), gidx_of(si));
                    if gd != gs && !edges.contains(&(gd, gs)) {
                        edges.push((gd, gs));
                    }
                }
            }
        }
    }
    let mut order: Vec<usize> = Vec::new();
    let mut placed = vec![false; n];
    while order.len() < n {
        let next = (0..n).find(|&g| {
            !placed[g] && edges.iter().all(|(a, b)| *b != g || placed[*a])
        });
        match next {
            Some(g) => {
                placed[g] = true;
                order.push(g);
            }
            None => {
                return Err(format!(
                    "linearize: cyclic loop-group dependencies in '{}' at depth {depth}",
                    knl.name
                ))
            }
        }
    }

    let mut out = Vec::new();
    for g in order {
        let (key, members) = &groups[g];
        match key {
            None => {
                for &si in members {
                    out.push(ScheduleItem::Stmt(si));
                }
            }
            Some(iname) => {
                let body = build_level(knl, members, paths, depth + 1)?;
                out.push(ScheduleItem::Loop {
                    iname: iname.clone(),
                    body,
                });
            }
        }
    }
    Ok(out)
}

/// Summarize local reads/writes of an item tree.
fn item_local_io(
    knl: &Kernel,
    item: &ScheduleItem,
    communicating: &[String],
) -> (Vec<String>, Vec<String>) {
    match item {
        ScheduleItem::Stmt(i) => local_io(knl, &knl.stmts[*i], communicating),
        ScheduleItem::Barrier => (Vec::new(), Vec::new()),
        ScheduleItem::Loop { body, .. } => {
            let mut w = Vec::new();
            let mut r = Vec::new();
            for it in body {
                let (iw, ir) = item_local_io(knl, it, communicating);
                w.extend(iw);
                r.extend(ir);
            }
            (w, r)
        }
    }
}

/// Insert local barriers:
///  * between a local write and a later local read of the same array
///    within one sequence (RAW across work-items), and
///  * at the head of a loop body that both reads and writes a local
///    array (WAR across iterations — the paper's matmul shows exactly
///    this two-barrier-per-iteration pattern).
fn insert_barriers(
    knl: &Kernel,
    items: &mut Vec<ScheduleItem>,
    is_loop_body: bool,
    communicating: &[String],
) {
    // Recurse first.
    for it in items.iter_mut() {
        if let ScheduleItem::Loop { body, .. } = it {
            insert_barriers(knl, body, true, communicating);
        }
    }
    let io: Vec<(Vec<String>, Vec<String>)> = items
        .iter()
        .map(|it| item_local_io(knl, it, communicating))
        .collect();

    // RAW: find the last writer before the first reader of any array
    // written earlier in the sequence.
    let mut insert_positions: Vec<usize> = Vec::new();
    let mut written: BTreeMap<String, usize> = BTreeMap::new();
    for (pos, (w, r)) in io.iter().enumerate() {
        for arr in r {
            if written.contains_key(arr) {
                insert_positions.push(pos);
                written.clear();
                break;
            }
        }
        for arr in w {
            written.insert(arr.clone(), pos);
        }
    }

    // WAR wraparound: loop body that reads and writes the same local
    // array needs a barrier before the first writer.
    let mut head_barrier_pos: Option<usize> = None;
    if is_loop_body {
        let reads_any: Vec<&String> = io.iter().flat_map(|(_, r)| r).collect();
        for (pos, (w, _)) in io.iter().enumerate() {
            if w.iter().any(|arr| reads_any.contains(&arr)) {
                head_barrier_pos = Some(pos);
                break;
            }
        }
    }

    // Apply inserts back-to-front.
    let mut all: Vec<usize> = insert_positions;
    if let Some(p) = head_barrier_pos {
        all.push(p);
    }
    all.sort_unstable();
    all.dedup();
    for &p in all.iter().rev() {
        items.insert(p, ScheduleItem::Barrier);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Access, AffExpr, ArrayDecl, DType, Expr};
    use crate::polyhedral::{LoopExtent, NestedDomain};
    use crate::transform::{add_prefetch, assume, split_iname, tag_inames};
    use crate::util::Rat;
    use std::collections::BTreeMap as Map;

    fn matmul(prefetch: bool) -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let mut k = Kernel::new("matmul", &["n"], dom);
        for name in ["a", "b", "c"] {
            k.add_array(ArrayDecl::global(
                name,
                DType::F32,
                vec![n.clone(), n.clone()],
            ));
        }
        k.add_temp("acc", DType::F32);
        k.add_stmt(Stmt::new(
            "init",
            LhsRef::Temp("acc".into()),
            Expr::fconst(0.0),
            &["i", "j"],
        ));
        k.add_stmt(
            Stmt::new(
                "upd",
                LhsRef::Temp("acc".into()),
                Expr::add(
                    Expr::temp("acc"),
                    Expr::mul(
                        Expr::load(Access::new(
                            "a",
                            vec![AffExpr::var("i"), AffExpr::var("k")],
                        )),
                        Expr::load(Access::new(
                            "b",
                            vec![AffExpr::var("k"), AffExpr::var("j")],
                        )),
                    ),
                ),
                &["i", "j", "k"],
            )
            .with_deps(&["init"]),
        );
        k.add_stmt(
            Stmt::new(
                "store",
                LhsRef::Array(Access::new(
                    "c",
                    vec![AffExpr::var("i"), AffExpr::var("j")],
                )),
                Expr::temp("acc"),
                &["i", "j"],
            )
            .with_deps(&["upd"]),
        );
        let k = assume(&k, "n >= 16 and n % 16 = 0").unwrap();
        let k = split_iname(&k, "i", 16).unwrap();
        let k = split_iname(&k, "j", 16).unwrap();
        let mut k = tag_inames(&k, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0").unwrap();
        if prefetch {
            k = split_iname(&k, "k", 16).unwrap();
            k = add_prefetch(&k, "a", &["i_in", "k_in"], false).unwrap();
            k = add_prefetch(&k, "b", &["k_in", "j_in"], false).unwrap();
        }
        k
    }

    fn env(n: i128) -> Map<String, i128> {
        [("n".to_string(), n)].into_iter().collect()
    }

    #[test]
    fn no_prefetch_matmul_has_no_barriers() {
        let k = matmul(false);
        let s = linearize(&k).unwrap();
        assert_eq!(s.barrier_count(&k), QPoly::zero());
        // Structure: init; loop k { upd }; store.
        assert!(matches!(s.items[0], ScheduleItem::Stmt(_)));
        assert!(matches!(s.items[1], ScheduleItem::Loop { .. }));
        assert!(matches!(s.items[2], ScheduleItem::Stmt(_)));
    }

    #[test]
    fn prefetch_matmul_has_two_barriers_per_k_out() {
        // The paper's generated kernel: per k_out iteration, one barrier
        // before the fetches and one after, i.e. count = 2 * n/16.
        let k = matmul(true);
        let s = linearize(&k).unwrap();
        let count = s.barrier_count(&k);
        assert_eq!(count.eval(&env(1024)), Rat::int(2 * 1024 / 16));
        assert_eq!(count.eval(&env(2048)), Rat::int(2 * 2048 / 16));
    }

    #[test]
    fn prefetch_schedule_orders_fetch_before_compute() {
        let k = matmul(true);
        let s = linearize(&k).unwrap();
        let listing = s.listing(&k);
        let pos = |pat: &str| listing.find(pat).unwrap_or(usize::MAX);
        assert!(pos("init") < pos("for k_out"), "{listing}");
        assert!(pos("fetch_a") < pos("for k_in"), "{listing}");
        assert!(pos("fetch_b") < pos("for k_in"), "{listing}");
        assert!(pos("for k_in") < pos("store"), "{listing}");
        // Two barriers inside k_out loop, in the expected places.
        let k_out_body = &listing[pos("for k_out")..];
        let first_barrier = k_out_body.find("barrier").unwrap();
        let fetch_pos = k_out_body.find("fetch_").unwrap();
        assert!(first_barrier < fetch_pos, "{listing}");
    }

    #[test]
    fn deps_break_textual_order() {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
        let mut k = Kernel::new("t", &["n"], dom);
        k.add_array(ArrayDecl::global("x", DType::F32, vec![n]));
        k.add_temp("t0", DType::F32);
        // Textually: consumer first, producer second; deps must flip.
        k.add_stmt(
            Stmt::new(
                "consume",
                LhsRef::Array(Access::new("x", vec![AffExpr::var("i")])),
                Expr::temp("t0"),
                &["i"],
            )
            .with_deps(&["produce"]),
        );
        k.add_stmt(Stmt::new(
            "produce",
            LhsRef::Temp("t0".into()),
            Expr::fconst(1.0),
            &["i"],
        ));
        let s = linearize(&k).unwrap();
        let listing = s.listing(&k);
        assert!(listing.find("produce").unwrap() < listing.find("consume").unwrap());
    }

    #[test]
    fn cyclic_group_deps_error() {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", QPoly::int(4)),
        ]);
        let mut k = Kernel::new("t", &["n"], dom);
        k.add_temp("t0", DType::F32);
        k.add_temp("t1", DType::F32);
        // s1 in loop i depends on s2 (loop j) and s3 (loop j) depends
        // on s0 (loop i): cycle between the i-group and j-group.
        k.add_stmt(Stmt::new("s0", LhsRef::Temp("t0".into()), Expr::fconst(0.0), &["i"]));
        k.add_stmt(
            Stmt::new("s1", LhsRef::Temp("t0".into()), Expr::temp("t1"), &["i"])
                .with_deps(&["s2"]),
        );
        k.add_stmt(Stmt::new("s2", LhsRef::Temp("t1".into()), Expr::fconst(1.0), &["j"]));
        k.add_stmt(
            Stmt::new("s3", LhsRef::Temp("t1".into()), Expr::temp("t0"), &["j"])
                .with_deps(&["s1"]),
        );
        // group(i) needs group(j) (s1<-s2) and group(j) needs group(i)
        // (s3<-s1)... both groups mutually depend -> error.
        let err = linearize(&k);
        assert!(err.is_err(), "{err:?}");
    }

    #[test]
    fn barrier_count_scales_with_problem_size() {
        let k = matmul(true);
        let s = linearize(&k).unwrap();
        let c = s.barrier_count(&k);
        // Symbolic: 2 * (n/16) = n/8.
        let expected = QPoly::var("n").scale(Rat::new(1, 8));
        assert_eq!(c, expected, "got {c}");
    }
}
