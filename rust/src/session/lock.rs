//! Cross-process concurrency primitives for the artifact store:
//! an advisory [`FileLock`] serializing writers, and a [`Lease`]
//! fencing destructive maintenance.
//!
//! Both are built on the one primitive every filesystem gives us
//! atomically: exclusive file creation (`O_CREAT | O_EXCL`).  No
//! `flock(2)` binding exists in the offline crate set, and `flock`
//! semantics differ across the network filesystems a fleet-shared
//! store is most likely to live on, so a lock *file* — created
//! atomically, removed on release — is the portable choice.  The two
//! types differ in policy, not mechanism:
//!
//! * [`FileLock`] (`<root>/index.lock`) protects short critical
//!   sections — a journal append, a snapshot checkpoint, a victim
//!   unlink — so acquisition *waits*, with bounded exponential
//!   backoff, and presumes a holder older than
//!   [`LockOptions::stale_after_secs`] crashed (its file is stolen).
//! * [`Lease`] (`<root>/gc.lease`) protects whole maintenance runs
//!   (`store gc`, `store compact`), so acquisition *refuses* while a
//!   live foreign lease exists — a second maintainer must not queue up
//!   behind the first and re-delete what it already swept — and the
//!   holder advertises an explicit expiry instead of relying on file
//!   age, so a crashed maintainer blocks the fleet for at most its
//!   TTL.
//!
//! Stale holders are stolen in two steps — rename the dead file to a
//! unique debris name, then remove the debris — so when several
//! processes notice the same corpse, exactly one rename wins and the
//! losers simply retry; nobody ever deletes a *live* holder's file,
//! and release only removes the file while it still carries the
//! releaser's own token.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

/// How long a maintenance lease protects its holder by default before
/// a fellow maintainer may presume it dead and steal it
/// (`--lease-ttl-secs` overrides).
pub const DEFAULT_LEASE_TTL_SECS: u64 = 10 * 60;

/// Seconds since the Unix epoch (0 on a pre-epoch clock).
fn unix_now_secs() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

static TOKEN_SEQ: AtomicU64 = AtomicU64::new(0);

/// A holder token unique across the processes sharing a store: pid +
/// a process-global sequence number + a nanosecond clock sample, so
/// concurrent holders (and a process's own successive acquisitions)
/// can always tell their files apart.
fn fresh_token() -> String {
    let nanos = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.subsec_nanos())
        .unwrap_or(0);
    format!(
        "{}-{}-{}",
        std::process::id(),
        TOKEN_SEQ.fetch_add(1, Ordering::Relaxed),
        nanos
    )
}

/// The holder metadata written into a lock/lease file.  Diagnostic
/// except for `token` (release-safety) and `expires_at` (lease
/// liveness); the exclusive create is the lock itself.
fn holder_json(token: &str, expires_at: Option<u64>) -> String {
    let mut fields = vec![
        ("pid", Json::from(std::process::id() as i64)),
        ("token", Json::from(token)),
        ("acquired_at", Json::from(unix_now_secs() as i64)),
    ];
    if let Some(t) = expires_at {
        fields.push(("expires_at", Json::from(t as i64)));
    }
    Json::obj(fields).to_string()
}

/// Atomically create `path` holding `content`; `Ok(false)` when it
/// already exists (someone else holds it).
fn try_create(path: &Path, content: &str) -> Result<bool, String> {
    match std::fs::OpenOptions::new()
        .write(true)
        .create_new(true)
        .open(path)
    {
        Ok(mut f) => {
            // Best-effort, and deliberately not fsynced: the metadata
            // is diagnostic (plus expiry/token bookkeeping) and the
            // exclusive create already is the acquisition — an fsync
            // here would tax every journal append, and losing the
            // content in a crash merely makes the file unreadable,
            // which observers already treat as a dead holder.
            let _ = f.write_all(content.as_bytes());
            Ok(true)
        }
        Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(false),
        Err(e) => Err(format!("creating {}: {e}", path.display())),
    }
}

/// Remove `path` iff it still carries `token` — a releaser must never
/// delete a file a stealer has since replaced.  The read-then-remove
/// pair is not atomic: a holder stalled past the staleness TTL whose
/// release interleaves exactly with a steal *and* a fresh acquisition
/// can still unlink the successor's file.  Holders avoid ever going
/// stale by calling `refresh()` during long operations, which is what
/// makes that window practically unreachable; closing it fully would
/// need link/rename tricks that do not survive all network
/// filesystems.
fn remove_if_token(path: &Path, token: &str) {
    if let Ok(text) = std::fs::read_to_string(path) {
        if text.contains(token) {
            let _ = std::fs::remove_file(path);
        }
    }
}

/// Steal a dead holder's file: rename it to a unique debris name (so
/// exactly one of several stealers wins — the losers' renames fail and
/// they retry), then remove the debris.
fn steal(path: &Path, token: &str) {
    let name = match path.file_name().and_then(|n| n.to_str()) {
        Some(n) => n,
        None => return,
    };
    let debris = path.with_file_name(format!("{name}.stale.{token}"));
    if std::fs::rename(path, &debris).is_ok() {
        let _ = std::fs::remove_file(&debris);
    }
}

/// Policy knobs for [`FileLock::acquire`].
#[derive(Clone, Copy, Debug)]
pub struct LockOptions {
    /// A lock file older than this is presumed to belong to a crashed
    /// holder and stolen.  Writer critical sections are normally
    /// milliseconds; a full rebuild scan under the lock is the long
    /// pole, so the default is generous.
    pub stale_after_secs: u64,
    /// Give up after waiting this long; callers degrade (a skipped
    /// journal line self-heals through adopt-on-miss) rather than
    /// hang.
    pub max_wait_ms: u64,
}

impl Default for LockOptions {
    fn default() -> Self {
        LockOptions {
            stale_after_secs: 60,
            max_wait_ms: 10_000,
        }
    }
}

/// An exclusive cross-process advisory lock backed by an atomically
/// created lock file.  Waits with bounded exponential backoff, steals
/// provably-stale holders, and releases on drop.
pub struct FileLock {
    path: PathBuf,
    token: String,
    contended: bool,
}

impl FileLock {
    pub fn acquire(path: &Path, opts: &LockOptions) -> Result<FileLock, String> {
        let token = fresh_token();
        let started = Instant::now();
        let mut backoff_ms = 1u64;
        let mut contended = false;
        loop {
            if try_create(path, &holder_json(&token, None))? {
                return Ok(FileLock {
                    path: path.to_path_buf(),
                    token,
                    contended,
                });
            }
            contended = true;
            // Crashed holder?  Age by mtime; steal races have exactly
            // one winner and the losers land back here.
            let age = std::fs::metadata(path)
                .and_then(|m| m.modified())
                .ok()
                .and_then(|t| SystemTime::now().duration_since(t).ok());
            if age.is_some_and(|a| a.as_secs() >= opts.stale_after_secs) {
                steal(path, &token);
                continue;
            }
            if started.elapsed() >= Duration::from_millis(opts.max_wait_ms) {
                return Err(format!(
                    "lock {} is busy (waited {}ms for the holder)",
                    path.display(),
                    opts.max_wait_ms
                ));
            }
            std::thread::sleep(Duration::from_millis(backoff_ms));
            backoff_ms = (backoff_ms * 2).min(50);
        }
    }

    /// True when this acquisition had to wait behind (or steal from)
    /// another holder — the store's lock-contention ledger counts it.
    pub fn contended(&self) -> bool {
        self.contended
    }

    /// Liveness beacon for long holds (a rebuild scan over a large
    /// store): rewrite the lock file so its mtime — the staleness
    /// clock every contender reads — restarts.  Without this, a hold
    /// outliving [`LockOptions::stale_after_secs`] looks crashed and
    /// gets stolen, voiding the exclusivity.  Best-effort and
    /// token-guarded like release.
    pub fn refresh(&self) {
        if let Ok(text) = std::fs::read_to_string(&self.path) {
            if text.contains(&self.token) {
                let _ = std::fs::write(&self.path, text);
            }
        }
    }
}

impl Drop for FileLock {
    fn drop(&mut self) {
        remove_if_token(&self.path, &self.token);
    }
}

/// A maintenance lease: holder pid + explicit expiry.  Acquisition
/// never waits — a live foreign lease is a *refusal* (the caller
/// surfaces it and exits), an expired or unreadable one is a dead
/// holder and is stolen.  Released on drop.
pub struct Lease {
    path: PathBuf,
    token: String,
}

impl Lease {
    pub fn acquire(path: &Path, ttl_secs: u64) -> Result<Lease, String> {
        let token = fresh_token();
        // Bounded retries: each round either acquires, refuses, or
        // steals a provably-dead lease (one steal winner per corpse),
        // so a handful of attempts always terminates.
        for _ in 0..8 {
            let expires_at = unix_now_secs().saturating_add(ttl_secs.max(1));
            if try_create(path, &holder_json(&token, Some(expires_at)))? {
                return Ok(Lease {
                    path: path.to_path_buf(),
                    token,
                });
            }
            let holder = std::fs::read_to_string(path)
                .ok()
                .and_then(|text| Json::parse(&text).ok());
            let expiry = holder
                .as_ref()
                .and_then(|j| j.get("expires_at"))
                .and_then(Json::as_f64)
                .filter(|s| *s >= 0.0)
                .map(|s| s as u64);
            let now = unix_now_secs();
            match expiry {
                Some(e) if e > now => {
                    let pid = holder
                        .as_ref()
                        .and_then(|j| j.get("pid"))
                        .and_then(Json::as_f64)
                        .map(|p| p as u64)
                        .unwrap_or(0);
                    return Err(format!(
                        "maintenance lease {} is held by pid {pid} (expires \
                         in {}s); refusing to run destructive maintenance \
                         under a live foreign lease",
                        path.display(),
                        e - now
                    ));
                }
                // Expired or unreadable: a dead holder.
                _ => steal(path, &token),
            }
        }
        Err(format!(
            "maintenance lease {} could not be acquired (persistent steal \
             races)",
            path.display()
        ))
    }

    /// Extend this lease to `ttl_secs` from now.  Long maintenance
    /// runs call this periodically (the store does so once per victim
    /// batch / compaction family) so a sweep can never outlive its own
    /// lease — an expired-mid-run lease would be stolen and two
    /// destructive maintainers would run concurrently, exactly what
    /// the lease exists to prevent.  Best-effort and token-guarded
    /// like release.
    pub fn refresh(&self, ttl_secs: u64) {
        if let Ok(text) = std::fs::read_to_string(&self.path) {
            if text.contains(&self.token) {
                let expires_at = unix_now_secs().saturating_add(ttl_secs.max(1));
                let _ = std::fs::write(
                    &self.path,
                    holder_json(&self.token, Some(expires_at)),
                );
            }
        }
    }
}

impl Drop for Lease {
    fn drop(&mut self) {
        remove_if_token(&self.path, &self.token);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_path(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "perflex-lock-test-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir.join("the.lock")
    }

    #[test]
    fn lock_excludes_waits_and_releases() {
        let path = tmp_path("excl");
        let first = FileLock::acquire(&path, &LockOptions::default()).unwrap();
        assert!(!first.contended());

        // A second acquisition with a short patience times out.
        let opts = LockOptions {
            stale_after_secs: 3600,
            max_wait_ms: 60,
        };
        let err = FileLock::acquire(&path, &opts).unwrap_err();
        assert!(err.contains("busy"), "{err}");
        assert!(path.exists(), "a failed acquire must not disturb the holder");

        // A patient acquisition gets the lock once the holder drops.
        let handle = std::thread::spawn({
            let path = path.clone();
            move || {
                FileLock::acquire(
                    &path,
                    &LockOptions {
                        stale_after_secs: 3600,
                        max_wait_ms: 5_000,
                    },
                )
                .unwrap()
            }
        });
        std::thread::sleep(Duration::from_millis(30));
        drop(first);
        let second = handle.join().unwrap();
        assert!(second.contended(), "the wait must be observable");
        drop(second);
        assert!(!path.exists(), "release must remove the lock file");
    }

    #[test]
    fn stale_lock_files_are_stolen() {
        let path = tmp_path("stale");
        std::fs::write(&path, "{\"pid\":999999,\"token\":\"dead\"}").unwrap();
        let f = std::fs::File::options().write(true).open(&path).unwrap();
        f.set_modified(SystemTime::now() - Duration::from_secs(3600))
            .unwrap();
        drop(f);
        let lock = FileLock::acquire(
            &path,
            &LockOptions {
                stale_after_secs: 60,
                max_wait_ms: 1_000,
            },
        )
        .unwrap();
        assert!(lock.contended(), "a theft counts as contention");
        drop(lock);
        assert!(!path.exists());
    }

    #[test]
    fn lease_refuses_live_foreign_holders_and_steals_expired_ones() {
        let path = tmp_path("lease");
        std::fs::write(
            &path,
            "{\"pid\":424242,\"token\":\"foreign\",\"expires_at\":99999999999}",
        )
        .unwrap();
        let err = Lease::acquire(&path, 60).unwrap_err();
        assert!(err.contains("424242"), "{err}");
        assert!(err.contains("refusing"), "{err}");
        assert!(path.exists(), "a refusal must not disturb the holder");

        // Expired: a dead maintainer; the lease is stolen.
        std::fs::write(
            &path,
            "{\"pid\":424242,\"token\":\"foreign\",\"expires_at\":1}",
        )
        .unwrap();
        let lease = Lease::acquire(&path, 60).unwrap();
        drop(lease);
        assert!(!path.exists(), "release must remove the lease file");

        // Unreadable: also a dead holder.
        std::fs::write(&path, "{not json").unwrap();
        let lease = Lease::acquire(&path, 60).unwrap();
        drop(lease);
        assert!(!path.exists());
    }

    /// A long hold that keeps refreshing never looks stale, so nobody
    /// steals it; the contender times out instead.
    #[test]
    fn refreshed_long_holds_are_not_stolen() {
        let path = tmp_path("refresh");
        let lock = FileLock::acquire(&path, &LockOptions::default()).unwrap();
        // Simulate a hold older than the staleness TTL...
        let f = std::fs::File::options().write(true).open(&path).unwrap();
        f.set_modified(SystemTime::now() - Duration::from_secs(3600))
            .unwrap();
        drop(f);
        // ...whose holder just proved liveness.
        lock.refresh();
        let err = FileLock::acquire(
            &path,
            &LockOptions {
                stale_after_secs: 60,
                max_wait_ms: 80,
            },
        )
        .unwrap_err();
        assert!(err.contains("busy"), "{err}");
        drop(lock);
        assert!(!path.exists(), "a refreshed lock still releases cleanly");
    }

    /// A refreshed lease advertises a new expiry, so a sweep that
    /// refreshes per batch can never be stolen mid-run.
    #[test]
    fn lease_refresh_extends_the_expiry() {
        let path = tmp_path("lease-refresh");
        let lease = Lease::acquire(&path, 1).unwrap();
        lease.refresh(3600);
        let err = Lease::acquire(&path, 60).unwrap_err();
        assert!(err.contains("refusing"), "{err}");
        drop(lease);
        assert!(!path.exists());
    }

    #[test]
    fn release_never_deletes_a_stolen_and_replaced_holder_file() {
        let path = tmp_path("replaced");
        let lock = FileLock::acquire(&path, &LockOptions::default()).unwrap();
        // Simulate a misbehaving stealer replacing the file mid-hold.
        std::fs::write(&path, "{\"pid\":1,\"token\":\"thief\"}").unwrap();
        drop(lock);
        assert!(
            path.exists(),
            "drop must leave a file that no longer carries its token"
        );
        let _ = std::fs::remove_file(&path);
    }
}
