//! Persistent calibration sessions: the reusable measure →
//! gather-features → fit → predict pipeline engine.
//!
//! The paper's promise is *calibrate once per GPU, predict at near-zero
//! cost*.  A [`Session`] makes that durable across process boundaries:
//! it owns the run's [`StatsCache`] and (optionally) a disk-backed
//! [`ArtifactStore`], and exposes the pipeline stages that both the
//! `perflex` CLI and the experiment coordinator consume — one
//! implementation of the flow instead of the two copies the CLI and
//! `coordinator::experiments` used to carry.
//!
//! # Key scheme
//!
//! Two artifact families are persisted, each fully keyed:
//!
//! * **Symbolic statistics** — keyed by
//!   ([`Kernel::fingerprint`](crate::ir::Kernel::fingerprint),
//!   sub-group size), exactly the in-memory [`StatsCache`] key.  The
//!   fingerprint covers the entire kernel IR, so any structural change
//!   mints a new key; devices sharing a sub-group size share entries.
//! * **Calibration fits** — keyed by [`FitKey`]: the *full* key —
//!   case id, device id, model form **and** `model_fingerprint` (hash
//!   of the model's feature columns, the measurement-set filter tags,
//!   the device's sub-group size, and the store format version) — is
//!   hashed into the filename (components sanitized, so ids containing
//!   `-` or path characters cannot collide or escape the store root),
//!   and the embedded key guards the content.  Fingerprint-only
//!   siblings (a re-featured model, sub-group twins of a renamed
//!   device) therefore persist side by side; before v3 they shared a
//!   path and silently evicted each other.  Both the CLI's
//!   `calibrate`/`predict` fits and the experiment harnesses'
//!   per-device fleet fits (via [`Session::fit_case_persistent`] /
//!   [`fit_key_parts`]) live here.
//!
//! Artifact existence and validity are answered by the journaled
//! [`index::StoreIndex`] (`<store>/index.json` + `index.journal`),
//! loaded once per process and shared read-mostly across fleet
//! sessions: warm `load_*`, `store ls`, `stat` and `gc` are hash-map
//! lookups, not per-lookup file probes or O(N · parse) scans (the
//! store ledger makes this observable; see
//! [`ArtifactStore::ledger`]).  `perflex store compact` additionally
//! deduplicates the sub-group-size-invariant section of stats bundles
//! shared between sg families of one kernel (`<store>/shared/`).
//!
//! The store is safe to share between *processes*, not just threads:
//! journal appends serialize under a cross-process writer lock and
//! fsync, snapshot checkpoints are epoch-fenced, and destructive
//! maintenance (`gc`, `compact`) runs under a lease and re-verifies
//! each victim under the lock before unlinking (see the
//! [`store`](ArtifactStore) and `lock` module docs).  The writer-lock
//! ledger ([`ArtifactStore::lock_ledger`], printed by store-backed CLI
//! commands) makes cross-process contention observable, and
//! `perflex store verify` ([`ArtifactStore::verify_index`]) asserts
//! the invariant all of this buys: the index always equals a full
//! rebuild scan.
//!
//! # Invalidation rules
//!
//! Artifacts are rejected, not silently reinterpreted: a loader
//! returns `None` — and the session falls back to a cold gather/fit —
//! whenever
//!
//! * the artifact's `format_version` differs from
//!   [`STORE_FORMAT_VERSION`] (bump it when any persisted semantics
//!   change, e.g. the counting rules or the LM schedule);
//! * the embedded key (kernel fingerprint / model fingerprint) does
//!   not match the requested one — covering edited models, changed
//!   measurement sets, changed calibration [`Target`], and a changed
//!   sub-group size;
//! * the payload fails to parse or validate.
//!
//! The one sanctioned migration is the v3→v4 *fit* read-compat: v3
//! had no target dimension, so every v3 fit is by construction a
//! `target=time` fit.  When a time-target lookup misses under the v4
//! key, the session probes the exact v3 key/path
//! ([`legacy_v3_fit_key_parts`] + [`ArtifactStore::load_legacy_v3_fit`]),
//! adopts a match as a converged time fit, and re-saves it under its
//! v4 key — a pre-bump store warms up instead of forcing a fleet-wide
//! cold refit.  Non-time targets never had v3 artifacts and never
//! consult the legacy path.
//!
//! Kernel fingerprints are minted once per kernel by
//! [`Kernel::freeze`](crate::ir::Kernel::freeze) (UiPiCK freezes every
//! generated kernel), so the hot paths never re-render IR; a frozen
//! kernel cannot be mutated without [`thawing`](
//! crate::ir::FrozenKernel::thaw) it, which discards the key.

pub mod codec;
pub mod index;
mod lock;
mod store;

pub use lock::DEFAULT_LEASE_TTL_SECS;
pub use store::{
    ArtifactInfo, ArtifactKind, ArtifactStore, CompactOutcome, FitKey, GcOptions,
    GcOutcome, IndexVerifyOutcome, STORE_FORMAT_VERSION,
};

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::calibrate::{
    eval_with_kernel_cached, gather_features_by_ids_cached_for, FeatureData,
    FitResult, LmOptions, Target,
};
use crate::coordinator::expsets::{self, EvalCase};
use crate::gpusim::{measure_with_cache, DeviceProfile, MeasuredSample};
use crate::ir::KernelRef;
use crate::model::{CompiledModel, CostModel};
use crate::runtime::{fit_cost_model_aot, fit_cost_model_native, Artifacts};
use crate::stats::StatsCache;
use crate::util::Fnv128;

/// A calibration produced by [`Session::calibrate_case`].
#[derive(Clone, Debug)]
pub struct Calibration {
    pub cm: CostModel,
    pub fit: FitResult,
    /// True when the fit was loaded from the artifact store: this
    /// process ran zero LM iterations (and, unless something else
    /// missed, zero symbolic counting passes) to produce it.
    pub from_store: bool,
}

/// One calibration/prediction session: a shared statistics cache plus
/// an optional persistent artifact store behind it.
#[derive(Default)]
pub struct Session {
    cache: StatsCache,
    store: Option<Arc<ArtifactStore>>,
    /// Compiled evaluation plans, cached beside the fits they were
    /// lowered from and keyed by everything that shaped them (kernel
    /// fingerprint, sub-group size, model terms, fitted parameters,
    /// target) — see [`compiled_key`].  Shared across the scoped
    /// threads of fleet harnesses like the stats cache is.
    compiled: Mutex<HashMap<u128, Arc<CompiledModel>>>,
    compiled_compiles: AtomicU64,
    compiled_cache_hits: AtomicU64,
    compiled_evals: AtomicU64,
}

impl Session {
    /// An in-memory session (no persistence) — what one-shot library
    /// callers and store-less CLI invocations use.
    pub fn new() -> Session {
        Session::default()
    }

    /// A session whose stats cache and calibrations persist under
    /// `dir`.  Fails fast if the directory cannot be created or
    /// written.
    pub fn with_store(dir: impl AsRef<Path>) -> Result<Session, String> {
        let store = Arc::new(ArtifactStore::open(dir.as_ref())?);
        Ok(Session {
            cache: StatsCache::with_backing(store.clone()),
            store: Some(store),
            ..Session::default()
        })
    }

    /// Build from an optional `--store` argument.
    pub fn from_store_arg(dir: Option<&str>) -> Result<Session, String> {
        match dir {
            Some(d) => Session::with_store(d),
            None => Ok(Session::new()),
        }
    }

    pub fn cache(&self) -> &StatsCache {
        &self.cache
    }

    pub fn store(&self) -> Option<&ArtifactStore> {
        self.store.as_deref()
    }

    /// The store-index ledger — `(index hits, full-artifact parses)` —
    /// or `None` for a store-less session.  Store-backed CLI commands
    /// print this beside the stats-cache ledger; the CI fleet-store
    /// job asserts zero full-artifact parses for `store ls` and warm
    /// `predict` against a fresh index.
    pub fn store_ledger(&self) -> Option<(u64, u64)> {
        self.store.as_ref().map(|s| s.ledger())
    }

    /// The cross-process writer-lock ledger — `(acquisitions,
    /// contended)` — or `None` for a store-less session.  Contended
    /// acquisitions mean another process (or thread) was appending to
    /// the shared journal at the same moment; they cost backoff
    /// milliseconds, never correctness.
    pub fn store_lock_ledger(&self) -> Option<(u64, u64)> {
        self.store.as_ref().map(|s| s.lock_ledger())
    }

    /// Pipeline stage 1: measure a kernel on a device (through the
    /// session cache, so its symbolic statistics are derived or loaded
    /// at most once per process).  One simulated launch yields every
    /// response variable at once — project with [`Target::of`] (e.g.
    /// `Target::Time.of(&sample)` for the wall time).
    pub fn measure<K: KernelRef>(
        &self,
        device: &DeviceProfile,
        knl: &K,
        env: &std::collections::BTreeMap<String, i64>,
    ) -> Result<MeasuredSample, String> {
        measure_with_cache(device, knl, env, &self.cache)
    }

    /// Pipeline stage 2: measure + gather (and output-scale) a case's
    /// feature data for one device, with the measured wall time as the
    /// response variable.
    pub fn gather_case_data(
        &self,
        case: &EvalCase,
        device: &DeviceProfile,
    ) -> Result<FeatureData, String> {
        self.gather_case_data_for(case, device, Target::Time)
    }

    /// [`Session::gather_case_data`] for an arbitrary calibration
    /// target.  The feature columns are shared by the linear and
    /// nonlinear model forms, so one gathering serves both fits; and
    /// because one simulated launch yields every response variable,
    /// targets of the same case share measurement and counting work
    /// through the session cache.  Evaluation is batched across
    /// problem sizes (see [`gather_features_by_ids_cached_for`]).
    pub fn gather_case_data_for(
        &self,
        case: &EvalCase,
        device: &DeviceProfile,
        target: Target,
    ) -> Result<FeatureData, String> {
        let cm = (case.model)(device.id, true);
        let kernels =
            expsets::generate_measurement_kernels(&(case.measurement_sets)())?;
        let mut data = gather_features_by_ids_cached_for(
            cm.feature_columns(),
            &kernels,
            device,
            &self.cache,
            target,
        )?;
        data.scale_features_by_output()?;
        Ok(data)
    }

    /// Pipeline stage 3: fit one model form from already-gathered data.
    /// The calibration target rides in on `data` (stamped by
    /// [`Session::gather_case_data_for`]) and comes back out on the
    /// returned [`FitResult`].
    pub fn fit_case(
        &self,
        case: &EvalCase,
        device: &DeviceProfile,
        data: &FeatureData,
        nonlinear: bool,
        aot: Option<&Artifacts>,
    ) -> Result<(CostModel, FitResult), String> {
        let cm = (case.model)(device.id, nonlinear);
        let opts = LmOptions::default();
        let fit = match aot {
            Some(a) => fit_cost_model_aot(a, &cm, data, &opts)?,
            None => fit_cost_model_native(&cm, data, &opts)?,
        };
        Ok((cm, fit))
    }

    /// Look a fit up in the artifact store: `None` without a store, on
    /// version skew, or on any key mismatch.  Fleet harnesses pair
    /// this with [`Session::persist_fit`] to warm-start per-device
    /// fits without re-gathering data a stored fit no longer needs.
    pub fn stored_fit(&self, key: &FitKey) -> Option<FitResult> {
        self.store.as_ref()?.load_fit(key)
    }

    /// [`Session::stored_fit`], falling back to the sanctioned v3→v4
    /// migration for time fits: on a v4 miss, probe the exact v3
    /// key/path, adopt a match as a converged time fit, and re-save it
    /// under the v4 key (best effort — a failed re-save still returns
    /// the fit, it just stays cold-keyed on disk).  Non-time targets
    /// never had v3 artifacts, so they never touch the legacy path.
    fn stored_fit_or_legacy(
        &self,
        case: &EvalCase,
        device: &DeviceProfile,
        key: &FitKey,
    ) -> Option<FitResult> {
        if let Some(fit) = self.stored_fit(key) {
            return Some(fit);
        }
        if key.target != Target::Time {
            return None;
        }
        let store = self.store.as_ref()?;
        let legacy = legacy_v3_fit_key(case, device, key.nonlinear);
        let fit = store.load_legacy_v3_fit(&legacy)?;
        if store.save_fit(key, &fit).is_err() {
            eprintln!(
                "warning: could not re-save migrated v3 fit for {}/{} under its \
                 v4 key; it will be re-adopted from the legacy artifact next run",
                key.case, key.device
            );
        }
        Some(fit)
    }

    /// Persist one fit artifact (a no-op without a store).
    ///
    /// Any *new* key family persisted through here (i.e. minted by
    /// [`fit_key_parts`] with a new case id) must also be registered
    /// in [`reachable_fit_fingerprints`], or `perflex store gc` will
    /// classify its artifacts as unreachable and collect them.
    pub fn persist_fit(&self, key: &FitKey, fit: &FitResult) -> Result<(), String> {
        if !fit.converged {
            // Diagnostics go to stderr: stdout is the byte-stable
            // report surface CI diffs against.
            eprintln!(
                "warning: persisting a non-converged {} fit for {} on {} \
                 (stopped at the iteration cap, residual {:.3e}); predictions \
                 from this artifact may be unstable",
                fit.target.name(),
                key.case,
                key.device,
                fit.residual
            );
        }
        match &self.store {
            Some(store) => store.save_fit(key, fit),
            None => Ok(()),
        }
    }

    /// Stages 2+3 with artifact reuse: return a stored calibration when
    /// a fresh one exists (zero LM iterations, zero measurement and
    /// counting work this process), otherwise gather, fit and persist.
    /// Calibrates the wall-time target; see
    /// [`Session::calibrate_case_for`] for the others.
    pub fn calibrate_case(
        &self,
        case: &EvalCase,
        device: &DeviceProfile,
        nonlinear: bool,
        aot: Option<&Artifacts>,
    ) -> Result<Calibration, String> {
        self.calibrate_case_for(case, device, nonlinear, aot, Target::Time)
    }

    /// [`Session::calibrate_case`] for an arbitrary calibration target.
    /// Fits for different targets persist side by side under
    /// target-qualified keys; a time-target miss additionally consults
    /// the pre-v4 artifact path (see the module docs' invalidation
    /// rules) before falling back to a cold gather/fit.
    pub fn calibrate_case_for(
        &self,
        case: &EvalCase,
        device: &DeviceProfile,
        nonlinear: bool,
        aot: Option<&Artifacts>,
        target: Target,
    ) -> Result<Calibration, String> {
        let key = fit_key_for(case, device, nonlinear, target);
        if let Some(fit) = self.stored_fit_or_legacy(case, device, &key) {
            return Ok(Calibration {
                cm: (case.model)(device.id, nonlinear),
                fit,
                from_store: true,
            });
        }
        let data = self.gather_case_data_for(case, device, target)?;
        let (cm, fit) = self.fit_case(case, device, &data, nonlinear, aot)?;
        self.persist_fit(&key, &fit)?;
        Ok(Calibration {
            cm,
            fit,
            from_store: false,
        })
    }

    /// [`Session::fit_case`] with artifact reuse over already-gathered
    /// (or lazily gathered) data: the warm path loads the stored fit
    /// and touches neither `data` nor the LM loop; the cold path
    /// gathers on demand, fits, and persists.  This is the engine
    /// behind the experiment harnesses' per-device fleet fits.
    pub fn fit_case_persistent(
        &self,
        case: &EvalCase,
        device: &DeviceProfile,
        data: &mut Option<FeatureData>,
        nonlinear: bool,
        aot: Option<&Artifacts>,
    ) -> Result<Calibration, String> {
        let key = fit_key(case, device, nonlinear);
        if let Some(fit) = self.stored_fit_or_legacy(case, device, &key) {
            return Ok(Calibration {
                cm: (case.model)(device.id, nonlinear),
                fit,
                from_store: true,
            });
        }
        if data.is_none() {
            *data = Some(self.gather_case_data(case, device)?);
        }
        let (cm, fit) =
            self.fit_case(case, device, data.as_ref().unwrap(), nonlinear, aot)?;
        self.persist_fit(&key, &fit)?;
        Ok(Calibration {
            cm,
            fit,
            from_store: false,
        })
    }

    /// True when fresh stored time fits exist for *both* model forms of
    /// (case, device) — the condition under which a fleet harness can
    /// skip gathering that device's calibration data entirely.  Probes
    /// through the legacy fallback, so a pre-v4 store counts as warm
    /// (and gets its fits adopted as a side effect).
    pub fn has_stored_fits(&self, case: &EvalCase, device: &DeviceProfile) -> bool {
        self.stored_fit_or_legacy(case, device, &fit_key(case, device, true))
            .is_some()
            && self
                .stored_fit_or_legacy(case, device, &fit_key(case, device, false))
                .is_some()
    }

    /// Pipeline stage 4: predict a kernel's response from a calibration
    /// (§7.3), through the session cache.  The prediction is in the
    /// fit's target units — seconds for time fits, joules for energy,
    /// watts for average power (`fit.target.unit()`).
    pub fn predict<K: KernelRef>(
        &self,
        cm: &CostModel,
        fit: &FitResult,
        knl: &K,
        env: &std::collections::BTreeMap<String, i64>,
        device: &DeviceProfile,
    ) -> Result<f64, String> {
        eval_with_kernel_cached(
            &cm.to_model(),
            fit,
            knl,
            env,
            device.sub_group_size,
            &self.cache,
        )
    }

    /// Lower `(cm, fit)` bound to `knl`'s statistics into a
    /// [`CompiledModel`], cached beside the fit for the life of the
    /// session.  Warm loads compile once per (kernel, fit) pair; every
    /// later prediction is a cache hit.  Two threads racing on a cold
    /// key may both compile (the result is identical and the last
    /// insert wins) — the ledger counts both, which is why CI asserts
    /// "≥ 1 compile", not "== 1".
    pub fn compiled_model<K: KernelRef>(
        &self,
        cm: &CostModel,
        fit: &FitResult,
        knl: &K,
        device: &DeviceProfile,
    ) -> Result<Arc<CompiledModel>, String> {
        let key = compiled_key(cm, fit, knl.fingerprint(), device.sub_group_size);
        if let Some(c) = self.compiled.lock().unwrap().get(&key) {
            self.compiled_cache_hits.fetch_add(1, Ordering::Relaxed);
            return Ok(c.clone());
        }
        let stats = self.cache.get_or_gather(knl, device.sub_group_size)?;
        let compiled = Arc::new(CompiledModel::compile(cm, fit, &stats)?);
        self.compiled_compiles.fetch_add(1, Ordering::Relaxed);
        self.compiled.lock().unwrap().insert(key, compiled.clone());
        Ok(compiled)
    }

    /// [`Session::predict`] through the compiled hot path: flat f64
    /// plans instead of per-query spec parsing and rational `QPoly`
    /// walks, agreeing with the exact path within
    /// [`crate::model::compiled::COMPILED_REL_ERR_BOUND`] relative
    /// error.  The CLI's `predict` runs here; experiment report paths
    /// that promise byte-identical output against historical runs stay
    /// on the exact [`Session::predict`].
    pub fn predict_compiled<K: KernelRef>(
        &self,
        cm: &CostModel,
        fit: &FitResult,
        knl: &K,
        env: &std::collections::BTreeMap<String, i64>,
        device: &DeviceProfile,
    ) -> Result<f64, String> {
        let compiled = self.compiled_model(cm, fit, knl, device)?;
        self.compiled_evals.fetch_add(1, Ordering::Relaxed);
        compiled.eval_env(env)
    }

    /// Batched prediction: sweep `var` over `values` with the other
    /// size variables fixed by `base_env`, reusing one bound value
    /// vector across the whole batch (one slot store + one dense
    /// evaluation per point — no per-point allocation).  Returns
    /// `(value, prediction)` rows in sweep order.  Errors name any
    /// unbound size variable; a `var` the model does not depend on
    /// yields constant predictions.
    pub fn predict_sweep<K: KernelRef>(
        &self,
        cm: &CostModel,
        fit: &FitResult,
        knl: &K,
        base_env: &std::collections::BTreeMap<String, i64>,
        var: &str,
        values: &[i64],
        device: &DeviceProfile,
    ) -> Result<Vec<(i64, f64)>, String> {
        let compiled = self.compiled_model(cm, fit, knl, device)?;
        let mut vals = Vec::with_capacity(compiled.vars().len());
        for v in compiled.vars() {
            if v == var {
                vals.push(0.0);
            } else {
                vals.push(*base_env.get(v).ok_or_else(|| {
                    format!("unbound size variable '{v}' (bind it as {v}=<int>)")
                })? as f64);
            }
        }
        let slot = compiled.slot_of(var);
        let mut out = Vec::with_capacity(values.len());
        for &x in values {
            if let Some(s) = slot {
                vals[s] = x as f64;
            }
            out.push((x, compiled.eval_slots(&vals)));
        }
        self.compiled_evals
            .fetch_add(values.len() as u64, Ordering::Relaxed);
        Ok(out)
    }

    /// The compiled-path ledger: `(lowerings, cache hits,
    /// evaluations)`.  A warm predict should show at least one
    /// lowering (or hit) and one evaluation; CI asserts the line this
    /// feeds to prove the hot path is actually exercised.
    pub fn compiled_ledger(&self) -> (u64, u64, u64) {
        (
            self.compiled_compiles.load(Ordering::Relaxed),
            self.compiled_cache_hits.load(Ordering::Relaxed),
            self.compiled_evals.load(Ordering::Relaxed),
        )
    }
}

/// Cache key for a session's compiled models: everything that shapes
/// the lowered plan — kernel fingerprint and sub-group size (the
/// statistics), the model's device/form/terms, and the fit's target,
/// parameter names and exact parameter bits.
fn compiled_key(cm: &CostModel, fit: &FitResult, kernel_fp: u128, sg: u64) -> u128 {
    let mut h = Fnv128::new();
    h.update(b"perflex-compiled-v1");
    h.update(&kernel_fp.to_be_bytes());
    h.update(sg.to_string().as_bytes());
    h.update(cm.device.as_bytes());
    h.update(if cm.nonlinear { b"overlap" } else { b"linear" });
    for t in &cm.terms {
        h.update(t.param.as_bytes());
        h.update(t.feature.as_bytes());
        h.update(&[t.group as u8]);
    }
    h.update(fit.target.name().as_bytes());
    for (name, p) in fit.param_names.iter().zip(fit.params.iter()) {
        h.update(name.as_bytes());
        h.update(&p.to_bits().to_be_bytes());
    }
    h.finish()
}

/// The full identity of a case's *time* calibration on a device; see
/// the module docs for what it covers (and therefore what invalidates
/// it).
pub fn fit_key(case: &EvalCase, device: &DeviceProfile, nonlinear: bool) -> FitKey {
    fit_key_for(case, device, nonlinear, Target::Time)
}

/// [`fit_key`] for an arbitrary calibration target: targets of one
/// (case, device, form) get distinct keys — and distinct model
/// fingerprints, since the target is part of what shaped the fit.
pub fn fit_key_for(
    case: &EvalCase,
    device: &DeviceProfile,
    nonlinear: bool,
    target: Target,
) -> FitKey {
    let cm = (case.model)(device.id, nonlinear);
    fit_key_parts(
        case.id,
        device,
        nonlinear,
        &cm,
        &(case.measurement_sets)(),
        target,
    )
}

/// [`fit_key_for`] for fits whose model and measurement set are built
/// inline rather than through an [`EvalCase`] — e.g. the fig5 overlap
/// harness.  `case_id` names the artifact family; the fingerprint
/// hashes everything that shaped the fit (feature columns, parameter
/// names, device, sub-group size, measurement-set filter tags, the
/// calibration target and the store format version), so a change to
/// any of them invalidates it.
///
/// Every distinct key family minted through this function must be
/// enumerated by [`reachable_fit_fingerprints`] — GC deletes fits it
/// cannot re-derive.  The fleet integration tests guard this by
/// running `gc` over a store a real experiment just populated and
/// asserting nothing is removed.
pub fn fit_key_parts(
    case_id: &str,
    device: &DeviceProfile,
    nonlinear: bool,
    cm: &CostModel,
    measurement_sets: &[Vec<String>],
    target: Target,
) -> FitKey {
    let mut h = Fnv128::new();
    h.update(b"perflex-fit-v");
    h.update(STORE_FORMAT_VERSION.to_string().as_bytes());
    h.update(case_id.as_bytes());
    h.update(device.id.as_bytes());
    h.update(device.sub_group_size.to_string().as_bytes());
    h.update(if nonlinear { b"overlap" } else { b"linear" });
    h.update(target.name().as_bytes());
    for col in cm.feature_columns() {
        h.update(col.as_bytes());
    }
    for name in cm.param_names() {
        h.update(name.as_bytes());
    }
    for set in measurement_sets {
        for tag in set {
            h.update(tag.as_bytes());
        }
        h.update(b"|");
    }
    FitKey {
        case: case_id.to_string(),
        device: device.id.to_string(),
        nonlinear,
        target,
        model_fingerprint: h.finish(),
    }
}

/// The exact key a **v3** binary would have computed for this fit —
/// version literal `"3"`, no target in the hash chain (v3 predates the
/// target dimension) — used only to locate pre-bump artifacts for the
/// sanctioned read-compat migration.  The returned key's `target` is
/// `Time` because that is what every v3 fit *is*.
///
/// This function is frozen: it must keep reproducing the v3 scheme
/// byte-for-byte even as [`fit_key_parts`] evolves, or migration
/// silently turns into a fleet-wide cold refit.
pub(crate) fn legacy_v3_fit_key_parts(
    case_id: &str,
    device: &DeviceProfile,
    nonlinear: bool,
    cm: &CostModel,
    measurement_sets: &[Vec<String>],
) -> FitKey {
    let mut h = Fnv128::new();
    h.update(b"perflex-fit-v");
    h.update(b"3");
    h.update(case_id.as_bytes());
    h.update(device.id.as_bytes());
    h.update(device.sub_group_size.to_string().as_bytes());
    h.update(if nonlinear { b"overlap" } else { b"linear" });
    for col in cm.feature_columns() {
        h.update(col.as_bytes());
    }
    for name in cm.param_names() {
        h.update(name.as_bytes());
    }
    for set in measurement_sets {
        for tag in set {
            h.update(tag.as_bytes());
        }
        h.update(b"|");
    }
    FitKey {
        case: case_id.to_string(),
        device: device.id.to_string(),
        nonlinear,
        target: Target::Time,
        model_fingerprint: h.finish(),
    }
}

/// [`legacy_v3_fit_key_parts`] derived from an [`EvalCase`] — the
/// legacy twin of [`fit_key`].
pub(crate) fn legacy_v3_fit_key(
    case: &EvalCase,
    device: &DeviceProfile,
    nonlinear: bool,
) -> FitKey {
    let cm = (case.model)(device.id, nonlinear);
    legacy_v3_fit_key_parts(
        case.id,
        device,
        nonlinear,
        &cm,
        &(case.measurement_sets)(),
    )
}

/// Every fit model fingerprint the current binary can produce: the
/// evaluation cases × the fleet × both model forms × every calibration
/// target (covering CLI `calibrate`/`predict` and the fig7–9/table3
/// harnesses) plus the fig5 overlap harness (time-only — overlap
/// discrimination is a timing question).  `perflex store gc` ages out
/// fit artifacts whose embedded fingerprint falls outside this set —
/// retired devices, edited models, stale format versions.
pub fn reachable_fit_fingerprints() -> std::collections::HashSet<u128> {
    let mut out = std::collections::HashSet::new();
    for device in crate::gpusim::fleet() {
        for case in expsets::eval_cases() {
            for nonlinear in [false, true] {
                for target in Target::ALL {
                    out.insert(
                        fit_key_for(&case, &device, nonlinear, target)
                            .model_fingerprint,
                    );
                }
            }
        }
        out.insert(
            crate::coordinator::experiments::fig5_fit_key(&device)
                .model_fingerprint,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device_by_id;

    fn tmp_dir(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "perflex-session-test-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn fit_keys_separate_forms_devices_and_models() {
        let cases = expsets::eval_cases();
        let dev = device_by_id("titan_v").unwrap();
        let amd = device_by_id("amd_r9_fury").unwrap();
        let a = fit_key(&cases[0], &dev, true);
        assert_eq!(a, fit_key(&cases[0], &dev, true), "keys are deterministic");
        assert_ne!(
            a.model_fingerprint,
            fit_key(&cases[0], &dev, false).model_fingerprint
        );
        assert_ne!(
            a.model_fingerprint,
            fit_key(&cases[0], &amd, true).model_fingerprint
        );
        assert_ne!(
            a.model_fingerprint,
            fit_key(&cases[1], &dev, true).model_fingerprint
        );
        let e = fit_key_for(&cases[0], &dev, true, Target::Energy);
        assert_eq!(e.target, Target::Energy);
        assert_eq!(fit_key(&cases[0], &dev, true).target, Target::Time);
        assert_ne!(
            a.model_fingerprint, e.model_fingerprint,
            "the target is part of the model fingerprint"
        );
    }

    #[test]
    fn storeless_session_calibrates_cold_every_time() {
        let session = Session::new();
        let cases = expsets::eval_cases();
        let dev = device_by_id("titan_v").unwrap();
        let cal = session
            .calibrate_case(&cases[0], &dev, true, None)
            .unwrap();
        assert!(!cal.from_store);
        assert!(cal.fit.iterations > 0);
        assert!(session.cache.misses() > 0);
    }

    #[test]
    fn warm_session_skips_fit_and_symbolic_passes_entirely() {
        let dir = tmp_dir("warm");
        let cases = expsets::eval_cases();
        let case = &cases[0];
        let dev = device_by_id("titan_v").unwrap();

        // Cold run: gathers, fits, persists.
        let cold = Session::with_store(&dir).unwrap();
        let cal_cold = cold.calibrate_case(case, &dev, true, None).unwrap();
        assert!(!cal_cold.from_store);
        assert!(cold.cache().misses() > 0);

        // Warm run in a "new process": the fit loads from disk (zero LM
        // iterations run here) and prediction's statistics come from
        // the store (zero symbolic counting passes).
        let warm = Session::with_store(&dir).unwrap();
        let cal_warm = warm.calibrate_case(case, &dev, true, None).unwrap();
        assert!(cal_warm.from_store, "fresh artifact must be reused");
        assert_eq!(cal_cold.fit.param_names, cal_warm.fit.param_names);
        assert_eq!(cal_cold.fit.params, cal_warm.fit.params);
        assert_eq!(cal_cold.fit.residual, cal_warm.fit.residual);
        assert_eq!(warm.cache().misses(), 0);

        let kernel = crate::uipick::apps::build_matmul(crate::ir::DType::F32, true, 16)
            .unwrap()
            .freeze();
        let env: std::collections::BTreeMap<String, i64> =
            [("n".to_string(), 2048i64)].into_iter().collect();
        let p_cold = cold
            .predict(&cal_cold.cm, &cal_cold.fit, &kernel, &env, &dev)
            .unwrap();
        let p_warm = warm
            .predict(&cal_warm.cm, &cal_warm.fit, &kernel, &env, &dev)
            .unwrap();
        assert_eq!(p_cold, p_warm, "warm prediction must match cold exactly");
        assert_eq!(
            warm.cache().misses(),
            0,
            "warm predict must not run the symbolic pass"
        );
        assert!(warm.cache().disk_hits() >= 1);
        let (index_hits, parses) = warm.store_ledger().unwrap();
        assert_eq!(
            parses, 0,
            "with a fresh index, a warm run performs zero full-artifact parses"
        );
        assert!(index_hits > 0, "warm loads must be index-vouched");
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// THE v3→v4 migration regression: a store left behind by a v3
    /// binary (fit artifact under the v3 path, v3 envelope, no target
    /// field anywhere) must warm-start a v4 time calibration — zero
    /// counting passes, zero LM iterations run here — and get adopted
    /// under its v4 key so later runs are plain index-vouched hits.
    #[test]
    fn pre_bump_v3_fit_artifacts_warm_start_and_migrate() {
        let dir = tmp_dir("v3migrate");
        let cases = expsets::eval_cases();
        let case = &cases[0];
        let dev = device_by_id("titan_v").unwrap();

        let legacy = legacy_v3_fit_key(case, &dev, true);
        let v4 = fit_key(case, &dev, true);
        assert_ne!(
            legacy.model_fingerprint, v4.model_fingerprint,
            "the format bump re-fingerprints every fit"
        );

        // Stage the store exactly as a v3 binary would have left it.
        std::fs::create_dir_all(dir.join("fits")).unwrap();
        let v3_artifact = format!(
            "{{\"format_version\":3,\"kind\":\"fit\",\"case\":\"{}\",\
             \"device\":\"titan_v\",\"nonlinear\":true,\
             \"model_fingerprint\":\"{}\",\"fit\":{{\
             \"param_names\":[\"p_a\",\"p_b\"],\"params\":[0.5,2.0],\
             \"residual\":0.25,\"iterations\":7}}}}",
            case.id,
            codec::fingerprint_to_hex(legacy.model_fingerprint)
        );
        std::fs::write(
            dir.join("fits").join(store::legacy_v3_fit_file_name(&legacy)),
            &v3_artifact,
        )
        .unwrap();

        // First v4 run: the time calibration comes from the legacy
        // artifact — no gathering, no counting, no LM — and is
        // re-saved under the v4 key.
        let session = Session::with_store(&dir).unwrap();
        let cal = session.calibrate_case(case, &dev, true, None).unwrap();
        assert!(cal.from_store, "the v3 artifact must be adopted, not refit");
        assert_eq!(cal.fit.params, vec![0.5, 2.0]);
        assert_eq!(cal.fit.iterations, 7);
        assert_eq!(cal.fit.target, Target::Time);
        assert!(cal.fit.converged, "v3 fits decode as converged");
        assert_eq!(
            session.cache().misses(),
            0,
            "migration must not re-run the counting pass"
        );

        // Second v4 run: a plain warm hit under the v4 key, no legacy
        // parse, no full-artifact parse at all.
        let warm = Session::with_store(&dir).unwrap();
        let cal2 = warm.calibrate_case(case, &dev, true, None).unwrap();
        assert!(cal2.from_store);
        assert_eq!(cal2.fit.params, cal.fit.params);
        let (_, parses) = warm.store_ledger().unwrap();
        assert_eq!(
            parses, 0,
            "post-migration loads must be index-vouched v4 hits"
        );

        // A non-time target finds nothing to migrate (v3 had no such
        // fits) and calibrates cold.
        let energy = warm
            .calibrate_case_for(case, &dev, true, None, Target::Energy)
            .unwrap();
        assert!(!energy.from_store);
        assert_eq!(energy.fit.target, Target::Energy);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
