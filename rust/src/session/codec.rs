//! Exact JSON codecs for the persisted artifact types.
//!
//! Everything the [`super::ArtifactStore`] writes must round-trip
//! *exactly*: a warm run replays cached statistics and fitted
//! parameters through the same arithmetic as a cold run, and the
//! acceptance bar is byte-identical reports.  Two representation rules
//! make that hold:
//!
//! * rational coefficients serialize their `i128` numerator and
//!   denominator as **strings** (JSON numbers are `f64` and would
//!   silently truncate beyond 2^53);
//! * `f64`s rely on [`crate::util::json::Json`]'s `Display`, which is
//!   Rust's shortest-roundtrip float formatting — parsing the text
//!   recovers the exact bit pattern.
//!
//! Quasi-polynomials are encoded structurally (terms of monomials of
//! atoms, with `floor` atoms recursing) and rebuilt through the public
//! [`QPoly`] algebra, which reproduces the canonical internal form:
//! serialize → parse → serialize is byte-stable.

use crate::calibrate::{FitResult, Target};
use crate::ir::{DType, MemScope};
use crate::polyhedral::{Atom, QPoly};
use crate::stats::{Direction, Granularity, KernelStats, MemAccessStat, OpStat};
use crate::util::json::Json;
use crate::util::Rat;

/// Largest monomial exponent the decoder accepts.  Real count
/// polynomials are low-degree (trip counts over a handful of nested
/// loops); anything bigger is a corrupt or adversarial artifact.
const MAX_EXPONENT: f64 = 64.0;

fn err(what: &str) -> String {
    format!("artifact codec: malformed {what}")
}

fn get<'a>(j: &'a Json, key: &str, what: &str) -> Result<&'a Json, String> {
    j.get(key).ok_or_else(|| err(what))
}

fn get_str(j: &Json, key: &str, what: &str) -> Result<String, String> {
    get(j, key, what)?
        .as_str()
        .map(str::to_string)
        .ok_or_else(|| err(what))
}

fn get_u64(j: &Json, key: &str, what: &str) -> Result<u64, String> {
    get(j, key, what)?
        .as_f64()
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as u64)
        .ok_or_else(|| err(what))
}

fn i128_from(j: &Json, what: &str) -> Result<i128, String> {
    j.as_str()
        .and_then(|s| s.parse::<i128>().ok())
        .ok_or_else(|| err(what))
}

/// Render a 128-bit fingerprint the way every artifact embeds it (and
/// the stats filenames encode it): fixed-width lowercase hex.
pub fn fingerprint_to_hex(fp: u128) -> String {
    format!("{fp:032x}")
}

/// Parse a [`fingerprint_to_hex`] rendering back; rejects anything but
/// exactly 32 lowercase hex digits, so filename and embedded-key
/// comparisons cannot be spoofed by alternate encodings.
pub fn fingerprint_from_hex(s: &str) -> Result<u128, String> {
    if s.len() != 32
        || !s
            .bytes()
            .all(|b| b.is_ascii_digit() || (b'a'..=b'f').contains(&b))
    {
        return Err(err("fingerprint"));
    }
    u128::from_str_radix(s, 16).map_err(|_| err("fingerprint"))
}

// ---------------------------------------------------------------------
// Rat / QPoly
// ---------------------------------------------------------------------

pub fn rat_to_json(r: &Rat) -> Json {
    Json::obj(vec![
        ("n", r.num().to_string().into()),
        ("d", r.den().to_string().into()),
    ])
}

pub fn rat_from_json(j: &Json) -> Result<Rat, String> {
    let num = i128_from(get(j, "n", "rational")?, "rational numerator")?;
    let den = i128_from(get(j, "d", "rational")?, "rational denominator")?;
    if den == 0 {
        return Err(err("rational (zero denominator)"));
    }
    Ok(Rat::new(num, den))
}

fn atom_to_json(a: &Atom) -> Json {
    match a {
        Atom::Var(v) => Json::obj(vec![("var", v.as_str().into())]),
        Atom::Floor { num, den } => Json::obj(vec![(
            "floor",
            Json::obj(vec![
                ("num", qpoly_to_json(num)),
                ("den", den.to_string().into()),
            ]),
        )]),
    }
}

/// A quasi-polynomial as `[[monomial, coeff], ...]` with `monomial =
/// [[atom, exponent], ...]`.  Term order is the canonical internal
/// order, so re-serializing a decoded polynomial is byte-stable.
pub fn qpoly_to_json(p: &QPoly) -> Json {
    Json::Arr(
        p.terms()
            .map(|(m, c)| {
                let mono = Json::Arr(
                    m.0.iter()
                        .map(|(a, e)| {
                            Json::Arr(vec![atom_to_json(a), Json::from(*e as i64)])
                        })
                        .collect(),
                );
                Json::Arr(vec![mono, rat_to_json(c)])
            })
            .collect(),
    )
}

fn atom_poly_from_json(j: &Json) -> Result<QPoly, String> {
    if let Some(v) = j.get("var").and_then(Json::as_str) {
        return Ok(QPoly::var(v));
    }
    if let Some(fl) = j.get("floor") {
        let num = qpoly_from_json(get(fl, "num", "floor atom")?)?;
        let den = i128_from(get(fl, "den", "floor atom")?, "floor denominator")?;
        if den <= 0 {
            return Err(err("floor atom (non-positive denominator)"));
        }
        return Ok(num.floor_div(den));
    }
    Err(err("atom"))
}

pub fn qpoly_from_json(j: &Json) -> Result<QPoly, String> {
    let terms = j.as_arr().ok_or_else(|| err("polynomial"))?;
    let mut out = QPoly::zero();
    for t in terms {
        let pair = t.as_arr().filter(|p| p.len() == 2).ok_or_else(|| err("term"))?;
        let coeff = rat_from_json(&pair[1])?;
        let mut term = QPoly::constant(coeff);
        for factor in pair[0].as_arr().ok_or_else(|| err("monomial"))? {
            let fp = factor
                .as_arr()
                .filter(|p| p.len() == 2)
                .ok_or_else(|| err("monomial factor"))?;
            // Exponents far beyond any real count polynomial are
            // rejected rather than decoded: `QPoly::pow` is O(k)
            // multiplications, so an adversarially large exponent in a
            // hand-edited artifact would otherwise hang the load (the
            // store contract is "corrupt artifact -> cold start").
            let exp = fp[1]
                .as_f64()
                .filter(|x| *x >= 0.0 && x.fract() == 0.0 && *x <= MAX_EXPONENT)
                .map(|x| x as u32)
                .ok_or_else(|| err("exponent"))?;
            term = &term * &atom_poly_from_json(&fp[0])?.pow(exp);
        }
        out = &out + &term;
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// KernelStats
// ---------------------------------------------------------------------

fn scope_name(s: MemScope) -> &'static str {
    match s {
        MemScope::Global => "global",
        MemScope::Local => "local",
        MemScope::Private => "private",
    }
}

fn scope_from(s: &str) -> Result<MemScope, String> {
    match s {
        "global" => Ok(MemScope::Global),
        "local" => Ok(MemScope::Local),
        "private" => Ok(MemScope::Private),
        _ => Err(err("memory scope")),
    }
}

fn direction_from(s: &str) -> Result<Direction, String> {
    match s {
        "load" => Ok(Direction::Load),
        "store" => Ok(Direction::Store),
        _ => Err(err("direction")),
    }
}

fn granularity_name(g: Granularity) -> &'static str {
    match g {
        Granularity::WorkItem => "wi",
        Granularity::SubGroup => "sg",
    }
}

fn granularity_from(s: &str) -> Result<Granularity, String> {
    match s {
        "wi" => Ok(Granularity::WorkItem),
        "sg" => Ok(Granularity::SubGroup),
        _ => Err(err("granularity")),
    }
}

fn dtype_from(s: &str) -> Result<DType, String> {
    DType::parse(s).ok_or_else(|| err("dtype"))
}

fn mem_to_json(m: &MemAccessStat) -> Json {
    let polys = |ps: &[QPoly; 3]| Json::Arr(ps.iter().map(qpoly_to_json).collect());
    Json::obj(vec![
        ("stmt_id", m.stmt_id.as_str().into()),
        ("array", m.array.as_str().into()),
        (
            "tag",
            match &m.tag {
                Some(t) => t.as_str().into(),
                None => Json::Null,
            },
        ),
        ("scope", scope_name(m.scope).into()),
        ("direction", m.direction.feature_name().into()),
        ("dtype", m.dtype.feature_name().into()),
        ("lstrides", polys(&m.lstrides)),
        ("gstrides", polys(&m.gstrides)),
        ("count_wi", qpoly_to_json(&m.count_wi)),
        ("footprint", qpoly_to_json(&m.footprint)),
        ("footprint_per_wg", qpoly_to_json(&m.footprint_per_wg)),
        ("granularity", granularity_name(m.granularity).into()),
        (
            "loop_strides",
            Json::Arr(
                m.loop_strides
                    .iter()
                    .map(|(iname, s)| {
                        Json::Arr(vec![iname.as_str().into(), qpoly_to_json(s)])
                    })
                    .collect(),
            ),
        ),
    ])
}

fn polys3_from(j: &Json, what: &str) -> Result<[QPoly; 3], String> {
    let arr = j.as_arr().filter(|a| a.len() == 3).ok_or_else(|| err(what))?;
    Ok([
        qpoly_from_json(&arr[0])?,
        qpoly_from_json(&arr[1])?,
        qpoly_from_json(&arr[2])?,
    ])
}

fn mem_from_json(j: &Json) -> Result<MemAccessStat, String> {
    let tag = match get(j, "tag", "mem access")? {
        Json::Null => None,
        t => Some(t.as_str().ok_or_else(|| err("mem access tag"))?.to_string()),
    };
    let loop_strides = get(j, "loop_strides", "mem access")?
        .as_arr()
        .ok_or_else(|| err("loop strides"))?
        .iter()
        .map(|p| {
            let pair = p
                .as_arr()
                .filter(|a| a.len() == 2)
                .ok_or_else(|| err("loop stride"))?;
            let iname = pair[0]
                .as_str()
                .ok_or_else(|| err("loop stride iname"))?
                .to_string();
            Ok((iname, qpoly_from_json(&pair[1])?))
        })
        .collect::<Result<Vec<_>, String>>()?;
    Ok(MemAccessStat {
        stmt_id: get_str(j, "stmt_id", "mem access")?,
        array: get_str(j, "array", "mem access")?,
        tag,
        scope: scope_from(&get_str(j, "scope", "mem access")?)?,
        direction: direction_from(&get_str(j, "direction", "mem access")?)?,
        dtype: dtype_from(&get_str(j, "dtype", "mem access")?)?,
        lstrides: polys3_from(get(j, "lstrides", "mem access")?, "lstrides")?,
        gstrides: polys3_from(get(j, "gstrides", "mem access")?, "gstrides")?,
        count_wi: qpoly_from_json(get(j, "count_wi", "mem access")?)?,
        footprint: qpoly_from_json(get(j, "footprint", "mem access")?)?,
        footprint_per_wg: qpoly_from_json(get(j, "footprint_per_wg", "mem access")?)?,
        granularity: granularity_from(&get_str(j, "granularity", "mem access")?)?,
        loop_strides,
    })
}

/// Arithmetic-op stats as a JSON array.  Factored out of the full
/// bundle codec because op counts (already scaled by 1/sg) are the
/// *only* sub-group-size-dependent section of a stats bundle — the
/// compacted artifact form persists them per sub-group size while the
/// rest of the bundle is deduplicated (see [`stats_shared_to_json`]).
pub fn ops_to_json(ops: &[OpStat]) -> Json {
    Json::Arr(
        ops.iter()
            .map(|o| {
                Json::obj(vec![
                    ("dtype", o.dtype.feature_name().into()),
                    ("op", o.op.as_str().into()),
                    ("count_sg", qpoly_to_json(&o.count_sg)),
                ])
            })
            .collect(),
    )
}

pub fn ops_from_json(j: &Json) -> Result<Vec<OpStat>, String> {
    j.as_arr()
        .ok_or_else(|| err("op stats"))?
        .iter()
        .map(|o| {
            Ok(OpStat {
                dtype: dtype_from(&get_str(o, "dtype", "op stat")?)?,
                op: get_str(o, "op", "op stat")?,
                count_sg: qpoly_from_json(get(o, "count_sg", "op stat")?)?,
            })
        })
        .collect()
}

/// The sub-group-size-invariant section of a [`KernelStats`] bundle:
/// everything [`crate::stats::gather`] derives without consulting the
/// sub-group size (memory-access classification, barriers, launch
/// geometry).  `perflex store compact` deduplicates this section
/// between the sg-32 and sg-64 twins of one kernel fingerprint; the
/// reassembled bundle ([`stats_from_parts`]) is structurally identical
/// to the original, so compaction never changes a report byte.
pub struct SharedStats {
    pub kernel_name: String,
    pub mem: Vec<MemAccessStat>,
    pub barriers_per_wi: QPoly,
    pub num_groups: QPoly,
    pub work_group_size: u64,
}

pub fn stats_shared_to_json(st: &KernelStats) -> Json {
    Json::obj(vec![
        ("kernel_name", st.kernel_name.as_str().into()),
        ("mem", Json::Arr(st.mem.iter().map(mem_to_json).collect())),
        ("barriers_per_wi", qpoly_to_json(&st.barriers_per_wi)),
        ("num_groups", qpoly_to_json(&st.num_groups)),
        ("work_group_size", (st.work_group_size as i64).into()),
    ])
}

pub fn stats_shared_from_json(j: &Json) -> Result<SharedStats, String> {
    let mem = get(j, "mem", "shared stats")?
        .as_arr()
        .ok_or_else(|| err("mem stats"))?
        .iter()
        .map(mem_from_json)
        .collect::<Result<Vec<_>, String>>()?;
    Ok(SharedStats {
        kernel_name: get_str(j, "kernel_name", "shared stats")?,
        mem,
        barriers_per_wi: qpoly_from_json(get(j, "barriers_per_wi", "shared stats")?)?,
        num_groups: qpoly_from_json(get(j, "num_groups", "shared stats")?)?,
        work_group_size: get_u64(j, "work_group_size", "shared stats")?,
    })
}

/// Reassemble a full bundle from its deduplicated halves — the inverse
/// of splitting via [`stats_shared_to_json`] + [`ops_to_json`].
pub fn stats_from_parts(
    shared: SharedStats,
    ops: Vec<OpStat>,
    sub_group_size: u64,
) -> KernelStats {
    KernelStats {
        kernel_name: shared.kernel_name,
        ops,
        mem: shared.mem,
        barriers_per_wi: shared.barriers_per_wi,
        num_groups: shared.num_groups,
        work_group_size: shared.work_group_size,
        sub_group_size,
    }
}

pub fn stats_to_json(st: &KernelStats) -> Json {
    Json::obj(vec![
        ("kernel_name", st.kernel_name.as_str().into()),
        ("ops", ops_to_json(&st.ops)),
        ("mem", Json::Arr(st.mem.iter().map(mem_to_json).collect())),
        ("barriers_per_wi", qpoly_to_json(&st.barriers_per_wi)),
        ("num_groups", qpoly_to_json(&st.num_groups)),
        ("work_group_size", (st.work_group_size as i64).into()),
        ("sub_group_size", (st.sub_group_size as i64).into()),
    ])
}

pub fn stats_from_json(j: &Json) -> Result<KernelStats, String> {
    let ops = ops_from_json(get(j, "ops", "kernel stats")?)?;
    let mem = get(j, "mem", "kernel stats")?
        .as_arr()
        .ok_or_else(|| err("mem stats"))?
        .iter()
        .map(mem_from_json)
        .collect::<Result<Vec<_>, String>>()?;
    Ok(KernelStats {
        kernel_name: get_str(j, "kernel_name", "kernel stats")?,
        ops,
        mem,
        barriers_per_wi: qpoly_from_json(get(j, "barriers_per_wi", "kernel stats")?)?,
        num_groups: qpoly_from_json(get(j, "num_groups", "kernel stats")?)?,
        work_group_size: get_u64(j, "work_group_size", "kernel stats")?,
        sub_group_size: get_u64(j, "sub_group_size", "kernel stats")?,
    })
}

// ---------------------------------------------------------------------
// FitResult
// ---------------------------------------------------------------------

pub fn fit_to_json(fit: &FitResult) -> Json {
    Json::obj(vec![
        (
            "param_names",
            Json::Arr(fit.param_names.iter().map(|n| n.as_str().into()).collect()),
        ),
        (
            "params",
            Json::Arr(fit.params.iter().map(|p| Json::Num(*p)).collect()),
        ),
        ("residual", Json::Num(fit.residual)),
        ("iterations", fit.iterations.into()),
        ("target", fit.target.name().into()),
        ("converged", Json::Bool(fit.converged)),
    ])
}

/// Decode a fit.  `target` and `converged` were introduced with store
/// format v4; v3 artifacts omit them and decode as a converged time
/// fit — exactly what every v3 store ever persisted — so the legacy
/// loader can adopt pre-bump fits without a cold start.  A *present*
/// but malformed field is still a hard error (corrupt artifact).
pub fn fit_from_json(j: &Json) -> Result<FitResult, String> {
    let param_names = get(j, "param_names", "fit")?
        .as_arr()
        .ok_or_else(|| err("fit param names"))?
        .iter()
        .map(|n| n.as_str().map(str::to_string).ok_or_else(|| err("param name")))
        .collect::<Result<Vec<_>, String>>()?;
    let params = get(j, "params", "fit")?
        .as_arr()
        .ok_or_else(|| err("fit params"))?
        .iter()
        .map(|p| p.as_f64().ok_or_else(|| err("param value")))
        .collect::<Result<Vec<_>, String>>()?;
    if param_names.len() != params.len() {
        return Err(err("fit (name/value length mismatch)"));
    }
    let residual = get(j, "residual", "fit")?
        .as_f64()
        .ok_or_else(|| err("fit residual"))?;
    let iterations = get_u64(j, "iterations", "fit")? as usize;
    let target = match j.get("target") {
        None => Target::Time,
        Some(t) => Target::parse(t.as_str().ok_or_else(|| err("fit target"))?)
            .map_err(|_| err("fit target"))?,
    };
    let converged = match j.get("converged") {
        None => true,
        Some(c) => c.as_bool().ok_or_else(|| err("fit converged flag"))?,
    };
    Ok(FitResult {
        param_names,
        params,
        residual,
        iterations,
        target,
        converged,
    })
}

/// Evaluate a decoded stats bundle against the original across sizes —
/// shared by the round-trip tests.
#[cfg(test)]
fn assert_stats_equivalent(a: &KernelStats, b: &KernelStats, envs: &[i128]) {
    use std::collections::BTreeMap;
    assert_eq!(a.kernel_name, b.kernel_name);
    assert_eq!(a.work_group_size, b.work_group_size);
    assert_eq!(a.sub_group_size, b.sub_group_size);
    assert_eq!(a.ops.len(), b.ops.len());
    assert_eq!(a.mem.len(), b.mem.len());
    for &n in envs {
        let env: BTreeMap<String, i128> = [
            ("n".to_string(), n),
            ("nelements".to_string(), n),
            ("nmatrices".to_string(), 3),
            ("m".to_string(), 64),
        ]
        .into_iter()
        .collect();
        assert_eq!(a.barriers_per_wi.eval(&env), b.barriers_per_wi.eval(&env));
        assert_eq!(a.num_groups.eval(&env), b.num_groups.eval(&env));
        for (oa, ob) in a.ops.iter().zip(&b.ops) {
            assert_eq!(oa.dtype, ob.dtype);
            assert_eq!(oa.op, ob.op);
            assert_eq!(oa.count_sg.eval(&env), ob.count_sg.eval(&env));
        }
        for (ma, mb) in a.mem.iter().zip(&b.mem) {
            assert_eq!(ma.stmt_id, mb.stmt_id);
            assert_eq!(ma.tag, mb.tag);
            assert_eq!(ma.granularity, mb.granularity);
            assert_eq!(ma.count_wi.eval(&env), mb.count_wi.eval(&env));
            assert_eq!(ma.footprint.eval(&env), mb.footprint.eval(&env));
            for ax in 0..3 {
                assert_eq!(ma.lstrides[ax].eval(&env), mb.lstrides[ax].eval(&env));
                assert_eq!(ma.gstrides[ax].eval(&env), mb.gstrides[ax].eval(&env));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    #[test]
    fn qpoly_roundtrip_is_byte_stable() {
        // Exercise vars, floor atoms (nested), big exact coefficients
        // and rational coefficients.
        let n = QPoly::var("n");
        let nd16 = (&n - &QPoly::int(16)).floor_div(16);
        let p = &(&n.pow(3).scale(Rat::new(1, 32)) + &nd16.pow(2).scale(Rat::int(7)))
            + &(&nd16.floor_div(4) * &QPoly::var("m")).scale(Rat::new(-3, 5));
        let j1 = qpoly_to_json(&p);
        let text = j1.to_string();
        let back = qpoly_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, p, "structural equality after round trip");
        assert_eq!(qpoly_to_json(&back).to_string(), text, "byte stability");
        // Coefficients beyond f64's 2^53 integer range stay exact.
        let big = QPoly::constant(Rat::new(1_234_567_890_123_456_789_012_345_671, 7));
        let back = qpoly_from_json(&Json::parse(&qpoly_to_json(&big).to_string()).unwrap())
            .unwrap();
        assert_eq!(back, big);
    }

    #[test]
    fn kernel_stats_roundtrip_preserves_every_count() {
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let st = crate::stats::gather(&k, 32).unwrap();
        let j = stats_to_json(&st);
        let text = j.to_string();
        let back = stats_from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_stats_equivalent(&st, &back, &[1024, 2048, 3584]);
        assert_eq!(
            stats_to_json(&back).to_string(),
            text,
            "stats serialization must be byte-stable"
        );
    }

    /// The compaction split: (shared section, ops, sg) must reassemble
    /// into a bundle indistinguishable from the full round trip, and
    /// the shared section of sg-32 and sg-64 gathers of one kernel must
    /// encode byte-identically (the invariant `store compact` relies
    /// on to dedup across sub-group families).
    #[test]
    fn shared_split_reassembles_exactly_and_is_sg_invariant() {
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let st32 = crate::stats::gather(&k, 32).unwrap();
        let st64 = crate::stats::gather(&k, 64).unwrap();
        assert_eq!(
            stats_shared_to_json(&st32).to_string(),
            stats_shared_to_json(&st64).to_string(),
            "shared section must not depend on the sub-group size"
        );

        let shared_text = stats_shared_to_json(&st32).to_string();
        let ops_text = ops_to_json(&st32.ops).to_string();
        let shared =
            stats_shared_from_json(&Json::parse(&shared_text).unwrap()).unwrap();
        let ops = ops_from_json(&Json::parse(&ops_text).unwrap()).unwrap();
        let rebuilt = stats_from_parts(shared, ops, 32);
        assert_stats_equivalent(&st32, &rebuilt, &[1024, 2048, 3584]);
        assert_eq!(
            stats_to_json(&rebuilt).to_string(),
            stats_to_json(&st32).to_string(),
            "reassembly must be byte-identical to the full encoding"
        );
    }

    #[test]
    fn fit_roundtrip_is_byte_stable() {
        // One fit per target, including a non-converged one: target and
        // convergence must survive the trip byte-for-byte alongside the
        // numeric payload.
        for (target, converged) in [
            (Target::Time, true),
            (Target::Energy, false),
            (Target::AvgPower, true),
        ] {
            let fit = FitResult {
                param_names: vec!["p_a".into(), "p_b".into(), "p_edge".into()],
                params: vec![1.5e-9, 0.1 + 0.2, 25.0],
                residual: 3.86e-17,
                iterations: 42,
                target,
                converged,
            };
            let text = fit_to_json(&fit).to_string();
            let back = fit_from_json(&Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.param_names, fit.param_names);
            assert_eq!(back.params, fit.params, "f64s must round-trip exactly");
            assert_eq!(back.residual, fit.residual);
            assert_eq!(back.iterations, fit.iterations);
            assert_eq!(back.target, target);
            assert_eq!(back.converged, converged);
            assert_eq!(fit_to_json(&back).to_string(), text);
        }
    }

    /// A v3-era fit body (no `target`, no `converged`) must decode as a
    /// converged time fit — the read-compat half of the v3→v4 bump —
    /// while present-but-malformed fields stay hard errors.
    #[test]
    fn v3_fit_bodies_decode_as_converged_time_fits() {
        let j = Json::parse(
            "{\"param_names\":[\"p_a\"],\"params\":[2.0],\"residual\":0.5,\
             \"iterations\":7}",
        )
        .unwrap();
        let fit = fit_from_json(&j).unwrap();
        assert_eq!(fit.target, Target::Time);
        assert!(fit.converged);
        assert_eq!(fit.params, vec![2.0]);

        let bad_target = Json::parse(
            "{\"param_names\":[\"p_a\"],\"params\":[2.0],\"residual\":0.5,\
             \"iterations\":7,\"target\":\"joules\"}",
        )
        .unwrap();
        assert!(fit_from_json(&bad_target).is_err());
        let bad_flag = Json::parse(
            "{\"param_names\":[\"p_a\"],\"params\":[2.0],\"residual\":0.5,\
             \"iterations\":7,\"converged\":\"yes\"}",
        )
        .unwrap();
        assert!(fit_from_json(&bad_flag).is_err());
    }

    #[test]
    fn fingerprint_hex_roundtrips_and_rejects_spoofs() {
        let fp: u128 = 0x00ab_cdef_0123_4567_89ab_cdef_0123_4567;
        let s = fingerprint_to_hex(fp);
        assert_eq!(s.len(), 32);
        assert_eq!(fingerprint_from_hex(&s).unwrap(), fp);
        assert!(fingerprint_from_hex("not-hex").is_err());
        assert!(fingerprint_from_hex(&s.to_uppercase()).is_err());
        assert!(fingerprint_from_hex(&s[1..]).is_err());
    }

    #[test]
    fn malformed_artifacts_are_rejected() {
        assert!(qpoly_from_json(&Json::parse("{}").unwrap()).is_err());
        // Oversized exponents are rejected up front (QPoly::pow is O(k),
        // so decoding one would hang the load), while sane ones decode.
        let term = |e: &str| {
            format!("[[[[{{\"var\":\"n\"}},{e}]],{{\"n\":\"1\",\"d\":\"1\"}}]]")
        };
        let huge = Json::parse(&term("4294967295")).unwrap();
        assert!(qpoly_from_json(&huge).is_err());
        let sane = Json::parse(&term("3")).unwrap();
        assert_eq!(
            qpoly_from_json(&sane).unwrap(),
            QPoly::var("n").pow(3)
        );
        assert!(fit_from_json(&Json::parse("{\"params\":[1]}").unwrap()).is_err());
        assert!(stats_from_json(&Json::parse("{\"ops\":[]}").unwrap()).is_err());
        // Length mismatch between names and values.
        let j = Json::parse(
            "{\"param_names\":[\"a\"],\"params\":[1,2],\"residual\":0,\"iterations\":1}",
        )
        .unwrap();
        assert!(fit_from_json(&j).is_err());
    }
}
