//! Disk-backed artifact store: persisted symbolic statistics and
//! calibration fits.
//!
//! Layout under the store root (the CLI's `--store <dir>`):
//!
//! ```text
//! <root>/stats/<fingerprint:032x>-sg<sub_group_size>.json
//! <root>/fits/<case>-<device>-<linear|overlap>.json
//! ```
//!
//! Every artifact embeds [`STORE_FORMAT_VERSION`] plus the key it was
//! written under; [`ArtifactStore::load_stats`] / `load_fit` return
//! `None` — forcing a fresh gather or refit — whenever the version,
//! the embedded key, or the payload fails to validate.  A stale or
//! corrupt store therefore degrades to a cold start, never to garbage
//! predictions.
//!
//! Writes go through a temp file + rename, so a crashed or concurrent
//! writer can leave behind at worst a stale temp file, never a torn
//! artifact.  The store implements [`StatsBacking`], which is how a
//! [`StatsCache`](crate::stats::StatsCache) built with
//! `with_backing` transparently persists the counting pass across
//! processes.

use std::path::{Path, PathBuf};

use super::codec;
use crate::calibrate::FitResult;
use crate::stats::{KernelStats, StatsBacking, StatsKey};
use crate::util::json::Json;

/// Bump when any persisted representation (or its semantics) changes;
/// all artifacts written under other versions are ignored.
pub const STORE_FORMAT_VERSION: u64 = 1;

/// Identity of one calibration artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FitKey {
    pub case: String,
    pub device: String,
    pub nonlinear: bool,
    /// Hash over the model's feature columns, the measurement-set
    /// filter tags, the device's sub-group size and the store format
    /// version — so a fit is reused only while everything that shaped
    /// it is unchanged.
    pub model_fingerprint: u128,
}

/// Disk-backed persistence for session artifacts.
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    /// Open (creating if necessary) a store rooted at `root`, and
    /// verify up front that both artifact directories are writable —
    /// so a bad `--store` argument fails before any expensive work,
    /// not after.
    pub fn open(root: impl Into<PathBuf>) -> Result<ArtifactStore, String> {
        let root = root.into();
        for sub in ["stats", "fits"] {
            crate::util::ensure_writable_dir(
                &root.join(sub),
                "artifact store directory",
            )?;
        }
        Ok(ArtifactStore { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn stats_path(&self, key: &StatsKey) -> PathBuf {
        self.root.join("stats").join(format!(
            "{:032x}-sg{}.json",
            key.fingerprint, key.sub_group_size
        ))
    }

    fn fit_path(&self, key: &FitKey) -> PathBuf {
        let form = if key.nonlinear { "overlap" } else { "linear" };
        self.root
            .join("fits")
            .join(format!("{}-{}-{form}.json", key.case, key.device))
    }

    /// Atomic-enough write: temp file in the target directory + rename.
    fn write_atomic(&self, path: &Path, text: &str) -> Result<(), String> {
        let tmp = path.with_extension(format!("tmp.{}", std::process::id()));
        std::fs::write(&tmp, text)
            .map_err(|e| format!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| format!("publishing {}: {e}", path.display()))
    }

    fn read_versioned(&self, path: &Path, kind: &str) -> Option<Json> {
        let text = std::fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        let version = j.get("format_version")?.as_f64()?;
        if version != STORE_FORMAT_VERSION as f64 {
            return None;
        }
        if j.get("kind")?.as_str()? != kind {
            return None;
        }
        Some(j)
    }

    /// Run an artifact loader with panic containment: the store's
    /// contract is that a corrupt artifact degrades to a cold start,
    /// and decoded values flow into checked arithmetic (e.g. `Rat`
    /// deliberately panics on overflow) that hand-edited JSON could
    /// otherwise trip.
    fn contained<T>(f: impl FnOnce() -> Option<T>) -> Option<T> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
            .ok()
            .flatten()
    }

    /// Load a persisted stats bundle; `None` on miss, version skew,
    /// key mismatch or parse failure.
    pub fn load_stats(&self, key: &StatsKey) -> Option<KernelStats> {
        Self::contained(|| {
            let j = self.read_versioned(&self.stats_path(key), "kernel-stats")?;
            if j.get("fingerprint")?.as_str()? != format!("{:032x}", key.fingerprint) {
                return None;
            }
            if j.get("sub_group_size")?.as_f64()? != key.sub_group_size as f64 {
                return None;
            }
            let st = codec::stats_from_json(j.get("stats")?).ok()?;
            (st.sub_group_size == key.sub_group_size).then_some(st)
        })
    }

    pub fn save_stats(&self, key: &StatsKey, stats: &KernelStats) -> Result<(), String> {
        let j = Json::obj(vec![
            ("format_version", (STORE_FORMAT_VERSION as i64).into()),
            ("kind", "kernel-stats".into()),
            ("fingerprint", format!("{:032x}", key.fingerprint).into()),
            ("sub_group_size", (key.sub_group_size as i64).into()),
            ("stats", codec::stats_to_json(stats)),
        ]);
        self.write_atomic(&self.stats_path(key), &j.to_string())
    }

    /// Load a persisted calibration; `None` unless the format version
    /// and the full model fingerprint both match.
    pub fn load_fit(&self, key: &FitKey) -> Option<FitResult> {
        Self::contained(|| {
            let j = self.read_versioned(&self.fit_path(key), "fit")?;
            if j.get("case")?.as_str()? != key.case
                || j.get("device")?.as_str()? != key.device
            {
                return None;
            }
            if j.get("model_fingerprint")?.as_str()?
                != format!("{:032x}", key.model_fingerprint)
            {
                return None;
            }
            codec::fit_from_json(j.get("fit")?).ok()
        })
    }

    pub fn save_fit(&self, key: &FitKey, fit: &FitResult) -> Result<(), String> {
        let j = Json::obj(vec![
            ("format_version", (STORE_FORMAT_VERSION as i64).into()),
            ("kind", "fit".into()),
            ("case", key.case.as_str().into()),
            ("device", key.device.as_str().into()),
            ("nonlinear", key.nonlinear.into()),
            (
                "model_fingerprint",
                format!("{:032x}", key.model_fingerprint).into(),
            ),
            ("fit", codec::fit_to_json(fit)),
        ]);
        self.write_atomic(&self.fit_path(key), &j.to_string())
    }
}

impl StatsBacking for ArtifactStore {
    fn load(&self, key: &StatsKey) -> Option<KernelStats> {
        self.load_stats(key)
    }

    fn store(&self, key: &StatsKey, stats: &KernelStats) {
        // Best-effort: a full disk must not fail the in-memory lookup.
        if let Err(e) = self.save_stats(key, stats) {
            eprintln!("warning: artifact store write failed: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    fn tmp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "perflex-store-test-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn open_rejects_unusable_roots() {
        let dir = tmp_store("open");
        // A file where the root should be.
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("not-a-dir");
        std::fs::write(&file, b"x").unwrap();
        assert!(ArtifactStore::open(&file).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_roundtrip_through_disk() {
        let dir = tmp_store("stats");
        let store = ArtifactStore::open(&dir).unwrap();
        let k = crate::uipick::derived::build_axpy(DType::F32).unwrap().freeze();
        let st = crate::stats::gather(&k, 32).unwrap();
        let key = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 32,
        };
        assert!(store.load_stats(&key).is_none(), "cold store must miss");
        store.save_stats(&key, &st).unwrap();
        let back = store.load_stats(&key).expect("saved stats must load");
        let env: std::collections::BTreeMap<String, i128> =
            [("n".to_string(), 1 << 20)].into_iter().collect();
        assert_eq!(
            st.op_count(DType::F32, "madd").eval(&env),
            back.op_count(DType::F32, "madd").eval(&env)
        );
        // A different sub-group size is a different artifact.
        let other = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 64,
        };
        assert!(store.load_stats(&other).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skew_and_key_mismatch_are_rejected() {
        let dir = tmp_store("skew");
        let store = ArtifactStore::open(&dir).unwrap();
        let fit = FitResult {
            param_names: vec!["p_a".into()],
            params: vec![2.0],
            residual: 0.0,
            iterations: 3,
        };
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            model_fingerprint: 0xabcd,
        };
        store.save_fit(&key, &fit).unwrap();
        assert!(store.load_fit(&key).is_some());

        // Model changed: same path, different fingerprint -> refit.
        let moved = FitKey {
            model_fingerprint: 0xabce,
            ..key.clone()
        };
        assert!(store.load_fit(&moved).is_none());

        // Stale format version on disk -> rejected (refit), not parsed.
        let path = store.fit_path(&key);
        let stale = std::fs::read_to_string(&path)
            .unwrap()
            .replace("\"format_version\":1", "\"format_version\":999");
        assert_ne!(
            stale,
            std::fs::read_to_string(&path).unwrap(),
            "version field must exist to be tampered with"
        );
        std::fs::write(&path, stale).unwrap();
        assert!(store.load_fit(&key).is_none());

        // Truncated JSON -> rejected.
        std::fs::write(&path, "{\"format_version\":1,\"kind\":\"fit\"").unwrap();
        assert!(store.load_fit(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
