//! Disk-backed artifact store: persisted symbolic statistics and
//! calibration fits, shareable fleet-wide and self-maintaining.
//!
//! Layout under the store root (the CLI's `--store <dir>`):
//!
//! ```text
//! <root>/stats/<fingerprint:032x>-sg<sub_group_size>.json
//! <root>/fits/<case>-<device>-<linear|overlap>-<keyhash:016x>.json
//! ```
//!
//! Fit filename components are sanitized to `[A-Za-z0-9_]` (raw case
//! or device ids containing `-`, `/` or `..` can neither collide nor
//! escape the store root) and disambiguated by a hash of the *raw*
//! key, so distinct keys always map to distinct paths.
//!
//! Every artifact embeds [`STORE_FORMAT_VERSION`] plus the key it was
//! written under; [`ArtifactStore::load_stats`] / `load_fit` return
//! `None` — forcing a fresh gather or refit — whenever the version,
//! the embedded key, or the payload fails to validate.  A stale or
//! corrupt store therefore degrades to a cold start, never to garbage
//! predictions.
//!
//! Writes go through a per-writer-unique temp file + rename, so any
//! number of concurrent writers — threads of one process or whole
//! fleet calibrations racing on a shared store — can leave behind at
//! worst a stale temp file, never a torn artifact.
//! [`ArtifactStore::gc`] is the maintenance half: it sweeps orphaned
//! temp files and ages out artifacts whose format version, placement
//! or model fingerprint no longer matches anything the current binary
//! can reach (`perflex store gc`).
//!
//! The store implements [`StatsBacking`], which is how a
//! [`StatsCache`](crate::stats::StatsCache) built with
//! `with_backing` transparently persists the counting pass across
//! processes — and, because stats keys are device-independent
//! (kernel fingerprint + sub-group size), across *devices*: in a
//! fleet calibration against one shared store, every device with the
//! same sub-group size reuses the first device's counting passes.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::SystemTime;

use super::codec;
use crate::calibrate::FitResult;
use crate::stats::{KernelStats, StatsBacking, StatsKey};
use crate::util::json::Json;
use crate::util::Fnv128;

/// Bump when any persisted representation (or its semantics) changes;
/// all artifacts written under other versions are ignored (and swept
/// by `store gc`).  v2: sanitized + hash-disambiguated fit filenames.
pub const STORE_FORMAT_VERSION: u64 = 2;

/// Identity of one calibration artifact.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FitKey {
    pub case: String,
    pub device: String,
    pub nonlinear: bool,
    /// Hash over the model's feature columns, the measurement-set
    /// filter tags, the device's sub-group size and the store format
    /// version — so a fit is reused only while everything that shaped
    /// it is unchanged.
    pub model_fingerprint: u128,
}

/// Disk-backed persistence for session artifacts.
pub struct ArtifactStore {
    root: PathBuf,
}

impl ArtifactStore {
    /// Open (creating if necessary) a store rooted at `root`, and
    /// verify up front that both artifact directories are writable —
    /// so a bad `--store` argument fails before any expensive work,
    /// not after.
    pub fn open(root: impl Into<PathBuf>) -> Result<ArtifactStore, String> {
        let root = root.into();
        for sub in ["stats", "fits"] {
            crate::util::ensure_writable_dir(
                &root.join(sub),
                "artifact store directory",
            )?;
        }
        Ok(ArtifactStore { root })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn stats_path(&self, key: &StatsKey) -> PathBuf {
        self.root.join("stats").join(format!(
            "{}-sg{}.json",
            codec::fingerprint_to_hex(key.fingerprint),
            key.sub_group_size
        ))
    }

    /// One filename component: anything outside `[A-Za-z0-9_]` maps to
    /// `_` (bounded length), so raw case/device ids can neither escape
    /// the store root nor smuggle the `-` field separator.
    fn sanitize_component(s: &str) -> String {
        let mut out: String = s
            .chars()
            .take(40)
            .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
            .collect();
        if out.is_empty() {
            out.push('_');
        }
        out
    }

    fn fit_path(&self, key: &FitKey) -> PathBuf {
        let form = if key.nonlinear { "overlap" } else { "linear" };
        // Sanitization is lossy ("fdiff-16x16" and "fdiff_16x16" both
        // map to "fdiff_16x16"), so the filename carries a hash of the
        // raw key fields: distinct keys get distinct paths, and the
        // readable prefix stays for humans.  The embedded-key check in
        // `load_fit` remains the actual guard.
        let mut h = Fnv128::new();
        h.update(key.case.as_bytes());
        h.update(key.device.as_bytes());
        h.update(form.as_bytes());
        self.root.join("fits").join(format!(
            "{}-{}-{form}-{:016x}.json",
            Self::sanitize_component(&key.case),
            Self::sanitize_component(&key.device),
            h.finish() as u64
        ))
    }

    /// Atomic-enough write: temp file in the target directory + rename.
    /// The temp name is unique per (process, write), so concurrent
    /// writers — even two threads publishing the same artifact — never
    /// clobber each other's temp file; `store gc` sweeps any orphan a
    /// crashed writer leaves behind.
    fn write_atomic(&self, path: &Path, text: &str) -> Result<(), String> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, text)
            .map_err(|e| format!("writing {}: {e}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| format!("publishing {}: {e}", path.display()))
    }

    fn read_versioned(&self, path: &Path, kind: &str) -> Option<Json> {
        let text = std::fs::read_to_string(path).ok()?;
        let j = Json::parse(&text).ok()?;
        let version = j.get("format_version")?.as_f64()?;
        if version != STORE_FORMAT_VERSION as f64 {
            return None;
        }
        if j.get("kind")?.as_str()? != kind {
            return None;
        }
        Some(j)
    }

    /// Run an artifact loader with panic containment: the store's
    /// contract is that a corrupt artifact degrades to a cold start,
    /// and decoded values flow into checked arithmetic (e.g. `Rat`
    /// deliberately panics on overflow) that hand-edited JSON could
    /// otherwise trip.
    fn contained<T>(f: impl FnOnce() -> Option<T>) -> Option<T> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
            .ok()
            .flatten()
    }

    /// Load a persisted stats bundle; `None` on miss, version skew,
    /// key mismatch or parse failure.
    pub fn load_stats(&self, key: &StatsKey) -> Option<KernelStats> {
        Self::contained(|| {
            let j = self.read_versioned(&self.stats_path(key), "kernel-stats")?;
            if j.get("fingerprint")?.as_str()?
                != codec::fingerprint_to_hex(key.fingerprint)
            {
                return None;
            }
            if j.get("sub_group_size")?.as_f64()? != key.sub_group_size as f64 {
                return None;
            }
            let st = codec::stats_from_json(j.get("stats")?).ok()?;
            (st.sub_group_size == key.sub_group_size).then_some(st)
        })
    }

    pub fn save_stats(&self, key: &StatsKey, stats: &KernelStats) -> Result<(), String> {
        let j = Json::obj(vec![
            ("format_version", (STORE_FORMAT_VERSION as i64).into()),
            ("kind", "kernel-stats".into()),
            ("fingerprint", codec::fingerprint_to_hex(key.fingerprint).into()),
            ("sub_group_size", (key.sub_group_size as i64).into()),
            ("stats", codec::stats_to_json(stats)),
        ]);
        self.write_atomic(&self.stats_path(key), &j.to_string())
    }

    /// Load a persisted calibration; `None` unless the format version
    /// and the full model fingerprint both match.
    pub fn load_fit(&self, key: &FitKey) -> Option<FitResult> {
        Self::contained(|| {
            let j = self.read_versioned(&self.fit_path(key), "fit")?;
            if j.get("case")?.as_str()? != key.case
                || j.get("device")?.as_str()? != key.device
            {
                return None;
            }
            if j.get("model_fingerprint")?.as_str()?
                != codec::fingerprint_to_hex(key.model_fingerprint)
            {
                return None;
            }
            codec::fit_from_json(j.get("fit")?).ok()
        })
    }

    pub fn save_fit(&self, key: &FitKey, fit: &FitResult) -> Result<(), String> {
        let j = Json::obj(vec![
            ("format_version", (STORE_FORMAT_VERSION as i64).into()),
            ("kind", "fit".into()),
            ("case", key.case.as_str().into()),
            ("device", key.device.as_str().into()),
            ("nonlinear", key.nonlinear.into()),
            (
                "model_fingerprint",
                codec::fingerprint_to_hex(key.model_fingerprint).into(),
            ),
            ("fit", codec::fit_to_json(fit)),
        ]);
        self.write_atomic(&self.fit_path(key), &j.to_string())
    }

    /// Inventory of every file under the store's artifact directories,
    /// classified and validated (`perflex store ls`/`stat`), sorted by
    /// path for deterministic output.
    pub fn list(&self) -> Result<Vec<ArtifactInfo>, String> {
        let mut out = Vec::new();
        for sub in ["stats", "fits"] {
            let dir = self.root.join(sub);
            let entries = std::fs::read_dir(&dir)
                .map_err(|e| format!("reading {}: {e}", dir.display()))?;
            for entry in entries {
                let entry =
                    entry.map_err(|e| format!("reading {}: {e}", dir.display()))?;
                let path = entry.path();
                if path.is_file() {
                    out.push(self.classify(sub, &path));
                }
            }
        }
        out.sort_by(|a, b| a.path.cmp(&b.path));
        Ok(out)
    }

    fn classify(&self, sub: &str, path: &Path) -> ArtifactInfo {
        let (bytes, age_secs) = match std::fs::metadata(path) {
            Ok(m) => (
                m.len(),
                m.modified().ok().and_then(|t| {
                    SystemTime::now().duration_since(t).ok().map(|d| d.as_secs())
                }),
            ),
            Err(_) => (0, None),
        };
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        let (kind, describe, model_fingerprint, valid) =
            if name.contains(".tmp.") {
                (
                    ArtifactKind::Temp,
                    "temp file from an interrupted write".to_string(),
                    None,
                    false,
                )
            } else if !name.ends_with(".json") {
                (
                    ArtifactKind::Other,
                    "foreign file (left alone)".to_string(),
                    None,
                    true,
                )
            } else if sub == "stats" {
                let (describe, valid) = self.classify_stats(path, name);
                (ArtifactKind::Stats, describe, None, valid)
            } else {
                let (describe, fp, valid) = self.classify_fit(path);
                (ArtifactKind::Fit, describe, fp, valid)
            };
        ArtifactInfo {
            path: path.to_path_buf(),
            kind,
            bytes,
            age_secs,
            describe,
            model_fingerprint,
            valid,
        }
    }

    fn classify_stats(&self, path: &Path, name: &str) -> (String, bool) {
        // Filename scheme: <fingerprint:032x>-sg<sub_group_size>.json.
        let key = name
            .strip_suffix(".json")
            .and_then(|stem| stem.split_once("-sg"))
            .and_then(|(fp_hex, sg)| {
                Some(StatsKey {
                    fingerprint: codec::fingerprint_from_hex(fp_hex).ok()?,
                    sub_group_size: sg.parse().ok()?,
                })
            });
        match key {
            Some(key) => {
                let valid = self.stats_path(&key) == path
                    && self.load_stats(&key).is_some();
                (
                    format!(
                        "stats kernel={} sg={}",
                        codec::fingerprint_to_hex(key.fingerprint),
                        key.sub_group_size
                    ),
                    valid,
                )
            }
            None => ("unrecognized stats filename".to_string(), false),
        }
    }

    fn classify_fit(&self, path: &Path) -> (String, Option<u128>, bool) {
        let parsed = Self::contained(|| {
            let j = self.read_versioned(path, "fit")?;
            let key = FitKey {
                case: j.get("case")?.as_str()?.to_string(),
                device: j.get("device")?.as_str()?.to_string(),
                nonlinear: j.get("nonlinear")?.as_bool()?,
                model_fingerprint: codec::fingerprint_from_hex(
                    j.get("model_fingerprint")?.as_str()?,
                )
                .ok()?,
            };
            let payload_ok = codec::fit_from_json(j.get("fit")?).is_ok();
            Some((key, payload_ok))
        });
        match parsed {
            Some((key, payload_ok)) => {
                // A valid artifact also lives where its embedded key
                // says it should: anything else (e.g. a file written
                // under an older path scheme) can never be loaded and
                // is GC fodder.
                let placed = self.fit_path(&key) == path;
                let form = if key.nonlinear { "overlap" } else { "linear" };
                (
                    format!(
                        "fit {}/{} {form} model={}",
                        key.case,
                        key.device,
                        codec::fingerprint_to_hex(key.model_fingerprint)
                    ),
                    Some(key.model_fingerprint),
                    payload_ok && placed,
                )
            }
            None => (
                "unreadable, stale-version or foreign fit artifact".to_string(),
                None,
                false,
            ),
        }
    }

    /// Age out everything the store can prove dead: artifacts that are
    /// corrupt, carry a stale [`STORE_FORMAT_VERSION`], sit at a path
    /// their embedded key no longer maps to, or (for fits, when a
    /// reachability set is given) belong to a model fingerprint the
    /// current binary can no longer produce — plus temp files older
    /// than `temp_ttl_secs`.  Foreign files are never touched.
    pub fn gc(&self, opts: &GcOptions) -> Result<GcOutcome, String> {
        let mut out = GcOutcome::default();
        for info in self.list()? {
            out.scanned += 1;
            let reason = match info.kind {
                ArtifactKind::Temp => {
                    if info.age_secs.is_some_and(|a| a >= opts.temp_ttl_secs) {
                        Some("orphaned temp file".to_string())
                    } else {
                        None
                    }
                }
                ArtifactKind::Other => None,
                ArtifactKind::Stats | ArtifactKind::Fit if !info.valid => {
                    Some("stale, corrupt or misplaced artifact".to_string())
                }
                ArtifactKind::Fit => match (opts.reachable_fits, info.model_fingerprint)
                {
                    (Some(reach), Some(fp)) if !reach.contains(&fp) => Some(
                        "model fingerprint unreachable from this binary".to_string(),
                    ),
                    _ => None,
                },
                ArtifactKind::Stats => None,
            };
            if let Some(reason) = reason {
                if !opts.dry_run {
                    std::fs::remove_file(&info.path).map_err(|e| {
                        format!("removing {}: {e}", info.path.display())
                    })?;
                }
                out.reclaimed_bytes += info.bytes;
                out.removed.push((info.path, reason));
            }
        }
        Ok(out)
    }
}

/// Classification of one file found under the store root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Stats,
    Fit,
    /// A `*.tmp.*` file left by an interrupted [`ArtifactStore`] write.
    Temp,
    /// Anything the store did not write; never removed.
    Other,
}

/// One entry of [`ArtifactStore::list`].
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub path: PathBuf,
    pub kind: ArtifactKind,
    pub bytes: u64,
    /// Seconds since last modification (None when the filesystem
    /// withholds mtimes).
    pub age_secs: Option<u64>,
    /// Human-readable key description for `store ls`.
    pub describe: String,
    /// Embedded model fingerprint (fit artifacts only).
    pub model_fingerprint: Option<u128>,
    /// Parses, carries the current format version, and lives at the
    /// path its embedded key maps to.
    pub valid: bool,
}

/// Policy knobs for [`ArtifactStore::gc`].
#[derive(Clone, Copy, Debug)]
pub struct GcOptions<'a> {
    /// Model fingerprints still derivable from this binary (see
    /// [`super::reachable_fit_fingerprints`]); fits outside the set
    /// are aged out.  `None` skips reachability pruning.
    pub reachable_fits: Option<&'a HashSet<u128>>,
    /// Minimum age before a temp file counts as orphaned — a live
    /// writer's temp is younger than this.
    pub temp_ttl_secs: u64,
    /// Report what would be removed without deleting anything.
    pub dry_run: bool,
}

impl Default for GcOptions<'_> {
    fn default() -> Self {
        GcOptions {
            reachable_fits: None,
            // Long enough that any live writer has finished its rename.
            temp_ttl_secs: 15 * 60,
            dry_run: false,
        }
    }
}

/// What [`ArtifactStore::gc`] did (or, dry-run, would do).
#[derive(Debug, Default)]
pub struct GcOutcome {
    pub scanned: usize,
    /// `(path, reason)` per removed artifact, in path order.
    pub removed: Vec<(PathBuf, String)>,
    pub reclaimed_bytes: u64,
}

impl StatsBacking for ArtifactStore {
    fn load(&self, key: &StatsKey) -> Option<KernelStats> {
        self.load_stats(key)
    }

    fn store(&self, key: &StatsKey, stats: &KernelStats) {
        // Best-effort: a full disk must not fail the in-memory lookup.
        if let Err(e) = self.save_stats(key, stats) {
            eprintln!("warning: artifact store write failed: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    fn tmp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "perflex-store-test-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn open_rejects_unusable_roots() {
        let dir = tmp_store("open");
        // A file where the root should be.
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("not-a-dir");
        std::fs::write(&file, b"x").unwrap();
        assert!(ArtifactStore::open(&file).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_roundtrip_through_disk() {
        let dir = tmp_store("stats");
        let store = ArtifactStore::open(&dir).unwrap();
        let k = crate::uipick::derived::build_axpy(DType::F32).unwrap().freeze();
        let st = crate::stats::gather(&k, 32).unwrap();
        let key = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 32,
        };
        assert!(store.load_stats(&key).is_none(), "cold store must miss");
        store.save_stats(&key, &st).unwrap();
        let back = store.load_stats(&key).expect("saved stats must load");
        let env: std::collections::BTreeMap<String, i128> =
            [("n".to_string(), 1 << 20)].into_iter().collect();
        assert_eq!(
            st.op_count(DType::F32, "madd").eval(&env),
            back.op_count(DType::F32, "madd").eval(&env)
        );
        // A different sub-group size is a different artifact.
        let other = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 64,
        };
        assert!(store.load_stats(&other).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skew_and_key_mismatch_are_rejected() {
        let dir = tmp_store("skew");
        let store = ArtifactStore::open(&dir).unwrap();
        let fit = FitResult {
            param_names: vec!["p_a".into()],
            params: vec![2.0],
            residual: 0.0,
            iterations: 3,
        };
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            model_fingerprint: 0xabcd,
        };
        store.save_fit(&key, &fit).unwrap();
        assert!(store.load_fit(&key).is_some());

        // Model changed: same path, different fingerprint -> refit.
        let moved = FitKey {
            model_fingerprint: 0xabce,
            ..key.clone()
        };
        assert!(store.load_fit(&moved).is_none());

        // Stale format version on disk -> rejected (refit), not parsed.
        let path = store.fit_path(&key);
        let stale = std::fs::read_to_string(&path).unwrap().replace(
            &format!("\"format_version\":{STORE_FORMAT_VERSION}"),
            "\"format_version\":999",
        );
        assert_ne!(
            stale,
            std::fs::read_to_string(&path).unwrap(),
            "version field must exist to be tampered with"
        );
        std::fs::write(&path, stale).unwrap();
        assert!(store.load_fit(&key).is_none());

        // Truncated JSON -> rejected.
        std::fs::write(&path, "{\"format_version\":2,\"kind\":\"fit\"").unwrap();
        assert!(store.load_fit(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn some_fit(p: f64) -> FitResult {
        FitResult {
            param_names: vec!["p_a".into()],
            params: vec![p],
            residual: 0.0,
            iterations: 1,
        }
    }

    /// The path-ambiguity regression: raw case/device ids containing
    /// `-` used to collide in `<case>-<device>-<form>.json`, and path
    /// characters could escape the store root.
    #[test]
    fn ambiguous_and_hostile_fit_keys_get_distinct_contained_paths() {
        let dir = tmp_store("paths");
        let store = ArtifactStore::open(&dir).unwrap();
        // "fdiff-16x16" + "dev" vs "fdiff" + "16x16-dev": identical
        // under naive concatenation.
        let a = FitKey {
            case: "fdiff-16x16".into(),
            device: "dev".into(),
            nonlinear: false,
            model_fingerprint: 1,
        };
        let b = FitKey {
            case: "fdiff".into(),
            device: "16x16-dev".into(),
            nonlinear: false,
            model_fingerprint: 2,
        };
        assert_ne!(store.fit_path(&a), store.fit_path(&b));
        store.save_fit(&a, &some_fit(1.0)).unwrap();
        store.save_fit(&b, &some_fit(2.0)).unwrap();
        assert_eq!(store.load_fit(&a).unwrap().params, vec![1.0]);
        assert_eq!(store.load_fit(&b).unwrap().params, vec![2.0]);

        // Hostile components stay inside <root>/fits.
        let evil = FitKey {
            case: "../../escape".into(),
            device: "a/b\\c".into(),
            nonlinear: true,
            model_fingerprint: 3,
        };
        let p = store.fit_path(&evil);
        assert!(p.starts_with(dir.join("fits")), "{}", p.display());
        store.save_fit(&evil, &some_fit(3.0)).unwrap();
        assert_eq!(store.load_fit(&evil).unwrap().params, vec![3.0]);
        assert!(
            std::fs::read_dir(dir.join("fits")).unwrap().count() >= 3,
            "every artifact must land in the fits directory"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The temp-file-clobber regression: many threads publishing the
    /// same artifact path concurrently must all succeed (per-writer
    /// temp names) and leave no temp debris behind.
    #[test]
    fn concurrent_same_key_writers_never_clobber() {
        let dir = tmp_store("contend");
        let store = ArtifactStore::open(&dir).unwrap();
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            model_fingerprint: 7,
        };
        std::thread::scope(|s| {
            for t in 0..8 {
                let (store, key) = (&store, &key);
                s.spawn(move || {
                    for i in 0..20 {
                        store
                            .save_fit(key, &some_fit((t * 100 + i) as f64))
                            .expect("concurrent save must not clobber");
                    }
                });
            }
        });
        assert!(store.load_fit(&key).is_some(), "a torn artifact leaked");
        let leftovers: Vec<_> = std::fs::read_dir(dir.join("fits"))
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_sweeps_dead_artifacts_and_spares_live_ones() {
        let dir = tmp_store("gc");
        let store = ArtifactStore::open(&dir).unwrap();

        // Live artifacts: one stats bundle, one reachable fit.
        let k = crate::uipick::derived::build_axpy(DType::F32)
            .unwrap()
            .freeze();
        let skey = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 32,
        };
        store
            .save_stats(&skey, &crate::stats::gather(&k, 32).unwrap())
            .unwrap();
        let live = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            model_fingerprint: 0xa11ce,
        };
        store.save_fit(&live, &some_fit(1.0)).unwrap();

        // Dead: unreachable-model fit, stale-version file, corrupt
        // file, orphan temp, and a foreign file that must survive.
        let dead = FitKey {
            case: "matmul".into(),
            device: "retired_gpu".into(),
            nonlinear: false,
            model_fingerprint: 0xdead,
        };
        store.save_fit(&dead, &some_fit(2.0)).unwrap();
        let stale = dir.join("fits").join("old-fit-linear-0000000000000000.json");
        std::fs::write(
            &stale,
            "{\"format_version\":1,\"kind\":\"fit\",\"case\":\"x\"}",
        )
        .unwrap();
        let corrupt = dir.join("stats").join("nonsense.json");
        std::fs::write(&corrupt, "{not json").unwrap();
        let orphan = dir.join("stats").join("whatever.tmp.999.0");
        std::fs::write(&orphan, "partial").unwrap();
        let foreign = dir.join("fits").join("NOTES.txt");
        std::fs::write(&foreign, "hands off").unwrap();

        let reachable: HashSet<u128> = [0xa11ce_u128].into_iter().collect();
        // Dry run first: reports, removes nothing.
        let dry = store
            .gc(&GcOptions {
                reachable_fits: Some(&reachable),
                temp_ttl_secs: 0,
                dry_run: true,
            })
            .unwrap();
        assert_eq!(dry.removed.len(), 4, "{:?}", dry.removed);
        assert!(stale.exists() && corrupt.exists() && orphan.exists());

        let gc = store
            .gc(&GcOptions {
                reachable_fits: Some(&reachable),
                temp_ttl_secs: 0,
                dry_run: false,
            })
            .unwrap();
        assert_eq!(gc.removed.len(), 4, "{:?}", gc.removed);
        assert!(gc.reclaimed_bytes > 0);
        assert!(!stale.exists() && !corrupt.exists() && !orphan.exists());
        assert!(store.load_fit(&dead).is_none(), "unreachable fit aged out");
        assert!(foreign.exists(), "foreign files are never touched");
        assert!(store.load_fit(&live).is_some(), "live fit survives");
        assert!(store.load_stats(&skey).is_some(), "live stats survive");

        // A fresh temp file survives a TTL-respecting sweep.
        std::fs::write(dir.join("fits").join("busy.tmp.1.2"), "x").unwrap();
        let gentle = store.gc(&GcOptions::default()).unwrap();
        assert!(gentle.removed.is_empty(), "{:?}", gentle.removed);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
