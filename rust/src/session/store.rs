//! Disk-backed artifact store: persisted symbolic statistics and
//! calibration fits, shareable fleet-wide and self-maintaining.
//!
//! Layout under the store root (the CLI's `--store <dir>`):
//!
//! ```text
//! <root>/stats/<fingerprint:032x>-sg<sub_group_size>.json
//! <root>/fits/<case>-<device>-<linear|overlap>-<target>-m<fp:08x>-<keyhash:016x>.json
//! <root>/shared/<fingerprint:032x>.json     (deduplicated sg-invariant
//!                                            stats sections, `store compact`)
//! <root>/index.json + <root>/index.journal  (the store index, see
//!                                            [`super::index`])
//! ```
//!
//! Fit filename components are sanitized to `[A-Za-z0-9_]` (raw case
//! or device ids containing `-`, `/` or `..` can neither collide nor
//! escape the store root) and disambiguated by a hash of the *raw*
//! key — **including the model fingerprint**, whose leading 32 bits
//! also appear readably as the `m<fp:08x>` field.  Two fits that
//! differ only in model fingerprint (a re-featured model, or the
//! sg-32/sg-64 twins of a renamed device) therefore persist side by
//! side instead of silently evicting each other (the v2 scheme hashed
//! only case/device/form, so such siblings shared one path and the
//! embedded-key guard turned the loser into a permanent cold start).
//!
//! Every artifact embeds [`STORE_FORMAT_VERSION`] plus the key it was
//! written under; [`ArtifactStore::load_stats`] / `load_fit` return
//! `None` — forcing a fresh gather or refit — whenever the version,
//! the embedded key, or the payload fails to validate.  A stale or
//! corrupt store therefore degrades to a cold start, never to garbage
//! predictions.
//!
//! Lookups go through the journaled [`StoreIndex`](super::index): the
//! manifest of valid artifacts is loaded once per process (snapshot +
//! journal replay, rebuilt from a full scan on corruption or version
//! skew) and shared read-mostly across every fleet session holding
//! the store, so warm `load_*`, `store ls`, `stat` and `gc` answer
//! existence/validity questions with hash-map lookups instead of
//! per-lookup validation parses and O(N · parse) scans (a cold miss
//! still falls back to one cheap file-open probe, adopted on success —
//! the index accelerates, it is never the authority).  The store ledger
//! ([`ArtifactStore::ledger`]) tallies `index hits` against
//! `full-artifact parses` — the probe/validate/classify parses the
//! index is meant to eliminate; payload decodes of index-vouched
//! artifacts are the irreducible data fetch and are not counted.
//! With a fresh index, `store ls` and a warm `predict` report zero
//! full-artifact parses (the CI fleet-store job asserts it).
//!
//! Writes go through a per-writer-unique temp file + fsync + rename
//! (see [`ArtifactStore::write_atomic`] for the durability contract),
//! so any number of concurrent writers — threads of one process or
//! whole fleet calibrations racing on a shared store — can leave
//! behind at worst a stale temp file, never a torn or hollow artifact.
//!
//! The store is **multi-process safe** (the exact usage fleet-wide
//! sharing advertises: several `perflex` invocations on one
//! `--store`).  Three mechanisms, all in [`super::lock`]:
//!
//! * every journal append happens under the cross-process writer lock
//!   (`<root>/index.lock`) as a single fsynced `O_APPEND` line, so
//!   concurrent writers serialize and torn journal lines are
//!   impossible rather than merely tolerated;
//! * snapshot checkpoints are *epoch-fenced*: under the same lock, the
//!   checkpoint re-bases on the current on-disk snapshot (not this
//!   process's possibly-stale view), replays every journal line on
//!   top, writes `max(disk epoch, seen epoch) + 1`, and only then
//!   truncates the journal — no concurrent appender's put can be lost
//!   between snapshot-write and journal-truncate;
//! * destructive maintenance (`gc`, `compact`) runs under a lease
//!   (`<root>/gc.lease`, holder pid + expiry): a live foreign lease is
//!   a refusal, and each victim classified stale/corrupt is
//!   re-verified under the writer lock immediately before its unlink,
//!   so a concurrent calibrate that just republished a valid artifact
//!   at that path never has it deleted out from under it.
//!
//! [`ArtifactStore::verify_index`] (`perflex store verify`) asserts
//! the resulting invariant: the journaled index always agrees
//! entry-for-entry with a full rebuild scan of the artifacts on disk.
//!
//! [`ArtifactStore::gc`] is the maintenance half: it sweeps orphaned
//! temp files and ages out artifacts whose format version, placement
//! or model fingerprint no longer matches anything the current binary
//! can reach (`perflex store gc`).  [`ArtifactStore::compact`]
//! deduplicates the sub-group-size-invariant section of stats bundles
//! shared between sg families of one kernel fingerprint
//! (`perflex store compact`); reassembled bundles are structurally
//! identical to the originals, so compaction never changes a report
//! byte.
//!
//! The store implements [`StatsBacking`], which is how a
//! [`StatsCache`](crate::stats::StatsCache) built with
//! `with_backing` transparently persists the counting pass across
//! processes — and, because stats keys are device-independent
//! (kernel fingerprint + sub-group size), across *devices*: in a
//! fleet calibration against one shared store, every device with the
//! same sub-group size reuses the first device's counting passes.

use std::collections::{HashMap, HashSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::SystemTime;

use super::codec;
use super::index::{
    snapshot_epoch, JournalOp, StatsEntry, StoreIndex, JOURNAL_COMPACT_THRESHOLD,
};
use super::lock::{FileLock, Lease, LockOptions, DEFAULT_LEASE_TTL_SECS};
use crate::calibrate::{FitResult, Target};
use crate::stats::{KernelStats, StatsBacking, StatsKey};
use crate::util::json::Json;
use crate::util::Fnv128;

/// Bump when any persisted representation (or its semantics) changes;
/// all artifacts written under other versions are ignored (and swept
/// by `store gc`).  v3: fit paths hash the model fingerprint (siblings
/// differing only in model fingerprint no longer collide), the store
/// index (`index.json` + journal), and compacted stats artifacts
/// referencing `<root>/shared/` sections.  v4: fits carry a calibration
/// *target* (time/energy/avg_power) in their key, filename and
/// envelope; the one sanctioned skew is read-compat for v3 *time* fits
/// ([`ArtifactStore::load_legacy_v3_fit`] — a pre-bump fit is adopted
/// as `target=time` and re-saved under its v4 key instead of forcing a
/// cold refit).
pub const STORE_FORMAT_VERSION: u64 = 4;

/// Identity of one calibration artifact.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct FitKey {
    pub case: String,
    pub device: String,
    pub nonlinear: bool,
    /// The response variable the fit explains; fits for different
    /// targets of one (case, device, form) persist side by side.
    pub target: Target,
    /// Hash over the model's feature columns, the measurement-set
    /// filter tags, the device's sub-group size, the target and the
    /// store format version — so a fit is reused only while everything
    /// that shaped it is unchanged.
    pub model_fingerprint: u128,
}

/// One filename component: anything outside `[A-Za-z0-9_]` maps to
/// `_` (bounded length), so raw case/device ids can neither escape
/// the store root nor smuggle the `-` field separator.
fn sanitize_component(s: &str) -> String {
    let mut out: String = s
        .chars()
        .take(40)
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect();
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Stats artifact filename — fully invertible (the filename *is* the
/// key), which is what lets the index serialize keys instead of paths.
pub(crate) fn stats_file_name(key: &StatsKey) -> String {
    format!(
        "{}-sg{}.json",
        codec::fingerprint_to_hex(key.fingerprint),
        key.sub_group_size
    )
}

fn stats_key_from_name(name: &str) -> Option<StatsKey> {
    let stem = name.strip_suffix(".json")?;
    let (fp_hex, sg) = stem.split_once("-sg")?;
    Some(StatsKey {
        fingerprint: codec::fingerprint_from_hex(fp_hex).ok()?,
        sub_group_size: sg.parse().ok()?,
    })
}

/// Fit artifact filename.  Sanitization is lossy ("fdiff-16x16" and
/// "fdiff_16x16" both map to "fdiff_16x16"), so the filename carries a
/// hash of the raw key fields — case, device, form, **and the model
/// fingerprint** (the v2 bug: omitting it sent fingerprint-only
/// siblings to one path, where each save evicted the other).  NUL
/// separators keep adjacent fields from aliasing, the `m<fp:08x>`
/// field keeps the fingerprint readable for humans, and the
/// embedded-key check in `load_fit` remains the actual guard.
pub(crate) fn fit_file_name(key: &FitKey) -> String {
    let form = if key.nonlinear { "overlap" } else { "linear" };
    let mut h = Fnv128::new();
    h.update(key.case.as_bytes());
    h.update(&[0]);
    h.update(key.device.as_bytes());
    h.update(&[0]);
    h.update(form.as_bytes());
    h.update(&[0]);
    h.update(key.target.name().as_bytes());
    h.update(&[0]);
    h.update(&key.model_fingerprint.to_le_bytes());
    format!(
        "{}-{}-{form}-{}-m{:08x}-{:016x}.json",
        sanitize_component(&key.case),
        sanitize_component(&key.device),
        key.target.name(),
        (key.model_fingerprint >> 96) as u32,
        h.finish() as u64
    )
}

/// The v3 fit filename scheme (no target field in the name or the key
/// hash) — used only by [`ArtifactStore::load_legacy_v3_fit`] to locate
/// pre-bump artifacts for read-compat adoption.
pub(crate) fn legacy_v3_fit_file_name(key: &FitKey) -> String {
    let form = if key.nonlinear { "overlap" } else { "linear" };
    let mut h = Fnv128::new();
    h.update(key.case.as_bytes());
    h.update(&[0]);
    h.update(key.device.as_bytes());
    h.update(&[0]);
    h.update(form.as_bytes());
    h.update(&[0]);
    h.update(&key.model_fingerprint.to_le_bytes());
    format!(
        "{}-{}-{form}-m{:08x}-{:016x}.json",
        sanitize_component(&key.case),
        sanitize_component(&key.device),
        (key.model_fingerprint >> 96) as u32,
        h.finish() as u64
    )
}

/// Shared (sg-invariant) stats-section filename.
pub(crate) fn shared_file_name(fp: u128) -> String {
    format!("{}.json", codec::fingerprint_to_hex(fp))
}

fn shared_fp_from_name(name: &str) -> Option<u128> {
    codec::fingerprint_from_hex(name.strip_suffix(".json")?).ok()
}

/// Disk-backed persistence for session artifacts.
pub struct ArtifactStore {
    root: PathBuf,
    /// The journaled manifest of valid artifacts; read-mostly (every
    /// lookup takes a read lock, only adoption/eviction/maintenance
    /// write).
    index: RwLock<StoreIndex>,
    /// The snapshot epoch this process last observed or wrote; the
    /// checkpoint fence takes `max(disk, this) + 1`.
    epoch: AtomicU64,
    index_hits: AtomicU64,
    artifact_parses: AtomicU64,
    lock_acquired: AtomicU64,
    lock_contended: AtomicU64,
}

impl ArtifactStore {
    /// Open (creating if necessary) a store rooted at `root`, verify
    /// up front that the artifact directories are writable — so a bad
    /// `--store` argument fails before any expensive work — and load
    /// the store index (snapshot + journal replay; full rebuild scan
    /// on corruption or version skew).
    pub fn open(root: impl Into<PathBuf>) -> Result<ArtifactStore, String> {
        let root = root.into();
        for sub in ["stats", "fits", "shared"] {
            crate::util::ensure_writable_dir(
                &root.join(sub),
                "artifact store directory",
            )?;
        }
        let store = ArtifactStore {
            root,
            index: RwLock::new(StoreIndex::new()),
            epoch: AtomicU64::new(0),
            index_hits: AtomicU64::new(0),
            artifact_parses: AtomicU64::new(0),
            lock_acquired: AtomicU64::new(0),
            lock_contended: AtomicU64::new(0),
        };
        store.load_index()?;
        Ok(store)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// `(index hits, full-artifact parses)`: lookups answered by the
    /// in-memory index vs artifact files fully parsed to (re)establish
    /// identity or validity — the per-lookup probes and O(N) scan
    /// parses the index replaces.  Payload decodes of index-vouched
    /// artifacts are the data being fetched, not a probe, and are not
    /// counted; with a fresh index a warm run therefore reports zero
    /// full-artifact parses (CI-asserted).
    pub fn ledger(&self) -> (u64, u64) {
        (
            self.index_hits.load(Ordering::Relaxed),
            self.artifact_parses.load(Ordering::Relaxed),
        )
    }

    pub fn index_hits(&self) -> u64 {
        self.index_hits.load(Ordering::Relaxed)
    }

    pub fn artifact_parses(&self) -> u64 {
        self.artifact_parses.load(Ordering::Relaxed)
    }

    /// `(stats, fits, shared)` entry counts of the in-memory index.
    pub fn index_counts(&self) -> (usize, usize, usize) {
        self.index.read().unwrap().counts()
    }

    /// `(acquisitions, contended)` cross-process writer-lock counts:
    /// how often this process took the lock (journal appends,
    /// checkpoints, victim unlinks) and how many of those had to wait
    /// behind — or steal from — another holder.  Printed beside the
    /// store-index ledger by store-backed CLI commands.
    pub fn lock_ledger(&self) -> (u64, u64) {
        (
            self.lock_acquired.load(Ordering::Relaxed),
            self.lock_contended.load(Ordering::Relaxed),
        )
    }

    /// Acquire the cross-process writer lock, counted in the lock
    /// ledger.  NOT reentrant (a lock file cannot be): a holder must
    /// thread its guard to [`ArtifactStore::record_under`] and friends
    /// instead of re-acquiring.
    fn writer_lock(&self) -> Result<FileLock, String> {
        let lock = FileLock::acquire(&self.lock_path(), &LockOptions::default())?;
        self.lock_acquired.fetch_add(1, Ordering::Relaxed);
        if lock.contended() {
            self.lock_contended.fetch_add(1, Ordering::Relaxed);
        }
        Ok(lock)
    }

    fn count_parse(&self) {
        self.artifact_parses.fetch_add(1, Ordering::Relaxed);
    }

    fn count_hit(&self) {
        self.index_hits.fetch_add(1, Ordering::Relaxed);
    }

    fn stats_path(&self, key: &StatsKey) -> PathBuf {
        self.root.join("stats").join(stats_file_name(key))
    }

    fn fit_path(&self, key: &FitKey) -> PathBuf {
        self.root.join("fits").join(fit_file_name(key))
    }

    fn shared_path(&self, fp: u128) -> PathBuf {
        self.root.join("shared").join(shared_file_name(fp))
    }

    fn index_path(&self) -> PathBuf {
        self.root.join("index.json")
    }

    fn journal_path(&self) -> PathBuf {
        self.root.join("index.journal")
    }

    fn lock_path(&self) -> PathBuf {
        self.root.join("index.lock")
    }

    fn lease_path(&self) -> PathBuf {
        self.root.join("gc.lease")
    }

    // -----------------------------------------------------------------
    // Index maintenance
    // -----------------------------------------------------------------

    /// Load the index: snapshot, then journal replay on top.  Any
    /// corruption or version skew falls back to a full rebuild scan —
    /// the index is an accelerator, never an authority, so the worst
    /// a bad manifest can cost is one O(N) re-scan.
    fn load_index(&self) -> Result<(), String> {
        let parsed = std::fs::read_to_string(self.index_path())
            .ok()
            .and_then(|text| Json::parse(&text).ok());
        let snapshot = parsed.as_ref().and_then(|j| {
            StoreIndex::from_snapshot_json(j)
                .ok()
                .map(|ix| (ix, snapshot_epoch(j)))
        });
        if let Some((mut index, epoch)) = snapshot {
            self.epoch.store(epoch, Ordering::Relaxed);
            let (applied, skipped) = self.replay_journal(&mut index);
            *self.index.write().unwrap() = index;
            // Tidy the journal when it has grown long or accumulated
            // unparseable lines (crash-truncated tails).
            if skipped > 0 || applied > JOURNAL_COMPACT_THRESHOLD {
                self.checkpoint_index();
            }
            return Ok(());
        }
        self.rebuild_index()
    }

    /// Replay `index.journal` onto `index`, skipping unparseable lines
    /// (with locked single-write appends these can only be
    /// crash-truncated tails or hand edits, never live-writer
    /// interleavings).  A skipped line is at worst a lost put (the
    /// next lookup re-adopts from disk) or a lost delete (the next
    /// vouched load drops the dead entry), so journal damage degrades
    /// to a few extra parses — never to wrong answers, and never to a
    /// full rebuild.  Returns `(applied, skipped)` line counts.
    fn replay_journal(&self, index: &mut StoreIndex) -> (usize, usize) {
        let text = match std::fs::read_to_string(self.journal_path()) {
            Ok(t) => t,
            Err(_) => return (0, 0),
        };
        let (mut applied, mut skipped) = (0, 0);
        for line in text.lines().filter(|l| !l.trim().is_empty()) {
            match Json::parse(line).and_then(|j| JournalOp::from_json(&j)) {
                Ok(op) => {
                    index.apply(&op);
                    applied += 1;
                }
                Err(_) => skipped += 1,
            }
        }
        (applied, skipped)
    }

    /// Rebuild the manifest from a full scan, holding the writer lock
    /// for the whole rebuild: every artifact file is parsed and
    /// validated (each one a counted full-artifact parse), valid ones
    /// are indexed, and a fresh snapshot replaces the corrupt one.
    /// The (corrupt or stale) journal is truncated first: its contents
    /// predate what the scan observes, so merging it back could
    /// resurrect stale deletes.  Because appends also take the lock,
    /// no foreign journal line can slip between the scan and the
    /// snapshot write — a concurrent writer either blocks (bounded)
    /// or skips its line and is re-adopted on a later lookup.
    fn rebuild_index(&self) -> Result<(), String> {
        match self.writer_lock() {
            Ok(guard) => {
                let _ = std::fs::write(self.journal_path(), "");
                let index = self.scan_index(Some(&guard))?;
                let epoch = self.epoch.load(Ordering::Relaxed) + 1;
                // Best-effort snapshot: a full disk degrades to a
                // re-scan at the next open, never to an error.
                if self
                    .write_atomic(
                        &self.index_path(),
                        &index.to_snapshot_json(epoch).to_string(),
                    )
                    .is_ok()
                {
                    self.epoch.store(epoch, Ordering::Relaxed);
                }
                *self.index.write().unwrap() = index;
            }
            // Lock unavailable (a wedged or very slow holder): the
            // index is an accelerator, never an authority, so opening
            // must degrade rather than fail.  Scan into memory only —
            // truncating the journal or writing a snapshot without
            // the lock could clobber live writers — and let the next
            // open retry the locked rebuild.
            Err(_) => {
                let index = self.scan_index(None)?;
                *self.index.write().unwrap() = index;
            }
        }
        Ok(())
    }

    /// Scan the artifact directories into a fresh [`StoreIndex`]
    /// without touching the live index or the journal — the read core
    /// of both the (lock-holding) rebuild and [`verify_index`].  Every
    /// artifact read here is a counted full-artifact parse; a family's
    /// shared sg-invariant section is decoded once per scan (the
    /// shared pass runs first and feeds the stats pass), not once per
    /// compacted twin.  `keepalive` is the rebuild's held writer lock:
    /// it is refreshed as the scan walks, so a long scan never looks
    /// stale to contenders.
    fn scan_index(&self, keepalive: Option<&FileLock>) -> Result<StoreIndex, String> {
        let mut index = StoreIndex::new();
        let mut shared_ok: HashSet<u128> = HashSet::new();
        for sub in ["shared", "stats", "fits"] {
            if let Some(guard) = keepalive {
                guard.refresh();
            }
            let dir = self.root.join(sub);
            let entries = std::fs::read_dir(&dir)
                .map_err(|e| format!("reading {}: {e}", dir.display()))?;
            for (seen, entry) in entries.enumerate() {
                if seen % 128 == 127 {
                    if let Some(guard) = keepalive {
                        guard.refresh();
                    }
                }
                let entry =
                    entry.map_err(|e| format!("reading {}: {e}", dir.display()))?;
                let path = entry.path();
                let name = match path.file_name().and_then(|n| n.to_str()) {
                    Some(n) => n.to_string(),
                    None => continue,
                };
                if !path.is_file() || name.contains(".tmp.") || !name.ends_with(".json")
                {
                    continue;
                }
                match sub {
                    "stats" => {
                        let key = stats_key_from_name(&name)
                            .filter(|k| stats_file_name(k) == name);
                        if let Some(key) = key {
                            if let Some(compacted) = Self::contained(|| {
                                self.scan_stats_valid(&key, &shared_ok)
                            }) {
                                index.apply(&JournalOp::PutStats(
                                    key,
                                    StatsEntry { compacted },
                                ));
                            }
                        }
                    }
                    "fits" => {
                        let parsed =
                            Self::contained(|| self.parse_fit_file(&path));
                        if let Some((key, payload_ok)) = parsed {
                            if payload_ok && fit_file_name(&key) == name {
                                index.apply(&JournalOp::PutFit(key));
                            }
                        }
                    }
                    _ => {
                        let fp = shared_fp_from_name(&name)
                            .filter(|fp| shared_file_name(*fp) == name);
                        if let Some(fp) = fp {
                            if Self::contained(|| self.read_shared_scan(fp))
                                .is_some()
                            {
                                shared_ok.insert(fp);
                                index.apply(&JournalOp::PutShared(fp));
                            }
                        }
                    }
                }
            }
        }
        Ok(index)
    }

    /// Scan-time validity for one stats artifact: `Some(compacted)`
    /// when it parses, matches its key, and (compacted form) both its
    /// op section decodes and its shared section was validated by the
    /// scan's shared pass — so a family of `k` twins decodes the large
    /// invariant section once, not `k` times.  Counted parse, no index
    /// side effects.
    fn scan_stats_valid(
        &self,
        key: &StatsKey,
        shared_ok: &HashSet<u128>,
    ) -> Option<bool> {
        let text = std::fs::read_to_string(self.stats_path(key)).ok()?;
        self.count_parse();
        let j = Self::parse_versioned(&text, "kernel-stats")?;
        if j.get("fingerprint")?.as_str()?
            != codec::fingerprint_to_hex(key.fingerprint)
        {
            return None;
        }
        if j.get("sub_group_size")?.as_f64()? != key.sub_group_size as f64 {
            return None;
        }
        if let Some(stats) = j.get("stats") {
            let st = codec::stats_from_json(stats).ok()?;
            return (st.sub_group_size == key.sub_group_size).then_some(false);
        }
        if j.get("shared")?.as_str()? != codec::fingerprint_to_hex(key.fingerprint) {
            return None;
        }
        codec::ops_from_json(j.get("ops")?).ok()?;
        shared_ok.contains(&key.fingerprint).then_some(true)
    }

    /// Write an atomic snapshot of the index and truncate the journal,
    /// under the writer lock and epoch-fenced: the snapshot re-bases
    /// on the *current* on-disk snapshot — another process may have
    /// checkpointed since this one loaded its view — replays every
    /// journal line on top, and carries `max(disk epoch, seen epoch)
    /// + 1`, so no concurrent appender's put can be lost between the
    /// snapshot write and the journal truncation and an older view
    /// can never downgrade a newer snapshot.  Best-effort: an
    /// unacquirable lock or a full disk leaves the journal growing
    /// (replayed, or rebuilt, at the next open) — never the store in
    /// an error state.
    fn checkpoint_index(&self) {
        if let Ok(guard) = self.writer_lock() {
            self.checkpoint_under(&guard);
        }
    }

    fn checkpoint_under(&self, _guard: &FileLock) {
        let disk = std::fs::read_to_string(self.index_path())
            .ok()
            .and_then(|text| Json::parse(&text).ok());
        let (mut index, disk_epoch) = match disk
            .as_ref()
            .and_then(|j| StoreIndex::from_snapshot_json(j).ok())
        {
            Some(ix) => {
                let e = disk.as_ref().map(snapshot_epoch).unwrap_or(0);
                (ix, e)
            }
            // Unreadable disk snapshot: fall back to this process's
            // view (the journal replay below still folds in every
            // surviving foreign append).
            None => (
                self.index.read().unwrap().clone(),
                self.epoch.load(Ordering::Relaxed),
            ),
        };
        self.replay_journal(&mut index);
        let epoch = disk_epoch.max(self.epoch.load(Ordering::Relaxed)) + 1;
        if self
            .write_atomic(
                &self.index_path(),
                &index.to_snapshot_json(epoch).to_string(),
            )
            .is_ok()
        {
            let _ = std::fs::write(self.journal_path(), "");
            self.epoch.store(epoch, Ordering::Relaxed);
            *self.index.write().unwrap() = index;
        }
    }

    /// Apply one index mutation and append it to the journal.  The
    /// append happens under the cross-process writer lock as a single
    /// pre-rendered fsynced `write_all` on an `O_APPEND` handle:
    /// concurrent fleet *processes* serialize on the lock, so
    /// interleaved bytes — torn journal lines — are impossible by
    /// construction rather than merely tolerated by the replayer.
    /// Best-effort: when the lock (or the journal) is unavailable the
    /// in-memory index is still updated and only the line is lost,
    /// re-adopted on a later lookup or restored by a rebuild.
    fn record(&self, op: JournalOp) {
        match self.writer_lock() {
            Ok(guard) => self.record_under(op, &guard),
            Err(_) => self.index.write().unwrap().apply(&op),
        }
    }

    /// [`ArtifactStore::record`] for callers already holding the
    /// writer lock (GC's victim bookkeeping) — the lock file is not
    /// reentrant, so re-acquiring would deadlock until the staleness
    /// TTL.
    fn record_under(&self, op: JournalOp, _guard: &FileLock) {
        self.index.write().unwrap().apply(&op);
        use std::io::Write;
        let line = format!("{}\n", op.to_json());
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(self.journal_path())
        {
            // A failed write or fsync costs at worst this one line —
            // re-adopted later — never a torn one (single write, and
            // the lock excludes interleaving writers).
            if f.write_all(line.as_bytes()).is_ok() {
                let _ = f.sync_data();
            }
        }
    }

    // -----------------------------------------------------------------
    // Reads and writes
    // -----------------------------------------------------------------

    /// Atomic durable write: temp file in the target directory, fsync,
    /// rename, then a best-effort fsync of the parent directory.
    ///
    /// Durability contract: the payload reaches stable storage
    /// *before* the rename publishes it (renaming an unsynced temp
    /// can, after a crash, surface a published-but-empty artifact that
    /// later loads flag as corrupt and GC has to sweep), and the
    /// parent-directory sync makes the rename itself survive the
    /// crash.  So a crash at any point leaves either the old artifact,
    /// the new artifact, or a stale temp file — never a torn or hollow
    /// published file.  The temp name is unique per (process, write),
    /// so concurrent writers — even two threads publishing the same
    /// artifact — never clobber each other's temp file; `store gc`
    /// sweeps any orphan a crashed writer leaves behind.
    fn write_atomic(&self, path: &Path, text: &str) -> Result<(), String> {
        static TMP_SEQ: AtomicU64 = AtomicU64::new(0);
        let tmp = path.with_extension(format!(
            "tmp.{}.{}",
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .map_err(|e| format!("writing {}: {e}", tmp.display()))?;
            f.write_all(text.as_bytes())
                .map_err(|e| format!("writing {}: {e}", tmp.display()))?;
            f.sync_all()
                .map_err(|e| format!("syncing {}: {e}", tmp.display()))?;
        }
        std::fs::rename(&tmp, path)
            .map_err(|e| format!("publishing {}: {e}", path.display()))?;
        if let Some(parent) = path.parent() {
            if let Ok(dir) = std::fs::File::open(parent) {
                let _ = dir.sync_all();
            }
        }
        Ok(())
    }

    /// Validate the envelope of a parsed artifact: current format
    /// version + expected kind.
    fn parse_versioned(text: &str, kind: &str) -> Option<Json> {
        let j = Json::parse(text).ok()?;
        let version = j.get("format_version")?.as_f64()?;
        if version != STORE_FORMAT_VERSION as f64 {
            return None;
        }
        if j.get("kind")?.as_str()? != kind {
            return None;
        }
        Some(j)
    }

    /// Run an artifact loader with panic containment: the store's
    /// contract is that a corrupt artifact degrades to a cold start,
    /// and decoded values flow into checked arithmetic (e.g. `Rat`
    /// deliberately panics on overflow) that hand-edited JSON could
    /// otherwise trip.
    fn contained<T>(f: impl FnOnce() -> Option<T>) -> Option<T> {
        std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
            .ok()
            .flatten()
    }

    /// Load a persisted stats bundle; `None` on miss, version skew,
    /// key mismatch or parse failure.  An index hit vouches for the
    /// artifact (the read is a payload fetch); an index miss falls
    /// back to a disk probe — a counted full-artifact parse when the
    /// file exists — whose result is adopted into the index, so
    /// another process's writes cost one parse, then hash-map hits.
    pub fn load_stats(&self, key: &StatsKey) -> Option<KernelStats> {
        let indexed = self.index.read().unwrap().stats(key);
        let vouched = indexed.is_some();
        if vouched {
            self.count_hit();
        }
        let loaded = Self::contained(|| self.read_stats_artifact(key, vouched));
        match &loaded {
            // Adopt on miss, and refresh a lagging `compacted` flag on
            // a hit: another process's `store compact` may have
            // rewritten the artifact since this index was loaded, and
            // gc's shared-section reference set depends on the flag.
            Some((_, compacted)) => {
                let fresh = StatsEntry {
                    compacted: *compacted,
                };
                if indexed != Some(fresh) {
                    self.record(JournalOp::PutStats(*key, fresh));
                }
            }
            None if vouched => self.record(JournalOp::DelStats(*key)),
            None => {}
        }
        loaded.map(|(st, _)| st)
    }

    /// The full read path for one stats artifact; the returned flag is
    /// true when the artifact is in compacted form.
    fn read_stats_artifact(
        &self,
        key: &StatsKey,
        vouched: bool,
    ) -> Option<(KernelStats, bool)> {
        self.read_stats_with(key, vouched, false)
    }

    /// [`ArtifactStore::read_stats_artifact`] for scan paths that must
    /// not touch the live index — the rebuild scan (which holds the
    /// writer lock, and journal adoption would deadlock on it),
    /// `verify_index`, and GC's under-lock victim revalidation.  Every
    /// read is a counted parse, and a compacted twin's shared section
    /// is read raw instead of through the adopt-on-miss path.
    fn read_stats_scan(&self, key: &StatsKey) -> Option<(KernelStats, bool)> {
        self.read_stats_with(key, false, true)
    }

    fn read_stats_with(
        &self,
        key: &StatsKey,
        vouched: bool,
        scan: bool,
    ) -> Option<(KernelStats, bool)> {
        let text = std::fs::read_to_string(self.stats_path(key)).ok()?;
        if !vouched {
            self.count_parse();
        }
        let j = Self::parse_versioned(&text, "kernel-stats")?;
        if j.get("fingerprint")?.as_str()?
            != codec::fingerprint_to_hex(key.fingerprint)
        {
            return None;
        }
        if j.get("sub_group_size")?.as_f64()? != key.sub_group_size as f64 {
            return None;
        }
        if let Some(stats) = j.get("stats") {
            let st = codec::stats_from_json(stats).ok()?;
            return (st.sub_group_size == key.sub_group_size).then_some((st, false));
        }
        // Compacted form: per-sub-group op counts plus a reference to
        // the deduplicated sg-invariant section under <root>/shared/.
        if j.get("shared")?.as_str()? != codec::fingerprint_to_hex(key.fingerprint) {
            return None;
        }
        let ops = codec::ops_from_json(j.get("ops")?).ok()?;
        let shared = if scan {
            self.read_shared_scan(key.fingerprint)?
        } else {
            self.read_shared_artifact(key.fingerprint)?
        };
        Some((codec::stats_from_parts(shared, ops, key.sub_group_size), true))
    }

    fn decode_shared(text: &str, fp: u128) -> Option<codec::SharedStats> {
        let j = Self::parse_versioned(text, "kernel-stats-shared")?;
        if j.get("fingerprint")?.as_str()? != codec::fingerprint_to_hex(fp) {
            return None;
        }
        codec::stats_shared_from_json(j.get("shared")?).ok()
    }

    /// Load one shared sg-invariant stats section (compacted stores).
    fn read_shared_artifact(&self, fp: u128) -> Option<codec::SharedStats> {
        let vouched = self.index.read().unwrap().has_shared(fp);
        if vouched {
            self.count_hit();
        }
        let loaded = (|| {
            let text = std::fs::read_to_string(self.shared_path(fp)).ok()?;
            if !vouched {
                self.count_parse();
            }
            Self::decode_shared(&text, fp)
        })();
        if vouched && loaded.is_none() {
            self.record(JournalOp::DelShared(fp));
        }
        if !vouched && loaded.is_some() {
            self.record(JournalOp::PutShared(fp));
        }
        loaded
    }

    /// [`ArtifactStore::read_shared_artifact`] without index side
    /// effects (see [`ArtifactStore::read_stats_scan`]); the parse is
    /// counted.
    fn read_shared_scan(&self, fp: u128) -> Option<codec::SharedStats> {
        let text = std::fs::read_to_string(self.shared_path(fp)).ok()?;
        self.count_parse();
        Self::decode_shared(&text, fp)
    }

    pub fn save_stats(&self, key: &StatsKey, stats: &KernelStats) -> Result<(), String> {
        let j = Json::obj(vec![
            ("format_version", (STORE_FORMAT_VERSION as i64).into()),
            ("kind", "kernel-stats".into()),
            ("fingerprint", codec::fingerprint_to_hex(key.fingerprint).into()),
            ("sub_group_size", (key.sub_group_size as i64).into()),
            ("stats", codec::stats_to_json(stats)),
        ]);
        self.write_atomic(&self.stats_path(key), &j.to_string())?;
        let entry = StatsEntry { compacted: false };
        if self.index.read().unwrap().stats(key) != Some(entry) {
            self.record(JournalOp::PutStats(*key, entry));
        }
        Ok(())
    }

    /// Load a persisted calibration; `None` unless the format version
    /// and the full embedded key (case, device, form and model
    /// fingerprint) all match.  Index vouching and miss-adoption work
    /// as in [`ArtifactStore::load_stats`].
    pub fn load_fit(&self, key: &FitKey) -> Option<FitResult> {
        let vouched = self.index.read().unwrap().has_fit(key);
        if vouched {
            self.count_hit();
        }
        let loaded = Self::contained(|| self.read_fit_artifact(key, vouched));
        if vouched && loaded.is_none() {
            self.record(JournalOp::DelFit(key.clone()));
        }
        if !vouched && loaded.is_some() {
            self.record(JournalOp::PutFit(key.clone()));
        }
        loaded
    }

    fn read_fit_artifact(&self, key: &FitKey, vouched: bool) -> Option<FitResult> {
        let text = std::fs::read_to_string(self.fit_path(key)).ok()?;
        if !vouched {
            self.count_parse();
        }
        let j = Self::parse_versioned(&text, "fit")?;
        if j.get("case")?.as_str()? != key.case
            || j.get("device")?.as_str()? != key.device
            || j.get("nonlinear")?.as_bool()? != key.nonlinear
            || j.get("target")?.as_str()? != key.target.name()
        {
            return None;
        }
        if j.get("model_fingerprint")?.as_str()?
            != codec::fingerprint_to_hex(key.model_fingerprint)
        {
            return None;
        }
        codec::fit_from_json(j.get("fit")?).ok()
    }

    pub fn save_fit(&self, key: &FitKey, fit: &FitResult) -> Result<(), String> {
        let j = Json::obj(vec![
            ("format_version", (STORE_FORMAT_VERSION as i64).into()),
            ("kind", "fit".into()),
            ("case", key.case.as_str().into()),
            ("device", key.device.as_str().into()),
            ("nonlinear", key.nonlinear.into()),
            ("target", key.target.name().into()),
            (
                "model_fingerprint",
                codec::fingerprint_to_hex(key.model_fingerprint).into(),
            ),
            ("fit", codec::fit_to_json(fit)),
        ]);
        self.write_atomic(&self.fit_path(key), &j.to_string())?;
        if !self.index.read().unwrap().has_fit(key) {
            self.record(JournalOp::PutFit(key.clone()));
        }
        Ok(())
    }

    /// Read-compat for pre-bump stores: attempt to load a **v3** fit
    /// artifact as `key` (which must be a `target=time` key — every v3
    /// fit was a time fit, there is nothing a v3 artifact could say
    /// about other targets).  `key.model_fingerprint` must already be
    /// the *v3* fingerprint (see `session::legacy_v3_fit_key_parts`:
    /// the fingerprint hashes the format version, so the v4 key never
    /// matches a v3 artifact).  The artifact is fully validated against
    /// its embedded key exactly like a current one — only the version
    /// check differs — and the decoded fit reads as a converged time
    /// fit (the codec's v3 defaults).  The load is a counted parse and
    /// never touches the index: v3 paths are invisible to it, and the
    /// caller is expected to re-save the fit under its v4 key
    /// ([`ArtifactStore::save_fit`]), after which the legacy artifact
    /// is dead weight for `store gc`.
    pub fn load_legacy_v3_fit(&self, key: &FitKey) -> Option<FitResult> {
        if key.target != Target::Time {
            return None;
        }
        let path = self
            .root
            .join("fits")
            .join(legacy_v3_fit_file_name(key));
        let text = std::fs::read_to_string(path).ok()?;
        self.count_parse();
        Self::contained(|| {
            let j = Json::parse(&text).ok()?;
            if j.get("format_version")?.as_f64()? != 3.0 {
                return None;
            }
            if j.get("kind")?.as_str()? != "fit" {
                return None;
            }
            if j.get("case")?.as_str()? != key.case
                || j.get("device")?.as_str()? != key.device
                || j.get("nonlinear")?.as_bool()? != key.nonlinear
            {
                return None;
            }
            if j.get("model_fingerprint")?.as_str()?
                != codec::fingerprint_to_hex(key.model_fingerprint)
            {
                return None;
            }
            codec::fit_from_json(j.get("fit")?).ok()
        })
    }

    // -----------------------------------------------------------------
    // Inventory, GC and compaction
    // -----------------------------------------------------------------

    /// Inventory of every file under the store root, classified and
    /// validated (`perflex store ls`/`stat`), sorted by path for
    /// deterministic output.  Indexed artifacts are described from the
    /// manifest without touching their bytes; only unindexed `.json`
    /// files pay a (counted) classification parse.  Nested
    /// directories and foreign files are surfaced — never silently
    /// omitted — so `ls`/`stat`/`gc` account for everything; the only
    /// paths skipped are store metadata, not artifacts:
    /// `index.json`/`index.journal`, the writer lock and the
    /// maintenance lease.
    pub fn list(&self) -> Result<Vec<ArtifactInfo>, String> {
        let mut out = Vec::new();
        let entries = std::fs::read_dir(&self.root)
            .map_err(|e| format!("reading {}: {e}", self.root.display()))?;
        for entry in entries {
            let entry =
                entry.map_err(|e| format!("reading {}: {e}", self.root.display()))?;
            let path = entry.path();
            let name = entry.file_name().to_string_lossy().into_owned();
            // Store metadata (the index, the writer lock, the
            // maintenance lease) is not inventory.
            if matches!(
                name.as_str(),
                "stats"
                    | "fits"
                    | "shared"
                    | "index.json"
                    | "index.journal"
                    | "index.lock"
                    | "gc.lease"
            ) {
                continue;
            }
            out.push(self.classify_foreign(&path));
        }
        for sub in ["stats", "fits", "shared"] {
            let dir = self.root.join(sub);
            let entries = std::fs::read_dir(&dir)
                .map_err(|e| format!("reading {}: {e}", dir.display()))?;
            for entry in entries {
                let entry =
                    entry.map_err(|e| format!("reading {}: {e}", dir.display()))?;
                let path = entry.path();
                if path.is_dir() {
                    out.push(self.classify_foreign(&path));
                } else {
                    out.push(self.classify(sub, &path));
                }
            }
        }
        out.sort_by(|a, b| a.path.cmp(&b.path));
        Ok(out)
    }

    fn file_meta(path: &Path) -> (u64, Option<u64>) {
        match std::fs::metadata(path) {
            Ok(m) => (
                m.len(),
                // A future mtime (clock skew between fleet writers)
                // counts as age 0, not "unknown": a skewed temp file
                // must still age toward the GC TTL instead of living
                // forever.
                m.modified().ok().map(|t| {
                    SystemTime::now()
                        .duration_since(t)
                        .map(|d| d.as_secs())
                        .unwrap_or(0)
                }),
            ),
            Err(_) => (0, None),
        }
    }

    /// Classify something the store does not own: nested directories,
    /// root-level files, and temp debris outside the artifact naming
    /// schemes.  Foreign entries are surfaced but never removed.
    fn classify_foreign(&self, path: &Path) -> ArtifactInfo {
        let (bytes, age_secs) = Self::file_meta(path);
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        let (kind, describe, valid) = if name.contains(".tmp.") {
            (
                ArtifactKind::Temp,
                "temp file from an interrupted write".to_string(),
                false,
            )
        } else if path.is_dir() {
            (
                ArtifactKind::Other,
                "nested directory (left alone)".to_string(),
                true,
            )
        } else {
            (
                ArtifactKind::Other,
                "foreign file (left alone)".to_string(),
                true,
            )
        };
        ArtifactInfo {
            path: path.to_path_buf(),
            kind,
            bytes,
            age_secs,
            describe,
            model_fingerprint: None,
            shared_fingerprint: None,
            valid,
        }
    }

    fn classify(&self, sub: &str, path: &Path) -> ArtifactInfo {
        let (bytes, age_secs) = Self::file_meta(path);
        let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("");
        let (kind, describe, model_fingerprint, shared_fingerprint, valid) =
            if name.contains(".tmp.") {
                (
                    ArtifactKind::Temp,
                    "temp file from an interrupted write".to_string(),
                    None,
                    None,
                    false,
                )
            } else if !name.ends_with(".json") {
                (
                    ArtifactKind::Other,
                    "foreign file (left alone)".to_string(),
                    None,
                    None,
                    true,
                )
            } else if sub == "stats" {
                let (describe, shared_fp, valid) = self.classify_stats(name);
                (ArtifactKind::Stats, describe, None, shared_fp, valid)
            } else if sub == "fits" {
                let (describe, fp, valid) = self.classify_fit(path, name);
                (ArtifactKind::Fit, describe, fp, None, valid)
            } else {
                let (describe, fp, valid) = self.classify_shared(name);
                (ArtifactKind::Shared, describe, None, fp, valid)
            };
        ArtifactInfo {
            path: path.to_path_buf(),
            kind,
            bytes,
            age_secs,
            describe,
            model_fingerprint,
            shared_fingerprint,
            valid,
        }
    }

    /// `(describe, referenced shared fingerprint, valid)` for one
    /// stats artifact.  The filename *is* the key, so an indexed entry
    /// answers without touching the file.
    fn classify_stats(&self, name: &str) -> (String, Option<u128>, bool) {
        let key = match stats_key_from_name(name)
            .filter(|k| stats_file_name(k) == name)
        {
            Some(k) => k,
            None => return ("unrecognized stats filename".to_string(), None, false),
        };
        let describe = format!(
            "stats kernel={} sg={}",
            codec::fingerprint_to_hex(key.fingerprint),
            key.sub_group_size
        );
        let indexed = self.index.read().unwrap().stats(&key);
        if let Some(entry) = indexed {
            self.count_hit();
            return (
                describe,
                entry.compacted.then_some(key.fingerprint),
                true,
            );
        }
        // Unindexed: one counted parse decides validity (and, on
        // success inside load_stats' probe path, adopts the entry).
        match Self::contained(|| self.read_stats_artifact(&key, false)) {
            Some((_, compacted)) => {
                self.record(JournalOp::PutStats(key, StatsEntry { compacted }));
                (describe, compacted.then_some(key.fingerprint), true)
            }
            None => (describe, None, false),
        }
    }

    fn fit_describe(key: &FitKey) -> String {
        let form = if key.nonlinear { "overlap" } else { "linear" };
        // Time fits keep the pre-v4 description (byte-identical `store
        // ls` output for time-only stores); other targets are named.
        let target = match key.target {
            Target::Time => String::new(),
            t => format!(" target={}", t.name()),
        };
        format!(
            "fit {}/{} {form}{target} model={}",
            key.case,
            key.device,
            codec::fingerprint_to_hex(key.model_fingerprint)
        )
    }

    /// Parse one fit artifact file into its embedded key plus payload
    /// validity — a counted full-artifact parse, no index side
    /// effects.  Shared by classification, the rebuild scan, and GC's
    /// under-lock victim revalidation.
    fn parse_fit_file(&self, path: &Path) -> Option<(FitKey, bool)> {
        let text = std::fs::read_to_string(path).ok()?;
        self.count_parse();
        let j = Self::parse_versioned(&text, "fit")?;
        let key = FitKey {
            case: j.get("case")?.as_str()?.to_string(),
            device: j.get("device")?.as_str()?.to_string(),
            nonlinear: j.get("nonlinear")?.as_bool()?,
            target: Target::parse(j.get("target")?.as_str()?).ok()?,
            model_fingerprint: codec::fingerprint_from_hex(
                j.get("model_fingerprint")?.as_str()?,
            )
            .ok()?,
        };
        let payload_ok = codec::fit_from_json(j.get("fit")?).is_ok();
        Some((key, payload_ok))
    }

    /// `(describe, model fingerprint, valid)` for one fit artifact.
    fn classify_fit(&self, path: &Path, name: &str) -> (String, Option<u128>, bool) {
        let indexed = self.index.read().unwrap().fit_for_file(name).cloned();
        if let Some(key) = indexed {
            self.count_hit();
            return (
                Self::fit_describe(&key),
                Some(key.model_fingerprint),
                true,
            );
        }
        let parsed = Self::contained(|| self.parse_fit_file(path));
        match parsed {
            Some((key, payload_ok)) => {
                // A valid artifact also lives where its embedded key
                // says it should: anything else (e.g. a file written
                // under the v2 path scheme) can never be loaded and is
                // GC fodder.
                let placed = fit_file_name(&key) == name;
                let valid = payload_ok && placed;
                if valid {
                    self.record(JournalOp::PutFit(key.clone()));
                }
                (Self::fit_describe(&key), Some(key.model_fingerprint), valid)
            }
            None => (
                "unreadable, stale-version or foreign fit artifact".to_string(),
                None,
                false,
            ),
        }
    }

    /// `(describe, fingerprint, valid)` for one shared stats section.
    fn classify_shared(&self, name: &str) -> (String, Option<u128>, bool) {
        let fp = match shared_fp_from_name(name).filter(|fp| shared_file_name(*fp) == name)
        {
            Some(fp) => fp,
            None => {
                return (
                    "unrecognized shared-section filename".to_string(),
                    None,
                    false,
                )
            }
        };
        let describe = format!(
            "shared stats section kernel={}",
            codec::fingerprint_to_hex(fp)
        );
        if self.index.read().unwrap().has_shared(fp) {
            self.count_hit();
            return (describe, Some(fp), true);
        }
        // read_shared_artifact adopts on success / counts the parse.
        let ok = Self::contained(|| self.read_shared_artifact(fp)).is_some();
        (describe, Some(fp), ok)
    }

    /// Before sweeping an apparently-orphaned shared section, verify
    /// against the *artifacts on disk* that no twin of its family
    /// references it: the in-memory `compacted` flags can lag another
    /// process's `store compact`, and removing a section that live
    /// twins reference would turn them all into permanent cold starts.
    /// Only runs for candidate orphans (each family member read is a
    /// counted full-artifact parse), and heals any lagging flag it
    /// finds.
    fn shared_referenced_on_disk(&self, fp: u128) -> bool {
        let family: Vec<(StatsKey, StatsEntry)> = {
            let index = self.index.read().unwrap();
            index
                .stats_entries()
                .filter(|(k, _)| k.fingerprint == fp)
                .map(|(k, e)| (*k, *e))
                .collect()
        };
        let mut referenced = false;
        for (key, entry) in family {
            if let Some((_, compacted)) =
                Self::contained(|| self.read_stats_artifact(&key, false))
            {
                let fresh = StatsEntry { compacted };
                if fresh != entry {
                    self.record(JournalOp::PutStats(key, fresh));
                }
                referenced |= compacted;
            }
        }
        referenced
    }

    /// Drop the index entry (if any) behind a file GC just removed —
    /// under the same writer-lock hold as the unlink itself.
    fn forget_file(&self, kind: ArtifactKind, path: &Path, guard: &FileLock) {
        let name = match path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => return,
        };
        match kind {
            ArtifactKind::Stats => {
                if let Some(key) = stats_key_from_name(name) {
                    if self.index.read().unwrap().stats(&key).is_some() {
                        self.record_under(JournalOp::DelStats(key), guard);
                    }
                }
            }
            ArtifactKind::Fit => {
                let indexed = self.index.read().unwrap().fit_for_file(name).cloned();
                if let Some(key) = indexed {
                    self.record_under(JournalOp::DelFit(key), guard);
                }
            }
            ArtifactKind::Shared => {
                if let Some(fp) = shared_fp_from_name(name) {
                    if self.index.read().unwrap().has_shared(fp) {
                        self.record_under(JournalOp::DelShared(fp), guard);
                    }
                }
            }
            ArtifactKind::Temp | ArtifactKind::Other => {}
        }
    }

    /// Under the writer lock, immediately before an unlink: does a
    /// victim classified stale/corrupt now parse as a *valid,
    /// correctly placed* artifact?  A concurrent `save_*` may have
    /// republished it between the GC scan and this moment; deleting it
    /// anyway would hand that writer's next load a vouched-but-missing
    /// artifact (the cross-process form of the silent-eviction bug).
    /// Counted parses, no index side effects — the sparing caller
    /// leaves the republisher's own journaled put standing.
    fn revalidates_under_lock(&self, info: &ArtifactInfo) -> bool {
        let name = match info.path.file_name().and_then(|n| n.to_str()) {
            Some(n) => n,
            None => return false,
        };
        match info.kind {
            ArtifactKind::Stats => stats_key_from_name(name)
                .filter(|k| stats_file_name(k) == name)
                .and_then(|k| Self::contained(|| self.read_stats_scan(&k)))
                .is_some(),
            ArtifactKind::Fit => Self::contained(|| self.parse_fit_file(&info.path))
                .is_some_and(|(key, payload_ok)| {
                    payload_ok && fit_file_name(&key) == name
                }),
            ArtifactKind::Shared => shared_fp_from_name(name)
                .filter(|fp| shared_file_name(*fp) == name)
                .is_some_and(|fp| {
                    Self::contained(|| self.read_shared_scan(fp)).is_some()
                }),
            ArtifactKind::Temp | ArtifactKind::Other => false,
        }
    }

    /// Age out everything the store can prove dead: artifacts that are
    /// corrupt, carry a stale [`STORE_FORMAT_VERSION`], sit at a path
    /// their embedded key no longer maps to, (for fits, when a
    /// reachability set is given) belong to a model fingerprint the
    /// current binary can no longer produce, or (for shared sections)
    /// are referenced by no valid stats artifact — plus temp files
    /// older than `temp_ttl_secs`.  Foreign files and nested
    /// directories are never touched.
    ///
    /// Corruption detection trusts the index: an *unindexed* corrupt
    /// file is caught (and swept) by its classification parse, while a
    /// file corrupted *behind* a valid index entry stays invisible to
    /// `ls`/`stat`/`gc` until the first warm load fails — which evicts
    /// the entry (cold start, never garbage), after which the next
    /// sweep reclaims the bytes.  A non-dry-run GC ends by
    /// checkpointing the index (journal merge + snapshot + journal
    /// truncation).
    ///
    /// Cross-process fencing: a destructive run holds the maintenance
    /// lease for its whole duration (a live foreign lease is a
    /// refusal — see [`GcOptions::lease_ttl_secs`]), every unlink
    /// happens under the writer lock, and a victim classified
    /// stale/corrupt is re-verified there first so a concurrently
    /// republished artifact is spared.  Dry runs touch nothing and
    /// need neither.
    pub fn gc(&self, opts: &GcOptions) -> Result<GcOutcome, String> {
        let lease = if opts.dry_run {
            None
        } else {
            Some(Lease::acquire(&self.lease_path(), opts.lease_ttl_secs)?)
        };
        let infos = self.list()?;
        // Shared sections are live while any valid stats artifact
        // references them.
        let referenced: HashSet<u128> = infos
            .iter()
            .filter(|i| i.kind == ArtifactKind::Stats && i.valid)
            .filter_map(|i| i.shared_fingerprint)
            .collect();
        let mut out = GcOutcome::default();
        let mut victims: Vec<(ArtifactInfo, String)> = Vec::new();
        for info in infos {
            out.scanned += 1;
            let reason = match info.kind {
                ArtifactKind::Temp => {
                    if info.age_secs.is_some_and(|a| a >= opts.temp_ttl_secs) {
                        Some("orphaned temp file".to_string())
                    } else {
                        None
                    }
                }
                ArtifactKind::Other => None,
                ArtifactKind::Stats | ArtifactKind::Fit | ArtifactKind::Shared
                    if !info.valid =>
                {
                    Some("stale, corrupt or misplaced artifact".to_string())
                }
                ArtifactKind::Fit => match (opts.reachable_fits, info.model_fingerprint)
                {
                    (Some(reach), Some(fp)) if !reach.contains(&fp) => Some(
                        "model fingerprint unreachable from this binary".to_string(),
                    ),
                    _ => None,
                },
                ArtifactKind::Shared => match info.shared_fingerprint {
                    Some(fp)
                        if !referenced.contains(&fp)
                            && !self.shared_referenced_on_disk(fp) =>
                    {
                        Some("shared stats section no longer referenced".to_string())
                    }
                    _ => None,
                },
                ArtifactKind::Stats => None,
            };
            if let Some(reason) = reason {
                victims.push((info, reason));
            }
        }
        if opts.dry_run {
            for (info, reason) in victims {
                out.reclaimed_bytes += info.bytes;
                out.removed.push((info.path, reason));
            }
            return Ok(out);
        }
        // Reclaim in small batches: one writer-lock hold per batch
        // (instead of per victim) bounds lockfile churn, while batch
        // boundaries both let concurrent writers in and refresh the
        // lease — a sweep that outlived its own lease would be stolen
        // mid-run, re-admitting the double-delete this fences out.
        let lease = lease.expect("destructive gc holds the maintenance lease");
        for batch in victims.chunks(16) {
            lease.refresh(opts.lease_ttl_secs);
            let guard = self.writer_lock()?;
            for (info, reason) in batch {
                if !info.valid && self.revalidates_under_lock(info) {
                    // Republished by a concurrent writer since the
                    // scan: spare it.
                    continue;
                }
                match std::fs::remove_file(&info.path) {
                    Ok(()) => {}
                    // Already gone (the temp's owner finished its
                    // rename): nothing to account.
                    Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                        continue;
                    }
                    Err(e) => {
                        return Err(format!("removing {}: {e}", info.path.display()))
                    }
                }
                self.forget_file(info.kind, &info.path, &guard);
                out.reclaimed_bytes += info.bytes;
                out.removed.push((info.path.clone(), reason.clone()));
            }
        }
        self.checkpoint_index();
        Ok(out)
    }

    /// Deduplicate the sub-group-size-invariant section of stats
    /// bundles shared between sg families of one kernel fingerprint
    /// (`perflex store compact`): families with two or more sub-group
    /// twins get one `<root>/shared/<fingerprint>.json` section, and
    /// each twin is rewritten to carry only its per-sg op counts plus
    /// a reference.  Reassembled bundles are structurally identical to
    /// the originals — warm reports stay byte-identical — and a
    /// family whose twins' invariant sections do not encode
    /// byte-identically (a hand-edited artifact) is skipped, never
    /// grafted.  Ends by checkpointing the index.
    ///
    /// Rewriting artifacts in place is destructive maintenance, so the
    /// whole run holds the maintenance lease (`lease_ttl_secs`; a live
    /// foreign lease is a refusal) — which also excludes a concurrent
    /// `gc` from sweeping a shared section mid-graft.
    pub fn compact(&self, lease_ttl_secs: u64) -> Result<CompactOutcome, String> {
        let lease = Lease::acquire(&self.lease_path(), lease_ttl_secs)?;
        let mut groups: HashMap<u128, Vec<(StatsKey, StatsEntry)>> = HashMap::new();
        {
            let index = self.index.read().unwrap();
            for (key, entry) in index.stats_entries() {
                groups.entry(key.fingerprint).or_default().push((*key, *entry));
            }
        }
        let mut fps: Vec<u128> = groups
            .iter()
            .filter(|(_, members)| members.len() >= 2)
            .map(|(fp, _)| *fp)
            .collect();
        fps.sort_unstable();

        let file_len =
            |p: &Path| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0);
        let mut out = CompactOutcome::default();
        for fp in fps {
            // One refresh per family keeps a long compaction from
            // outliving (and thereby losing) its own lease.
            lease.refresh(lease_ttl_secs);
            let mut members = groups.remove(&fp).unwrap();
            members.sort_by_key(|(k, _)| k.sub_group_size);
            out.families += 1;
            let blob_present = self.index.read().unwrap().has_shared(fp);
            if blob_present && members.iter().all(|(_, e)| e.compacted) {
                continue; // nothing left to dedup in this family
            }
            let mut loaded = Vec::new();
            for (key, entry) in &members {
                match self.load_stats(key) {
                    Some(st) => loaded.push((*key, *entry, st)),
                    None => break, // vanished or corrupt: skip family
                }
            }
            if loaded.len() != members.len() {
                out.skipped += 1;
                continue;
            }
            let shared_texts: Vec<String> = loaded
                .iter()
                .map(|(_, _, st)| codec::stats_shared_to_json(st).to_string())
                .collect();
            if shared_texts.windows(2).any(|w| w[0] != w[1]) {
                out.skipped += 1;
                continue;
            }
            let bytes_before: u64 = members
                .iter()
                .map(|(k, _)| file_len(&self.stats_path(k)))
                .sum::<u64>()
                + file_len(&self.shared_path(fp));

            // Publish the shared section *before* rewriting any twin:
            // a compacted artifact must never reference a missing
            // section, even across a crash mid-compaction.
            let shared_j = Json::obj(vec![
                ("format_version", (STORE_FORMAT_VERSION as i64).into()),
                ("kind", "kernel-stats-shared".into()),
                ("fingerprint", codec::fingerprint_to_hex(fp).into()),
                ("shared", Json::parse(&shared_texts[0]).expect("just encoded")),
            ]);
            self.write_atomic(&self.shared_path(fp), &shared_j.to_string())?;
            if !self.index.read().unwrap().has_shared(fp) {
                self.record(JournalOp::PutShared(fp));
            }
            out.shared_sections += 1;
            for (key, entry, st) in &loaded {
                if entry.compacted {
                    continue; // already referencing the section
                }
                let j = Json::obj(vec![
                    ("format_version", (STORE_FORMAT_VERSION as i64).into()),
                    ("kind", "kernel-stats".into()),
                    ("fingerprint", codec::fingerprint_to_hex(fp).into()),
                    ("sub_group_size", (key.sub_group_size as i64).into()),
                    ("shared", codec::fingerprint_to_hex(fp).into()),
                    ("ops", codec::ops_to_json(&st.ops)),
                ]);
                self.write_atomic(&self.stats_path(key), &j.to_string())?;
                self.record(JournalOp::PutStats(*key, StatsEntry { compacted: true }));
                out.rewritten += 1;
            }
            let bytes_after: u64 = members
                .iter()
                .map(|(k, _)| file_len(&self.stats_path(k)))
                .sum::<u64>()
                + file_len(&self.shared_path(fp));
            out.reclaimed_bytes += bytes_before.saturating_sub(bytes_after);
        }
        self.checkpoint_index();
        Ok(out)
    }

    /// Compare the live index (snapshot + journal, as loaded and
    /// maintained by this process) against a full rebuild scan of the
    /// artifacts on disk (`perflex store verify`).  Agreement is the
    /// store's cross-process acceptance bar: concurrent writers may
    /// cost each other extra parses, never index entries.  The live
    /// index is untouched; every scanned artifact is a counted
    /// full-artifact parse.
    pub fn verify_index(&self) -> Result<IndexVerifyOutcome, String> {
        let (loaded_text, indexed) = {
            let index = self.index.read().unwrap();
            (index.to_snapshot_json(0).to_string(), index.counts())
        };
        let scan = self.scan_index(None)?;
        Ok(IndexVerifyOutcome {
            matches: loaded_text == scan.to_snapshot_json(0).to_string(),
            indexed,
            scanned: scan.counts(),
        })
    }
}

/// Outcome of [`ArtifactStore::verify_index`].
#[derive(Clone, Copy, Debug)]
pub struct IndexVerifyOutcome {
    /// The live index and the rebuild scan agree entry-for-entry.
    pub matches: bool,
    /// `(stats, fits, shared)` counts of the live index.
    pub indexed: (usize, usize, usize),
    /// `(stats, fits, shared)` counts of the rebuild scan.
    pub scanned: (usize, usize, usize),
}

/// Classification of one file found under the store root.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Stats,
    Fit,
    /// A deduplicated sg-invariant stats section (`store compact`).
    Shared,
    /// A `*.tmp.*` file left by an interrupted [`ArtifactStore`] write.
    Temp,
    /// Anything the store did not write — foreign files and nested
    /// directories; surfaced by `ls`, never removed.
    Other,
}

/// One entry of [`ArtifactStore::list`].
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub path: PathBuf,
    pub kind: ArtifactKind,
    pub bytes: u64,
    /// Seconds since last modification; future mtimes (clock skew)
    /// clamp to 0, and `None` only when the filesystem withholds
    /// mtimes entirely.
    pub age_secs: Option<u64>,
    /// Human-readable key description for `store ls`.
    pub describe: String,
    /// Embedded model fingerprint (fit artifacts only).
    pub model_fingerprint: Option<u128>,
    /// For a compacted stats artifact: the shared section it
    /// references.  For a shared section: its own fingerprint.
    pub shared_fingerprint: Option<u128>,
    /// Parses, carries the current format version, and lives at the
    /// path its embedded key maps to.
    pub valid: bool,
}

/// Policy knobs for [`ArtifactStore::gc`].
#[derive(Clone, Copy, Debug)]
pub struct GcOptions<'a> {
    /// Model fingerprints still derivable from this binary (see
    /// [`super::reachable_fit_fingerprints`]); fits outside the set
    /// are aged out.  `None` skips reachability pruning.
    pub reachable_fits: Option<&'a HashSet<u128>>,
    /// Minimum age before a temp file counts as orphaned — a live
    /// writer's temp is younger than this.
    pub temp_ttl_secs: u64,
    /// How long this run's maintenance lease protects it: a concurrent
    /// destructive `gc`/`compact` refuses while the lease is live, and
    /// a crashed holder blocks the fleet for at most this long
    /// (`--lease-ttl-secs` on the CLI).
    pub lease_ttl_secs: u64,
    /// Report what would be removed without deleting anything (needs
    /// no lease).
    pub dry_run: bool,
}

impl Default for GcOptions<'_> {
    fn default() -> Self {
        GcOptions {
            reachable_fits: None,
            // Long enough that any live writer has finished its rename.
            temp_ttl_secs: 15 * 60,
            lease_ttl_secs: DEFAULT_LEASE_TTL_SECS,
            dry_run: false,
        }
    }
}

/// What [`ArtifactStore::gc`] did (or, dry-run, would do).
#[derive(Debug, Default)]
pub struct GcOutcome {
    pub scanned: usize,
    /// `(path, reason)` per removed artifact, in path order.
    pub removed: Vec<(PathBuf, String)>,
    pub reclaimed_bytes: u64,
}

/// What [`ArtifactStore::compact`] did.
#[derive(Debug, Default)]
pub struct CompactOutcome {
    /// Kernel fingerprints with two or more sub-group twins on file.
    pub families: usize,
    /// Shared sections written (or refreshed) this run.
    pub shared_sections: usize,
    /// Per-sub-group artifacts rewritten into compacted form.
    pub rewritten: usize,
    /// Families skipped: a twin vanished mid-compaction or the twins'
    /// invariant sections diverged (hand-edited artifact).
    pub skipped: usize,
    pub reclaimed_bytes: u64,
}

impl StatsBacking for ArtifactStore {
    fn load(&self, key: &StatsKey) -> Option<KernelStats> {
        self.load_stats(key)
    }

    fn store(&self, key: &StatsKey, stats: &KernelStats) {
        // Best-effort: a full disk must not fail the in-memory lookup.
        if let Err(e) = self.save_stats(key, stats) {
            eprintln!("warning: artifact store write failed: {e}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::DType;

    fn tmp_store(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "perflex-store-test-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn open_rejects_unusable_roots() {
        let dir = tmp_store("open");
        // A file where the root should be.
        std::fs::create_dir_all(&dir).unwrap();
        let file = dir.join("not-a-dir");
        std::fs::write(&file, b"x").unwrap();
        assert!(ArtifactStore::open(&file).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn stats_roundtrip_through_disk() {
        let dir = tmp_store("stats");
        let store = ArtifactStore::open(&dir).unwrap();
        let k = crate::uipick::derived::build_axpy(DType::F32).unwrap().freeze();
        let st = crate::stats::gather(&k, 32).unwrap();
        let key = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 32,
        };
        assert!(store.load_stats(&key).is_none(), "cold store must miss");
        store.save_stats(&key, &st).unwrap();
        let back = store.load_stats(&key).expect("saved stats must load");
        let env: std::collections::BTreeMap<String, i128> =
            [("n".to_string(), 1 << 20)].into_iter().collect();
        assert_eq!(
            st.op_count(DType::F32, "madd").eval(&env),
            back.op_count(DType::F32, "madd").eval(&env)
        );
        // A different sub-group size is a different artifact.
        let other = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 64,
        };
        assert!(store.load_stats(&other).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_skew_and_key_mismatch_are_rejected() {
        let dir = tmp_store("skew");
        let store = ArtifactStore::open(&dir).unwrap();
        let fit = FitResult {
            param_names: vec!["p_a".into()],
            params: vec![2.0],
            residual: 0.0,
            iterations: 3,
            target: Target::Time,
            converged: true,
        };
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 0xabcd,
        };
        store.save_fit(&key, &fit).unwrap();
        assert!(store.load_fit(&key).is_some());

        // Model changed: a different fingerprint is a different path
        // (the v3 fix) and a cold start, not a misload.
        let moved = FitKey {
            model_fingerprint: 0xabce,
            ..key.clone()
        };
        assert!(store.load_fit(&moved).is_none());

        // Stale format version on disk -> rejected (refit), not parsed.
        let path = store.fit_path(&key);
        let stale = std::fs::read_to_string(&path).unwrap().replace(
            &format!("\"format_version\":{STORE_FORMAT_VERSION}"),
            "\"format_version\":999",
        );
        assert_ne!(
            stale,
            std::fs::read_to_string(&path).unwrap(),
            "version field must exist to be tampered with"
        );
        std::fs::write(&path, stale).unwrap();
        assert!(store.load_fit(&key).is_none());

        // Truncated JSON -> rejected.
        std::fs::write(&path, "{\"format_version\":4,\"kind\":\"fit\"").unwrap();
        assert!(store.load_fit(&key).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    fn some_fit(p: f64) -> FitResult {
        FitResult {
            param_names: vec!["p_a".into()],
            params: vec![p],
            residual: 0.0,
            iterations: 1,
            target: Target::Time,
            converged: true,
        }
    }

    /// THE v3 regression: two fits differing *only* in model
    /// fingerprint used to map to one path — each save evicted the
    /// other, and the embedded-key guard turned the survivor's sibling
    /// into a permanent cold start.  They must persist side by side
    /// and both load warm.
    #[test]
    fn fingerprint_only_siblings_coexist_and_both_load_warm() {
        let dir = tmp_store("fp-siblings");
        let store = ArtifactStore::open(&dir).unwrap();
        let a = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: false,
            target: Target::Time,
            model_fingerprint: 0x1111_2222_3333_4444_5555_6666_7777_8888,
        };
        let b = FitKey {
            model_fingerprint: 0x9999_aaaa_bbbb_cccc_dddd_eeee_ffff_0000,
            ..a.clone()
        };
        assert_ne!(
            store.fit_path(&a),
            store.fit_path(&b),
            "fingerprint-only siblings must get distinct paths"
        );
        store.save_fit(&a, &some_fit(1.0)).unwrap();
        store.save_fit(&b, &some_fit(2.0)).unwrap();
        assert_eq!(store.load_fit(&a).unwrap().params, vec![1.0]);
        assert_eq!(store.load_fit(&b).unwrap().params, vec![2.0]);

        // And across a "process restart" (fresh index load).
        let warm = ArtifactStore::open(&dir).unwrap();
        assert_eq!(warm.load_fit(&a).unwrap().params, vec![1.0]);
        assert_eq!(warm.load_fit(&b).unwrap().params, vec![2.0]);
        assert_eq!(
            warm.artifact_parses(),
            0,
            "journal-replayed index must vouch for both siblings"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The path-ambiguity regression: raw case/device ids containing
    /// `-` used to collide in `<case>-<device>-<form>.json`, and path
    /// characters could escape the store root.
    #[test]
    fn ambiguous_and_hostile_fit_keys_get_distinct_contained_paths() {
        let dir = tmp_store("paths");
        let store = ArtifactStore::open(&dir).unwrap();
        // "fdiff-16x16" + "dev" vs "fdiff" + "16x16-dev": identical
        // under naive concatenation.
        let a = FitKey {
            case: "fdiff-16x16".into(),
            device: "dev".into(),
            nonlinear: false,
            target: Target::Time,
            model_fingerprint: 1,
        };
        let b = FitKey {
            case: "fdiff".into(),
            device: "16x16-dev".into(),
            nonlinear: false,
            target: Target::Time,
            model_fingerprint: 2,
        };
        assert_ne!(store.fit_path(&a), store.fit_path(&b));
        store.save_fit(&a, &some_fit(1.0)).unwrap();
        store.save_fit(&b, &some_fit(2.0)).unwrap();
        assert_eq!(store.load_fit(&a).unwrap().params, vec![1.0]);
        assert_eq!(store.load_fit(&b).unwrap().params, vec![2.0]);

        // Hostile components stay inside <root>/fits.
        let evil = FitKey {
            case: "../../escape".into(),
            device: "a/b\\c".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 3,
        };
        let p = store.fit_path(&evil);
        assert!(p.starts_with(dir.join("fits")), "{}", p.display());
        store.save_fit(&evil, &some_fit(3.0)).unwrap();
        assert_eq!(store.load_fit(&evil).unwrap().params, vec![3.0]);
        assert!(
            std::fs::read_dir(dir.join("fits")).unwrap().count() >= 3,
            "every artifact must land in the fits directory"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The temp-file-clobber regression: many threads publishing the
    /// same artifact path concurrently must all succeed (per-writer
    /// temp names) and leave no temp debris behind.
    #[test]
    fn concurrent_same_key_writers_never_clobber() {
        let dir = tmp_store("contend");
        let store = ArtifactStore::open(&dir).unwrap();
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 7,
        };
        std::thread::scope(|s| {
            for t in 0..8 {
                let (store, key) = (&store, &key);
                s.spawn(move || {
                    for i in 0..20 {
                        store
                            .save_fit(key, &some_fit((t * 100 + i) as f64))
                            .expect("concurrent save must not clobber");
                    }
                });
            }
        });
        assert!(store.load_fit(&key).is_some(), "a torn artifact leaked");
        let leftovers: Vec<_> = std::fs::read_dir(dir.join("fits"))
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn gc_sweeps_dead_artifacts_and_spares_live_ones() {
        let dir = tmp_store("gc");
        let store = ArtifactStore::open(&dir).unwrap();

        // Live artifacts: one stats bundle, one reachable fit.
        let k = crate::uipick::derived::build_axpy(DType::F32)
            .unwrap()
            .freeze();
        let skey = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 32,
        };
        store
            .save_stats(&skey, &crate::stats::gather(&k, 32).unwrap())
            .unwrap();
        let live = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 0xa11ce,
        };
        store.save_fit(&live, &some_fit(1.0)).unwrap();

        // Dead: unreachable-model fit, stale-version file, corrupt
        // file, orphan temp, and a foreign file that must survive.
        let dead = FitKey {
            case: "matmul".into(),
            device: "retired_gpu".into(),
            nonlinear: false,
            target: Target::Time,
            model_fingerprint: 0xdead,
        };
        store.save_fit(&dead, &some_fit(2.0)).unwrap();
        let stale = dir.join("fits").join("old-fit-linear-0000000000000000.json");
        std::fs::write(
            &stale,
            "{\"format_version\":1,\"kind\":\"fit\",\"case\":\"x\"}",
        )
        .unwrap();
        let corrupt = dir.join("stats").join("nonsense.json");
        std::fs::write(&corrupt, "{not json").unwrap();
        let orphan = dir.join("stats").join("whatever.tmp.999.0");
        std::fs::write(&orphan, "partial").unwrap();
        let foreign = dir.join("fits").join("NOTES.txt");
        std::fs::write(&foreign, "hands off").unwrap();

        let reachable: HashSet<u128> = [0xa11ce_u128].into_iter().collect();
        // Dry run first: reports, removes nothing.
        let dry = store
            .gc(&GcOptions {
                reachable_fits: Some(&reachable),
                temp_ttl_secs: 0,
                dry_run: true,
                ..GcOptions::default()
            })
            .unwrap();
        assert_eq!(dry.removed.len(), 4, "{:?}", dry.removed);
        assert!(stale.exists() && corrupt.exists() && orphan.exists());

        let gc = store
            .gc(&GcOptions {
                reachable_fits: Some(&reachable),
                temp_ttl_secs: 0,
                dry_run: false,
                ..GcOptions::default()
            })
            .unwrap();
        assert_eq!(gc.removed.len(), 4, "{:?}", gc.removed);
        assert!(gc.reclaimed_bytes > 0);
        assert!(!stale.exists() && !corrupt.exists() && !orphan.exists());
        assert!(store.load_fit(&dead).is_none(), "unreachable fit aged out");
        assert!(foreign.exists(), "foreign files are never touched");
        assert!(store.load_fit(&live).is_some(), "live fit survives");
        assert!(store.load_stats(&skey).is_some(), "live stats survive");

        // A fresh temp file survives a TTL-respecting sweep.
        std::fs::write(dir.join("fits").join("busy.tmp.1.2"), "x").unwrap();
        let gentle = store.gc(&GcOptions::default()).unwrap();
        assert!(gentle.removed.is_empty(), "{:?}", gentle.removed);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Clock-skew regression: a temp file whose mtime is in the
    /// *future* used to get `age_secs = None` and survive every sweep;
    /// it must count as age 0 and age out normally.
    #[test]
    fn future_mtime_temp_files_age_from_zero_not_forever() {
        let dir = tmp_store("skewed-mtime");
        let store = ArtifactStore::open(&dir).unwrap();
        let orphan = dir.join("stats").join("skewed.tmp.1.0");
        std::fs::write(&orphan, "partial").unwrap();
        let f = std::fs::File::options().write(true).open(&orphan).unwrap();
        f.set_modified(SystemTime::now() + std::time::Duration::from_secs(3600))
            .unwrap();
        drop(f);

        let info = store
            .list()
            .unwrap()
            .into_iter()
            .find(|i| i.path == orphan)
            .expect("skewed temp file must be surfaced");
        assert_eq!(info.kind, ArtifactKind::Temp);
        assert_eq!(info.age_secs, Some(0), "future mtime must clamp to age 0");

        // A TTL-respecting sweep spares it (age 0 < ttl)...
        let gentle = store.gc(&GcOptions::default()).unwrap();
        assert!(gentle.removed.is_empty(), "{:?}", gentle.removed);
        // ... and a zero-TTL sweep reclaims it instead of skipping it.
        let gc = store
            .gc(&GcOptions {
                reachable_fits: None,
                temp_ttl_secs: 0,
                dry_run: false,
                ..GcOptions::default()
            })
            .unwrap();
        assert_eq!(gc.removed.len(), 1, "{:?}", gc.removed);
        assert!(!orphan.exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Nested directories under the artifact dirs used to be invisible
    /// to ls/stat/gc (`is_file` guard); they must be surfaced as
    /// foreign entries and never removed.
    #[test]
    fn nested_directories_are_surfaced_and_never_removed() {
        let dir = tmp_store("nested");
        let store = ArtifactStore::open(&dir).unwrap();
        let nested = dir.join("stats").join("backup");
        std::fs::create_dir_all(&nested).unwrap();
        std::fs::write(nested.join("old.json"), "{}").unwrap();

        let infos = store.list().unwrap();
        let info = infos
            .iter()
            .find(|i| i.path == nested)
            .expect("nested directory must be surfaced, not skipped");
        assert_eq!(info.kind, ArtifactKind::Other);
        assert!(info.valid);
        assert!(info.describe.contains("nested directory"));

        let gc = store
            .gc(&GcOptions {
                reachable_fits: None,
                temp_ttl_secs: 0,
                dry_run: false,
                ..GcOptions::default()
            })
            .unwrap();
        assert!(gc.removed.is_empty(), "{:?}", gc.removed);
        assert!(nested.exists() && nested.join("old.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `compact` dedups the sg-invariant section between sub-group
    /// twins; both twins must reload exactly (byte-identical
    /// re-encoding), the orphaned section must be GC'd once its
    /// referents are gone, and a second compaction must be a no-op.
    #[test]
    fn compact_dedups_sub_group_twins_and_reloads_exactly() {
        let dir = tmp_store("compact");
        let store = ArtifactStore::open(&dir).unwrap();
        let k = crate::uipick::derived::build_axpy(DType::F32).unwrap().freeze();
        let keys: Vec<StatsKey> = [32u64, 64]
            .iter()
            .map(|&sg| StatsKey {
                fingerprint: k.fingerprint(),
                sub_group_size: sg,
            })
            .collect();
        let mut originals = Vec::new();
        for key in &keys {
            let st = crate::stats::gather(&k, key.sub_group_size).unwrap();
            store.save_stats(key, &st).unwrap();
            originals.push(codec::stats_to_json(&st).to_string());
        }

        let outcome = store.compact(DEFAULT_LEASE_TTL_SECS).unwrap();
        assert_eq!(outcome.families, 1);
        assert_eq!(outcome.shared_sections, 1);
        assert_eq!(outcome.rewritten, 2);
        assert_eq!(outcome.skipped, 0);
        assert!(
            store.root().join("shared").join(shared_file_name(k.fingerprint())).exists(),
            "shared section must be on disk"
        );

        for (key, original) in keys.iter().zip(&originals) {
            let back = store.load_stats(key).expect("compacted twin must load");
            assert_eq!(
                codec::stats_to_json(&back).to_string(),
                *original,
                "reassembled bundle must be byte-identical (sg={})",
                key.sub_group_size
            );
        }
        // GC right after compaction: everything is referenced, nothing
        // is removed.
        let gc = store.gc(&GcOptions::default()).unwrap();
        assert!(gc.removed.is_empty(), "{:?}", gc.removed);

        // A second compaction finds nothing left to rewrite.
        let again = store.compact(DEFAULT_LEASE_TTL_SECS).unwrap();
        assert_eq!((again.shared_sections, again.rewritten), (0, 0));

        // Remove both twins: the shared section is orphaned and GC'd.
        for key in &keys {
            std::fs::remove_file(store.stats_path(key)).unwrap();
            assert!(store.load_stats(key).is_none());
        }
        let gc = store
            .gc(&GcOptions {
                reachable_fits: None,
                temp_ttl_secs: 0,
                dry_run: false,
                ..GcOptions::default()
            })
            .unwrap();
        assert_eq!(gc.removed.len(), 1, "{:?}", gc.removed);
        assert!(
            !store.root().join("shared").join(shared_file_name(k.fingerprint())).exists(),
            "orphaned shared section must be reclaimed"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// A fresh open replays the journal: every artifact the first
    /// "process" saved is vouched for without a single full-artifact
    /// parse, and `ls` stays parse-free too.
    #[test]
    fn journal_replay_makes_reopened_stores_parse_free() {
        let dir = tmp_store("replay");
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 0x77,
        };
        {
            let store = ArtifactStore::open(&dir).unwrap();
            store.save_fit(&key, &some_fit(4.0)).unwrap();
            let k =
                crate::uipick::derived::build_axpy(DType::F32).unwrap().freeze();
            let skey = StatsKey {
                fingerprint: k.fingerprint(),
                sub_group_size: 32,
            };
            store
                .save_stats(&skey, &crate::stats::gather(&k, 32).unwrap())
                .unwrap();
        }
        let warm = ArtifactStore::open(&dir).unwrap();
        assert_eq!(
            warm.index_counts(),
            (1, 1, 0),
            "journal replay must reconstruct the manifest"
        );
        assert!(warm.load_fit(&key).is_some());
        let infos = warm.list().unwrap();
        assert!(infos.iter().all(|i| i.valid), "{infos:?}");
        assert_eq!(
            warm.artifact_parses(),
            0,
            "a fresh index must answer ls + warm loads without parses"
        );
        assert!(warm.index_hits() > 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Corrupt index metadata (snapshot or journal) must trigger a
    /// full rebuild scan that restores the manifest — never an error,
    /// never a cold store.
    #[test]
    fn corrupt_index_rebuilds_from_scan() {
        let dir = tmp_store("rebuild");
        let key = FitKey {
            case: "dg".into(),
            device: "amd_r9_fury".into(),
            nonlinear: false,
            target: Target::Time,
            model_fingerprint: 0x55,
        };
        {
            let store = ArtifactStore::open(&dir).unwrap();
            store.save_fit(&key, &some_fit(9.0)).unwrap();
        }
        // Torn final journal line: ignored, no rebuild needed.
        {
            use std::io::Write;
            let mut f = std::fs::OpenOptions::new()
                .append(true)
                .open(dir.join("index.journal"))
                .unwrap();
            write!(f, "{{\"op\":\"put-f").unwrap();
        }
        {
            let store = ArtifactStore::open(&dir).unwrap();
            assert!(store.load_fit(&key).is_some());
            assert_eq!(store.artifact_parses(), 0, "torn tail must not force a rebuild");
        }
        // Corrupt snapshot: rebuild scan re-validates every artifact.
        std::fs::write(dir.join("index.json"), "{definitely not json").unwrap();
        std::fs::write(dir.join("index.journal"), "garbage\nmore garbage\n").unwrap();
        let store = ArtifactStore::open(&dir).unwrap();
        assert!(
            store.artifact_parses() > 0,
            "rebuild must re-scan the artifacts"
        );
        assert_eq!(store.index_counts().1, 1, "the fit must be re-indexed");
        assert!(store.load_fit(&key).is_some());
        // The rebuild checkpointed a fresh snapshot: the next open is
        // parse-free again.
        let warm = ArtifactStore::open(&dir).unwrap();
        assert!(warm.load_fit(&key).is_some());
        assert_eq!(warm.artifact_parses(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// THE cross-process tentpole: N "processes" (threads, each with
    /// its own `ArtifactStore::open` over one root) interleave saves,
    /// vouched loads, open-time checkpoints and destructive GC.
    /// Afterwards the journaled index must agree entry-for-entry with
    /// a full rebuild scan, and no vouched load may ever have observed
    /// a missing artifact.
    #[test]
    fn concurrent_stores_lose_no_index_entries_or_vouched_loads() {
        let dir = tmp_store("multiproc");
        drop(ArtifactStore::open(&dir).unwrap());
        let (n_threads, iters) = (4usize, 8usize);
        std::thread::scope(|s| {
            for t in 0..n_threads {
                let dir = dir.clone();
                s.spawn(move || {
                    for i in 0..iters {
                        // A fresh open per round exercises snapshot
                        // load + journal replay against live writers.
                        let store = ArtifactStore::open(&dir).unwrap();
                        let key = FitKey {
                            case: format!("case{t}"),
                            device: format!("dev{i}"),
                            nonlinear: (i + t) % 2 == 0,
                            target: Target::Time,
                            model_fingerprint: (t * 1000 + i) as u128,
                        };
                        store.save_fit(&key, &some_fit(i as f64)).unwrap();
                        assert!(
                            store.load_fit(&key).is_some(),
                            "a vouched load observed a missing artifact \
                             (t={t}, i={i})"
                        );
                        if i % 3 == 0 {
                            // Destructive maintenance racing writers: a
                            // live foreign lease refuses (fine); an
                            // acquired one must never delete anything
                            // live.
                            match store.gc(&GcOptions {
                                temp_ttl_secs: 3600,
                                lease_ttl_secs: 30,
                                ..GcOptions::default()
                            }) {
                                Ok(out) => assert!(
                                    out.removed.is_empty(),
                                    "gc deleted live artifacts: {:?}",
                                    out.removed
                                ),
                                Err(e) => assert!(
                                    e.contains("lease") || e.contains("lock"),
                                    "unexpected gc failure: {e}"
                                ),
                            }
                        }
                    }
                });
            }
        });
        let store = ArtifactStore::open(&dir).unwrap();
        let outcome = store.verify_index().unwrap();
        assert!(
            outcome.matches,
            "index {:?} must equal the rebuild scan {:?}",
            outcome.indexed, outcome.scanned
        );
        assert_eq!(
            store.index_counts().1,
            n_threads * iters,
            "no concurrent writer's put may be lost"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The epoch fence: interleaved checkpoints from two stores over
    /// one root (each its own "process") must never lose the other's
    /// entries — the second checkpoint re-bases on the first's
    /// snapshot instead of overwriting it with its own older view.
    /// Pre-fence, the loser's put survived only in self-healing
    /// adopt-on-miss form; post-fence it is in the snapshot itself, so
    /// a fresh open vouches for both with zero parses.
    #[test]
    fn interleaved_checkpoints_preserve_both_writers_entries() {
        let dir = tmp_store("epoch-fence");
        let a = ArtifactStore::open(&dir).unwrap();
        let b = ArtifactStore::open(&dir).unwrap();
        let key_a = FitKey {
            case: "a".into(),
            device: "d".into(),
            nonlinear: false,
            target: Target::Time,
            model_fingerprint: 1,
        };
        let key_b = FitKey {
            case: "b".into(),
            device: "d".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 2,
        };
        a.save_fit(&key_a, &some_fit(1.0)).unwrap();
        b.save_fit(&key_b, &some_fit(2.0)).unwrap();
        // Both checkpoint (gc is the public path ending in one).
        a.gc(&GcOptions::default()).unwrap();
        b.gc(&GcOptions::default()).unwrap();
        let fresh = ArtifactStore::open(&dir).unwrap();
        assert!(fresh.load_fit(&key_a).is_some());
        assert!(fresh.load_fit(&key_b).is_some());
        assert_eq!(
            fresh.artifact_parses(),
            0,
            "both writers' puts must be in the snapshot, not merely \
             re-adoptable"
        );
        assert!(fresh.verify_index().unwrap().matches);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Destructive maintenance under a live foreign lease must refuse
    /// without deleting anything; an expired lease is a dead holder
    /// and is stolen.
    #[test]
    fn gc_refuses_under_live_foreign_lease_and_steals_expired_ones() {
        let dir = tmp_store("lease");
        let store = ArtifactStore::open(&dir).unwrap();
        let corrupt = dir.join("stats").join("junk.json");
        std::fs::write(&corrupt, "{not json").unwrap();

        std::fs::write(
            dir.join("gc.lease"),
            "{\"pid\":424242,\"token\":\"foreign\",\"expires_at\":99999999999}",
        )
        .unwrap();
        let err = store
            .gc(&GcOptions {
                temp_ttl_secs: 0,
                ..GcOptions::default()
            })
            .unwrap_err();
        assert!(err.contains("lease"), "{err}");
        assert!(err.contains("refusing"), "{err}");
        assert!(corrupt.exists(), "a refused gc must not delete anything");
        assert!(
            store.compact(60).unwrap_err().contains("refusing"),
            "compact is destructive maintenance too"
        );

        // Dry runs are non-destructive: they report under any lease.
        let dry = store
            .gc(&GcOptions {
                temp_ttl_secs: 0,
                dry_run: true,
                ..GcOptions::default()
            })
            .unwrap();
        assert_eq!(dry.removed.len(), 1, "{:?}", dry.removed);
        assert!(corrupt.exists());

        // An expired lease is a dead maintainer: stolen, gc proceeds,
        // and the lease releases on completion.
        std::fs::write(
            dir.join("gc.lease"),
            "{\"pid\":424242,\"token\":\"foreign\",\"expires_at\":1}",
        )
        .unwrap();
        let out = store
            .gc(&GcOptions {
                temp_ttl_secs: 0,
                ..GcOptions::default()
            })
            .unwrap();
        assert_eq!(out.removed.len(), 1, "{:?}", out.removed);
        assert!(!corrupt.exists());
        assert!(!dir.join("gc.lease").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The save-vs-gc race, deterministically: a victim classified
    /// corrupt at scan time that a concurrent writer republishes as
    /// valid before the unlink must be spared by the under-lock
    /// re-verification.
    #[test]
    fn invalid_victims_that_revalidate_under_the_lock_are_spared() {
        let dir = tmp_store("revive");
        let store = ArtifactStore::open(&dir).unwrap();
        let k = crate::uipick::derived::build_axpy(DType::F32).unwrap().freeze();
        let skey = StatsKey {
            fingerprint: k.fingerprint(),
            sub_group_size: 32,
        };
        let path = store.stats_path(&skey);
        std::fs::write(&path, "{not json").unwrap();
        let info = store
            .list()
            .unwrap()
            .into_iter()
            .find(|i| i.path == path)
            .expect("the corrupt stats file must be surfaced");
        assert!(!info.valid, "scan-time classification: GC fodder");

        // A "concurrent writer" republishes a valid artifact at the
        // same path before the unlink would happen.
        let writer = ArtifactStore::open(&dir).unwrap();
        writer
            .save_stats(&skey, &crate::stats::gather(&k, 32).unwrap())
            .unwrap();
        assert!(
            store.revalidates_under_lock(&info),
            "the republished artifact must be spared"
        );

        // Still corrupt: still fodder.
        std::fs::write(&path, "{not json").unwrap();
        assert!(!store.revalidates_under_lock(&info));
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `verify_index` must detect an index whose artifact vanished
    /// behind its back (the class of damage the locked journal +
    /// epoch fence prevent live writers from ever causing).
    #[test]
    fn verify_index_detects_entries_with_missing_artifacts() {
        let dir = tmp_store("verify");
        let store = ArtifactStore::open(&dir).unwrap();
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 0x42,
        };
        store.save_fit(&key, &some_fit(1.0)).unwrap();
        let ok = store.verify_index().unwrap();
        assert!(ok.matches, "{ok:?}");
        assert_eq!(ok.indexed, ok.scanned);

        std::fs::remove_file(store.fit_path(&key)).unwrap();
        let bad = store.verify_index().unwrap();
        assert!(!bad.matches, "a lost artifact must be detected");
        assert_eq!(bad.indexed.1, 1);
        assert_eq!(bad.scanned.1, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// Fits for different targets of one (case, device, form, model)
    /// persist side by side: distinct paths, both warm after a reopen,
    /// and `ls` describes the time fit exactly as v3 did while naming
    /// the energy target explicitly.
    #[test]
    fn per_target_fits_coexist_and_both_load_warm() {
        let dir = tmp_store("targets");
        let store = ArtifactStore::open(&dir).unwrap();
        let time_key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 0xbeef,
        };
        let energy_key = FitKey {
            target: Target::Energy,
            ..time_key.clone()
        };
        assert_ne!(store.fit_path(&time_key), store.fit_path(&energy_key));
        store.save_fit(&time_key, &some_fit(1.0)).unwrap();
        let energy_fit = FitResult {
            target: Target::Energy,
            ..some_fit(2.0)
        };
        store.save_fit(&energy_key, &energy_fit).unwrap();
        assert_eq!(store.load_fit(&time_key).unwrap().params, vec![1.0]);
        let back = store.load_fit(&energy_key).unwrap();
        assert_eq!(back.params, vec![2.0]);
        assert_eq!(back.target, Target::Energy);

        let warm = ArtifactStore::open(&dir).unwrap();
        assert!(warm.load_fit(&time_key).is_some());
        assert!(warm.load_fit(&energy_key).is_some());
        assert_eq!(warm.artifact_parses(), 0, "both targets must be vouched");

        let describes: Vec<String> = warm
            .list()
            .unwrap()
            .into_iter()
            .filter(|i| matches!(i.kind, ArtifactKind::Fit))
            .map(|i| i.describe)
            .collect();
        assert!(
            describes.iter().any(|d| d.contains("target=energy")),
            "{describes:?}"
        );
        assert!(
            describes
                .iter()
                .any(|d| !d.contains("target=") && d.contains("overlap")),
            "time fits keep the pre-v4 description: {describes:?}"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// v3→v4 read-compat at the store layer: a raw v3 fit artifact at
    /// the legacy path loads through `load_legacy_v3_fit` as a
    /// converged time fit, is invisible to the v4 `load_fit` path, and
    /// re-saving it under the v4 key makes subsequent loads warm.
    #[test]
    fn legacy_v3_fit_artifacts_load_and_migrate() {
        let dir = tmp_store("v3compat");
        let store = ArtifactStore::open(&dir).unwrap();
        let key = FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 0x0123_4567_89ab_cdef_0123_4567_89ab_cdef,
        };
        // A v3 writer's artifact, verbatim: v3 envelope (no target
        // field anywhere) at the v3 path.
        let v3 = format!(
            "{{\"format_version\":3,\"kind\":\"fit\",\"case\":\"matmul\",\
             \"device\":\"titan_v\",\"nonlinear\":true,\
             \"model_fingerprint\":\"{}\",\"fit\":{{\
             \"param_names\":[\"p_a\"],\"params\":[2.5],\"residual\":0.125,\
             \"iterations\":9}}}}",
            codec::fingerprint_to_hex(key.model_fingerprint)
        );
        let legacy_path =
            dir.join("fits").join(legacy_v3_fit_file_name(&key));
        std::fs::write(&legacy_path, &v3).unwrap();

        assert!(
            store.load_fit(&key).is_none(),
            "the v4 path must not see the legacy artifact"
        );
        let fit = store
            .load_legacy_v3_fit(&key)
            .expect("the v3 artifact must load via the legacy path");
        assert_eq!(fit.params, vec![2.5]);
        assert_eq!(fit.iterations, 9);
        assert_eq!(fit.target, Target::Time, "v3 fits are time fits");
        assert!(fit.converged, "v3 fits decode as converged");

        // Non-time keys have no legacy counterpart by definition.
        assert!(store
            .load_legacy_v3_fit(&FitKey {
                target: Target::Energy,
                ..key.clone()
            })
            .is_none());

        // Key mismatch inside the envelope is rejected like any other.
        assert!(store
            .load_legacy_v3_fit(&FitKey {
                nonlinear: false,
                ..key.clone()
            })
            .is_none());

        // The migration step: re-save under the v4 key, then loads are
        // warm and the legacy file is dead weight.
        store.save_fit(&key, &fit).unwrap();
        let warm = ArtifactStore::open(&dir).unwrap();
        assert_eq!(warm.load_fit(&key).unwrap().params, vec![2.5]);
        assert_eq!(warm.artifact_parses(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
