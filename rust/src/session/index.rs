//! Read-mostly store index: a journaled manifest of every *valid*
//! artifact in an [`ArtifactStore`](super::ArtifactStore).
//!
//! The seed store answered every question by touching the filesystem:
//! `load_*` probed (and on a hit fully parsed) an artifact file per
//! lookup, and `store ls`/`stat`/`gc` re-parsed **every** artifact on
//! every invocation — O(N · parse) per scan, paid again by each fleet
//! member sharing the store.  The index replaces those probes with
//! hash-map lookups:
//!
//! * `<root>/index.json` — an atomic snapshot of the manifest, written
//!   at open (after a rebuild), after `gc`/`compact`, and whenever the
//!   journal grows past [`JOURNAL_COMPACT_THRESHOLD`];
//! * `<root>/index.journal` — an append-only log of
//!   [`JournalOp`] records (one JSON object per line) written by
//!   `save_stats`/`save_fit`/`gc`/`compact` between snapshots.
//!
//! A process loads the snapshot once, replays the journal on top, and
//! thereafter shares the in-memory index read-mostly across every
//! fleet session holding the same `Arc<ArtifactStore>`.  The index is
//! an *accelerator, never an authority*: a positive entry still has
//! its artifact validated when the payload is fetched (a vouched file
//! that fails validation is dropped from the index and degrades to a
//! cold start), a negative answer falls back to a direct disk probe
//! (so another process's writes are adopted, at the cost of one
//! counted full-artifact parse), a corrupt or version-skewed snapshot
//! triggers a full rebuild scan, and unparseable journal lines are
//! simply skipped — a lost put re-adopts on the next lookup, a lost
//! delete is dropped by the next vouched load, so journal damage never
//! produces wrong answers.  Since the cross-process layer
//! ([`super::lock`]) serialized appends under the writer lock (one
//! fsynced `O_APPEND` line per record) and epoch-fenced checkpoints,
//! torn lines are impossible from live writers rather than merely
//! tolerated; the tolerant replay remains as defense in depth against
//! hand-edited or crash-truncated journals.
//!
//! Filenames are *derived*, not stored: every artifact family's path
//! is a pure function of its key (see `ArtifactStore::fit_path` and
//! friends), so the manifest serializes only keys and the reverse
//! (filename → key) maps are rebuilt in memory on load.

use std::collections::{HashMap, HashSet};

use super::codec;
use super::store::{fit_file_name, FitKey, STORE_FORMAT_VERSION};
use crate::calibrate::Target;
use crate::stats::StatsKey;
use crate::util::json::Json;

/// Journal lines accumulated before the next open rewrites the
/// snapshot and truncates the journal (bounds replay cost).
pub(crate) const JOURNAL_COMPACT_THRESHOLD: usize = 256;

fn err(what: &str) -> String {
    format!("store index: malformed {what}")
}

/// Index metadata for one stats artifact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StatsEntry {
    /// True when the artifact is in compacted form: it persists only
    /// the per-sub-group op counts and references the deduplicated
    /// sg-invariant section under `<root>/shared/` (`store compact`).
    pub compacted: bool,
}

/// One journal record: a single put/delete of an index entry.
#[derive(Clone, Debug, PartialEq)]
pub enum JournalOp {
    PutStats(StatsKey, StatsEntry),
    DelStats(StatsKey),
    PutFit(FitKey),
    DelFit(FitKey),
    PutShared(u128),
    DelShared(u128),
}

fn stats_key_fields(key: &StatsKey) -> Vec<(&'static str, Json)> {
    vec![
        (
            "fingerprint",
            codec::fingerprint_to_hex(key.fingerprint).into(),
        ),
        ("sub_group_size", (key.sub_group_size as i64).into()),
    ]
}

fn stats_key_from(j: &Json) -> Result<StatsKey, String> {
    Ok(StatsKey {
        fingerprint: codec::fingerprint_from_hex(
            j.get("fingerprint")
                .and_then(Json::as_str)
                .ok_or_else(|| err("stats entry"))?,
        )?,
        sub_group_size: j
            .get("sub_group_size")
            .and_then(Json::as_f64)
            .filter(|x| *x >= 1.0 && x.fract() == 0.0)
            .ok_or_else(|| err("stats entry"))? as u64,
    })
}

fn fit_key_fields(key: &FitKey) -> Vec<(&'static str, Json)> {
    vec![
        ("case", key.case.as_str().into()),
        ("device", key.device.as_str().into()),
        ("nonlinear", key.nonlinear.into()),
        ("target", key.target.name().into()),
        (
            "model_fingerprint",
            codec::fingerprint_to_hex(key.model_fingerprint).into(),
        ),
    ]
}

fn fit_key_from(j: &Json) -> Result<FitKey, String> {
    Ok(FitKey {
        case: j
            .get("case")
            .and_then(Json::as_str)
            .ok_or_else(|| err("fit entry"))?
            .to_string(),
        device: j
            .get("device")
            .and_then(Json::as_str)
            .ok_or_else(|| err("fit entry"))?
            .to_string(),
        nonlinear: j
            .get("nonlinear")
            .and_then(Json::as_bool)
            .ok_or_else(|| err("fit entry"))?,
        // Strict: index entries are written by v4+ code only (v3
        // snapshots are rejected wholesale by the version check, v3
        // journal lines degrade to skipped lines → disk-probe
        // fallback), so a missing target is corruption, not legacy.
        target: Target::parse(
            j.get("target")
                .and_then(Json::as_str)
                .ok_or_else(|| err("fit entry"))?,
        )
        .map_err(|_| err("fit entry"))?,
        model_fingerprint: codec::fingerprint_from_hex(
            j.get("model_fingerprint")
                .and_then(Json::as_str)
                .ok_or_else(|| err("fit entry"))?,
        )?,
    })
}

impl JournalOp {
    pub fn to_json(&self) -> Json {
        let (op, mut fields) = match self {
            JournalOp::PutStats(key, entry) => {
                let mut f = stats_key_fields(key);
                f.push(("compacted", entry.compacted.into()));
                ("put-stats", f)
            }
            JournalOp::DelStats(key) => ("del-stats", stats_key_fields(key)),
            JournalOp::PutFit(key) => ("put-fit", fit_key_fields(key)),
            JournalOp::DelFit(key) => ("del-fit", fit_key_fields(key)),
            JournalOp::PutShared(fp) => (
                "put-shared",
                vec![("fingerprint", codec::fingerprint_to_hex(*fp).into())],
            ),
            JournalOp::DelShared(fp) => (
                "del-shared",
                vec![("fingerprint", codec::fingerprint_to_hex(*fp).into())],
            ),
        };
        fields.push(("op", op.into()));
        Json::obj(fields)
    }

    pub fn from_json(j: &Json) -> Result<JournalOp, String> {
        let shared_fp = |j: &Json| {
            codec::fingerprint_from_hex(
                j.get("fingerprint")
                    .and_then(Json::as_str)
                    .ok_or_else(|| err("shared entry"))?,
            )
        };
        match j.get("op").and_then(Json::as_str) {
            Some("put-stats") => Ok(JournalOp::PutStats(
                stats_key_from(j)?,
                StatsEntry {
                    compacted: j
                        .get("compacted")
                        .and_then(Json::as_bool)
                        .ok_or_else(|| err("stats entry"))?,
                },
            )),
            Some("del-stats") => Ok(JournalOp::DelStats(stats_key_from(j)?)),
            Some("put-fit") => Ok(JournalOp::PutFit(fit_key_from(j)?)),
            Some("del-fit") => Ok(JournalOp::DelFit(fit_key_from(j)?)),
            Some("put-shared") => Ok(JournalOp::PutShared(shared_fp(j)?)),
            Some("del-shared") => Ok(JournalOp::DelShared(shared_fp(j)?)),
            _ => Err(err("journal op")),
        }
    }
}

/// The monotonically increasing compaction epoch carried by a
/// snapshot; snapshots from pre-epoch writers read as 0.  The epoch is
/// a *fence*, not content: a checkpoint re-bases itself on the current
/// on-disk snapshot and writes `max(disk epoch, seen epoch) + 1`, so a
/// writer holding an older view can detect — and never clobber — a
/// newer snapshot another process published since it loaded.
pub fn snapshot_epoch(j: &Json) -> u64 {
    j.get("epoch")
        .and_then(Json::as_f64)
        .filter(|e| *e >= 0.0 && e.fract() == 0.0)
        .map(|e| e as u64)
        .unwrap_or(0)
}

/// The in-memory manifest: which keys have a valid artifact on disk,
/// and in which form.  See the module docs for the maintenance
/// protocol (snapshot + journal + rebuild).
#[derive(Clone, Default)]
pub struct StoreIndex {
    stats: HashMap<StatsKey, StatsEntry>,
    fits: HashSet<FitKey>,
    /// Derived reverse map: fit artifact filename → key (fit filenames
    /// embed a key hash, so unlike stats filenames they cannot be
    /// parsed back into their key).
    fit_names: HashMap<String, FitKey>,
    shared: HashSet<u128>,
}

impl StoreIndex {
    pub fn new() -> StoreIndex {
        StoreIndex::default()
    }

    pub fn stats(&self, key: &StatsKey) -> Option<StatsEntry> {
        self.stats.get(key).copied()
    }

    pub fn has_fit(&self, key: &FitKey) -> bool {
        self.fits.contains(key)
    }

    pub fn fit_for_file(&self, name: &str) -> Option<&FitKey> {
        self.fit_names.get(name)
    }

    pub fn has_shared(&self, fp: u128) -> bool {
        self.shared.contains(&fp)
    }

    pub fn stats_entries(&self) -> impl Iterator<Item = (&StatsKey, &StatsEntry)> {
        self.stats.iter()
    }

    pub fn shared_fingerprints(&self) -> impl Iterator<Item = u128> + '_ {
        self.shared.iter().copied()
    }

    /// `(stats, fits, shared)` entry counts.
    pub fn counts(&self) -> (usize, usize, usize) {
        (self.stats.len(), self.fits.len(), self.shared.len())
    }

    pub fn apply(&mut self, op: &JournalOp) {
        match op {
            JournalOp::PutStats(key, entry) => {
                self.stats.insert(*key, *entry);
            }
            JournalOp::DelStats(key) => {
                self.stats.remove(key);
            }
            JournalOp::PutFit(key) => {
                if self.fits.insert(key.clone()) {
                    self.fit_names.insert(fit_file_name(key), key.clone());
                }
            }
            JournalOp::DelFit(key) => {
                if self.fits.remove(key) {
                    self.fit_names.remove(&fit_file_name(key));
                }
            }
            JournalOp::PutShared(fp) => {
                self.shared.insert(*fp);
            }
            JournalOp::DelShared(fp) => {
                self.shared.remove(fp);
            }
        }
    }

    /// Serialize the manifest as a deterministic snapshot (entries in
    /// sorted key order, so identical manifests are byte-identical,
    /// and two manifests serialized under the same `epoch` compare
    /// byte-for-byte iff their entries agree — which is how
    /// `verify_index` and the multi-process tests compare an index
    /// against a rebuild scan).
    pub fn to_snapshot_json(&self, epoch: u64) -> Json {
        let mut stats: Vec<_> = self.stats.iter().collect();
        stats.sort_by_key(|(k, _)| (k.fingerprint, k.sub_group_size));
        let mut fits: Vec<_> = self.fits.iter().collect();
        fits.sort_by(|a, b| {
            (&a.case, &a.device, a.nonlinear, a.target, a.model_fingerprint)
                .cmp(&(
                    &b.case,
                    &b.device,
                    b.nonlinear,
                    b.target,
                    b.model_fingerprint,
                ))
        });
        let mut shared: Vec<_> = self.shared.iter().copied().collect();
        shared.sort_unstable();
        Json::obj(vec![
            ("format_version", (STORE_FORMAT_VERSION as i64).into()),
            ("kind", "store-index".into()),
            ("epoch", (epoch as i64).into()),
            (
                "stats",
                Json::Arr(
                    stats
                        .into_iter()
                        .map(|(key, entry)| {
                            let mut f = stats_key_fields(key);
                            f.push(("compacted", entry.compacted.into()));
                            Json::obj(f)
                        })
                        .collect(),
                ),
            ),
            (
                "fits",
                Json::Arr(
                    fits.into_iter()
                        .map(|key| Json::obj(fit_key_fields(key)))
                        .collect(),
                ),
            ),
            (
                "shared",
                Json::Arr(
                    shared
                        .into_iter()
                        .map(|fp| codec::fingerprint_to_hex(fp).into())
                        .collect(),
                ),
            ),
        ])
    }

    /// Strict snapshot decode: any malformed entry or version skew is
    /// an error, and the caller falls back to a full rebuild scan —
    /// the index never limps along on a partially-understood manifest.
    /// The `epoch` field is decoded separately ([`snapshot_epoch`]):
    /// it fences checkpoints, it is not manifest content.
    pub fn from_snapshot_json(j: &Json) -> Result<StoreIndex, String> {
        if j.get("format_version").and_then(Json::as_f64)
            != Some(STORE_FORMAT_VERSION as f64)
        {
            return Err(err("snapshot version"));
        }
        if j.get("kind").and_then(Json::as_str) != Some("store-index") {
            return Err(err("snapshot kind"));
        }
        let mut index = StoreIndex::new();
        for entry in j
            .get("stats")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("snapshot stats"))?
        {
            let key = stats_key_from(entry)?;
            let compacted = entry
                .get("compacted")
                .and_then(Json::as_bool)
                .ok_or_else(|| err("stats entry"))?;
            index.apply(&JournalOp::PutStats(key, StatsEntry { compacted }));
        }
        for entry in j
            .get("fits")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("snapshot fits"))?
        {
            index.apply(&JournalOp::PutFit(fit_key_from(entry)?));
        }
        for entry in j
            .get("shared")
            .and_then(Json::as_arr)
            .ok_or_else(|| err("snapshot shared"))?
        {
            let fp = codec::fingerprint_from_hex(
                entry.as_str().ok_or_else(|| err("shared entry"))?,
            )?;
            index.apply(&JournalOp::PutShared(fp));
        }
        Ok(index)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_fit_key() -> FitKey {
        FitKey {
            case: "matmul".into(),
            device: "titan_v".into(),
            nonlinear: true,
            target: Target::Time,
            model_fingerprint: 0xabcd,
        }
    }

    #[test]
    fn journal_ops_roundtrip_and_apply() {
        let skey = StatsKey {
            fingerprint: 0x1234,
            sub_group_size: 64,
        };
        let fkey = sample_fit_key();
        let ops = vec![
            JournalOp::PutStats(skey, StatsEntry { compacted: false }),
            JournalOp::PutFit(fkey.clone()),
            JournalOp::PutShared(0x1234),
            JournalOp::PutStats(skey, StatsEntry { compacted: true }),
            JournalOp::DelShared(0x1234),
        ];
        let mut index = StoreIndex::new();
        for op in &ops {
            let line = op.to_json().to_string();
            let back = JournalOp::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(&back, op, "journal line must round-trip: {line}");
            index.apply(&back);
        }
        assert_eq!(index.stats(&skey), Some(StatsEntry { compacted: true }));
        assert!(index.has_fit(&fkey));
        assert!(!index.has_shared(0x1234));
        assert_eq!(
            index.fit_for_file(&fit_file_name(&fkey)),
            Some(&fkey),
            "fit filename reverse map must track puts"
        );
        index.apply(&JournalOp::DelFit(fkey.clone()));
        assert!(!index.has_fit(&fkey));
        assert!(index.fit_for_file(&fit_file_name(&fkey)).is_none());
    }

    /// Fit keys differing only in target are distinct index entries,
    /// and their journal lines round-trip the target.
    #[test]
    fn fit_keys_are_distinct_per_target() {
        let mut index = StoreIndex::new();
        for target in Target::ALL {
            let key = FitKey {
                target,
                ..sample_fit_key()
            };
            let line = JournalOp::PutFit(key.clone()).to_json().to_string();
            let back =
                JournalOp::from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(back, JournalOp::PutFit(key.clone()), "{line}");
            index.apply(&back);
        }
        assert_eq!(index.counts().1, Target::ALL.len());
        index.apply(&JournalOp::DelFit(FitKey {
            target: Target::Energy,
            ..sample_fit_key()
        }));
        assert!(index.has_fit(&sample_fit_key()));
        assert!(!index.has_fit(&FitKey {
            target: Target::Energy,
            ..sample_fit_key()
        }));
    }

    #[test]
    fn snapshot_roundtrips_and_is_deterministic() {
        let mut index = StoreIndex::new();
        for sg in [32u64, 64] {
            index.apply(&JournalOp::PutStats(
                StatsKey {
                    fingerprint: 0xfeed,
                    sub_group_size: sg,
                },
                StatsEntry { compacted: sg == 64 },
            ));
        }
        index.apply(&JournalOp::PutFit(sample_fit_key()));
        index.apply(&JournalOp::PutShared(0xfeed));

        let text = index.to_snapshot_json(7).to_string();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(snapshot_epoch(&parsed), 7, "the epoch fence must round-trip");
        let back = StoreIndex::from_snapshot_json(&parsed).unwrap();
        assert_eq!(back.counts(), index.counts());
        assert_eq!(
            back.to_snapshot_json(7).to_string(),
            text,
            "snapshot serialization must be byte-stable"
        );
        assert_ne!(
            back.to_snapshot_json(8).to_string(),
            text,
            "the epoch is part of the serialized snapshot"
        );
        assert!(back.has_fit(&sample_fit_key()));
        assert_eq!(
            back.stats(&StatsKey {
                fingerprint: 0xfeed,
                sub_group_size: 64
            }),
            Some(StatsEntry { compacted: true })
        );
    }

    /// Snapshots written before the epoch fence existed carry no
    /// `epoch` field; they decode (strictly) and read as epoch 0, so
    /// upgrading a binary never forces a rebuild scan.
    #[test]
    fn pre_epoch_snapshots_decode_and_read_as_epoch_zero() {
        let text = format!(
            "{{\"format_version\":{STORE_FORMAT_VERSION},\
             \"kind\":\"store-index\",\"stats\":[],\"fits\":[],\"shared\":[]}}"
        );
        let j = Json::parse(&text).unwrap();
        assert!(StoreIndex::from_snapshot_json(&j).is_ok());
        assert_eq!(snapshot_epoch(&j), 0);
    }

    #[test]
    fn corrupt_snapshots_and_journal_lines_are_rejected() {
        assert!(StoreIndex::from_snapshot_json(&Json::parse("{}").unwrap()).is_err());
        let skewed = format!(
            "{{\"format_version\":{},\"kind\":\"store-index\",\
             \"stats\":[],\"fits\":[],\"shared\":[]}}",
            STORE_FORMAT_VERSION + 1
        );
        assert!(
            StoreIndex::from_snapshot_json(&Json::parse(&skewed).unwrap()).is_err(),
            "version skew must force a rebuild"
        );
        assert!(JournalOp::from_json(&Json::parse("{\"op\":\"nope\"}").unwrap())
            .is_err());
        assert!(JournalOp::from_json(
            &Json::parse("{\"op\":\"put-fit\",\"case\":\"x\"}").unwrap()
        )
        .is_err());
    }
}
