//! Symbolic memory access-pattern analysis: coalescing and bank
//! conflicts, derived per access from the lid(0) stride.
//!
//! The counting pass already records *how many* accesses a kernel
//! performs; the dominant cross-GPU cost drivers are access *patterns*
//! — how many memory transactions a sub-group's access coalesces into,
//! and how many ways a local-memory access serializes across banks.
//! This pass derives both statically, per global/local array access:
//!
//! * **Transactions per sub-group access.**  A sub-group of `sg`
//!   work-items accessing `e`-byte elements with lid(0) stride `s`
//!   touches a span of `sg·|s|·e` bytes, i.e.
//!   `ceil(sg·|s|·e / cacheline_bytes)` cache lines, clamped between
//!   the contiguous baseline `ceil(sg·e / cacheline_bytes)` and the
//!   one-line-per-lane worst case `sg`.  A stride-0 (uniform) access is
//!   a single broadcast transaction.  For 4-byte elements on a 32-wide
//!   sub-group with 128-byte lines this reduces to the familiar
//!   `min(s, sg)` transactions.  Accesses whose transaction count
//!   exceeds the contiguous baseline get
//!   [`DiagCode::UncoalescedGlobal`].
//! * **Bank-conflict multiplier.**  `sg` lanes with stride `s` over
//!   `B` local-memory banks touch `B / gcd(|s|, B)` distinct banks, so
//!   the access serializes `gcd(|s|, B)`-way.  Multipliers above 1 get
//!   [`DiagCode::BankConflict`].
//!
//! Strides come from [`Kernel::lid_stride`] (the flattened access
//! form), simplified under the kernel's assumptions; parametric
//! strides are evaluated at the same assumption-derived sample sizes
//! the race/bounds checks use, taking the worst case.
//!
//! Three consumers: [`Analyzer::check`](super::Analyzer::check) runs
//! the pass with the device-independent [`Geometry`] (warp 32, 128-byte
//! lines, 32 banks); [`check_feasibility`](super::check_feasibility)
//! re-runs it with the target device's geometry; and
//! [`admissible`](super::admissible) returns the full [`AccessReport`]
//! so the autotune loop can explain *why* a candidate's memory cost
//! regressed, not just whether it is valid.  The feature families
//! `f_mem_transactions[_tag:<t>]` and `f_bank_conflict_factor`
//! ([`crate::features`]) lower the same per-access factors into model
//! features.

use std::collections::{BTreeMap, BTreeSet};

use super::{sample_envs, Analyzer, DiagCode, Diagnostic};
use crate::gpusim::{
    DeviceProfile, DEFAULT_CACHELINE_BYTES, DEFAULT_LOCAL_MEM_BANKS,
    DEFAULT_SUB_GROUP_SIZE,
};
use crate::ir::{Kernel, LhsRef, MemScope};
use crate::polyhedral::QPoly;
use crate::util::json::Json;

/// The three hardware numbers the access-pattern model consumes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    /// Sub-group (warp/wavefront) width in work-items.
    pub sub_group: u64,
    /// Coalescing-unit (cache line) width in bytes.
    pub cacheline_bytes: u64,
    /// Local-memory bank count.
    pub local_mem_banks: u64,
}

impl Geometry {
    /// The device-independent geometry used by [`Analyzer::check`]:
    /// warp 32, 128-byte lines, 32 banks (every NVIDIA fleet device).
    pub fn device_independent() -> Geometry {
        Geometry {
            sub_group: DEFAULT_SUB_GROUP_SIZE,
            cacheline_bytes: DEFAULT_CACHELINE_BYTES,
            local_mem_banks: DEFAULT_LOCAL_MEM_BANKS,
        }
    }

    /// The geometry of one fleet device.
    pub fn for_device(dev: &DeviceProfile) -> Geometry {
        Geometry {
            sub_group: dev.sub_group_size,
            cacheline_bytes: dev.cacheline_bytes,
            local_mem_banks: dev.local_mem_banks,
        }
    }
}

impl Default for Geometry {
    fn default() -> Geometry {
        Geometry::device_independent()
    }
}

fn ceil_div(a: u64, b: u64) -> u64 {
    (a + b - 1) / b.max(1)
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

/// Transactions a *contiguous* (stride-1) sub-group access of
/// `elem_bytes`-byte elements needs: the baseline every other stride is
/// judged against (1 line for f32 at warp 32 / 128-byte lines; 2 lines
/// for f64).
pub fn contiguous_txns(elem_bytes: u64, geom: &Geometry) -> u64 {
    ceil_div(geom.sub_group * elem_bytes, geom.cacheline_bytes).max(1)
}

/// Transactions one sub-group access with constant lid(0) stride
/// `stride` (elements) needs: 1 for a uniform (stride-0) broadcast,
/// otherwise `ceil(sg·|s|·e / line)` clamped to
/// `[contiguous_txns, sub_group]`.
pub fn txns_for_stride(stride: i128, elem_bytes: u64, geom: &Geometry) -> u64 {
    if stride == 0 {
        return 1;
    }
    let lo = contiguous_txns(elem_bytes, geom);
    let hi = geom.sub_group.max(lo);
    let span = (stride.unsigned_abs().min(u64::MAX as u128) as u64)
        .saturating_mul(geom.sub_group)
        .saturating_mul(elem_bytes);
    ceil_div(span, geom.cacheline_bytes).clamp(lo, hi)
}

/// Bank-conflict serialization factor of a constant lid(0) stride:
/// `gcd(|s|, banks)` (1 = conflict-free; a stride-0 broadcast is
/// conflict-free by hardware broadcast).
pub fn bank_conflict_multiplier(stride: i128, geom: &Geometry) -> u64 {
    if stride == 0 {
        return 1;
    }
    gcd(
        stride.unsigned_abs().min(u64::MAX as u128) as u64,
        geom.local_mem_banks,
    )
}

/// One classified array access: its symbolic lid(0) stride and the
/// derived transaction / bank-conflict factors.
#[derive(Clone, Debug)]
pub struct AccessPattern {
    /// Statement the access belongs to.
    pub stmt: String,
    pub array: String,
    pub tag: Option<String>,
    pub scope: MemScope,
    /// True for the statement's store target, false for a load.
    pub store: bool,
    /// lid(0) stride in elements, simplified under the kernel's
    /// assumptions (possibly symbolic in the problem sizes).
    pub stride: QPoly,
    /// Global arrays: transactions per sub-group access (worst case
    /// over the sample sizes when the stride is parametric).
    pub txns_per_access: Option<u64>,
    /// Global arrays: the contiguous baseline for the element width.
    pub contiguous_txns: Option<u64>,
    /// Local arrays: bank-conflict serialization factor.
    pub bank_multiplier: Option<u64>,
}

impl AccessPattern {
    /// True when the access pays more than the ideal pattern would: an
    /// uncoalesced global access or a bank-conflicted local one.
    pub fn is_penalized(&self) -> bool {
        match (self.txns_per_access, self.contiguous_txns) {
            (Some(t), Some(b)) if t > b => return true,
            _ => {}
        }
        matches!(self.bank_multiplier, Some(m) if m > 1)
    }

    pub fn to_json(&self) -> Json {
        let opt = |v: Option<u64>| match v {
            Some(n) => (n as f64).into(),
            None => Json::Null,
        };
        Json::obj(vec![
            ("stmt", self.stmt.as_str().into()),
            ("array", self.array.as_str().into()),
            (
                "tag",
                match &self.tag {
                    Some(t) => t.as_str().into(),
                    None => Json::Null,
                },
            ),
            (
                "scope",
                match self.scope {
                    MemScope::Global => "global".into(),
                    MemScope::Local => "local".into(),
                    MemScope::Private => "private".into(),
                },
            ),
            ("store", self.store.into()),
            ("lid0_stride", self.stride.to_string().into()),
            ("txns_per_access", opt(self.txns_per_access)),
            ("contiguous_txns", opt(self.contiguous_txns)),
            ("bank_multiplier", opt(self.bank_multiplier)),
            ("penalized", self.is_penalized().into()),
        ])
    }
}

/// Per-candidate access-pattern report: what [`super::admissible`]
/// returns alongside its verdict, so the pruning loop can explain a
/// cost regression (a candidate may be perfectly *valid* and still
/// pay 32x the memory transactions of its baseline).
#[derive(Clone, Debug)]
pub struct AccessReport {
    pub kernel: String,
    /// Device id the geometry came from.
    pub device: String,
    pub geometry: Geometry,
    /// Every global/local access of the kernel, classified.
    pub accesses: Vec<AccessPattern>,
}

impl AccessReport {
    /// The accesses paying a coalescing or bank-conflict penalty.
    pub fn penalized(&self) -> Vec<&AccessPattern> {
        self.accesses.iter().filter(|a| a.is_penalized()).collect()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("kernel", self.kernel.as_str().into()),
            ("device", self.device.as_str().into()),
            ("sub_group", (self.geometry.sub_group as f64).into()),
            (
                "cacheline_bytes",
                (self.geometry.cacheline_bytes as f64).into(),
            ),
            (
                "local_mem_banks",
                (self.geometry.local_mem_banks as f64).into(),
            ),
            (
                "accesses",
                Json::Arr(
                    self.accesses.iter().map(AccessPattern::to_json).collect(),
                ),
            ),
        ])
    }
}

/// Round a sampled rational stride to the integer magnitude the
/// transaction/bank model consumes (non-integer strides round away
/// from zero; they do not occur in practice).
fn sampled_stride(r: crate::util::Rat) -> i128 {
    let a = r.abs();
    let s = if a.is_integer() {
        a.as_integer().unwrap_or(0)
    } else {
        a.floor() + 1
    };
    if r < crate::util::Rat::ZERO {
        -s
    } else {
        s
    }
}

/// Worst-case factor of a possibly-parametric stride: exact for
/// constant strides, the max over the kernel's sample sizes otherwise,
/// degrading to `cap` when no sample point evaluates.
fn worst_factor(
    stride: &QPoly,
    envs: &[BTreeMap<String, i128>],
    cap: u64,
    f: impl Fn(i128) -> u64,
) -> u64 {
    if let Some(s) = stride.as_constant() {
        return f(sampled_stride(s));
    }
    let mut worst: Option<u64> = None;
    for env in envs {
        if let Ok(v) = stride.try_eval(env) {
            let t = f(sampled_stride(v));
            worst = Some(worst.map_or(t, |w| w.max(t)));
        }
    }
    worst.unwrap_or(cap)
}

/// Classify every global/local access of the kernel.  Assumes the
/// structural gate has passed (subscript ranks match declarations).
fn classify(
    knl: &Kernel,
    envs: &[BTreeMap<String, i128>],
    geom: &Geometry,
) -> Vec<AccessPattern> {
    let mut out = Vec::new();
    for s in &knl.stmts {
        // Store target first, then loads (the `accesses_of` order).
        let mut accs: Vec<(&crate::ir::Access, bool)> = Vec::new();
        if let LhsRef::Array(a) = &s.lhs {
            accs.push((a, true));
        }
        accs.extend(s.rhs.loads().into_iter().map(|l| (l, false)));
        for (acc, store) in accs {
            let decl = &knl.arrays[&acc.array];
            if decl.scope == MemScope::Private {
                continue;
            }
            let stride = knl.assumptions.simplify(&knl.lid_stride(acc, 0));
            let elem_bytes = decl.dtype.size_bytes() as u64;
            let (txns, baseline, banks) = match decl.scope {
                MemScope::Global => (
                    Some(worst_factor(&stride, envs, geom.sub_group, |s| {
                        txns_for_stride(s, elem_bytes, geom)
                    })),
                    Some(contiguous_txns(elem_bytes, geom)),
                    None,
                ),
                MemScope::Local => (
                    None,
                    None,
                    Some(worst_factor(
                        &stride,
                        envs,
                        geom.local_mem_banks,
                        |s| bank_conflict_multiplier(s, geom),
                    )),
                ),
                MemScope::Private => unreachable!(),
            };
            out.push(AccessPattern {
                stmt: s.id.clone(),
                array: acc.array.clone(),
                tag: acc.tag.clone(),
                scope: decl.scope,
                store,
                stride,
                txns_per_access: txns,
                contiguous_txns: baseline,
                bank_multiplier: banks,
            });
        }
    }
    out
}

/// The access-pattern check: one Warn-severity diagnostic per
/// (statement, array) whose pattern pays a penalty under `geom` —
/// [`DiagCode::UncoalescedGlobal`] for global accesses needing more
/// transactions than the contiguous baseline,
/// [`DiagCode::BankConflict`] for local accesses serializing across
/// banks.  The diagnostic message carries the symbolic stride and the
/// derived factor.
pub(super) fn check_access_patterns(
    knl: &Kernel,
    envs: &[BTreeMap<String, i128>],
    geom: &Geometry,
    diags: &mut Vec<Diagnostic>,
) {
    let mut flagged: BTreeSet<(String, String)> = BTreeSet::new();
    for p in classify(knl, envs, geom) {
        match (p.txns_per_access, p.contiguous_txns, p.bank_multiplier) {
            (Some(txns), Some(base), _) if txns > base => {
                if flagged.insert((p.stmt.clone(), p.array.clone())) {
                    diags.push(Diagnostic {
                        code: DiagCode::UncoalescedGlobal,
                        kernel: knl.name.clone(),
                        stmt: Some(p.stmt),
                        object: Some(p.array.clone()),
                        message: format!(
                            "global access to '{}' with lid(0) stride {} \
                             needs {} transaction(s) per {}-item sub-group \
                             access at {} B lines (contiguous baseline: {})",
                            p.array,
                            p.stride,
                            txns,
                            geom.sub_group,
                            geom.cacheline_bytes,
                            base
                        ),
                    });
                }
            }
            (_, _, Some(mult)) if mult > 1 => {
                if flagged.insert((p.stmt.clone(), p.array.clone())) {
                    diags.push(Diagnostic {
                        code: DiagCode::BankConflict,
                        kernel: knl.name.clone(),
                        stmt: Some(p.stmt),
                        object: Some(p.array.clone()),
                        message: format!(
                            "local access to '{}' with lid(0) stride {} \
                             serializes {}-way across {} banks",
                            p.array, p.stride, mult, geom.local_mem_banks
                        ),
                    });
                }
            }
            _ => {}
        }
    }
}

/// Build the full [`AccessReport`] of a kernel under one device's
/// geometry.  `Err` carries the single
/// [`DiagCode::MalformedKernel`](super::DiagCode::MalformedKernel)
/// diagnostic when the kernel is structurally broken (same degradation
/// contract as [`Analyzer::check`]).
pub fn report(
    knl: &Kernel,
    dev: &DeviceProfile,
) -> Result<AccessReport, Diagnostic> {
    let gate = Analyzer::new();
    if let Some(d) = gate.structural_gate(knl) {
        return Err(d);
    }
    let geom = Geometry::for_device(dev);
    let envs = sample_envs(knl);
    Ok(AccessReport {
        kernel: knl.name.clone(),
        device: dev.id.to_string(),
        geometry: geom,
        accesses: classify(knl, &envs, &geom),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device_by_id;
    use crate::ir::{
        Access, AffExpr, ArrayDecl, DType, Expr, IndexTag, Stmt,
    };
    use crate::polyhedral::{LoopExtent, NestedDomain};

    fn geom() -> Geometry {
        Geometry::device_independent()
    }

    #[test]
    fn transaction_factors_reduce_to_min_s_sg_for_f32() {
        // f32 at warp 32 / 128 B lines: baseline 1, stride-s access
        // needs min(s, 32) transactions — the Tentpole's closed form.
        let g = geom();
        assert_eq!(contiguous_txns(4, &g), 1);
        assert_eq!(txns_for_stride(0, 4, &g), 1);
        for s in [1i128, 2, 4, 8, 16, 32, 64, -2] {
            let expect = s.unsigned_abs().min(32) as u64;
            assert_eq!(txns_for_stride(s, 4, &g), expect.max(1), "s={s}");
        }
    }

    #[test]
    fn f64_baseline_is_two_lines() {
        let g = geom();
        assert_eq!(contiguous_txns(8, &g), 2);
        // Stride-1 f64 pays the baseline — not a coalescing penalty.
        assert_eq!(txns_for_stride(1, 8, &g), 2);
        assert_eq!(txns_for_stride(2, 8, &g), 4);
        assert_eq!(txns_for_stride(32, 8, &g), 32);
    }

    #[test]
    fn bank_multipliers_follow_gcd() {
        let g = geom();
        assert_eq!(bank_conflict_multiplier(0, &g), 1);
        assert_eq!(bank_conflict_multiplier(1, &g), 1);
        assert_eq!(bank_conflict_multiplier(-1, &g), 1);
        assert_eq!(bank_conflict_multiplier(2, &g), 2);
        assert_eq!(bank_conflict_multiplier(16, &g), 16);
        assert_eq!(bank_conflict_multiplier(32, &g), 32);
        assert_eq!(bank_conflict_multiplier(17, &g), 1);
    }

    /// 16x16 work-group storing to `out[li0 * stride_elems]`-style
    /// flattened addresses.
    fn strided_store(stride_elems: i128) -> Kernel {
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("li1", QPoly::int(16)),
            LoopExtent::zero_to("li0", QPoly::int(16)),
        ]);
        let mut k = Kernel::new("strided_store", &[], dom);
        k.iname_tags.insert("li1".into(), IndexTag::Local(1));
        k.iname_tags.insert("li0".into(), IndexTag::Local(0));
        k.add_array(ArrayDecl::global(
            "out",
            DType::F32,
            vec![QPoly::int(16 * stride_elems.max(1) * 16)],
        ));
        k.add_stmt(Stmt::new(
            "st",
            LhsRef::Array(Access::new(
                "out",
                vec![AffExpr::scaled_var("li0", stride_elems as i64).plus(
                    &AffExpr::scaled_var("li1", (16 * stride_elems) as i64),
                )],
            )),
            Expr::fconst(1.0),
            &["li1", "li0"],
        ));
        k
    }

    #[test]
    fn strided_global_store_is_flagged_contiguous_is_not() {
        let envs = sample_envs(&strided_store(1));
        let mut diags = Vec::new();
        check_access_patterns(&strided_store(1), &envs, &geom(), &mut diags);
        assert!(diags.is_empty(), "{diags:?}");
        let mut diags = Vec::new();
        check_access_patterns(&strided_store(32), &envs, &geom(), &mut diags);
        assert_eq!(diags.len(), 1, "{diags:?}");
        assert_eq!(diags[0].code, DiagCode::UncoalescedGlobal);
        assert!(diags[0].message.contains("32 transaction"), "{}", diags[0]);
    }

    #[test]
    fn report_classifies_against_device_geometry() {
        let k = strided_store(2);
        // NVIDIA: stride-2 f32 = 2 lines vs baseline 1 — penalized.
        let titan = device_by_id("titan_v").unwrap();
        let r = report(&k, &titan).unwrap();
        assert_eq!(r.accesses.len(), 1);
        assert_eq!(r.accesses[0].txns_per_access, Some(2));
        assert_eq!(r.accesses[0].contiguous_txns, Some(1));
        assert_eq!(r.penalized().len(), 1);
        // AMD coalesces 64-wide wavefronts at 64 B lines: baseline 4,
        // stride 2 needs 8.
        let amd = device_by_id("amd_r9_fury").unwrap();
        let r = report(&k, &amd).unwrap();
        assert_eq!(r.accesses[0].contiguous_txns, Some(4));
        assert_eq!(r.accesses[0].txns_per_access, Some(8));
        let j = r.to_json().to_string();
        assert!(j.contains("\"penalized\":true"), "{j}");
        assert!(j.contains("\"cacheline_bytes\":64"), "{j}");
    }
}
