//! Transform-chain equivalence: does a transformed kernel still
//! compute what its baseline computes?
//!
//! The check is observational, over the kernel's *global* effects —
//! local tiles, private accumulators, and extra fetch statements are
//! exactly what legitimate transforms add, so only globally visible
//! behavior is compared.  At each assumption-derived sample size (the
//! same envs as the race/bounds checks, over the *merged* assumptions
//! of both kernels) it summarizes, per global array:
//!
//! * the set of arrays written, and per array the **write-instance
//!   count** (box volume of the writing statements' iteration domains)
//!   and the **flattened write-location hull** (interval of the
//!   linearized subscript over the interval-propagated iname boxes);
//! * the set of arrays read, and per array the flattened
//!   **read-location hull** — the candidate's hull must *cover* the
//!   baseline's (a bounding-box prefetch legitimately over-reads the
//!   stencil's halo corners; reading extra is harmless, reading less
//!   means values are missing from the computation);
//! * the **op volume** per operation kind (adds, muls, fused madds, …
//!   times iteration count).
//!
//! A divergence in any of these is a [`DiagCode::SemanticsChanged`]
//! finding: a tiling that drops the last partial tile loses write
//! instances, a halo-less `add_prefetch` shrinks the read
//! hull, a `remove_work` spec erases arrays from the read/write sets
//! and shifts op volume.  Hulls and box volumes are abstractions:
//! agreement is necessary, not sufficient, for true equivalence — but
//! the shipped transform chains are exactly preserved by them, so a
//! flag is always worth a look and the sweep in
//! `tests/analysis_equiv.rs` pins zero false positives.

use std::collections::{BTreeMap, BTreeSet};

use super::{
    iname_boxes, sample_envs_from, Analyzer, DiagCode, Diagnostic, Interval,
};
use crate::ir::{Kernel, LhsRef, MemScope, Stmt};
use crate::util::Rat;

/// Compare `candidate` against `baseline` and report every observable
/// divergence as a [`DiagCode::SemanticsChanged`] diagnostic (empty =
/// equivalent under the summarized abstraction).
pub fn check_equiv(baseline: &Kernel, candidate: &Kernel) -> Vec<Diagnostic> {
    let gate = Analyzer::new();
    if let Some(d) = gate.structural_gate(baseline) {
        return vec![d];
    }
    if let Some(d) = gate.structural_gate(candidate) {
        return vec![d];
    }

    let mut diags = Vec::new();
    let bp: BTreeSet<&String> = baseline.params.iter().collect();
    let cp: BTreeSet<&String> = candidate.params.iter().collect();
    if bp != cp {
        diags.push(changed(
            candidate,
            None,
            format!(
                "parameter set {:?} differs from baseline {:?}",
                candidate.params, baseline.params
            ),
        ));
        return diags;
    }

    let mut assumptions = baseline.assumptions.clone();
    assumptions.merge(&candidate.assumptions);
    let envs = sample_envs_from(&baseline.params, &assumptions);

    // One finding per (aspect, array) across all sample sizes.
    let mut seen: BTreeSet<String> = BTreeSet::new();
    for env in &envs {
        let (b, c) = match (summarize(baseline, env), summarize(candidate, env))
        {
            (Some(b), Some(c)) => (b, c),
            // Interval propagation failed at this size: stay silent
            // rather than guess (the verifier's own checks degrade the
            // same way).
            _ => continue,
        };
        compare(candidate, env, &b, &c, &mut seen, &mut diags);
    }
    diags
}

fn changed(knl: &Kernel, object: Option<&str>, message: String) -> Diagnostic {
    Diagnostic {
        code: DiagCode::SemanticsChanged,
        kernel: knl.name.clone(),
        stmt: None,
        object: object.map(str::to_string),
        message,
    }
}

/// Global-effect summary of one kernel at one sample size.
struct Summary {
    /// Global array -> (write-instance count, flattened location hull).
    writes: BTreeMap<String, (i128, Interval)>,
    /// Global array -> flattened read-location hull.
    reads: BTreeMap<String, Interval>,
    /// Op kind -> instances (op count per statement body × iteration
    /// count).
    ops: BTreeMap<&'static str, i128>,
}

fn summarize(knl: &Kernel, env: &BTreeMap<String, i128>) -> Option<Summary> {
    let boxes = iname_boxes(knl, env).ok()?;
    let mut writes: BTreeMap<String, (i128, Interval)> = BTreeMap::new();
    let mut reads: BTreeMap<String, Interval> = BTreeMap::new();
    let mut ops: BTreeMap<&'static str, i128> = BTreeMap::new();

    for s in &knl.stmts {
        // Iteration count of the statement: box volume over its
        // nesting (exact for the rectangular domains the generators
        // and transforms produce; a hull overestimate otherwise, taken
        // identically on both sides).
        let mut count: i128 = 1;
        for iname in &s.within {
            let ext = boxes.get(iname).map(|b| b.extent()).unwrap_or(1);
            count = count.saturating_mul(ext.max(0));
        }
        if count == 0 {
            continue;
        }

        let oc = s.rhs.count_ops();
        for (kind, n) in [
            ("add", oc.add),
            ("sub", oc.sub),
            ("mul", oc.mul),
            ("div", oc.div),
            ("madd", oc.madd),
        ] {
            if n > 0 {
                *ops.entry(kind).or_insert(0) += n as i128 * count;
            }
        }

        if let LhsRef::Array(acc) = &s.lhs {
            if knl.arrays[&acc.array].scope == MemScope::Global {
                let hull = access_hull(knl, s, env, &boxes)?;
                writes
                    .entry(acc.array.clone())
                    .and_modify(|(n, h)| {
                        *n += count;
                        *h = union(*h, hull);
                    })
                    .or_insert((count, hull));
            }
        }
        for l in s.rhs.loads() {
            if knl.arrays[&l.array].scope != MemScope::Global {
                continue;
            }
            let hull = hull_of(knl, l, env, &boxes)?;
            reads
                .entry(l.array.clone())
                .and_modify(|h| *h = union(*h, hull))
                .or_insert(hull);
        }
    }
    Some(Summary { writes, reads, ops })
}

fn union(a: Interval, b: Interval) -> Interval {
    Interval {
        lo: a.lo.min(b.lo),
        hi: a.hi.max(b.hi),
    }
}

fn access_hull(
    knl: &Kernel,
    s: &Stmt,
    env: &BTreeMap<String, i128>,
    boxes: &BTreeMap<String, Interval>,
) -> Option<Interval> {
    match &s.lhs {
        LhsRef::Array(acc) => hull_of(knl, acc, env, boxes),
        LhsRef::Temp(_) => None,
    }
}

/// Interval of the flattened (element-linearized) subscript of one
/// access over the iname boxes: the layout-aware location footprint,
/// so `tag_data_axes` permutations that still cover the same storage
/// compare equal.
fn hull_of(
    knl: &Kernel,
    acc: &crate::ir::Access,
    env: &BTreeMap<String, i128>,
    boxes: &BTreeMap<String, Interval>,
) -> Option<Interval> {
    let lf = knl.flatten_access(acc);
    let mut lo = lf.constant.try_eval(env).ok()?;
    let mut hi = lo;
    for (var, coeff) in &lf.coeffs {
        let c = coeff.try_eval(env).ok()?;
        if c.is_zero() {
            continue;
        }
        let b = match boxes.get(var) {
            Some(b) => *b,
            None => {
                let v = *env.get(var)?;
                Interval { lo: v, hi: v }
            }
        };
        if c > Rat::int(0) {
            lo = lo + c * Rat::int(b.lo);
            hi = hi + c * Rat::int(b.hi);
        } else {
            lo = lo + c * Rat::int(b.hi);
            hi = hi + c * Rat::int(b.lo);
        }
    }
    Some(Interval {
        lo: lo.floor(),
        hi: hi.floor(),
    })
}

fn compare(
    candidate: &Kernel,
    env: &BTreeMap<String, i128>,
    b: &Summary,
    c: &Summary,
    seen: &mut BTreeSet<String>,
    diags: &mut Vec<Diagnostic>,
) {
    let at = super::fmt_env(env);
    let mut push = |key: String, object: Option<&str>, message: String| {
        if seen.insert(key) {
            diags.push(changed(candidate, object, message));
        }
    };

    for (arr, (bn, bh)) in &b.writes {
        match c.writes.get(arr) {
            None => push(
                format!("write-set:{arr}"),
                Some(arr),
                format!(
                    "global array '{arr}' is written by the baseline but \
                     not by the candidate"
                ),
            ),
            Some((cn, ch)) => {
                if cn != bn {
                    push(
                        format!("write-count:{arr}"),
                        Some(arr),
                        format!(
                            "candidate writes '{arr}' {cn} time(s) vs \
                             baseline {bn} at {at}: iterations were \
                             dropped or duplicated"
                        ),
                    );
                }
                if ch != bh {
                    push(
                        format!("write-hull:{arr}"),
                        Some(arr),
                        format!(
                            "candidate write footprint of '{arr}' spans \
                             [{}, {}] vs baseline [{}, {}] at {at}",
                            ch.lo, ch.hi, bh.lo, bh.hi
                        ),
                    );
                }
            }
        }
    }
    for arr in c.writes.keys() {
        if !b.writes.contains_key(arr) {
            push(
                format!("write-set:{arr}"),
                Some(arr),
                format!(
                    "global array '{arr}' is written by the candidate but \
                     not by the baseline"
                ),
            );
        }
    }

    for (arr, bh) in &b.reads {
        match c.reads.get(arr) {
            None => push(
                format!("read-set:{arr}"),
                Some(arr),
                format!(
                    "global array '{arr}' is read by the baseline but not \
                     by the candidate"
                ),
            ),
            Some(ch) => {
                if ch.lo > bh.lo || ch.hi < bh.hi {
                    push(
                        format!("read-hull:{arr}"),
                        Some(arr),
                        format!(
                            "candidate read footprint of '{arr}' spans \
                             [{}, {}], not covering baseline [{}, {}] at \
                             {at}: part of the input was dropped",
                            ch.lo, ch.hi, bh.lo, bh.hi
                        ),
                    );
                }
            }
        }
    }
    for arr in c.reads.keys() {
        if !b.reads.contains_key(arr) {
            push(
                format!("read-set:{arr}"),
                Some(arr),
                format!(
                    "global array '{arr}' is read by the candidate but not \
                     by the baseline"
                ),
            );
        }
    }

    if b.ops != c.ops {
        let fmt = |m: &BTreeMap<&'static str, i128>| {
            if m.is_empty() {
                "none".to_string()
            } else {
                m.iter()
                    .map(|(k, v)| format!("{k}={v}"))
                    .collect::<Vec<_>>()
                    .join(", ")
            }
        };
        push(
            "op-volume".to_string(),
            None,
            format!(
                "candidate op volume ({}) differs from baseline ({}) at {at}",
                fmt(&c.ops),
                fmt(&b.ops)
            ),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{Access, AffExpr, ArrayDecl, DType, Expr};
    use crate::polyhedral::{LoopExtent, NestedDomain, QPoly};

    /// `res[i] = u[i] + u[i+1]` over `i in [0, n)`.
    fn stencil_base() -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
        let mut k = Kernel::new("stencil_base", &["n"], dom);
        k.add_array(ArrayDecl::global(
            "u",
            DType::F32,
            vec![&n + &QPoly::one()],
        ));
        k.add_array(ArrayDecl::global("res", DType::F32, vec![n]));
        k.add_stmt(Stmt::new(
            "comp",
            LhsRef::Array(Access::new("res", vec![AffExpr::var("i")])),
            Expr::add(
                Expr::load(Access::new("u", vec![AffExpr::var("i")])),
                Expr::load(Access::new(
                    "u",
                    vec![AffExpr::var("i").plus_cst(1)],
                )),
            ),
            &["i"],
        ));
        k
    }

    #[test]
    fn identical_kernels_are_equivalent() {
        let k = stencil_base();
        assert!(check_equiv(&k, &k).is_empty());
    }

    #[test]
    fn parameter_set_mismatch_is_flagged() {
        let b = stencil_base();
        let mut c = stencil_base();
        c.params.push("m".to_string());
        let diags = check_equiv(&b, &c);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].code, DiagCode::SemanticsChanged);
        assert!(diags[0].message.contains("parameter set"));
    }
}
