//! Static kernel verifier: a polyhedral analysis pass that proves a
//! kernel race-free, in-bounds, and barrier-correct *before* it is
//! counted, measured, or autotuned.
//!
//! The paper's pipeline trusts every kernel it counts: transform
//! chains (`split_iname`, `add_prefetch`, `remove_work`) are assumed
//! to produce valid GPU programs, and an invalid variant silently
//! yields a plausible-looking model.  This module reuses the existing
//! polyhedral machinery ([`NestedDomain`](crate::polyhedral::NestedDomain)
//! bounds, [`QPoly`] evaluation, [`Assumptions`](crate::polyhedral::Assumptions)
//! sample points, [`AffExpr`](crate::ir::AffExpr) subscripts) to check,
//! per kernel — symbolically, without executing anything:
//!
//! 1. **Write-race freedom** ([`DiagCode::RaceWrite`]) — every
//!    assignment to shared memory must cover all parallel axes of the
//!    launch grid in its subscripts, and must do so *injectively*: no
//!    two work-items may write the same flattened location.
//! 2. **Bounds safety** ([`DiagCode::OobAccess`]) — each access's
//!    symbolic index interval, under the kernel's assumptions, stays
//!    inside the declared [`ArrayDecl`](crate::ir::ArrayDecl) shape.
//! 3. **Barrier / scope correctness** ([`DiagCode::MissingBarrier`],
//!    [`DiagCode::DivergentBarrier`], [`DiagCode::ScopeMisuse`]) —
//!    cross-work-item reads of local memory must be ordered after a
//!    write (so the scheduler can place a barrier between them),
//!    barriers must not sit under local-iname-dependent loop bounds
//!    (work-items would diverge on barrier arrival), and
//!    `Private`/`Local` arrays must not be subscripted inconsistently
//!    with their scope.
//! 4. **Hygiene lints** ([`DiagCode::UnusedIname`],
//!    [`DiagCode::DeadArray`], [`DiagCode::UnprovableGuard`]) —
//!    warnings for loops that drive nothing, declared-but-unaccessed
//!    arrays, and loop bounds whose `floor` guards the assumptions
//!    could not discharge.
//! 5. **Access-pattern lints** ([`access`]:
//!    [`DiagCode::UncoalescedGlobal`], [`DiagCode::BankConflict`]) —
//!    warnings for global accesses whose lid(0) stride costs more
//!    memory transactions per sub-group than a contiguous access, and
//!    local accesses that serialize across local-memory banks.
//!
//! Two sibling passes extend correctness checking into *pruning*:
//!
//! 5. **Resource feasibility** ([`resources`]) — a symbolic per-kernel
//!    resource model (work-group size, local-memory bytes as a
//!    [`QPoly`] over the tiles `add_prefetch` materializes, private
//!    pressure, barrier count) checked against a
//!    [`DeviceProfile`](crate::gpusim::DeviceProfile), yielding
//!    [`DiagCode::WgSizeExceeded`], [`DiagCode::ExcessiveLocalMem`]
//!    and the [`DiagCode::LowOccupancy`] warning.
//! 6. **Transform equivalence** ([`equiv`]) — proves a transformed
//!    kernel still computes what its baseline computes (per-array
//!    write counts and footprints, read footprints, op volume at
//!    sampled sizes), yielding [`DiagCode::SemanticsChanged`].
//!
//! The entry point is [`Analyzer::check`]; [`verify`] is the
//! gate-shaped wrapper (a typed [`AnalysisError`] on any
//! Error-severity diagnostic) that `transform`/`uipick` tests call,
//! and [`admissible`] is the complete pruning predicate — correctness
//! + equivalence + feasibility — the autotune loop (ROADMAP item 3)
//! applies per candidate before pricing it with the compiled
//! evaluator.  `perflex lint [--device <id>|--all-devices]` exposes
//! the same passes on the CLI.
//!
//! Every check degrades gracefully: a kernel that fails
//! [`Kernel::validate`] or has structurally broken accesses gets a
//! single [`DiagCode::MalformedKernel`] diagnostic instead of a panic
//! (the hostile-input direction of ROADMAP item 5).

pub mod access;
pub mod equiv;
pub mod resources;

pub use access::{AccessPattern, AccessReport};
pub use equiv::check_equiv;
pub use resources::{check_feasibility, Feasibility, ResourceUsage};

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

use crate::gpusim::DeviceProfile;
use crate::ir::{Access, IndexTag, Kernel, LhsRef, MemScope};
use crate::polyhedral::qpoly::Atom;
use crate::polyhedral::{Assumptions, QPoly};
use crate::schedule::{self, ScheduleItem};
use crate::util::json::Json;
use crate::util::Rat;

/// How bad a diagnostic is.  `Error` means the kernel must not be
/// counted, measured, or autotuned; `Warn` is advisory hygiene.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warn,
    Error,
}

impl Severity {
    pub fn as_str(&self) -> &'static str {
        match self {
            Severity::Warn => "warn",
            Severity::Error => "error",
        }
    }
}

/// Stable diagnostic codes.  The string forms (`RACE_WRITE`, …) are a
/// public contract: CI and downstream tooling match on them, so they
/// must never be renamed, only added to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum DiagCode {
    /// Two work-items can write the same memory location.
    RaceWrite,
    /// An access index can fall outside the declared array shape.
    OobAccess,
    /// A cross-work-item local read is not ordered after any write, so
    /// no barrier can be (or is) placed between them.
    MissingBarrier,
    /// A barrier sits under a loop whose trip count depends on a local
    /// iname: work-items would diverge on barrier arrival.
    DivergentBarrier,
    /// A `Private`/`Local` array is subscripted inconsistently with
    /// its scope (private memory indexed by a parallel iname, local
    /// memory indexed by a group iname).
    ScopeMisuse,
    /// A sequential loop that drives no statement and no subscript.
    UnusedIname,
    /// An array that is declared but never loaded or stored.
    DeadArray,
    /// A loop bound still contains a `floor` atom the kernel's
    /// assumptions could not discharge.
    UnprovableGuard,
    /// The kernel failed structural validation; no further checks ran.
    MalformedKernel,
    /// The kernel's work-group size exceeds the device's
    /// `max_wg_size`: the launch would be rejected.
    WgSizeExceeded,
    /// The kernel's per-work-group local-memory footprint exceeds the
    /// device's `local_mem_bytes_per_sm`: not even one work-group fits.
    ExcessiveLocalMem,
    /// The local-memory footprint caps resident work-groups per SM
    /// below the device's nominal `wgs_per_sm` (advisory: the kernel
    /// runs, but latency hiding degrades).
    LowOccupancy,
    /// A transform chain altered what the kernel computes relative to
    /// its baseline (write set/count/footprint, read footprint, or op
    /// volume differs at a sampled size).
    SemanticsChanged,
    /// A global access's lid(0) stride makes each sub-group access pay
    /// more memory transactions than a contiguous access would
    /// (advisory: the kernel is correct, but global bandwidth is
    /// wasted).  See [`access`].
    UncoalescedGlobal,
    /// A local access's lid(0) stride serializes across local-memory
    /// banks (advisory: on-chip throughput degrades by the conflict
    /// multiplier).  See [`access`].
    BankConflict,
}

impl DiagCode {
    /// The stable wire/string form of the code.
    pub fn as_str(&self) -> &'static str {
        match self {
            DiagCode::RaceWrite => "RACE_WRITE",
            DiagCode::OobAccess => "OOB_ACCESS",
            DiagCode::MissingBarrier => "MISSING_BARRIER",
            DiagCode::DivergentBarrier => "DIVERGENT_BARRIER",
            DiagCode::ScopeMisuse => "SCOPE_MISUSE",
            DiagCode::UnusedIname => "UNUSED_INAME",
            DiagCode::DeadArray => "DEAD_ARRAY",
            DiagCode::UnprovableGuard => "UNPROVABLE_GUARD",
            DiagCode::MalformedKernel => "MALFORMED_KERNEL",
            DiagCode::WgSizeExceeded => "WG_SIZE_EXCEEDED",
            DiagCode::ExcessiveLocalMem => "EXCESSIVE_LOCAL_MEM",
            DiagCode::LowOccupancy => "LOW_OCCUPANCY",
            DiagCode::SemanticsChanged => "SEMANTICS_CHANGED",
            DiagCode::UncoalescedGlobal => "UNCOALESCED_GLOBAL",
            DiagCode::BankConflict => "BANK_CONFLICT",
        }
    }

    pub fn severity(&self) -> Severity {
        match self {
            DiagCode::RaceWrite
            | DiagCode::OobAccess
            | DiagCode::MissingBarrier
            | DiagCode::DivergentBarrier
            | DiagCode::ScopeMisuse
            | DiagCode::MalformedKernel
            | DiagCode::WgSizeExceeded
            | DiagCode::ExcessiveLocalMem
            | DiagCode::SemanticsChanged => Severity::Error,
            DiagCode::UnusedIname
            | DiagCode::DeadArray
            | DiagCode::UnprovableGuard
            | DiagCode::LowOccupancy
            | DiagCode::UncoalescedGlobal
            | DiagCode::BankConflict => Severity::Warn,
        }
    }

    /// All codes, for catalogs and exhaustiveness tests.
    pub fn all() -> &'static [DiagCode] {
        &[
            DiagCode::RaceWrite,
            DiagCode::OobAccess,
            DiagCode::MissingBarrier,
            DiagCode::DivergentBarrier,
            DiagCode::ScopeMisuse,
            DiagCode::UnusedIname,
            DiagCode::DeadArray,
            DiagCode::UnprovableGuard,
            DiagCode::MalformedKernel,
            DiagCode::WgSizeExceeded,
            DiagCode::ExcessiveLocalMem,
            DiagCode::LowOccupancy,
            DiagCode::SemanticsChanged,
            DiagCode::UncoalescedGlobal,
            DiagCode::BankConflict,
        ]
    }
}

/// One finding of the verifier.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Diagnostic {
    pub code: DiagCode,
    /// Kernel the finding is about.
    pub kernel: String,
    /// Statement id, when the finding anchors to one.
    pub stmt: Option<String>,
    /// Array or iname the finding anchors to, when applicable.
    pub object: Option<String>,
    pub message: String,
}

impl Diagnostic {
    pub fn severity(&self) -> Severity {
        self.code.severity()
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("code", self.code.as_str().into()),
            ("severity", self.severity().as_str().into()),
            (
                "stmt",
                match &self.stmt {
                    Some(s) => s.as_str().into(),
                    None => Json::Null,
                },
            ),
            (
                "object",
                match &self.object {
                    Some(s) => s.as_str().into(),
                    None => Json::Null,
                },
            ),
            ("message", self.message.as_str().into()),
        ])
    }
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.severity().as_str(), self.code.as_str())?;
        if let Some(s) = &self.stmt {
            write!(f, " stmt '{s}'")?;
        }
        if let Some(o) = &self.object {
            write!(f, " '{o}'")?;
        }
        write!(f, ": {}", self.message)
    }
}

/// Count of Error-severity diagnostics in a report.
pub fn error_count(diags: &[Diagnostic]) -> usize {
    diags
        .iter()
        .filter(|d| d.severity() == Severity::Error)
        .count()
}

/// Why [`verify`] rejected a kernel.  Callers (the lint CLI's exit
/// codes, the autotune loop) distinguish a *malformed* kernel — the
/// input never was a valid GPU program, nothing else was checked —
/// from a well-formed kernel the checks found defects in.
#[derive(Clone, Debug)]
pub enum AnalysisError {
    /// Structural validation failed; carries the single
    /// [`DiagCode::MalformedKernel`] diagnostic.
    Malformed {
        kernel: String,
        diagnostic: Diagnostic,
    },
    /// The kernel is well-formed but at least one check found an
    /// Error-severity defect; carries the full report.
    Rejected {
        kernel: String,
        diagnostics: Vec<Diagnostic>,
    },
}

impl AnalysisError {
    pub fn kernel(&self) -> &str {
        match self {
            AnalysisError::Malformed { kernel, .. }
            | AnalysisError::Rejected { kernel, .. } => kernel,
        }
    }

    /// Every diagnostic behind the rejection.
    pub fn diagnostics(&self) -> &[Diagnostic] {
        match self {
            AnalysisError::Malformed { diagnostic, .. } => {
                std::slice::from_ref(diagnostic)
            }
            AnalysisError::Rejected { diagnostics, .. } => diagnostics,
        }
    }
}

impl fmt::Display for AnalysisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AnalysisError::Malformed { kernel, diagnostic } => {
                write!(f, "kernel '{kernel}' is malformed: {diagnostic}")
            }
            AnalysisError::Rejected {
                kernel,
                diagnostics,
            } => {
                let errors: Vec<&Diagnostic> = diagnostics
                    .iter()
                    .filter(|d| d.severity() == Severity::Error)
                    .collect();
                write!(
                    f,
                    "kernel '{kernel}' failed static verification \
                     ({} error(s)):",
                    errors.len()
                )?;
                for e in errors {
                    write!(f, "\n  {e}")?;
                }
                Ok(())
            }
        }
    }
}

impl std::error::Error for AnalysisError {}

/// Gate form: a typed [`AnalysisError`] carrying every finding, `Ok`
/// when the kernel is provably race-free, in-bounds, and
/// barrier-correct (warnings do not fail the gate).
pub fn verify(knl: &Kernel) -> Result<Vec<Diagnostic>, AnalysisError> {
    let diags = Analyzer::new().check(knl);
    if let Some(d) =
        diags.iter().find(|d| d.code == DiagCode::MalformedKernel)
    {
        return Err(AnalysisError::Malformed {
            kernel: knl.name.clone(),
            diagnostic: d.clone(),
        });
    }
    if error_count(&diags) == 0 {
        return Ok(diags);
    }
    Err(AnalysisError::Rejected {
        kernel: knl.name.clone(),
        diagnostics: diags,
    })
}

/// The complete autotune pruning predicate (ROADMAP item 3): is
/// `candidate` — a transform-chain variant of `baseline` — correct,
/// equivalent to the baseline, and launchable on `device`?  Runs
/// [`Analyzer::check`], [`equiv::check_equiv`], and
/// [`resources::check_feasibility`], and returns every Error-severity
/// finding; `Ok` carries the candidate's [`AccessReport`] under the
/// device's geometry, so when the enumeration loop prices the
/// candidate with the compiled evaluator it can also *explain* a cost
/// regression (an admissible candidate may still pay 32x the memory
/// transactions of its baseline).
pub fn admissible(
    baseline: &Kernel,
    candidate: &Kernel,
    device: &DeviceProfile,
) -> Result<AccessReport, Vec<Diagnostic>> {
    let mut diags = Analyzer::new().check(candidate);
    // A malformed candidate already carries its one gating diagnostic;
    // the sibling passes would only re-derive it.
    if !diags.iter().any(|d| d.code == DiagCode::MalformedKernel) {
        diags.extend(equiv::check_equiv(baseline, candidate));
        match resources::check_feasibility(candidate, device) {
            Ok(f) => diags.extend(f.diags),
            Err(d) => diags.push(d),
        }
    }
    let errors: Vec<Diagnostic> = diags
        .into_iter()
        .filter(|d| d.severity() == Severity::Error)
        .collect();
    if errors.is_empty() {
        access::report(candidate, device).map_err(|d| vec![d])
    } else {
        Err(errors)
    }
}

/// The static verifier.  Stateless; `new()` + [`check`](Analyzer::check).
#[derive(Default)]
pub struct Analyzer;

/// Interval of integer values an iname (or index expression) can take
/// at one sample point.  `lo > hi` encodes an empty loop.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
struct Interval {
    lo: i128,
    hi: i128,
}

impl Interval {
    fn extent(&self) -> i128 {
        (self.hi - self.lo + 1).max(0)
    }
}

impl Analyzer {
    pub fn new() -> Analyzer {
        Analyzer
    }

    /// Run every check and return all findings (deterministic order:
    /// structural gate, then per-statement checks in statement order,
    /// then kernel-wide checks).
    pub fn check(&self, knl: &Kernel) -> Vec<Diagnostic> {
        let mut diags = Vec::new();

        // Structural gate: validate() plus the access-shape invariants
        // flatten_access() would otherwise assert on.  A malformed
        // kernel gets exactly one diagnostic and no further analysis.
        if let Some(d) = self.structural_gate(knl) {
            return vec![d];
        }

        let envs = sample_envs(knl);
        self.check_races(knl, &envs, &mut diags);
        self.check_bounds(knl, &envs, &mut diags);
        self.check_scopes(knl, &mut diags);
        self.check_missing_barriers(knl, &mut diags);
        self.check_divergent_barriers(knl, &mut diags);
        self.check_unused_inames(knl, &mut diags);
        self.check_dead_arrays(knl, &mut diags);
        self.check_unprovable_guards(knl, &mut diags);
        access::check_access_patterns(
            knl,
            &envs,
            &access::Geometry::device_independent(),
            &mut diags,
        );
        diags
    }

    fn malformed(&self, knl: &Kernel, message: String) -> Diagnostic {
        Diagnostic {
            code: DiagCode::MalformedKernel,
            kernel: knl.name.clone(),
            stmt: None,
            object: None,
            message,
        }
    }

    fn structural_gate(&self, knl: &Kernel) -> Option<Diagnostic> {
        if let Err(e) = knl.validate() {
            return Some(self.malformed(knl, e));
        }
        // validate() does not check access rank; flatten_access()
        // asserts on it, so the analyzer must pre-check.
        for s in &knl.stmts {
            for acc in accesses_of(s) {
                let decl = match knl.arrays.get(&acc.array) {
                    Some(d) => d,
                    None => {
                        return Some(self.malformed(
                            knl,
                            format!(
                                "stmt '{}' accesses undeclared array '{}'",
                                s.id, acc.array
                            ),
                        ))
                    }
                };
                if decl.shape.len() != acc.indices.len() {
                    return Some(self.malformed(
                        knl,
                        format!(
                            "stmt '{}' accesses '{}' with {} subscript(s), \
                             declared rank {}",
                            s.id,
                            acc.array,
                            acc.indices.len(),
                            decl.shape.len()
                        ),
                    ));
                }
            }
        }
        None
    }

    /// Check 1: write-race freedom.  For every store to shared memory
    /// (`Global`: shared across the grid; `Local`: shared across the
    /// work-group), the subscripts must (a) *cover* every relevant
    /// parallel axis — some iname on that axis appears with a nonzero
    /// coefficient — and (b) be *injective* over the relevant parallel
    /// inames: sorting the flattened strides ascending, each parallel
    /// stride must exceed the combined span of everything below it, so
    /// distinct work-items always land on distinct locations.
    fn check_races(
        &self,
        knl: &Kernel,
        envs: &[BTreeMap<String, i128>],
        diags: &mut Vec<Diagnostic>,
    ) {
        for s in &knl.stmts {
            let acc = match &s.lhs {
                LhsRef::Array(a) => a,
                // Temporaries are per-work-item registers: no race.
                LhsRef::Temp(_) => continue,
            };
            let scope = knl.arrays[&acc.array].scope;
            if scope == MemScope::Private {
                continue; // per-work-item storage: no race possible
            }
            // Group axes are only shared for Global arrays; each
            // work-group has its own copy of a Local array.
            let relevant = |tag: IndexTag| match tag {
                IndexTag::Local(_) => true,
                IndexTag::Group(_) => scope == MemScope::Global,
                _ => false,
            };

            let lf = knl.flatten_access(acc);
            'env: for env in envs {
                let boxes = match iname_boxes(knl, env) {
                    Ok(b) => b,
                    Err(_) => continue,
                };
                // (a) coverage of every relevant, non-trivial axis.
                let mut axes: BTreeMap<(u8, u8), (i128, bool)> = BTreeMap::new();
                for l in &knl.domain.loops {
                    let key = match knl.tag(&l.var) {
                        IndexTag::Group(a) if relevant(IndexTag::Group(a)) => {
                            (0u8, a)
                        }
                        IndexTag::Local(a) => (1u8, a),
                        _ => continue,
                    };
                    let ext = boxes.get(&l.var).map(|b| b.extent()).unwrap_or(1);
                    let covered = acc
                        .indices
                        .iter()
                        .any(|ix| ix.coeff(&l.var) != 0);
                    let e = axes.entry(key).or_insert((1, false));
                    e.0 = e.0.max(ext);
                    e.1 |= covered;
                }
                for ((kind, axis), (ext, covered)) in &axes {
                    if *ext > 1 && !*covered {
                        let axis_name =
                            format!("{}.{axis}", if *kind == 0 { "g" } else { "l" });
                        diags.push(Diagnostic {
                            code: DiagCode::RaceWrite,
                            kernel: knl.name.clone(),
                            stmt: Some(s.id.clone()),
                            object: Some(acc.array.clone()),
                            message: format!(
                                "store to '{}' does not use parallel axis \
                                 {axis_name}: all work-items along it write \
                                 the same location",
                                acc.array
                            ),
                        });
                        break 'env;
                    }
                }
                // (b) injectivity over the relevant parallel inames.
                let mut entries: Vec<(String, Rat, i128, bool)> = Vec::new();
                let mut ok = true;
                for (var, c) in &lf.coeffs {
                    let cv = match c.try_eval(env) {
                        Ok(v) => v,
                        Err(_) => {
                            ok = false;
                            break;
                        }
                    };
                    if cv.is_zero() {
                        continue;
                    }
                    let ext = match boxes.get(var) {
                        Some(b) => b.extent(),
                        None => 1, // parameter: a single value per launch
                    };
                    if ext <= 1 {
                        continue;
                    }
                    entries.push((
                        var.clone(),
                        cv.abs(),
                        ext,
                        relevant(knl.tag(var)),
                    ));
                }
                if !ok {
                    continue;
                }
                entries.sort_by(|a, b| a.1.cmp(&b.1).then(a.0.cmp(&b.0)));
                let mut span = Rat::int(0);
                for (var, c, ext, is_parallel) in &entries {
                    if *is_parallel && *c < span + Rat::int(1) {
                        diags.push(Diagnostic {
                            code: DiagCode::RaceWrite,
                            kernel: knl.name.clone(),
                            stmt: Some(s.id.clone()),
                            object: Some(acc.array.clone()),
                            message: format!(
                                "store to '{}' is not injective over parallel \
                                 iname '{var}': stride {c} overlaps the \
                                 {span}-wide span of lower-stride subscripts",
                                acc.array
                            ),
                        });
                        break 'env;
                    }
                    span = span + *c * Rat::int(*ext - 1);
                }
            }
        }
    }

    /// Check 2: bounds safety.  Each subscript's interval — propagated
    /// from the loop bounds at assumption-derived sample sizes — must
    /// stay inside `[0, shape_d)`.
    fn check_bounds(
        &self,
        knl: &Kernel,
        envs: &[BTreeMap<String, i128>],
        diags: &mut Vec<Diagnostic>,
    ) {
        let mut flagged: BTreeSet<(String, String)> = BTreeSet::new();
        for s in &knl.stmts {
            for acc in accesses_of(s) {
                if flagged.contains(&(s.id.clone(), acc.array.clone())) {
                    continue;
                }
                let decl = &knl.arrays[&acc.array];
                'env: for env in envs {
                    let boxes = match iname_boxes(knl, env) {
                        Ok(b) => b,
                        Err(_) => continue,
                    };
                    for (d, ix) in acc.indices.iter().enumerate() {
                        let iv = match affine_interval(ix, env, &boxes) {
                            Ok(iv) => iv,
                            Err(_) => continue,
                        };
                        if iv.lo > iv.hi {
                            continue; // empty loop: access never executes
                        }
                        let dim = match decl.shape[d].try_eval(env) {
                            Ok(v) => v,
                            Err(_) => continue,
                        };
                        if iv.lo < 0 || Rat::int(iv.hi) >= dim {
                            flagged.insert((s.id.clone(), acc.array.clone()));
                            diags.push(Diagnostic {
                                code: DiagCode::OobAccess,
                                kernel: knl.name.clone(),
                                stmt: Some(s.id.clone()),
                                object: Some(acc.array.clone()),
                                message: format!(
                                    "subscript {d} of '{}' spans [{}, {}] but \
                                     the axis has {} entries at {}",
                                    acc.array,
                                    iv.lo,
                                    iv.hi,
                                    dim,
                                    fmt_env(env),
                                ),
                            });
                            break 'env;
                        }
                    }
                }
            }
        }
    }

    /// Check 3a: scope consistency.  Private memory is per-work-item,
    /// so subscripting it by a parallel iname is a scope violation
    /// (each work-item only ever sees its own copy); local memory is
    /// per-work-group, so a group iname in a local subscript addresses
    /// storage that does not vary with the group.
    fn check_scopes(&self, knl: &Kernel, diags: &mut Vec<Diagnostic>) {
        let mut flagged: BTreeSet<(String, String)> = BTreeSet::new();
        for s in &knl.stmts {
            for acc in accesses_of(s) {
                let scope = knl.arrays[&acc.array].scope;
                for ix in &acc.indices {
                    for var in ix.vars() {
                        if ix.coeff(var) == 0 {
                            continue;
                        }
                        let bad = match (scope, knl.tag(var)) {
                            (MemScope::Private, t) if t.is_parallel() => Some(
                                format!(
                                    "private array '{}' subscripted by \
                                     parallel iname '{var}' — each work-item \
                                     only sees its own copy",
                                    acc.array
                                ),
                            ),
                            (MemScope::Local, IndexTag::Group(_)) => Some(
                                format!(
                                    "local array '{}' subscripted by group \
                                     iname '{var}' — local memory does not \
                                     vary with the work-group",
                                    acc.array
                                ),
                            ),
                            _ => None,
                        };
                        if let Some(message) = bad {
                            if flagged.insert((s.id.clone(), acc.array.clone()))
                            {
                                diags.push(Diagnostic {
                                    code: DiagCode::ScopeMisuse,
                                    kernel: knl.name.clone(),
                                    stmt: Some(s.id.clone()),
                                    object: Some(acc.array.clone()),
                                    message,
                                });
                            }
                        }
                    }
                }
            }
        }
    }

    /// Check 3b: missing barriers.  The scheduler places barriers
    /// between ordered writes and reads of *communicating* local
    /// arrays (accessed with more than one parallel-coefficient
    /// signature, i.e. data actually crosses work-items).  That
    /// ordering comes from statement dependencies: a cross-item read
    /// with no dependency path back to a writer may be scheduled
    /// before the write, and no barrier can fix an unordered pair.
    fn check_missing_barriers(&self, knl: &Kernel, diags: &mut Vec<Diagnostic>) {
        let communicating = schedule::communicating_local_arrays(knl);
        if communicating.is_empty() {
            return;
        }
        // Transitive dependency closure, statement id -> reachable ids.
        let idx: BTreeMap<&str, usize> = knl
            .stmts
            .iter()
            .enumerate()
            .map(|(i, s)| (s.id.as_str(), i))
            .collect();
        let mut reach: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); knl.stmts.len()];
        for (i, s) in knl.stmts.iter().enumerate() {
            let mut stack: Vec<usize> = s
                .deps
                .iter()
                .filter_map(|d| idx.get(d.as_str()).copied())
                .collect();
            while let Some(j) = stack.pop() {
                if reach[i].insert(j) {
                    stack.extend(
                        knl.stmts[j]
                            .deps
                            .iter()
                            .filter_map(|d| idx.get(d.as_str()).copied()),
                    );
                }
            }
        }
        for (i, s) in knl.stmts.iter().enumerate() {
            for l in s.rhs.loads() {
                if !communicating.contains(&l.array) {
                    continue;
                }
                let ordered_after_write = reach[i].iter().any(|&j| {
                    matches!(&knl.stmts[j].lhs,
                             LhsRef::Array(a) if a.array == l.array)
                });
                if !ordered_after_write {
                    diags.push(Diagnostic {
                        code: DiagCode::MissingBarrier,
                        kernel: knl.name.clone(),
                        stmt: Some(s.id.clone()),
                        object: Some(l.array.clone()),
                        message: format!(
                            "cross-work-item read of local array '{}' has no \
                             dependency on any statement writing it, so no \
                             barrier separates the exchange",
                            l.array
                        ),
                    });
                }
            }
        }
    }

    /// Check 3c: divergent barriers.  Linearize the kernel and verify
    /// no barrier sits inside a loop whose bounds depend (transitively)
    /// on a local iname — such a loop has a per-work-item trip count,
    /// and work-items would reach the barrier different numbers of
    /// times.
    fn check_divergent_barriers(&self, knl: &Kernel, diags: &mut Vec<Diagnostic>) {
        let sched = match schedule::linearize(knl) {
            Ok(s) => s,
            Err(e) => {
                diags.push(self.malformed(knl, format!("unschedulable: {e}")));
                return;
            }
        };
        // Inames whose bounds depend on a local iname, transitively.
        let mut tainted: BTreeSet<String> = knl
            .domain
            .loops
            .iter()
            .filter(|l| matches!(knl.tag(&l.var), IndexTag::Local(_)))
            .map(|l| l.var.clone())
            .collect();
        loop {
            let mut grew = false;
            for l in &knl.domain.loops {
                if tainted.contains(&l.var) {
                    continue;
                }
                if tainted
                    .iter()
                    .any(|t| l.lo.mentions(t) || l.hi.mentions(t))
                {
                    tainted.insert(l.var.clone());
                    grew = true;
                }
            }
            if !grew {
                break;
            }
        }

        fn walk(
            knl: &Kernel,
            items: &[ScheduleItem],
            divergent_loop: Option<&str>,
            tainted: &BTreeSet<String>,
            diags: &mut Vec<Diagnostic>,
        ) {
            for item in items {
                match item {
                    ScheduleItem::Barrier => {
                        if let Some(iname) = divergent_loop {
                            let d = Diagnostic {
                                code: DiagCode::DivergentBarrier,
                                kernel: knl.name.clone(),
                                stmt: None,
                                object: Some(iname.to_string()),
                                message: format!(
                                    "barrier under loop '{iname}' whose trip \
                                     count depends on a local iname: \
                                     work-items diverge on barrier arrival"
                                ),
                            };
                            if !diags.contains(&d) {
                                diags.push(d);
                            }
                        }
                    }
                    ScheduleItem::Stmt(_) => {}
                    ScheduleItem::Loop { iname, body } => {
                        let inner = if tainted.contains(iname) {
                            Some(iname.as_str())
                        } else {
                            divergent_loop
                        };
                        walk(knl, body, inner, tainted, diags);
                    }
                }
            }
        }
        walk(knl, &sched.items, None, &tainted, diags);
    }

    /// Check 4a: unused inames.  A sequential loop no statement nests
    /// in, no subscript reads, and no other bound references is dead
    /// weight (parallel inames define the launch grid even when only
    /// subscripts use them, so they are exempt).
    fn check_unused_inames(&self, knl: &Kernel, diags: &mut Vec<Diagnostic>) {
        for l in &knl.domain.loops {
            if knl.tag(&l.var).is_parallel() {
                continue;
            }
            let in_within = knl.stmts.iter().any(|s| s.within.contains(&l.var));
            let in_subscript = knl.stmts.iter().any(|s| {
                accesses_of(s).iter().any(|a| {
                    a.indices.iter().any(|ix| ix.coeff(&l.var) != 0)
                })
            });
            let in_bounds = knl.domain.loops.iter().any(|o| {
                o.var != l.var
                    && (o.lo.mentions(&l.var) || o.hi.mentions(&l.var))
            });
            if !in_within && !in_subscript && !in_bounds {
                diags.push(Diagnostic {
                    code: DiagCode::UnusedIname,
                    kernel: knl.name.clone(),
                    stmt: None,
                    object: Some(l.var.clone()),
                    message: format!(
                        "sequential iname '{}' drives no statement, subscript, \
                         or bound",
                        l.var
                    ),
                });
            }
        }
    }

    /// Check 4b: dead arrays — declared but never loaded or stored.
    fn check_dead_arrays(&self, knl: &Kernel, diags: &mut Vec<Diagnostic>) {
        let mut used: BTreeSet<&str> = BTreeSet::new();
        for s in &knl.stmts {
            for acc in accesses_of(s) {
                used.insert(acc.array.as_str());
            }
        }
        for name in knl.arrays.keys() {
            if !used.contains(name.as_str()) {
                diags.push(Diagnostic {
                    code: DiagCode::DeadArray,
                    kernel: knl.name.clone(),
                    stmt: None,
                    object: Some(name.clone()),
                    message: format!("array '{name}' is never accessed"),
                });
            }
        }
    }

    /// Check 4c: unprovable guards.  A surviving `floor` atom in a
    /// loop bound means the assumptions did not discharge a split or
    /// tiling guard; counting and scheduling treat the bound as exact,
    /// so the variant's model may not match its real iteration space.
    fn check_unprovable_guards(&self, knl: &Kernel, diags: &mut Vec<Diagnostic>) {
        for l in &knl.domain.loops {
            if has_floor(&l.lo) || has_floor(&l.hi) {
                diags.push(Diagnostic {
                    code: DiagCode::UnprovableGuard,
                    kernel: knl.name.clone(),
                    stmt: None,
                    object: Some(l.var.clone()),
                    message: format!(
                        "bounds of '{}' contain a floor() the assumptions \
                         cannot discharge; add a divisibility assumption or \
                         pad the domain",
                        l.var
                    ),
                });
            }
        }
    }
}

/// Every array access of a statement (the store target plus all loads).
fn accesses_of(s: &crate::ir::Stmt) -> Vec<&Access> {
    let mut out = Vec::new();
    if let LhsRef::Array(a) = &s.lhs {
        out.push(a);
    }
    out.extend(s.rhs.loads());
    out
}

/// Does the polynomial contain any `floor` atom?
fn has_floor(q: &QPoly) -> bool {
    q.terms().any(|(m, _)| {
        m.0.iter().any(|(a, _)| matches!(a, Atom::Floor { .. }))
    })
}

/// Sample problem sizes derived from the kernel's assumptions: the
/// smallest size satisfying every divisibility/minimum constraint, and
/// twice that, so size-dependent violations show up at both a corner
/// and an interior point.  Parameters without constraints default to a
/// small non-degenerate value.
fn sample_envs(knl: &Kernel) -> Vec<BTreeMap<String, i128>> {
    sample_envs_from(&knl.params, &knl.assumptions)
}

/// [`sample_envs`] over an explicit parameter list and assumption set —
/// the equivalence checker samples the *merged* assumptions of a
/// baseline/candidate pair so both kernels are summarized at the same
/// sizes.
fn sample_envs_from(
    params: &[String],
    assumptions: &Assumptions,
) -> Vec<BTreeMap<String, i128>> {
    let mut base: BTreeMap<String, i128> = BTreeMap::new();
    for p in params {
        let k = assumptions.divisible.get(p).copied().unwrap_or(1).max(1);
        let lo = assumptions.min_value.get(p).copied().unwrap_or(0);
        let mut v = lo.max(if k > 1 { k } else { 4 });
        v = v.div_euclid(k) * k + if v % k == 0 { 0 } else { k };
        base.insert(p.clone(), v.max(1));
    }
    let doubled: BTreeMap<String, i128> =
        base.iter().map(|(k, v)| (k.clone(), v * 2)).collect();
    if base == doubled {
        vec![base]
    } else {
        vec![base, doubled]
    }
}

/// Integer interval of every iname at one sample size, propagated in
/// domain order (bounds may reference earlier inames: the interval of
/// such a bound is taken over the corners of the referenced boxes,
/// exact for the affine bounds our transforms produce).
fn iname_boxes(
    knl: &Kernel,
    env: &BTreeMap<String, i128>,
) -> Result<BTreeMap<String, Interval>, String> {
    let mut boxes: BTreeMap<String, Interval> = BTreeMap::new();
    for l in &knl.domain.loops {
        let lo = qpoly_interval(&l.lo, env, &boxes)?;
        let hi = qpoly_interval(&l.hi, env, &boxes)?;
        boxes.insert(l.var.clone(), Interval { lo: lo.lo, hi: hi.hi });
    }
    Ok(boxes)
}

/// Interval of a bound polynomial over the corner points of the boxes
/// of the inames it mentions.
fn qpoly_interval(
    q: &QPoly,
    env: &BTreeMap<String, i128>,
    boxes: &BTreeMap<String, Interval>,
) -> Result<Interval, String> {
    let vars: Vec<&String> =
        boxes.keys().filter(|v| q.mentions(v.as_str())).collect();
    if vars.len() > 12 {
        return Err(format!("bound mentions {} inames", vars.len()));
    }
    let mut lo: Option<Rat> = None;
    let mut hi: Option<Rat> = None;
    for corner in 0..(1u32 << vars.len()) {
        let mut full = env.clone();
        for (bit, v) in vars.iter().enumerate() {
            let b = boxes[v.as_str()];
            full.insert(
                (*v).clone(),
                if corner & (1 << bit) != 0 { b.hi } else { b.lo },
            );
        }
        let v = q.try_eval(&full)?;
        lo = Some(match lo {
            Some(cur) => cur.min(v),
            None => v,
        });
        hi = Some(match hi {
            Some(cur) => cur.max(v),
            None => v,
        });
    }
    let (lo, hi) = (lo.unwrap_or(Rat::int(0)), hi.unwrap_or(Rat::int(0)));
    // Bounds are inclusive integers: round inward.
    Ok(Interval {
        lo: -(-lo).floor(),
        hi: hi.floor(),
    })
}

/// Interval of an affine subscript given iname boxes and parameter
/// values (exact: the expression is linear).
fn affine_interval(
    ix: &crate::ir::AffExpr,
    env: &BTreeMap<String, i128>,
    boxes: &BTreeMap<String, Interval>,
) -> Result<Interval, String> {
    let mut lo = ix.constant as i128;
    let mut hi = ix.constant as i128;
    for var in ix.vars() {
        let c = ix.coeff(var) as i128;
        if c == 0 {
            continue;
        }
        let b = match boxes.get(var) {
            Some(b) => *b,
            None => match env.get(var) {
                Some(v) => Interval { lo: *v, hi: *v },
                None => return Err(format!("unbound subscript var '{var}'")),
            },
        };
        if c > 0 {
            lo += c * b.lo;
            hi += c * b.hi;
        } else {
            lo += c * b.hi;
            hi += c * b.lo;
        }
    }
    Ok(Interval { lo, hi })
}

fn fmt_env(env: &BTreeMap<String, i128>) -> String {
    if env.is_empty() {
        return "{}".to_string();
    }
    let parts: Vec<String> =
        env.iter().map(|(k, v)| format!("{k}={v}")).collect();
    parts.join(", ")
}

/// One kernel's lint result: the verifier report plus per-device
/// feasibility verdicts (empty unless `--device`/`--all-devices`).
pub struct LintEntry {
    pub kernel: String,
    pub generator: String,
    pub diags: Vec<Diagnostic>,
    pub feasibility: Vec<resources::Feasibility>,
}

impl LintEntry {
    /// Every diagnostic of the entry — verifier findings first, then
    /// feasibility findings per device in device order.
    pub fn all_diags(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags
            .iter()
            .chain(self.feasibility.iter().flat_map(|f| f.diags.iter()))
    }
}

/// Render a lint report for a batch of kernels as stable JSON (the
/// `perflex lint --json` payload, asserted in CI).  Schema version 3:
/// version 2 gave each kernel a `feasibility` array (one object per
/// checked device) with the top-level error/warning totals including
/// feasibility findings; version 3 adds the Warn-severity
/// access-pattern codes (`UNCOALESCED_GLOBAL`, `BANK_CONFLICT`) to the
/// diagnostic vocabulary, so the `warnings` total is no longer zero on
/// a healthy inventory.
pub fn report_to_json(entries: &[LintEntry]) -> Json {
    let mut errors = 0i64;
    let mut warnings = 0i64;
    let kernels: Vec<Json> = entries
        .iter()
        .map(|e| {
            for d in e.all_diags() {
                match d.severity() {
                    Severity::Error => errors += 1,
                    Severity::Warn => warnings += 1,
                }
            }
            Json::obj(vec![
                ("kernel", e.kernel.as_str().into()),
                ("generator", e.generator.as_str().into()),
                (
                    "diagnostics",
                    Json::Arr(
                        e.diags.iter().map(Diagnostic::to_json).collect(),
                    ),
                ),
                (
                    "feasibility",
                    Json::Arr(
                        e.feasibility
                            .iter()
                            .map(resources::Feasibility::to_json)
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    Json::obj(vec![
        ("schema", "perflex-lint".into()),
        ("version", 3i64.into()),
        ("kernels", Json::Arr(kernels)),
        ("errors", errors.into()),
        ("warnings", warnings.into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{AffExpr, ArrayDecl, DType, Expr, Stmt};
    use crate::polyhedral::{LoopExtent, NestedDomain};

    fn codes(diags: &[Diagnostic]) -> Vec<&'static str> {
        diags.iter().map(|d| d.code.as_str()).collect()
    }

    #[test]
    fn code_strings_are_stable() {
        let all: Vec<&str> = DiagCode::all().iter().map(|c| c.as_str()).collect();
        assert_eq!(
            all,
            vec![
                "RACE_WRITE",
                "OOB_ACCESS",
                "MISSING_BARRIER",
                "DIVERGENT_BARRIER",
                "SCOPE_MISUSE",
                "UNUSED_INAME",
                "DEAD_ARRAY",
                "UNPROVABLE_GUARD",
                "MALFORMED_KERNEL",
                "WG_SIZE_EXCEEDED",
                "EXCESSIVE_LOCAL_MEM",
                "LOW_OCCUPANCY",
                "SEMANTICS_CHANGED",
                "UNCOALESCED_GLOBAL",
                "BANK_CONFLICT",
            ]
        );
    }

    #[test]
    fn malformed_kernel_gates_all_other_checks() {
        // Rank mismatch: 2-D array, 1 subscript. validate() passes
        // (it does not check rank) but flatten_access would assert.
        let n = QPoly::var("n");
        let dom =
            NestedDomain::new(vec![LoopExtent::zero_to("i", n.clone())]);
        let mut k = Kernel::new("bad_rank", &["n"], dom);
        k.add_array(ArrayDecl::global("a", DType::F32, vec![n.clone(), n]));
        k.add_stmt(Stmt::new(
            "s",
            LhsRef::Array(Access::new("a", vec![AffExpr::var("i")])),
            Expr::fconst(0.0),
            &["i"],
        ));
        let diags = Analyzer::new().check(&k);
        assert_eq!(codes(&diags), vec!["MALFORMED_KERNEL"]);
        match verify(&k) {
            Err(AnalysisError::Malformed { kernel, diagnostic }) => {
                assert_eq!(kernel, "bad_rank");
                assert_eq!(diagnostic.code, DiagCode::MalformedKernel);
            }
            other => panic!("expected Malformed, got {other:?}"),
        }
    }

    #[test]
    fn interval_propagation_handles_negative_strides() {
        let dom = NestedDomain::new(vec![LoopExtent::zero_to(
            "i",
            QPoly::int(16),
        )]);
        let mut k = Kernel::new("neg", &[], dom);
        k.add_array(ArrayDecl::global("a", DType::F32, vec![QPoly::int(16)]));
        // a[15 - i] is in bounds; a[14 - i] is not (hits -1).
        k.add_stmt(Stmt::new(
            "ok",
            LhsRef::Array(Access::new(
                "a",
                vec![AffExpr::scaled_var("i", -1).plus_cst(15)],
            )),
            Expr::fconst(0.0),
            &["i"],
        ));
        assert!(Analyzer::new()
            .check(&k)
            .iter()
            .all(|d| d.code != DiagCode::OobAccess));
        k.stmts[0].lhs = LhsRef::Array(Access::new(
            "a",
            vec![AffExpr::scaled_var("i", -1).plus_cst(14)],
        ));
        assert!(Analyzer::new()
            .check(&k)
            .iter()
            .any(|d| d.code == DiagCode::OobAccess));
    }

    #[test]
    fn report_json_is_stable() {
        let d = Diagnostic {
            code: DiagCode::RaceWrite,
            kernel: "k".into(),
            stmt: Some("s".into()),
            object: Some("a".into()),
            message: "m".into(),
        };
        let j = report_to_json(&[LintEntry {
            kernel: "k".into(),
            generator: "g".into(),
            diags: vec![d],
            feasibility: vec![],
        }]);
        let text = j.to_string();
        assert!(text.contains("\"schema\":\"perflex-lint\""), "{text}");
        assert!(text.contains("\"version\":3"), "{text}");
        assert!(text.contains("\"feasibility\":[]"), "{text}");
        assert!(text.contains("\"code\":\"RACE_WRITE\""), "{text}");
        assert!(text.contains("\"errors\":1"), "{text}");
    }
}
