//! Symbolic per-kernel resource model and device feasibility check.
//!
//! Derives what a kernel asks of the hardware — work-group size,
//! local-memory footprint in bytes (a [`QPoly`] over the array shapes
//! `add_prefetch` materializes), private/temporary pressure, and
//! barrier count — and checks it against a
//! [`DeviceProfile`](crate::gpusim::DeviceProfile)'s published limits
//! (`max_wg_size`, `local_mem_bytes_per_sm`, `wgs_per_sm`).  This is
//! the "can this device even launch it?" half of the autotune pruning
//! predicate [`admissible`](super::admissible): the simulator rejects
//! an oversized launch at run time ([`crate::gpusim::exec`]), but the
//! enumeration loop needs the same answer for free, before pricing.
//!
//! The checks mirror the pruning practice of autotuning-space search
//! (arxiv 2102.05299): a candidate that cannot launch, or that fits
//! but starves the SM of resident work-groups, is discarded or
//! deprioritized without ever being measured.

use super::{sample_envs, Analyzer, DiagCode, Diagnostic};
use crate::gpusim::DeviceProfile;
use crate::ir::{IndexTag, Kernel, MemScope};
use crate::polyhedral::QPoly;
use crate::schedule;
use crate::util::json::Json;

/// What one kernel asks of the hardware, derived symbolically.
#[derive(Clone, Debug)]
pub struct ResourceUsage {
    /// Work-items per work-group (product of local-axis extents; local
    /// extents are constant by construction).
    pub wg_size: u64,
    /// Bytes of local (shared/LDS) memory per work-group: the summed
    /// byte sizes of every `Local`-scope array.  Symbolic in the
    /// problem-size parameters when a tile shape is.
    pub local_mem_bytes: QPoly,
    /// Bytes of private storage per work-item: `Private`-scope arrays
    /// plus scalar temporaries.  Advisory — register allocation is out
    /// of scope for a black-box model — but recorded so tooling can
    /// see a transform's private-pressure trend.
    pub private_bytes: QPoly,
    /// Barriers one work-item passes per kernel launch (from the
    /// linearized schedule).
    pub barriers_per_item: QPoly,
}

/// One kernel × one device: the derived usage, the resident-group
/// bound, and any limit violations.
#[derive(Clone, Debug)]
pub struct Feasibility {
    /// Device id the verdict is for.
    pub device: String,
    pub usage: ResourceUsage,
    /// Work-groups resident per SM once the local-memory footprint is
    /// applied to `wgs_per_sm` (`None` when the footprint stays
    /// symbolic at every sample size; 0 when nothing fits).
    pub resident_wgs: Option<u64>,
    /// Feasibility findings for this device (empty = launchable at
    /// full nominal occupancy).
    pub diags: Vec<Diagnostic>,
}

impl Feasibility {
    /// True when the kernel can launch on the device (no
    /// Error-severity finding; warnings allowed).
    pub fn launchable(&self) -> bool {
        super::error_count(&self.diags) == 0
    }

    pub fn to_json(&self) -> Json {
        let lmem = match self.usage.local_mem_bytes.as_constant() {
            Some(r) => (r.floor() as f64).into(),
            None => self.usage.local_mem_bytes.to_string().into(),
        };
        Json::obj(vec![
            ("device", self.device.as_str().into()),
            ("wg_size", (self.usage.wg_size as f64).into()),
            ("local_mem_bytes", lmem),
            (
                "barriers_per_item",
                self.usage.barriers_per_item.to_string().into(),
            ),
            (
                "resident_wgs",
                match self.resident_wgs {
                    Some(n) => (n as f64).into(),
                    None => Json::Null,
                },
            ),
            ("launchable", self.launchable().into()),
            (
                "diagnostics",
                Json::Arr(self.diags.iter().map(Diagnostic::to_json).collect()),
            ),
        ])
    }
}

/// Derive the symbolic resource usage of a kernel.  `Err` carries a
/// single [`DiagCode::MalformedKernel`] diagnostic when the kernel is
/// structurally broken or unschedulable (same degradation contract as
/// [`Analyzer::check`]).
pub fn usage(knl: &Kernel) -> Result<ResourceUsage, Diagnostic> {
    let gate = Analyzer::new();
    if let Some(d) = gate.structural_gate(knl) {
        return Err(d);
    }

    // Work-group size: max constant extent per local axis, multiplied
    // across axes.  Mirrors `Kernel::lsize` but degrades to a
    // diagnostic instead of panicking on a non-constant local extent.
    let mut wg_size = 1u64;
    for axis in 0..3u8 {
        let mut axis_extent = 1u64;
        for iname in knl.inames_with_tag(IndexTag::Local(axis)) {
            let l = knl
                .domain
                .loops
                .iter()
                .find(|l| l.var == iname)
                .expect("validate() checked tagged inames exist");
            let ext = knl.assumptions.simplify(&l.extent());
            match ext.as_constant().and_then(|r| r.as_integer()) {
                Some(v) if v >= 1 => axis_extent = axis_extent.max(v as u64),
                _ => {
                    return Err(gate.malformed(
                        knl,
                        format!(
                            "local iname '{iname}' has non-constant extent \
                             {ext}: the work-group size is undefined"
                        ),
                    ))
                }
            }
        }
        wg_size = wg_size.saturating_mul(axis_extent);
    }

    let mut local_mem_bytes = QPoly::zero();
    let mut private_bytes = QPoly::zero();
    for a in knl.arrays.values() {
        let bytes = a
            .size_elems()
            .scale(crate::util::Rat::int(a.dtype.size_bytes() as i128));
        match a.scope {
            MemScope::Local => local_mem_bytes = &local_mem_bytes + &bytes,
            MemScope::Private => private_bytes = &private_bytes + &bytes,
            MemScope::Global => {}
        }
    }
    for t in knl.temps.values() {
        private_bytes = &private_bytes
            + &QPoly::int(t.dtype.size_bytes() as i128);
    }
    local_mem_bytes = knl.assumptions.simplify(&local_mem_bytes);
    private_bytes = knl.assumptions.simplify(&private_bytes);

    let barriers_per_item = match schedule::linearize(knl) {
        Ok(s) => knl.assumptions.simplify(&s.barrier_count(knl)),
        Err(e) => {
            return Err(gate.malformed(knl, format!("unschedulable: {e}")))
        }
    };

    Ok(ResourceUsage {
        wg_size,
        local_mem_bytes,
        private_bytes,
        barriers_per_item,
    })
}

/// Largest value the footprint takes over the kernel's sample sizes
/// (the same assumption-derived envs the race/bounds checks use).
fn max_sampled_bytes(q: &QPoly, knl: &Kernel) -> Option<i128> {
    if let Some(r) = q.as_constant() {
        return Some(r.floor());
    }
    let mut best: Option<i128> = None;
    for env in sample_envs(knl) {
        if let Ok(v) = q.try_eval(&env) {
            let v = v.floor();
            best = Some(best.map_or(v, |b| b.max(v)));
        }
    }
    best
}

/// Check a kernel's derived usage against one device's limits.  `Err`
/// carries the [`DiagCode::MalformedKernel`] diagnostic when usage
/// derivation itself failed.
pub fn check_feasibility(
    knl: &Kernel,
    dev: &DeviceProfile,
) -> Result<Feasibility, Diagnostic> {
    let usage = usage(knl)?;
    let mut diags = Vec::new();

    if usage.wg_size > dev.max_wg_size {
        diags.push(Diagnostic {
            code: DiagCode::WgSizeExceeded,
            kernel: knl.name.clone(),
            stmt: None,
            object: Some(dev.id.to_string()),
            message: format!(
                "work-group size {} exceeds max_wg_size {} on {}: the \
                 launch would be rejected",
                usage.wg_size, dev.max_wg_size, dev.id
            ),
        });
    }

    let budget = dev.local_mem_bytes_per_sm as i128;
    let lmem = max_sampled_bytes(&usage.local_mem_bytes, knl);
    let mut resident_wgs = Some(dev.wgs_per_sm);
    match lmem {
        Some(bytes) if bytes > budget => {
            resident_wgs = Some(0);
            diags.push(Diagnostic {
                code: DiagCode::ExcessiveLocalMem,
                kernel: knl.name.clone(),
                stmt: None,
                object: Some(dev.id.to_string()),
                message: format!(
                    "local-memory footprint {} = {} B per work-group \
                     exceeds local_mem_bytes_per_sm {} B on {}: not even \
                     one work-group fits",
                    usage.local_mem_bytes, bytes, budget, dev.id
                ),
            });
        }
        Some(bytes) if bytes > 0 => {
            let fit = (budget / bytes) as u64;
            if fit < dev.wgs_per_sm {
                resident_wgs = Some(fit);
                diags.push(Diagnostic {
                    code: DiagCode::LowOccupancy,
                    kernel: knl.name.clone(),
                    stmt: None,
                    object: Some(dev.id.to_string()),
                    message: format!(
                        "local-memory footprint {} = {} B caps residency \
                         at {} work-group(s)/SM on {} (nominal wgs_per_sm \
                         {}): latency hiding degrades",
                        usage.local_mem_bytes, bytes, fit, dev.id,
                        dev.wgs_per_sm
                    ),
                });
            }
        }
        Some(_) => {}
        None => {
            // Symbolic at every sample size: record the unknown rather
            // than guessing (parameters involved are named so the
            // caller can constrain them).
            resident_wgs = None;
            let vars: Vec<String> =
                usage.local_mem_bytes.vars().into_iter().collect();
            diags.push(Diagnostic {
                code: DiagCode::ExcessiveLocalMem,
                kernel: knl.name.clone(),
                stmt: None,
                object: Some(dev.id.to_string()),
                message: format!(
                    "local-memory footprint {} could not be bounded (free \
                     parameters: {}) against local_mem_bytes_per_sm {} B \
                     on {}",
                    usage.local_mem_bytes,
                    vars.join(", "),
                    budget,
                    dev.id
                ),
            });
        }
    }

    // Device-geometry access-pattern lints: the kernel-level pass in
    // `Analyzer::check` uses the device-independent geometry; here the
    // same pass re-runs against *this* device's cache-line width and
    // bank count so per-device reports reflect real coalescing.
    super::access::check_access_patterns(
        knl,
        &sample_envs(knl),
        &super::access::Geometry::for_device(dev),
        &mut diags,
    );

    Ok(Feasibility {
        device: dev.id.to_string(),
        usage,
        resident_wgs,
        diags,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device_by_id;
    use crate::ir::{Access, AffExpr, ArrayDecl, DType, Expr, LhsRef, Stmt};
    use crate::polyhedral::{LoopExtent, NestedDomain};

    /// One local tile of `elems` f32 entries, written per work-item.
    fn lmem_kernel(elems: i128) -> Kernel {
        let dom = NestedDomain::new(vec![LoopExtent::zero_to(
            "li",
            QPoly::int(16),
        )]);
        let mut k = Kernel::new("lmem_case", &[], dom);
        k.iname_tags.insert("li".into(), IndexTag::Local(0));
        k.add_array(ArrayDecl::local(
            "tile",
            DType::F32,
            vec![QPoly::int(elems)],
        ));
        k.add_stmt(Stmt::new(
            "w",
            LhsRef::Array(Access::new("tile", vec![AffExpr::var("li")])),
            Expr::fconst(1.0),
            &["li"],
        ));
        k
    }

    #[test]
    fn usage_derives_symbolic_local_footprint() {
        let k = lmem_kernel(256);
        let u = usage(&k).unwrap();
        assert_eq!(u.wg_size, 16);
        assert_eq!(
            u.local_mem_bytes.as_constant().unwrap(),
            crate::util::Rat::int(1024)
        );
        assert!(u.barriers_per_item.is_zero());
    }

    #[test]
    fn excessive_local_mem_flags_oversized_tile() {
        // 2^18 f32 = 1 MiB: over every device's budget.
        let k = lmem_kernel(1 << 18);
        let f =
            check_feasibility(&k, &device_by_id("titan_v").unwrap()).unwrap();
        assert!(!f.launchable());
        assert_eq!(f.resident_wgs, Some(0));
        assert_eq!(f.diags.len(), 1);
        assert_eq!(f.diags[0].code, DiagCode::ExcessiveLocalMem);
        assert!(f.diags[0].message.contains("98304"), "{}", f.diags[0]);
    }

    #[test]
    fn low_occupancy_warns_but_stays_launchable() {
        // 6000 f32 = 24000 B: 2 groups fit in Kepler's 48 KiB, below
        // the nominal 8.
        let k = lmem_kernel(6000);
        let f = check_feasibility(&k, &device_by_id("tesla_k40c").unwrap())
            .unwrap();
        assert!(f.launchable());
        assert_eq!(f.resident_wgs, Some(2));
        assert_eq!(f.diags.len(), 1);
        assert_eq!(f.diags[0].code, DiagCode::LowOccupancy);
    }
}
