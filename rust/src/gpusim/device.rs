//! Device profiles for the Table 2 fleet.
//!
//! Headline numbers (SM counts, clocks, DRAM bandwidth, peak FLOP/s)
//! follow the real devices; micro-parameters (overlap window, locality
//! derate, launch costs, noise) are plausible stand-ins chosen to
//! reproduce the qualitative behaviors the paper reports per device.

/// The NVIDIA warp width — the sub-group size of every non-AMD fleet
/// device, and the counting granularity used by device-independent
/// symbolic tests.  Per-device code must use
/// [`DeviceProfile::sub_group_size`] instead: the GCN3 part runs
/// 64-wide wavefronts.
pub const DEFAULT_SUB_GROUP_SIZE: u64 = 32;

/// Device-independent coalescing-unit width in bytes, used by the
/// access-pattern pass and features when no [`DeviceProfile`] is in
/// scope (every NVIDIA fleet device coalesces at 128 B).
pub const DEFAULT_CACHELINE_BYTES: u64 = 128;

/// Device-independent local-memory bank count (32 across the fleet).
pub const DEFAULT_LOCAL_MEM_BANKS: u64 = 32;

/// One simulated GPU.
#[derive(Clone, Debug)]
pub struct DeviceProfile {
    /// Short id used in `f_cl_wall_time_<id>`.
    pub id: &'static str,
    /// Human-readable name + generation (Table 2).
    pub name: &'static str,
    /// OpenCL/platform/driver string (Table 2).
    pub opencl_info: &'static str,
    pub vendor: &'static str,
    pub sub_group_size: u64,
    /// Compute units (SMs / CUs).
    pub sm_count: u64,
    pub clock_ghz: f64,
    /// OpenCL max work-group size (AMD: 256 — blocks the 18x18
    /// stencil; `analysis::resources` enforces this statically via
    /// `WG_SIZE_EXCEEDED`).
    pub max_wg_size: u64,
    /// Resident work-groups per SM (256-item groups).
    pub wgs_per_sm: u64,
    /// Local (shared/LDS) memory per SM in bytes.  Bounds a kernel's
    /// local footprint (`EXCESSIVE_LOCAL_MEM`) and, divided by the
    /// per-group footprint, the resident work-groups that feed
    /// `LOW_OCCUPANCY`.
    pub local_mem_bytes_per_sm: u64,
    /// f32 FMA lanes per SM per cycle (peak FLOP/s = 2x this x SMs x clock).
    pub fma_lanes_per_sm: u64,
    /// f32 div throughput lanes per SM per cycle.
    pub div_lanes_per_sm: u64,
    /// f64 throughput as a fraction of f32.
    pub f64_ratio: f64,
    /// Local-memory elements (4B) per SM per cycle.
    pub lmem_elems_per_sm_cycle: u64,
    pub dram_gbps: f64,
    pub dram_latency_ns: f64,
    /// Per-SM L1/texture cache budget: decides whether a warp's working
    /// lines survive across sequential-loop iterations (streaming
    /// reuse) or must be refetched from L2.
    pub l1_kb_per_sm: u64,
    pub l2_kb: u64,
    pub l2_gbps: f64,
    /// Memory transaction (cache line) size.
    pub line_bytes: u64,
    /// Coalescing-unit width in bytes (Table 2): the cache-line
    /// granularity `analysis::access` divides a sub-group's footprint
    /// by when counting global-memory transactions.  Matches
    /// `line_bytes` on the NVIDIA parts; GCN3 coalesces at 64 B.
    pub cacheline_bytes: u64,
    /// Local (shared/LDS) memory banks.  A sub-group access whose
    /// lid(0) stride shares a factor with this count serializes into
    /// `gcd(stride, banks)`-way bank conflicts (`BANK_CONFLICT`).
    pub local_mem_banks: u64,
    /// Sequential-loop stride (bytes) beyond which a streaming access
    /// loses DRAM row locality...
    pub row_hop_bytes: u64,
    /// ... and gets its DRAM bandwidth derated by this factor.
    pub row_hop_factor: f64,
    /// Fraction of min(gmem, on-chip) cost hidden by overlap: the
    /// paper's Fig. 5 finding — near-zero on Kepler/Fermi, substantial
    /// on Volta/Maxwell/GCN3.
    pub overlap: f64,
    pub kernel_launch_us: f64,
    pub wg_launch_ns: f64,
    /// Cost per barrier per resident work-group slot.
    pub barrier_ns: f64,
    /// Log-normal measurement noise sigma.
    pub noise_sigma: f64,
    /// Probability of an anomalous ~1e5x timing event (observed on the
    /// AMD R9 Fury; excluded by the measurement procedure like the
    /// paper does).
    pub anomaly_rate: f64,
    /// Static board power drawn for the whole kernel duration (W).
    /// Together with the per-op coefficients below this is the
    /// simulator's energy model — a crude idle + activity split, NOT a
    /// measured power curve; it exists so multi-target calibration
    /// (`--target energy|avg_power`) has a closed black-box loop
    /// in-tree.
    pub idle_watts: f64,
    /// Dynamic energy per arithmetic / local-memory operation (pJ).
    pub pj_per_op: f64,
    /// Dynamic energy per DRAM byte moved (pJ/B).
    pub pj_per_dram_byte: f64,
}

impl DeviceProfile {
    /// Peak f32 FLOP/s (madd = 2 ops), for Table 3-style reporting.
    pub fn peak_flops(&self) -> f64 {
        2.0 * self.fma_lanes_per_sm as f64 * self.sm_count as f64 * self.clock_ghz * 1e9
    }

    /// Peak DRAM bandwidth in bytes/s.
    pub fn peak_bw(&self) -> f64 {
        self.dram_gbps * 1e9
    }
}

/// The five-device fleet of Table 2.
pub fn fleet() -> Vec<DeviceProfile> {
    vec![
        DeviceProfile {
            id: "titan_v",
            name: "Nvidia Titan V (Volta)",
            opencl_info: "OCL 1.2, CUDA 10.0.246 (410.93) [simulated]",
            vendor: "nvidia",
            sub_group_size: 32,
            sm_count: 80,
            clock_ghz: 1.2,
            max_wg_size: 1024,
            wgs_per_sm: 8,
            // Volta: 96 KiB unified shared memory per SM.
            local_mem_bytes_per_sm: 98_304,
            fma_lanes_per_sm: 64,
            div_lanes_per_sm: 16,
            f64_ratio: 0.5,
            lmem_elems_per_sm_cycle: 32,
            dram_gbps: 652.0,
            dram_latency_ns: 400.0,
            l1_kb_per_sm: 96,
            l2_kb: 4608,
            l2_gbps: 2200.0,
            line_bytes: 128,
            cacheline_bytes: 128,
            local_mem_banks: 32,
            row_hop_bytes: 2048,
            row_hop_factor: 3.2,
            overlap: 0.95,
            kernel_launch_us: 8.0,
            wg_launch_ns: 1.6,
            barrier_ns: 40.0,
            noise_sigma: 0.012,
            anomaly_rate: 0.0,
            idle_watts: 25.0,
            pj_per_op: 10.0,
            pj_per_dram_byte: 30.0,
        },
        DeviceProfile {
            id: "gtx_titan_x",
            name: "Nvidia GTX Titan X (Maxwell)",
            opencl_info: "OCL 1.2, CUDA 10.0.292 (410.104) [simulated]",
            vendor: "nvidia",
            sub_group_size: 32,
            sm_count: 24,
            clock_ghz: 1.0,
            max_wg_size: 1024,
            wgs_per_sm: 8,
            // Maxwell: 96 KiB dedicated shared memory per SM.
            local_mem_bytes_per_sm: 98_304,
            fma_lanes_per_sm: 128,
            div_lanes_per_sm: 32,
            f64_ratio: 1.0 / 32.0,
            lmem_elems_per_sm_cycle: 32,
            dram_gbps: 336.6,
            dram_latency_ns: 450.0,
            l1_kb_per_sm: 48,
            l2_kb: 3072,
            l2_gbps: 1100.0,
            line_bytes: 128,
            cacheline_bytes: 128,
            local_mem_banks: 32,
            row_hop_bytes: 2048,
            row_hop_factor: 4.2,
            overlap: 0.92,
            kernel_launch_us: 10.0,
            wg_launch_ns: 2.2,
            barrier_ns: 55.0,
            noise_sigma: 0.015,
            anomaly_rate: 0.0,
            idle_watts: 15.0,
            pj_per_op: 20.0,
            pj_per_dram_byte: 60.0,
        },
        DeviceProfile {
            id: "tesla_k40c",
            name: "Nvidia Tesla K40c (Kepler)",
            opencl_info: "OCL 1.2, CUDA 9.1.84 (390.87) [simulated]",
            vendor: "nvidia",
            sub_group_size: 32,
            sm_count: 15,
            clock_ghz: 0.745,
            max_wg_size: 1024,
            wgs_per_sm: 8,
            // Kepler: 48 KiB shared (of the 64 KiB L1/shared split).
            local_mem_bytes_per_sm: 49_152,
            fma_lanes_per_sm: 192,
            div_lanes_per_sm: 32,
            f64_ratio: 1.0 / 3.0,
            lmem_elems_per_sm_cycle: 64,
            dram_gbps: 288.0,
            dram_latency_ns: 500.0,
            l1_kb_per_sm: 32,
            l2_kb: 1536,
            l2_gbps: 800.0,
            line_bytes: 128,
            cacheline_bytes: 128,
            local_mem_banks: 32,
            row_hop_bytes: 2048,
            row_hop_factor: 4.8,
            // Kepler's in-order scheduling hides almost no on-chip
            // cost behind memory (paper Fig. 5).
            overlap: 0.08,
            kernel_launch_us: 12.0,
            wg_launch_ns: 3.0,
            barrier_ns: 70.0,
            noise_sigma: 0.015,
            anomaly_rate: 0.0,
            idle_watts: 20.0,
            pj_per_op: 30.0,
            pj_per_dram_byte: 70.0,
        },
        DeviceProfile {
            id: "tesla_c2070",
            name: "Nvidia Tesla C2070 (Fermi)",
            opencl_info: "OCL 1.2 CUDA 9.1.84 (390.116) [simulated]",
            vendor: "nvidia",
            sub_group_size: 32,
            sm_count: 14,
            clock_ghz: 1.15,
            max_wg_size: 1024,
            wgs_per_sm: 8,
            // Fermi: 48 KiB shared (of the 64 KiB L1/shared split).
            local_mem_bytes_per_sm: 49_152,
            fma_lanes_per_sm: 32,
            div_lanes_per_sm: 8,
            f64_ratio: 0.5,
            lmem_elems_per_sm_cycle: 16,
            dram_gbps: 144.0,
            dram_latency_ns: 600.0,
            l1_kb_per_sm: 48,
            l2_kb: 768,
            l2_gbps: 450.0,
            line_bytes: 128,
            cacheline_bytes: 128,
            local_mem_banks: 32,
            row_hop_bytes: 2048,
            row_hop_factor: 5.0,
            overlap: 0.05,
            kernel_launch_us: 15.0,
            wg_launch_ns: 4.0,
            barrier_ns: 90.0,
            noise_sigma: 0.018,
            anomaly_rate: 0.0,
            idle_watts: 30.0,
            pj_per_op: 45.0,
            pj_per_dram_byte: 80.0,
        },
        DeviceProfile {
            id: "amd_r9_fury",
            name: "AMD Radeon R9 Fury (GCN 3)",
            opencl_info: "OpenCL/ROCm 1.2.0-2019020110 [simulated]",
            vendor: "amd",
            // GCN3 executes 64-wide wavefronts, not 32-wide warps: the
            // one per-device hardware statistic the paper's counting
            // granularity actually consumes.
            sub_group_size: 64,
            sm_count: 56,
            clock_ghz: 1.0,
            // The paper could not run the 18x18 stencil variant here.
            max_wg_size: 256,
            wgs_per_sm: 8,
            // GCN3: 64 KiB LDS per CU.
            local_mem_bytes_per_sm: 65_536,
            fma_lanes_per_sm: 64,
            div_lanes_per_sm: 16,
            f64_ratio: 1.0 / 16.0,
            lmem_elems_per_sm_cycle: 32,
            dram_gbps: 512.0,
            dram_latency_ns: 420.0,
            l1_kb_per_sm: 16,
            l2_kb: 2048,
            l2_gbps: 1600.0,
            line_bytes: 128,
            // GCN3 coalesces at 64 B granularity (4 B x 16-lane
            // quarter-wavefront), half the NVIDIA 128 B unit.
            cacheline_bytes: 64,
            local_mem_banks: 32,
            row_hop_bytes: 2048,
            row_hop_factor: 3.8,
            overlap: 0.85,
            kernel_launch_us: 14.0,
            wg_launch_ns: 2.5,
            barrier_ns: 60.0,
            noise_sigma: 0.02,
            anomaly_rate: 0.02,
            idle_watts: 20.0,
            pj_per_op: 15.0,
            pj_per_dram_byte: 25.0,
        },
    ]
}

/// Look up a device by id.
pub fn device_by_id(id: &str) -> Option<DeviceProfile> {
    fleet().into_iter().find(|d| d.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_matches_table2() {
        let f = fleet();
        assert_eq!(f.len(), 5);
        let ids: Vec<_> = f.iter().map(|d| d.id).collect();
        assert_eq!(
            ids,
            vec!["titan_v", "gtx_titan_x", "tesla_k40c", "tesla_c2070", "amd_r9_fury"]
        );
        // Sub-group size is the only hardware statistic the paper's
        // models require: warp 32 on the NVIDIA parts, wavefront 64 on
        // the GCN3 part.
        for d in &f {
            let expect = if d.vendor == "amd" {
                64
            } else {
                DEFAULT_SUB_GROUP_SIZE
            };
            assert_eq!(d.sub_group_size, expect, "{}", d.id);
        }
    }

    #[test]
    fn peak_flops_match_spec_sheets() {
        // Titan V ~12.3 TFLOP/s (Table 3), Titan X ~6.1, K40c ~4.3,
        // C2070 ~1.0, Fury ~7.2.
        let expect = [
            ("titan_v", 12.3e12),
            ("gtx_titan_x", 6.1e12),
            ("tesla_k40c", 4.3e12),
            ("tesla_c2070", 1.03e12),
            ("amd_r9_fury", 7.2e12),
        ];
        for (id, peak) in expect {
            let d = device_by_id(id).unwrap();
            let got = d.peak_flops();
            assert!(
                (got - peak).abs() / peak < 0.06,
                "{id}: {got:.3e} vs {peak:.3e}"
            );
        }
    }

    #[test]
    fn overlap_split_matches_paper_fig5() {
        // Volta/Maxwell/GCN3 hide on-chip cost; Kepler/Fermi do not.
        for id in ["titan_v", "gtx_titan_x", "amd_r9_fury"] {
            assert!(device_by_id(id).unwrap().overlap > 0.5, "{id}");
        }
        for id in ["tesla_k40c", "tesla_c2070"] {
            assert!(device_by_id(id).unwrap().overlap < 0.2, "{id}");
        }
    }

    #[test]
    fn power_model_coefficients_are_physical() {
        // The simulator power model is crude, but it must at least be
        // positive everywhere (energy targets are output-scaled during
        // calibration, which rejects non-positive outputs) and give the
        // older process nodes worse energy-per-op than Volta.
        for d in fleet() {
            assert!(d.idle_watts > 0.0, "{}", d.id);
            assert!(d.pj_per_op > 0.0, "{}", d.id);
            assert!(d.pj_per_dram_byte > 0.0, "{}", d.id);
        }
        let volta = device_by_id("titan_v").unwrap();
        let fermi = device_by_id("tesla_c2070").unwrap();
        assert!(fermi.pj_per_op > volta.pj_per_op);
        assert!(fermi.pj_per_dram_byte > volta.pj_per_dram_byte);
    }

    #[test]
    fn access_geometry_matches_table2() {
        // Coalescing unit and bank count feed the access-pattern pass:
        // 128 B lines / 32 banks on the NVIDIA parts, 64 B coalescing
        // on GCN3.
        for d in fleet() {
            let expect_line = if d.vendor == "amd" {
                64
            } else {
                DEFAULT_CACHELINE_BYTES
            };
            assert_eq!(d.cacheline_bytes, expect_line, "{}", d.id);
            assert_eq!(d.local_mem_banks, DEFAULT_LOCAL_MEM_BANKS, "{}", d.id);
        }
    }

    #[test]
    fn amd_work_group_limit() {
        assert_eq!(device_by_id("amd_r9_fury").unwrap().max_wg_size, 256);
        assert!(device_by_id("titan_v").unwrap().max_wg_size >= 1024);
    }

    #[test]
    fn local_mem_budgets_match_spec_sheets() {
        let expect = [
            ("titan_v", 96 * 1024),
            ("gtx_titan_x", 96 * 1024),
            ("tesla_k40c", 48 * 1024),
            ("tesla_c2070", 48 * 1024),
            ("amd_r9_fury", 64 * 1024),
        ];
        for (id, bytes) in expect {
            assert_eq!(
                device_by_id(id).unwrap().local_mem_bytes_per_sm,
                bytes,
                "{id}"
            );
        }
    }
}
