//! The simulator's execution-cost model and measurement procedure.

use std::collections::BTreeMap;

use super::device::DeviceProfile;
use crate::ir::{DType, Kernel, KernelRef, MemScope};
use crate::stats::{self, Granularity, KernelStats, MemAccessStat, StatsCache};
use crate::util::Rng;

/// Per-component cost breakdown of one simulated execution (useful for
/// debugging, the simulator's own tests, and DESIGN.md analyses; the
/// black-box calibration path never reads it).
#[derive(Clone, Debug, Default)]
pub struct CostBreakdown {
    pub t_dram: f64,
    pub t_l2: f64,
    pub t_lsu: f64,
    pub t_latency: f64,
    pub t_gmem: f64,
    pub t_arith: f64,
    pub t_lmem: f64,
    pub t_onchip: f64,
    pub t_barrier: f64,
    pub t_launch: f64,
    pub utilization: f64,
    pub total: f64,
    /// Dynamic (activity-proportional) energy in joules: op and DRAM
    /// traffic counts weighted by the device's per-op coefficients.
    /// The static half of the energy model (idle watts x wall time) is
    /// added at measurement time, where the noisy trial time is known.
    pub e_dynamic_j: f64,
}

/// One black-box measurement: the paper's wall time plus the simulator
/// power model's energy for the same execution.  Derived quantities
/// (average power) come from methods, so a `Target` never recomputes
/// them inconsistently.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MeasuredSample {
    /// Mean wall time over the kept trials (seconds) — exactly the
    /// scalar `measure` returned before targets existed.
    pub time_s: f64,
    /// Energy for one execution (joules): idle watts x measured time
    /// plus the breakdown's dynamic energy.
    pub energy_j: f64,
}

impl MeasuredSample {
    /// Average power over the kernel's execution (watts).
    pub fn avg_power_w(&self) -> f64 {
        self.energy_j / self.time_s
    }
}

fn env_i128(env: &BTreeMap<String, i64>) -> BTreeMap<String, i128> {
    env.iter().map(|(k, v)| (k.clone(), *v as i128)).collect()
}

/// Marker carried by per-kernel measurement errors: one kernel's
/// statistics cannot be evaluated on this device/size combination
/// (e.g. an access map whose local strides reference a parameter the
/// measurement env does not bind).  The sweep drivers treat such
/// errors like `CL_INVALID_WORK_GROUP_SIZE` — skip the kernel, keep
/// the sweep — instead of aborting the whole run.
pub const KERNEL_UNMEASURABLE: &str = "KERNEL_UNMEASURABLE";

/// True for errors that condemn a single measurement kernel rather
/// than the whole sweep (unlaunchable work-group sizes, unevaluable
/// access maps).
pub fn is_per_kernel_measure_error(e: &str) -> bool {
    e.contains("CL_INVALID_WORK_GROUP_SIZE") || e.contains(KERNEL_UNMEASURABLE)
}

fn unmeasurable(knl: &Kernel, m: &MemAccessStat, err: String) -> String {
    format!(
        "{KERNEL_UNMEASURABLE}: kernel '{}', access of array '{}' in \
         statement '{}': {err}",
        knl.name, m.array, m.stmt_id
    )
}

/// Coalescing analysis of one sub-group's 32 lane addresses: returns
/// (unique cache lines touched, unique addresses) from the evaluated
/// lid strides.
fn lines_per_subgroup(
    knl: &Kernel,
    m: &MemAccessStat,
    e: &BTreeMap<String, i128>,
    line_bytes: u64,
    sg: u64,
) -> Result<(u64, u64), String> {
    let dsize = m.dtype.size_bytes() as i128;
    let ls: Vec<i128> = (0..3)
        .map(|ax| m.lstrides[ax].try_eval(e).map(|r| r.floor()))
        .collect::<Result<_, _>>()?;
    let (l0, l1) = (knl.lsize(0).max(1), knl.lsize(1).max(1));
    let mut lines: Vec<i128> = Vec::with_capacity(sg as usize);
    let mut addrs: Vec<i128> = Vec::with_capacity(sg as usize);
    for t in 0..sg {
        let lid0 = (t % l0) as i128;
        let lid1 = ((t / l0) % l1) as i128;
        let lid2 = (t / (l0 * l1)) as i128;
        let addr = (lid0 * ls[0] + lid1 * ls[1] + lid2 * ls[2]) * dsize;
        let line = addr.div_euclid(line_bytes as i128);
        if !lines.contains(&line) {
            lines.push(line);
        }
        if !addrs.contains(&addr) {
            addrs.push(addr);
        }
    }
    Ok((lines.len() as u64, addrs.len() as u64))
}

/// Innermost non-zero sequential-loop stride in bytes (None if the
/// access is loop-invariant).
fn innermost_seq_stride_bytes(
    m: &MemAccessStat,
    e: &BTreeMap<String, i128>,
) -> Result<Option<i128>, String> {
    let dsize = m.dtype.size_bytes() as i128;
    for (_, s) in m.loop_strides.iter().rev() {
        let s = s.try_eval(e)?.floor().abs() * dsize;
        if s != 0 {
            return Ok(Some(s));
        }
    }
    Ok(None)
}

/// Launchability check: runs before any symbolic work so that kernels
/// a device must reject pay nothing.
fn check_launchable(dev: &DeviceProfile, knl: &Kernel) -> Result<(), String> {
    let wg_size = knl.work_group_size();
    if wg_size > dev.max_wg_size {
        return Err(format!(
            "CL_INVALID_WORK_GROUP_SIZE: kernel '{}' uses {wg_size} work-items, \
             device '{}' allows {}",
            knl.name, dev.id, dev.max_wg_size
        ));
    }
    Ok(())
}

/// Deterministic execution-time estimate (no noise), with breakdown.
pub fn simulate_breakdown(
    dev: &DeviceProfile,
    knl: &Kernel,
    env: &BTreeMap<String, i64>,
) -> Result<CostBreakdown, String> {
    check_launchable(dev, knl)?;
    let stats = stats::gather(knl, dev.sub_group_size)?;
    breakdown_from_stats(dev, knl, &stats, env)
}

/// [`simulate_breakdown`] through a shared [`StatsCache`]: the symbolic
/// pass runs at most once per distinct (kernel, sub-group size).
/// Accepts any [`KernelRef`]; a [`crate::ir::FrozenKernel`] avoids the
/// per-lookup IR rendering of the cache key.
pub fn simulate_breakdown_with_cache<K: KernelRef>(
    dev: &DeviceProfile,
    knl: &K,
    env: &BTreeMap<String, i64>,
    cache: &StatsCache,
) -> Result<CostBreakdown, String> {
    check_launchable(dev, knl.as_kernel())?;
    let stats = cache.get_or_gather(knl, dev.sub_group_size)?;
    breakdown_from_stats(dev, knl.as_kernel(), &stats, env)
}

/// Core cost model over gathered statistics.  Fallible: a kernel whose
/// access map cannot be evaluated at these sizes yields a
/// [`KERNEL_UNMEASURABLE`] error (skippable per kernel) instead of a
/// process-aborting panic.
pub(crate) fn breakdown_from_stats(
    dev: &DeviceProfile,
    knl: &Kernel,
    stats: &KernelStats,
    env: &BTreeMap<String, i64>,
) -> Result<CostBreakdown, String> {
    let e = env_i128(env);
    // Kernel-level counts guarded like the access strides below: a
    // stats bundle (possibly decoded from a hand-edited store) whose
    // polynomials reference parameters the env does not bind fails
    // this one kernel, never the process.
    let ev = |p: &crate::polyhedral::QPoly, what: &str| -> Result<f64, String> {
        p.try_eval_f64(&e).map_err(|err| {
            format!("{KERNEL_UNMEASURABLE}: kernel '{}', {what}: {err}", knl.name)
        })
    };
    let sg = dev.sub_group_size;
    let clock = dev.clock_ghz * 1e9;
    let n_wg = ev(&stats.num_groups, "group count")?.max(1.0);
    let wg_size = stats.work_group_size.max(1);

    // Warp quantization: a 324-item work-group occupies ceil(324/32) =
    // 11 sub-group slots; issue-bound costs scale by slots*sg/size.
    let wg_slots = (wg_size + sg - 1) / sg;
    let wq = (wg_slots * sg) as f64 / wg_size as f64;
    // Residency is limited by the WG budget, raw threads, and the SM's
    // warp-slot budget (64 slots): odd-sized groups waste slots, which
    // is precisely why the paper's 18x18 stencil tends to lose.
    let resident_wgs_per_sm = dev
        .wgs_per_sm
        .min((2048 / wg_size).max(1))
        .min((64 / wg_slots).max(1)) as f64;
    let resident_wgs = dev.sm_count as f64 * resident_wgs_per_sm;
    let resident_sgs_per_sm = resident_wgs_per_sm * (wg_size as f64 / sg as f64);

    // ---- Arithmetic (on-chip) -------------------------------------
    let mut t_arith = 0.0;
    // Activity counts for the energy model: every executed op (arith or
    // local-memory) and every DRAM byte moved draws dynamic energy on
    // top of the device's idle power.
    let mut energy_ops = 0.0;
    let mut energy_dram_bytes = 0.0;
    for op in &stats.ops {
        let wi_ops = ev(&op.count_sg, "op count")? * sg as f64;
        if wi_ops <= 0.0 {
            continue;
        }
        energy_ops += wi_ops;
        let lanes = match op.op.as_str() {
            "div" => dev.div_lanes_per_sm,
            _ => dev.fma_lanes_per_sm,
        } as f64;
        let ratio = match op.dtype {
            DType::F64 => dev.f64_ratio,
            _ => 1.0,
        };
        t_arith += wi_ops * wq / (dev.sm_count as f64 * lanes * ratio * clock);
    }

    // ---- Local memory (on-chip) -----------------------------------
    let mut t_lmem = 0.0;
    for m in stats.mem.iter().filter(|m| m.scope == MemScope::Local) {
        let wi = m
            .count_wi
            .try_eval_f64(&e)
            .map_err(|err| unmeasurable(knl, m, err))?;
        if wi <= 0.0 {
            continue;
        }
        // Bank conflicts: stride-s access across 32 banks serializes by
        // gcd(s, 32); capped — modern LDS/shared pipes mitigate worst
        // cases.  The stride evaluation is guarded: an access map with
        // no evaluable local stride must fail this one kernel, not
        // abort the whole measurement sweep.
        let s0 = m.lstrides[0]
            .try_eval(&e)
            .map_err(|err| unmeasurable(knl, m, err))?
            .floor()
            .unsigned_abs() as u64
            % 32;
        let conflict = if s0 == 0 {
            1 // broadcast
        } else {
            num_gcd(s0, 32).min(4)
        } as f64;
        t_lmem += wi * conflict * wq
            / (dev.sm_count as f64 * dev.lmem_elems_per_sm_cycle as f64 * clock);
        energy_ops += wi;
    }

    // ---- Global memory --------------------------------------------
    // Three-level model: the LSU issues one line-transaction per cycle
    // per SM (scattered warp accesses replay); per-WG tiles that fit L1
    // absorb intra-WG reuse; L2 absorbs footprint-level reuse; DRAM
    // traffic pays a row-locality derate for large-stride streams.
    let mut dram_time = 0.0;
    let mut l2_bytes = 0.0;
    let mut lsu_transactions = 0.0;
    let mut mem_transactions = 0.0;
    let l1_capacity = dev.l1_kb_per_sm as f64 * 1024.0;
    let l2_capacity = dev.l2_kb as f64 * 1024.0;
    for m in stats.mem.iter().filter(|m| m.scope == MemScope::Global) {
        let wi = m
            .count_wi
            .try_eval_f64(&e)
            .map_err(|err| unmeasurable(knl, m, err))?;
        if wi <= 0.0 {
            continue;
        }
        let dsize = m.dtype.size_bytes() as f64;
        // Sub-group instances: uniform accesses issue one per SG.
        let sg_instances = wi / sg as f64 * wq;
        let (lines_u, addrs_u) = match m.granularity {
            Granularity::SubGroup => (1, 1),
            Granularity::WorkItem => {
                lines_per_subgroup(knl, m, &e, dev.line_bytes, sg)
                    .map_err(|err| unmeasurable(knl, m, err))?
            }
        };
        let (lines, uniq_addrs) = (lines_u as f64, addrs_u as f64);
        // Every touched line costs an LSU issue slot even when it hits
        // in cache (scattered-access replay).
        lsu_transactions += sg_instances * lines;

        // Sequential streaming reuse: a small-stride loop revisits the
        // same line on consecutive iterations — if the warp's working
        // lines survive in L1 across iterations.
        let retained =
            lines * dev.line_bytes as f64 * resident_sgs_per_sm <= l1_capacity;
        let seq_stride = innermost_seq_stride_bytes(m, &e)
            .map_err(|err| unmeasurable(knl, m, err))?;
        let seq_reuse = match seq_stride {
            Some(s) if (s as u64) < dev.line_bytes && s > 0 && retained => {
                s as f64 / dev.line_bytes as f64
            }
            _ => 1.0,
        };
        let issued = sg_instances * lines * seq_reuse;
        let issued_bytes = issued * dev.line_bytes as f64;

        // Per-WG tile (group inames pinned), inflated by the line
        // overfetch of the access's coalescing pattern.
        let overfetch =
            (lines * dev.line_bytes as f64) / (uniq_addrs * dsize).max(1.0);
        let wg_tile_bytes = m
            .footprint_per_wg
            .try_eval_f64(&e)
            .map_err(|err| unmeasurable(knl, m, err))?
            .max(1.0)
            * dsize
            * overfetch.max(1.0);
        let to_l2 = if wg_tile_bytes <= l1_capacity {
            // Intra-WG reuse is L1-served: L2 sees roughly one tile per
            // work-group plus a small residual of capacity misses.
            (n_wg * wg_tile_bytes + 0.02 * issued_bytes).min(issued_bytes)
        } else {
            issued_bytes
        };
        l2_bytes += to_l2;
        mem_transactions += to_l2 / dev.line_bytes as f64;

        // L2 capacity: footprints that stay hot (well under capacity,
        // since concurrent streams compete for the cache) are fetched
        // from DRAM ~once; larger footprints still see partial
        // concurrent-WG reuse.
        let footprint_bytes = m
            .footprint
            .try_eval_f64(&e)
            .map_err(|err| unmeasurable(knl, m, err))?
            .min(wi)
            * dsize;
        let dram_bytes = if to_l2 > footprint_bytes {
            let miss = if footprint_bytes <= l2_capacity / 4.0 {
                0.05
            } else {
                0.5
            };
            footprint_bytes + miss * (to_l2 - footprint_bytes)
        } else {
            to_l2
        };
        // DRAM row locality: large-stride streams hop rows.
        let hop = match seq_stride {
            Some(s) if s as u64 > dev.row_hop_bytes => dev.row_hop_factor,
            _ => 1.0,
        };
        dram_time += dram_bytes * hop / dev.peak_bw();
        // Energy charges the bytes actually moved; the row-hop factor
        // derates bandwidth (time), not traffic.
        energy_dram_bytes += dram_bytes;
    }
    let t_l2 = l2_bytes / (dev.l2_gbps * 1e9);
    // LSU issue serialization: one line-transaction per SM per cycle.
    let t_lsu = lsu_transactions / (dev.sm_count as f64 * clock);
    // Memory-level parallelism bound on latency.
    let total_sgs = n_wg * (wg_size as f64 / sg as f64);
    let mlp = (dev.sm_count as f64 * resident_sgs_per_sm)
        .min(total_sgs)
        .max(1.0);
    let t_latency = mem_transactions * dev.dram_latency_ns * 1e-9 / mlp;
    let t_gmem = dram_time.max(t_l2).max(t_latency).max(t_lsu);

    // ---- Synchronization & launch ----------------------------------
    let barriers = ev(&stats.barriers_per_wi, "barrier count")?;
    let t_barrier = barriers * n_wg * dev.barrier_ns * 1e-9 / resident_wgs;
    let t_launch = dev.kernel_launch_us * 1e-6 + n_wg * dev.wg_launch_ns * 1e-9;

    // ---- Waves / utilization ---------------------------------------
    // Partial waves and partial warps (wq) both waste issue slots.
    let waves = (n_wg / resident_wgs).ceil().max(1.0);
    let utilization =
        ((n_wg / (waves * resident_wgs)).min(1.0) / wq).max(1e-3);

    // ---- Overlap (Eq. 3's max(), partially) -------------------------
    let t_onchip = t_arith + t_lmem;
    let t_core = t_gmem.max(t_onchip) + (1.0 - dev.overlap) * t_gmem.min(t_onchip);

    let total = t_launch + t_barrier + t_core / utilization;
    Ok(CostBreakdown {
        t_dram: dram_time,
        t_l2,
        t_lsu,
        t_latency,
        t_gmem,
        t_arith,
        t_lmem,
        t_onchip,
        t_barrier,
        t_launch,
        utilization,
        total,
        e_dynamic_j: (energy_ops * dev.pj_per_op
            + energy_dram_bytes * dev.pj_per_dram_byte)
            * 1e-12,
    })
}

fn num_gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a.max(1)
}

/// Deterministic execution time (seconds).
pub fn simulate_time(
    dev: &DeviceProfile,
    knl: &Kernel,
    env: &BTreeMap<String, i64>,
) -> Result<f64, String> {
    simulate_breakdown(dev, knl, env).map(|b| b.total)
}

/// [`simulate_time`] through a shared [`StatsCache`].
pub fn simulate_time_with_cache<K: KernelRef>(
    dev: &DeviceProfile,
    knl: &K,
    env: &BTreeMap<String, i64>,
    cache: &StatsCache,
) -> Result<f64, String> {
    simulate_breakdown_with_cache(dev, knl, env, cache).map(|b| b.total)
}

/// The paper's measurement procedure: 60 timing trials, average, with
/// anomalous events (AMD) excluded as the paper does.  Deterministic
/// given (device, kernel name, sizes).
///
/// Returns a full [`MeasuredSample`]: the noisy wall time plus the
/// board energy for the run (idle power over the measured time plus
/// the deterministic dynamic energy from the breakdown).  The timing
/// noise stream is unchanged from when this returned a bare `f64` —
/// energy consumes no RNG draws.
pub fn measure(
    dev: &DeviceProfile,
    knl: &Kernel,
    env: &BTreeMap<String, i64>,
) -> Result<MeasuredSample, String> {
    let bd = simulate_breakdown(dev, knl, env)?;
    let time_s = noisy_trials(dev, knl, env, bd.total);
    Ok(MeasuredSample {
        time_s,
        energy_j: dev.idle_watts * time_s + bd.e_dynamic_j,
    })
}

/// [`measure`] through a shared [`StatsCache`]: byte-identical results
/// (the noise seed depends only on device, kernel name and sizes), but
/// the symbolic pass is skipped whenever the cache already holds the
/// kernel's statistics.
pub fn measure_with_cache<K: KernelRef>(
    dev: &DeviceProfile,
    knl: &K,
    env: &BTreeMap<String, i64>,
    cache: &StatsCache,
) -> Result<MeasuredSample, String> {
    let bd = simulate_breakdown_with_cache(dev, knl, env, cache)?;
    let time_s = noisy_trials(dev, knl.as_kernel(), env, bd.total);
    Ok(MeasuredSample {
        time_s,
        energy_j: dev.idle_watts * time_s + bd.e_dynamic_j,
    })
}

fn noisy_trials(
    dev: &DeviceProfile,
    knl: &Kernel,
    env: &BTreeMap<String, i64>,
    base: f64,
) -> f64 {
    // Reproducible seed from device, kernel and sizes.
    let mut h = 0xcbf29ce484222325u64;
    for b in dev.id.bytes().chain(knl.name.bytes()) {
        h = (h ^ b as u64).wrapping_mul(0x100000001b3);
    }
    for (k, v) in env {
        for b in k.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x100000001b3);
        }
        h = (h ^ *v as u64).wrapping_mul(0x100000001b3);
    }
    let mut rng = Rng::new(h);
    let mut trials: Vec<f64> = (0..60)
        .map(|_| {
            let mut t = base * rng.lognormal_factor(dev.noise_sigma);
            if dev.anomaly_rate > 0.0 && rng.uniform() < dev.anomaly_rate {
                t *= 1e5; // the Fury's anomalous events
            }
            t
        })
        .collect();
    // Exclude anomalies: drop trials more than 8x the median.
    trials.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = trials[trials.len() / 2];
    let kept: Vec<f64> = trials.into_iter().filter(|t| *t <= 8.0 * median).collect();
    kept.iter().sum::<f64>() / kept.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::device::{device_by_id, fleet};
    use crate::ir::{Access, AffExpr, ArrayDecl, Expr, Kernel, LhsRef, Stmt};
    use crate::polyhedral::{LoopExtent, NestedDomain, QPoly};
    use crate::transform::{add_prefetch, assume, split_iname, tag_inames};

    fn env(n: i64) -> BTreeMap<String, i64> {
        [("n".to_string(), n)].into_iter().collect()
    }

    fn matmul(prefetch: bool) -> Kernel {
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", n.clone()),
            LoopExtent::zero_to("j", n.clone()),
            LoopExtent::zero_to("k", n.clone()),
        ]);
        let mut k = Kernel::new(
            if prefetch { "mm_pf" } else { "mm_nopf" },
            &["n"],
            dom,
        );
        for name in ["a", "b", "c"] {
            k.add_array(ArrayDecl::global(
                name,
                crate::ir::DType::F32,
                vec![n.clone(), n.clone()],
            ));
        }
        k.add_temp("acc", crate::ir::DType::F32);
        k.add_stmt(Stmt::new(
            "init",
            LhsRef::Temp("acc".into()),
            Expr::fconst(0.0),
            &["i", "j"],
        ));
        k.add_stmt(
            Stmt::new(
                "upd",
                LhsRef::Temp("acc".into()),
                Expr::add(
                    Expr::temp("acc"),
                    Expr::mul(
                        Expr::load(Access::tagged(
                            "a",
                            "aLD",
                            vec![AffExpr::var("i"), AffExpr::var("k")],
                        )),
                        Expr::load(Access::tagged(
                            "b",
                            "bLD",
                            vec![AffExpr::var("k"), AffExpr::var("j")],
                        )),
                    ),
                ),
                &["i", "j", "k"],
            )
            .with_deps(&["init"]),
        );
        k.add_stmt(
            Stmt::new(
                "store",
                LhsRef::Array(Access::new(
                    "c",
                    vec![AffExpr::var("i"), AffExpr::var("j")],
                )),
                Expr::temp("acc"),
                &["i", "j"],
            )
            .with_deps(&["upd"]),
        );
        let k = assume(&k, "n >= 16 and n % 16 = 0").unwrap();
        let k = split_iname(&k, "i", 16).unwrap();
        let k = split_iname(&k, "j", 16).unwrap();
        let mut k = tag_inames(&k, "i_out:g.1, i_in:l.1, j_out:g.0, j_in:l.0").unwrap();
        if prefetch {
            k = split_iname(&k, "k", 16).unwrap();
            k = add_prefetch(&k, "a", &["i_in", "k_in"], false).unwrap();
            k = add_prefetch(&k, "b", &["k_in", "j_in"], false).unwrap();
        }
        k
    }

    #[test]
    fn prefetch_beats_no_prefetch_on_all_devices() {
        let pf = matmul(true);
        let nopf = matmul(false);
        for d in fleet() {
            let t_pf = simulate_time(&d, &pf, &env(2048)).unwrap();
            let t_no = simulate_time(&d, &nopf, &env(2048)).unwrap();
            assert!(
                t_pf < t_no,
                "{}: prefetch {t_pf:.4} !< no-prefetch {t_no:.4}",
                d.id
            );
        }
    }

    #[test]
    fn prefetch_matmul_hits_plausible_flops_fraction() {
        // The paper: tiled prefetching matmul achieves 8-20% of peak on
        // all five GPUs.  Allow a slightly wider band for the simulator.
        let pf = matmul(true);
        for d in fleet() {
            let t = simulate_time(&d, &pf, &env(2048)).unwrap();
            let flops = 2.0 * 2048f64.powi(3) / t;
            let frac = flops / d.peak_flops();
            assert!(
                (0.03..0.45).contains(&frac),
                "{}: {:.1}% of peak (t={t:.4}s)",
                d.id,
                frac * 100.0
            );
        }
    }

    #[test]
    fn time_scales_with_problem_size() {
        let pf = matmul(true);
        let d = device_by_id("titan_v").unwrap();
        // Out of cache the scaling is near-cubic:
        // (3584/2048)^3 ~ 5.36; allow slack for launch overheads and
        // the (mild) cache-regime shift at small sizes.
        let t1 = simulate_time(&d, &pf, &env(1024)).unwrap();
        let t2 = simulate_time(&d, &pf, &env(2048)).unwrap();
        let t3 = simulate_time(&d, &pf, &env(3584)).unwrap();
        assert!(t2 > 4.0 * t1, "scaling too flat: t1={t1} t2={t2}");
        let ratio = t3 / t2;
        assert!(
            (4.0..7.0).contains(&ratio),
            "out-of-cache scaling not cubic: {ratio} (t2={t2}, t3={t3})"
        );
    }

    #[test]
    fn overlap_devices_hide_onchip_cost() {
        // On Titan V (overlap 0.95) the prefetch variant's total should
        // sit near max(gmem, onchip); on K40c near the sum.
        let pf = matmul(true);
        let tv = device_by_id("titan_v").unwrap();
        let b = simulate_breakdown(&tv, &pf, &env(2048)).unwrap();
        let core = b.total - b.t_launch - b.t_barrier;
        let max_c = b.t_gmem.max(b.t_onchip) / b.utilization;
        let sum_c = (b.t_gmem + b.t_onchip) / b.utilization;
        assert!((core - max_c).abs() < 0.15 * max_c, "{b:?}");

        let k40 = device_by_id("tesla_k40c").unwrap();
        let b = simulate_breakdown(&k40, &pf, &env(2048)).unwrap();
        let core = b.total - b.t_launch - b.t_barrier;
        let sum_c40 = (b.t_gmem + b.t_onchip) / b.utilization;
        assert!((core - sum_c40).abs() < 0.15 * sum_c40, "{b:?}");
        let _ = sum_c;
    }

    #[test]
    fn amd_rejects_oversized_work_groups() {
        // 18x18 = 324 work-items exceeds the Fury's limit.
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("i", QPoly::int(18)),
            LoopExtent::zero_to("j", QPoly::int(18)),
        ]);
        let mut k = Kernel::new("big_wg", &["n"], dom);
        k.add_array(ArrayDecl::global("x", crate::ir::DType::F32, vec![n]));
        k.add_stmt(Stmt::new(
            "s",
            LhsRef::Array(Access::new(
                "x",
                vec![AffExpr::scaled_var("i", 18).plus(&AffExpr::var("j"))],
            )),
            Expr::fconst(1.0),
            &["i", "j"],
        ));
        let k = tag_inames(&k, "i:l.1, j:l.0").unwrap();
        let amd = device_by_id("amd_r9_fury").unwrap();
        let err = simulate_time(&amd, &k, &env(1024)).unwrap_err();
        assert!(err.contains("CL_INVALID_WORK_GROUP_SIZE"), "{err}");
        let tv = device_by_id("titan_v").unwrap();
        assert!(simulate_time(&tv, &k, &env(1024)).is_ok());
    }

    #[test]
    fn measure_is_deterministic_and_near_true_time() {
        let pf = matmul(true);
        let d = device_by_id("gtx_titan_x").unwrap();
        let s1 = measure(&d, &pf, &env(1024)).unwrap();
        let s2 = measure(&d, &pf, &env(1024)).unwrap();
        assert_eq!(s1, s2);
        let truth = simulate_time(&d, &pf, &env(1024)).unwrap();
        assert!(
            (s1.time_s - truth).abs() / truth < 0.05,
            "{} vs {truth}",
            s1.time_s
        );
    }

    #[test]
    fn measured_energy_sits_above_the_idle_floor() {
        // Energy = idle power over the measured time plus dynamic
        // activity energy; any kernel that executes work must land
        // strictly above the idle floor, and its average power above
        // idle watts.
        let pf = matmul(true);
        for d in fleet() {
            let s = measure(&d, &pf, &env(1024)).unwrap();
            assert!(
                s.energy_j > d.idle_watts * s.time_s,
                "{}: {} J !> idle floor {} J",
                d.id,
                s.energy_j,
                d.idle_watts * s.time_s
            );
            assert!(
                s.avg_power_w() > d.idle_watts,
                "{}: avg power {} W !> idle {} W",
                d.id,
                s.avg_power_w(),
                d.idle_watts
            );
        }
    }

    #[test]
    fn energy_model_consumes_no_timing_rng() {
        // The timing noise stream must be unchanged from the bare-f64
        // days: the measured time is the deterministic noisy mean and
        // the energy is a pure function of it plus the deterministic
        // breakdown -- two calls agree exactly, and the time matches a
        // manual reconstruction from the breakdown's idle/dynamic split.
        let pf = matmul(true);
        let d = device_by_id("titan_v").unwrap();
        let s = measure(&d, &pf, &env(1024)).unwrap();
        let bd = simulate_breakdown(&d, &pf, &env(1024)).unwrap();
        assert!(bd.e_dynamic_j > 0.0, "{bd:?}");
        let expect = d.idle_watts * s.time_s + bd.e_dynamic_j;
        assert_eq!(s.energy_j, expect);
    }

    #[test]
    fn measure_with_cache_is_byte_identical_to_measure() {
        let pf = matmul(true);
        let cache = StatsCache::new();
        for d in fleet() {
            let fresh = measure(&d, &pf, &env(1024)).unwrap();
            let cached = measure_with_cache(&d, &pf, &env(1024), &cache).unwrap();
            assert_eq!(fresh, cached, "{}", d.id);
        }
        // One symbolic pass per distinct sub-group size in the fleet
        // (warp 32 on the NVIDIA parts, wavefront 64 on GCN3).
        assert_eq!(cache.misses(), 2);
        assert_eq!(cache.hits(), 3);
    }

    /// A local access whose stride polynomial references a parameter
    /// the measurement env does not bind must fail as a skippable
    /// per-kernel error, not abort the process (the sweep drivers
    /// skip such kernels exactly like unlaunchable ones).
    #[test]
    fn unevaluable_local_stride_is_a_per_kernel_error() {
        let pf = matmul(true);
        let d = device_by_id("titan_v").unwrap();
        let mut stats = crate::stats::gather(&pf, d.sub_group_size).unwrap();
        let local = stats
            .mem
            .iter_mut()
            .find(|m| m.scope == MemScope::Local)
            .expect("prefetch matmul has local accesses");
        local.lstrides[0] = QPoly::var("never_bound");
        let err =
            breakdown_from_stats(&d, &pf, &stats, &env(2048)).unwrap_err();
        assert!(err.contains(KERNEL_UNMEASURABLE), "{err}");
        assert!(err.contains("never_bound"), "{err}");
        assert!(is_per_kernel_measure_error(&err));
        assert!(is_per_kernel_measure_error("CL_INVALID_WORK_GROUP_SIZE: x"));
        assert!(!is_per_kernel_measure_error("singular normal equations"));
    }

    #[test]
    fn amd_anomalies_are_excluded() {
        let pf = matmul(true);
        let amd = device_by_id("amd_r9_fury").unwrap();
        let t = measure(&amd, &pf, &env(1024)).unwrap().time_s;
        let truth = simulate_time(&amd, &pf, &env(1024)).unwrap();
        // Without exclusion a single 1e5x trial would blow the mean up
        // by ~1e3x; with exclusion we stay near truth.
        assert!(t < 2.0 * truth, "anomaly leaked into mean: {t} vs {truth}");
    }

    #[test]
    fn launch_overhead_dominates_empty_kernels() {
        // An almost-empty kernel's time ~ kernel launch + wg launches,
        // and grows with the group count (paper §6.1.4).
        let n = QPoly::var("n");
        let dom = NestedDomain::new(vec![
            LoopExtent::zero_to("g", n.clone()),
            LoopExtent::zero_to("l", QPoly::int(256)),
        ]);
        let mut k = Kernel::new("empty", &["n"], dom);
        k.add_array(ArrayDecl::global("x", crate::ir::DType::F32, vec![n.clone()]));
        k.add_stmt(Stmt::new(
            "s",
            LhsRef::Array(Access::new("x", vec![AffExpr::var("g")])),
            Expr::fconst(0.0),
            &["g"],
        ));
        let k = tag_inames(&k, "g:g.0, l:l.0").unwrap();
        let d = device_by_id("titan_v").unwrap();
        let t_small = simulate_time(&d, &k, &env(16)).unwrap();
        let t_big = simulate_time(&d, &k, &env(65536)).unwrap();
        assert!(t_big > t_small * 1.5, "{t_small} vs {t_big}");
        assert!(t_small >= d.kernel_launch_us * 1e-6);
    }
}
