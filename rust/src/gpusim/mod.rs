//! Simulated GPU fleet — the measurement substrate.
//!
//! The paper evaluates on five physical GPUs (Table 2).  This
//! environment has none, so per the substitution rule (DESIGN.md §3)
//! we build a SIMT *cost simulator* per device.  Calibration still
//! treats each device as a black box: Perflex only ever sees wall
//! times.  Crucially, the simulator's cost structure is finer-grained
//! than the model's feature space — 128-byte transaction coalescing
//! enumerated over actual sub-group lane addresses, sequential-reuse
//! and DRAM row-locality effects, an L2 capacity model, bank
//! conflicts, wave quantization / partial-wave utilization, launch
//! overheads and device-specific memory/compute overlap, plus
//! log-normal measurement noise — so models must genuinely *fit*, and
//! the paper's qualitative cross-device differences (e.g. Kepler/Fermi
//! hiding almost no on-chip cost, AMD's 256-work-item limit) are
//! reproduced.
//!
//! Measurements are [`MeasuredSample`]s (wall time plus board energy
//! from a crude idle+activity power model), so calibration can target
//! responses other than time while the black-box loop stays closed
//! in-tree.

pub mod device;
pub mod exec;

pub use device::{
    device_by_id, fleet, DeviceProfile, DEFAULT_CACHELINE_BYTES,
    DEFAULT_LOCAL_MEM_BANKS, DEFAULT_SUB_GROUP_SIZE,
};
pub use exec::{
    is_per_kernel_measure_error, measure, measure_with_cache, simulate_time,
    simulate_time_with_cache, CostBreakdown, MeasuredSample,
    KERNEL_UNMEASURABLE,
};
