//! `perflex` — command-line driver for the cross-machine black-box GPU
//! performance-modeling framework.
//!
//! The CLI is hand-rolled (no clap in the offline crate set; see
//! Cargo.toml).  Sub-commands:
//!
//! ```text
//! perflex list-generators                 UiPiCK generator inventory
//! perflex list-devices                    the simulated fleet (Table 2)
//! perflex gen <tag>...                    generate measurement kernels
//! perflex show <tag>...                   print kernel schedule listings
//! perflex lint [--json] [--device <id>|--all-devices] [tag...]
//!                                         static kernel verifier
//! perflex measure <device> <tag>... [--store <dir>]
//! perflex calibrate <case> <device> [--store <dir>] [--target <name>]
//! perflex predict <case> <device> <variant> <k=v>... [--store <dir>]
//!               [--target <name>] [--sweep k=lo..hi[:step]]
//! perflex experiment <id>|all [--no-aot] [--json <dir>] [--store <dir>]
//! perflex store ls|stat|verify|gc|compact --store <dir> [--dry-run]
//!               [--temp-ttl-secs <n>] [--lease-ttl-secs <n>]
//! ```
//!
//! `lint` runs the static kernel verifier (`perflex::analysis`) over
//! the generated kernel inventory (all generators when no tags are
//! given), deduplicated by structural fingerprint.  `--device <id>`
//! (or `--all-devices`) additionally checks every kernel's derived
//! resource usage — work-group size, local-memory bytes, barrier
//! count — against the device's limits, re-runs the access-pattern
//! lints under the device's coalescing geometry, and prints per-device
//! feasibility lines (findings identical to a kernel-level one are
//! deduplicated, so device lines only carry what that device's
//! geometry adds); `--json` emits the stable `perflex-lint` report
//! document (schema version 3: per-kernel `feasibility` arrays plus
//! the access-pattern warning codes) instead of the human listing.
//! Exit codes are typed: 1 =
//! Error-severity findings (races, out-of-bounds accesses, barrier
//! defects, infeasible launches), 3 = a structurally malformed kernel
//! (`MALFORMED_KERNEL` — the input never was a valid GPU program),
//! 2 = usage or internal errors (every other command's failure code).
//!
//! `--target <name>` selects the response variable `calibrate` fits
//! and `predict` predicts: `time` (the default), `energy` or
//! `avg_power`.  Fits for different targets persist side by side in
//! the store; an unknown name is rejected with the valid list.
//!
//! `predict` runs on the compiled evaluation plan (see
//! `perflex::model::compiled`): the fitted model is lowered once to
//! flat f64 arithmetic and each query is a dense evaluation, agreeing
//! with the exact path within a documented relative-error bound.
//! `--sweep k=lo..hi[:step]` batch-predicts over a range of one size
//! variable (the remaining `k=v` bindings stay fixed), emitting one
//! JSON row per point on stdout — machine-readable input for
//! experiment tables and autotuning sweeps.  Duplicate `k=v` bindings
//! and malformed ranges are rejected with the offending argument
//! named.
//!
//! `--store <dir>` opens a persistent artifact store (see
//! `perflex::session`): symbolic kernel statistics and calibration
//! fits are written there, and later invocations start warm — a
//! `predict` against a fresh store runs zero LM iterations and zero
//! symbolic counting passes.  The store is fleet-wide: stats entries
//! are keyed by (kernel fingerprint, sub-group size), so calibrating a
//! second device with the same sub-group size against the same store
//! performs zero fresh counting passes (store-backed commands print
//! the cache + store-index ledgers so this is observable; a warm run
//! against a fresh index also reports zero full-artifact parses).
//! `perflex store` inspects (`ls`, `stat`, `verify`) and maintains
//! (`gc`, `compact`) a store: GC sweeps orphaned temp files and ages
//! out artifacts whose format version or model fingerprint no longer
//! matches anything this binary can produce; `compact` deduplicates
//! the sub-group-size-invariant stats sections shared between sg
//! families of one kernel; `verify` asserts the journaled index
//! equals a full rebuild scan.  The store is multi-process safe:
//! concurrent invocations serialize journal appends under a
//! cross-process writer lock, and destructive maintenance holds a
//! lease (`--lease-ttl-secs`) — a second `gc`/`compact` refuses with
//! a lease-held error instead of double-deleting.

use std::collections::BTreeMap;

use perflex::coordinator::{run_experiment_in_session, EXPERIMENT_IDS};
use perflex::gpusim::{device_by_id, fleet};
use perflex::session::Session;
use perflex::uipick::KernelCollection;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {}", e.msg);
            e.code
        }
    };
    std::process::exit(code);
}

/// A CLI failure carrying its process exit code.  2 is the historical
/// catch-all (usage mistakes, internal errors); `lint` distinguishes
/// defect findings (1) from malformed input kernels (3) so scripts —
/// and the autotune driver — can tell "your kernel is wrong" from
/// "your kernel is not a kernel".
struct CliError {
    code: i32,
    msg: String,
}

impl From<String> for CliError {
    fn from(msg: String) -> CliError {
        CliError { code: 2, msg }
    }
}

impl From<&str> for CliError {
    fn from(msg: &str) -> CliError {
        CliError::from(msg.to_string())
    }
}

fn usage() -> String {
    "usage: perflex <command> [...]\n\
     commands: list-generators | list-devices | gen | show | lint | \
     measure | calibrate | predict | experiment | store\n\
     lint [--json] [--device <id>|--all-devices] [tag...] statically \
     verifies kernels (races, bounds, barriers) and, per device, launch \
     feasibility (work-group size, local memory, occupancy)\n\
     global flag: --store <dir> persists calibration artifacts across runs\n\
     calibrate/predict flag: --target time|energy|avg_power (default: time)\n\
     predict flag: --sweep k=lo..hi[:step] emits one JSON row per point\n\
     store maintenance: perflex store ls|stat|verify|gc|compact --store <dir>\n\
     \x20    [--dry-run] [--temp-ttl-secs <n>] [--lease-ttl-secs <n>]\n\
     run `perflex experiment all` to reproduce the paper's evaluation"
        .to_string()
}

/// Remove `flag <value>` from `args`, returning the value if present.
/// A duplicated flag is an error, not a silent misparse: removing only
/// the first `--store a` of `--store a --store b` used to leave
/// `--store b` behind to be consumed as positional arguments.
fn take_flag_value(args: &mut Vec<String>, flag: &str) -> Result<Option<String>, String> {
    match args.iter().position(|a| a == flag) {
        Some(i) if i + 1 < args.len() => {
            let v = args.remove(i + 1);
            args.remove(i);
            if args.iter().any(|a| a == flag) {
                return Err(format!(
                    "{flag} given more than once; pass a single value"
                ));
            }
            Ok(Some(v))
        }
        Some(_) => Err(format!("{flag} needs a value")),
        None => Ok(None),
    }
}

/// Remove a boolean `flag` from `args`, returning whether it was given.
/// Boolean flags are idempotent, so duplicates are consumed rather
/// than left behind as stray positional arguments.
fn take_flag(args: &mut Vec<String>, flag: &str) -> bool {
    let before = args.len();
    args.retain(|a| a != flag);
    args.len() != before
}

/// The cache ledger store-backed commands end with: how many symbolic
/// counting passes actually ran vs were served from disk or memory,
/// and how the store answered — index hits vs full-artifact parses
/// (the probe/validate/classify parses the index eliminates; payload
/// decodes of vouched artifacts are the data fetch, not a probe).
/// The shared-store CI job asserts "0 fresh counting passes" here when
/// a sub-group twin already populated the store, and "0 full-artifact
/// parses" for warm runs against a fresh index.
fn print_ledger(session: &Session) {
    let (fresh, disk, mem) = session.cache().ledger();
    println!(
        "stats cache: {fresh} fresh counting passes, {disk} disk hits, \
         {mem} memory hits"
    );
    if let Some((hits, parses)) = session.store_ledger() {
        println!("store index: {hits} index hits, {parses} full-artifact parses");
    }
    if let Some((locks, contended)) = session.store_lock_ledger() {
        println!("store lock: {locks} acquisitions, {contended} contended");
    }
    // The compiled-path ledger proves predictions ran on the lowered
    // f64 plans rather than the exact evaluator; CI greps for it on
    // warm predicts.  Commands that never predict (measure, calibrate)
    // print nothing here.
    let (lowerings, hits, evals) = session.compiled_ledger();
    if lowerings > 0 || evals > 0 {
        println!(
            "compiled eval: {lowerings} lowerings, {hits} cache hits, \
             {evals} evaluations"
        );
    }
}

/// The store-index half of the ledger alone, for `perflex store`
/// subcommands (which operate on a bare store, not a session).
fn print_store_ledger(store: &perflex::session::ArtifactStore) {
    let (hits, parses) = store.ledger();
    println!("store index: {hits} index hits, {parses} full-artifact parses");
    let (locks, contended) = store.lock_ledger();
    println!("store lock: {locks} acquisitions, {contended} contended");
}

fn dispatch(mut args: Vec<String>) -> Result<(), CliError> {
    let store_dir = take_flag_value(&mut args, "--store")?;
    let cmd = args.first().cloned().ok_or_else(usage)?;
    let mut rest: Vec<String> = args[1..].to_vec();
    match cmd.as_str() {
        "list-generators" => {
            let c = KernelCollection::all();
            for g in &c.generators {
                println!("{:<20} tags: {:?}", g.name, g.tags);
                for (arg, dom) in &g.arg_domains {
                    println!("    {arg}: {dom:?}");
                }
            }
            Ok(())
        }
        "list-devices" => {
            for d in fleet() {
                println!(
                    "{:<14} {:<32} peak {:>5.1} TF, {:>4.0} GB/s, {} CUs",
                    d.id,
                    d.name,
                    d.peak_flops() / 1e12,
                    d.dram_gbps,
                    d.sm_count
                );
            }
            Ok(())
        }
        "gen" | "show" => {
            let tags: Vec<&str> = rest.iter().map(|s| s.as_str()).collect();
            if tags.is_empty() {
                return Err(
                    "gen/show needs filter tags, e.g. `perflex gen matmul_sq n:2048`"
                        .into(),
                );
            }
            let knls = KernelCollection::all().generate_kernels(&tags)?;
            println!("{} kernel(s)", knls.len());
            for k in &knls {
                println!(
                    "- {} (generator {}, env {:?})",
                    k.kernel.name, k.generator, k.env
                );
                if cmd == "show" {
                    let sched = perflex::schedule::linearize(&k.kernel)?;
                    print!("{}", sched.listing(&k.kernel));
                    println!();
                }
            }
            Ok(())
        }
        "lint" => {
            use perflex::analysis::{self, DiagCode, LintEntry, Severity};
            let json = take_flag(&mut rest, "--json");
            let all_devices = take_flag(&mut rest, "--all-devices");
            let device_flag = take_flag_value(&mut rest, "--device")?;
            if all_devices && device_flag.is_some() {
                return Err(
                    "pass either --device <id> or --all-devices, not both"
                        .into(),
                );
            }
            // Devices to run the feasibility pass against (none by
            // default: correctness checks are device-independent).
            let devices: Vec<perflex::gpusim::DeviceProfile> = if all_devices {
                fleet()
            } else {
                match device_flag {
                    Some(id) => vec![device_by_id(&id)
                        .ok_or_else(|| format!("unknown device '{id}'"))?],
                    None => Vec::new(),
                }
            };
            let tags: Vec<&str> = rest.iter().map(|s| s.as_str()).collect();
            // No tags = lint the whole inventory: every generator with
            // its full argument product, deduplicated structurally so
            // size-only twins verify once.
            let knls = KernelCollection::all().generate_kernels(&tags)?;
            let analyzer = analysis::Analyzer::new();
            let mut seen = std::collections::BTreeSet::new();
            let mut entries: Vec<LintEntry> = Vec::new();
            for k in &knls {
                if !seen.insert(k.kernel.fingerprint()) {
                    continue;
                }
                let diags = analyzer.check(&k.kernel);
                // A malformed kernel's one diagnostic already gates
                // everything; feasibility would just re-derive it.
                let feasibility = if diags
                    .iter()
                    .any(|d| d.code == DiagCode::MalformedKernel)
                {
                    Vec::new()
                } else {
                    devices
                        .iter()
                        .filter_map(|d| {
                            analysis::check_feasibility(&k.kernel, d).ok()
                        })
                        .map(|mut f| {
                            // Device-independent findings (the access
                            // lints under the default geometry) already
                            // print at kernel level; keep only what
                            // this device's geometry adds, so
                            // --all-devices does not repeat each
                            // finding N times.
                            f.diags.retain(|fd| {
                                !diags.iter().any(|kd| {
                                    kd.code == fd.code
                                        && kd.stmt == fd.stmt
                                        && kd.object == fd.object
                                })
                            });
                            f
                        })
                        .collect()
                };
                entries.push(LintEntry {
                    kernel: k.kernel.name.clone(),
                    generator: k.generator.clone(),
                    diags,
                    feasibility,
                });
            }
            let mut errors = 0usize;
            let mut warnings = 0usize;
            for e in &entries {
                for d in e.all_diags() {
                    match d.severity() {
                        Severity::Error => errors += 1,
                        Severity::Warn => warnings += 1,
                    }
                }
            }
            if json {
                println!("{}", analysis::report_to_json(&entries));
            } else {
                for e in &entries {
                    let clean = e.all_diags().next().is_none();
                    if clean {
                        println!("{:<28} [{}] OK", e.kernel, e.generator);
                    } else {
                        println!("{:<28} [{}]", e.kernel, e.generator);
                        for d in &e.diags {
                            println!("    {d}");
                        }
                    }
                    for f in &e.feasibility {
                        let resident = match f.resident_wgs {
                            Some(n) => n.to_string(),
                            None => "?".to_string(),
                        };
                        println!(
                            "    @{:<12} wg {:>4}  lmem {:>6} B  \
                             resident {resident}/SM  {}",
                            f.device,
                            f.usage.wg_size,
                            f.usage.local_mem_bytes,
                            if f.launchable() { "ok" } else { "INFEASIBLE" }
                        );
                        for d in &f.diags {
                            println!("        {d}");
                        }
                    }
                }
                println!(
                    "{} kernel(s): {} error(s), {} warning(s)",
                    entries.len(),
                    errors,
                    warnings
                );
            }
            let malformed = entries
                .iter()
                .filter(|e| {
                    e.diags
                        .iter()
                        .any(|d| d.code == DiagCode::MalformedKernel)
                })
                .count();
            if malformed > 0 {
                return Err(CliError {
                    code: 3,
                    msg: format!(
                        "lint hit {malformed} malformed kernel(s) across {} \
                         kernel(s)",
                        entries.len()
                    ),
                });
            }
            if errors > 0 {
                return Err(CliError {
                    code: 1,
                    msg: format!(
                        "lint found {errors} error(s) across {} kernel(s)",
                        entries.len()
                    ),
                });
            }
            Ok(())
        }
        "measure" => {
            let dev_id = rest.first().ok_or("measure <device> <tag>...")?;
            let device = device_by_id(dev_id)
                .ok_or_else(|| format!("unknown device '{dev_id}'"))?;
            let tags: Vec<&str> = rest[1..].iter().map(|s| s.as_str()).collect();
            let knls = KernelCollection::all().generate_kernels(&tags)?;
            // One session for the whole sweep: kernels repeated across
            // problem sizes are symbolically counted once (and served
            // from the artifact store when one is given).
            let session = Session::from_store_arg(store_dir.as_deref())?;
            for k in &knls {
                match session.measure(&device, &k.kernel, &k.env) {
                    Ok(s) => println!(
                        "{:<28} {:?} -> {}",
                        k.kernel.name,
                        k.env,
                        perflex::coordinator::report::fmt_time(s.time_s)
                    ),
                    Err(e) => {
                        println!("{:<28} {:?} -> ERROR {e}", k.kernel.name, k.env)
                    }
                }
            }
            if store_dir.is_some() {
                print_ledger(&session);
            }
            Ok(())
        }
        "calibrate" | "predict" => {
            // `--target` picks the response variable (default: time).
            // Parse errors name the valid set, so a typo is caught
            // before any measurement work starts.
            let target = match take_flag_value(&mut rest, "--target")? {
                Some(name) => perflex::calibrate::Target::parse(&name)?,
                None => perflex::calibrate::Target::Time,
            };
            // `--sweep` batch-predicts over one size variable; parse
            // (and reject malformed ranges) before any calibration
            // work starts.
            let sweep = match take_flag_value(&mut rest, "--sweep")? {
                Some(arg) => Some(parse_sweep(&arg)?),
                None => None,
            };
            if cmd == "calibrate" && sweep.is_some() {
                return Err("--sweep only applies to predict".into());
            }
            let case_id = rest
                .first()
                .ok_or("calibrate <case:matmul|dg|fdiff> <device>")?;
            let dev_id = rest.get(1).ok_or("missing device")?;
            let device = device_by_id(dev_id)
                .ok_or_else(|| format!("unknown device '{dev_id}'"))?;
            let case = perflex::coordinator::expsets::eval_case(case_id)
                .ok_or_else(|| format!("unknown case '{case_id}' (matmul|dg|fdiff)"))?;
            let aot = if perflex::runtime::artifacts_available() {
                Some(perflex::runtime::Artifacts::load()?)
            } else {
                None
            };
            // One session per CLI invocation: calibration and the
            // optional prediction below share symbolic passes, and a
            // `--store` session persists them for the next run.
            let session = Session::from_store_arg(store_dir.as_deref())?;
            let cal =
                session.calibrate_case_for(&case, &device, true, aot.as_ref(), target)?;
            // Time runs print exactly the pre-target lines (the CI
            // byte-identity job diffs this output); other targets name
            // themselves.
            let tgt = match target {
                perflex::calibrate::Target::Time => String::new(),
                t => format!(" [target {}]", t.name()),
            };
            if cal.from_store {
                println!(
                    "calibration for {} on {}{tgt} loaded from artifact store \
                     ({} params, residual {:.3e}; 0 LM iterations this run)",
                    case.id,
                    device.id,
                    cal.fit.params.len(),
                    cal.fit.residual,
                );
                if !cal.fit.converged {
                    eprintln!(
                        "warning: the stored {} fit for {} on {} did not \
                         converge (it stopped at the LM iteration cap); \
                         consider re-calibrating",
                        cal.fit.target.name(),
                        case.id,
                        device.id
                    );
                }
            } else {
                println!(
                    "calibrated {} on {}{tgt} ({} params, residual {:.3e}, {} LM iters{})",
                    case.id,
                    device.id,
                    cal.fit.params.len(),
                    cal.fit.residual,
                    cal.fit.iterations,
                    if aot.is_some() {
                        ", AOT path"
                    } else {
                        ", native path"
                    }
                );
            }
            for (n, v) in cal.fit.param_names.iter().zip(&cal.fit.params) {
                println!("    {n:<40} = {v:.4e}");
            }
            if cmd == "predict" {
                let variant = rest.get(2).ok_or("predict ... <variant> <k=v>...")?;
                let env = parse_size_bindings(&rest[3..])?;
                if let Some(sw) = &sweep {
                    if let Some(fixed) = env.get(&sw.var) {
                        return Err(format!(
                            "size variable '{}' is both swept (--sweep) and \
                             fixed ({}={fixed}); drop one of the two",
                            sw.var, sw.var
                        )
                        .into());
                    }
                }
                let kernel = build_variant(case_id, variant)?.freeze();
                match &sweep {
                    // Batched prediction over the compiled plan: one
                    // JSON row per point, predictions only (sweeps are
                    // what-if queries, not measurements).
                    Some(sw) => {
                        use perflex::util::json::Json;
                        let rows = session.predict_sweep(
                            &cal.cm,
                            &cal.fit,
                            &kernel,
                            &env,
                            &sw.var,
                            &sw.values(),
                            &device,
                        )?;
                        for (x, v) in rows {
                            let mut sizes: BTreeMap<String, Json> = env
                                .iter()
                                .map(|(k, v)| (k.clone(), Json::from(*v)))
                                .collect();
                            sizes.insert(sw.var.clone(), Json::from(x));
                            let mut row: BTreeMap<String, Json> = BTreeMap::new();
                            row.insert("sizes".into(), Json::Obj(sizes));
                            row.insert("predicted".into(), Json::from(v));
                            row.insert("unit".into(), Json::from(target.unit()));
                            if target != perflex::calibrate::Target::Time {
                                row.insert("target".into(), Json::from(target.name()));
                            }
                            println!("{}", Json::Obj(row));
                        }
                    }
                    None => {
                        // Single queries run on the same compiled plan
                        // (the CI-asserted warm hot path); the exact
                        // evaluator remains the reference the plan is
                        // equivalence-tested against.
                        let predicted = session
                            .predict_compiled(&cal.cm, &cal.fit, &kernel, &env, &device)?;
                        let measured =
                            target.of(&session.measure(&device, &kernel, &env)?);
                        // fmt_target(Time, ·) == fmt_time(·), so time output is
                        // byte-identical to the pre-target renderer.
                        println!(
                            "predicted {} / measured {} (err {:.1}%)",
                            perflex::coordinator::report::fmt_target(target, predicted),
                            perflex::coordinator::report::fmt_target(target, measured),
                            100.0 * (predicted - measured).abs() / measured
                        );
                    }
                }
            }
            if store_dir.is_some() {
                print_ledger(&session);
            }
            Ok(())
        }
        "experiment" => {
            let use_aot = !take_flag(&mut rest, "--no-aot");
            let json_dir = take_flag_value(&mut rest, "--json")?
                .map(std::path::PathBuf::from);
            let id = rest
                .first()
                .ok_or_else(|| format!("experiment <id>; known: {EXPERIMENT_IDS:?}"))?;
            // Fail on an unusable --json directory *before* the run,
            // not after minutes of fleet calibration.
            if let Some(dir) = &json_dir {
                perflex::util::ensure_writable_dir(dir, "--json directory")?;
            }
            let session = Session::from_store_arg(store_dir.as_deref())?;
            let rep = run_experiment_in_session(id, use_aot, &session)?;
            print!("{}", rep.render());
            if let Some(dir) = json_dir {
                rep.write_json(&dir)?;
                println!("(json written to {}/{}.json)", dir.display(), rep.id);
            }
            if store_dir.is_some() {
                print_ledger(&session);
            }
            Ok(())
        }
        "store" => {
            let dry_run = take_flag(&mut rest, "--dry-run");
            let temp_ttl_secs = match take_flag_value(&mut rest, "--temp-ttl-secs")? {
                Some(v) => v
                    .parse::<u64>()
                    .map_err(|_| format!("--temp-ttl-secs: bad integer '{v}'"))?,
                None => perflex::session::GcOptions::default().temp_ttl_secs,
            };
            // How long this run's maintenance lease fences out other
            // destructive maintainers (a crashed gc/compact blocks the
            // fleet for at most this long).
            let lease_ttl_secs = match take_flag_value(&mut rest, "--lease-ttl-secs")?
            {
                Some(v) => v
                    .parse::<u64>()
                    .map_err(|_| format!("--lease-ttl-secs: bad integer '{v}'"))?,
                None => perflex::session::DEFAULT_LEASE_TTL_SECS,
            };
            let sub = rest
                .first()
                .ok_or("store <ls|stat|verify|gc|compact> --store <dir>")?
                .clone();
            let dir = store_dir
                .ok_or("store commands need --store <dir> (the store to operate on)")?;
            // Maintenance commands inspect an *existing* store; opening
            // would silently create directories at a mistyped path.
            if !std::path::Path::new(&dir).is_dir() {
                return Err(format!(
                    "store directory '{dir}' does not exist (store \
                     ls/stat/gc never create one)"
                )
                .into());
            }
            let store = perflex::session::ArtifactStore::open(&dir)?;
            // Fits are reachable while this binary can still mint their
            // model fingerprint (eval cases x fleet x forms, plus the
            // experiment harness fits).
            let reachable = perflex::session::reachable_fit_fingerprints();
            let unreachable = |info: &perflex::session::ArtifactInfo| {
                info.kind == perflex::session::ArtifactKind::Fit
                    && info
                        .model_fingerprint
                        .is_some_and(|fp| !reachable.contains(&fp))
            };
            match sub.as_str() {
                "ls" => {
                    for info in store.list()? {
                        let kind = match info.kind {
                            perflex::session::ArtifactKind::Stats => "stats",
                            perflex::session::ArtifactKind::Fit => "fit",
                            perflex::session::ArtifactKind::Shared => "shared",
                            perflex::session::ArtifactKind::Temp => "temp",
                            perflex::session::ArtifactKind::Other => "other",
                        };
                        // Temps are possibly-live writes, not staleness.
                        let status = match info.kind {
                            perflex::session::ArtifactKind::Temp => "temp",
                            perflex::session::ArtifactKind::Other => "ok",
                            _ if !info.valid => "STALE",
                            _ if unreachable(&info) => "UNREACHABLE",
                            _ => "ok",
                        };
                        println!(
                            "{kind:<6} {:>9}B {status:<12} {}",
                            info.bytes, info.describe
                        );
                    }
                    print_store_ledger(&store);
                    Ok(())
                }
                "stat" => {
                    let infos = store.list()?;
                    let count = |k: perflex::session::ArtifactKind| {
                        let matching: Vec<_> =
                            infos.iter().filter(|i| i.kind == k).collect();
                        (
                            matching.len(),
                            matching.iter().map(|i| i.bytes).sum::<u64>(),
                        )
                    };
                    let (n_stats, b_stats) = count(perflex::session::ArtifactKind::Stats);
                    let (n_fits, b_fits) = count(perflex::session::ArtifactKind::Fit);
                    let (n_shared, b_shared) =
                        count(perflex::session::ArtifactKind::Shared);
                    let (n_temp, b_temp) = count(perflex::session::ArtifactKind::Temp);
                    // Temp files are counted on their own line above,
                    // not as staleness — a mid-write temp is healthy.
                    let stale = infos
                        .iter()
                        .filter(|i| {
                            !i.valid
                                && matches!(
                                    i.kind,
                                    perflex::session::ArtifactKind::Stats
                                        | perflex::session::ArtifactKind::Fit
                                        | perflex::session::ArtifactKind::Shared
                                )
                        })
                        .count();
                    let dead_fits = infos.iter().filter(|i| unreachable(i)).count();
                    let (ix_stats, ix_fits, ix_shared) = store.index_counts();
                    println!("store root: {}", store.root().display());
                    println!(
                        "format version: {}",
                        perflex::session::STORE_FORMAT_VERSION
                    );
                    println!("stats artifacts: {n_stats} ({b_stats} bytes)");
                    println!("fit artifacts: {n_fits} ({b_fits} bytes)");
                    println!("shared sections: {n_shared} ({b_shared} bytes)");
                    println!("temp files: {n_temp} ({b_temp} bytes)");
                    println!("stale or corrupt: {stale}");
                    println!("unreachable fits: {dead_fits}");
                    println!(
                        "index entries: {ix_stats} stats, {ix_fits} fits, \
                         {ix_shared} shared"
                    );
                    print_store_ledger(&store);
                    Ok(())
                }
                "verify" => {
                    let outcome = store.verify_index()?;
                    let (ix_stats, ix_fits, ix_shared) = outcome.indexed;
                    let (sc_stats, sc_fits, sc_shared) = outcome.scanned;
                    println!(
                        "index entries: {ix_stats} stats, {ix_fits} fits, \
                         {ix_shared} shared"
                    );
                    println!(
                        "rebuild scan:  {sc_stats} stats, {sc_fits} fits, \
                         {sc_shared} shared"
                    );
                    print_store_ledger(&store);
                    if outcome.matches {
                        println!("index matches a full rebuild scan");
                        Ok(())
                    } else {
                        Err("store index does not match a full rebuild scan \
                             (a `store gc` checkpoint, or the next open's \
                             rebuild, will heal it)"
                            .into())
                    }
                }
                "gc" => {
                    let outcome = store.gc(&perflex::session::GcOptions {
                        reachable_fits: Some(&reachable),
                        temp_ttl_secs,
                        lease_ttl_secs,
                        dry_run,
                    })?;
                    let verb = if dry_run { "would remove" } else { "removed" };
                    for (path, reason) in &outcome.removed {
                        println!("{verb} {} ({reason})", path.display());
                    }
                    println!(
                        "{verb} {} of {} artifact(s), {} bytes reclaimed",
                        outcome.removed.len(),
                        outcome.scanned,
                        outcome.reclaimed_bytes
                    );
                    print_store_ledger(&store);
                    Ok(())
                }
                "compact" => {
                    let outcome = store.compact(lease_ttl_secs)?;
                    println!(
                        "compacted {} of {} sub-group famil{} ({} artifacts \
                         rewritten, {} shared sections, {} skipped), {} bytes \
                         reclaimed",
                        outcome.shared_sections,
                        outcome.families,
                        if outcome.families == 1 { "y" } else { "ies" },
                        outcome.rewritten,
                        outcome.shared_sections,
                        outcome.skipped,
                        outcome.reclaimed_bytes
                    );
                    print_store_ledger(&store);
                    Ok(())
                }
                other => Err(format!(
                    "unknown store subcommand '{other}' \
                     (ls|stat|verify|gc|compact)"
                )
                .into()),
            }
        }
        "--help" | "-h" | "help" => {
            println!("{}", usage());
            Ok(())
        }
        other => Err(format!("unknown command '{other}'\n{}", usage()).into()),
    }
}

/// Parse `k=v` size bindings.  A duplicate binding is an error naming
/// the offending argument, not a silent overwrite: `n=1024 n=2048`
/// used to predict at 2048 while the user thought both were honored.
fn parse_size_bindings(args: &[String]) -> Result<BTreeMap<String, i64>, String> {
    let mut env = BTreeMap::new();
    for kv in args {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| format!("expected k=v, got '{kv}'"))?;
        let v: i64 = v
            .parse()
            .map_err(|_| format!("bad integer in size binding '{kv}'"))?;
        if env.insert(k.to_string(), v).is_some() {
            return Err(format!(
                "size variable '{k}' bound more than once \
                 (duplicate binding '{kv}')"
            ));
        }
    }
    Ok(env)
}

/// A parsed `--sweep k=lo..hi[:step]` range (inclusive bounds,
/// positive step, step defaults to 1).
#[derive(Clone, Debug, PartialEq)]
struct Sweep {
    var: String,
    lo: i64,
    hi: i64,
    step: i64,
}

impl Sweep {
    fn values(&self) -> Vec<i64> {
        let mut out = Vec::new();
        let mut x = self.lo;
        while x <= self.hi {
            out.push(x);
            x += self.step;
        }
        out
    }
}

/// Parse a `--sweep` argument; every rejection names the argument it
/// is rejecting so a malformed range in a long command line is
/// findable.
fn parse_sweep(arg: &str) -> Result<Sweep, String> {
    let err = |why: String| format!("--sweep {arg}: {why} (expected k=lo..hi[:step])");
    let (var, range) = arg
        .split_once('=')
        .ok_or_else(|| err("missing '='".into()))?;
    if var.is_empty() {
        return Err(err("empty variable name".into()));
    }
    let (range, step) = match range.split_once(':') {
        Some((r, s)) => (
            r,
            s.parse::<i64>()
                .map_err(|_| err(format!("bad step '{s}'")))?,
        ),
        None => (range, 1),
    };
    let (lo, hi) = range
        .split_once("..")
        .ok_or_else(|| err("missing '..'".into()))?;
    let lo: i64 = lo
        .parse()
        .map_err(|_| err(format!("bad lower bound '{lo}'")))?;
    let hi: i64 = hi
        .parse()
        .map_err(|_| err(format!("bad upper bound '{hi}'")))?;
    if step <= 0 {
        return Err(err(format!("step must be positive, got {step}")));
    }
    if lo > hi {
        return Err(err(format!("empty range ({lo} > {hi})")));
    }
    Ok(Sweep {
        var: var.to_string(),
        lo,
        hi,
        step,
    })
}

fn build_variant(case: &str, variant: &str) -> Result<perflex::ir::Kernel, String> {
    use perflex::uipick::apps::*;
    match (case, variant) {
        ("matmul", "prefetch") => build_matmul(perflex::ir::DType::F32, true, 16),
        ("matmul", "no_prefetch") => build_matmul(perflex::ir::DType::F32, false, 16),
        ("dg", v) => build_dg(DgVariant::parse(v)?, 64, 16),
        ("fdiff", "16x16") => build_fdiff(16),
        ("fdiff", "18x18") => build_fdiff(18),
        _ => Err(format!("unknown variant '{variant}' for case '{case}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::{parse_size_bindings, parse_sweep, take_flag, take_flag_value, Sweep};

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn take_flag_value_extracts_and_leaves_the_rest() {
        let mut a = args(&["calibrate", "--store", "/tmp/s", "matmul", "titan_v"]);
        assert_eq!(
            take_flag_value(&mut a, "--store").unwrap().as_deref(),
            Some("/tmp/s")
        );
        assert_eq!(a, args(&["calibrate", "matmul", "titan_v"]));
        assert_eq!(take_flag_value(&mut a, "--store").unwrap(), None);
        assert_eq!(a, args(&["calibrate", "matmul", "titan_v"]));
    }

    /// The duplicate-flag regression: `--store a --store b` used to
    /// consume only `--store a` and leave `--store b` behind as two
    /// stray positional arguments.
    #[test]
    fn take_flag_value_rejects_duplicate_flags() {
        let mut a = args(&["calibrate", "--store", "a", "--store", "b", "matmul"]);
        let err = take_flag_value(&mut a, "--store").unwrap_err();
        assert!(err.contains("--store"), "{err}");
        assert!(err.contains("more than once"), "{err}");
    }

    #[test]
    fn take_flag_value_requires_a_value() {
        let mut a = args(&["store", "gc", "--temp-ttl-secs"]);
        assert!(take_flag_value(&mut a, "--temp-ttl-secs")
            .unwrap_err()
            .contains("needs a value"));
    }

    #[test]
    fn take_flag_consumes_every_occurrence() {
        let mut a = args(&["experiment", "--dry-run", "fig5", "--dry-run"]);
        assert!(take_flag(&mut a, "--dry-run"));
        assert_eq!(
            a,
            args(&["experiment", "fig5"]),
            "no stray flag copy may survive as a positional argument"
        );
        assert!(!take_flag(&mut a, "--dry-run"));
    }

    #[test]
    fn size_bindings_parse_and_reject_duplicates() {
        let env = parse_size_bindings(&args(&["n=2048", "m=16"])).unwrap();
        assert_eq!(env.get("n"), Some(&2048));
        assert_eq!(env.get("m"), Some(&16));

        let err = parse_size_bindings(&args(&["n=1024", "n=2048"])).unwrap_err();
        assert!(err.contains("'n'"), "{err}");
        assert!(err.contains("n=2048"), "{err}");

        let err = parse_size_bindings(&args(&["n2048"])).unwrap_err();
        assert!(err.contains("n2048"), "{err}");
        let err = parse_size_bindings(&args(&["n=big"])).unwrap_err();
        assert!(err.contains("n=big"), "{err}");
    }

    #[test]
    fn sweep_parses_ranges_and_steps() {
        assert_eq!(
            parse_sweep("n=1024..4096:1024").unwrap(),
            Sweep {
                var: "n".into(),
                lo: 1024,
                hi: 4096,
                step: 1024,
            }
        );
        // Step defaults to 1; bounds are inclusive.
        assert_eq!(parse_sweep("k=3..6").unwrap().values(), vec![3, 4, 5, 6]);
        // A step that overshoots still includes the lower bound.
        assert_eq!(parse_sweep("k=5..9:10").unwrap().values(), vec![5]);
    }

    #[test]
    fn sweep_rejections_name_the_argument() {
        for bad in [
            "n",            // missing '='
            "=1..4",        // empty variable
            "n=14",         // missing '..'
            "n=a..4",       // bad lower bound
            "n=1..b",       // bad upper bound
            "n=1..4:x",     // bad step
            "n=1..4:0",     // non-positive step
            "n=1..4:-2",    // negative step
            "n=9..1",       // empty range
        ] {
            let err = parse_sweep(bad).unwrap_err();
            assert!(err.contains(bad), "error for '{bad}' must name it: {err}");
            assert!(err.contains("k=lo..hi[:step]"), "{err}");
        }
    }
}
