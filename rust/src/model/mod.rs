//! Perflex performance models (paper Section 6).
//!
//! A model is an output feature (typically `f_cl_wall_time_<device>`)
//! approximated by an arithmetic expression over input features
//! (`f_...`) and hardware-dependent parameters (`p_...`):
//!
//! ```text
//! Model::new(
//!     "f_cl_wall_time_titan_v",
//!     "p_f32madd * f_op_float32_madd + p_f32l * f_mem_access_local_float32",
//! )
//! ```
//!
//! Expressions support `+ - * /`, parentheses, numeric literals and
//! `tanh(...)` — enough to express the nonlinear overlap model (Eq. 8).
//! [`expr::ModelExpr`] provides native evaluation and the symbolic
//! differentiation w.r.t. parameters that calibration requires; the
//! [`cost_model`] module provides the paper's three-cost-component
//! builtin family, which additionally maps onto the AOT JAX/Pallas
//! `lm_step` artifact.

pub mod compiled;
pub mod cost_model;
pub mod expr;

pub use compiled::{CompiledModel, COMPILED_REL_ERR_BOUND};
pub use cost_model::{CostGroup, CostModel, CostTerm};
pub use expr::ModelExpr;

use crate::features::FeatureSpec;

/// A performance model: output feature ≈ expression.
#[derive(Clone, Debug, PartialEq)]
pub struct Model {
    pub output: FeatureSpec,
    pub expr: ModelExpr,
}

impl Model {
    pub fn new(output: &str, expr_text: &str) -> Result<Model, String> {
        let output = FeatureSpec::parse(output)?;
        let expr = ModelExpr::parse(expr_text)?;
        // Validate embedded feature identifiers eagerly.
        for f in expr.features() {
            FeatureSpec::parse(&f)?;
        }
        Ok(Model { output, expr })
    }

    /// Parameter names in deterministic order.
    pub fn params(&self) -> Vec<String> {
        self.expr.params()
    }

    /// Input feature identifiers in deterministic order.
    pub fn input_features(&self) -> Vec<String> {
        self.expr.features()
    }

    /// All features (inputs plus the output), parsed.
    pub fn all_features(&self) -> Result<Vec<FeatureSpec>, String> {
        let mut out = vec![self.output.clone()];
        for f in self.input_features() {
            out.push(FeatureSpec::parse(&f)?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_from_paper_section_2_2() {
        let m = Model::new(
            "f_cl_wall_time_nvidia_geforce",
            "p_f32madd * f_op_float32_madd",
        )
        .unwrap();
        assert_eq!(m.params(), vec!["p_f32madd"]);
        assert_eq!(m.input_features(), vec!["f_op_float32_madd"]);
        assert!(m.output.is_wall_time());
    }

    #[test]
    fn model_with_tagged_accesses() {
        let m = Model::new(
            "f_cl_wall_time_nvidia_geforce",
            "p_f32madd * f_op_float32_madd + \
             p_f32l * f_mem_access_local_float32 + \
             p_f32ga * f_mem_access_tag:aLD + \
             p_f32gb * f_mem_access_tag:bLD + \
             p_f32gc * f_mem_access_global_float32_store",
        )
        .unwrap();
        assert_eq!(m.params().len(), 5);
        assert_eq!(m.input_features().len(), 5);
    }

    #[test]
    fn rejects_malformed_features() {
        assert!(Model::new("f_cl_wall_time_x", "p_a * f_bogus_feature").is_err());
    }
}
