//! Compiled model evaluation: the batched-prediction hot path.
//!
//! Warm prediction pays no counting passes and no LM iterations, but
//! the exact evaluator ([`crate::calibrate::eval_with_stats`]) still
//! re-parses feature identifiers, walks `QPoly` trees with `Rat`
//! i128 gcd arithmetic per monomial, and round-trips every value
//! through name-keyed `BTreeMap`s — per query.  A [`CompiledModel`]
//! does all of that once: it lowers a fitted [`CostModel`] bound to
//! one kernel's [`KernelStats`] into flat f64 plans
//! ([`crate::polyhedral::PolyPlan`] per feature, coefficients fetched
//! from the [`FitResult`] up front), after which each evaluation is a
//! few dense loops over a value slice — no allocation, no map lookups,
//! no rational arithmetic.  This is ROADMAP item 2's "millions of
//! model evaluations per second" engine for sweeps, capacity planning
//! and the autotuning arc.
//!
//! # Accuracy: the compiled-vs-exact contract
//!
//! Exactness stays in calibration; the compiled path is a *prediction*
//! fast path checked against the exact path.  The guarantee:
//!
//! > For every environment on which the exact path succeeds, the
//! > compiled prediction agrees within [`COMPILED_REL_ERR_BOUND`]
//! > relative error.
//!
//! Where the two paths can differ, and why the bound holds:
//!
//! * **Feature polynomials.**  The exact path evaluates each `QPoly`
//!   in rational arithmetic and rounds once at the end; the compiled
//!   plan accumulates in f64.  Both visit monomials in the same order,
//!   so the divergence is ordinary floating-point rounding — a few ulp
//!   per term ([`crate::polyhedral::PolyPlan`] documents the summation
//!   bound).  Counting polynomials have single-digit degrees and a few
//!   dozen terms, keeping this at ~1e-13 relative in practice.
//! * **Floor boundaries.**  `floor` factors snap near-integer
//!   arguments before truncating (see `FLOOR_SNAP_TOL` in
//!   `polyhedral::qpoly`), so arguments that are exactly integral in
//!   rational arithmetic truncate identically; a genuinely fractional
//!   argument is at least one part in `den·D` away from the boundary
//!   (D = the lcm of coefficient denominators), out of reach of ulp
//!   noise until the floor's unit error is itself below the relative
//!   bound.
//! * **Filter re-checks.**  Parametric-stride and AFR constraints are
//!   re-evaluated per environment on both paths with the same 1e-9
//!   comparison epsilons; compiled check values differ from exact ones
//!   by ulps, far inside those epsilons for the integer-valued strides
//!   and well-separated AFR values the counting pass produces.
//! * **Model combination.**  The compiled combiner reproduces
//!   [`CostModel::to_model`]'s expression tree exactly — same per-term
//!   `p·f` products, same left-associated group sums in term order,
//!   same `(o + a) + b` / tanh-switch association — so no new rounding
//!   is introduced at this level.  The nonlinear switch can *amplify*
//!   a feature-level perturbation by at most
//!   `1 + sup|x·sech²(x)| ≈ 1.45` in the relevant regime, which is
//!   why [`COMPILED_REL_ERR_BOUND`] carries generous headroom over the
//!   observed ~1e-12.
//!
//! The contract is enforced by `tests/compiled_equivalence.rs`
//! (property-tested over every eval case, fleet device and calibration
//! target, including degenerate and near-i128-overflow sizes) and by
//! unit tests here.

use std::collections::BTreeMap;

use crate::calibrate::{FitResult, Target};
use crate::features::{CompiledFeature, FeatureSpec};
use crate::model::cost_model::{CostModel, EDGE_PARAM};
use crate::stats::KernelStats;

/// Maximum relative error of a compiled prediction versus the exact
/// path, on any environment where the exact path succeeds.  See the
/// module docs for the derivation; typical agreement is ~1e-12 and the
/// bound carries headroom for tanh-switch amplification and deep
/// floor nests.
pub const COMPILED_REL_ERR_BOUND: f64 = 1e-6;

/// A fitted cost model lowered to a flat f64 evaluation plan for one
/// kernel: fitted coefficients × compiled feature plans over a shared
/// size-variable table.  Build with [`CompiledModel::compile`];
/// evaluate with [`CompiledModel::eval_env`] (name-keyed convenience)
/// or [`CompiledModel::eval_slots`] (the allocation-free batch form —
/// bind once, then mutate the value slice between calls).
#[derive(Clone, Debug)]
pub struct CompiledModel {
    /// Size-variable names; `vals[i]` in [`CompiledModel::eval_slots`]
    /// is the value of `vars[i]`.
    vars: Vec<String>,
    /// One compiled feature per cost term, in `CostModel::terms` order
    /// (duplicated feature names stay duplicated — they compile to
    /// identical plans, preserving the exact path's term structure).
    features: Vec<CompiledFeature>,
    /// Fitted coefficient for each term.
    coeffs: Vec<f64>,
    /// Cost group of each term (`CostGroup as u8`).
    groups: Vec<u8>,
    /// Fitted `p_edge` for the nonlinear overlap form; `None` for the
    /// linear form.
    edge: Option<f64>,
    target: Target,
}

impl CompiledModel {
    /// Lower `cm` with fitted parameters `fit` against one kernel's
    /// statistics.  Fails if the fit is missing a term's parameter
    /// (or `p_edge` for the nonlinear form), or a term's feature
    /// cannot be parsed/bound (e.g. a wall-time input feature).
    pub fn compile(
        cm: &CostModel,
        fit: &FitResult,
        stats: &KernelStats,
    ) -> Result<CompiledModel, String> {
        let mut vars: Vec<String> = Vec::new();
        let mut features = Vec::with_capacity(cm.terms.len());
        let mut coeffs = Vec::with_capacity(cm.terms.len());
        let mut groups = Vec::with_capacity(cm.terms.len());
        {
            let mut slot = |name: &str| -> u32 {
                match vars.iter().position(|v| v == name) {
                    Some(i) => i as u32,
                    None => {
                        vars.push(name.to_string());
                        (vars.len() - 1) as u32
                    }
                }
            };
            for t in &cm.terms {
                let coeff = fit.param(&t.param).ok_or_else(|| {
                    format!(
                        "compile: fit ({} params) is missing parameter '{}' \
                         for feature '{}'",
                        fit.param_names.len(),
                        t.param,
                        t.feature
                    )
                })?;
                let spec = FeatureSpec::parse(&t.feature)?;
                let bound = spec.bind(stats)?;
                features.push(bound.lower(stats, &mut slot));
                coeffs.push(coeff);
                groups.push(t.group as u8);
            }
        }
        let edge = if cm.nonlinear {
            Some(fit.param(EDGE_PARAM).ok_or_else(|| {
                format!("compile: nonlinear fit is missing '{EDGE_PARAM}'")
            })?)
        } else {
            None
        };
        Ok(CompiledModel {
            vars,
            features,
            coeffs,
            groups,
            edge,
            target: fit.target,
        })
    }

    /// Size-variable names, in slot order.
    pub fn vars(&self) -> &[String] {
        &self.vars
    }

    /// Slot index of a size variable, if the model depends on it.
    pub fn slot_of(&self, name: &str) -> Option<usize> {
        self.vars.iter().position(|v| v == name)
    }

    /// The calibration target the fitted coefficients explain.
    pub fn target(&self) -> Target {
        self.target
    }

    /// Resolve a name-keyed environment to a slot-ordered value vector
    /// for [`CompiledModel::eval_slots`]; errors name the first
    /// unbound size variable.  Extra bindings are ignored, matching
    /// the exact path.
    pub fn bind_env(&self, env: &BTreeMap<String, i64>) -> Result<Vec<f64>, String> {
        self.vars
            .iter()
            .map(|v| {
                env.get(v).map(|x| *x as f64).ok_or_else(|| {
                    format!("unbound size variable '{v}' (bind it as {v}=<int>)")
                })
            })
            .collect()
    }

    /// Single-query convenience: [`CompiledModel::bind_env`] +
    /// [`CompiledModel::eval_slots`].
    pub fn eval_env(&self, env: &BTreeMap<String, i64>) -> Result<f64, String> {
        Ok(self.eval_slots(&self.bind_env(env)?))
    }

    /// The hot path: evaluate at one point of a batch.  `vals` is
    /// indexed by [`CompiledModel::vars`] (see
    /// [`CompiledModel::bind_env`]); callers running sweeps mutate one
    /// slot between calls and re-evaluate — no per-query allocation.
    ///
    /// The combining arithmetic reproduces [`CostModel::to_model`]'s
    /// expression tree operation-for-operation (see module docs), so
    /// divergence from the exact path comes only from the feature
    /// plans.
    pub fn eval_slots(&self, vals: &[f64]) -> f64 {
        let (mut o, mut a, mut b) = (0.0f64, 0.0f64, 0.0f64);
        for i in 0..self.features.len() {
            let v = self.coeffs[i] * self.features[i].eval(vals);
            match self.groups[i] {
                0 => o += v,
                1 => a += v,
                _ => b += v,
            }
        }
        match self.edge {
            None => (o + a) + b,
            Some(p_edge) => {
                let u = a - b;
                let denom = (a + b) + 1e-30;
                let s1 = ((p_edge * u / denom).tanh() + 1.0) / 2.0;
                (o + b) + u * s1
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::eval_with_stats;
    use crate::ir::DType;
    use crate::model::CostGroup;

    fn fit_for(cm: &CostModel, seed: u64) -> FitResult {
        let mut rng = crate::util::Rng::new(seed);
        let names: Vec<String> = cm.to_model().params();
        let params: Vec<f64> = names
            .iter()
            .map(|n| {
                if n == EDGE_PARAM {
                    rng.uniform_in(1.0, 1e4)
                } else {
                    // Log-uniform over realistic per-feature cost scales.
                    10f64.powf(rng.uniform_in(-9.0, -3.0))
                }
            })
            .collect();
        FitResult {
            param_names: names,
            params,
            residual: 0.0,
            iterations: 0,
            target: Target::Time,
            converged: true,
        }
    }

    fn rel_diff(x: f64, y: f64) -> f64 {
        (x - y).abs() / x.abs().max(y.abs()).max(f64::MIN_POSITIVE)
    }

    #[test]
    fn compiled_matches_exact_for_matmul_both_forms() {
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let stats = crate::stats::gather(&k, 32).unwrap();
        for (seed, nonlinear) in [(1u64, false), (2, true)] {
            let case = &crate::coordinator::expsets::eval_cases()[0];
            let cm = (case.model)("titan_v", nonlinear);
            let fit = fit_for(&cm, seed);
            let model = cm.to_model();
            let compiled = CompiledModel::compile(&cm, &fit, &stats).unwrap();
            assert_eq!(compiled.target(), Target::Time);
            for n in [1i64, 16, 1024, 2048, 3584] {
                let env: BTreeMap<String, i64> =
                    [("n".to_string(), n)].into_iter().collect();
                let exact = eval_with_stats(&model, &fit, &stats, &env).unwrap();
                let fast = compiled.eval_env(&env).unwrap();
                assert!(
                    rel_diff(exact, fast) <= COMPILED_REL_ERR_BOUND,
                    "nonlinear={nonlinear} n={n}: exact {exact} vs compiled {fast}"
                );
            }
        }
    }

    #[test]
    fn eval_slots_supports_in_place_sweeps() {
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let stats = crate::stats::gather(&k, 32).unwrap();
        let case = &crate::coordinator::expsets::eval_cases()[0];
        let cm = (case.model)("titan_v", true);
        let fit = fit_for(&cm, 7);
        let compiled = CompiledModel::compile(&cm, &fit, &stats).unwrap();
        let base: BTreeMap<String, i64> =
            [("n".to_string(), 1024i64)].into_iter().collect();
        let mut vals = compiled.bind_env(&base).unwrap();
        let slot = compiled.slot_of("n").unwrap();
        for n in [1024i64, 1280, 2048] {
            vals[slot] = n as f64;
            let swept = compiled.eval_slots(&vals);
            let env: BTreeMap<String, i64> =
                [("n".to_string(), n)].into_iter().collect();
            assert_eq!(swept, compiled.eval_env(&env).unwrap(), "n={n}");
        }
    }

    #[test]
    fn compile_errors_name_the_missing_piece() {
        let k = crate::uipick::apps::build_matmul(DType::F32, true, 16).unwrap();
        let stats = crate::stats::gather(&k, 32).unwrap();
        let cm = CostModel::new("titan_v", true).term(
            "madd",
            "f_op_float32_madd",
            CostGroup::OnChip,
        );
        // Missing the term's parameter entirely.
        let empty = FitResult {
            param_names: vec![],
            params: vec![],
            residual: 0.0,
            iterations: 0,
            target: Target::Time,
            converged: true,
        };
        let err = CompiledModel::compile(&cm, &empty, &stats).unwrap_err();
        assert!(err.contains("p_madd"), "{err}");
        // Nonlinear fit without p_edge.
        let no_edge = FitResult {
            param_names: vec!["p_madd".into()],
            params: vec![1e-6],
            residual: 0.0,
            iterations: 0,
            target: Target::Time,
            converged: true,
        };
        let err = CompiledModel::compile(&cm, &no_edge, &stats).unwrap_err();
        assert!(err.contains(EDGE_PARAM), "{err}");
        // Unbound size variable at eval time, named in the error.
        let fit = fit_for(&cm, 3);
        let compiled = CompiledModel::compile(&cm, &fit, &stats).unwrap();
        let err = compiled.eval_env(&BTreeMap::new()).unwrap_err();
        assert!(err.contains("'n'"), "{err}");
    }
}
